//! Table-I workload driver: compress the full ResNet-32 with TTD,
//! Tucker and TRD across an accuracy sweep, reporting compression
//! ratio / parameter count / reconstruction error per method — the
//! data behind `cargo bench --bench table1_td_comparison`.
//!
//! Run: `cargo run --release --example compress_resnet32 [--eps 0.12]`

use tt_edge::metrics::Table;
use tt_edge::sim::workload::{compress_model, synthetic_model};
use tt_edge::trace::NullSink;
use tt_edge::ttd::{trd, tucker};
use tt_edge::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let seed: u64 = args.parse_opt("seed").unwrap_or(42);
    let sweep: Vec<f32> = match args.opt("eps") {
        Some(e) => vec![e.parse().expect("bad --eps")],
        None => vec![0.06, 0.09, 0.12, 0.18],
    };
    let layers = synthetic_model(seed, 3.55, 0.035);
    let dense = tt_edge::model::param_count();
    let conv_dense: usize = layers.iter().map(|(l, _)| l.numel()).sum();

    let mut t = Table::new(
        "ResNet-32 compression sweep (whole model, incl. dense bn/fc)",
        &["eps", "method", "recon err", "ratio", "#params"],
    );
    for eps in sweep {
        // TTD (Algorithm 1)
        let out = compress_model(&layers, eps, &mut NullSink);
        t.row(&[
            format!("{eps:.2}"),
            "TTD".into(),
            format!("{:.3}", out.max_rel_err),
            format!("{:.2}x", out.compression_ratio),
            out.final_params.to_string(),
        ]);
        // Tucker (HOSVD)
        let (mut p, mut e) = (0usize, 0.0f32);
        for (l, w) in &layers {
            let x = w.reshape(&l.tt_dims());
            let d = tucker::decompose(&x, eps);
            p += d.param_count();
            e = e.max(tucker::relative_error(&x, &d));
        }
        let fin = dense - conv_dense + p;
        t.row(&[
            format!("{eps:.2}"),
            "Tucker".into(),
            format!("{e:.3}"),
            format!("{:.2}x", dense as f64 / fin as f64),
            fin.to_string(),
        ]);
        // TRD (TR-SVD)
        let (mut p, mut e) = (0usize, 0.0f32);
        for (l, w) in &layers {
            let x = w.reshape(&l.tt_dims());
            let d = trd::decompose(&x, eps);
            p += d.param_count();
            e = e.max(trd::relative_error(&x, &d));
        }
        let fin = dense - conv_dense + p;
        t.row(&[
            format!("{eps:.2}"),
            "TRD".into(),
            format!("{e:.3}"),
            format!("{:.2}x", dense as f64 / fin as f64),
            fin.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("paper Table I: Tucker 2.8x | TRD 2.7x | TTD 3.4x (0.14M params)");
}
