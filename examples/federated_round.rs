//! END-TO-END DRIVER (Fig. 1): proves all three layers compose.
//!
//! 1. **Train** — ResNet-32 (0.47 M params) is trained for a few
//!    hundred SGD steps on a synthetic 10-class image corpus, running
//!    the AOT-exported `resnet32_sgd_b8` graph (L2 JAX fwd+bwd, lowered
//!    through the L1 Pallas-bearing pipeline) on the PJRT CPU client
//!    from rust — python is never executed.
//! 2. **Compress** — the trained conv tensors become the global model
//!    of a federated fleet; each edge node's TTD compression runs
//!    through the real Algorithm-1 numerics while the SoC simulator
//!    accounts cycles + energy on TT-Edge vs Baseline silicon.
//! 3. **Reconstruct & evaluate** — the leader decodes the TT cores,
//!    and the reconstructed model is re-evaluated through the
//!    `resnet32_fwd_b4` artifact: accuracy retention is the paper's
//!    Table-I accuracy column, measured rather than transcribed.
//!
//! Run: `make artifacts && cargo run --release --example federated_round`
//! The reference run is recorded in EXPERIMENTS.md.

use anyhow::Result;
use tt_edge::coordinator::{Coordinator, FederatedConfig};
use tt_edge::model::{conv_layers, ParamStore};
use tt_edge::runtime::{Engine, Value};
use tt_edge::sim::SocConfig;
use tt_edge::ttd::Tensor;
use tt_edge::util::cli::Args;
use tt_edge::util::Rng;

/// Synthetic 10-class corpus: class-conditional means + noise, so the
/// model has real structure to learn (and accuracy is meaningful).
fn make_corpus(rng: &mut Rng, n: usize) -> (Vec<Vec<f32>>, Vec<Vec<i32>>) {
    let mut class_means: Vec<Vec<f32>> = Vec::new();
    for _ in 0..10 {
        class_means.push(rng.normal_vec(32 * 32 * 3).iter().map(|v| v * 0.8).collect());
    }
    let mut batches_x = Vec::new();
    let mut batches_y = Vec::new();
    for _ in 0..n {
        let mut x = Vec::with_capacity(8 * 32 * 32 * 3);
        let mut y = Vec::with_capacity(8);
        for _ in 0..8 {
            let c = rng.below(10);
            y.push(c as i32);
            for m in &class_means[c] {
                x.push(m + 0.35 * rng.normal() as f32);
            }
        }
        batches_x.push(x);
        batches_y.push(y);
    }
    (batches_x, batches_y)
}

fn accuracy(eng: &mut Engine, params: &ParamStore, xs: &[Vec<f32>], ys: &[Vec<i32>]) -> Result<f64> {
    let mut correct = 0usize;
    let mut total = 0usize;
    for (x8, y8) in xs.iter().zip(ys) {
        // fwd artifact is batch-4: split each batch of 8.
        for half in 0..2 {
            let xi = &x8[half * 4 * 3072..(half + 1) * 4 * 3072];
            let mut inputs: Vec<Value> =
                params.values.iter().map(Value::from_tensor).collect();
            inputs.push(Value::F32 { shape: vec![4, 32, 32, 3], data: xi.to_vec() });
            let out = eng.run("resnet32_fwd_b4", &inputs)?;
            let logits = out[0].as_f32()?;
            for b in 0..4 {
                let row = &logits[b * 10..(b + 1) * 10];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred as i32 == y8[half * 4 + b] {
                    correct += 1;
                }
                total += 1;
            }
        }
    }
    Ok(correct as f64 / total as f64)
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps: usize = args.parse_opt("steps").unwrap_or(240);
    let eps: f32 = args.parse_opt("eps").unwrap_or(0.08);
    let nodes: usize = args.parse_opt("nodes").unwrap_or(3);
    let rounds: usize = args.parse_opt("rounds").unwrap_or(2);

    let mut eng = Engine::load_default()?;
    println!("PJRT platform: {} | artifacts: {}", eng.platform(), eng.entry_names().len());

    // ------------------------------------------------- 1. training
    let mut rng = Rng::new(2026);
    let (xs, ys) = make_corpus(&mut rng, 8); // 64 samples
    let mut params = ParamStore::init_resnet32(1);
    // "Pretrained" conv weights: planted low-TT-rank structure scaled
    // to He magnitude (trained CNNs are TT-compressible — that is the
    // phenomenon the paper exploits; He-random ones are not, see
    // DESIGN.md section 2). Fine-tuning then preserves near-low-rank.
    for l in conv_layers() {
        let mut crng = rng.fork(0x1000 + l.param_index as u64);
        let planted =
            tt_edge::sim::workload::synthetic_trained_conv(&mut crng, &l, 3.55, 0.03);
        let fan_in = (l.shape[0] * l.shape[1] * l.shape[2]) as f32;
        let target_rms = (2.0 / fan_in).sqrt();
        let rms = planted.frobenius() / (planted.numel() as f32).sqrt();
        let scale = target_rms / rms.max(1e-12);
        let shape = params.values[l.param_index].shape.clone();
        params.values[l.param_index] = Tensor::from_vec(
            &shape,
            planted.data.iter().map(|v| v * scale).collect(),
        );
    }
    let lr = 0.5f32;
    println!("\n[1] fine-tuning ResNet-32 ({} params) for {steps} SGD steps (PJRT, batch 8)", params.total_params());
    let t0 = std::time::Instant::now();
    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;
    for step in 0..steps {
        let b = step % xs.len();
        let mut inputs: Vec<Value> = params.values.iter().map(Value::from_tensor).collect();
        inputs.push(Value::F32 { shape: vec![8, 32, 32, 3], data: xs[b].clone() });
        inputs.push(Value::I32 { shape: vec![8], data: ys[b].clone() });
        inputs.push(Value::scalar_f32(lr));
        let out = eng.run("resnet32_sgd_b8", &inputs)?;
        // outputs: params' (95) + loss
        for (t, v) in params.values.iter_mut().zip(&out[..out.len() - 1]) {
            t.data.copy_from_slice(v.as_f32()?);
        }
        let loss = out.last().unwrap().as_f32()?[0];
        if step == 0 {
            first_loss = loss;
        }
        last_loss = loss;
        if step % 40 == 0 || step + 1 == steps {
            println!("  step {step:>4}: loss {loss:.4}");
        }
    }
    println!(
        "  loss {first_loss:.3} -> {last_loss:.3} in {:.1}s",
        t0.elapsed().as_secs_f64()
    );
    let acc_trained = accuracy(&mut eng, &params, &xs, &ys)?;
    println!("  trained accuracy on corpus: {:.1}%", acc_trained * 100.0);

    // --------------------------------- 2. federated compression
    println!("\n[2] federated compression: {nodes} nodes x {rounds} rounds, eps={eps}");
    let layers = conv_layers();
    let global: Vec<_> = layers
        .iter()
        .map(|l| {
            let t = params.values[l.param_index].reshape(&l.tt_dims());
            (l.clone(), t)
        })
        .collect();
    for soc in [SocConfig::baseline(), SocConfig::tt_edge()] {
        let name = soc.name();
        let cfg = FederatedConfig { nodes, rounds: 1, eps, drift: 0.0, soc, ..Default::default() };
        let mut c = Coordinator::with_global(cfg, global.clone());
        let r = c.round(0);
        println!(
            "  {name:<9} per-node compression {:>8.1} ms / {:>7.1} mJ | {:.2}x comm. reduction | agg err {:.4}",
            r.mean_compress_ms, r.mean_compress_mj, r.communication_reduction, r.aggregate_rel_err
        );
    }

    // ------------------------- 3. reconstruct + evaluate accuracy
    println!("\n[3] accuracy retention after TTD round-trip");
    let cfg = FederatedConfig {
        nodes,
        rounds,
        eps,
        drift: 0.0,
        soc: SocConfig::tt_edge(),
        ..Default::default()
    };
    let mut c = Coordinator::with_global(cfg, global.clone());
    let reports = c.run();
    // write reconstructed convs back into the parameter store
    let mut compressed = params.clone();
    for (l, (_, w)) in layers.iter().zip(&c.global) {
        compressed.values[l.param_index] =
            Tensor::from_vec(&compressed.values[l.param_index].shape.clone(), w.data.clone());
    }
    let acc_compressed = accuracy(&mut eng, &compressed, &xs, &ys)?;
    let total_wire: usize = reports.iter().map(|r| r.wire_bytes).sum();
    let conv_params: usize = layers.iter().map(|l| l.numel()).sum();
    println!(
        "  accuracy {:.1}% -> {:.1}% (delta {:+.1} pts)",
        acc_trained * 100.0,
        acc_compressed * 100.0,
        (acc_compressed - acc_trained) * 100.0
    );
    println!(
        "  wire traffic {:.0} KB over {} node-rounds (dense would be {:.0} KB)",
        total_wire as f64 / 1024.0,
        nodes * rounds,
        (nodes * rounds * 4 * conv_params) as f64 / 1024.0
    );
    println!("\nfederated_round e2e OK");
    Ok(())
}
