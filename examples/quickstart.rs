//! Quickstart: compress one conv kernel with Algorithm-1 TTD, check
//! the reconstruction, and see what the TT-Edge SoC buys you.
//!
//! Run: `cargo run --release --example quickstart`

use tt_edge::sim::{HwTimeline, SimReport, SocConfig};
use tt_edge::trace::{TraceSink, VecSink};
use tt_edge::ttd::{decompose, reconstruct, relative_error, Tensor};
use tt_edge::util::Rng;

fn main() {
    // A "trained-like" 3x3x64x64 conv kernel (planted TT structure +
    // noise — see DESIGN.md section 2 for why).
    let layer = tt_edge::model::conv_layers().pop().unwrap();
    let mut rng = Rng::new(42);
    let w: Tensor =
        tt_edge::sim::workload::synthetic_trained_conv(&mut rng, &layer, 3.5, 0.03);
    println!("input tensor: {:?} ({} params)", w.shape, w.numel());

    // --- Algorithm 1: TTD with prescribed accuracy eps ------------
    let eps = 0.10;
    let mut trace = VecSink::default();
    let d = decompose(&w, eps, None, &mut trace);
    println!(
        "TT ranks {:?} -> {} params ({:.2}x compression)",
        d.ranks,
        d.param_count(),
        d.compression_ratio()
    );

    // --- Eq. (1)/(2): reconstruction -------------------------------
    let err = relative_error(&w, &d);
    println!("reconstruction error {err:.4} (budget eps = {eps})");
    assert!(err <= eps + 1e-3);
    let wr = reconstruct(&d);
    assert_eq!(wr.shape, w.shape);

    // --- The same operation stream on both SoCs --------------------
    for cfg in [SocConfig::baseline(), SocConfig::tt_edge()] {
        let name = cfg.name();
        let mut tl = HwTimeline::new(cfg);
        for op in &trace.ops {
            tl.op(*op);
        }
        let r = SimReport::from_timeline(&tl);
        println!(
            "{name:<9} compression of this layer: {:8.2} ms, {:7.2} mJ",
            r.total_ms, r.total_mj
        );
    }
    println!("quickstart OK");
}
