//! Quickstart: compress one conv kernel with Algorithm-1 TTD through
//! the `CompressionJob` builder, check the reconstruction, and see
//! what the TT-Edge SoC buys you — both SoCs costed in one streaming
//! pass, with an op-counting observer stacked on top.
//!
//! Run: `cargo run --release --example quickstart`

use tt_edge::sim::SocConfig;
use tt_edge::trace::CountingSink;
use tt_edge::ttd::{reconstruct, Tensor};
use tt_edge::util::Rng;
use tt_edge::CompressionJob;

fn main() {
    // A "trained-like" 3x3x64x64 conv kernel (planted TT structure +
    // noise — see DESIGN.md section 2 for why).
    let layer = tt_edge::model::conv_layers().pop().unwrap();
    let mut rng = Rng::new(42);
    let w: Tensor =
        tt_edge::sim::workload::synthetic_trained_conv(&mut rng, &layer, 3.5, 0.03);
    println!("input tensor: {:?} ({} params)", w.shape, w.numel());

    // --- Algorithm 1 + SoC costing, one builder, one pass ----------
    let eps = 0.10;
    let mut ops = CountingSink::default(); // observer: stacked, not forked
    let out = CompressionJob::new(&w)
        .eps(eps)
        .soc(SocConfig::baseline())
        .soc(SocConfig::tt_edge())
        .sink(&mut ops)
        .run()
        .expect("no cancel token");
    let d = out.decomp();
    println!(
        "TT ranks {:?} -> {} params ({:.2}x compression), {} hardware ops",
        d.ranks,
        d.param_count(),
        d.compression_ratio(),
        ops.ops
    );

    // --- Eq. (1)/(2): reconstruction -------------------------------
    let err = out.outcome.max_rel_err;
    println!("reconstruction error {err:.4} (budget eps = {eps})");
    assert!(err <= eps + 1e-3);
    let wr = reconstruct(d);
    assert_eq!(wr.shape, w.shape);

    // --- The same operation stream on both SoCs --------------------
    for r in &out.reports {
        println!(
            "{:<9} compression of this layer: {:8.2} ms, {:7.2} mJ",
            r.config_name, r.total_ms, r.total_mj
        );
    }
    println!("quickstart OK");
}
