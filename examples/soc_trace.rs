//! Print the modeled SoC pipeline + the simulated Table III.
//!
//! Demonstrates sink composition: one numerics pass streams through a
//! `Tee` of (multi-config cost fold, recorded trace) — the costs need
//! no buffer; the trace is kept only for the raw op aggregates below.
use tt_edge::sim::{format_table3, CostSink, SocConfig};
use tt_edge::trace::{HwOp, Phase, Tee, VecSink};
use tt_edge::sim::workload::{synthetic_model, compress_model};

fn main() {
    let layers = synthetic_model(42, 3.55, 0.035);
    let mut cost = CostSink::new(&[SocConfig::baseline(), SocConfig::tt_edge()]);
    let mut trace = VecSink::default();
    {
        let mut tee = Tee::new(&mut cost, &mut trace);
        let _ = compress_model(&layers, 0.12, &mut tee);
    }
    // raw per-phase op aggregates
    let mut phase = Phase::ReshapeEtc;
    let mut tiles_hbd = 0u64; let mut house_elems = 0u64; let mut vecdiv_elems = 0u64;
    let mut givens_elems = 0u64; let mut sort_cmps = 0u64; let mut reorder_elems = 0u64;
    let mut trunc_probes = 0u64; let mut reshape_elems = 0u64; let mut upd_elems = 0u64;
    let mut house_count = 0u64; let mut gemm_count_hbd = 0u64;
    for op in &trace.ops {
        match *op {
            HwOp::SetPhase(p) => phase = p,
            HwOp::Gemm { m, n, k } => {
                let t = tt_edge::sim::gemm::tiles(
                    tt_edge::sim::gemm::PE_TILE,
                    m as u64,
                    n as u64,
                    k as u64,
                );
                if phase == Phase::Hbd { tiles_hbd += t; gemm_count_hbd += 1; }
                if phase == Phase::UpdateSvdInput { upd_elems += (m*n) as u64; }
            }
            HwOp::HouseGen { len } => { house_elems += len as u64; house_count += 1; }
            HwOp::VecDiv { len } => vecdiv_elems += len as u64,
            HwOp::GivensRot { len } => givens_elems += len as u64,
            HwOp::Sort { n, .. } => sort_cmps += (n*(n.saturating_sub(1))/2) as u64,
            HwOp::ReorderBasis { rows, cols } => reorder_elems += (rows*cols) as u64,
            HwOp::Trunc { probes, .. } => trunc_probes += probes as u64,
            HwOp::Reshape { elems } => reshape_elems += elems as u64,
            _ => {}
        }
    }
    println!("tiles_hbd={tiles_hbd} gemms_hbd={gemm_count_hbd} house_count={house_count} house_elems={house_elems} vecdiv_elems={vecdiv_elems}");
    println!("givens_elems={givens_elems} sort_cmps={sort_cmps} reorder_elems={reorder_elems} trunc_probes={trunc_probes} reshape_elems={reshape_elems} upd_elems={upd_elems}");

    let reports = cost.reports();
    println!("{}", format_table3(&reports[0], &reports[1]));
}
