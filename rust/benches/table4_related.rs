//! TABLE IV — comparison with Qu et al. [21] (TCAD'21). Their column
//! is published data; ours is derived from the Table-II model.

use tt_edge::hw_model::related::{qu_tcad21, tt_edge};
use tt_edge::metrics::Table;

fn main() {
    let q = qu_tcad21();
    let e = tt_edge();
    let mut t = Table::new(
        "TABLE IV: proposed TT-Edge vs related technique",
        &["Resource Metrics", q.name, e.name],
    );
    t.row(&["Process technology".into(), format!("{} nm", q.process_nm), format!("{} nm", e.process_nm)]);
    t.row(&["Number of PEs".into(), format!("{} + {}", q.pes.0, q.pes.1), format!("{} + {}", e.pes.0, e.pes.1)]);
    t.row(&["On-chip memory".into(), format!("{} KB", q.on_chip_memory_kb), "128 KB + 320 KB".into()]);
    t.row(&["Arithmetic precision".into(), q.precision.into(), e.precision.into()]);
    t.row(&["Clock frequency".into(), format!("{} MHz", q.clock_mhz), format!("{} MHz", e.clock_mhz)]);
    t.row(&[
        "Power consumption".into(),
        format!("{:.2} W", q.power_mw / 1000.0),
        format!("{:.0} mW ({:.0} mW*)", e.power_mw, e.total_power_mw.unwrap()),
    ]);
    println!("{}", t.render());
    println!("(*total processor power)\n");

    assert!(q.power_mw / e.power_mw > 50.0, "power contrast lost");
    assert_eq!(e.pes, (64, 3));
    println!("table4 OK");
}
