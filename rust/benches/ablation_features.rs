//! Ablation: each TT-Edge mechanism toggled independently (DESIGN.md
//! section 4). Shows where the 1.7x / 40% actually comes from.
//!
//! One numerics pass, seven SoC configurations: the op stream folds
//! into a multi-config streaming `CostSink` as it is emitted — the
//! sink-combinator replacement for the old record-the-trace-then-
//! replay-per-config loop (no `Vec<HwOp>` is materialized).

use tt_edge::metrics::{f1, f2, Table};
use tt_edge::sim::workload::{compress_model, synthetic_model};
use tt_edge::sim::{CostSink, Features, SimReport, SocConfig};

fn main() {
    let variants: [(&str, fn(&mut Features)); 5] = [
        ("- hbd_acc", |f| f.hbd_acc = false),
        ("- direct_gemm_link", |f| f.direct_gemm_link = false),
        ("- spm_retention", |f| f.spm_retention = false),
        ("- hw_sort_trunc", |f| f.hw_sort_trunc = false),
        ("- clock_gating", |f| f.clock_gating = false),
    ];
    let mut configs = vec![SocConfig::baseline(), SocConfig::tt_edge()];
    for (_, tweak) in &variants {
        let mut f = Features::ALL_ON;
        tweak(&mut f);
        configs.push(SocConfig::tt_edge_with(f));
    }

    // one numerics run, every configuration costed online
    let layers = synthetic_model(42, 3.55, 0.035);
    let mut cost = CostSink::new(&configs);
    let _ = compress_model(&layers, 0.12, &mut cost);
    let reports = cost.reports();
    let base = &reports[0];
    let full = &reports[1];

    let mut t = Table::new(
        "Feature ablation (full ResNet-32 TTD workload)",
        &["config", "T (ms)", "E (mJ)", "speedup", "E saving %"],
    );
    let row = |t: &mut Table, name: &str, r: &SimReport| {
        t.row(&[
            name.into(),
            f2(r.total_ms),
            f2(r.total_mj),
            format!("{:.2}x", base.total_ms / r.total_ms),
            f1((1.0 - r.total_mj / base.total_mj) * 100.0),
        ]);
    };
    row(&mut t, "Baseline", base);
    row(&mut t, "TT-Edge (full)", full);
    for (i, (name, _)) in variants.iter().enumerate() {
        row(&mut t, name, &reports[2 + i]);
    }
    println!("{}", t.render());

    // sanity: removing any feature must not make it faster than full
    for (i, (name, _)) in variants.iter().enumerate() {
        let r = &reports[2 + i];
        assert!(
            r.total_ms >= full.total_ms - 1e-9 && r.total_mj >= full.total_mj - 1e-6,
            "{name} improved on full TT-Edge?"
        );
    }
    println!("ablation_features OK");
}
