//! Ablation: each TT-Edge mechanism toggled independently (DESIGN.md
//! section 4). Shows where the 1.7x / 40% actually comes from.

use tt_edge::metrics::{f1, f2, Table};
use tt_edge::sim::workload::{compress_model, synthetic_model};
use tt_edge::sim::{Features, HwTimeline, SimReport, SocConfig};
use tt_edge::trace::{TraceSink, VecSink};

fn main() {
    // one shared trace: the numerics never change across features
    let layers = synthetic_model(42, 3.55, 0.035);
    let mut trace = VecSink::default();
    let _ = compress_model(&layers, 0.12, &mut trace);
    let replay = |cfg: SocConfig| -> SimReport {
        let mut tl = HwTimeline::new(cfg);
        for op in &trace.ops {
            tl.op(*op);
        }
        SimReport::from_timeline(&tl)
    };

    let base = replay(SocConfig::baseline());
    let full = replay(SocConfig::tt_edge());

    let variants: [(&str, Box<dyn Fn(&mut Features)>); 5] = [
        ("- hbd_acc", Box::new(|f| f.hbd_acc = false)),
        ("- direct_gemm_link", Box::new(|f| f.direct_gemm_link = false)),
        ("- spm_retention", Box::new(|f| f.spm_retention = false)),
        ("- hw_sort_trunc", Box::new(|f| f.hw_sort_trunc = false)),
        ("- clock_gating", Box::new(|f| f.clock_gating = false)),
    ];

    let mut t = Table::new(
        "Feature ablation (full ResNet-32 TTD workload)",
        &["config", "T (ms)", "E (mJ)", "speedup", "E saving %"],
    );
    let row = |t: &mut Table, name: &str, r: &SimReport| {
        t.row(&[
            name.into(),
            f2(r.total_ms),
            f2(r.total_mj),
            format!("{:.2}x", base.total_ms / r.total_ms),
            f1((1.0 - r.total_mj / base.total_mj) * 100.0),
        ]);
    };
    row(&mut t, "Baseline", &base);
    row(&mut t, "TT-Edge (full)", &full);
    for (name, tweak) in &variants {
        let mut f = Features::ALL_ON;
        tweak(&mut f);
        let r = replay(SocConfig::tt_edge_with(f));
        row(&mut t, name, &r);
    }
    println!("{}", t.render());

    // sanity: removing any feature must not make it faster than full
    for (name, tweak) in &variants {
        let mut f = Features::ALL_ON;
        tweak(&mut f);
        let r = replay(SocConfig::tt_edge_with(f));
        assert!(
            r.total_ms >= full.total_ms - 1e-9 && r.total_mj >= full.total_mj - 1e-6,
            "{name} improved on full TT-Edge?"
        );
    }
    println!("ablation_features OK");
}
