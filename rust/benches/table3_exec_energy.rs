//! TABLE III — the headline experiment: execution time and energy of
//! TTD-based ResNet-32 compression on the baseline vs TT-Edge SoCs,
//! with the paper's numbers side by side.

use tt_edge::metrics::{f1, f2, Table};
use tt_edge::sim::report::paper;
use tt_edge::sim::{compress_resnet32, SocConfig};
use tt_edge::trace::Phase;

fn main() {
    let t0 = std::time::Instant::now();
    let (out, reports) =
        compress_resnet32(42, 0.12, &[SocConfig::baseline(), SocConfig::tt_edge()]);
    let wall = t0.elapsed().as_secs_f64();
    let (base, tte) = (&reports[0], &reports[1]);

    println!(
        "workload: full ResNet-32 TTD compression ({:.2}x, {} -> {} params); sim wall time {wall:.2}s\n",
        out.compression_ratio, out.model_dense_params, out.final_params
    );

    let mut t = Table::new(
        "TABLE III: T_exec (ms) and E (mJ), simulated vs paper",
        &["TTD procedure", "Base T", "(paper)", "Base E", "(paper)", "TTE T", "(paper)", "TTE E", "(paper)"],
    );
    for (i, phase) in Phase::ALL.iter().enumerate() {
        let b = base.phase(*phase);
        let e = tte.phase(*phase);
        let (pb, pbt, pbe) = (paper::BASE[i].0, paper::BASE[i].1, paper::BASE[i].2);
        assert_eq!(pb, *phase);
        let (ptt, pte) = (paper::TTE[i].1, paper::TTE[i].2);
        t.row(&[
            phase.label().into(),
            f2(b.time_ms), f2(pbt), f2(b.energy_mj), f2(pbe),
            f2(e.time_ms), f2(ptt), f2(e.energy_mj), f2(pte),
        ]);
    }
    t.row(&[
        "Total".into(),
        f2(base.total_ms), f2(paper::BASE_TOTAL.0),
        f2(base.total_mj), f2(paper::BASE_TOTAL.1),
        f2(tte.total_ms), f2(paper::TTE_TOTAL.0),
        f2(tte.total_mj), f2(paper::TTE_TOTAL.1),
    ]);
    println!("{}", t.render());

    let speedup = base.total_ms / tte.total_ms;
    let saving = (1.0 - tte.total_mj / base.total_mj) * 100.0;
    println!(
        "headline: speedup {:.2}x (paper {:.2}x) | energy reduction {}% (paper {}%)",
        speedup, paper::SPEEDUP, f1(saving), f1(paper::ENERGY_REDUCTION_PCT)
    );
    println!(
        "HBD speedup {:.2}x (paper 2.05x) | Sort&Trunc speedup {:.2}x (paper 9.96x)",
        base.phase(Phase::Hbd).time_ms / tte.phase(Phase::Hbd).time_ms,
        base.phase(Phase::SortTrunc).time_ms / tte.phase(Phase::SortTrunc).time_ms,
    );
    assert!((speedup - paper::SPEEDUP).abs() / paper::SPEEDUP < 0.05);
    assert!((saving - paper::ENERGY_REDUCTION_PCT).abs() < 2.0);
    println!("table3 OK");
}
