//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS/README.md):
//! L3 numerics (rank-1 updates, HBD, GK, full-layer TTD), the blocked
//! vs naive GEMM kernel, the vectorized vs reference microkernel (the
//! PR-7 >= 1.5x self-assert, bit-identity checked inline), the seeded
//! randomized range-finder vs the exact SVD (the ISSUE-9 >= 2x
//! self-assert at sketch 32), the serial
//! vs panel-parallel bidiagonalization, the serial vs parallel
//! multi-layer pipeline (the ISSUE-1 acceptance numbers), and the
//! simulator costing loop (streaming CostSink vs recorded-trace
//! replay vs the serial/parallel program folds).
//!
//! Run: `cargo bench --bench hotpath` (or `cargo run --release` on the
//! compiled bench binary). The "ALL-LAYER PIPELINE" section prints the
//! parallel-over-serial speedup, and the run writes the machine-
//! readable numbers to `EXPERIMENTS/BENCH_pipeline.json` (schema in
//! `EXPERIMENTS/README.md`).

use std::collections::BTreeMap;
use std::path::PathBuf;

use tt_edge::metrics::bench::{black_box, time_it};
use tt_edge::pipeline;
use tt_edge::sim::workload::{compress_model, synthetic_model};
use tt_edge::sim::{CostSink, SocConfig};
use tt_edge::trace::{NullSink, VecSink};
use tt_edge::ttd::svd::bidiag::{
    bidiagonalize, bidiagonalize_reference, panel_threads, set_panel_threads,
};
use tt_edge::ttd::svd::house::{apply_left, house};
use tt_edge::ttd::svd::randomized::rsvd;
use tt_edge::ttd::svd::svd;
use tt_edge::ttd::tensor::{matmul_reference, matmul_vectorized};
use tt_edge::ttd::{decompose, Matrix, Tensor, TtSpec};
use tt_edge::util::json::Json;
use tt_edge::util::Rng;

fn main() {
    let mut rng = Rng::new(1);

    // ---- kernel: blocked vs naive matmul --------------------------
    let a = Matrix::from_vec(512, 512, rng.normal_vec(512 * 512));
    let b = Matrix::from_vec(512, 512, rng.normal_vec(512 * 512));
    let blocked = time_it("matmul 512^3 (blocked ikj)", 1, 5, || {
        black_box(a.matmul(&b));
    });
    println!("{}", blocked.report());
    let naive = time_it("matmul 512^3 (naive ijk)", 1, 3, || {
        black_box(a.matmul_naive(&b));
    });
    println!("{}", naive.report());
    println!(
        "  -> blocked kernel speedup over naive: {:.2}x\n",
        naive.mean_ms / blocked.mean_ms
    );

    // ---- kernel: vectorized vs reference microkernel --------------
    // The PR-7 acceptance number: the lane-blocked microkernel must
    // beat the pinned scalar loop by >= 1.5x on a 512-class GEMM, and
    // the two must agree to the bit (the kernel-fallback contract —
    // see tests/kernel_equivalence.rs for the shape sweep).
    let (gm, gk, gn) = (512, 512, 512);
    let mut out_v = vec![0.0f32; gm * gn];
    let mut out_r = vec![0.0f32; gm * gn];
    matmul_vectorized(gm, gk, gn, &a.data, &b.data, &mut out_v);
    matmul_reference(gm, gk, gn, &a.data, &b.data, &mut out_r);
    assert_eq!(out_v, out_r, "vectorized kernel must be bit-identical to reference");
    let gemm_simd = time_it("matmul_acc 512^3 (vectorized kernel)", 1, 5, || {
        out_v.fill(0.0);
        matmul_vectorized(gm, gk, gn, &a.data, &b.data, &mut out_v);
        black_box(out_v[0]);
    });
    println!("{}", gemm_simd.report());
    let gemm_ref = time_it("matmul_acc 512^3 (reference kernel)", 1, 5, || {
        out_r.fill(0.0);
        matmul_reference(gm, gk, gn, &a.data, &b.data, &mut out_r);
        black_box(out_r[0]);
    });
    println!("{}", gemm_ref.report());
    let gemm_speedup = gemm_ref.mean_ms / gemm_simd.mean_ms;
    println!("  -> vectorized kernel speedup over reference: {gemm_speedup:.2}x\n");
    assert!(
        gemm_speedup >= 1.5,
        "vectorized microkernel must be >= 1.5x over matmul_reference on 512^3, got {gemm_speedup:.2}x"
    );

    // ---- rsvd vs exact SVD (ISSUE 9) ------------------------------
    // A tall transformer-shaped unfolding (bert-base d_model rows
    // after the balanced reshape): the seeded randomized range-finder
    // at sketch 32 (rank cap 24 + oversample 8) replaces the O(mn^2)
    // dense HBD with O(mnl) sketch work and a 32-row projected SVD.
    let tall = Matrix::from_vec(768, 256, rng.normal_vec(768 * 256));
    let rsvd_exact = time_it("svd 768x256 (exact HBD+GK)", 1, 5, || {
        black_box(svd(&tall, &mut NullSink));
    });
    println!("{}", rsvd_exact.report());
    let rsvd_sketch = time_it("rsvd 768x256 (sketch 32 = cap 24 + oversample 8)", 1, 5, || {
        black_box(rsvd(&tall, 32, 42, &mut NullSink));
    });
    println!("{}", rsvd_sketch.report());
    let rsvd_speedup = rsvd_exact.mean_ms / rsvd_sketch.mean_ms;
    println!("  -> rsvd speedup over exact at sketch 32: {rsvd_speedup:.2}x\n");
    assert!(
        rsvd_speedup >= 2.0,
        "randomized range-finder must be >= 2x over the exact SVD at sketch 32 on 768x256, got {rsvd_speedup:.2}x"
    );

    // fused rank-1 update (the HBD inner loop), 576x64
    let mut m = Matrix::from_vec(576, 64, rng.normal_vec(576 * 64));
    let x: Vec<f32> = (0..576).map(|r| m.get(r, 0)).collect();
    let h = house(&x);
    println!("{}", time_it("apply_left 576x64", 10, 200, || {
        apply_left(black_box(&mut m), 0, 1, &h.v, h.beta);
    }).report());

    // full HBD of the dominant working matrix: blocked compact-WY
    // accumulation (the default) vs the per-reflector rank-1 reference
    let a2 = Matrix::from_vec(576, 64, rng.normal_vec(576 * 64));
    let hbd_blocked = time_it("bidiagonalize 576x64 (blocked WY)", 1, 10, || {
        black_box(bidiagonalize(&a2, &mut NullSink));
    });
    println!("{}", hbd_blocked.report());
    let hbd_reference = time_it("bidiagonalize 576x64 (per-reflector)", 1, 10, || {
        black_box(bidiagonalize_reference(&a2, &mut NullSink));
    });
    println!("{}", hbd_reference.report());
    println!(
        "  -> blocked accumulation speedup over per-reflector: {:.2}x\n",
        hbd_reference.mean_ms / hbd_blocked.mean_ms
    );

    // ---- in-layer panel parallelism (row-band WY accumulation) ----
    // A tall HBD shape where the accumulation GEMMs dominate; the
    // row-band split must agree with serial to the bit (it leaves
    // every k-accumulation chain intact).
    let host_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let a3 = Matrix::from_vec(1024, 192, rng.normal_vec(1024 * 192));
    let saved_width = panel_threads();
    let par_width = host_threads.clamp(2, 8);
    set_panel_threads(1);
    let serial_bd = bidiagonalize(&a3, &mut NullSink);
    let hbd_par_serial = time_it("bidiagonalize 1024x192 (panel x1)", 1, 5, || {
        black_box(bidiagonalize(&a3, &mut NullSink));
    });
    println!("{}", hbd_par_serial.report());
    set_panel_threads(par_width);
    let par_bd = bidiagonalize(&a3, &mut NullSink);
    let hbd_par = time_it(&format!("bidiagonalize 1024x192 (panel x{par_width})"), 1, 5, || {
        black_box(bidiagonalize(&a3, &mut NullSink));
    });
    println!("{}", hbd_par.report());
    set_panel_threads(saved_width);
    assert_eq!(serial_bd.u.data, par_bd.u.data, "panel-parallel U must match serial bit-for-bit");
    assert_eq!(serial_bd.b.data, par_bd.b.data, "panel-parallel B must match serial bit-for-bit");
    assert_eq!(serial_bd.vt.data, par_bd.vt.data, "panel-parallel Vt must match serial bit-for-bit");
    println!(
        "  -> panel x{par_width} speedup over panel x1: {:.2}x (bit-identical)\n",
        hbd_par_serial.mean_ms / hbd_par.mean_ms
    );

    // full-layer TTD (9,64,64)
    let layer = tt_edge::model::conv_layers().pop().unwrap();
    let mut r2 = Rng::new(2);
    let w: Tensor = tt_edge::sim::workload::synthetic_trained_conv(&mut r2, &layer, 3.5, 0.03);
    let spec = TtSpec::eps(0.12);
    println!("{}", time_it("ttd decompose 9x64x64", 1, 10, || {
        black_box(decompose(&w, &spec, &mut NullSink));
    }).report());

    // ---- ALL-LAYER PIPELINE: serial vs parallel -------------------
    // The ISSUE-1 acceptance metric: wall-clock to compress every
    // ResNet-32 conv layer, seed serial path vs the work-stealing
    // pipeline (identical decompositions + merged trace; see
    // tests/golden_trace.rs for the equivalence assertions).
    let layers = synthetic_model(42, 3.55, 0.035);
    let serial = time_it("resnet32 all-layer TTD (serial)", 1, 5, || {
        black_box(compress_model(&layers, 0.12, &mut NullSink));
    });
    println!("{}", serial.report());
    let mut par_results = Vec::new();
    for threads in [2, 4, host_threads] {
        if threads < 2 || par_results.iter().any(|(t, _)| *t == threads) {
            continue;
        }
        let res = time_it(
            &format!("resnet32 all-layer TTD (parallel x{threads})"),
            1,
            5,
            || {
                black_box(pipeline::compress_model_parallel(
                    &layers,
                    0.12,
                    threads,
                    &mut NullSink,
                ));
            },
        );
        println!("{}", res.report());
        par_results.push((threads, res));
    }
    for (threads, res) in &par_results {
        println!(
            "  -> pipeline x{threads} speedup over serial: {:.2}x",
            serial.mean_ms / res.mean_ms
        );
    }
    println!();

    // ---- simulator costing: record-then-replay vs streaming -------
    // Two comparable end-to-end shapes (same numerics, same both-SoC
    // costing): (a) decompose into a VecSink then replay the trace,
    // (b) decompose straight into the streaming CostSink. Plus the
    // isolated replay loop for raw costing throughput.
    let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
    let mut trace = VecSink::default();
    let _ = decompose(&w, &spec, &mut trace);
    let n_ops = trace.ops.len();
    let replay = time_it("sim replay only (per layer trace, both SoCs)", 2, 50, || {
        let mut cost = CostSink::new(&configs);
        trace.replay(&mut cost);
        black_box(cost.timelines()[1].cycles.total());
    });
    println!("{}  ({} ops, {:.1} Mops/s)", replay.report(), n_ops,
        n_ops as f64 / (replay.mean_ms / 1e3) / 1e6);
    let record_replay = time_it("ttd + record trace + replay (both SoCs)", 1, 10, || {
        let mut rec = VecSink::default();
        let _ = decompose(&w, &spec, &mut rec);
        let mut cost = CostSink::new(&configs);
        rec.replay(&mut cost);
        black_box(cost.timelines()[1].cycles.total());
    });
    println!("{}", record_replay.report());
    let streaming = time_it("ttd + streaming cost (both SoCs, no buffer)", 1, 10, || {
        let mut cost = CostSink::new(&configs);
        let _ = decompose(&w, &spec, &mut cost);
        black_box(cost.timelines()[1].cycles.total());
    });
    println!("{}", streaming.report());
    // record-once / replay-many: the RLE program's O(#runs) run-fold
    // vs the per-op replay loop above (same both-SoC cost bank)
    let mut rec = tt_edge::trace::RecordingSink::default();
    let _ = decompose(&w, &spec, &mut rec);
    let mut program = tt_edge::trace::OpProgram::default();
    program.push_layer(rec);
    let program_fold = time_it("sim program fold (RLE runs, both SoCs)", 2, 50, || {
        let mut cost = CostSink::new(&configs);
        cost.fold_program(&program);
        black_box(cost.timelines()[1].cycles.total());
    });
    println!(
        "{}  ({} runs for {} ops)",
        program_fold.report(),
        program.run_count(),
        program.op_count()
    );
    // parallel program fold: a ResNet-32-sized 31-segment program,
    // serial run-fold vs the work-stealing per-layer fold (absorbed in
    // layer order — bit-identical, asserted here on the totals)
    let mut rec31 = tt_edge::trace::RecordingSink::default();
    let _ = decompose(&w, &spec, &mut rec31);
    let mut big = tt_edge::trace::OpProgram::default();
    for _ in 0..31 {
        big.push_layer(rec31.clone());
    }
    let mut fold_serial = CostSink::new(&configs);
    fold_serial.fold_program(&big);
    let mut fold_par = CostSink::new(&configs);
    fold_par.fold_program_parallel(&big, host_threads);
    assert_eq!(
        fold_serial.timelines()[1].cycles.total(),
        fold_par.timelines()[1].cycles.total(),
        "parallel program fold must be bit-identical to serial"
    );
    let fold_par_bench = time_it(
        &format!("sim program fold x{host_threads} (31 segments, both SoCs)"),
        2,
        50,
        || {
            let mut cost = CostSink::new(&configs);
            cost.fold_program_parallel(&big, host_threads);
            black_box(cost.timelines()[1].cycles.total());
        },
    );
    println!("{}  ({} segments)", fold_par_bench.report(), big.layer_count());

    // ---- machine-readable artifact (EXPERIMENTS/BENCH_pipeline.json)
    let mut obj = BTreeMap::new();
    obj.insert("bench".into(), Json::from("hotpath"));
    obj.insert("workload".into(), Json::from("resnet32 all-layer TTD, eps=0.12, seed=42"));
    obj.insert("host_threads".into(), Json::from(host_threads));
    obj.insert("matmul_naive_ms".into(), Json::from(naive.mean_ms));
    obj.insert("matmul_blocked_ms".into(), Json::from(blocked.mean_ms));
    obj.insert(
        "matmul_blocked_speedup".into(),
        Json::from(naive.mean_ms / blocked.mean_ms),
    );
    obj.insert("gemm_simd_ms".into(), Json::from(gemm_simd.mean_ms));
    obj.insert("gemm_reference_ms".into(), Json::from(gemm_ref.mean_ms));
    obj.insert("gemm_simd_speedup".into(), Json::from(gemm_speedup));
    obj.insert("rsvd_exact_ms".into(), Json::from(rsvd_exact.mean_ms));
    obj.insert("rsvd_ms".into(), Json::from(rsvd_sketch.mean_ms));
    obj.insert("rsvd_speedup".into(), Json::from(rsvd_speedup));
    obj.insert("hbd_panel_par_serial_ms".into(), Json::from(hbd_par_serial.mean_ms));
    obj.insert("hbd_panel_par_ms".into(), Json::from(hbd_par.mean_ms));
    obj.insert(
        "hbd_panel_par_speedup".into(),
        Json::from(hbd_par_serial.mean_ms / hbd_par.mean_ms),
    );
    obj.insert("hbd_panel_par_threads".into(), Json::from(par_width));
    obj.insert("pipeline_serial_ms".into(), Json::from(serial.mean_ms));
    let par: Vec<Json> = par_results
        .iter()
        .map(|(threads, res)| {
            let mut m = BTreeMap::new();
            m.insert("threads".into(), Json::from(*threads));
            m.insert("ms".into(), Json::from(res.mean_ms));
            m.insert("speedup_vs_serial".into(), Json::from(serial.mean_ms / res.mean_ms));
            Json::Obj(m)
        })
        .collect();
    obj.insert("pipeline_parallel".into(), Json::Arr(par));
    obj.insert("hbd_blocked_ms".into(), Json::from(hbd_blocked.mean_ms));
    obj.insert("hbd_reference_ms".into(), Json::from(hbd_reference.mean_ms));
    obj.insert(
        "hbd_blocked_speedup".into(),
        Json::from(hbd_reference.mean_ms / hbd_blocked.mean_ms),
    );
    obj.insert("sim_replay_only_ms".into(), Json::from(replay.mean_ms));
    obj.insert("sim_program_fold_ms".into(), Json::from(program_fold.mean_ms));
    obj.insert("sim_fold_par_ms".into(), Json::from(fold_par_bench.mean_ms));
    obj.insert("ttd_record_then_replay_ms".into(), Json::from(record_replay.mean_ms));
    obj.insert("ttd_streaming_cost_ms".into(), Json::from(streaming.mean_ms));
    let path: PathBuf =
        [env!("CARGO_MANIFEST_DIR"), "..", "EXPERIMENTS", "BENCH_pipeline.json"]
            .iter()
            .collect();
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(&path, Json::Obj(obj).render() + "\n") {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", path.display()),
    }
}
