//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS/README.md):
//! L3 numerics (rank-1 updates, HBD, GK, full-layer TTD), the blocked
//! vs naive GEMM kernel, the serial vs parallel multi-layer pipeline
//! (the ISSUE-1 acceptance numbers), and the simulator costing loop
//! (streaming CostSink vs recorded-trace replay).
//!
//! Run: `cargo bench --bench hotpath` (or `cargo run --release` on the
//! compiled bench binary). The "ALL-LAYER PIPELINE" section prints the
//! parallel-over-serial speedup, and the run writes the machine-
//! readable numbers to `EXPERIMENTS/BENCH_pipeline.json` (schema in
//! `EXPERIMENTS/README.md`).

use std::collections::BTreeMap;
use std::path::PathBuf;

use tt_edge::metrics::bench::{black_box, time_it};
use tt_edge::pipeline;
use tt_edge::sim::workload::{compress_model, synthetic_model};
use tt_edge::sim::{CostSink, SocConfig};
use tt_edge::trace::{NullSink, VecSink};
use tt_edge::ttd::svd::bidiag::{bidiagonalize, bidiagonalize_reference};
use tt_edge::ttd::svd::house::{apply_left, house};
use tt_edge::ttd::{decompose, Matrix, Tensor, TtSpec};
use tt_edge::util::json::Json;
use tt_edge::util::Rng;

fn main() {
    let mut rng = Rng::new(1);

    // ---- kernel: blocked vs naive matmul --------------------------
    let a = Matrix::from_vec(512, 512, rng.normal_vec(512 * 512));
    let b = Matrix::from_vec(512, 512, rng.normal_vec(512 * 512));
    let blocked = time_it("matmul 512^3 (blocked ikj)", 1, 5, || {
        black_box(a.matmul(&b));
    });
    println!("{}", blocked.report());
    let naive = time_it("matmul 512^3 (naive ijk)", 1, 3, || {
        black_box(a.matmul_naive(&b));
    });
    println!("{}", naive.report());
    println!(
        "  -> blocked kernel speedup over naive: {:.2}x\n",
        naive.mean_ms / blocked.mean_ms
    );

    // fused rank-1 update (the HBD inner loop), 576x64
    let mut m = Matrix::from_vec(576, 64, rng.normal_vec(576 * 64));
    let x: Vec<f32> = (0..576).map(|r| m.get(r, 0)).collect();
    let h = house(&x);
    println!("{}", time_it("apply_left 576x64", 10, 200, || {
        apply_left(black_box(&mut m), 0, 1, &h.v, h.beta);
    }).report());

    // full HBD of the dominant working matrix: blocked compact-WY
    // accumulation (the default) vs the per-reflector rank-1 reference
    let a2 = Matrix::from_vec(576, 64, rng.normal_vec(576 * 64));
    let hbd_blocked = time_it("bidiagonalize 576x64 (blocked WY)", 1, 10, || {
        black_box(bidiagonalize(&a2, &mut NullSink));
    });
    println!("{}", hbd_blocked.report());
    let hbd_reference = time_it("bidiagonalize 576x64 (per-reflector)", 1, 10, || {
        black_box(bidiagonalize_reference(&a2, &mut NullSink));
    });
    println!("{}", hbd_reference.report());
    println!(
        "  -> blocked accumulation speedup over per-reflector: {:.2}x\n",
        hbd_reference.mean_ms / hbd_blocked.mean_ms
    );

    // full-layer TTD (9,64,64)
    let layer = tt_edge::model::conv_layers().pop().unwrap();
    let mut r2 = Rng::new(2);
    let w: Tensor = tt_edge::sim::workload::synthetic_trained_conv(&mut r2, &layer, 3.5, 0.03);
    let spec = TtSpec::eps(0.12);
    println!("{}", time_it("ttd decompose 9x64x64", 1, 10, || {
        black_box(decompose(&w, &spec, &mut NullSink));
    }).report());

    // ---- ALL-LAYER PIPELINE: serial vs parallel -------------------
    // The ISSUE-1 acceptance metric: wall-clock to compress every
    // ResNet-32 conv layer, seed serial path vs the work-stealing
    // pipeline (identical decompositions + merged trace; see
    // tests/golden_trace.rs for the equivalence assertions).
    let layers = synthetic_model(42, 3.55, 0.035);
    let host_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let serial = time_it("resnet32 all-layer TTD (serial)", 1, 5, || {
        black_box(compress_model(&layers, 0.12, &mut NullSink));
    });
    println!("{}", serial.report());
    let mut par_results = Vec::new();
    for threads in [2, 4, host_threads] {
        if threads < 2 || par_results.iter().any(|(t, _)| *t == threads) {
            continue;
        }
        let res = time_it(
            &format!("resnet32 all-layer TTD (parallel x{threads})"),
            1,
            5,
            || {
                black_box(pipeline::compress_model_parallel(
                    &layers,
                    0.12,
                    threads,
                    &mut NullSink,
                ));
            },
        );
        println!("{}", res.report());
        par_results.push((threads, res));
    }
    for (threads, res) in &par_results {
        println!(
            "  -> pipeline x{threads} speedup over serial: {:.2}x",
            serial.mean_ms / res.mean_ms
        );
    }
    println!();

    // ---- simulator costing: record-then-replay vs streaming -------
    // Two comparable end-to-end shapes (same numerics, same both-SoC
    // costing): (a) decompose into a VecSink then replay the trace,
    // (b) decompose straight into the streaming CostSink. Plus the
    // isolated replay loop for raw costing throughput.
    let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
    let mut trace = VecSink::default();
    let _ = decompose(&w, &spec, &mut trace);
    let n_ops = trace.ops.len();
    let replay = time_it("sim replay only (per layer trace, both SoCs)", 2, 50, || {
        let mut cost = CostSink::new(&configs);
        trace.replay(&mut cost);
        black_box(cost.timelines()[1].cycles.total());
    });
    println!("{}  ({} ops, {:.1} Mops/s)", replay.report(), n_ops,
        n_ops as f64 / (replay.mean_ms / 1e3) / 1e6);
    let record_replay = time_it("ttd + record trace + replay (both SoCs)", 1, 10, || {
        let mut rec = VecSink::default();
        let _ = decompose(&w, &spec, &mut rec);
        let mut cost = CostSink::new(&configs);
        rec.replay(&mut cost);
        black_box(cost.timelines()[1].cycles.total());
    });
    println!("{}", record_replay.report());
    let streaming = time_it("ttd + streaming cost (both SoCs, no buffer)", 1, 10, || {
        let mut cost = CostSink::new(&configs);
        let _ = decompose(&w, &spec, &mut cost);
        black_box(cost.timelines()[1].cycles.total());
    });
    println!("{}", streaming.report());
    // record-once / replay-many: the RLE program's O(#runs) run-fold
    // vs the per-op replay loop above (same both-SoC cost bank)
    let mut rec = tt_edge::trace::RecordingSink::default();
    let _ = decompose(&w, &spec, &mut rec);
    let mut program = tt_edge::trace::OpProgram::default();
    program.push_layer(rec);
    let program_fold = time_it("sim program fold (RLE runs, both SoCs)", 2, 50, || {
        let mut cost = CostSink::new(&configs);
        cost.fold_program(&program);
        black_box(cost.timelines()[1].cycles.total());
    });
    println!(
        "{}  ({} runs for {} ops)",
        program_fold.report(),
        program.run_count(),
        program.op_count()
    );

    // ---- machine-readable artifact (EXPERIMENTS/BENCH_pipeline.json)
    let mut obj = BTreeMap::new();
    obj.insert("bench".into(), Json::from("hotpath"));
    obj.insert("workload".into(), Json::from("resnet32 all-layer TTD, eps=0.12, seed=42"));
    obj.insert("host_threads".into(), Json::from(host_threads));
    obj.insert("matmul_naive_ms".into(), Json::from(naive.mean_ms));
    obj.insert("matmul_blocked_ms".into(), Json::from(blocked.mean_ms));
    obj.insert(
        "matmul_blocked_speedup".into(),
        Json::from(naive.mean_ms / blocked.mean_ms),
    );
    obj.insert("pipeline_serial_ms".into(), Json::from(serial.mean_ms));
    let par: Vec<Json> = par_results
        .iter()
        .map(|(threads, res)| {
            let mut m = BTreeMap::new();
            m.insert("threads".into(), Json::from(*threads));
            m.insert("ms".into(), Json::from(res.mean_ms));
            m.insert("speedup_vs_serial".into(), Json::from(serial.mean_ms / res.mean_ms));
            Json::Obj(m)
        })
        .collect();
    obj.insert("pipeline_parallel".into(), Json::Arr(par));
    obj.insert("hbd_blocked_ms".into(), Json::from(hbd_blocked.mean_ms));
    obj.insert("hbd_reference_ms".into(), Json::from(hbd_reference.mean_ms));
    obj.insert(
        "hbd_blocked_speedup".into(),
        Json::from(hbd_reference.mean_ms / hbd_blocked.mean_ms),
    );
    obj.insert("sim_replay_only_ms".into(), Json::from(replay.mean_ms));
    obj.insert("sim_program_fold_ms".into(), Json::from(program_fold.mean_ms));
    obj.insert("ttd_record_then_replay_ms".into(), Json::from(record_replay.mean_ms));
    obj.insert("ttd_streaming_cost_ms".into(), Json::from(streaming.mean_ms));
    let path: PathBuf =
        [env!("CARGO_MANIFEST_DIR"), "..", "EXPERIMENTS", "BENCH_pipeline.json"]
            .iter()
            .collect();
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(&path, Json::Obj(obj).render() + "\n") {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", path.display()),
    }
}
