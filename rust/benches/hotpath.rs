//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! L3 numerics (rank-1 updates, HBD, GK, full-layer TTD) and the
//! simulator replay loop.

use tt_edge::metrics::bench::{black_box, time_it};
use tt_edge::sim::{HwTimeline, SocConfig};
use tt_edge::trace::{NullSink, TraceSink, VecSink};
use tt_edge::ttd::svd::bidiag::bidiagonalize;
use tt_edge::ttd::svd::house::{apply_left, house};
use tt_edge::ttd::{decompose, Matrix, Tensor};
use tt_edge::util::Rng;

fn main() {
    let mut rng = Rng::new(1);

    // matmul kernel (512x512)
    let a = Matrix::from_vec(512, 512, rng.normal_vec(512 * 512));
    let b = Matrix::from_vec(512, 512, rng.normal_vec(512 * 512));
    println!("{}", time_it("matmul 512^3", 1, 5, || {
        black_box(a.matmul(&b));
    }).report());

    // fused rank-1 update (the HBD inner loop), 576x64
    let mut m = Matrix::from_vec(576, 64, rng.normal_vec(576 * 64));
    let x: Vec<f32> = (0..576).map(|r| m.get(r, 0)).collect();
    let h = house(&x);
    println!("{}", time_it("apply_left 576x64", 10, 200, || {
        apply_left(black_box(&mut m), 0, 1, &h.v, h.beta);
    }).report());

    // full HBD of the dominant working matrix
    let a2 = Matrix::from_vec(576, 64, rng.normal_vec(576 * 64));
    println!("{}", time_it("bidiagonalize 576x64", 1, 10, || {
        black_box(bidiagonalize(&a2, &mut NullSink));
    }).report());

    // full-layer TTD (9,64,64)
    let layer = tt_edge::model::conv_layers().pop().unwrap();
    let mut r2 = Rng::new(2);
    let w: Tensor = tt_edge::sim::workload::synthetic_trained_conv(&mut r2, &layer, 3.5, 0.03);
    println!("{}", time_it("ttd decompose 9x64x64", 1, 10, || {
        black_box(decompose(&w, 0.12, None, &mut NullSink));
    }).report());

    // simulator replay throughput
    let mut trace = VecSink::default();
    let _ = decompose(&w, 0.12, None, &mut trace);
    let n_ops = trace.ops.len();
    let res = time_it("sim replay (per layer trace)", 2, 50, || {
        let mut tl = HwTimeline::new(SocConfig::tt_edge());
        for op in &trace.ops {
            tl.op(*op);
        }
        black_box(tl.cycles.total());
    });
    println!("{}  ({} ops, {:.1} Mops/s)", res.report(), n_ops,
        n_ops as f64 / (res.mean_ms / 1e3) / 1e6);
}
