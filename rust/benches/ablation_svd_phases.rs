//! Ablation: the paper's section-I profiling claim — on the edge
//! processor, bidiagonalization dominates SVD, ~3.6x the cost of
//! diagonalization — reproduced on the actual ResNet-32 working set.
//!
//! The ratio is workload-dependent: tall-skinny working matrices
//! (ResNet reshapes) are HBD-heavy, near-square random matrices are
//! QR-heavier. Both views are reported; the paper's number refers to
//! the TTD workload mix.

use tt_edge::metrics::{f2, Table};
use tt_edge::sim::workload::{compress_model, synthetic_model};
use tt_edge::sim::{CostSink, SocConfig};
use tt_edge::trace::Phase;
use tt_edge::ttd::svd::svd;
use tt_edge::ttd::Matrix;
use tt_edge::util::Rng;

/// HBD/QR time split of whatever streamed into a baseline-SoC cost
/// sink — no trace buffering, costs fold online.
fn phase_split(cost: &CostSink) -> (f64, f64) {
    let r = cost.reports().remove(0);
    (r.phase(Phase::Hbd).time_ms, r.phase(Phase::QrDiag).time_ms)
}

fn main() {
    // ---- the real workload: all 31 conv layers --------------------
    let layers = synthetic_model(42, 3.55, 0.035);
    let mut cost = CostSink::single(SocConfig::baseline());
    let _ = compress_model(&layers, 0.12, &mut cost);
    let (hbd_w, qr_w) = phase_split(&cost);

    // ---- per-shape view on representative matrices -----------------
    let mut rng = Rng::new(9);
    let shapes = [
        (144usize, 16usize),
        (576, 64),
        (1024, 64),
        (4096, 9),
        (64, 64), // near-square: QR-heavy corner
    ];
    let mut t = Table::new(
        "SVD phase split on the baseline SoC",
        &["matrix", "HBD ms", "QR ms", "HBD/QR"],
    );
    for (m, n) in shapes {
        let a = Matrix::from_vec(m, n, rng.normal_vec(m * n));
        let mut c = CostSink::single(SocConfig::baseline());
        let _ = svd(&a, &mut c);
        let (h, q) = phase_split(&c);
        t.row(&[format!("{m}x{n}"), f2(h), f2(q), f2(h / q)]);
    }
    t.row(&[
        "ResNet-32 TTD workload".into(),
        f2(hbd_w),
        f2(qr_w),
        f2(hbd_w / qr_w),
    ]);
    println!("{}", t.render());

    let ratio = hbd_w / qr_w;
    println!(
        "workload-weighted HBD/diagonalization ratio: {ratio:.2} (paper: ~3.6)"
    );
    assert!((2.8..4.4).contains(&ratio), "workload ratio {ratio}");
    println!("ablation_svd_phases OK");
}
