//! Serve-mode throughput: sustained requests/sec draining the same
//! JSONL request stream with and without the keyed `JobProgram` cache
//! (ISSUE 6 acceptance: cached must sustain >= 2x uncached rps).
//!
//! The stream is 24 requests over 3 unique (workload, TtSpec) keys —
//! the repeated-shape pattern a federated coordinator produces when
//! many edge nodes ask for the same compression. Uncached mode
//! (`cache_capacity: 0`) pays 24 numerics passes; cached mode pays 3
//! and replays the rest. Both drains must produce byte-identical
//! responses.
//!
//! Run: `cargo bench --bench serve_throughput`. Like the other benches
//! it prints its numbers, self-asserts the headline invariants, and
//! merges the machine-readable fields into
//! `EXPERIMENTS/BENCH_pipeline.json` (schema in
//! `EXPERIMENTS/README.md`). CI only compiles it (`--no-run`).

use std::collections::BTreeMap;
use std::path::PathBuf;

use tt_edge::metrics::bench::{black_box, time_it, BenchResult};
use tt_edge::serve::{parse_requests, serve, ServeConfig, ServeRequest};
use tt_edge::util::json::{parse, Json};

const UNIQUE_KEYS: u64 = 3;

/// The bench stream, through the same wire parser `serve --requests`
/// uses (so the bench also exercises the JSONL front door).
fn request_stream() -> Vec<ServeRequest> {
    let mut text = String::from("# serve_throughput bench stream: 24 requests, 3 keys\n");
    for i in 0..24 {
        text.push_str(match i % 3 {
            0 => "{\"workload\": \"tiny\", \"seed\": \"7\", \"eps\": 0.12}\n",
            1 => "{\"workload\": \"tiny\", \"seed\": \"7\", \"eps\": 0.2, \"rank_cap\": 8}\n",
            _ => "{\"workload\": \"tiny\", \"seed\": \"9\", \"eps\": 0.12}\n",
        });
    }
    parse_requests(&text).expect("bench stream parses")
}

fn rps(requests: usize, res: &BenchResult) -> f64 {
    requests as f64 / (res.mean_ms / 1e3)
}

fn main() {
    let requests = request_stream();
    let host_threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // ---- correctness first: cached == uncached, pass accounting ----
    let uncached_out = serve(
        &requests,
        &ServeConfig { workers: 1, cache_capacity: 0, ..ServeConfig::default() },
    );
    let cached_out = serve(
        &requests,
        &ServeConfig { workers: 1, cache_capacity: 16, ..ServeConfig::default() },
    );
    assert_eq!(uncached_out.numerics_passes, requests.len() as u64);
    assert_eq!(cached_out.numerics_passes, UNIQUE_KEYS, "one pass per unique key");
    for (a, b) in uncached_out.responses.iter().zip(&cached_out.responses) {
        assert_eq!(
            a.to_json().render(),
            b.to_json().render(),
            "cached drain diverged from uncached on request {}",
            a.index
        );
    }

    // ---- sustained rps, serial and parallel ------------------------
    let mut recorded: Option<(f64, f64)> = None;
    for workers in [1usize, host_threads] {
        let uncached = time_it(
            &format!("serve 24 reqs / 3 keys, uncached (x{workers})"),
            1,
            5,
            || {
                let out = serve(
                    &requests,
                    &ServeConfig { workers, cache_capacity: 0, ..ServeConfig::default() },
                );
                black_box(out.responses.len());
            },
        );
        println!("{}  ({:.1} req/s)", uncached.report(), rps(requests.len(), &uncached));
        let cached = time_it(
            &format!("serve 24 reqs / 3 keys, cached   (x{workers})"),
            1,
            5,
            || {
                let out = serve(
                    &requests,
                    &ServeConfig { workers, cache_capacity: 16, ..ServeConfig::default() },
                );
                black_box(out.responses.len());
            },
        );
        println!("{}  ({:.1} req/s)", cached.report(), rps(requests.len(), &cached));
        let speedup = rps(requests.len(), &cached) / rps(requests.len(), &uncached);
        println!("  -> cache speedup at x{workers}: {speedup:.2}x\n");
        // The acceptance bar: a cold cache still coalesces 24 requests
        // into 3 numerics passes, so sustained rps must clear 2x even
        // counting the misses inside the timed region.
        assert!(
            speedup >= 2.0,
            "cached serve must sustain >= 2x uncached rps at x{workers}, got {speedup:.2}x"
        );
        if workers == host_threads {
            recorded =
                Some((rps(requests.len(), &uncached), rps(requests.len(), &cached)));
        }
    }
    let (rps_uncached, rps_cached) = recorded.expect("host-thread run recorded");

    // ---- merge the machine-readable fields into the shared artifact
    // (read-modify-write: hotpath.rs owns the rest of the object)
    let path: PathBuf =
        [env!("CARGO_MANIFEST_DIR"), "..", "EXPERIMENTS", "BENCH_pipeline.json"]
            .iter()
            .collect();
    let mut obj = match std::fs::read_to_string(&path).ok().and_then(|t| parse(&t).ok())
    {
        Some(Json::Obj(m)) => m,
        _ => BTreeMap::new(),
    };
    obj.insert("serve_requests".into(), Json::from(requests.len()));
    obj.insert("serve_unique_keys".into(), Json::from(UNIQUE_KEYS as usize));
    obj.insert("serve_workers".into(), Json::from(host_threads));
    obj.insert("serve_rps_uncached".into(), Json::from(rps_uncached));
    obj.insert("serve_rps_cached".into(), Json::from(rps_cached));
    obj.insert(
        "serve_cache_speedup".into(),
        Json::from(rps_cached / rps_uncached),
    );
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(&path, Json::Obj(obj).render() + "\n") {
        Ok(()) => println!("merged serve_* fields into {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
    println!("serve_throughput OK");
}
