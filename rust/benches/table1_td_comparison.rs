//! TABLE I — TD method comparison on ResNet-32.
//!
//! Regenerates the paper's accuracy/ratio/#params table. Accuracy is
//! proxied by worst-layer reconstruction error (the paper's accuracy
//! column needs CIFAR-10 training; see DESIGN.md section 2 and the
//! federated_round e2e example for measured accuracy retention).

use tt_edge::metrics::{bench, Table};
use tt_edge::sim::workload::{compress_model, synthetic_model};
use tt_edge::trace::NullSink;
use tt_edge::ttd::{trd, tucker};

fn main() {
    let layers = synthetic_model(42, 3.55, 0.035);
    let dense = tt_edge::model::param_count();
    let conv_dense: usize = layers.iter().map(|(l, _)| l.numel()).sum();
    let eps = 0.12f32;

    let mut t = Table::new(
        "TABLE I: performance of TD methods for ResNet-32 (paper: Tucker 2.8x / TRD 2.7x / TTD 3.4x, 0.14M)",
        &["Method", "Recon err", "Comp. ratio", "Final #params", "paper ratio"],
    );
    t.row(&["Uncompressed".into(), "-".into(), "1.0x".into(), dense.to_string(), "1.0x".into()]);

    // Tucker
    let r = bench::time_it("tucker: full model", 0, 1, || {
        let (mut p, mut e) = (0usize, 0.0f32);
        for (l, w) in &layers {
            let x = w.reshape(&l.tt_dims());
            let d = tucker::decompose(&x, eps);
            p += d.param_count();
            e = e.max(tucker::relative_error(&x, &d));
        }
        bench::black_box((p, e));
    });
    let (mut p, mut e) = (0usize, 0.0f32);
    for (l, w) in &layers {
        let x = w.reshape(&l.tt_dims());
        let d = tucker::decompose(&x, eps);
        p += d.param_count();
        e = e.max(tucker::relative_error(&x, &d));
    }
    let fin = dense - conv_dense + p;
    t.row(&["Tucker [12]".into(), format!("{e:.3}"), format!("{:.1}x", dense as f64 / fin as f64), fin.to_string(), "2.8x".into()]);
    println!("{}", r.report());

    // TRD
    let (mut p, mut e) = (0usize, 0.0f32);
    let r = bench::time_it("trd: full model", 0, 1, || {
        let mut pp = 0usize;
        for (l, w) in &layers {
            pp += trd::decompose(&w.reshape(&l.tt_dims()), eps).param_count();
        }
        bench::black_box(pp);
    });
    for (l, w) in &layers {
        let x = w.reshape(&l.tt_dims());
        let d = trd::decompose(&x, eps);
        p += d.param_count();
        e = e.max(trd::relative_error(&x, &d));
    }
    let fin = dense - conv_dense + p;
    t.row(&["TRD [13]".into(), format!("{e:.3}"), format!("{:.1}x", dense as f64 / fin as f64), fin.to_string(), "2.7x".into()]);
    println!("{}", r.report());

    // TTD — sweep eps to the paper's operating point (3.4x)
    let mut best = None;
    for eps_c in [0.08f32, 0.10, 0.12, 0.14, 0.16] {
        let out = compress_model(&layers, eps_c, &mut NullSink);
        let d = (out.compression_ratio - 3.4).abs();
        if best.as_ref().map(|(bd, _, _)| d < *bd).unwrap_or(true) {
            best = Some((d, eps_c, out));
        }
    }
    let (_, eps_star, out) = best.unwrap();
    let r = bench::time_it("ttd: full model", 0, 1, || {
        bench::black_box(compress_model(&layers, eps_star, &mut NullSink).final_params);
    });
    t.row(&[
        format!("TTD (this work, eps={eps_star})"),
        format!("{:.3}", out.max_rel_err),
        format!("{:.1}x", out.compression_ratio),
        out.final_params.to_string(),
        "3.4x".into(),
    ]);
    println!("{}\n", r.report());
    println!("{}", t.render());

    // shape assertions: who wins, roughly by how much
    assert!(out.compression_ratio > 3.0, "TTD ratio {}", out.compression_ratio);
    assert!(out.compression_ratio > dense as f64 / fin as f64, "TTD must beat TRD");
    println!("table1 OK");
}
