//! DSE bench: how the streaming multi-config cost sink scales with
//! the number of candidate SoCs in a single numerics pass, and an
//! end-to-end frontier sweep over the feature space.
//!
//! Run: `cargo bench --bench dse_frontier`. Like the other benches it
//! prints its tables and self-asserts the headline invariants; CI only
//! compiles it (`cargo bench --no-run`).

use tt_edge::dse::{
    explore, explore_live, DesignSpace, ExploreConfig, SpaceKind, Strategy, Workload,
};
use tt_edge::metrics::bench::{black_box, time_it};
use tt_edge::sim::workload::{compress_model, synthetic_model};
use tt_edge::sim::{CostSink, SocConfig};

fn main() {
    // ---- multi-config costing scaling -----------------------------
    // One numerics pass, N timelines: the cost of adding candidates to
    // a sweep is the per-op fold, not a numerics re-run.
    let mut layers = synthetic_model(42, 3.55, 0.035);
    layers.truncate(6);
    let space = DesignSpace::new(SpaceKind::Full);
    for n_configs in [1usize, 8, 32] {
        let configs: Vec<SocConfig> =
            space.genomes()[..n_configs].iter().map(|&g| space.to_soc(g)).collect();
        let res = time_it(
            &format!("6-layer TTD + streaming cost x{n_configs} configs"),
            1,
            5,
            || {
                let mut cost = CostSink::new(&configs);
                let _ = compress_model(&layers, 0.12, &mut cost);
                black_box(cost.reports().len());
            },
        );
        println!("{}", res.report());
    }
    println!();

    // ---- end-to-end sweep: feature space, grid --------------------
    let cfg = ExploreConfig {
        workload: Workload::Resnet32,
        space: SpaceKind::Features,
        strategy: Strategy::Grid,
        budget: 32,
        seed: 42,
        eps: 0.12,
        method: tt_edge::ttd::SvdMethod::Exact,
        parallel: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    };
    let t0 = std::time::Instant::now();
    let out = explore(&cfg);
    println!(
        "explore: {} candidates in {:.0} ms wall\n",
        out.evaluated.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    println!("{}", out.frontier_table());

    // headline invariants: both paper anchors are frontier members and
    // TT-Edge clears the paper margins
    assert!(out.frontier.contains(&0), "baseline fell off the frontier");
    assert!(out.frontier.contains(&1), "tt-edge fell off the frontier");
    let tte = &out.evaluated[1];
    assert!(out.speedup(tte) >= 1.5, "speedup {}", out.speedup(tte));
    assert!(
        out.energy_reduction_pct(tte) >= 35.0,
        "energy reduction {}",
        out.energy_reduction_pct(tte)
    );
    println!();

    // ---- live vs replay: multi-generation evolve sweep ------------
    // The PR-5 acceptance metric: a seeded-evolutionary sweep with G
    // generations used to pay G identical numerics passes; the
    // record-once / replay-many driver pays exactly one. Budget 40 on
    // the full space spans 5 evolve generations over the ResNet-32
    // workload.
    let evolve_cfg = ExploreConfig {
        workload: Workload::Resnet32,
        space: SpaceKind::Full,
        strategy: Strategy::Evolve,
        budget: 40,
        seed: 42,
        eps: 0.12,
        method: tt_edge::ttd::SvdMethod::Exact,
        parallel: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    };
    let mut lived = None;
    let live = time_it("evolve 40 (live: numerics per generation)", 0, 3, || {
        lived = Some(black_box(explore_live(&evolve_cfg)));
    });
    println!("{}", live.report());
    let mut replayed = None;
    let replay = time_it("evolve 40 (record-once / replay-many)", 0, 3, || {
        replayed = Some(black_box(explore(&evolve_cfg)));
    });
    println!("{}", replay.report());
    let speedup = live.mean_ms / replay.mean_ms;
    println!("  -> replay speedup over live costing: {speedup:.2}x");

    let replayed = replayed.expect("timed at least once");
    let lived = lived.expect("timed at least once");
    assert_eq!(replayed.numerics_passes, 1, "replay driver re-ran the numerics");
    assert!(lived.numerics_passes >= 3, "live evolve should pay per generation");
    assert_eq!(
        replayed.sweep_json().render(),
        lived.sweep_json().render(),
        "replay sweep diverged from the live-costed artifact"
    );
    assert!(
        speedup >= 2.0,
        "record-once / replay-many must be >= 2x on a multi-generation sweep, got {speedup:.2}x"
    );
    println!("dse_frontier OK");
}
