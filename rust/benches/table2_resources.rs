//! TABLE II — resource usage + 45 nm power breakdown, with every
//! derived prose claim recomputed from the structured model.

use tt_edge::hw_model::{summarize, tt_edge_blocks};
use tt_edge::metrics::{f2, Table};

fn main() {
    let mut t = Table::new(
        "TABLE II: TT-Edge prototype resources (Genesys2) + power (45 nm PrimeTime model)",
        &["IP", "LUTs", "FFs", "Power (mW)"],
    );
    for b in tt_edge_blocks() {
        let name = if b.ttd_engine_specialized { format!("  TTD-Engine/{}", b.name) } else { b.name.to_string() };
        let p = match b.gated_power_mw {
            Some(g) => format!("{:.2} / {:.2}*", b.power_mw, g),
            None => f2(b.power_mw),
        };
        t.row(&[name, b.luts.to_string(), b.ffs.to_string(), p]);
    }
    println!("{}", t.render());
    println!("(*no clock gating / with clock gating)\n");

    let s = summarize();
    let mut d = Table::new("Derived claims vs paper prose", &["claim", "model", "paper"]);
    d.row(&["TT-Edge total power (mW)".into(), f2(s.total_power_mw), "178.23".into()]);
    d.row(&["baseline power (mW)".into(), f2(s.baseline_power_mw), "171.04".into()]);
    d.row(&["gated power (mW)".into(), f2(s.gated_power_mw), "169.96".into()]);
    d.row(&["power overhead (%)".into(), f2((s.total_power_mw / s.baseline_power_mw - 1.0) * 100.0), "~4".into()]);
    d.row(&["TTD-Engine LUT share (%)".into(), f2(s.ttd_engine_luts as f64 / s.total_luts as f64 * 100.0), "5.6".into()]);
    d.row(&["TTD-Engine FF share (%)".into(), f2(s.ttd_engine_ffs as f64 / s.total_ffs as f64 * 100.0), "7.7".into()]);
    println!("{}", d.render());

    assert!((s.total_power_mw - 178.23).abs() < 0.2);
    assert!((s.gated_power_mw - 169.96).abs() < 0.2);
    println!("table2 OK");
}
