//! Integration: fault-tolerant federated rounds under deterministic
//! chaos (ISSUE 2 tentpole suite).
//!
//! A seeded matrix of dropout x straggler x quorum configurations runs
//! short federated rounds on a truncated model and asserts the three
//! contracts the scheduler must keep:
//!
//! 1. the aggregate stays finite and `aggregate_rel_err` stays within
//!    the per-layer TTD budget under *partial* participation,
//! 2. participation arithmetic is conserved
//!    (`participants + late + dropped == scheduled`),
//! 3. identical `FaultPlan` seeds give byte-identical `RoundReport`s.

use tt_edge::coordinator::{Coordinator, FaultPlan, FederatedConfig, Link, RoundReport};

const SEEDS: [u64; 3] = [101, 202, 303];

fn chaos_cfg(fault_seed: u64, dropout: f64, straggler_mult: f64, quorum: usize) -> FederatedConfig {
    FederatedConfig {
        nodes: 4,
        rounds: 2,
        eps: 0.12,
        min_quorum: quorum,
        faults: FaultPlan {
            seed: fault_seed,
            dropout,
            straggler_mult,
            straggler_frac: 0.5,
            ..FaultPlan::default()
        },
        ..Default::default()
    }
}

fn run_truncated(cfg: FederatedConfig) -> (Coordinator, Vec<RoundReport>) {
    let mut c = Coordinator::new(cfg);
    c.global.truncate(4); // keep the chaos matrix fast
    let reports = c.run();
    (c, reports)
}

fn assert_round_contracts(r: &RoundReport, quorum: usize) {
    // participation arithmetic is conserved
    assert_eq!(
        r.participants + r.late + r.dropped,
        r.scheduled,
        "round {}: {} + {} + {} != {}",
        r.round,
        r.participants,
        r.late,
        r.dropped,
        r.scheduled
    );
    // the scheduler never closes below an achievable quorum
    let delivered = r.scheduled - r.dropped;
    let achievable = if quorum == 0 { delivered } else { quorum.min(delivered) };
    assert!(
        r.participants >= achievable,
        "round {}: participants {} < achievable quorum {achievable}",
        r.round,
        r.participants
    );
    // quorum_met reports exactly whether the *requested* quorum landed
    let requested = if quorum == 0 { r.scheduled } else { quorum };
    assert_eq!(r.quorum_met, r.participants >= requested, "round {}", r.round);
    if r.participants > 0 {
        // partial FedAvg renormalizes: the aggregate tracks the exact
        // average over the *same participants* within the TTD budget
        assert!(r.aggregate_rel_err.is_finite());
        assert!(
            r.aggregate_rel_err < 0.2,
            "round {}: agg err {} with {} participants",
            r.round,
            r.aggregate_rel_err,
            r.participants
        );
        assert!(r.communication_reduction > 1.0);
        assert!(r.wire_bytes > 0 && r.dense_bytes > r.wire_bytes);
    } else {
        assert_eq!(r.wire_bytes, 0);
        assert_eq!(r.aggregate_rel_err, 0.0);
    }
    assert!(r.deadline_ms.is_finite() && r.round_close_ms.is_finite());
    assert!(r.round_close_ms >= 0.0);
}

#[test]
fn chaos_matrix_keeps_the_aggregate_finite_and_bounded() {
    for &seed in &SEEDS {
        for dropout in [0.0, 0.35] {
            for straggler_mult in [1.0, 3.0] {
                for quorum in [0usize, 2] {
                    let (c, reports) =
                        run_truncated(chaos_cfg(seed, dropout, straggler_mult, quorum));
                    for r in &reports {
                        assert_round_contracts(r, quorum);
                    }
                    for (_, w) in &c.global {
                        assert!(
                            w.data.iter().all(|v| v.is_finite()),
                            "non-finite global after seed {seed} dropout {dropout} \
                             mult {straggler_mult} quorum {quorum}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn identical_fault_seeds_give_byte_identical_reports() {
    for &seed in &SEEDS {
        let cfg = chaos_cfg(seed, 0.35, 3.0, 2);
        let (_, a) = run_truncated(cfg.clone());
        let (_, b) = run_truncated(cfg);
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "seed {seed} not replayable");
    }
    // distinct seeds must actually explore distinct fault schedules
    let (_, a) = run_truncated(chaos_cfg(SEEDS[0], 0.35, 3.0, 2));
    let (_, b) = run_truncated(chaos_cfg(SEEDS[1], 0.35, 3.0, 2));
    assert_ne!(format!("{a:?}"), format!("{b:?}"), "fault seed has no effect");
}

#[test]
fn benign_plan_reports_full_participation() {
    // dropout=0, straggler-mult=1, quorum=all: the scheduler must look
    // exactly like the legacy all-or-nothing round (the golden test
    // pins the numeric values; this pins the participation shape).
    for &seed in &SEEDS {
        let (_, reports) = run_truncated(chaos_cfg(seed, 0.0, 1.0, 0));
        for r in &reports {
            assert_eq!(r.participants, r.scheduled);
            assert!(r.quorum_met);
            assert_eq!((r.dropped, r.late, r.retries, r.stragglers), (0, 0, 0, 0));
            assert!(r.round_transfer_ms <= r.deadline_ms);
            assert!(r.round_close_ms <= r.deadline_ms);
        }
    }
}

#[test]
fn universal_stragglers_reduce_to_quorum() {
    // Every node straggles 5x past a slack-1.0 deadline; with quorum 1
    // the leader admits exactly the first arrival and marks the rest
    // late. Fully deterministic — no probabilistic draws at frac 1.0.
    let mut cfg = chaos_cfg(7, 0.0, 5.0, 1);
    cfg.faults.straggler_frac = 1.0;
    cfg.rounds = 1;
    let (_, reports) = run_truncated(cfg);
    let r = &reports[0];
    assert_eq!(r.stragglers, r.scheduled);
    assert_eq!(r.participants, 1);
    assert_eq!(r.late, r.scheduled - 1);
    assert_eq!(r.dropped, 0);
    assert!(r.round_close_ms > r.deadline_ms);
    assert!(r.aggregate_rel_err < 0.2);
}

#[test]
fn total_link_loss_skips_the_round_without_corruption() {
    let mut cfg = chaos_cfg(9, 0.0, 1.0, 1);
    cfg.link = Link { loss: 1.0, max_retries: 2, ..Link::default() };
    cfg.rounds = 1;
    let mut c = Coordinator::new(cfg);
    c.global.truncate(4);
    let before: Vec<Vec<f32>> = c.global.iter().map(|(_, w)| w.data.clone()).collect();
    let r = c.round(0);
    assert_eq!(r.participants, 0);
    assert_eq!(r.dropped, r.scheduled);
    assert_eq!(r.wire_bytes, 0);
    assert_eq!(r.retries, r.scheduled * 3); // 1 + max_retries attempts each
    // the global model is untouched — a skipped round cannot corrupt it
    for ((_, w), b) in c.global.iter().zip(&before) {
        assert_eq!(&w.data, b);
    }
}

#[test]
fn lossy_link_retries_are_accounted_per_round() {
    let mut cfg = chaos_cfg(13, 0.0, 1.0, 0);
    cfg.link = Link { loss: 0.6, max_retries: 10, ..Link::default() };
    cfg.rounds = 2;
    let (c, reports) = run_truncated(cfg);
    let total_retries: usize = reports.iter().map(|r| r.retries).sum();
    let total_retrans: usize = reports.iter().map(|r| r.retrans_bytes).sum();
    // per-round tallies decompose the cumulative transport stats
    assert_eq!(total_retries, c.transport.retries);
    assert_eq!(total_retrans, c.transport.retrans_bytes);
    // at 60% loss over 8 node-rounds a clean sweep has probability
    // 0.4^8 ~ 7e-4, and the seed is pinned — chaos deterministically
    // fired
    assert!(total_retries > 0, "no retries at 60% loss");
    for r in &reports {
        assert_round_contracts(r, 0);
        // retry timeouts lengthen the slowest admitted transfer
        if r.retries > 0 && r.participants > 0 {
            assert!(r.round_transfer_ms > 0.0);
        }
    }
}
