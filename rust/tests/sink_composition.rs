//! Sink-composition contracts (ISSUE 3 satellite): the combinators in
//! `trace.rs` and the streaming `CostSink` must compose without
//! changing what any branch observes, and the streaming fold must be
//! bit-identical to the legacy record-then-replay costing — across
//! multiple seeds, both SoC variants, and serial + parallel widths.

use tt_edge::pipeline::{self, CancelToken};
use tt_edge::sim::workload::{compress_model, synthetic_model};
use tt_edge::sim::{CostSink, SocConfig};
use tt_edge::trace::{CountingSink, HwOp, Phase, SummarySink, Tee, VecSink};
use tt_edge::ttd::{decompose, Tensor, TtSpec};
use tt_edge::util::Rng;

fn small_model(seed: u64) -> Vec<(tt_edge::model::ConvLayer, Tensor)> {
    let mut layers = synthetic_model(seed, 3.55, 0.035);
    layers.truncate(4);
    layers
}

#[test]
fn tee_preserves_op_order_to_both_branches() {
    // Run the real numerics through a tee of two recorders: both
    // branches must see the exact stream a direct run emits.
    let mut rng = Rng::new(77);
    let w = Tensor::from_vec(&[4, 6, 6], rng.normal_vec(144));
    let spec = TtSpec::eps(0.15);

    let mut direct = VecSink::default();
    let _ = decompose(&w, &spec, &mut direct);

    let mut tee = Tee::new(VecSink::default(), VecSink::default());
    let _ = decompose(&w, &spec, &mut tee);
    let (a, b) = tee.into_inner();
    assert_eq!(a.ops, direct.ops);
    assert_eq!(b.ops, direct.ops);

    // nested tees fan out to three observers, same order everywhere
    let mut nested = Tee::new(VecSink::default(), Tee::new(VecSink::default(), VecSink::default()));
    let _ = decompose(&w, &spec, &mut nested);
    assert_eq!(nested.a.ops, direct.ops);
    assert_eq!(nested.b.a.ops, direct.ops);
    assert_eq!(nested.b.b.ops, direct.ops);
}

#[test]
fn counting_sink_total_equals_vecsink_len() {
    for seed in [1u64, 2, 3] {
        let layers = small_model(seed);
        let mut vec = VecSink::default();
        let _ = compress_model(&layers, 0.12, &mut vec);
        let mut count = CountingSink::default();
        let _ = compress_model(&layers, 0.12, &mut count);
        assert_eq!(count.ops as usize, vec.ops.len(), "seed={seed}");
        // and a summary's total agrees too
        let mut sum = SummarySink::default();
        vec.replay(&mut sum);
        assert_eq!(sum.total(), count.ops);
        assert_eq!(sum.count("SetPhase") as usize, vec.count(|o| matches!(o, HwOp::SetPhase(_))));
    }
}

#[test]
fn streaming_cost_equals_replay_across_seeds_and_socs() {
    // The tentpole acceptance property: the streaming CostSink fold
    // must produce bit-identical per-phase cycle/energy totals to a
    // VecSink-then-replay run — >= 3 seeds x both SoC variants.
    for seed in [11u64, 22, 33] {
        let layers = small_model(seed);
        let configs = [SocConfig::baseline(), SocConfig::tt_edge()];

        let mut streamed = CostSink::new(&configs);
        let out_s = compress_model(&layers, 0.12, &mut streamed);

        let mut trace = VecSink::default();
        let out_r = compress_model(&layers, 0.12, &mut trace);
        let mut replayed = CostSink::new(&configs);
        trace.replay(&mut replayed);

        assert_eq!(out_s.final_params, out_r.final_params, "seed={seed}");
        for (a, b) in streamed.timelines().iter().zip(replayed.timelines()) {
            for p in Phase::ALL {
                assert_eq!(a.cycles.get(p), b.cycles.get(p), "seed={seed} {p:?}");
            }
            assert_eq!(a.stats.gemms, b.stats.gemms);
            assert_eq!(a.stats.house_gens, b.stats.house_gens);
        }
        for (a, b) in streamed.reports().iter().zip(&replayed.reports()) {
            assert_eq!(a.total_ms, b.total_ms, "seed={seed} {}", a.config_name);
            assert_eq!(a.total_mj, b.total_mj, "seed={seed} {}", a.config_name);
            for (pa, pb) in a.phases.iter().zip(&b.phases) {
                assert_eq!(pa.cycles, pb.cycles);
                assert_eq!(pa.time_ms, pb.time_ms);
                assert_eq!(pa.energy_mj, pb.energy_mj);
            }
        }
    }
}

#[test]
fn parallel_streaming_merge_equals_serial_stream() {
    // Layer-order merge of per-layer cost summaries == one serial
    // stream, at every thread count (u64 accumulators).
    for seed in [5u64, 6, 7] {
        let layers = small_model(seed);
        let jobs: Vec<_> = layers.iter().map(|(l, w)| (l, w)).collect();
        let configs = [SocConfig::baseline(), SocConfig::tt_edge()];

        let mut serial = CostSink::new(&configs);
        let _ = compress_model(&layers, 0.12, &mut serial);

        for threads in [1, 2, 4] {
            let batch = pipeline::compress_layers_costed(
                &jobs,
                &TtSpec::eps(0.12),
                threads,
                &CancelToken::default(),
                &configs,
            )
            .unwrap();
            for (a, b) in batch.cost.timelines().iter().zip(serial.timelines()) {
                for p in Phase::ALL {
                    assert_eq!(
                        a.cycles.get(p),
                        b.cycles.get(p),
                        "seed={seed} threads={threads} {p:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn tee_of_cost_and_trace_changes_neither_branch() {
    // Stacking observers must not perturb the cost fold, and the
    // recorded branch must equal a direct recording.
    let layers = small_model(13);
    let configs = [SocConfig::baseline(), SocConfig::tt_edge()];

    let mut cost_only = CostSink::new(&configs);
    let _ = compress_model(&layers, 0.12, &mut cost_only);
    let mut trace_only = VecSink::default();
    let _ = compress_model(&layers, 0.12, &mut trace_only);

    let mut cost = CostSink::new(&configs);
    let mut trace = VecSink::default();
    {
        let mut tee = Tee::new(&mut cost, &mut trace);
        let _ = compress_model(&layers, 0.12, &mut tee);
    }
    assert_eq!(trace.ops, trace_only.ops);
    for (a, b) in cost.timelines().iter().zip(cost_only.timelines()) {
        assert_eq!(a.cycles.total(), b.cycles.total());
    }
}

#[test]
fn phase_scoped_guard_counts_match_full_stream_attribution() {
    // A PhaseScoped(HBD) counting sink must count exactly the ops the
    // full stream attributes to HBD (plus its SetPhase brackets).
    let mut rng = Rng::new(55);
    let w = Tensor::from_vec(&[4, 6, 6], rng.normal_vec(144));
    let mut full = VecSink::default();
    let _ = decompose(&w, &TtSpec::eps(0.15), &mut full);

    let mut scoped = tt_edge::trace::PhaseScoped::new(Phase::Hbd, VecSink::default());
    full.replay(&mut scoped);
    let scoped = scoped.into_inner();

    // oracle: walk the stream tracking the phase by hand
    let mut phase = Phase::ReshapeEtc;
    let mut want = Vec::new();
    for op in &full.ops {
        match op {
            HwOp::SetPhase(p) => {
                phase = *p;
                if *p == Phase::Hbd {
                    want.push(*op);
                }
            }
            _ if phase == Phase::Hbd => want.push(*op),
            _ => {}
        }
    }
    assert_eq!(scoped.ops, want);
    assert!(scoped.ops.iter().any(|o| matches!(o, HwOp::HouseGen { .. })));
    // HBD never contains sort/trunc ops
    assert_eq!(scoped.count(|o| matches!(o, HwOp::Sort { .. })), 0);
}
