//! DSE engine contracts:
//!
//! 1. Pareto properties — the frontier contains no dominated point,
//!    and every pruned point is dominated (or duplicate-shadowed) by a
//!    frontier member.
//! 2. Seeded-search determinism — the same `seed` produces
//!    byte-identical frontier/sweep JSON across serial and parallel
//!    evaluation, for every strategy, over several seeds.
//! 3. The paper anchors — on the ResNet-32 workload `ALL_ON` must
//!    dominate `ALL_OFF` on both cycles and energy, sit on the
//!    frontier, and clear the paper's headline margins (>=1.5x cycles,
//!    >=35% energy).

use tt_edge::dse::{
    dominates, explore, explore_live, pareto_front, ExploreConfig, Objectives, SpaceKind,
    Strategy, Workload,
};
use tt_edge::dse::pareto::pruned_by;
use tt_edge::ttd::SvdMethod;
use tt_edge::util::Rng;

fn random_points(seed: u64, n: usize) -> Vec<Objectives> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| Objectives {
            cycles: 1_000 + rng.below(500) as u64,
            energy_mj: 10.0 + (rng.below(400) as f64) / 10.0,
            area_luts: 100_000 + rng.below(20_000) as u64,
        })
        .collect()
}

#[test]
fn frontier_has_no_dominated_member() {
    for seed in [1u64, 2, 3] {
        let pts = random_points(seed, 200);
        let front = pareto_front(&pts);
        assert!(!front.is_empty());
        for &i in &front {
            for &j in &front {
                assert!(
                    !dominates(&pts[j], &pts[i]),
                    "seed {seed}: frontier member {j} dominates frontier member {i}"
                );
            }
        }
    }
}

#[test]
fn every_pruned_point_is_dominated_by_a_frontier_member() {
    for seed in [4u64, 5, 6] {
        let pts = random_points(seed, 200);
        let front = pareto_front(&pts);
        for i in 0..pts.len() {
            if front.contains(&i) {
                continue;
            }
            let witness = pruned_by(&pts, i).expect("pruned point must have a pruner");
            // the witness itself need not be frontier; but some
            // frontier member must dominate or duplicate-shadow i
            let covered = front.iter().any(|&f| {
                dominates(&pts[f], &pts[i]) || (pts[f] == pts[i] && f < i)
            });
            assert!(covered, "seed {seed}: point {i} pruned by {witness} but uncovered");
        }
    }
}

fn cfg(strategy: Strategy, seed: u64, parallel: usize) -> ExploreConfig {
    ExploreConfig {
        workload: Workload::Tiny,
        space: SpaceKind::Full,
        strategy,
        budget: 6,
        seed,
        eps: 0.2,
        method: SvdMethod::Exact,
        parallel,
    }
}

#[test]
fn seeded_search_is_byte_identical_across_parallel_widths() {
    for strategy in [Strategy::Grid, Strategy::Random, Strategy::Evolve] {
        for seed in [1u64, 2, 3] {
            let serial = explore(&cfg(strategy, seed, 1));
            let wide = explore(&cfg(strategy, seed, 4));
            assert_eq!(
                serial.report_json().render(),
                wide.report_json().render(),
                "{strategy:?} seed {seed}: frontier JSON diverged across widths"
            );
            assert_eq!(
                serial.sweep_json().render(),
                wide.sweep_json().render(),
                "{strategy:?} seed {seed}: sweep JSON diverged across widths"
            );
        }
    }
}

#[test]
fn replay_artifacts_are_byte_identical_to_the_live_costed_path() {
    // The PR-5 acceptance pin: explore (record-once / replay-many)
    // must render exactly the JSON the pre-cache live-costed driver
    // renders — every strategy, several seeds, serial and parallel.
    for strategy in [Strategy::Grid, Strategy::Random, Strategy::Evolve] {
        for seed in [1u64, 2, 3] {
            for parallel in [1usize, 4] {
                let replayed = explore(&cfg(strategy, seed, parallel));
                let live = explore_live(&cfg(strategy, seed, parallel));
                assert_eq!(
                    replayed.sweep_json().render(),
                    live.sweep_json().render(),
                    "{strategy:?} seed {seed} parallel {parallel}: sweep JSON diverged"
                );
                assert_eq!(
                    replayed.report_json().render(),
                    live.report_json().render(),
                    "{strategy:?} seed {seed} parallel {parallel}: frontier JSON diverged"
                );
            }
        }
    }
}

#[test]
fn evolve_costs_exactly_one_numerics_pass() {
    // Budget 20 on the full space spans >= 3 evolve generations; the
    // record-once driver must still run the numerics exactly once,
    // while the live reference pays per generation.
    let big = ExploreConfig {
        workload: Workload::Tiny,
        space: SpaceKind::Full,
        strategy: Strategy::Evolve,
        budget: 20,
        seed: 11,
        eps: 0.2,
        method: SvdMethod::Exact,
        parallel: 1,
    };
    let replayed = explore(&big);
    assert_eq!(replayed.numerics_passes, 1);
    assert_eq!(replayed.evaluated.len(), 20);
    let live = explore_live(&big);
    assert!(live.numerics_passes >= 3, "passes {}", live.numerics_passes);
    assert_eq!(replayed.sweep_json().render(), live.sweep_json().render());
    // grid and random are single-batch: one pass on both paths
    for strategy in [Strategy::Grid, Strategy::Random] {
        assert_eq!(explore(&cfg(strategy, 1, 1)).numerics_passes, 1);
        assert_eq!(explore_live(&cfg(strategy, 1, 1)).numerics_passes, 1);
    }
}

#[test]
fn different_seeds_move_the_seeded_strategies() {
    let a = explore(&cfg(Strategy::Random, 1, 1));
    let b = explore(&cfg(Strategy::Random, 2, 1));
    // seeds key both the weights and the sample: sweeps must differ
    assert_ne!(a.sweep_json().render(), b.sweep_json().render());
}

#[test]
fn evaluated_genomes_are_unique_and_within_budget() {
    for strategy in [Strategy::Grid, Strategy::Random, Strategy::Evolve] {
        let out = explore(&cfg(strategy, 9, 1));
        assert!(out.evaluated.len() <= 6, "{strategy:?}");
        assert!(out.evaluated.len() >= 2);
        let mut genomes: Vec<_> = out.evaluated.iter().map(|e| e.genome).collect();
        genomes.sort();
        genomes.dedup();
        assert_eq!(genomes.len(), out.evaluated.len(), "{strategy:?} revisited a genome");
        assert_eq!(out.evaluated[0].name, "baseline");
        assert_eq!(out.evaluated[1].name, "tt-edge");
    }
}

#[test]
fn systolic_backend_is_byte_neutral_at_the_anchors_and_moves_its_twins() {
    // ISSUE 9: the backend axis reprices GEMM ops only, and the two
    // paper anchors decode to the paper datapath — so a sweep that
    // spans the systolic backend must leave the anchors' objectives
    // byte-identical to a paper-space sweep that never instantiates
    // the systolic model at all.
    let paper = explore(&ExploreConfig {
        workload: Workload::Tiny,
        space: SpaceKind::Paper,
        strategy: Strategy::Grid,
        budget: 2,
        seed: 3,
        eps: 0.2,
        method: SvdMethod::Exact,
        parallel: 1,
    });
    let mut wide = cfg(Strategy::Grid, 3, 1);
    wide.budget = 40; // ids 32..40 are the first systolic genomes
    let full = explore(&wide);
    for i in [0usize, 1] {
        assert_eq!(paper.evaluated[i].name, full.evaluated[i].name);
        assert_eq!(paper.evaluated[i].objectives, full.evaluated[i].objectives, "anchor {i}");
        assert_eq!(paper.evaluated[i].time_ms, full.evaluated[i].time_ms);
    }
    // the baseline's systolic twin shares its area (no new Table-II
    // rows) but prices the GEMM stream differently
    let twin = full
        .evaluated
        .iter()
        .find(|e| e.name == "base systolic")
        .expect("budget 40 must reach the systolic genomes");
    let base = &full.evaluated[0];
    assert_eq!(twin.objectives.area_luts, base.objectives.area_luts);
    assert_ne!(twin.objectives.cycles, base.objectives.cycles);
}

#[test]
fn all_on_dominates_all_off_on_the_paper_workload() {
    // The acceptance anchor: paper workload, paper SoCs. One numerics
    // pass costs both configs.
    let out = explore(&ExploreConfig {
        workload: Workload::Resnet32,
        space: SpaceKind::Paper,
        strategy: Strategy::Grid,
        budget: 2,
        seed: 42,
        eps: 0.12,
        method: SvdMethod::Exact,
        parallel: 2,
    });
    assert_eq!(out.evaluated.len(), 2);
    let base = &out.evaluated[0];
    let tte = &out.evaluated[1];
    // ALL_ON dominates ALL_OFF on cycles and energy...
    assert!(tte.objectives.cycles < base.objectives.cycles);
    assert!(tte.objectives.energy_mj < base.objectives.energy_mj);
    // ...and therefore sits on the (cycles, energy, area) frontier
    // (it trades area, so both anchors are frontier members).
    assert!(out.frontier.contains(&1), "tt-edge not on the frontier");
    assert!(out.frontier.contains(&0), "baseline (least area) not on the frontier");
    // headline margins: >=1.5x cycle speedup, >=35% energy reduction
    let speedup = out.speedup(tte);
    let esave = out.energy_reduction_pct(tte);
    assert!(speedup >= 1.5, "speedup {speedup}");
    assert!(esave >= 35.0, "energy reduction {esave}%");
}

#[test]
fn explore_matches_the_simulate_path_on_the_anchors() {
    // The DSE evaluation must cost exactly what `simulate` costs: same
    // job builder, same streaming sink, same workload generator.
    use tt_edge::sim::SocConfig;
    use tt_edge::CompressionJob;

    let out = explore(&ExploreConfig {
        workload: Workload::Tiny,
        space: SpaceKind::Paper,
        strategy: Strategy::Grid,
        budget: 2,
        seed: 7,
        eps: 0.15,
        method: SvdMethod::Exact,
        parallel: 1,
    });
    let mut layers = tt_edge::sim::workload::synthetic_model(7, 3.55, 0.035);
    layers.truncate(4);
    let job = CompressionJob::model(&layers)
        .eps(0.15)
        .socs(&[SocConfig::baseline(), SocConfig::tt_edge()])
        .run()
        .unwrap();
    for (e, r) in out.evaluated.iter().zip(&job.reports) {
        assert_eq!(e.time_ms, r.total_ms);
        assert_eq!(e.objectives.energy_mj, r.total_mj);
    }
}
