//! Program-cache + serve property suite (ISSUE 6).
//!
//! Pins the compression-as-a-service contracts:
//!
//! * hit-served reports are byte-identical to fresh-numerics reports
//!   (3 seeds x both SoCs x serial/parallel-4);
//! * a request stream with R requests over K unique (workload, TtSpec)
//!   keys costs exactly K numerics passes at any worker count
//!   (single-flight misses);
//! * cache keys include rank caps, not just eps — the PR-6 bugfix
//!   regression;
//! * LRU invariants: capacity never exceeded, eviction follows
//!   least-recent-use under a seeded request stream, counters conserve
//!   (`hits + misses == lookups`, `inserts - evictions == resident`);
//! * multi-worker queue drains are byte-identical to the serial drain
//!   (same pattern as `tests/sink_composition.rs`).

use tt_edge::cache::CacheKey;
use tt_edge::dse::Workload;
use tt_edge::serve::{serve, serve_with_cache, ServeConfig, ServeOutcome, ServeRequest};
use tt_edge::sim::SocConfig;
use tt_edge::ttd::Tensor;
use tt_edge::util::Rng;
use tt_edge::{numerics_pass_count, CompressionJob, JobProgram, ProgramCache};

/// A tiny-workload request (4 layers — fast, same numerics substrate).
fn req(seed: u64, eps: f32) -> ServeRequest {
    ServeRequest { workload: Workload::Tiny, seed, eps, ..Default::default() }
}

fn rendered(out: &ServeOutcome) -> Vec<String> {
    out.responses.iter().map(|r| r.to_json().render()).collect()
}

#[test]
fn hit_served_reports_are_byte_identical_to_fresh_numerics() {
    for seed in [3u64, 5, 9] {
        // fresh-numerics oracle: no cache anywhere near it
        let layers = Workload::Tiny.layers(seed);
        let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
        let fresh = CompressionJob::model(&layers)
            .eps(0.12)
            .socs(&configs)
            .run()
            .unwrap();

        // two identical requests: the second is served from cache
        let requests = [req(seed, 0.12), req(seed, 0.12)];
        for workers in [1usize, 4] {
            let before = numerics_pass_count();
            let out = serve(&requests, &ServeConfig { workers, cache_capacity: 8, ..ServeConfig::default() });
            if workers == 1 {
                assert_eq!(
                    numerics_pass_count() - before,
                    1,
                    "seed={seed}: 2 requests, 1 unique key, 1 pass"
                );
            }
            assert_eq!(out.numerics_passes, 1, "seed={seed} workers={workers}");
            for resp in &out.responses {
                assert_eq!(resp.reports.len(), fresh.reports.len());
                for (got, want) in resp.reports.iter().zip(&fresh.reports) {
                    assert_eq!(
                        got.to_json().render(),
                        want.to_json().render(),
                        "seed={seed} workers={workers} req={} {}",
                        resp.index,
                        want.config_name,
                    );
                }
                assert_eq!(resp.final_params, fresh.outcome.final_params);
                assert_eq!(resp.max_rel_err, fresh.outcome.max_rel_err);
                assert_eq!(resp.compression_ratio, fresh.outcome.compression_ratio);
            }
            assert!(out.stats.conserved(), "seed={seed}: {:?}", out.stats);
        }
    }
}

#[test]
fn r_requests_over_k_keys_cost_exactly_k_numerics_passes() {
    // K = 3 unique keys (eps varies), R = 7 requests
    let requests = [
        req(11, 0.12),
        req(11, 0.2),
        req(11, 0.12),
        req(11, 0.3),
        req(11, 0.2),
        req(11, 0.12),
        req(11, 0.3),
    ];
    for workers in [1usize, 4] {
        let before = numerics_pass_count();
        let out = serve(&requests, &ServeConfig { workers, cache_capacity: 8, ..ServeConfig::default() });
        if workers == 1 {
            assert_eq!(numerics_pass_count() - before, 3, "thread-local pass counter");
        }
        assert_eq!(out.numerics_passes, 3, "workers={workers}");
        assert_eq!(out.stats.lookups, 7);
        assert_eq!(out.stats.misses, 3, "single-flight: K misses at any width");
        assert_eq!(out.stats.hits, 4);
        assert_eq!(out.stats.resident, 3);
        assert!(out.stats.conserved(), "{:?}", out.stats);
    }
}

#[test]
fn concurrent_drain_is_byte_identical_to_serial_at_any_width() {
    // 12 requests over 3 unique keys, in a scheduling-hostile order
    let requests: Vec<ServeRequest> = (0..12)
        .map(|i| match i % 3 {
            0 => req(21, 0.12),
            1 => req(21, 0.18),
            _ => req(22, 0.12),
        })
        .collect();
    let serial = serve(&requests, &ServeConfig { workers: 1, cache_capacity: 8, ..ServeConfig::default() });
    let want = rendered(&serial);
    assert_eq!(serial.numerics_passes, 3);
    for workers in [2usize, 4, 8] {
        let out = serve(&requests, &ServeConfig { workers, cache_capacity: 8, ..ServeConfig::default() });
        assert_eq!(rendered(&out), want, "workers={workers}");
        // aggregate accounting is deterministic too: single-flight
        // makes exactly one miss per unique key at every width
        assert_eq!(out.numerics_passes, 3, "workers={workers}");
        assert_eq!(out.stats.misses, 3, "workers={workers}");
        assert_eq!(out.stats.lookups, 12);
        assert!(out.stats.conserved(), "workers={workers}: {:?}", out.stats);
    }
}

#[test]
fn rank_caps_are_part_of_the_cache_key() {
    // The PR-6 bugfix regression: two requests sharing (workload,
    // seed, eps) but differing in rank caps must never collide to the
    // same program.
    let unbounded = req(31, 0.12);
    let capped = ServeRequest { rank_cap: Some(2), ..req(31, 0.12) };
    let per_bond = ServeRequest { rank_caps: vec![2, 2], ..req(31, 0.12) };

    let requests =
        [unbounded.clone(), capped.clone(), unbounded.clone(), capped.clone()];
    let before = numerics_pass_count();
    let out = serve(&requests, &ServeConfig { workers: 1, cache_capacity: 8, ..ServeConfig::default() });
    assert_eq!(numerics_pass_count() - before, 2, "2 unique keys, 2 passes");
    assert_eq!(out.stats.misses, 2);
    assert_eq!(out.stats.hits, 2);
    // the capped program genuinely differs (rank-2 bonds store fewer
    // parameters) — a collision would have surfaced one of these twice
    assert_ne!(
        out.responses[0].final_params, out.responses[1].final_params,
        "capped and unbounded programs should differ on this workload"
    );
    assert_eq!(out.responses[0].final_params, out.responses[2].final_params);
    assert_eq!(out.responses[1].final_params, out.responses[3].final_params);

    // ...while the two spellings of the same caps share one key: the
    // canonicalization half of the same bugfix.
    let spelled = [capped, per_bond];
    let out = serve(&spelled, &ServeConfig { workers: 1, cache_capacity: 8, ..ServeConfig::default() });
    assert_eq!(out.numerics_passes, 1, "rank_cap(2) == rank_caps([2,2])");
    assert_eq!(out.stats.hits, 1);
}

#[test]
fn svd_method_is_part_of_the_cache_key() {
    // The ISSUE 9 twin of the rank-caps regression: two requests
    // sharing (workload, seed, eps) but differing in SVD method — or
    // in the rsvd sketch seed/oversampling — must never collide to the
    // same program.
    use tt_edge::ttd::{SvdMethod, TtSpec};

    let exact = req(61, 0.12);
    let rsvd = ServeRequest {
        method: SvdMethod::Randomized { seed: 61, oversample: 8 },
        ..req(61, 0.12)
    };
    let requests = [exact.clone(), rsvd.clone(), exact.clone(), rsvd.clone()];
    let before = numerics_pass_count();
    let out = serve(&requests, &ServeConfig { workers: 1, cache_capacity: 8, ..ServeConfig::default() });
    assert_eq!(numerics_pass_count() - before, 2, "2 unique keys, 2 passes");
    assert_eq!(out.stats.misses, 2);
    assert_eq!(out.stats.hits, 2);
    // repeats replay their own method's program, never the other's
    let texts = rendered(&out);
    assert_eq!(texts[0], texts[2]);
    assert_eq!(texts[1], texts[3]);

    // the sketch parameters are numeric identity: seed and oversample
    // each split the key, and the same spec spelled twice shares one
    let key = |spec: TtSpec| CompressionJob::synthetic(1).spec(spec).cache_key();
    let base = key(TtSpec::eps(0.12).rsvd(7, 8));
    assert_ne!(base, key(TtSpec::eps(0.12).rsvd(8, 8)), "sketch seed");
    assert_ne!(base, key(TtSpec::eps(0.12).rsvd(7, 16)), "oversample");
    assert_ne!(base, key(TtSpec::eps(0.12)), "exact vs rsvd");
    assert_eq!(base, key(TtSpec::eps(0.12).rsvd(7, 8)));
}

/// Record one small program to use as the LRU tests' payload (its
/// contents are irrelevant to eviction order).
fn sample_program() -> JobProgram {
    let mut rng = Rng::new(77);
    let w = Tensor::from_vec(&[4, 4, 4], rng.normal_vec(64));
    let (_, program) = CompressionJob::new(&w).eps(0.2).program().unwrap();
    program
}

#[test]
fn lru_capacity_is_never_exceeded_and_eviction_is_least_recent_first() {
    const CAPACITY: usize = 3;
    let cache = ProgramCache::new(CAPACITY);
    let program = sample_program();
    // 6 distinct keys (eps varies); indices into `keys` drive the oracle
    let keys: Vec<CacheKey> = (0..6)
        .map(|i| CompressionJob::synthetic(1).eps(0.1 + 0.05 * i as f32).cache_key())
        .collect();

    // hand-rolled LRU oracle: key indices, least-recent first
    let mut oracle: Vec<usize> = Vec::new();
    let mut rng = Rng::new(2024);
    for step in 0..80 {
        let k = rng.below(keys.len());
        let hit = cache.lookup(&keys[k]).is_some();
        assert_eq!(hit, oracle.contains(&k), "step {step}: oracle disagrees on key {k}");
        if hit {
            // touch: move to most-recent
            oracle.retain(|&i| i != k);
            oracle.push(k);
        } else {
            cache.insert(keys[k].clone(), program.clone());
            oracle.push(k);
            if oracle.len() > CAPACITY {
                let evicted = oracle.remove(0); // least recently used
                assert!(
                    !cache.contains(&keys[evicted]),
                    "step {step}: key {evicted} should have been the LRU victim"
                );
            }
        }
        // capacity never exceeded; residency matches the oracle exactly
        assert!(cache.len() <= CAPACITY, "step {step}");
        assert_eq!(cache.len(), oracle.len(), "step {step}");
        for (i, key) in keys.iter().enumerate() {
            assert_eq!(
                cache.contains(key),
                oracle.contains(&i),
                "step {step}: key {i} residency"
            );
        }
        let s = cache.stats();
        assert!(s.conserved(), "step {step}: {s:?}");
    }
    let s = cache.stats();
    assert_eq!(s.lookups, 80);
    assert!(s.evictions > 0, "80 draws over 6 keys at capacity 3 must evict");
}

#[test]
fn scripted_churn_pins_exact_eviction_victims() {
    // Deterministic eviction-order regression for the BTreeMap tick
    // index: a hand-scripted capacity-2 churn where every victim is
    // pinned by name. Covers all three recency-moving operations —
    // `insert`, a `lookup` hit, and a `claim` hit — so an index that
    // forgets to re-key a touched entry (or evicts in hasher order)
    // fails on the exact step, not statistically.
    use tt_edge::cache::Claim;

    let cache = ProgramCache::new(2);
    let program = sample_program();
    let keys: Vec<CacheKey> = (0..4)
        .map(|i| CompressionJob::synthetic(1).eps(0.3 + 0.05 * i as f32).cache_key())
        .collect();
    let (a, b, c, d) = (&keys[0], &keys[1], &keys[2], &keys[3]);

    cache.insert(a.clone(), program.clone()); // recency: [a]
    cache.insert(b.clone(), program.clone()); // recency: [a, b]
    cache.insert(c.clone(), program.clone()); // evicts a -> [b, c]
    assert!(!cache.contains(a), "a was least-recent at the first overflow");
    assert!(cache.contains(b) && cache.contains(c));

    // lookup-hit on b moves it to most-recent: [c, b]
    assert!(cache.lookup(b).is_some());
    cache.insert(d.clone(), program.clone()); // evicts c, NOT b -> [b, d]
    assert!(cache.contains(b), "the looked-up entry must have been touched");
    assert!(!cache.contains(c), "c was least-recent after b's touch");

    // claim-hit on b touches it too: [d, b]
    match cache.claim(b) {
        Claim::Hit(_) => {}
        Claim::Miss(_) => panic!("b is resident — claim must hit"),
    }
    cache.insert(a.clone(), program.clone()); // evicts d, NOT b -> [b, a]
    assert!(cache.contains(b), "the claim-hit entry must have been touched");
    assert!(!cache.contains(d), "d was least-recent after b's claim-hit");
    assert!(cache.contains(a));

    let s = cache.stats();
    assert_eq!(s.inserts, 5);
    assert_eq!(s.evictions, 3);
    assert_eq!(s.resident, 2);
    assert_eq!(s.hits, 2, "one lookup hit + one claim hit");
    assert!(s.conserved(), "{s:?}");
}

#[test]
fn capacity_zero_disables_residency_but_not_correctness() {
    let requests = [req(41, 0.12), req(41, 0.12), req(41, 0.2)];
    let cached = serve(&requests, &ServeConfig { workers: 1, cache_capacity: 8, ..ServeConfig::default() });
    let uncached = serve(&requests, &ServeConfig { workers: 1, cache_capacity: 0, ..ServeConfig::default() });
    // identical outputs...
    assert_eq!(rendered(&cached), rendered(&uncached));
    // ...but every request paid numerics and nothing stayed resident
    assert_eq!(cached.numerics_passes, 2);
    assert_eq!(uncached.numerics_passes, 3);
    assert_eq!(uncached.stats.misses, 3);
    assert_eq!(uncached.stats.resident, 0);
    assert_eq!(uncached.stats.resident_bytes, 0);
    assert!(uncached.stats.conserved(), "{:?}", uncached.stats);
}

#[test]
fn pre_warmed_cache_serves_the_whole_drain_from_hits() {
    let requests = [req(51, 0.12), req(51, 0.12)];
    let cache = ProgramCache::new(8);
    let warm = serve_with_cache(&requests, 1, &cache);
    assert_eq!(warm.numerics_passes, 1);
    // same cache, second drain: all hits, zero numerics
    let before = numerics_pass_count();
    let again = serve_with_cache(&requests, 1, &cache);
    assert_eq!(numerics_pass_count() - before, 0, "warm drain must be numerics-free");
    assert_eq!(again.numerics_passes, 0);
    assert_eq!(rendered(&warm), rendered(&again));
    let s = cache.stats();
    assert_eq!(s.lookups, 4);
    assert_eq!(s.hits, 3);
    assert_eq!(s.misses, 1);
    assert!(s.conserved(), "{s:?}");
}
