//! PR-7 equivalence pins: the vectorized GEMM microkernel, the
//! panel-parallel WY accumulation, and the parallel program fold must
//! all be **bit-identical** to their serial/reference counterparts —
//! not tolerance-close. The op stream feeds `W_temp = Sigma * V^T` and
//! every downstream QR sweep / sort swap / truncation decision, so a
//! single flipped low bit would fork the golden traces.
//!
//! The kernel and panel-width selectors are process globals (that is
//! what makes `TTEDGE_KERNEL` / `TTEDGE_HBD_THREADS` work without
//! threading a config through every call site). Flipping them from
//! concurrently running tests is benign *because* every mode is
//! bit-identical — which is exactly what this file proves. Each test
//! still restores the defaults on exit out of politeness.

use tt_edge::sim::workload::synthetic_model;
use tt_edge::sim::SocConfig;
use tt_edge::trace::VecSink;
use tt_edge::ttd::svd::bidiag::{panel_threads, set_panel_threads};
use tt_edge::ttd::tensor::{
    matmul_reference, matmul_vectorized, set_gemm_kernel, GEMM_LANES,
};
use tt_edge::ttd::Tensor;
use tt_edge::util::Rng;
use tt_edge::{CompressionJob, GemmKernel};

/// Shapes chosen to cross every control-flow edge of the vectorized
/// microkernel: n below one lane, n on/off the `2*GEMM_LANES` column
/// tile, m on/off the 4-row tile, odd k (the single-remainder path of
/// the global k-pairing), and k across the BK=128 block edge.
fn boundary_shapes() -> Vec<(usize, usize, usize)> {
    let l = GEMM_LANES;
    vec![
        (1, 1, 1),
        (2, 3, l - 1),          // column tail only, odd k
        (4, 4, l),              // one lane exactly
        (3, 7, 2 * l),          // full column tile, row remainder
        (4, 128, 2 * l),        // k exactly one block
        (5, 129, 2 * l + 3),    // k just over a block, ragged n
        (9, 255, 3 * l + 1),    // odd k, tile + lane + scalar tail
        (16, 64, 4 * l),
    ]
}

#[test]
fn vectorized_and_reference_kernels_agree_to_the_bit() {
    let mut rng = Rng::new(4001);
    for (m, k, n) in boundary_shapes() {
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(k * n);
        // nonzero seed exercises the accumulate-into-out contract
        let seed: Vec<f32> = (0..m * n).map(|i| (i % 13) as f32 * 0.25 - 1.0).collect();
        let mut out_v = seed.clone();
        let mut out_r = seed;
        matmul_vectorized(m, k, n, &a, &b, &mut out_v);
        matmul_reference(m, k, n, &a, &b, &mut out_r);
        assert_eq!(out_v, out_r, "kernel divergence at m={m} k={k} n={n}");
    }
}

/// Run one single-tensor job under a given kernel, capturing the full
/// op stream, the TT cores, and the Table-III reports.
fn job_fingerprint(w: &Tensor, kernel: GemmKernel) -> (Vec<String>, Vec<Vec<f32>>, Vec<String>) {
    let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
    let mut sink = VecSink::default();
    let out = CompressionJob::new(w)
        .eps(0.12)
        .kernel(kernel)
        .socs(&configs)
        .sink(&mut sink)
        .run()
        .unwrap();
    let ops = sink.ops.iter().map(|op| format!("{op:?}")).collect();
    let cores = out.decomp().cores.iter().map(|c| c.data.clone()).collect();
    let reports = out.reports.iter().map(|r| r.to_json().render()).collect();
    (ops, cores, reports)
}

#[test]
fn decompose_is_kernel_invariant_trace_cores_and_reports() {
    let mut rng = Rng::new(4002);
    // [40, 6, 6]: stage-0 unfolding is 40x36, so the WY loop runs a
    // full 32-reflector panel plus a ragged tail — both kernels see
    // every accumulation shape class.
    let tall = Tensor::from_vec(&[40, 6, 6], rng.normal_vec(40 * 36));
    // rank-deficient: duplicated slices force early truncation, the
    // path where a low-bit fork would move a rank decision.
    let block = rng.normal_vec(6 * 25);
    let mut defic = Vec::new();
    for _ in 0..4 {
        defic.extend_from_slice(&block);
    }
    let deficient = Tensor::from_vec(&[24, 5, 5], defic);

    for w in [&tall, &deficient] {
        let vec = job_fingerprint(w, GemmKernel::Vectorized);
        let refr = job_fingerprint(w, GemmKernel::Reference);
        set_gemm_kernel(GemmKernel::Vectorized);
        assert_eq!(vec.0, refr.0, "op stream must be kernel-invariant");
        assert_eq!(vec.1, refr.1, "TT cores must be kernel-invariant");
        assert_eq!(vec.2, refr.2, "reports must be kernel-invariant");
    }
}

#[test]
fn panel_width_is_invisible_through_the_job() {
    let mut rng = Rng::new(4003);
    let w = Tensor::from_vec(&[40, 6, 6], rng.normal_vec(40 * 36));
    let saved = panel_threads();
    let run = |width: usize| {
        let mut sink = VecSink::default();
        let out = CompressionJob::new(&w)
            .eps(0.12)
            .hbd_threads(width)
            .soc(SocConfig::tt_edge())
            .sink(&mut sink)
            .run()
            .unwrap();
        let ops: Vec<String> = sink.ops.iter().map(|op| format!("{op:?}")).collect();
        let cores: Vec<Vec<f32>> = out.decomp().cores.iter().map(|c| c.data.clone()).collect();
        (ops, cores, out.reports[0].to_json().render())
    };
    let baseline = run(1);
    for width in [2, 4, 8] {
        assert_eq!(run(width), baseline, "panel width {width} diverged from serial");
    }
    set_panel_threads(saved);
}

#[test]
fn parallel_program_fold_is_byte_identical_through_replay() {
    let mut layers = synthetic_model(7, 3.55, 0.035);
    layers.truncate(5);
    let (out, program) = CompressionJob::model(&layers).eps(0.12).program().unwrap();
    let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
    let render = |reports: &[tt_edge::sim::SimReport]| -> Vec<String> {
        reports.iter().map(|r| r.to_json().render()).collect()
    };
    let recorded = {
        let o = CompressionJob::replay(&program).socs(&configs).parallel(1).run().unwrap();
        render(&o.reports)
    };
    for width in [2, 4, 8] {
        let o = CompressionJob::replay(&program)
            .socs(&configs)
            .parallel(width)
            .run()
            .unwrap();
        assert_eq!(render(&o.reports), recorded, "fold width {width} diverged");
        assert_eq!(o.outcome.final_params, out.outcome.final_params);
    }
}
