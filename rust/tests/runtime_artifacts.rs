//! Integration: the PJRT runtime executes every AOT artifact and the
//! numerics agree with the in-process rust substrate (L1/L2 vs L3
//! cross-validation). Skips (with a message) when `make artifacts`
//! has not produced the artifacts directory.

use tt_edge::runtime::{Engine, Value};
use tt_edge::trace::NullSink;
use tt_edge::ttd::svd::house::house;
use tt_edge::ttd::{Matrix, Tensor, TtSpec};
use tt_edge::util::Rng;

fn engine() -> Option<Engine> {
    if cfg!(not(feature = "pjrt")) {
        // The default build ships the manifest-only stub Engine whose
        // `run` always bails — executing artifacts needs the real
        // PJRT client.
        eprintln!("skipping: PJRT runtime disabled (rebuild with --features pjrt)");
        return None;
    }
    let dir = tt_edge::runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Engine::load(&dir).expect("engine"))
}

#[test]
fn manifest_lists_all_entries() {
    let Some(eng) = engine() else { return };
    let names = eng.entry_names();
    for required in [
        "house_left_128",
        "house_right_128",
        "gemm_256",
        "norm_4096",
        "svd_144x64",
        "ttd3_conv64",
        "tt_rec3_conv64",
        "resnet32_fwd_b4",
        "resnet32_sgd_b8",
    ] {
        assert!(names.iter().any(|n| n == required), "missing {required}");
    }
}

#[test]
fn gemm_artifact_matches_rust_matmul() {
    let Some(mut eng) = engine() else { return };
    let mut rng = Rng::new(1);
    let a = Matrix::from_vec(256, 256, rng.normal_vec(256 * 256));
    let b = Matrix::from_vec(256, 256, rng.normal_vec(256 * 256));
    let out = eng
        .run(
            "gemm_256",
            &[
                Value::F32 { shape: vec![256, 256], data: a.data.clone() },
                Value::F32 { shape: vec![256, 256], data: b.data.clone() },
            ],
        )
        .expect("run");
    let want = a.matmul(&b);
    let got = out[0].as_f32().unwrap();
    let max = got
        .iter()
        .zip(&want.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max < 1e-2, "max diff {max}");
}

#[test]
fn norm_artifact_matches_rust_norm() {
    let Some(mut eng) = engine() else { return };
    let mut rng = Rng::new(2);
    let x = rng.normal_vec(4096);
    let out = eng
        .run("norm_4096", &[Value::F32 { shape: vec![4096], data: x.clone() }])
        .expect("run");
    let want = tt_edge::ttd::svd::house::norm(&x);
    let got = out[0].as_f32().unwrap()[0];
    assert!((got - want).abs() < 1e-3 * want, "{got} vs {want}");
}

#[test]
fn house_update_artifact_matches_rust_apply_left() {
    let Some(mut eng) = engine() else { return };
    let mut rng = Rng::new(3);
    let mut a = Matrix::from_vec(128, 128, rng.normal_vec(128 * 128));
    let x: Vec<f32> = (0..128).map(|r| a.get(r, 0)).collect();
    let h = house(&x);
    let out = eng
        .run(
            "house_left_128",
            &[
                Value::F32 { shape: vec![128], data: h.v.clone() },
                Value::F32 { shape: vec![128, 128], data: a.data.clone() },
                Value::scalar_f32(h.beta),
            ],
        )
        .expect("run");
    tt_edge::ttd::svd::house::apply_left(&mut a, 0, 0, &h.v, h.beta);
    let got = out[0].as_f32().unwrap();
    let max = got
        .iter()
        .zip(&a.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max < 2e-2, "max diff {max}");
}

#[test]
fn svd_artifact_matches_rust_singular_values() {
    let Some(mut eng) = engine() else { return };
    let mut rng = Rng::new(4);
    let a = Matrix::from_vec(144, 64, rng.normal_vec(144 * 64));
    let out = eng
        .run("svd_144x64", &[Value::F32 { shape: vec![144, 64], data: a.data.clone() }])
        .expect("run");
    // python svd returns (U (144,64), sigma (64), Vt (64,64)), sorted.
    let sigma_py = out[1].as_f32().unwrap();
    let s = tt_edge::ttd::svd::svd(&a, &mut NullSink);
    let mut sigma_rs = s.sigma.clone();
    sigma_rs.sort_by(|a, b| b.partial_cmp(a).unwrap());
    for (i, (p, r)) in sigma_py.iter().zip(&sigma_rs).enumerate() {
        assert!(
            (p - r).abs() < 2e-3 * (1.0 + r.abs()),
            "sigma[{i}]: python {p} vs rust {r}"
        );
    }
}

#[test]
fn ttd3_artifact_roundtrips_through_reconstruction() {
    let Some(mut eng) = engine() else { return };
    let mut rng = Rng::new(5);
    // compressible synthetic conv kernel (3,3,64,64)
    let layer = tt_edge::model::conv_layers().pop().unwrap();
    let w3 = tt_edge::sim::workload::synthetic_trained_conv(&mut rng, &layer, 3.5, 0.02);
    let w = Tensor::from_vec(&[3, 3, 64, 64], w3.data.clone());
    let eps = 0.1f32;
    let out = eng
        .run("ttd3_conv64", &[Value::from_tensor(&w), Value::scalar_f32(eps)])
        .expect("run ttd3");
    let (g1, g2, g3) = (&out[0], &out[1], &out[2]);
    let r1 = out[3].as_i32().unwrap()[0];
    let r2 = out[4].as_i32().unwrap()[0];
    assert!(r1 >= 1 && r2 >= 1, "ranks {r1} {r2}");
    // reconstruct through the dedicated artifact
    let rec = eng
        .run("tt_rec3_conv64", &[g1.clone(), g2.clone(), g3.clone()])
        .expect("run rec");
    let got = rec[0].as_f32().unwrap();
    // relative error within the prescribed budget
    let num: f64 = got
        .iter()
        .zip(&w3.data)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum();
    let den: f64 = w3.data.iter().map(|b| (*b as f64).powi(2)).sum();
    let rel = (num / den).sqrt();
    assert!(rel <= eps as f64 + 0.02, "rel err {rel}");
    // and the rust-side TTD agrees on the retained ranks (+-small)
    let d = tt_edge::ttd::decompose(&w3, &TtSpec::eps(eps), &mut NullSink);
    assert!((d.ranks[1] as i32 - r1).abs() <= 2, "r1 {} vs {}", d.ranks[1], r1);
    assert!((d.ranks[2] as i32 - r2).abs() <= 4, "r2 {} vs {}", d.ranks[2], r2);
}

#[test]
fn resnet_forward_artifact_runs() {
    let Some(mut eng) = engine() else { return };
    let params = tt_edge::model::ParamStore::init_resnet32(6);
    let mut rng = Rng::new(7);
    let mut inputs: Vec<Value> = params.values.iter().map(Value::from_tensor).collect();
    inputs.push(Value::F32 { shape: vec![4, 32, 32, 3], data: rng.normal_vec(4 * 32 * 32 * 3) });
    let out = eng.run("resnet32_fwd_b4", &inputs).expect("fwd");
    let logits = out[0].as_f32().unwrap();
    assert_eq!(logits.len(), 40);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let Some(mut eng) = engine() else { return };
    let err = eng
        .run("norm_4096", &[Value::F32 { shape: vec![7], data: vec![0.0; 7] }])
        .unwrap_err();
    assert!(format!("{err}").contains("input 0"), "{err}");
    let err = eng.run("nope", &[]).unwrap_err();
    assert!(format!("{err}").contains("no artifact entry"), "{err}");
}
