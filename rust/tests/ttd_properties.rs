//! Property-test harness for the TTD numerics (ISSUE 1 satellite):
//! randomized round-trip invariants over random dims/ranks/eps, the
//! delta-truncation error contract, and the two-phase-SVD (HBD +
//! implicit-shift QR) vs one-sided-Jacobi singular-value cross-check.
//!
//! Everything runs through `testutil::check`, so a failure prints the
//! case index + seed needed to replay the exact counterexample.

use tt_edge::testutil::{check, rand_matrix, rand_shape, rand_tensor, rand_tt_tensor, rel_frobenius};
use tt_edge::trace::NullSink;
use tt_edge::ttd::svd::bidiag::bidiagonalize;
use tt_edge::ttd::svd::jacobi::jacobi_svd;
use tt_edge::ttd::svd::svd;
use tt_edge::ttd::{decompose, reconstruct, TtSpec};

/// `||W - reconstruct(TTD(W))||_F <= eps ||W||_F` — the Oseledets
/// prescribed-accuracy bound — across random dimension counts, sizes
/// and eps values (the delta-truncation invariant).
#[test]
fn roundtrip_error_bounded_by_eps_random_dims() {
    check(25, 9000, |rng| {
        let nd = 2 + rng.below(3); // 2..=4 dims
        let shape = rand_shape(rng, nd, 2, 6);
        let w = rand_tensor(rng, &shape);
        let eps = [0.05f32, 0.15, 0.3, 0.6][rng.below(4)];
        let d = decompose(&w, &TtSpec::eps(eps), &mut NullSink);
        let err = rel_frobenius(&reconstruct(&d), &w);
        assert!(
            err <= eps + 1e-3,
            "shape {shape:?} eps {eps}: err {err}"
        );
        // boundary ranks stay 1 and core shapes stay consistent
        assert_eq!(d.ranks[0], 1);
        assert_eq!(*d.ranks.last().unwrap(), 1);
        for (k, c) in d.cores.iter().enumerate() {
            assert_eq!((c.r_in, c.n, c.r_out), (d.ranks[k], d.dims[k], d.ranks[k + 1]));
        }
    });
}

/// eps = 0 must reproduce the tensor to f32 round-off regardless of
/// shape (full-rank TT is exact).
#[test]
fn zero_eps_roundtrip_is_exact() {
    check(15, 9001, |rng| {
        let nd = 2 + rng.below(3);
        let shape = rand_shape(rng, nd, 2, 5);
        let w = rand_tensor(rng, &shape);
        let d = decompose(&w, &TtSpec::eps(0.0), &mut NullSink);
        let err = rel_frobenius(&reconstruct(&d), &w);
        assert!(err < 5e-4, "shape {shape:?}: err {err}");
    });
}

/// Planted low-TT-rank tensors are recovered with ranks no larger
/// than planted and near-zero error at tiny eps.
#[test]
fn planted_ranks_are_recovered() {
    check(15, 9002, |rng| {
        let nd = 3 + rng.below(2); // 3..=4 dims
        let shape = rand_shape(rng, nd, 3, 6);
        let rmax = 1 + rng.below(3);
        let w = rand_tt_tensor(rng, &shape, rmax);
        let d = decompose(&w, &TtSpec::eps(1e-3), &mut NullSink);
        for r in &d.ranks[1..nd] {
            // recovered bond rank can never exceed the planted cap
            assert!(*r <= rmax, "rank {r} > planted cap {rmax} ({shape:?})");
        }
        let err = rel_frobenius(&reconstruct(&d), &w);
        assert!(err <= 2e-3, "err {err}");
    });
}

/// Larger eps can only shrink (never grow) the parameter count, and
/// every rank respects an explicit cap.
#[test]
fn truncation_monotone_and_caps_respected() {
    check(10, 9003, |rng| {
        let shape = rand_shape(rng, 3, 3, 7);
        let w = rand_tensor(rng, &shape);
        let mut last = usize::MAX;
        for eps in [0.02f32, 0.1, 0.35, 0.7] {
            let d = decompose(&w, &TtSpec::eps(eps), &mut NullSink);
            assert!(d.param_count() <= last, "eps {eps} grew params");
            last = d.param_count();
        }
        let caps = [1 + rng.below(3), 1 + rng.below(3)];
        let d = decompose(&w, &TtSpec::eps(0.0).rank_caps(&caps), &mut NullSink);
        assert!(d.ranks[1] <= caps[0] && d.ranks[2] <= caps[1]);
    });
}

/// Two-phase SVD (Householder bidiagonalization + implicit-shift QR)
/// vs one-sided Jacobi: two independent algorithms must agree on the
/// singular values of random square matrices.
#[test]
fn two_phase_svd_cross_checks_with_jacobi_square() {
    check(20, 9004, |rng| {
        let n = 2 + rng.below(16);
        let a = rand_matrix(rng, n, n);
        let mut two_phase = svd(&a, &mut NullSink).sigma;
        two_phase.sort_by(|x, y| y.partial_cmp(x).unwrap());
        let jc = jacobi_svd(&a, 60);
        let scale = jc.sigma.first().copied().unwrap_or(1.0).max(1.0);
        for (i, (g, j)) in two_phase.iter().zip(&jc.sigma).enumerate() {
            assert!(
                (g - j).abs() < 2e-3 * scale,
                "n={n} sigma[{i}]: two-phase {g} vs jacobi {j}"
            );
        }
    });
}

/// Rectangular inputs: cross-check through the bidiagonal reduction
/// (Jacobi runs on the square bidiagonal factor; orthogonal
/// invariance means the singular values are those of A).
#[test]
fn two_phase_svd_cross_checks_with_jacobi_rectangular() {
    check(15, 9005, |rng| {
        let n = 2 + rng.below(10);
        let m = n + rng.below(20);
        let a = rand_matrix(rng, m, n);
        let mut two_phase = svd(&a, &mut NullSink).sigma;
        two_phase.sort_by(|x, y| y.partial_cmp(x).unwrap());
        let f = bidiagonalize(&a, &mut NullSink);
        let jc = jacobi_svd(&f.b, 60);
        let scale = jc.sigma.first().copied().unwrap_or(1.0).max(1.0);
        for (g, j) in two_phase.iter().zip(&jc.sigma) {
            assert!((g - j).abs() < 2e-3 * scale, "{g} vs {j} (m={m} n={n})");
        }
    });
}

/// The sum of squared singular values equals ||A||_F^2 (orthogonal
/// invariance) — a global sanity anchor for both SVD paths.
#[test]
fn singular_values_preserve_frobenius_energy() {
    check(15, 9006, |rng| {
        let m = 2 + rng.below(20);
        let n = 2 + rng.below(20);
        let a = rand_matrix(rng, m, n);
        let s = svd(&a, &mut NullSink);
        let energy: f64 = s.sigma.iter().map(|v| (*v as f64) * (*v as f64)).sum();
        let fa = a.frobenius() as f64;
        assert!(
            (energy.sqrt() - fa).abs() / fa.max(1.0) < 1e-3,
            "m={m} n={n}: {} vs {fa}",
            energy.sqrt()
        );
    });
}
