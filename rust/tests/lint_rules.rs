//! Fixture suite for the `ttedge-lint` static-analysis pass (ISSUE 8).
//!
//! One known-bad fixture per rule pinning the exact `file:line` the
//! scanner must report, the scoping exemptions (blessed owners,
//! `#[cfg(test)]`, file class), the pragma grammar (trailing and
//! own-line placement, mandatory reasons, unknown rules), and a
//! clean-tree smoke run over this very crate — the same invocation the
//! CI `static-analysis` job gates on.
//!
//! Fixtures are string literals, so scanning *this* file stays quiet:
//! the lexer blanks them before any rule looks at the code.

use std::path::Path;

use tt_edge::analysis::{analyze_source, analyze_tree, FileAnalysis, Rule, Violation};

/// Expect exactly one violation and return it.
fn only(fa: &FileAnalysis) -> &Violation {
    assert_eq!(fa.violations.len(), 1, "expected one violation: {:?}", fa.violations);
    &fa.violations[0]
}

fn assert_quiet(rel: &str, src: &str) {
    let fa = analyze_source(rel, src);
    assert!(fa.violations.is_empty(), "{rel} should be quiet: {:?}", fa.violations);
}

#[test]
fn no_adhoc_threads_fires_with_exact_location() {
    let src = "fn f() {\n    std::thread::spawn(|| {});\n}\n";
    let fa = analyze_source("src/fixture.rs", src);
    let v = only(&fa);
    assert_eq!(v.rule, Rule::NoAdhocThreads);
    assert_eq!((v.file.as_str(), v.line), ("src/fixture.rs", 2));
    assert!(v.render().starts_with("src/fixture.rs:2 no-adhoc-threads "));

    // blessed owners and #[cfg(test)] regions are exempt
    assert_quiet("src/serve/mod.rs", src);
    assert_quiet("src/pipeline/mod.rs", src);
    assert_quiet(
        "src/fixture.rs",
        "#[cfg(test)]\nmod tests {\n    fn f() { std::thread::spawn(|| {}); }\n}\n",
    );
    // cfg(not(test)) is NOT a test region
    let gated = "#[cfg(not(test))]\nmod prod {\n    fn f() { std::thread::spawn(|| {}); }\n}\n";
    assert_eq!(only(&analyze_source("src/fixture.rs", gated)).line, 3);
}

#[test]
fn single_entry_point_fires_outside_blessed_callers() {
    let bare =
        "use crate::ttd::{decompose, Tensor};\nfn f() {\n    let d = decompose(&t, &spec, s);\n}\n";
    let fa = analyze_source("src/sim/other.rs", bare);
    let v = only(&fa);
    assert_eq!(v.rule, Rule::SingleEntryPoint);
    assert_eq!(v.line, 3);

    let qualified = "fn f() {\n    let d = crate::ttd::decompose(&t, &spec, s);\n}\n";
    assert_eq!(only(&analyze_source("src/sim/other.rs", qualified)).line, 2);

    // job.rs and the defining modules own the entry points; tests and
    // benches pin them on purpose; `tucker::decompose` is a different
    // function, not a bare `decompose` call.
    assert_quiet("src/job.rs", qualified);
    assert_quiet("src/ttd/ttd.rs", qualified);
    assert_quiet("tests/props.rs", bare);
    assert_quiet("benches/hot.rs", bare);
    assert_quiet(
        "src/sim/other.rs",
        "use crate::ttd::{decompose, Tensor};\nfn f() {\n    let d = tucker::decompose(&t, eps);\n}\n",
    );
    // without a ttd decompose import, a bare local `decompose(` is fine
    assert_quiet("src/sim/other.rs", "fn g() {\n    let d = decompose(&t);\n}\n");
}

#[test]
fn no_unordered_iteration_fires_on_declared_hash_containers() {
    let looped = "use std::collections::HashMap;\nfn f() {\n    let mut m: HashMap<u32, u32> = HashMap::new();\n    for (k, v) in &m {\n    }\n}\n";
    let fa = analyze_source("src/fixture.rs", looped);
    let v = only(&fa);
    assert_eq!(v.rule, Rule::NoUnorderedIteration);
    assert_eq!(v.line, 4);
    assert!(v.message.contains("`m`"), "names the container: {}", v.message);

    let methods = "struct S { seen: HashSet<u64> }\nfn f(s: &S) {\n    let n = s.seen.iter().count();\n}\n";
    assert_eq!(only(&analyze_source("src/fixture.rs", methods)).line, 3);

    // BTreeMap iteration is ordered — never flagged; and a HashMap
    // used only for point lookups is fine.
    assert_quiet(
        "src/fixture.rs",
        "fn f() {\n    let m: BTreeMap<u32, u32> = BTreeMap::new();\n    for (k, v) in &m {\n    }\n}\n",
    );
    assert_quiet(
        "src/fixture.rs",
        "fn f() {\n    let m: HashMap<u32, u32> = HashMap::new();\n    let x = m.get(&1);\n}\n",
    );
}

#[test]
fn no_wallclock_fires_outside_benches_and_metrics() {
    let src = "fn f() {\n    let t0 = std::time::Instant::now();\n}\n";
    let fa = analyze_source("src/fixture.rs", src);
    let v = only(&fa);
    assert_eq!(v.rule, Rule::NoWallclock);
    assert_eq!(v.line, 2);

    assert_quiet("benches/wall.rs", src);
    assert_quiet("src/metrics/bench.rs", src);

    // unseeded RNG is the same class of nondeterminism
    let rng = "fn f() {\n    let mut r = rand::thread_rng();\n}\n";
    assert_eq!(only(&analyze_source("src/fixture.rs", rng)).line, 2);
}

#[test]
fn hard_assert_rule_guards_the_kernel_entry_files() {
    let src = "fn get(r: usize) {\n    debug_assert!(r < 4);\n}\n";
    let fa = analyze_source("src/ttd/tensor.rs", src);
    let v = only(&fa);
    assert_eq!(v.rule, Rule::HardAssertDispatchGuards);
    assert_eq!(v.line, 2);
    assert_eq!(only(&analyze_source("src/ttd/svd/bidiag.rs", src)).line, 2);

    // only the kernel entry-path files are in scope, and their own
    // test modules may use debug_assert freely
    assert_quiet("src/ttd/golub_kahan.rs", src);
    assert_quiet(
        "src/ttd/tensor.rs",
        "#[cfg(test)]\nmod tests {\n    fn f() { debug_assert!(true); }\n}\n",
    );
}

#[test]
fn no_hotpath_alloc_fires_only_inside_tagged_regions() {
    let src = "fn f(xs: &[f32]) {\n    // lint: hotpath\n    let v = xs.to_vec();\n}\nfn g(xs: &[f32]) {\n    let v = xs.to_vec();\n}\n";
    let fa = analyze_source("src/fixture.rs", src);
    let v = only(&fa);
    assert_eq!(v.rule, Rule::NoHotpathAlloc);
    assert_eq!(v.line, 3, "g's alloc is outside the tagged region");

    // the region closes with its block: code after the brace is free
    let closed = "fn f() {\n    {\n        // lint: hotpath\n        let a = 1;\n    }\n    let v = Vec::new();\n}\n";
    assert_quiet("src/fixture.rs", closed);
}

#[test]
fn lock_discipline_fires_on_bare_lock_unwrap() {
    let src = "fn f(&self) {\n    let g = self.state.lock().unwrap();\n}\n";
    let fa = analyze_source("src/fixture.rs", src);
    let v = only(&fa);
    assert_eq!(v.rule, Rule::LockDiscipline);
    assert_eq!(v.line, 2);
    let expect = "fn f(&self) {\n    let g = self.state.lock().expect(\"poisoned\");\n}\n";
    assert_eq!(only(&analyze_source("src/fixture.rs", expect)).line, 2);

    // tests may lock however they like
    assert_quiet(
        "src/fixture.rs",
        "#[cfg(test)]\nmod tests {\n    fn f(m: &M) { m.state.lock().unwrap(); }\n}\n",
    );
}

#[test]
fn allow_pragmas_suppress_exactly_one_line_and_are_recorded() {
    // own-line pragma covers the next non-blank code line
    let own_line = "fn f() {\n    // lint: allow(no-wallclock-or-unseeded-rng): operator-facing timing only\n\n    let t0 = std::time::Instant::now();\n}\n";
    let fa = analyze_source("src/fixture.rs", own_line);
    assert!(fa.violations.is_empty(), "{:?}", fa.violations);
    assert_eq!(fa.allows.len(), 1);
    assert_eq!(fa.allows[0].rule, Rule::NoWallclock);
    assert_eq!(fa.allows[0].reason, "operator-facing timing only");

    // trailing pragma covers its own line...
    let trailing = "fn f(&self) {\n    let g = self.state.lock().unwrap(); // lint: allow(lock-discipline): test double, single consumer\n}\n";
    let fa = analyze_source("src/fixture.rs", trailing);
    assert!(fa.violations.is_empty(), "{:?}", fa.violations);
    assert_eq!(fa.allows.len(), 1);

    // ...and only that line: the next occurrence still fires
    let two = "fn f(&self) {\n    let a = self.state.lock().unwrap(); // lint: allow(lock-discipline): first site justified\n    let b = self.state.lock().unwrap();\n}\n";
    assert_eq!(only(&analyze_source("src/fixture.rs", two)).line, 3);

    // a pragma for a different rule suppresses nothing
    let wrong = "fn f(&self) {\n    // lint: allow(no-adhoc-threads): wrong rule\n    let g = self.state.lock().unwrap();\n}\n";
    let fa = analyze_source("src/fixture.rs", wrong);
    assert_eq!(only(&fa).rule, Rule::LockDiscipline);
    assert_eq!(fa.allows.len(), 1, "the mismatched pragma is still recorded");
}

#[test]
fn malformed_pragmas_are_violations_and_never_suppress() {
    // empty reason: rejected, and the covered violation survives
    let empty = "fn f() {\n    // lint: allow(no-wallclock-or-unseeded-rng):\n    let t0 = std::time::Instant::now();\n}\n";
    let fa = analyze_source("src/fixture.rs", empty);
    assert_eq!(fa.violations.len(), 2, "{:?}", fa.violations);
    assert_eq!(fa.violations[0].line, 2);
    assert_eq!(fa.violations[0].rule, Rule::MalformedPragma);
    assert_eq!(fa.violations[1].line, 3);
    assert_eq!(fa.violations[1].rule, Rule::NoWallclock);
    assert!(fa.allows.is_empty());

    // unknown rule names are rejected, including the meta-rule itself
    let unknown = "// lint: allow(no-such-rule): because\nfn f() {}\n";
    assert_eq!(only(&analyze_source("src/fixture.rs", unknown)).rule, Rule::MalformedPragma);
    let meta = "// lint: allow(malformed-pragma): nice try\nfn f() {}\n";
    assert_eq!(only(&analyze_source("src/fixture.rs", meta)).rule, Rule::MalformedPragma);

    // unrecognized directives are flagged, doc prose is not parsed
    let directive = "// lint: frobnicate\nfn f() {}\n";
    assert_eq!(only(&analyze_source("src/fixture.rs", directive)).rule, Rule::MalformedPragma);
    assert_quiet("src/fixture.rs", "/// lint: allow(no-adhoc-threads): doc prose\nfn f() {}\n");
}

#[test]
fn strings_and_comments_never_trip_rules() {
    let src = "fn f() {\n    let a = \"std::thread::spawn(Instant::now())\";\n    let b = r#\"state.lock().unwrap()\"#;\n    // a comment mentioning debug_assert! and Vec::new()\n}\n";
    assert_quiet("src/ttd/tensor.rs", src);
}

#[test]
fn the_tree_scans_clean_with_reasoned_pragmas() {
    // The same gate CI enforces: deny mode over this crate must be
    // clean, and every allow pragma must carry a non-empty reason.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = analyze_tree(root).expect("scan the crate");
    assert!(report.files_scanned > 20, "walked src/tests/benches: {}", report.files_scanned);
    let rendered: Vec<String> = report.violations.iter().map(|v| v.render()).collect();
    assert!(report.clean(), "tree must lint clean:\n{}", rendered.join("\n"));
    assert!(!report.allows.is_empty(), "the tree documents its known exceptions");
    for a in &report.allows {
        assert!(!a.reason.trim().is_empty(), "{}:{} allow({}) needs a reason", a.file, a.line, a.rule.id());
    }
    let json = report.to_json("deny").render();
    assert!(json.contains("lint-report-v1"));
    assert!(json.contains("\"clean\":true"));
}
