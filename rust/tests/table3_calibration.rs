//! Integration test: the simulated Table III must reproduce the
//! paper's headline numbers — 1.7x speedup, ~40% energy reduction —
//! and every per-phase cell within 10%.

use tt_edge::sim::report::paper;
use tt_edge::sim::{compress_resnet32, SocConfig};
use tt_edge::trace::Phase;

fn within(pct: f64, got: f64, want: f64) -> bool {
    (got - want).abs() / want <= pct / 100.0
}

#[test]
fn table3_reproduces_paper_within_tolerance() {
    let (outcome, reports) =
        compress_resnet32(42, 0.12, &[SocConfig::baseline(), SocConfig::tt_edge()]);
    let base = &reports[0];
    let tte = &reports[1];

    // Workload sanity: Table-I-like compression on the same run.
    assert!(
        (2.9..4.2).contains(&outcome.compression_ratio),
        "compression ratio {}",
        outcome.compression_ratio
    );

    // Per-phase execution times within 10% of Table III.
    for (phase, t_ms, _e) in paper::BASE {
        let got = base.phase(phase).time_ms;
        assert!(within(10.0, got, t_ms), "base {phase:?}: {got:.1} vs {t_ms}");
    }
    for (phase, t_ms, _e) in paper::TTE {
        let got = tte.phase(phase).time_ms;
        assert!(within(10.0, got, t_ms), "tte {phase:?}: {got:.1} vs {t_ms}");
    }

    // Headline claims.
    let speedup = base.total_ms / tte.total_ms;
    assert!(within(5.0, speedup, paper::SPEEDUP), "speedup {speedup:.3}");
    let reduction = (1.0 - tte.total_mj / base.total_mj) * 100.0;
    assert!(
        (reduction - paper::ENERGY_REDUCTION_PCT).abs() < 2.0,
        "energy reduction {reduction:.1}%"
    );

    // Structural claims from the prose.
    let hbd_speedup = base.phase(Phase::Hbd).time_ms / tte.phase(Phase::Hbd).time_ms;
    assert!(within(6.0, hbd_speedup, 2.05), "HBD speedup {hbd_speedup:.2}");
    let st_speedup =
        base.phase(Phase::SortTrunc).time_ms / tte.phase(Phase::SortTrunc).time_ms;
    assert!(within(12.0, st_speedup, 9.96), "S&T speedup {st_speedup:.2}");
    // "HBD ... 72.8% of the total TTD runtime" on the baseline
    let hbd_share = base.phase(Phase::Hbd).time_ms / base.total_ms * 100.0;
    assert!((hbd_share - 72.8).abs() < 3.0, "HBD share {hbd_share:.1}%");
    // QR rows identical across configs (core-resident in both)
    assert!(
        (base.phase(Phase::QrDiag).time_ms - tte.phase(Phase::QrDiag).time_ms).abs() < 1e-9
    );
}

#[test]
fn bidiagonalization_dominates_svd_by_about_3_6x() {
    // Paper section I: "bidiagonalization ... about 3.6x more
    // time-consuming than diagonalization" on the edge processor.
    let (_, reports) = compress_resnet32(7, 0.12, &[SocConfig::baseline()]);
    let base = &reports[0];
    let ratio = base.phase(Phase::Hbd).time_ms / base.phase(Phase::QrDiag).time_ms;
    assert!((2.8..4.4).contains(&ratio), "HBD/QR ratio {ratio:.2}");
}
