//! Property harness for the transport model (ISSUE 2 satellite),
//! offline-hypothesis style mirroring `ttd_properties.rs`: randomized
//! invariants through `testutil::check`, so a failure prints the case
//! index + seed needed to replay the exact counterexample.

use tt_edge::coordinator::transport::{Link, SendOutcome, TransportStats};
use tt_edge::testutil::check;
use tt_edge::util::Rng;

fn rand_link(rng: &mut Rng) -> Link {
    Link {
        bandwidth_kbps: 1.0 + rng.uniform() * 10_000.0,
        latency_ms: rng.uniform() * 500.0,
        loss: 0.0,
        max_retries: rng.below(6) as u32,
    }
}

/// Transfer time is strictly monotone in payload size (more bytes can
/// never arrive sooner) and latency is an exact lower bound.
#[test]
fn transfer_time_monotone_in_payload_bytes() {
    check(40, 0xBEA7, |rng| {
        let link = rand_link(rng);
        let a = rng.below(1 << 20);
        let b = a + 1 + rng.below(1 << 20);
        let ta = link.transfer_ms(a);
        let tb = link.transfer_ms(b);
        assert!(tb > ta, "bytes {a}->{b} but ms {ta}->{tb}");
        assert!(ta >= link.latency_ms);
        // and exactly linear: doubling the payload doubles the
        // payload-time component
        let t2 = link.transfer_ms(2 * a);
        let payload = ta - link.latency_ms;
        assert!(
            ((t2 - link.latency_ms) - 2.0 * payload).abs() <= 1e-9 * payload.max(1.0),
            "non-linear payload time"
        );
    });
}

/// Retry accounting conserves bytes: every attempt's payload lands in
/// exactly one of `bytes` (the delivering attempt) or `retrans_bytes`
/// (lost attempts), and `retries` counts the lost attempts.
#[test]
fn retry_accounting_conserves_bytes() {
    check(30, 0xC0DE, |rng| {
        let link = Link {
            loss: rng.uniform() * 0.9,
            max_retries: rng.below(5) as u32,
            ..rand_link(rng)
        };
        let payload = 64 + rng.below(8192);
        let sends = 1 + rng.below(24);
        let mut stats = TransportStats::default();
        let mut draw = rng.fork(1);
        let outcomes: Vec<SendOutcome> =
            (0..sends).map(|_| stats.send_faulty(&link, payload, &mut draw)).collect();

        let total_attempts: u32 = outcomes.iter().map(|o| o.attempts).sum();
        let delivered = outcomes.iter().filter(|o| o.delivered).count();
        // conservation: every attempt's bytes are accounted exactly once
        assert_eq!(stats.bytes + stats.retrans_bytes, payload * total_attempts as usize);
        assert_eq!(stats.bytes, payload * delivered);
        assert_eq!(stats.retries, (total_attempts as usize) - delivered);
        assert_eq!(stats.messages, delivered);
        assert_eq!(stats.dropped, sends - delivered);
        // attempts are bounded by the retry budget
        for o in &outcomes {
            assert!(o.attempts >= 1 && o.attempts <= 1 + link.max_retries);
            assert!(o.delivered || o.attempts == 1 + link.max_retries);
            // time is exactly attempts x per-attempt transfer
            let want = o.attempts as f64 * link.transfer_ms(payload);
            assert!((o.ms - want).abs() < 1e-6 * want.max(1.0), "{} vs {want}", o.ms);
        }
    });
}

/// A zero-loss link reproduces today's exact latencies: `send_faulty`
/// is bit-identical to the legacy `send` — same per-message ms, same
/// stats, no RNG consumed, no retries.
#[test]
fn zero_loss_link_reproduces_legacy_latencies() {
    check(30, 0x10E5, |rng| {
        let link = rand_link(rng); // loss = 0.0
        let sends = 1 + rng.below(16);
        let payloads: Vec<usize> = (0..sends).map(|_| rng.below(1 << 16)).collect();

        let mut legacy = TransportStats::default();
        let legacy_ms: Vec<f64> = payloads.iter().map(|&b| legacy.send(&link, b)).collect();

        let mut faulty = TransportStats::default();
        let mut draw = rng.fork(2);
        let probe = draw.clone().next_u64();
        let faulty_ms: Vec<f64> = payloads
            .iter()
            .map(|&b| {
                let o = faulty.send_faulty(&link, b, &mut draw);
                assert!(o.delivered);
                assert_eq!(o.attempts, 1);
                o.ms
            })
            .collect();

        // bit-identical per-message times and tallies
        assert_eq!(legacy_ms, faulty_ms);
        assert_eq!(legacy.messages, faulty.messages);
        assert_eq!(legacy.bytes, faulty.bytes);
        assert_eq!(legacy.total_ms, faulty.total_ms);
        assert_eq!(faulty.retries, 0);
        assert_eq!(faulty.retrans_bytes, 0);
        assert_eq!(faulty.dropped, 0);
        // the zero-loss path must not consume randomness
        assert_eq!(draw.next_u64(), probe);
    });
}

/// The lossy path is a pure function of the RNG stream: identical
/// seeds give identical outcome sequences and identical stats.
#[test]
fn lossy_sends_replay_from_the_seed() {
    check(20, 0x5EED, |rng| {
        let link = Link {
            loss: 0.1 + rng.uniform() * 0.8,
            max_retries: 1 + rng.below(4) as u32,
            ..rand_link(rng)
        };
        let payload = 1 + rng.below(4096);
        let stream_seed = rng.next_u64();
        let run = || {
            let mut stats = TransportStats::default();
            let mut draw = Rng::new(stream_seed);
            let outs: Vec<SendOutcome> =
                (0..12).map(|_| stats.send_faulty(&link, payload, &mut draw)).collect();
            (format!("{outs:?}"), format!("{stats:?}"))
        };
        assert_eq!(run(), run());
    });
}
