//! Golden-trace regression harness (ISSUE 1 satellite): the `HwOp`
//! stream emitted by the numerics is the contract between `ttd/` and
//! the SoC simulator, so we pin it three ways:
//!
//! 1. **Analytic counts** — for a fixed-seed 16x8 SVD the reflector
//!    algebra fixes the exact HouseGen/VecDiv/GEMM counts; any change
//!    to the op-emission protocol trips these immediately.
//! 2. **Snapshot file** — a serialized summary of the 16x8 SVD and a
//!    4x6x6 TTD trace (op counts + per-phase simulated cycles on both
//!    SoCs) is compared against `tests/golden/trace_summary.golden`.
//!    Set `TT_EDGE_BLESS=1` to re-bless after an *intentional* change;
//!    a missing file is written on first run.
//! 3. **Serial/parallel equivalence** — the pipeline's deterministic
//!    layer-order merge must reproduce the serial trace op-for-op, and
//!    therefore cost identical cycles and energy under both SoCs.

use std::path::PathBuf;

use tt_edge::pipeline;
use tt_edge::sim::workload::{compress_model, synthetic_model};
use tt_edge::sim::{CostSink, HwTimeline, SimReport, SocConfig};
use tt_edge::trace::{HwOp, Phase, SummarySink, TraceSink, VecSink};
use tt_edge::ttd::svd::svd;
use tt_edge::ttd::{decompose, Matrix, Tensor, TtSpec};
use tt_edge::util::Rng;

fn svd_trace_16x8() -> VecSink {
    let mut rng = Rng::new(0xA11CE);
    let a = Matrix::from_vec(16, 8, rng.normal_vec(16 * 8));
    let mut sink = VecSink::default();
    let _ = svd(&a, &mut sink);
    sink
}

fn ttd_trace_4x6x6() -> VecSink {
    let mut rng = Rng::new(0xB0B);
    let w = Tensor::from_vec(&[4, 6, 6], rng.normal_vec(144));
    let mut sink = VecSink::default();
    let _ = decompose(&w, &TtSpec::eps(0.15), &mut sink);
    sink
}

fn phase_sequence(ops: &[HwOp]) -> Vec<Phase> {
    ops.iter()
        .filter_map(|o| match o {
            HwOp::SetPhase(p) => Some(*p),
            _ => None,
        })
        .collect()
}

/// Per-kind op counts via the streaming [`SummarySink`] — same labels
/// and order the hand-rolled golden harness always used
/// ([`HwOp::KIND_LABELS`] is defined to match).
fn op_kind_counts(ops: &[HwOp]) -> Vec<(&'static str, u64)> {
    let mut s = SummarySink::default();
    for op in ops {
        s.op(*op);
    }
    s.counts().collect()
}

/// Phase-bracketed cycle totals on both SoCs — the simulator-facing
/// fingerprint of a trace. Computed twice, via the streaming
/// [`CostSink`] and via a recorded-trace replay, and asserted equal:
/// the golden file therefore pins both paths to the same numbers.
fn cost_fingerprint(ops: &[HwOp]) -> String {
    let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
    let mut streamed = CostSink::new(&configs);
    for op in ops {
        streamed.op(*op);
    }
    let mut out = String::new();
    for (tl, cfg) in streamed.timelines().iter().zip(&configs) {
        let name = cfg.name();
        // replay oracle: bit-identical per-phase cycles
        let mut replayed = HwTimeline::new(cfg.clone());
        for op in ops {
            replayed.op(*op);
        }
        for p in Phase::ALL {
            assert_eq!(
                tl.cycles.get(p),
                replayed.cycles.get(p),
                "streaming vs replay drift: {name}/{p:?}"
            );
            out.push_str(&format!("{name}/{}: {} cycles\n", p.label(), tl.cycles.get(p)));
        }
        out.push_str(&format!("{name}/total: {} cycles\n", tl.cycles.total()));
    }
    out
}

// ---------------------------------------------------- analytic pins

#[test]
fn svd_16x8_has_exact_reflector_op_counts() {
    let sink = svd_trace_16x8();
    // n = 8 columns: n left + (n-2) right Householder generations.
    assert_eq!(sink.count(|o| matches!(o, HwOp::HouseGen { .. })), 8 + 6);
    // VEC-DIVISIONs: 14 in the reduction (every reflector), 14 more in
    // the accumulation replay (8 left + 6 right).
    assert_eq!(sink.count(|o| matches!(o, HwOp::VecDiv { .. })), 28);
    // Chained GEMM pairs: reduction 7 left + 6 right, accumulation
    // 8 left + 6 right -> 27 pairs.
    assert_eq!(sink.count(|o| matches!(o, HwOp::Gemm { .. })), 54);
    // The first HOUSE spans the full 16-row pivot column.
    assert!(matches!(
        sink.ops.iter().find(|o| matches!(o, HwOp::HouseGen { .. })).copied(),
        Some(HwOp::HouseGen { len: 16 })
    ));
    // Phase brackets: exactly HBD then QR for a tall input.
    assert_eq!(phase_sequence(&sink.ops), vec![Phase::Hbd, Phase::QrDiag]);
    // QR emitted rotations, and every op after the QR bracket is QR-phase.
    assert!(sink.count(|o| matches!(o, HwOp::GivensRot { .. })) > 0);
}

#[test]
fn ttd_4x6x6_has_expected_phase_structure() {
    let sink = ttd_trace_4x6x6();
    let phases = phase_sequence(&sink.ops);
    // Algorithm 1 on a 3-d tensor: 2 SVDs -> 2 HBD + 2 QR brackets,
    // one Sort+Trunc bracket per split + the delta computation.
    assert_eq!(phases.iter().filter(|p| **p == Phase::Hbd).count(), 2);
    assert_eq!(phases.iter().filter(|p| **p == Phase::QrDiag).count(), 2);
    assert_eq!(phases[0], Phase::SortTrunc, "delta comes first");
    assert_eq!(
        phases.iter().filter(|p| **p == Phase::UpdateSvdInput).count(),
        2
    );
    // Every HBD bracket is followed by its QR bracket before the next HBD.
    let hbd_qr: Vec<Phase> = phases
        .iter()
        .copied()
        .filter(|p| matches!(p, Phase::Hbd | Phase::QrDiag))
        .collect();
    assert_eq!(hbd_qr, vec![Phase::Hbd, Phase::QrDiag, Phase::Hbd, Phase::QrDiag]);
    // One sort, one truncation per split; one delta CoreScalar total.
    assert_eq!(sink.count(|o| matches!(o, HwOp::Sort { .. })), 2);
    assert_eq!(sink.count(|o| matches!(o, HwOp::Trunc { .. })), 2);
    assert_eq!(sink.count(|o| matches!(o, HwOp::CoreScalar { .. })), 1);
    // Reshapes: split 0 is wide (2 transpose reshapes) + working-matrix
    // reshape + core reshape; split 1 tall: working + core; final core.
    assert_eq!(sink.count(|o| matches!(o, HwOp::Reshape { .. })), 7);
}

// ---------------------------------------------------- snapshot file

fn trace_summary() -> String {
    let mut summary = String::from("# golden trace summary (TT_EDGE_BLESS=1 to re-bless)\n");
    for (label, sink) in [("svd16x8", svd_trace_16x8()), ("ttd4x6x6", ttd_trace_4x6x6())] {
        summary.push_str(&format!("[{label}]\n"));
        summary.push_str(&format!("ops: {}\n", sink.ops.len()));
        for (kind, count) in op_kind_counts(&sink.ops) {
            summary.push_str(&format!("{kind}: {count}\n"));
        }
        summary.push_str(&cost_fingerprint(&sink.ops));
    }
    summary
}

#[test]
fn trace_summary_matches_golden_snapshot() {
    let summary = trace_summary();
    let path: PathBuf = [env!("CARGO_MANIFEST_DIR"), "tests", "golden", "trace_summary.golden"]
        .iter()
        .collect();
    let bless = std::env::var("TT_EDGE_BLESS").is_ok();
    if bless || !path.exists() {
        // No blessed file yet (fresh checkout) or an explicit re-bless.
        // A fresh checkout must not turn the test vacuous: before
        // writing the pin, prove the summary is *reproducible* — a
        // second independent generation must match bit-for-bit (the
        // property the pin relies on).
        assert_eq!(summary, trace_summary(), "trace summary is not deterministic — cannot bless");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &summary).unwrap();
        eprintln!("blessed golden trace summary at {}", path.display());
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        summary, want,
        "trace summary drifted from {} — investigate, then TT_EDGE_BLESS=1 to re-bless",
        path.display()
    );
}

// ------------------------------- federated faulty-round snapshot

/// One benign + one faulty federated round (TT-Edge SoC, node 2
/// force-dropped) on a truncated model, serialized through the
/// RoundReport JSON emitter. Pins the event-driven scheduler end to
/// end: wire bytes, aggregate_rel_err, simulated ms/mJ, deadline and
/// participation arithmetic — a scheduler refactor that shifts any
/// simulated cost or admission decision trips this.
fn federated_round_summary() -> String {
    use tt_edge::coordinator::{Coordinator, FaultPlan, FederatedConfig};

    let mut out = String::from(
        "# golden federated rounds (TT_EDGE_BLESS=1 to re-bless)\n",
    );
    for (label, faults) in [
        ("benign", FaultPlan::default()),
        (
            "node2-dropped",
            FaultPlan { forced_dropouts: vec![(0, 2)], ..FaultPlan::default() },
        ),
    ] {
        let cfg = FederatedConfig {
            nodes: 4,
            rounds: 1,
            eps: 0.12,
            soc: SocConfig::tt_edge(),
            faults,
            ..Default::default()
        };
        let mut c = Coordinator::new(cfg);
        c.global.truncate(4);
        let r = c.round(0);
        out.push_str(&format!("[{label}]\n{}\n", r.to_json().render()));
    }
    out
}

#[test]
fn federated_faulty_round_matches_golden_snapshot() {
    let summary = federated_round_summary();
    let path: PathBuf =
        [env!("CARGO_MANIFEST_DIR"), "tests", "golden", "federated_round.golden"]
            .iter()
            .collect();
    let bless = std::env::var("TT_EDGE_BLESS").is_ok();
    if bless || !path.exists() {
        // Same protocol as the trace snapshot: prove reproducibility
        // before pinning, so a fresh checkout can't bless noise.
        assert_eq!(
            summary,
            federated_round_summary(),
            "federated round summary is not deterministic — cannot bless"
        );
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &summary).unwrap();
        eprintln!("blessed golden federated rounds at {}", path.display());
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        summary, want,
        "federated round drifted from {} — investigate, then TT_EDGE_BLESS=1 to re-bless",
        path.display()
    );
}

#[test]
fn faulty_golden_round_has_exactly_one_dropped_node() {
    // The snapshot's shape contract, asserted directly (the snapshot
    // file pins the numbers; this pins the semantics).
    let summary = federated_round_summary();
    let faulty = summary.split("[node2-dropped]\n").nth(1).unwrap().trim();
    let j = tt_edge::util::json::parse(faulty).unwrap();
    assert_eq!(j.get("scheduled").unwrap().as_usize().unwrap(), 4);
    assert_eq!(j.get("participants").unwrap().as_usize().unwrap(), 3);
    assert_eq!(j.get("dropped").unwrap().as_usize().unwrap(), 1);
    assert_eq!(j.get("late").unwrap().as_usize().unwrap(), 0);
    assert_eq!(j.get("quorum_met").unwrap(), &tt_edge::util::json::Json::Bool(false));
    let benign = summary
        .split("[benign]\n")
        .nth(1)
        .unwrap()
        .split("[node2-dropped]")
        .next()
        .unwrap()
        .trim();
    let jb = tt_edge::util::json::parse(benign).unwrap();
    assert_eq!(jb.get("participants").unwrap().as_usize().unwrap(), 4);
    assert_eq!(jb.get("dropped").unwrap().as_usize().unwrap(), 0);
    // dropping a node shrinks the round's wire traffic
    assert!(
        j.get("wire_bytes").unwrap().as_usize().unwrap()
            < jb.get("wire_bytes").unwrap().as_usize().unwrap()
    );
}

// ------------------------------------- serial/parallel equivalence

#[test]
fn parallel_merged_trace_costs_identically_to_serial() {
    let mut layers = synthetic_model(7, 3.55, 0.035);
    layers.truncate(5); // keep the test fast; covers mixed layer sizes

    let mut serial = VecSink::default();
    let serial_out = compress_model(&layers, 0.12, &mut serial);

    let mut parallel = VecSink::default();
    let parallel_out = pipeline::compress_model_parallel(&layers, 0.12, 4, &mut parallel);

    // Op-for-op identical streams...
    assert_eq!(serial.ops, parallel.ops);
    assert_eq!(serial_out.final_params, parallel_out.final_params);

    // ...therefore identical simulated cycles AND energy on both SoCs.
    for cfg in [SocConfig::baseline(), SocConfig::tt_edge()] {
        let mut tl_s = HwTimeline::new(cfg.clone());
        let mut tl_p = HwTimeline::new(cfg);
        for op in &serial.ops {
            tl_s.op(*op);
        }
        for op in &parallel.ops {
            tl_p.op(*op);
        }
        assert_eq!(tl_s.cycles.total(), tl_p.cycles.total());
        let rs = SimReport::from_timeline(&tl_s);
        let rp = SimReport::from_timeline(&tl_p);
        assert_eq!(rs.total_ms, rp.total_ms, "{}", rs.config_name);
        assert_eq!(rs.total_mj, rp.total_mj, "{}", rs.config_name);
        for (a, b) in rs.phases.iter().zip(&rp.phases) {
            assert_eq!(a.cycles, b.cycles, "{:?}", a.phase);
        }
    }
}
