//! Randomized-SVD property suite (ISSUE 9).
//!
//! The `rsvd` method swaps the exact bidiagonal SVD for the seeded
//! Halko range-finder *inside* the same TTD pipeline, so the contracts
//! it must keep are the pipeline's own:
//!
//! * uncapped specs keep the Oseledets eps round-trip bound exactly
//!   (the sketch clamps to full rank, so nothing is thrown away before
//!   delta-truncation);
//! * capped specs recover planted TT ranks — the sketch of width
//!   `cap + oversample` captures an exactly-low-rank range;
//! * the op stream, TT cores, and reports are **bitwise** deterministic
//!   in the sketch seed — across host-parallel widths and both GEMM
//!   kernels (the golden-trace discipline of `kernel_equivalence.rs`);
//! * on well-separated spectra rsvd and exact agree on the recovered
//!   bond ranks.

use tt_edge::dse::Workload;
use tt_edge::sim::SocConfig;
use tt_edge::testutil::{check, rand_shape, rand_tensor, rand_tt_tensor, rel_frobenius};
use tt_edge::trace::NullSink;
use tt_edge::ttd::tensor::set_gemm_kernel;
use tt_edge::ttd::{decompose, reconstruct, TtSpec};
use tt_edge::{CompressionJob, GemmKernel};

/// Uncapped rsvd keeps the prescribed-accuracy bound: the sketch is
/// clamped to the full unfolding rank, so the eps contract is the
/// exact path's, not a probabilistic relaxation.
#[test]
fn uncapped_rsvd_keeps_the_eps_roundtrip_bound() {
    check(20, 9100, |rng| {
        let nd = 2 + rng.below(3); // 2..=4 dims
        let shape = rand_shape(rng, nd, 2, 6);
        let w = rand_tensor(rng, &shape);
        let eps = [0.05f32, 0.15, 0.3, 0.6][rng.below(4)];
        let seed = 1 + rng.below(1000) as u64;
        let d = decompose(&w, &TtSpec::eps(eps).rsvd(seed, 8), &mut NullSink);
        let err = rel_frobenius(&reconstruct(&d), &w);
        assert!(err <= eps + 1e-3, "shape {shape:?} eps {eps} seed {seed}: err {err}");
        assert_eq!(d.ranks[0], 1);
        assert_eq!(*d.ranks.last().unwrap(), 1);
    });
}

/// Planted low-TT-rank tensors are recovered through the capped rsvd
/// path: `cap + oversample` sketch columns capture an exactly-rank-r
/// range, so ranks stay within the plant and the error stays near
/// round-off.
#[test]
fn capped_rsvd_recovers_planted_ranks() {
    check(15, 9101, |rng| {
        let nd = 3 + rng.below(2); // 3..=4 dims
        let shape = rand_shape(rng, nd, 3, 6);
        let rmax = 1 + rng.below(3);
        let w = rand_tt_tensor(rng, &shape, rmax);
        let seed = 1 + rng.below(1000) as u64;
        let d = decompose(&w, &TtSpec::eps(1e-3).rank_cap(rmax).rsvd(seed, 8), &mut NullSink);
        for r in &d.ranks[1..nd] {
            assert!(*r <= rmax, "rank {r} > planted cap {rmax} ({shape:?})");
        }
        let err = rel_frobenius(&reconstruct(&d), &w);
        assert!(err <= 5e-3, "shape {shape:?} seed {seed}: err {err}");
    });
}

/// On well-separated spectra (an exactly low-rank plant) rsvd and the
/// exact SVD must agree on every recovered bond rank — the two methods
/// disagree on basis vectors, never on how much signal there is.
#[test]
fn rsvd_and_exact_agree_on_planted_bond_ranks() {
    check(15, 9102, |rng| {
        let shape = rand_shape(rng, 3, 3, 6);
        let rmax = 1 + rng.below(3);
        let w = rand_tt_tensor(rng, &shape, rmax);
        let exact = decompose(&w, &TtSpec::eps(1e-3).rank_cap(rmax), &mut NullSink);
        let seed = 1 + rng.below(1000) as u64;
        let rand =
            decompose(&w, &TtSpec::eps(1e-3).rank_cap(rmax).rsvd(seed, 8), &mut NullSink);
        assert_eq!(exact.ranks, rand.ranks, "shape {shape:?} seed {seed}");
    });
}

/// One rsvd transformer job, fingerprinted end-to-end: reports, final
/// params, worst error. Everything downstream of the sketch must be a
/// pure function of (workload seed, sketch seed) — not of the host
/// width or the GEMM kernel.
fn rsvd_fingerprint(kernel: GemmKernel, parallel: usize) -> (Vec<String>, usize, f32) {
    let configs = [SocConfig::tt_edge(), SocConfig::systolic()];
    let mut backing = None;
    let out = Workload::TinyGpt
        .job(7, &mut backing)
        .spec(TtSpec::eps(0.12).rsvd(7, 8))
        .kernel(kernel)
        .parallel(parallel)
        .socs(&configs)
        .run()
        .unwrap();
    let reports = out.reports.iter().map(|r| r.to_json().render()).collect();
    (reports, out.outcome.final_params, out.outcome.max_rel_err)
}

#[test]
fn rsvd_is_bitwise_deterministic_across_widths_and_kernels() {
    let baseline = rsvd_fingerprint(GemmKernel::Reference, 1);
    for kernel in [GemmKernel::Reference, GemmKernel::Vectorized] {
        for parallel in [1usize, 4] {
            assert_eq!(
                rsvd_fingerprint(kernel, parallel),
                baseline,
                "{kernel:?} x parallel {parallel} diverged from the serial reference"
            );
        }
    }
    set_gemm_kernel(GemmKernel::Vectorized);

    // different sketch seeds are different numeric identities: the
    // cache key splits (ISSUE 9 satellite), so byte-equality across
    // seeds is not promised — only within one.
    let k7 = CompressionJob::synthetic(1).spec(TtSpec::eps(0.12).rsvd(7, 8)).cache_key();
    let k8 = CompressionJob::synthetic(1).spec(TtSpec::eps(0.12).rsvd(8, 8)).cache_key();
    assert_ne!(k7, k8);
}
