//! Record-once / replay-many contracts for the [`tt_edge::trace::OpProgram`]
//! seam:
//!
//! 1. RLE round-trip — a program recorded from a job replays op-for-op
//!    identical to the `VecSink` trace of the same job, at any thread
//!    count.
//! 2. Costing bit-identity — replaying a program (both the per-op path
//!    and the fast run-fold) produces cycles, energy and per-phase
//!    banks identical to live `CostSink` costing, across >= 3 seeds x
//!    both paper SoCs x serial-vs-`--parallel 4`.
//! 3. The numerics-pass counter moves only when numerics actually run.

use tt_edge::model::resnet32::ConvLayer;
use tt_edge::sim::workload::{compress_model, synthetic_model};
use tt_edge::sim::{CostSink, SocConfig};
use tt_edge::trace::{Phase, VecSink};
use tt_edge::ttd::Tensor;
use tt_edge::CompressionJob;

fn small_model(seed: u64) -> Vec<(ConvLayer, Tensor)> {
    let mut layers = synthetic_model(seed, 3.55, 0.035);
    layers.truncate(4);
    layers
}

#[test]
fn rle_compaction_round_trips_vec_sink_replay() {
    for seed in [3u64, 7, 11] {
        let layers = small_model(seed);
        let mut serial = VecSink::default();
        let _ = compress_model(&layers, 0.12, &mut serial);
        for threads in [1, 4] {
            let (_, program) = CompressionJob::model(&layers)
                .eps(0.12)
                .parallel(threads)
                .program()
                .unwrap();
            assert_eq!(program.ops.layer_count(), layers.len());
            assert_eq!(
                program.ops.op_count() as usize,
                serial.ops.len(),
                "seed {seed} threads {threads}"
            );
            // RLE never inflates; how much it compacts depends on how
            // homogeneous the Givens sweeps are (crafted-stream pins
            // live in trace::program's unit tests)
            assert!(program.ops.run_count() as u64 <= program.ops.op_count());
            let mut replayed = VecSink::default();
            program.ops.replay(&mut replayed);
            assert_eq!(replayed.ops, serial.ops, "seed {seed} threads {threads}");
        }
    }
}

#[test]
fn program_replay_costs_bit_identically_to_live_costing() {
    let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
    for seed in [1u64, 2, 3] {
        let layers = small_model(seed);
        for threads in [1, 4] {
            let live = CompressionJob::model(&layers)
                .eps(0.12)
                .parallel(threads)
                .socs(&configs)
                .run()
                .unwrap();
            let (recorded, program) = CompressionJob::model(&layers)
                .eps(0.12)
                .parallel(threads)
                .socs(&configs)
                .program()
                .unwrap();
            let replayed = CompressionJob::replay(&program).socs(&configs).run().unwrap();
            // fast run-fold path, driven directly
            let mut folded = CostSink::new(&configs);
            folded.fold_program(&program.ops);
            let fold_reports = folded.reports();
            for (((a, b), c), d) in live
                .reports
                .iter()
                .zip(&recorded.reports)
                .zip(&replayed.reports)
                .zip(&fold_reports)
            {
                for r in [b, c, d] {
                    assert_eq!(a.total_ms, r.total_ms, "seed {seed} threads {threads}");
                    assert_eq!(a.total_mj, r.total_mj);
                    for p in Phase::ALL {
                        assert_eq!(a.phase(p).cycles, r.phase(p).cycles, "{p:?}");
                        assert_eq!(a.phase(p).energy_mj, r.phase(p).energy_mj, "{p:?}");
                    }
                }
            }
            // the recorded summary survives into replay outcomes
            assert_eq!(replayed.outcome.final_params, live.outcome.final_params);
            assert_eq!(replayed.outcome.max_rel_err, live.outcome.max_rel_err);
        }
    }
}

#[test]
fn replay_never_moves_the_numerics_pass_counter() {
    let layers = small_model(9);
    let (_, program) = CompressionJob::model(&layers).eps(0.2).program().unwrap();
    let before = tt_edge::numerics_pass_count();
    for _ in 0..5 {
        let out = CompressionJob::replay(&program)
            .soc(SocConfig::tt_edge())
            .run()
            .unwrap();
        assert_eq!(out.reports.len(), 1);
        assert!(out.reports[0].total_ms > 0.0);
    }
    assert_eq!(tt_edge::numerics_pass_count(), before);
}
