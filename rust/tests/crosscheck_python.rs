//! Cross-language contract tests: the rust model inventory must match
//! the manifest the python exporter wrote (shapes, order, arity) —
//! this is the contract that lets the coordinator marshal parameters
//! into the AOT artifacts blindly. Skips if artifacts are absent.

use tt_edge::model::resnet32::param_specs;
use tt_edge::runtime::{default_dir, Dtype, Manifest};

fn manifest() -> Option<Manifest> {
    let dir = default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(&dir).expect("manifest"))
}

#[test]
fn resnet_forward_inputs_match_rust_param_specs() {
    let Some(m) = manifest() else { return };
    let e = m.entry("resnet32_fwd_b4").expect("entry");
    let specs = param_specs();
    // params... then the image batch
    assert_eq!(e.inputs.len(), specs.len() + 1);
    for (i, (spec, input)) in specs.iter().zip(&e.inputs).enumerate() {
        assert_eq!(
            input.shape, spec.shape,
            "input {i} ({}) shape mismatch",
            spec.name
        );
        assert_eq!(input.dtype, Dtype::F32);
    }
    assert_eq!(e.inputs.last().unwrap().shape, vec![4, 32, 32, 3]);
    assert_eq!(e.outputs[0].shape, vec![4, 10]);
}

#[test]
fn sgd_entry_returns_params_in_same_order() {
    let Some(m) = manifest() else { return };
    let e = m.entry("resnet32_sgd_b8").expect("entry");
    let specs = param_specs();
    // inputs: params + x + labels + lr ; outputs: params' + loss
    assert_eq!(e.inputs.len(), specs.len() + 3);
    assert_eq!(e.outputs.len(), specs.len() + 1);
    for (spec, (inp, outp)) in specs.iter().zip(e.inputs.iter().zip(&e.outputs)) {
        assert_eq!(inp.shape, spec.shape, "{}", spec.name);
        assert_eq!(outp.shape, spec.shape, "{}", spec.name);
    }
    // trailing entries: x (8,32,32,3), labels (8) i32, lr scalar
    let n = specs.len();
    assert_eq!(e.inputs[n].shape, vec![8, 32, 32, 3]);
    assert_eq!(e.inputs[n + 1].dtype, Dtype::I32);
    assert_eq!(e.inputs[n + 2].shape, Vec::<usize>::new());
    // loss scalar
    assert_eq!(e.outputs[n].shape, Vec::<usize>::new());
}

#[test]
fn ttd3_entry_shapes_match_conv_layout() {
    let Some(m) = manifest() else { return };
    let e = m.entry("ttd3_conv64").expect("entry");
    assert_eq!(e.inputs[0].shape, vec![3, 3, 64, 64]);
    // cores: (1,9,9), (9,64,64), (64,64,1) + two i32 ranks
    assert_eq!(e.outputs[0].shape, vec![1, 9, 9]);
    assert_eq!(e.outputs[1].shape, vec![9, 64, 64]);
    assert_eq!(e.outputs[2].shape, vec![64, 64, 1]);
    assert_eq!(e.outputs[3].dtype, Dtype::I32);
    assert_eq!(e.outputs[4].dtype, Dtype::I32);
    // chain consistency, as the rust TtDecomp enforces
    assert_eq!(e.outputs[0].shape[0], 1);
    assert_eq!(e.outputs[0].shape[2], e.outputs[1].shape[0]);
    assert_eq!(e.outputs[1].shape[2], e.outputs[2].shape[0]);
    assert_eq!(e.outputs[2].shape[2], 1);
}
