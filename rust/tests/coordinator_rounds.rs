//! Integration: federated rounds over the full 31-layer model —
//! leader/worker threading, transport accounting, aggregation quality,
//! and the TT-Edge vs Baseline contrast at the fleet level.

use tt_edge::coordinator::{Coordinator, FederatedConfig, Link};
use tt_edge::sim::SocConfig;

fn cfg(soc: SocConfig, nodes: usize, rounds: usize) -> FederatedConfig {
    FederatedConfig { nodes, rounds, eps: 0.12, soc, ..Default::default() }
}

#[test]
fn full_model_round_reduces_communication_3x() {
    let mut c = Coordinator::new(cfg(SocConfig::tt_edge(), 4, 1));
    let r = &c.run()[0];
    // Fig. 1 motivation: TT cores instead of dense parameters.
    assert!(
        r.communication_reduction > 2.8,
        "communication reduction {}",
        r.communication_reduction
    );
    // aggregation error bounded by the per-layer budget
    assert!(r.aggregate_rel_err < 0.12, "{}", r.aggregate_rel_err);
}

#[test]
fn multi_round_convergence_of_global_model() {
    let mut c = Coordinator::new(cfg(SocConfig::tt_edge(), 3, 3));
    let reports = c.run();
    assert_eq!(reports.len(), 3);
    // The model stays compressible across rounds (drift + truncation
    // must not blow up the ranks).
    let first = reports.first().unwrap().communication_reduction;
    let last = reports.last().unwrap().communication_reduction;
    assert!(last > 0.7 * first, "ratio collapsed: {first} -> {last}");
    for (_, w) in &c.global {
        assert!(w.data.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn fleet_level_energy_matches_table3_contrast() {
    let mut base = Coordinator::new(cfg(SocConfig::baseline(), 2, 1));
    let mut tte = Coordinator::new(cfg(SocConfig::tt_edge(), 2, 1));
    let rb = &base.run()[0];
    let rt = &tte.run()[0];
    // identical numerics, therefore identical wire traffic...
    assert_eq!(rb.wire_bytes, rt.wire_bytes);
    // ...but ~1.7x faster and ~40% cheaper on-device compression.
    let speedup = rb.mean_compress_ms / rt.mean_compress_ms;
    assert!((1.5..1.9).contains(&speedup), "speedup {speedup}");
    let saving = 1.0 - rt.mean_compress_mj / rb.mean_compress_mj;
    assert!((0.3..0.5).contains(&saving), "saving {saving}");
}

#[test]
fn slow_links_dominate_round_latency() {
    let mut cfg_slow = cfg(SocConfig::tt_edge(), 2, 1);
    cfg_slow.link = Link { bandwidth_kbps: 16.0, latency_ms: 100.0, ..Link::default() };
    let mut cfg_fast = cfg(SocConfig::tt_edge(), 2, 1);
    cfg_fast.link = Link { bandwidth_kbps: 10_000.0, latency_ms: 1.0, ..Link::default() };
    let r_slow = Coordinator::new(cfg_slow).round(0);
    let r_fast = Coordinator::new(cfg_fast).round(0);
    assert!(r_slow.round_transfer_ms > 20.0 * r_fast.round_transfer_ms);
}

#[test]
fn full_model_fault_free_round_schedules_everyone_on_time() {
    // Scheduler-era invariants on the full 31-layer model: with the
    // default (benign) fault plan the event-driven round is exactly
    // the legacy all-or-nothing round.
    let mut c = Coordinator::new(cfg(SocConfig::tt_edge(), 3, 1));
    let r = &c.run()[0];
    assert_eq!(r.participants, 3);
    assert_eq!(r.scheduled, 3);
    assert_eq!((r.dropped, r.late, r.retries, r.stragglers), (0, 0, 0, 0));
    // every node arrives at or before the profile-derived deadline,
    // and the round closes no later than that
    assert!(r.round_transfer_ms <= r.deadline_ms);
    assert!(r.round_close_ms <= r.deadline_ms);
    assert!(r.deadline_ms > 0.0);
}

#[test]
fn quorum_round_survives_a_dropped_node_on_the_full_model() {
    let mut c = Coordinator::new(FederatedConfig {
        min_quorum: 2,
        faults: tt_edge::coordinator::FaultPlan {
            forced_dropouts: vec![(0, 0)],
            ..Default::default()
        },
        ..cfg(SocConfig::tt_edge(), 3, 1)
    });
    let r = c.round(0);
    assert_eq!(r.participants, 2);
    assert_eq!(r.dropped, 1);
    // partial FedAvg stays within the per-layer budget
    assert!(r.aggregate_rel_err < 0.12, "{}", r.aggregate_rel_err);
    for (_, w) in &c.global {
        assert!(w.data.iter().all(|v| v.is_finite()));
    }
}
