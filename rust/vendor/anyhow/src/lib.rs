//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access and no vendored crate
//! registry, so this shim reimplements exactly the API surface the
//! workspace uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros. Error chains
//! are stored as flattened context strings: `{e}` prints the outermost
//! message, `{e:#}` prints the whole chain joined with `": "` (the same
//! rendering contract as real anyhow's alternate Display).

use std::fmt;

/// A flattened error: context frames first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Mirrors real anyhow: any std error converts, capturing its source
// chain. (`Error` itself deliberately does not implement
// `std::error::Error`, which keeps this blanket impl coherent.)
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: missing thing");
        assert_eq!(e.root_cause(), "missing thing");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("loading {}", "x")).unwrap_err();
        assert_eq!(format!("{e:#}"), "loading x: missing thing");
        let o: Option<u32> = None;
        assert_eq!(format!("{}", o.context("empty").unwrap_err()), "empty");
    }

    #[test]
    fn macros() {
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert!(f(true).is_ok());
        assert_eq!(format!("{}", f(false).unwrap_err()), "flag was false");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").is_err());
    }
}
