//! Hardware resource & power models — the structured description that
//! regenerates Table II (FPGA LUT/FF + 45 nm post-synthesis power) and
//! Table IV (comparison with Qu et al. [21]).
//!
//! The per-IP numbers are the paper's measurements (Genesys2
//! Kintex7-325T prototype; Design Compiler + PrimeTime PX at Nangate
//! 45 nm); everything *derived* — totals, shares, baseline vs TT-Edge
//! deltas, gated power — is computed here and cross-checked by tests
//! against the prose claims (+4% power, 5.6%/7.7% LUT/FF overhead,
//! 169.96 mW gated).

pub mod related;

/// One IP block row of Table II.
#[derive(Clone, Debug)]
pub struct IpBlock {
    pub name: &'static str,
    pub luts: u64,
    pub ffs: u64,
    /// Active power at 45 nm, mW (PrimeTime PX).
    pub power_mw: f64,
    /// Clock-gated power, mW (only the Rocket core gates).
    pub gated_power_mw: Option<f64>,
    /// Part of the TTD-Engine's specialized logic (not the reused GEMM)?
    pub ttd_engine_specialized: bool,
}

/// The TT-Edge processor's IP inventory (Table II).
pub fn tt_edge_blocks() -> Vec<IpBlock> {
    vec![
        IpBlock { name: "Rocket RISC-V Core", luts: 15_041, ffs: 9_890, power_mw: 10.90, gated_power_mw: Some(2.63), ttd_engine_specialized: false },
        IpBlock { name: "SRAM", luts: 166, ffs: 323, power_mw: 1.87, gated_power_mw: None, ttd_engine_specialized: false },
        IpBlock { name: "DDR Controller", luts: 7_961, ffs: 7_581, power_mw: 89.12, gated_power_mw: None, ttd_engine_specialized: false },
        IpBlock { name: "Peripherals incl. DMA", luts: 5_047, ffs: 10_373, power_mw: 10.60, gated_power_mw: None, ttd_engine_specialized: false },
        // Table II's interconnect LUT cell is garbled in the camera
        // copy; 10,186 is back-derived from the prose share claims
        // ("TTD-Engine contributes 5.6% of LUTs").
        IpBlock { name: "System Interconnect", luts: 10_186, ffs: 17_376, power_mw: 17.78, gated_power_mw: None, ttd_engine_specialized: false },
        IpBlock { name: "GEMM Accelerator", luts: 84_150, ffs: 32_939, power_mw: 40.77, gated_power_mw: None, ttd_engine_specialized: false },
        IpBlock { name: "HBD-ACC", luts: 1_346, ffs: 1_411, power_mw: 1.42, gated_power_mw: None, ttd_engine_specialized: true },
        IpBlock { name: "TRUNCATION", luts: 413, ffs: 884, power_mw: 0.78, gated_power_mw: None, ttd_engine_specialized: true },
        IpBlock { name: "SORTING", luts: 756, ffs: 476, power_mw: 0.49, gated_power_mw: None, ttd_engine_specialized: true },
        IpBlock { name: "FP-ALU", luts: 3_314, ffs: 2_287, power_mw: 2.23, gated_power_mw: None, ttd_engine_specialized: true },
        IpBlock { name: "DMA/SPM/GEMM IF + interconnect", luts: 1_412, ffs: 1_167, power_mw: 1.43, gated_power_mw: None, ttd_engine_specialized: true },
        // Table II's specialized-modules header row (6,517 FFs,
        // 7.19 mW) exceeds the sum of its itemized sub-rows; the
        // remainder is control/FSM glue the paper does not itemize.
        IpBlock { name: "TTD-Engine glue (unitemized)", luts: 29, ffs: 292, power_mw: 0.84, gated_power_mw: None, ttd_engine_specialized: true },
    ]
}

/// Look up one Table-II block by exact name. Panics on an unknown
/// name: the derived models (`sim::power`, `dse::area_proxy_luts`)
/// price mechanisms by these names, and a silent miss would zero a
/// block's power/area instead of failing loudly on a rename.
pub fn block(name: &str) -> IpBlock {
    tt_edge_blocks()
        .into_iter()
        .find(|b| b.name == name)
        .unwrap_or_else(|| panic!("unknown Table-II block `{name}`"))
}

/// Summary of Table II with derived quantities.
#[derive(Clone, Debug)]
pub struct ResourceSummary {
    pub total_luts: u64,
    pub total_ffs: u64,
    /// Active total power (mW) — TT-Edge, no clock gating.
    pub total_power_mw: f64,
    /// Power with the Rocket core clock-gated (TTD-offloaded phases).
    pub gated_power_mw: f64,
    /// Baseline = TT-Edge minus the specialized TTD-Engine modules.
    pub baseline_power_mw: f64,
    /// Specialized-logic totals.
    pub ttd_engine_luts: u64,
    pub ttd_engine_ffs: u64,
}

pub fn summarize() -> ResourceSummary {
    let blocks = tt_edge_blocks();
    let total_luts = blocks.iter().map(|b| b.luts).sum();
    let total_ffs = blocks.iter().map(|b| b.ffs).sum();
    let total_power_mw: f64 = blocks.iter().map(|b| b.power_mw).sum();
    let gate_delta: f64 = blocks
        .iter()
        .filter_map(|b| b.gated_power_mw.map(|g| b.power_mw - g))
        .sum();
    let ttd_power: f64 = blocks
        .iter()
        .filter(|b| b.ttd_engine_specialized)
        .map(|b| b.power_mw)
        .sum();
    ResourceSummary {
        total_luts,
        total_ffs,
        total_power_mw,
        gated_power_mw: total_power_mw - gate_delta,
        baseline_power_mw: total_power_mw - ttd_power,
        ttd_engine_luts: blocks.iter().filter(|b| b.ttd_engine_specialized).map(|b| b.luts).sum(),
        ttd_engine_ffs: blocks.iter().filter(|b| b.ttd_engine_specialized).map(|b| b.ffs).sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_match_paper_prose() {
        let s = summarize();
        // "TT-Edge consumes a total of 178.23 mW"
        assert!((s.total_power_mw - 178.23).abs() < 0.2, "{}", s.total_power_mw);
        // "baseline processor's 171.04 mW"
        assert!((s.baseline_power_mw - 171.04).abs() < 0.4, "{}", s.baseline_power_mw);
        // "TT-Edge operates at 169.96 mW" (core gated)
        assert!((s.gated_power_mw - 169.96).abs() < 0.2, "{}", s.gated_power_mw);
        // "+4% relative to the baseline"
        let pct = (s.total_power_mw / s.baseline_power_mw - 1.0) * 100.0;
        assert!((pct - 4.2).abs() < 0.6, "{pct}");
    }

    #[test]
    fn ttd_engine_area_share_matches_prose() {
        let s = summarize();
        // "5.6% of LUTs and 7.7% of FFs across the entire processor"
        let lut_pct = s.ttd_engine_luts as f64 / s.total_luts as f64 * 100.0;
        let ff_pct = s.ttd_engine_ffs as f64 / s.total_ffs as f64 * 100.0;
        assert!((lut_pct - 5.6).abs() < 0.3, "{lut_pct}");
        assert!((ff_pct - 7.7).abs() < 0.8, "{ff_pct}");
    }

    #[test]
    fn module_shares_within_specialized_logic() {
        let blocks = tt_edge_blocks();
        let spec: Vec<_> = blocks.iter().filter(|b| b.ttd_engine_specialized).collect();
        let luts: u64 = spec.iter().map(|b| b.luts).sum();
        let hbd = spec.iter().find(|b| b.name == "HBD-ACC").unwrap();
        // "the HBD-ACC ... consumes 18.5% of LUTs"
        assert!((hbd.luts as f64 / luts as f64 * 100.0 - 18.5).abs() < 0.5);
        let fpalu = spec.iter().find(|b| b.name == "FP-ALU").unwrap();
        // "the Shared FP-ALU takes up 45.6% of LUTs"
        assert!((fpalu.luts as f64 / luts as f64 * 100.0 - 45.6).abs() < 0.5);
    }

    #[test]
    fn block_lookup_finds_every_inventory_name() {
        for b in tt_edge_blocks() {
            assert_eq!(block(b.name).luts, b.luts);
        }
        assert_eq!(block("FP-ALU").luts, 3_314);
    }

    #[test]
    #[should_panic(expected = "unknown Table-II block")]
    fn block_lookup_panics_on_unknown_names() {
        let _ = block("FP-ALU-2");
    }

    #[test]
    fn specialized_power_breakdown_matches_prose() {
        let blocks = tt_edge_blocks();
        let spec_power: f64 = blocks
            .iter()
            .filter(|b| b.ttd_engine_specialized)
            .map(|b| b.power_mw)
            .sum();
        // TTD-Engine specialized modules: ~7.19-7.35 mW (Table II sums)
        assert!((spec_power - 7.19).abs() < 0.4, "{spec_power}");
        let hbd = blocks.iter().find(|b| b.name == "HBD-ACC").unwrap();
        // "HBD-ACC contributes 1.42 mW (19.7%)"
        assert!((hbd.power_mw / spec_power * 100.0 - 19.7).abs() < 1.5);
    }
}
