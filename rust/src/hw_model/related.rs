//! Table IV: comparison with the prior hardware TTD accelerator of
//! Qu et al. [21] (TCAD'21). Their side is published data; the TT-Edge
//! side is derived from [`crate::hw_model::summarize`].

use crate::hw_model::summarize;

/// One column of Table IV.
#[derive(Clone, Debug)]
pub struct AcceleratorSpec {
    pub name: &'static str,
    pub process_nm: u32,
    /// (dedicated PEs, reused PEs) — the paper writes "256 + 64" for
    /// [21] and "64 + 3" for TT-Edge (reused GEMM PEs + FP-ALU units).
    pub pes: (u32, u32),
    pub on_chip_memory_kb: u32,
    pub precision: &'static str,
    pub clock_mhz: u32,
    /// Accelerator-only power, mW.
    pub power_mw: f64,
    /// Whole-processor power if reported, mW.
    pub total_power_mw: Option<f64>,
}

/// Qu et al. [21] — dedicated TTD accelerator.
pub fn qu_tcad21() -> AcceleratorSpec {
    AcceleratorSpec {
        name: "Qu et al. [21]",
        process_nm: 45,
        pes: (256, 64),
        on_chip_memory_kb: 1024,
        precision: "16-bit fixed",
        clock_mhz: 400,
        power_mw: 2890.0,
        total_power_mw: None,
    }
}

/// TT-Edge — this work. Power derived from the Table-II model: the
/// TTD-Engine adds ~48 mW of *active* silicon during TTD (specialized
/// modules + reused GEMM accelerator), inside a 177/178 mW processor.
pub fn tt_edge() -> AcceleratorSpec {
    let s = summarize();
    let blocks = crate::hw_model::tt_edge_blocks();
    let gemm = blocks.iter().find(|b| b.name == "GEMM Accelerator").unwrap().power_mw;
    let spec: f64 = blocks
        .iter()
        .filter(|b| b.ttd_engine_specialized)
        .map(|b| b.power_mw)
        .sum();
    AcceleratorSpec {
        name: "TT-Edge",
        process_nm: 45,
        pes: (64, 3), // reused GEMM PEs + MAC/DIV/SQRT units
        on_chip_memory_kb: 128 + 320,
        precision: "32-bit floating",
        clock_mhz: 100,
        power_mw: gemm + spec, // the engine + reused accelerator
        total_power_mw: Some(s.total_power_mw),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_tt_edge_column() {
        let t = tt_edge();
        assert_eq!(t.process_nm, 45);
        assert_eq!(t.pes, (64, 3));
        assert_eq!(t.on_chip_memory_kb, 448); // "448 KB total"
        assert_eq!(t.clock_mhz, 100);
        // "adds just 48 mW for the TTD-Engine itself"
        assert!((t.power_mw - 48.0).abs() < 1.0, "{}", t.power_mw);
        // "(177 mW for the entire processor)"
        let total = t.total_power_mw.unwrap();
        assert!((total - 178.23).abs() < 1.5, "{total}");
    }

    #[test]
    fn table4_contrast_with_qu() {
        let q = qu_tcad21();
        let t = tt_edge();
        // TT-Edge uses ~60x less accelerator power at 1/4 the clock
        assert!(q.power_mw / t.power_mw > 50.0);
        assert!(q.on_chip_memory_kb > t.on_chip_memory_kb);
        assert_eq!(q.precision, "16-bit fixed");
        assert_eq!(t.precision, "32-bit floating");
    }
}
