//! Minimal hand-rolled Rust lexer for `ttedge-lint` — just enough
//! structure to run line-oriented rules soundly, with no `syn` and no
//! network (the build stays fully offline).
//!
//! Two passes:
//!
//! * [`scrub`] blanks string literals (plain, byte, raw, raw-byte),
//!   char literals, and comments — preserving the byte-for-byte line
//!   layout so every reported column/line matches the original file —
//!   and collects each line comment's text for pragma parsing. Rule
//!   patterns therefore never fire inside quoted text or prose, which
//!   is what lets the linter scan its own rule tables and the fixture
//!   strings in `tests/lint_rules.rs` without tripping on them.
//! * [`line_regions`] walks the scrubbed code tracking brace depth to
//!   mark `#[cfg(test)]` / `#[test]` blocks and `lint: hotpath`
//!   regions per line.
//!
//! Deliberately NOT a full parser. Known approximations, chosen to
//! match the repo's house style: attributes are recognized on a single
//! line; a region tag or `#[cfg(test)]` attribute applies from the
//! *next* opened block, so one-liners like `#[cfg(test)] mod t { .. }`
//! are only tracked from their own `{`; and a `lint: hotpath` tag must
//! sit on its own line as the first line *inside* the block it covers.
//! The tricky lexical cases that would cause unsound matches — nested
//! block comments, `r#".."#` with hashes, `b'\''`, `'\u{41}'`,
//! lifetime ticks vs char literals — are handled and unit-tested.

/// One `//` line comment: its 1-indexed line and the text after `//`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Comment {
    pub line: usize,
    pub text: String,
}

/// Output of [`scrub`]: code with all literals/comments blanked to
/// spaces (newlines kept, so line numbers are unchanged) plus the
/// collected line comments.
#[derive(Clone, Debug)]
pub struct Scrubbed {
    pub code: String,
    pub comments: Vec<Comment>,
}

fn blank(out: &mut Vec<u8>, n: usize) {
    out.resize(out.len() + n, b' ');
}

/// Byte length of the UTF-8 code point starting with `b0`.
fn utf8_len(b0: u8) -> usize {
    if b0 < 0x80 {
        1
    } else if b0 >= 0xF0 {
        4
    } else if b0 >= 0xE0 {
        3
    } else {
        2
    }
}

/// Blank a `quote`-delimited literal with backslash escapes (plain
/// strings, byte strings, escaped char literals). `i` points at the
/// opening quote; returns the index just past the closing quote.
fn scrub_quoted(b: &[u8], i: usize, quote: u8, out: &mut Vec<u8>, line: &mut usize) -> usize {
    blank(out, 1);
    let mut j = i + 1;
    while j < b.len() {
        if b[j] == b'\\' {
            blank(out, 1);
            j += 1;
            if j < b.len() {
                if b[j] == b'\n' {
                    out.push(b'\n');
                    *line += 1;
                } else {
                    blank(out, 1);
                }
                j += 1;
            }
        } else if b[j] == quote {
            blank(out, 1);
            j += 1;
            break;
        } else if b[j] == b'\n' {
            out.push(b'\n');
            *line += 1;
            j += 1;
        } else {
            blank(out, 1);
            j += 1;
        }
    }
    j
}

/// Blank source `src` as described in the module docs.
pub fn scrub(src: &str) -> Scrubbed {
    let b = src.as_bytes();
    let mut out: Vec<u8> = Vec::with_capacity(b.len());
    let mut comments = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < b.len() {
        match b[i] {
            b'\n' => {
                out.push(b'\n');
                line += 1;
                i += 1;
            }
            b'/' if b.get(i + 1) == Some(&b'/') => {
                let start = i + 2;
                let mut j = start;
                while j < b.len() && b[j] != b'\n' {
                    j += 1;
                }
                comments.push(Comment {
                    line,
                    text: String::from_utf8_lossy(&b[start..j]).into_owned(),
                });
                blank(&mut out, j - i);
                i = j;
            }
            b'/' if b.get(i + 1) == Some(&b'*') => {
                // Block comment; Rust block comments nest.
                let mut depth = 1usize;
                blank(&mut out, 2);
                let mut j = i + 2;
                while j < b.len() && depth > 0 {
                    if b[j] == b'\n' {
                        out.push(b'\n');
                        line += 1;
                        j += 1;
                    } else if b[j] == b'/' && b.get(j + 1) == Some(&b'*') {
                        depth += 1;
                        blank(&mut out, 2);
                        j += 2;
                    } else if b[j] == b'*' && b.get(j + 1) == Some(&b'/') {
                        depth -= 1;
                        blank(&mut out, 2);
                        j += 2;
                    } else {
                        blank(&mut out, 1);
                        j += 1;
                    }
                }
                i = j;
            }
            b'"' => {
                i = scrub_quoted(b, i, b'"', &mut out, &mut line);
            }
            b'\'' => {
                // Char literal or lifetime tick. A backslash right
                // after the tick is always a char literal; otherwise
                // it is a char literal iff the single code point that
                // follows is closed by another tick (`'a'`), and a
                // lifetime otherwise (`'a>`).
                if b.get(i + 1) == Some(&b'\\') {
                    i = scrub_quoted(b, i, b'\'', &mut out, &mut line);
                } else {
                    let l = utf8_len(b.get(i + 1).copied().unwrap_or(b' '));
                    if b.get(i + 1 + l) == Some(&b'\'') {
                        blank(&mut out, 2 + l);
                        i += 2 + l;
                    } else {
                        blank(&mut out, 1);
                        i += 1;
                    }
                }
            }
            b'r' | b'b' => {
                // Possible raw / byte string prefix; fall through to
                // a plain identifier byte when the quote never comes.
                let mut j = i + 1;
                let mut raw = b[i] == b'r';
                if b[i] == b'b' && b.get(j) == Some(&b'r') {
                    raw = true;
                    j += 1;
                }
                if raw {
                    let mut hashes = 0usize;
                    while b.get(j) == Some(&b'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if b.get(j) == Some(&b'"') {
                        blank(&mut out, j + 1 - i);
                        let mut k = j + 1;
                        while k < b.len() {
                            if b[k] == b'\n' {
                                out.push(b'\n');
                                line += 1;
                                k += 1;
                            } else if b[k] == b'"'
                                && (0..hashes).all(|h| b.get(k + 1 + h) == Some(&b'#'))
                            {
                                blank(&mut out, 1 + hashes);
                                k += 1 + hashes;
                                break;
                            } else {
                                blank(&mut out, 1);
                                k += 1;
                            }
                        }
                        i = k;
                    } else {
                        out.push(b[i]);
                        i += 1;
                    }
                } else if b.get(j) == Some(&b'"') {
                    // b"...": blank the prefix, then the quoted body
                    blank(&mut out, 1);
                    i = scrub_quoted(b, i + 1, b'"', &mut out, &mut line);
                } else {
                    out.push(b[i]);
                    i += 1;
                }
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    Scrubbed {
        code: String::from_utf8(out).expect("scrub only blanks bytes, UTF-8 is preserved"),
        comments,
    }
}

/// Region membership of one source line.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LineFlags {
    /// Inside a block opened under `#[cfg(test)]` or `#[test]`.
    pub test: bool,
    /// Inside a block carrying a `lint: hotpath` tag.
    pub hotpath: bool,
}

/// Index of the `]` closing an attribute whose `[` sits at `i - 1`
/// (bracket nesting respected); `lb.len()` when unterminated.
fn attr_close(lb: &[u8], mut i: usize) -> usize {
    let mut depth = 1usize;
    while i < lb.len() {
        match lb[i] {
            b'[' => depth += 1,
            b']' => {
                depth -= 1;
                if depth == 0 {
                    return i;
                }
            }
            _ => {}
        }
        i += 1;
    }
    i
}

/// Per-line region flags over scrubbed code (1-indexed; index 0 is
/// unused padding). `hotpath_tag_lines` are the lines carrying a
/// `lint: hotpath` comment (the caller extracts them from
/// [`Scrubbed::comments`]); each tag opens a region at its line's
/// brace depth that closes with the enclosing block.
pub fn line_regions(code: &str, hotpath_tag_lines: &[usize]) -> Vec<LineFlags> {
    let nlines = code.lines().count();
    let mut flags = vec![LineFlags::default(); nlines + 2];
    let mut depth: i64 = 0;
    let mut pending_test = false;
    let mut test_stack: Vec<i64> = Vec::new();
    let mut hot_stack: Vec<i64> = Vec::new();
    for (idx, text) in code.lines().enumerate() {
        let line_no = idx + 1;
        if hotpath_tag_lines.contains(&line_no) {
            hot_stack.push(depth);
        }
        flags[line_no] = LineFlags {
            test: !test_stack.is_empty(),
            hotpath: !hot_stack.is_empty(),
        };
        let lb = text.as_bytes();
        let mut i = 0usize;
        while i < lb.len() {
            match lb[i] {
                b'#' if lb.get(i + 1) == Some(&b'[') => {
                    let close = attr_close(lb, i + 2);
                    let attr = &text[i + 2..close.min(lb.len())];
                    // `cfg(test)` exactly — `cfg(not(test))` must NOT
                    // open a test region.
                    if attr.contains("cfg(test)") || attr.trim() == "test" {
                        pending_test = true;
                    }
                    i = close + 1;
                }
                b'{' => {
                    depth += 1;
                    if pending_test {
                        test_stack.push(depth);
                        pending_test = false;
                    }
                    i += 1;
                }
                b'}' => {
                    depth -= 1;
                    while test_stack.last().is_some_and(|d| depth < *d) {
                        test_stack.pop();
                    }
                    while hot_stack.last().is_some_and(|d| depth < *d) {
                        hot_stack.pop();
                    }
                    i += 1;
                }
                b';' => {
                    // attribute on a braceless item: `#[cfg(test)] use ..;`
                    pending_test = false;
                    i += 1;
                }
                _ => i += 1,
            }
        }
    }
    flags
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrub_blanks_strings_and_keeps_layout() {
        let src = "let a = \"thread::spawn\";\nlet b = 1;\n";
        let s = scrub(src);
        assert_eq!(s.code.len(), src.len());
        assert!(!s.code.contains("thread::spawn"));
        assert!(s.code.contains("let b = 1;"));
        assert_eq!(s.code.matches('\n').count(), 2);
    }

    #[test]
    fn scrub_handles_raw_and_byte_strings() {
        let src = "let a = r#\"x \"quoted\" HashMap\"#;\nlet b = b\"bytes\\\"esc\";\nlet c = br##\"deep\"# still\"##;\nlet tail = 9;\n";
        let s = scrub(src);
        assert!(!s.code.contains("HashMap"));
        assert!(!s.code.contains("quoted"));
        assert!(!s.code.contains("bytes"));
        assert!(!s.code.contains("still"));
        assert!(s.code.contains("let tail = 9;"));
    }

    #[test]
    fn scrub_distinguishes_chars_from_lifetimes() {
        let src = "fn f<'a>(x: &'a str) -> char { let q = '\\''; let r = '{'; 'x' }\nlet open = 1;\n";
        let s = scrub(src);
        // the char-literal braces/quotes are blanked...
        assert!(!s.code.contains("'{'"), "{}", s.code);
        assert!(!s.code.contains("'x'"));
        // ...while lifetime names survive as plain identifiers
        assert!(s.code.contains("a str"));
        assert!(s.code.contains("let open = 1;"));
        // brace balance is preserved: one open, one close
        assert_eq!(s.code.matches('{').count(), 1);
        assert_eq!(s.code.matches('}').count(), 1);
    }

    #[test]
    fn scrub_collects_comments_and_nests_blocks() {
        let src = "let x = 1; // lint: hotpath\n/* outer /* inner */ still comment */ let y = 2;\n";
        let s = scrub(src);
        assert_eq!(s.comments.len(), 1);
        assert_eq!(s.comments[0].line, 1);
        assert_eq!(s.comments[0].text.trim(), "lint: hotpath");
        assert!(!s.code.contains("still comment"));
        assert!(s.code.contains("let y = 2;"));
    }

    #[test]
    fn regions_track_cfg_test_blocks() {
        let src = "fn live() {\n    work();\n}\n#[cfg(test)]\nmod tests {\n    fn t() {\n        check();\n    }\n}\nfn live2() {}\n";
        let f = line_regions(src, &[]);
        assert!(!f[2].test, "body of live()");
        assert!(f[6].test && f[7].test, "inside mod tests");
        assert!(!f[10].test, "after the test mod closes");
    }

    #[test]
    fn regions_ignore_cfg_not_test() {
        let src = "#[cfg(not(test))]\nmod prod {\n    work();\n}\n";
        let f = line_regions(src, &[]);
        assert!(!f[3].test);
    }

    #[test]
    fn regions_close_hotpath_with_block() {
        let src = "fn hot() {\n\n    inner();\n    if x {\n        deep();\n    }\n}\nfn cold() {\n    other();\n}\n";
        // tag on line 2 (blank in scrubbed code where the comment was)
        let f = line_regions(src, &[2]);
        assert!(f[3].hotpath && f[5].hotpath, "tagged block and nested block");
        assert!(!f[9].hotpath, "next function is outside the region");
    }
}
