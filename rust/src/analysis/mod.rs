//! `ttedge-lint` — the repo-invariant static-analysis pass.
//!
//! Every headline number in this reproduction (the Table-III pins, the
//! bench self-assertions) rests on bit-identity contracts: kernel vs
//! reference, serial vs parallel, record vs replay. The architectural
//! rules that keep those contracts true used to live only in ROADMAP
//! prose and code comments; this module makes them machine-checked.
//! The rules (deny-by-default, run by the `ttedge-lint` binary over
//! `src/`, `tests/`, and `benches/`):
//!
//! | rule | what it catches |
//! |------|-----------------|
//! | `no-adhoc-threads` | `std::thread::{spawn,scope}` outside the blessed concurrency owners (`pipeline`, `sim/cost`, `ttd/svd/bidiag`, `serve`, `coordinator`) and `#[cfg(test)]` blocks |
//! | `single-entry-point` | direct `ttd::decompose` / `pipeline::compress_layers*` calls outside `job.rs` and the defining modules (the PR-3 rule: `CompressionJob` is the one entry point) |
//! | `no-unordered-iteration` | iterating a `HashMap`/`HashSet` (hasher order is not a total order) |
//! | `no-wallclock-or-unseeded-rng` | `Instant::now` / `SystemTime::now` / unseeded RNG outside `benches/` and `src/metrics/` (artifacts must stay byte-deterministic) |
//! | `hard-assert-dispatch-guards` | `debug_assert!` in `tensor.rs`/`bidiag.rs` kernel entry paths (the PR-7 `matmul_acc` bug class: guards that compile out in release) |
//! | `no-hotpath-alloc` | allocation (`Vec::new`, `vec![]`, `.clone()`, `.collect()`, ...) inside a block tagged `lint: hotpath` (the `WyScratch` bug class) |
//! | `lock-discipline` | bare `.lock().unwrap()` / `.lock().expect(..)` — each mutex gets one named lock helper stating its poison policy |
//!
//! Suppression is per-line via an allow pragma whose reason is
//! **mandatory and non-empty**:
//!
//! ```text
//! value.pragma_target_line();   <comment> lint: allow(<rule-id>): <reason>
//! ```
//!
//! (written with `//` in real code; spelled `<comment>` above only so
//! this doc comment is not itself a pragma). A pragma on a line of its
//! own covers the next non-blank code line instead. A pragma with an
//! empty reason or an unknown rule id is itself reported as a
//! `malformed-pragma` violation and suppresses nothing.
//!
//! Hot regions are opened with a `lint: hotpath` comment placed on its
//! own line as the first line inside the block it covers; the region
//! closes with that block's closing brace.
//!
//! The pass is wired in three places: the `ttedge-lint` binary (CI's
//! `static-analysis` job runs it in deny mode), the fixture suite in
//! `tests/lint_rules.rs` proving each rule fires with the right
//! `file:line`, and a clean-tree smoke test that keeps the real tree
//! at zero violations under `cargo test`.

pub mod lexer;

use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::util::json::Json;
use lexer::{line_regions, scrub};

/// Blessed `std::thread` owners: the modules whose *job* is
/// parallelism, each carrying its own determinism argument (row-band
/// partitioning, ordered response slots, quorum barriers). Everything
/// else routes through them. Entries ending in `/` bless a directory.
const THREAD_OWNERS: &[&str] = &[
    "src/pipeline/",
    "src/sim/cost.rs",
    "src/ttd/svd/bidiag.rs",
    "src/serve/",
    "src/coordinator/",
    // ISSUE 10: the deadline watchdog (`fault::with_deadline`) parks a
    // scoped thread on an mpsc timeout — no wall-clock reads, and the
    // only observable effect is a CancelToken trip.
    "src/fault/",
];

/// Callers allowed to invoke the raw numerics entry points directly:
/// the `CompressionJob` owner itself and the defining modules.
const ENTRY_OWNERS: &[&str] = &["src/job.rs", "src/pipeline/", "src/ttd/"];

/// Paths where wall-clock reads are the *point* (operator-facing
/// timing that never feeds a byte-pinned artifact).
const WALLCLOCK_EXEMPT: &[&str] = &["src/metrics/"];

/// Kernel entry-path files where a size/shape guard must be a hard
/// assert (the PR-7 `matmul_acc` rule).
const KERNEL_GUARD_FILES: &[&str] = &["src/ttd/tensor.rs", "src/ttd/svd/bidiag.rs"];

/// Which tree a file came from; drives per-rule scoping.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FileClass {
    Src,
    Tests,
    Benches,
}

impl FileClass {
    pub fn of(rel_path: &str) -> FileClass {
        if rel_path.starts_with("tests/") {
            FileClass::Tests
        } else if rel_path.starts_with("benches/") {
            FileClass::Benches
        } else {
            FileClass::Src
        }
    }
}

/// The enforced rule set. `MalformedPragma` is the meta-rule for
/// broken suppression comments; it has no allow pragma of its own.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    NoAdhocThreads,
    SingleEntryPoint,
    NoUnorderedIteration,
    NoWallclock,
    HardAssertDispatchGuards,
    NoHotpathAlloc,
    LockDiscipline,
    MalformedPragma,
}

impl Rule {
    pub const ENFORCED: [Rule; 7] = [
        Rule::NoAdhocThreads,
        Rule::SingleEntryPoint,
        Rule::NoUnorderedIteration,
        Rule::NoWallclock,
        Rule::HardAssertDispatchGuards,
        Rule::NoHotpathAlloc,
        Rule::LockDiscipline,
    ];

    pub fn id(self) -> &'static str {
        match self {
            Rule::NoAdhocThreads => "no-adhoc-threads",
            Rule::SingleEntryPoint => "single-entry-point",
            Rule::NoUnorderedIteration => "no-unordered-iteration",
            Rule::NoWallclock => "no-wallclock-or-unseeded-rng",
            Rule::HardAssertDispatchGuards => "hard-assert-dispatch-guards",
            Rule::NoHotpathAlloc => "no-hotpath-alloc",
            Rule::LockDiscipline => "lock-discipline",
            Rule::MalformedPragma => "malformed-pragma",
        }
    }

    /// Resolve an allow-pragma rule id. Only enforced rules resolve —
    /// `allow(malformed-pragma)` is deliberately unparseable.
    pub fn from_id(id: &str) -> Option<Rule> {
        Rule::ENFORCED.iter().copied().find(|r| r.id() == id)
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl Violation {
    /// The canonical `file:line rule message` output line.
    pub fn render(&self) -> String {
        format!("{}:{} {} {}", self.file, self.line, self.rule.id(), self.message)
    }
}

/// A parsed, well-formed `lint: allow(<rule>): <reason>` pragma.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AllowPragma {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub reason: String,
}

/// Per-file result: surviving violations (post-suppression, sorted by
/// line) and every well-formed allow pragma found.
#[derive(Clone, Debug, Default)]
pub struct FileAnalysis {
    pub violations: Vec<Violation>,
    pub allows: Vec<AllowPragma>,
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn find_with(line: &str, pat: &str, prev_ok: impl Fn(char) -> bool) -> Option<usize> {
    let mut from = 0;
    while let Some(rel) = line[from..].find(pat) {
        let at = from + rel;
        let ok = match line[..at].chars().next_back() {
            None => true,
            Some(prev) => prev_ok(prev),
        };
        if ok {
            return Some(at);
        }
        from = at + 1;
    }
    None
}

/// First occurrence of `pat` not preceded by an identifier character
/// (path qualification like `std::` before it is fine).
fn find_qualified(line: &str, pat: &str) -> Option<usize> {
    find_with(line, pat, |prev| !is_ident_char(prev))
}

/// First occurrence of `pat` as a bare token: not preceded by an
/// identifier char, `:` (path segment) or `.` (method/field) — so
/// `tucker::decompose(` does not match a bare `decompose(`.
fn find_bare(line: &str, pat: &str) -> Option<usize> {
    find_with(line, pat, |prev| !is_ident_char(prev) && prev != ':' && prev != '.')
}

fn path_in(rel: &str, list: &[&str]) -> bool {
    list.iter().any(|p| {
        if p.ends_with('/') {
            rel.starts_with(p)
        } else {
            rel == *p
        }
    })
}

/// Trailing identifier of `s` (after trimming), if any.
fn trailing_ident(s: &str) -> Option<String> {
    let s = s.trim_end();
    let start = s
        .char_indices()
        .rev()
        .take_while(|(_, c)| is_ident_char(*c))
        .last()
        .map(|(i, _)| i)?;
    let ident = &s[start..];
    let head = ident.chars().next()?;
    if head.is_ascii_alphabetic() || head == '_' {
        Some(ident.to_string())
    } else {
        None
    }
}

/// Record hash-container bindings declared on this line: both
/// `name: HashMap<..>` (let/field/param annotations) and
/// `name = HashMap::new()` style initializers.
fn collect_hash_decls(line: &str, names: &mut Vec<String>) {
    for marker in ["HashMap", "HashSet"] {
        let mut from = 0;
        while let Some(rel) = line[from..].find(marker) {
            let at = from + rel;
            from = at + marker.len();
            if let Some(prev) = line[..at].chars().next_back() {
                if is_ident_char(prev) {
                    continue;
                }
            }
            let before = line[..at].trim_end();
            let name = if let Some(annotated) = before.strip_suffix(':') {
                trailing_ident(annotated)
            } else if before.ends_with('=') {
                trailing_ident(before.trim_end_matches('=').trim_end())
            } else {
                None
            };
            if let Some(n) = name {
                if !names.contains(&n) {
                    names.push(n);
                }
            }
        }
    }
}

/// Whether this line is a `for .. in <path>` loop whose iterated
/// expression's last path segment is `name`.
fn for_loop_over(line: &str, name: &str) -> bool {
    let t = line.trim_start();
    if !t.starts_with("for ") {
        return false;
    }
    let Some(pos) = t.find(" in ") else { return false };
    let mut expr = t[pos + 4..].trim_start();
    while let Some(rest) = expr.strip_prefix('&') {
        expr = rest.trim_start();
    }
    expr = expr.strip_prefix("mut ").unwrap_or(expr).trim_start();
    let end = expr
        .find(|c: char| !is_ident_char(c) && c != '.' && c != ':')
        .unwrap_or(expr.len());
    let path = &expr[..end];
    path == name || path.ends_with(&format!(".{name}")) || path.ends_with(&format!("::{name}"))
}

fn parse_allow(rest: &str) -> Result<(Rule, String), String> {
    let Some(body) = rest.strip_prefix("allow(") else {
        return Err(format!(
            "unrecognized lint directive `{rest}` — expected `allow(<rule>): <reason>` or `hotpath`"
        ));
    };
    let Some(close) = body.find(')') else {
        return Err("malformed allow pragma: missing `)`".to_string());
    };
    let rule_id = body[..close].trim();
    let Some(rule) = Rule::from_id(rule_id) else {
        return Err(format!("unknown rule `{rule_id}` in allow pragma"));
    };
    let after = body[close + 1..].trim_start();
    let Some(reason) = after.strip_prefix(':') else {
        return Err(format!("allow({rule_id}) pragma is missing its `: <reason>`"));
    };
    let reason = reason.trim();
    if reason.is_empty() {
        return Err(format!(
            "allow({rule_id}) pragma has an empty reason — a non-empty reason is mandatory"
        ));
    }
    Ok((rule, reason.to_string()))
}

/// The line an allow pragma covers: its own line when that line has
/// code (trailing pragma), else the next non-blank code line.
fn allow_target(lines: &[&str], pragma_line: usize) -> usize {
    let own_has_code = lines
        .get(pragma_line - 1)
        .map(|l| !l.trim().is_empty())
        .unwrap_or(false);
    if own_has_code {
        return pragma_line;
    }
    for l in pragma_line + 1..=lines.len() {
        if !lines[l - 1].trim().is_empty() {
            return l;
        }
    }
    pragma_line
}

struct LineCtx<'a> {
    rel: &'a str,
    class: FileClass,
    in_test: bool,
    hotpath: bool,
    hash_names: &'a [String],
    imports_decompose: bool,
    imports_compress_layers: bool,
}

fn check_line(ctx: &LineCtx<'_>, line_no: usize, text: &str, out: &mut Vec<Violation>) {
    let mut push = |rule: Rule, message: String| {
        out.push(Violation {
            file: ctx.rel.to_string(),
            line: line_no,
            rule,
            message,
        });
    };

    // no-adhoc-threads
    if !ctx.in_test && !path_in(ctx.rel, THREAD_OWNERS) {
        for pat in ["thread::spawn", "thread::scope", "thread::Builder"] {
            if find_qualified(text, pat).is_some() {
                push(
                    Rule::NoAdhocThreads,
                    format!(
                        "`{pat}` outside a blessed concurrency owner — route parallelism \
                         through pipeline/sim::cost/ttd::svd::bidiag/serve/coordinator \
                         or move it under #[cfg(test)]"
                    ),
                );
            }
        }
    }

    // single-entry-point (the PR-3 rule; tests and benches may pin the
    // raw entry points on purpose)
    if ctx.class == FileClass::Src && !ctx.in_test && !path_in(ctx.rel, ENTRY_OWNERS) {
        let qualified = find_qualified(text, "ttd::decompose").is_some()
            || find_qualified(text, "pipeline::compress_layers").is_some();
        let bare = (ctx.imports_decompose && find_bare(text, "decompose(").is_some())
            || (ctx.imports_compress_layers && find_bare(text, "compress_layers").is_some());
        if qualified || bare {
            push(
                Rule::SingleEntryPoint,
                "direct decompose/compress_layers call — go through CompressionJob \
                 (job.rs), the single entry point owning kernel selection, spec \
                 canonicalization, and pass counting"
                    .to_string(),
            );
        }
    }

    // no-unordered-iteration
    for name in ctx.hash_names {
        let method_hit = [
            ".iter()",
            ".iter_mut()",
            ".keys()",
            ".values()",
            ".values_mut()",
            ".into_iter()",
            ".into_keys()",
            ".into_values()",
            ".drain(",
            ".retain(",
        ]
        .iter()
        .any(|suffix| find_qualified(text, &format!("{name}{suffix}")).is_some());
        if method_hit || for_loop_over(text, name) {
            push(
                Rule::NoUnorderedIteration,
                format!(
                    "iterating `{name}` (HashMap/HashSet) observes hasher order — use a \
                     BTreeMap/sorted view, or state the total-order argument in an allow \
                     pragma"
                ),
            );
        }
    }

    // no-wallclock-or-unseeded-rng
    if ctx.class != FileClass::Benches && !path_in(ctx.rel, WALLCLOCK_EXEMPT) {
        for pat in [
            "Instant::now",
            "SystemTime::now",
            "thread_rng",
            "from_entropy",
            "rand::random",
        ] {
            if find_qualified(text, pat).is_some() {
                push(
                    Rule::NoWallclock,
                    format!(
                        "`{pat}` makes output nondeterministic — confine timing/entropy to \
                         benches/ or src/metrics/, or justify why it never reaches a \
                         byte-pinned artifact"
                    ),
                );
            }
        }
    }

    // hard-assert-dispatch-guards (the PR-7 bug class)
    if !ctx.in_test && KERNEL_GUARD_FILES.contains(&ctx.rel) {
        for pat in ["debug_assert!", "debug_assert_eq!", "debug_assert_ne!"] {
            if find_qualified(text, pat).is_some() {
                push(
                    Rule::HardAssertDispatchGuards,
                    "debug_assert on a kernel entry path compiles out in release — \
                     size/shape guards here must be hard asserts (the PR-7 matmul_acc \
                     bug class)"
                        .to_string(),
                );
            }
        }
    }

    // no-hotpath-alloc (the WyScratch bug class). Method-call patterns
    // (leading `.`) are matched verbatim — their receiver is an
    // identifier, so the boundary check would never fire on them.
    if ctx.hotpath {
        for pat in [
            "Vec::new(",
            "Vec::with_capacity(",
            "vec![",
            ".to_vec(",
            ".collect(",
            ".clone(",
            "Box::new(",
            "String::new(",
            "format!(",
            ".to_string(",
            ".to_owned(",
        ] {
            let hit = if pat.starts_with('.') {
                text.contains(pat)
            } else {
                find_qualified(text, pat).is_some()
            };
            if hit {
                push(
                    Rule::NoHotpathAlloc,
                    format!(
                        "`{pat}` allocates inside a hotpath region — hoist the buffer \
                         into caller-owned scratch (the WyScratch pattern)"
                    ),
                );
            }
        }
    }

    // lock-discipline
    if !ctx.in_test {
        for pat in [".lock().unwrap()", ".lock().expect("] {
            if text.contains(pat) {
                push(
                    Rule::LockDiscipline,
                    "bare Mutex lock+unwrap — take the lock through the module's named \
                     lock helper so the poison policy is stated exactly once (see \
                     cache::ProgramCache::lock_cache)"
                        .to_string(),
                );
            }
        }
    }
}

/// Run every rule over one file. `rel_path` is the `/`-separated path
/// relative to the crate root (e.g. `src/cache/mod.rs`); it selects
/// the file class and the blessed-owner exemptions, so fixtures can
/// probe any scoping behavior by choosing a synthetic label.
pub fn analyze_source(rel_path: &str, source: &str) -> FileAnalysis {
    let class = FileClass::of(rel_path);
    let scrubbed = scrub(source);

    let mut allows: Vec<AllowPragma> = Vec::new();
    let mut malformed: Vec<Violation> = Vec::new();
    let mut hotpath_tags: Vec<usize> = Vec::new();
    for c in &scrubbed.comments {
        // `///` and `//!` doc text is prose, never a pragma
        if c.text.starts_with('/') || c.text.starts_with('!') {
            continue;
        }
        let Some(rest) = c.text.trim().strip_prefix("lint:") else {
            continue;
        };
        let rest = rest.trim();
        if rest == "hotpath" {
            hotpath_tags.push(c.line);
            continue;
        }
        match parse_allow(rest) {
            Ok((rule, reason)) => allows.push(AllowPragma {
                file: rel_path.to_string(),
                line: c.line,
                rule,
                reason,
            }),
            Err(message) => malformed.push(Violation {
                file: rel_path.to_string(),
                line: c.line,
                rule: Rule::MalformedPragma,
                message,
            }),
        }
    }

    let flags = line_regions(&scrubbed.code, &hotpath_tags);
    let lines: Vec<&str> = scrubbed.code.lines().collect();

    let mut imports_decompose = false;
    let mut imports_compress_layers = false;
    let mut hash_names: Vec<String> = Vec::new();
    for l in &lines {
        let t = l.trim_start();
        if t.starts_with("use ") || t.starts_with("pub use ") {
            if l.contains("ttd") && l.contains("decompose") {
                imports_decompose = true;
            }
            if l.contains("pipeline") && l.contains("compress_layers") {
                imports_compress_layers = true;
            }
        }
        collect_hash_decls(l, &mut hash_names);
    }

    let mut violations: Vec<Violation> = Vec::new();
    for (idx, l) in lines.iter().enumerate() {
        let line_no = idx + 1;
        let f = flags[line_no];
        let ctx = LineCtx {
            rel: rel_path,
            class,
            in_test: f.test || class == FileClass::Tests,
            hotpath: f.hotpath,
            hash_names: &hash_names,
            imports_decompose,
            imports_compress_layers,
        };
        check_line(&ctx, line_no, l, &mut violations);
    }

    // Suppression: each well-formed allow covers exactly one line.
    let targets: Vec<(Rule, usize)> = allows
        .iter()
        .map(|a| (a.rule, allow_target(&lines, a.line)))
        .collect();
    violations.retain(|v| !targets.iter().any(|(r, l)| *r == v.rule && *l == v.line));
    violations.extend(malformed);
    violations.sort_by_key(|v| (v.line, v.rule));

    FileAnalysis { violations, allows }
}

/// Whole-tree report — the payload behind the `lint-report-v1` schema.
#[derive(Clone, Debug)]
pub struct Report {
    pub root: String,
    pub files_scanned: usize,
    pub violations: Vec<Violation>,
    pub allows: Vec<AllowPragma>,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Render as `lint-report-v1` JSON. Deterministic: object keys are
    /// BTreeMap-ordered and files were walked in sorted order.
    pub fn to_json(&self, mode: &str) -> Json {
        let violation = |v: &Violation| {
            let mut o = BTreeMap::new();
            o.insert("file".to_string(), Json::Str(v.file.clone()));
            o.insert("line".to_string(), Json::Num(v.line as f64));
            o.insert("rule".to_string(), Json::Str(v.rule.id().to_string()));
            o.insert("message".to_string(), Json::Str(v.message.clone()));
            Json::Obj(o)
        };
        let allow = |a: &AllowPragma| {
            let mut o = BTreeMap::new();
            o.insert("file".to_string(), Json::Str(a.file.clone()));
            o.insert("line".to_string(), Json::Num(a.line as f64));
            o.insert("rule".to_string(), Json::Str(a.rule.id().to_string()));
            o.insert("reason".to_string(), Json::Str(a.reason.clone()));
            Json::Obj(o)
        };
        let mut obj = BTreeMap::new();
        obj.insert("schema".to_string(), Json::Str("lint-report-v1".to_string()));
        obj.insert("mode".to_string(), Json::Str(mode.to_string()));
        obj.insert("root".to_string(), Json::Str(self.root.clone()));
        obj.insert(
            "files_scanned".to_string(),
            Json::Num(self.files_scanned as f64),
        );
        obj.insert(
            "rules".to_string(),
            Json::Arr(
                Rule::ENFORCED
                    .iter()
                    .map(|r| Json::Str(r.id().to_string()))
                    .collect(),
            ),
        );
        obj.insert(
            "violations".to_string(),
            Json::Arr(self.violations.iter().map(violation).collect()),
        );
        obj.insert(
            "allows".to_string(),
            Json::Arr(self.allows.iter().map(allow).collect()),
        );
        obj.insert("clean".to_string(), Json::Bool(self.clean()));
        Json::Obj(obj)
    }
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Walk `src/`, `tests/`, and `benches/` under `root` (whichever
/// exist), analyzing every `.rs` file in sorted path order.
pub fn analyze_tree(root: &Path) -> io::Result<Report> {
    let mut files: Vec<PathBuf> = Vec::new();
    for sub in ["src", "tests", "benches"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs_files(&dir, &mut files)?;
        }
    }
    files.sort();
    let mut violations = Vec::new();
    let mut allows = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = fs::read_to_string(path)?;
        let fa = analyze_source(&rel, &text);
        violations.extend(fa.violations);
        allows.extend(fa.allows);
    }
    Ok(Report {
        root: root.display().to_string(),
        files_scanned: files.len(),
        violations,
        allows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_ids_round_trip() {
        for r in Rule::ENFORCED {
            assert_eq!(Rule::from_id(r.id()), Some(r));
        }
        assert_eq!(Rule::from_id("malformed-pragma"), None);
        assert_eq!(Rule::from_id("no-such-rule"), None);
    }

    #[test]
    fn hash_decl_collection_finds_fields_and_lets() {
        let mut names = Vec::new();
        collect_hash_decls("    slots: HashMap<CacheKey, Slot>,", &mut names);
        collect_hash_decls("    let mut seen = HashSet::new();", &mut names);
        collect_hash_decls("    let sorted: BTreeMap<u64, K> = x;", &mut names);
        assert_eq!(names, vec!["slots".to_string(), "seen".to_string()]);
    }

    #[test]
    fn bare_match_rejects_qualified_paths() {
        assert!(find_bare("let d = decompose(&t, &spec);", "decompose(").is_some());
        assert!(find_bare("let d = tucker::decompose(&t, eps);", "decompose(").is_none());
        assert!(find_bare("self.decompose(x)", "decompose(").is_none());
        assert!(find_qualified("crate::ttd::decompose(&t)", "ttd::decompose").is_some());
        assert!(find_qualified("my_ttd::decomposer(&t)", "ttd::decompose").is_none());
    }

    #[test]
    fn string_and_comment_content_never_fires() {
        let src = "fn f() {\n    let s = \"std::thread::spawn(Instant::now())\";\n    let r = r#\"x.lock().unwrap()\"#;\n}\n";
        let fa = analyze_source("src/quiet.rs", src);
        assert!(fa.violations.is_empty(), "{:?}", fa.violations);
    }
}
