//! Hardware-operation trace: the interface between the TTD numerics
//! ([`crate::ttd`]) and the SoC timing/energy simulator ([`crate::sim`]).
//!
//! The numeric code *is* the workload: as Algorithm 1/2 executes, it
//! emits one [`HwOp`] per hardware-visible primitive (Householder
//! generation, vector division, blockwise GEMM, bubble-sort pass,
//! truncation probe, DMA movement, ...). A [`TraceSink`] consumes the
//! stream *as it is emitted*; the default consumer is the simulator's
//! streaming cost sink ([`crate::sim::CostSink`]), which folds every
//! op into per-phase cycles online — no trace is ever materialized
//! unless a caller opts into [`VecSink`].
//!
//! Sinks compose instead of forking code paths:
//!
//! * [`NullSink`] — discard (pure math).
//! * [`VecSink`] — record the full stream (tests/benches introspect).
//! * [`CountingSink`] — count ops, O(1) memory.
//! * [`SummarySink`] — per-kind op counts, O(1) memory.
//! * [`Tee`] — duplicate the stream to two sinks in order.
//! * [`PhaseScoped`] — forward only the ops attributed to one
//!   Table-III [`Phase`] (a phase-scoped guard for ablations).
//!
//! `&mut S` also implements [`TraceSink`], so combinators can borrow
//! sinks owned by the caller: `Tee::new(&mut cost, &mut trace)`.
//!
//! [`program`] adds the record-once / replay-many seam: a
//! [`RecordingSink`] run-length-encodes the stream into an
//! [`OpProgram`] that replays (op-for-op, order-preserving) against
//! any number of SoC configs without re-running the numerics.

pub mod program;

pub use program::{LayerProgram, OpProgram, OpRun, RecordingSink};

/// TTD phases exactly as Table III rows report them.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Phase {
    /// Householder bidiagonalization (left/right transforms + accumulation).
    Hbd,
    /// Diagonalization of the bidiagonal matrix (QR iteration).
    QrDiag,
    /// Singular-value sorting + delta-truncation.
    SortTrunc,
    /// `W_temp <- Sigma_t V_t^T` (Update SVD Input row).
    UpdateSvdInput,
    /// Reshape & everything else (address arithmetic, copies).
    ReshapeEtc,
}

impl Phase {
    pub const ALL: [Phase; 5] = [
        Phase::Hbd,
        Phase::QrDiag,
        Phase::SortTrunc,
        Phase::UpdateSvdInput,
        Phase::ReshapeEtc,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Phase::Hbd => "HBD",
            Phase::QrDiag => "QR Decomp.",
            Phase::SortTrunc => "Sort. & Trunc.",
            Phase::UpdateSvdInput => "Update SVD In.",
            Phase::ReshapeEtc => "Reshape & etc",
        }
    }
}

/// One hardware-visible primitive with its problem size.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum HwOp {
    /// Enter a Table-III phase; all following ops are attributed to it.
    SetPhase(Phase),
    /// Generate a Householder vector of `len` elements: norm (MAC
    /// stream + SQRT) plus the pivot update. (Alg. 2 HOUSE)
    HouseGen { len: usize },
    /// Scale a Householder vector by 1/beta (`len` divisions).
    /// (Alg. 2 HOUSE_MM_UPDATE, VEC DIVISION stage)
    VecDiv { len: usize },
    /// Blockwise matrix multiply (m x k) @ (k x n) on the GEMM unit.
    Gemm { m: usize, n: usize, k: usize },
    /// Read `bytes` from DRAM into the SPM (or back).
    DataMove { bytes: usize },
    /// One bubble-sort pass structure over `n` singular values
    /// (`swaps` actual exchanges, which also reorder U/V columns).
    Sort { n: usize, swaps: usize },
    /// Reorder the basis matrices after sorting: `rows x cols` moved.
    ReorderBasis { rows: usize, cols: usize },
    /// delta-truncation FSM: `probes` tail-norm tests over vectors of
    /// mean length `veclen`.
    Trunc { probes: usize, veclen: usize },
    /// One Givens rotation of the QR diagonalization applied across
    /// `len` elements (bidiagonal chase + U/V accumulation).
    GivensRot { len: usize },
    /// Scalar FP ops executed on the core (bookkeeping, shifts).
    CoreScalar { ops: usize },
    /// Reshape/copy of `elems` elements (address arithmetic + moves).
    Reshape { elems: usize },
}

impl HwOp {
    /// Kind labels in the fixed reporting order used by the golden
    /// trace snapshots ([`HwOp::SetPhase`] deliberately last).
    pub const KIND_LABELS: [&'static str; 11] = [
        "HouseGen",
        "VecDiv",
        "Gemm",
        "DataMove",
        "Sort",
        "ReorderBasis",
        "Trunc",
        "GivensRot",
        "CoreScalar",
        "Reshape",
        "SetPhase",
    ];

    /// Index of this op's kind into [`HwOp::KIND_LABELS`].
    pub fn kind_index(&self) -> usize {
        match self {
            HwOp::HouseGen { .. } => 0,
            HwOp::VecDiv { .. } => 1,
            HwOp::Gemm { .. } => 2,
            HwOp::DataMove { .. } => 3,
            HwOp::Sort { .. } => 4,
            HwOp::ReorderBasis { .. } => 5,
            HwOp::Trunc { .. } => 6,
            HwOp::GivensRot { .. } => 7,
            HwOp::CoreScalar { .. } => 8,
            HwOp::Reshape { .. } => 9,
            HwOp::SetPhase(_) => 10,
        }
    }

    pub fn kind_label(&self) -> &'static str {
        Self::KIND_LABELS[self.kind_index()]
    }
}

/// Sink for hardware ops. The numerics call this; implementations
/// range from [`NullSink`] (pure math) to the simulator's streaming
/// [`crate::sim::CostSink`].
pub trait TraceSink {
    fn op(&mut self, op: HwOp);
}

/// Sinks borrow: `&mut S` forwards to `S`, so a caller-owned sink can
/// be handed to combinators like [`Tee`] without giving it up.
impl<S: TraceSink + ?Sized> TraceSink for &mut S {
    #[inline]
    fn op(&mut self, op: HwOp) {
        (**self).op(op);
    }
}

/// Discards everything — used when only the numbers matter.
#[derive(Default, Clone, Copy, Debug)]
pub struct NullSink;

impl TraceSink for NullSink {
    #[inline]
    fn op(&mut self, _op: HwOp) {}
}

/// Records the full trace (benches and tests introspect it).
#[derive(Default, Clone, Debug)]
pub struct VecSink {
    pub ops: Vec<HwOp>,
}

impl TraceSink for VecSink {
    #[inline]
    fn op(&mut self, op: HwOp) {
        self.ops.push(op);
    }
}

impl VecSink {
    pub fn count(&self, pred: impl Fn(&HwOp) -> bool) -> usize {
        self.ops.iter().filter(|o| pred(o)).count()
    }

    /// Replay the recorded stream into another sink, in order.
    pub fn replay<S: TraceSink>(&self, sink: &mut S) {
        for op in &self.ops {
            sink.op(*op);
        }
    }
}

/// Counts ops (including [`HwOp::SetPhase`] markers) without storing
/// them — `CountingSink::ops` equals `VecSink::ops.len()` for the same
/// stream, at O(1) memory.
#[derive(Default, Clone, Copy, Debug)]
pub struct CountingSink {
    pub ops: u64,
}

impl TraceSink for CountingSink {
    #[inline]
    fn op(&mut self, _op: HwOp) {
        self.ops += 1;
    }
}

/// Per-kind op counts — the streaming form of the golden harness's
/// trace summary. O(1) memory regardless of trace length.
#[derive(Default, Clone, Copy, Debug, PartialEq, Eq)]
pub struct SummarySink {
    counts: [u64; HwOp::KIND_LABELS.len()],
}

impl TraceSink for SummarySink {
    #[inline]
    fn op(&mut self, op: HwOp) {
        self.counts[op.kind_index()] += 1;
    }
}

impl SummarySink {
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Count for one kind label (see [`HwOp::KIND_LABELS`]); unknown
    /// labels count zero.
    pub fn count(&self, label: &str) -> u64 {
        HwOp::KIND_LABELS
            .iter()
            .position(|l| *l == label)
            .map(|i| self.counts[i])
            .unwrap_or(0)
    }

    /// `(label, count)` pairs in the fixed [`HwOp::KIND_LABELS`] order.
    pub fn counts(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        HwOp::KIND_LABELS.iter().copied().zip(self.counts.iter().copied())
    }
}

/// Duplicates the stream to two sinks, preserving op order in both.
/// Sinks can be owned or borrowed (`Tee::new(&mut a, &mut b)`);
/// nesting tees fans out to any width.
#[derive(Default, Clone, Debug)]
pub struct Tee<A, B> {
    pub a: A,
    pub b: B,
}

impl<A: TraceSink, B: TraceSink> Tee<A, B> {
    pub fn new(a: A, b: B) -> Self {
        Tee { a, b }
    }

    pub fn into_inner(self) -> (A, B) {
        (self.a, self.b)
    }
}

impl<A: TraceSink, B: TraceSink> TraceSink for Tee<A, B> {
    #[inline]
    fn op(&mut self, op: HwOp) {
        self.a.op(op);
        self.b.op(op);
    }
}

/// Phase-scoped guard: forwards only the ops attributed to `scope`
/// (tracking [`HwOp::SetPhase`] markers the way the simulator does,
/// starting from the [`Phase::ReshapeEtc`] reset state). The
/// `SetPhase` marker *entering* the scoped phase is forwarded so a
/// downstream cost sink attributes cycles to the right Table-III row.
#[derive(Clone, Debug)]
pub struct PhaseScoped<S> {
    pub inner: S,
    scope: Phase,
    current: Phase,
}

impl<S: TraceSink> PhaseScoped<S> {
    pub fn new(scope: Phase, inner: S) -> Self {
        PhaseScoped { inner, scope, current: Phase::ReshapeEtc }
    }

    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: TraceSink> TraceSink for PhaseScoped<S> {
    #[inline]
    fn op(&mut self, op: HwOp) {
        if let HwOp::SetPhase(p) = op {
            self.current = p;
            if p == self.scope {
                self.inner.op(op);
            }
            return;
        }
        if self.current == self.scope {
            self.inner.op(op);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_sink_records_in_order() {
        let mut s = VecSink::default();
        s.op(HwOp::SetPhase(Phase::Hbd));
        s.op(HwOp::HouseGen { len: 8 });
        assert_eq!(s.ops.len(), 2);
        assert_eq!(s.ops[1], HwOp::HouseGen { len: 8 });
    }

    #[test]
    fn phase_labels_match_table3_rows() {
        assert_eq!(Phase::Hbd.label(), "HBD");
        assert_eq!(Phase::SortTrunc.label(), "Sort. & Trunc.");
        assert_eq!(Phase::ALL.len(), 5);
    }

    fn sample_stream() -> Vec<HwOp> {
        vec![
            HwOp::SetPhase(Phase::Hbd),
            HwOp::HouseGen { len: 8 },
            HwOp::Gemm { m: 4, n: 4, k: 4 },
            HwOp::SetPhase(Phase::QrDiag),
            HwOp::GivensRot { len: 4 },
            HwOp::SetPhase(Phase::Hbd),
            HwOp::VecDiv { len: 8 },
        ]
    }

    #[test]
    fn tee_duplicates_in_order_to_both_branches() {
        let mut tee = Tee::new(VecSink::default(), VecSink::default());
        for op in sample_stream() {
            tee.op(op);
        }
        let (a, b) = tee.into_inner();
        assert_eq!(a.ops, sample_stream());
        assert_eq!(b.ops, sample_stream());
    }

    #[test]
    fn tee_borrows_caller_owned_sinks() {
        let mut count = CountingSink::default();
        let mut vec = VecSink::default();
        {
            let mut tee = Tee::new(&mut count, &mut vec);
            for op in sample_stream() {
                tee.op(op);
            }
        }
        assert_eq!(count.ops as usize, vec.ops.len());
    }

    #[test]
    fn counting_matches_vec_len_including_phase_markers() {
        let mut c = CountingSink::default();
        for op in sample_stream() {
            c.op(op);
        }
        assert_eq!(c.ops as usize, sample_stream().len());
    }

    #[test]
    fn summary_counts_per_kind() {
        let mut s = SummarySink::default();
        for op in sample_stream() {
            s.op(op);
        }
        assert_eq!(s.count("SetPhase"), 3);
        assert_eq!(s.count("HouseGen"), 1);
        assert_eq!(s.count("Gemm"), 1);
        assert_eq!(s.count("Trunc"), 0);
        assert_eq!(s.total() as usize, sample_stream().len());
        let labels: Vec<&str> = s.counts().map(|(l, _)| l).collect();
        assert_eq!(labels, HwOp::KIND_LABELS.to_vec());
    }

    #[test]
    fn phase_scoped_forwards_only_its_phase() {
        let mut g = PhaseScoped::new(Phase::Hbd, VecSink::default());
        for op in sample_stream() {
            g.op(op);
        }
        let inner = g.into_inner();
        assert_eq!(
            inner.ops,
            vec![
                HwOp::SetPhase(Phase::Hbd),
                HwOp::HouseGen { len: 8 },
                HwOp::Gemm { m: 4, n: 4, k: 4 },
                HwOp::SetPhase(Phase::Hbd),
                HwOp::VecDiv { len: 8 },
            ]
        );
    }

    #[test]
    fn replay_reproduces_the_stream() {
        let mut v = VecSink::default();
        for op in sample_stream() {
            v.op(op);
        }
        let mut out = VecSink::default();
        v.replay(&mut out);
        assert_eq!(out.ops, v.ops);
    }

    #[test]
    fn kind_labels_cover_every_op() {
        for (i, op) in sample_stream().iter().enumerate() {
            assert_eq!(HwOp::KIND_LABELS[op.kind_index()], op.kind_label(), "op {i}");
        }
    }
}
