//! Hardware-operation trace: the interface between the TTD numerics
//! ([`crate::ttd`]) and the SoC timing/energy simulator ([`crate::sim`]).
//!
//! The numeric code *is* the workload: as Algorithm 1/2 executes, it
//! emits one [`HwOp`] per hardware-visible primitive (Householder
//! generation, vector division, blockwise GEMM, bubble-sort pass,
//! truncation probe, DMA movement, ...). The simulator replays the
//! trace under a [`crate::sim::SocConfig`] to produce the paper's
//! per-phase cycle and energy breakdown (Table III) — the same
//! operation stream costed under two microarchitectures.

/// TTD phases exactly as Table III rows report them.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Phase {
    /// Householder bidiagonalization (left/right transforms + accumulation).
    Hbd,
    /// Diagonalization of the bidiagonal matrix (QR iteration).
    QrDiag,
    /// Singular-value sorting + delta-truncation.
    SortTrunc,
    /// `W_temp <- Sigma_t V_t^T` (Update SVD Input row).
    UpdateSvdInput,
    /// Reshape & everything else (address arithmetic, copies).
    ReshapeEtc,
}

impl Phase {
    pub const ALL: [Phase; 5] = [
        Phase::Hbd,
        Phase::QrDiag,
        Phase::SortTrunc,
        Phase::UpdateSvdInput,
        Phase::ReshapeEtc,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Phase::Hbd => "HBD",
            Phase::QrDiag => "QR Decomp.",
            Phase::SortTrunc => "Sort. & Trunc.",
            Phase::UpdateSvdInput => "Update SVD In.",
            Phase::ReshapeEtc => "Reshape & etc",
        }
    }
}

/// One hardware-visible primitive with its problem size.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum HwOp {
    /// Enter a Table-III phase; all following ops are attributed to it.
    SetPhase(Phase),
    /// Generate a Householder vector of `len` elements: norm (MAC
    /// stream + SQRT) plus the pivot update. (Alg. 2 HOUSE)
    HouseGen { len: usize },
    /// Scale a Householder vector by 1/beta (`len` divisions).
    /// (Alg. 2 HOUSE_MM_UPDATE, VEC DIVISION stage)
    VecDiv { len: usize },
    /// Blockwise matrix multiply (m x k) @ (k x n) on the GEMM unit.
    Gemm { m: usize, n: usize, k: usize },
    /// Read `bytes` from DRAM into the SPM (or back).
    DataMove { bytes: usize },
    /// One bubble-sort pass structure over `n` singular values
    /// (`swaps` actual exchanges, which also reorder U/V columns).
    Sort { n: usize, swaps: usize },
    /// Reorder the basis matrices after sorting: `rows x cols` moved.
    ReorderBasis { rows: usize, cols: usize },
    /// delta-truncation FSM: `probes` tail-norm tests over vectors of
    /// mean length `veclen`.
    Trunc { probes: usize, veclen: usize },
    /// One Givens rotation of the QR diagonalization applied across
    /// `len` elements (bidiagonal chase + U/V accumulation).
    GivensRot { len: usize },
    /// Scalar FP ops executed on the core (bookkeeping, shifts).
    CoreScalar { ops: usize },
    /// Reshape/copy of `elems` elements (address arithmetic + moves).
    Reshape { elems: usize },
}

/// Sink for hardware ops. The numerics call this; implementations
/// range from [`NullSink`] (pure math) to the simulator's timeline.
pub trait TraceSink {
    fn op(&mut self, op: HwOp);
}

/// Discards everything — used when only the numbers matter.
#[derive(Default, Clone, Copy, Debug)]
pub struct NullSink;

impl TraceSink for NullSink {
    #[inline]
    fn op(&mut self, _op: HwOp) {}
}

/// Records the full trace (benches and tests introspect it).
#[derive(Default, Clone, Debug)]
pub struct VecSink {
    pub ops: Vec<HwOp>,
}

impl TraceSink for VecSink {
    #[inline]
    fn op(&mut self, op: HwOp) {
        self.ops.push(op);
    }
}

impl VecSink {
    pub fn count(&self, pred: impl Fn(&HwOp) -> bool) -> usize {
        self.ops.iter().filter(|o| pred(o)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_sink_records_in_order() {
        let mut s = VecSink::default();
        s.op(HwOp::SetPhase(Phase::Hbd));
        s.op(HwOp::HouseGen { len: 8 });
        assert_eq!(s.ops.len(), 2);
        assert_eq!(s.ops[1], HwOp::HouseGen { len: 8 });
    }

    #[test]
    fn phase_labels_match_table3_rows() {
        assert_eq!(Phase::Hbd.label(), "HBD");
        assert_eq!(Phase::SortTrunc.label(), "Sort. & Trunc.");
        assert_eq!(Phase::ALL.len(), 5);
    }
}
