//! # TT-Edge
//!
//! Reproduction of *TT-Edge: A Hardware–Software Co-Design for
//! Energy-Efficient Tensor-Train Decomposition on Edge AI* (DATE 2026)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's system contribution: the edge
//!   SoC simulator with the TTD-Engine ([`sim`]), the full TTD numeric
//!   substrate ([`ttd`]), the hardware resource/power models
//!   ([`hw_model`]), and the Fig.-1 federated-learning coordinator
//!   ([`coordinator`]).
//! * **L2/L1 (python/, build-time only)** — the JAX compute graph and
//!   Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt` and executed
//!   from the [`runtime`] PJRT wrapper. Python never runs at runtime.
//!
//! See `DESIGN.md` for the full system inventory and the experiment
//! index mapping every paper table/figure to a module and bench.

// Index-based loops over matrix coordinates are the house style in the
// numeric kernels (mirrors the Algorithm 1/2 pseudocode); don't let
// `-D warnings` CI trip on the iterator-style suggestion.
#![allow(clippy::needless_range_loop)]

pub mod analysis;
pub mod cache;
pub mod coordinator;
pub mod dse;
pub mod fault;
pub mod hw_model;
pub mod job;
pub mod metrics;
pub mod model;
pub mod pipeline;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod testutil;
pub mod trace;
pub mod ttd;
pub mod util;

pub use cache::{CacheKey, ProgramCache};
pub use fault::{ChaosPlan, JobError, SvdStall};
pub use job::{numerics_pass_count, CompressionJob, JobOutput, JobProgram};
pub use ttd::tensor::GemmKernel;
