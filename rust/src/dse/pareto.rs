//! Pareto-frontier engine over the three DSE objectives: simulated
//! cycles, energy (mJ), and the area proxy (LUT-equivalents). All
//! objectives minimize.
//!
//! Determinism contract: [`pareto_front`] depends only on the
//! *multiset* of objective vectors and their indices — never on
//! evaluation timing — so a sweep's frontier is byte-identical at any
//! `--parallel` width. Ties (bit-identical objective vectors) are
//! broken by index: the earliest-evaluated point stays on the
//! frontier, later duplicates are pruned as dominated.

/// One candidate's objective vector (all minimized).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Objectives {
    /// Total simulated cycles across all Table-III phases (u64: the
    /// merge-order-invariant accumulator the cost sink maintains).
    pub cycles: u64,
    /// Total energy, mJ.
    pub energy_mj: f64,
    /// Area proxy, LUT-equivalents (see `dse::space::area_proxy_luts`).
    pub area_luts: u64,
}

/// Strict Pareto dominance: `a` is no worse in every objective and
/// strictly better in at least one.
pub fn dominates(a: &Objectives, b: &Objectives) -> bool {
    let no_worse =
        a.cycles <= b.cycles && a.energy_mj <= b.energy_mj && a.area_luts <= b.area_luts;
    let better =
        a.cycles < b.cycles || a.energy_mj < b.energy_mj || a.area_luts < b.area_luts;
    no_worse && better
}

/// Is point `i` pruned from the frontier of `points`? True when some
/// other point strictly dominates it, or an identical objective
/// vector appears at a lower index (the deterministic tie-break).
pub fn pruned_by(points: &[Objectives], i: usize) -> Option<usize> {
    points.iter().enumerate().find_map(|(j, p)| {
        let dup = j < i && *p == points[i];
        (dominates(p, &points[i]) || dup).then_some(j)
    })
}

/// Indices of the Pareto-optimal points, sorted by
/// (cycles, energy, area, index) ascending — a total order, so the
/// frontier listing is unique for a given evaluated set.
pub fn pareto_front(points: &[Objectives]) -> Vec<usize> {
    let mut front: Vec<usize> =
        (0..points.len()).filter(|&i| pruned_by(points, i).is_none()).collect();
    front.sort_by(|&a, &b| {
        let pa = &points[a];
        let pb = &points[b];
        pa.cycles
            .cmp(&pb.cycles)
            .then(pa.energy_mj.total_cmp(&pb.energy_mj))
            .then(pa.area_luts.cmp(&pb.area_luts))
            .then(a.cmp(&b))
    });
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(cycles: u64, energy_mj: f64, area_luts: u64) -> Objectives {
        Objectives { cycles, energy_mj, area_luts }
    }

    #[test]
    fn dominance_is_strict_and_irreflexive() {
        let a = p(10, 1.0, 100);
        assert!(!dominates(&a, &a));
        assert!(dominates(&p(9, 1.0, 100), &a));
        assert!(dominates(&p(9, 0.5, 50), &a));
        // trade-off: neither dominates
        assert!(!dominates(&p(9, 2.0, 100), &a));
        assert!(!dominates(&a, &p(9, 2.0, 100)));
    }

    #[test]
    fn frontier_keeps_tradeoffs_and_prunes_dominated() {
        let pts = [p(10, 1.0, 100), p(5, 2.0, 100), p(12, 1.5, 100), p(5, 2.0, 90)];
        let front = pareto_front(&pts);
        // 2 is dominated by 0; 1 is dominated by 3 (same cycles/energy,
        // less area); 0 and 3 trade off.
        assert_eq!(front, vec![3, 0]);
        assert_eq!(pruned_by(&pts, 2), Some(0));
        assert_eq!(pruned_by(&pts, 1), Some(3));
    }

    #[test]
    fn duplicate_vectors_keep_the_earliest_index() {
        let pts = [p(10, 1.0, 100), p(10, 1.0, 100), p(10, 1.0, 100)];
        assert_eq!(pareto_front(&pts), vec![0]);
        assert_eq!(pruned_by(&pts, 1), Some(0));
        assert_eq!(pruned_by(&pts, 2), Some(0));
        assert_eq!(pruned_by(&pts, 0), None);
    }

    #[test]
    fn frontier_order_is_total() {
        let pts = [p(5, 3.0, 10), p(5, 2.0, 20), p(4, 4.0, 30), p(6, 1.0, 5)];
        let front = pareto_front(&pts);
        assert_eq!(front, vec![2, 1, 0, 3]);
    }

    #[test]
    fn empty_and_singleton() {
        assert!(pareto_front(&[]).is_empty());
        assert_eq!(pareto_front(&[p(1, 1.0, 1)]), vec![0]);
    }
}
