//! Design-space exploration over the TT-Edge SoC simulator.
//!
//! The PR-3 costing seam made one numerics pass cost arbitrarily many
//! [`SocConfig`]s at once (streaming [`crate::sim::CostSink`], one
//! `HwTimeline` per config). This module turns that capability into a
//! scenario-diversity engine:
//!
//! * [`space`] — the candidate universe: all 2^5 [`Features`] combos
//!   x knob axes (GEMM tile edge, SPM KB, FP-ALU count, gating
//!   policy), enumerated canonically with the two paper anchors first.
//! * [`strategy`] — exhaustive grid, seeded random sampling, and a
//!   seeded evolutionary loop, all under an evaluation budget.
//! * [`pareto`] — the (cycles, energy mJ, area-proxy LUTs) frontier
//!   with dominance pruning and deterministic tie-breaking.
//! * [`explore`] — the driver, record-once / replay-many: **one**
//!   numerics pass total captures the workload's op stream as a
//!   [`crate::job::JobProgram`] (`--parallel` fans the layer work out
//!   via `pipeline`; the simulated objectives are invariant to it),
//!   then every strategy batch — every evolve generation included —
//!   is costed by replaying that program under the batch's SoC bank.
//!   [`explore_live`] keeps the per-batch live costing as the pinned
//!   reference path.
//!
//! Determinism contract (pinned by `tests/dse_engine.rs`): for a
//! fixed `(workload, space, strategy, budget, seed, eps)` the sweep
//! artifact and frontier report render byte-identically at any
//! `--parallel` width and any candidate evaluation order, because
//! every objective is either a u64 cycle bank or an f64 computed from
//! one, candidate ids follow the strategy's (seeded, thread-free)
//! selection order, and the frontier is a pure function of the
//! evaluated set.

pub mod pareto;
pub mod space;
pub mod strategy;

use std::collections::BTreeMap;

use crate::job::CompressionJob;
use crate::metrics::{f1, f2, Table};
use crate::model::resnet32::ConvLayer;
use crate::model::transformer::TransformerSpec;
use crate::sim::config::SocConfig;
use crate::sim::workload::synthetic_model;
use crate::trace::Phase;
use crate::ttd::{SvdMethod, Tensor, TtSpec};
use crate::util::json::Json;

pub use pareto::{dominates, pareto_front, Objectives};
pub use space::{area_proxy_luts, DesignSpace, Genome, SpaceKind};
pub use strategy::Strategy;

/// Which workload the candidates are costed on (`--workload`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// All 31 synthetic-trained ResNet-32 conv layers (the paper's
    /// Table-III workload).
    Resnet32,
    /// The first 4 layers — a fast proxy for tests/smoke runs.
    Tiny,
    /// A 2-block transformer decoder stack (ISSUE 9) — LLM-shaped
    /// matrices at CI speed.
    TinyGpt,
    /// BERT-base scale: 12 blocks at (768, 3072). Shape-enumerable,
    /// but decomposing it is a dedicated run, not a smoke job.
    BertBase,
    /// The tiny-gpt activation-map variant (per-block `seq_len x
    /// d_model` stacks).
    Activations,
}

impl Workload {
    pub fn parse(s: &str) -> Option<Workload> {
        match s {
            "resnet32" => Some(Workload::Resnet32),
            "tiny" => Some(Workload::Tiny),
            "tiny-gpt" => Some(Workload::TinyGpt),
            "bert-base" => Some(Workload::BertBase),
            "activations" => Some(Workload::Activations),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Workload::Resnet32 => "resnet32",
            Workload::Tiny => "tiny",
            Workload::TinyGpt => "tiny-gpt",
            Workload::BertBase => "bert-base",
            Workload::Activations => "activations",
        }
    }

    /// Materialize the layer set (same synthetic-trained generators
    /// the `simulate`/`compress` commands use; the seed keys the
    /// weights).
    pub fn layers(&self, seed: u64) -> Vec<(ConvLayer, Tensor)> {
        match self {
            Workload::Resnet32 => synthetic_model(seed, 3.55, 0.035),
            Workload::Tiny => {
                let mut layers = synthetic_model(seed, 3.55, 0.035);
                layers.truncate(4);
                layers
            }
            Workload::TinyGpt => TransformerSpec::tiny_gpt().synthetic_weights(seed),
            Workload::BertBase => TransformerSpec::bert_base().synthetic_weights(seed),
            Workload::Activations => TransformerSpec::tiny_gpt().synthetic_activations(seed),
        }
    }

    /// Build the workload's [`CompressionJob`] so every caller gets
    /// the right whole-model accounting (transformer inputs carry
    /// their own inventory; the ResNet-derived ones keep the legacy
    /// whole-ResNet-32 remainder). `backing` owns materialized layer
    /// sets for the ResNet workloads; transformer inputs materialize
    /// lazily inside the job.
    pub fn job<'a>(
        &self,
        seed: u64,
        backing: &'a mut Option<Vec<(ConvLayer, Tensor)>>,
    ) -> CompressionJob<'a> {
        match self {
            Workload::Resnet32 | Workload::Tiny => {
                *backing = Some(self.layers(seed));
                CompressionJob::model(backing.as_ref().expect("just set"))
            }
            Workload::TinyGpt => CompressionJob::transformer(TransformerSpec::tiny_gpt(), seed),
            Workload::BertBase => CompressionJob::transformer(TransformerSpec::bert_base(), seed),
            Workload::Activations => {
                CompressionJob::transformer_activations(TransformerSpec::tiny_gpt(), seed)
            }
        }
    }
}

/// Everything one exploration run needs.
#[derive(Clone, Debug)]
pub struct ExploreConfig {
    pub workload: Workload,
    pub space: SpaceKind,
    pub strategy: Strategy,
    /// Max candidate evaluations (clamped to [2, space size]).
    pub budget: usize,
    /// Seeds the workload weights AND the search RNG.
    pub seed: u64,
    pub eps: f32,
    /// SVD method for the numerics pass (`--method`). Exact by
    /// default; the randomized range-finder trades a small rank
    /// optimality loss for much cheaper sketches on LLM-shaped
    /// matrices. Lives here — not on the genome — because it changes
    /// the op stream, and record-once / replay-many requires every
    /// candidate to replay the *same* program.
    pub method: SvdMethod,
    /// Host worker threads per numerics pass (cost-invariant).
    pub parallel: usize,
}

impl Default for ExploreConfig {
    fn default() -> Self {
        ExploreConfig {
            workload: Workload::Resnet32,
            space: SpaceKind::Full,
            strategy: Strategy::Grid,
            budget: 32,
            seed: 42,
            eps: 0.12,
            method: SvdMethod::Exact,
            parallel: 1,
        }
    }
}

impl ExploreConfig {
    /// The full numeric spec this exploration decomposes under.
    pub fn spec(&self) -> TtSpec {
        TtSpec::eps(self.eps).with_method(self.method)
    }
}

/// One evaluated candidate.
#[derive(Clone, Debug)]
pub struct Evaluated {
    /// Evaluation-order id (0 = baseline anchor, 1 = TT-Edge anchor).
    pub id: usize,
    pub genome: Genome,
    pub name: String,
    /// The decoded SoC this candidate simulated.
    pub soc: SocConfig,
    pub objectives: Objectives,
    pub time_ms: f64,
}

/// The outcome of one exploration: every evaluated point + the
/// frontier over them.
#[derive(Clone, Debug)]
pub struct ExploreOutcome {
    pub cfg: ExploreConfig,
    pub space_size: usize,
    pub evaluated: Vec<Evaluated>,
    /// Ids (= indices into `evaluated`) on the Pareto frontier, in the
    /// deterministic (cycles, energy, area, id) order.
    pub frontier: Vec<usize>,
    /// Whole-model compression stats of the (config-independent)
    /// numerics: (ratio, max rel err, final params).
    pub compression: (f64, f32, usize),
    /// Numerics passes this exploration executed (counted on the
    /// calling thread via [`crate::job::numerics_pass_count`]).
    /// [`explore`] records once and replays, so this is 1 regardless
    /// of strategy or generation count; [`explore_live`] pays one per
    /// strategy batch. Deliberately NOT serialized into the sweep or
    /// frontier artifacts — those stay byte-identical across paths.
    pub numerics_passes: u64,
}

impl ExploreOutcome {
    /// The baseline anchor (id 0) — denominators for speedups.
    pub fn baseline(&self) -> &Evaluated {
        &self.evaluated[0]
    }

    pub fn speedup(&self, e: &Evaluated) -> f64 {
        self.baseline().objectives.cycles as f64 / e.objectives.cycles as f64
    }

    pub fn energy_reduction_pct(&self, e: &Evaluated) -> f64 {
        (1.0 - e.objectives.energy_mj / self.baseline().objectives.energy_mj) * 100.0
    }

    fn point_json(&self, e: &Evaluated) -> Json {
        let soc = &e.soc;
        let mut feats = BTreeMap::new();
        feats.insert("hbd_acc".into(), Json::Bool(soc.features.hbd_acc));
        feats.insert("direct_gemm_link".into(), Json::Bool(soc.features.direct_gemm_link));
        feats.insert("spm_retention".into(), Json::Bool(soc.features.spm_retention));
        feats.insert("hw_sort_trunc".into(), Json::Bool(soc.features.hw_sort_trunc));
        feats.insert("clock_gating".into(), Json::Bool(soc.features.clock_gating));
        let mut knobs = BTreeMap::new();
        knobs.insert("gemm_tile".into(), Json::from(soc.cost.gemm_tile as f64));
        knobs.insert("spm_kb".into(), Json::from(soc.cost.spm_kb as f64));
        knobs.insert("fpalu_units".into(), Json::from(soc.cost.fpalu_units as f64));
        knobs.insert("gating".into(), Json::from(soc.gating.label()));
        knobs.insert("backend".into(), Json::from(soc.backend.label()));
        let mut m = BTreeMap::new();
        m.insert("id".into(), Json::from(e.id));
        m.insert("name".into(), Json::from(e.name.as_str()));
        m.insert("features".into(), Json::Obj(feats));
        m.insert("knobs".into(), Json::Obj(knobs));
        m.insert("cycles".into(), Json::from(e.objectives.cycles as f64));
        m.insert("time_ms".into(), Json::from(e.time_ms));
        m.insert("energy_mj".into(), Json::from(e.objectives.energy_mj));
        m.insert("area_luts".into(), Json::from(e.objectives.area_luts as f64));
        m.insert("speedup".into(), Json::from(self.speedup(e)));
        m.insert(
            "energy_reduction_pct".into(),
            Json::from(self.energy_reduction_pct(e)),
        );
        m.insert("on_frontier".into(), Json::Bool(self.frontier.contains(&e.id)));
        Json::Obj(m)
    }

    fn header_json(&self) -> BTreeMap<String, Json> {
        let mut m = BTreeMap::new();
        m.insert("workload".into(), Json::from(self.cfg.workload.label()));
        m.insert("space".into(), Json::from(self.cfg.space.label()));
        m.insert("strategy".into(), Json::from(self.cfg.strategy.label()));
        m.insert("budget".into(), Json::from(self.cfg.budget));
        // string, not number: u64 seeds above 2^53 would silently
        // lose precision through JSON's f64 number path, breaking the
        // regenerate-from-artifact contract
        m.insert("seed".into(), Json::Str(self.cfg.seed.to_string()));
        m.insert("eps".into(), Json::from(f64::from(self.cfg.eps)));
        match self.cfg.method {
            SvdMethod::Exact => {
                m.insert("method".into(), Json::from("exact"));
            }
            SvdMethod::Randomized { seed, oversample } => {
                m.insert("method".into(), Json::from("rsvd"));
                // string for the same u64-precision reason as `seed`
                m.insert("rsvd_seed".into(), Json::Str(seed.to_string()));
                m.insert("rsvd_oversample".into(), Json::from(oversample as usize));
            }
        }
        m.insert("space_size".into(), Json::from(self.space_size));
        m.insert("evaluated".into(), Json::from(self.evaluated.len()));
        let mut comp = BTreeMap::new();
        comp.insert("ratio".into(), Json::from(self.compression.0));
        comp.insert("max_rel_err".into(), Json::from(f64::from(self.compression.1)));
        comp.insert("final_params".into(), Json::from(self.compression.2));
        m.insert("compression".into(), Json::Obj(comp));
        m.insert(
            "frontier".into(),
            Json::Arr(self.frontier.iter().map(|&i| Json::from(i)).collect()),
        );
        m
    }

    /// The frontier report (the `--json` stdout surface): run header +
    /// frontier points only. Deliberately excludes `--parallel` and
    /// all wall-clock times, so it is byte-identical at any width.
    pub fn report_json(&self) -> Json {
        let mut m = self.header_json();
        m.insert("schema".into(), Json::from("dse-frontier-v1"));
        m.insert(
            "points".into(),
            Json::Arr(self.frontier.iter().map(|&i| self.point_json(&self.evaluated[i])).collect()),
        );
        Json::Obj(m)
    }

    /// The full sweep artifact (written into `EXPERIMENTS/`): run
    /// header + every evaluated point in evaluation order.
    pub fn sweep_json(&self) -> Json {
        let mut m = self.header_json();
        m.insert("schema".into(), Json::from("dse-sweep-v1"));
        m.insert(
            "points".into(),
            Json::Arr(self.evaluated.iter().map(|e| self.point_json(e)).collect()),
        );
        Json::Obj(m)
    }

    /// Human frontier table.
    pub fn frontier_table(&self) -> String {
        let mut t = Table::new(
            &format!(
                "Pareto frontier ({} of {} evaluated candidates, space `{}`, strategy `{}`)",
                self.frontier.len(),
                self.evaluated.len(),
                self.cfg.space.label(),
                self.cfg.strategy.label(),
            ),
            &["id", "config", "T (ms)", "E (mJ)", "area (LUT)", "speedup", "E save %"],
        );
        for &i in &self.frontier {
            let e = &self.evaluated[i];
            t.row(&[
                e.id.to_string(),
                e.name.clone(),
                f2(e.time_ms),
                f2(e.objectives.energy_mj),
                e.objectives.area_luts.to_string(),
                format!("{:.2}x", self.speedup(e)),
                f1(self.energy_reduction_pct(e)),
            ]);
        }
        t.render()
    }
}

/// Append one batch's [`Evaluated`] records from its simulation
/// reports (shared by the replay and live evaluators, so both produce
/// byte-identical artifacts).
fn push_evaluated(
    space: &DesignSpace,
    genomes: &[Genome],
    socs: Vec<SocConfig>,
    reports: &[crate::sim::report::SimReport],
    next_id: usize,
    out: &mut Vec<Evaluated>,
) {
    for (i, ((&g, soc), report)) in genomes.iter().zip(socs).zip(reports).enumerate() {
        let cycles: u64 = Phase::ALL.iter().map(|&p| report.phase(p).cycles).sum();
        out.push(Evaluated {
            id: next_id + i,
            genome: g,
            name: space.name(g),
            soc,
            objectives: Objectives {
                cycles,
                energy_mj: report.total_mj,
                area_luts: space.area(g),
            },
            time_ms: report.total_ms,
        });
    }
}

/// Evaluate one batch of genomes by replaying the recorded op program
/// under every candidate SoC — zero numerics, bit-identical costing.
fn evaluate_batch_replay(
    program: &crate::job::JobProgram,
    space: &DesignSpace,
    genomes: &[Genome],
    next_id: usize,
    out: &mut Vec<Evaluated>,
) {
    let socs: Vec<SocConfig> = genomes.iter().map(|&g| space.to_soc(g)).collect();
    let job = CompressionJob::replay(program)
        .socs(&socs)
        .run()
        .expect("replay jobs carry no cancel token");
    push_evaluated(space, genomes, socs, &job.reports, next_id, out);
}

/// Evaluate one batch with live costing: a full numerics pass with
/// every candidate SoC costed online in the streaming multi-config
/// sink, layer fan-out on `parallel` host workers. This is the
/// pre-cache reference path [`explore_live`] keeps alive; the
/// byte-identity of its artifacts against [`explore`]'s replay path is
/// pinned by `tests/dse_engine.rs`.
fn evaluate_batch_live(
    space: &DesignSpace,
    cfg: &ExploreConfig,
    genomes: &[Genome],
    next_id: usize,
    out: &mut Vec<Evaluated>,
) -> (f64, f32, usize) {
    let socs: Vec<SocConfig> = genomes.iter().map(|&g| space.to_soc(g)).collect();
    let mut backing = None;
    let job = cfg
        .workload
        .job(cfg.seed, &mut backing)
        .spec(cfg.spec())
        .parallel(cfg.parallel)
        .socs(&socs)
        .run()
        .expect("explore jobs carry no cancel token");
    push_evaluated(space, genomes, socs, &job.reports, next_id, out);
    (
        job.outcome.compression_ratio,
        job.outcome.max_rel_err,
        job.outcome.final_params,
    )
}

fn finish(
    cfg: &ExploreConfig,
    space: &DesignSpace,
    evaluated: Vec<Evaluated>,
    compression: (f64, f32, usize),
    passes_before: u64,
) -> ExploreOutcome {
    let objs: Vec<Objectives> = evaluated.iter().map(|e| e.objectives).collect();
    let frontier = pareto_front(&objs);
    ExploreOutcome {
        cfg: cfg.clone(),
        space_size: space.len(),
        evaluated,
        frontier,
        compression,
        numerics_passes: crate::job::numerics_pass_count() - passes_before,
    }
}

/// Run one exploration (see the [module docs](self) for the
/// determinism contract).
///
/// Record-once / replay-many: the workload's op stream is captured in
/// **one** numerics pass ([`CompressionJob::program`]) and every
/// strategy batch — including every evolve generation — is costed by
/// replaying that program under the batch's SoC bank. Replay is
/// bit-identical to live costing, so the sweep/frontier artifacts are
/// byte-identical to [`explore_live`] while the numerics cost stays
/// constant in the generation count ([`ExploreOutcome::numerics_passes`]
/// asserts exactly 1).
pub fn explore(cfg: &ExploreConfig) -> ExploreOutcome {
    let passes_before = crate::job::numerics_pass_count();
    let space = DesignSpace::new(cfg.space);
    // THE numerics pass: record the config-independent op program
    // (no SoC bank attached — per-batch costing happens on replay).
    let mut backing = None;
    let (job_out, program) = cfg
        .workload
        .job(cfg.seed, &mut backing)
        .spec(cfg.spec())
        .parallel(cfg.parallel)
        .program()
        .expect("explore jobs carry no cancel token");
    let compression = (
        job_out.outcome.compression_ratio,
        job_out.outcome.max_rel_err,
        job_out.outcome.final_params,
    );
    let mut evaluated: Vec<Evaluated> = Vec::new();

    match cfg.strategy {
        Strategy::Grid | Strategy::Random => {
            let plan = match cfg.strategy {
                Strategy::Grid => strategy::plan_grid(&space, cfg.budget),
                _ => strategy::plan_random(&space, cfg.budget, cfg.seed),
            };
            evaluate_batch_replay(&program, &space, &plan, 0, &mut evaluated);
        }
        Strategy::Evolve => {
            strategy::run_evolve(&space, cfg.budget, cfg.seed, |batch| {
                let next_id = evaluated.len();
                evaluate_batch_replay(&program, &space, batch, next_id, &mut evaluated);
                evaluated[next_id..].iter().map(|e| e.objectives).collect()
            });
        }
    }

    finish(cfg, &space, evaluated, compression, passes_before)
}

/// [`explore`] with live per-batch costing (one numerics pass per
/// strategy batch — the pre-PR-5 behavior). Kept as the reference the
/// replay path is pinned against (`tests/dse_engine.rs` asserts
/// byte-identical artifacts) and as the baseline the live-vs-replay
/// bench in `benches/dse_frontier.rs` measures.
pub fn explore_live(cfg: &ExploreConfig) -> ExploreOutcome {
    let passes_before = crate::job::numerics_pass_count();
    let space = DesignSpace::new(cfg.space);
    let mut evaluated: Vec<Evaluated> = Vec::new();
    let mut compression = (0.0f64, 0.0f32, 0usize);

    match cfg.strategy {
        Strategy::Grid | Strategy::Random => {
            let plan = match cfg.strategy {
                Strategy::Grid => strategy::plan_grid(&space, cfg.budget),
                _ => strategy::plan_random(&space, cfg.budget, cfg.seed),
            };
            compression = evaluate_batch_live(&space, cfg, &plan, 0, &mut evaluated);
        }
        Strategy::Evolve => {
            let mut comp = compression;
            strategy::run_evolve(&space, cfg.budget, cfg.seed, |batch| {
                let next_id = evaluated.len();
                comp = evaluate_batch_live(&space, cfg, batch, next_id, &mut evaluated);
                evaluated[next_id..].iter().map(|e| e.objectives).collect()
            });
            compression = comp;
        }
    }

    finish(cfg, &space, evaluated, compression, passes_before)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(strategy: Strategy, budget: usize) -> ExploreConfig {
        ExploreConfig {
            workload: Workload::Tiny,
            space: SpaceKind::Features,
            strategy,
            budget,
            seed: 5,
            eps: 0.2,
            method: SvdMethod::Exact,
            parallel: 1,
        }
    }

    #[test]
    fn grid_explore_evaluates_the_prefix_and_fronts_ttedge() {
        let out = explore(&tiny_cfg(Strategy::Grid, 4));
        assert_eq!(out.evaluated.len(), 4);
        assert_eq!(out.evaluated[0].name, "baseline");
        assert_eq!(out.evaluated[1].name, "tt-edge");
        assert!(!out.frontier.is_empty());
        // anchors: tt-edge is faster and leaner in energy than base
        let b = &out.evaluated[0].objectives;
        let t = &out.evaluated[1].objectives;
        assert!(t.cycles < b.cycles);
        assert!(t.energy_mj < b.energy_mj);
        assert!(t.area_luts > b.area_luts);
        // compression stats are populated from the numerics
        assert!(out.compression.0 > 1.0);
        assert!(out.compression.2 > 0);
    }

    #[test]
    fn evaluation_ids_are_dense_and_ordered() {
        let out = explore(&tiny_cfg(Strategy::Evolve, 6));
        for (i, e) in out.evaluated.iter().enumerate() {
            assert_eq!(e.id, i);
        }
        assert!(out.evaluated.len() <= 6);
        for &i in &out.frontier {
            assert!(i < out.evaluated.len());
        }
    }

    #[test]
    fn explore_records_once_regardless_of_generations() {
        let mut cfg = tiny_cfg(Strategy::Evolve, 20);
        cfg.space = SpaceKind::Full; // room for several generations
        let out = explore(&cfg);
        assert_eq!(out.numerics_passes, 1, "replay path re-ran the numerics");
        assert!(
            out.evaluated.len() > 8,
            "budget 20 should span >1 generation, got {}",
            out.evaluated.len()
        );
        let live = explore_live(&cfg);
        assert!(live.numerics_passes >= 2, "live evolve pays per generation");
        // and the artifacts agree byte for byte
        assert_eq!(out.sweep_json().render(), live.sweep_json().render());
        assert_eq!(out.report_json().render(), live.report_json().render());
    }

    #[test]
    fn transformer_workload_explores_under_rsvd_with_one_pass() {
        let mut cfg = tiny_cfg(Strategy::Grid, 4);
        cfg.workload = Workload::TinyGpt;
        cfg.method = SvdMethod::Randomized { seed: 9, oversample: 8 };
        cfg.eps = 0.12;
        let out = explore(&cfg);
        assert_eq!(out.numerics_passes, 1);
        assert!(out.compression.0 > 1.0, "ratio {}", out.compression.0);
        // the rsvd header fields are in the artifact
        let sweep = out.sweep_json();
        assert_eq!(sweep.get("method").unwrap().as_str().unwrap(), "rsvd");
        assert_eq!(sweep.get("rsvd_seed").unwrap().as_str().unwrap(), "9");
        assert_eq!(sweep.get("workload").unwrap().as_str().unwrap(), "tiny-gpt");
        // replay-vs-live byte identity holds for the new method too
        let live = explore_live(&cfg);
        assert_eq!(out.sweep_json().render(), live.sweep_json().render());
    }

    #[test]
    fn full_space_grid_budget_40_spans_both_backends() {
        let mut cfg = tiny_cfg(Strategy::Grid, 40);
        cfg.space = SpaceKind::Full;
        let out = explore(&cfg);
        assert_eq!(out.numerics_passes, 1, "cross-backend sweep must still record once");
        let sweep = out.sweep_json();
        let points = sweep.get("points").unwrap().as_arr().unwrap();
        let systolic = points
            .iter()
            .filter(|p| {
                p.get("knobs").unwrap().get("backend").unwrap().as_str() == Some("systolic")
            })
            .count();
        assert_eq!(systolic, 8, "ids 32..40 are the first systolic genomes");
    }

    #[test]
    fn report_is_a_subset_of_the_sweep() {
        let out = explore(&tiny_cfg(Strategy::Grid, 5));
        let report = out.report_json();
        let sweep = out.sweep_json();
        let rp = report.get("points").unwrap().as_arr().unwrap();
        let sp = sweep.get("points").unwrap().as_arr().unwrap();
        assert_eq!(rp.len(), out.frontier.len());
        assert_eq!(sp.len(), out.evaluated.len());
        // every frontier point appears verbatim in the sweep
        for p in rp {
            assert!(sp.contains(p));
        }
        // both render as valid JSON for our own parser
        let text = sweep.render();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("schema").unwrap().as_str().unwrap(),
            "dse-sweep-v1"
        );
    }
}
