//! Search strategies over a [`DesignSpace`] under an evaluation
//! budget.
//!
//! * **Grid** — the enumeration-order prefix (anchors first, then
//!   feature-diverse before knob-diverse; see `DesignSpace::new`).
//! * **Random** — anchors + a seeded Fisher–Yates sample of the
//!   remaining genomes, without replacement.
//! * **Evolve** — a (mu + lambda)-style loop: seed with the anchors
//!   plus random genomes, then repeatedly select the current Pareto
//!   parents and mutate them (flip one feature bit or step one knob
//!   axis) into unseen canonical children until the budget is spent.
//!
//! Every strategy is a pure function of `(space, budget, seed)` plus —
//! for Evolve — the objective values the caller feeds back, all of
//! which are host-thread-count invariant. Hence the selection order,
//! and therefore the whole sweep artifact, is byte-identical at any
//! `--parallel` width — and also across the two evaluators the driver
//! offers (`dse::explore`'s op-program replay and `dse::explore_live`'s
//! per-batch numerics), because replayed objectives are bit-identical
//! to live-costed ones.

use std::collections::BTreeSet;

use crate::dse::pareto::{pareto_front, Objectives};
use crate::dse::space::{DesignSpace, Genome};
use crate::util::Rng;

/// Which search to run (`--strategy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Grid,
    Random,
    Evolve,
}

impl Strategy {
    pub fn parse(s: &str) -> Option<Strategy> {
        match s {
            "grid" => Some(Strategy::Grid),
            "random" => Some(Strategy::Random),
            "evolve" => Some(Strategy::Evolve),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Strategy::Grid => "grid",
            Strategy::Random => "random",
            Strategy::Evolve => "evolve",
        }
    }
}

/// Evolve-loop population per generation.
const EVOLVE_POP: usize = 8;
/// Parents kept per generation (frontier prefix).
const EVOLVE_PARENTS: usize = 4;
/// Mutation attempts before falling back to a fresh random genome.
const MUTATE_TRIES: usize = 16;

/// Clamp a requested budget to [2, space size]: the two anchors are
/// always evaluated (speedup/energy reductions are relative to the
/// baseline anchor).
pub fn clamp_budget(space: &DesignSpace, budget: usize) -> usize {
    // every space contains at least the two anchors
    budget.clamp(2, space.len())
}

/// Grid plan: the first `budget` genomes in enumeration order.
pub fn plan_grid(space: &DesignSpace, budget: usize) -> Vec<Genome> {
    let n = clamp_budget(space, budget);
    space.genomes()[..n].to_vec()
}

/// Random plan: anchors + a seeded sample (without replacement) of
/// the rest of the space.
pub fn plan_random(space: &DesignSpace, budget: usize, seed: u64) -> Vec<Genome> {
    let n = clamp_budget(space, budget);
    let mut rest: Vec<Genome> = space.genomes()[2..].to_vec();
    let mut rng = Rng::new(seed ^ 0xD5E0_5EED);
    // Partial Fisher–Yates: fix positions 0.. as we draw.
    for i in 0..rest.len().min(n.saturating_sub(2)) {
        let j = i + rng.below(rest.len() - i);
        rest.swap(i, j);
    }
    let mut plan = space.genomes()[..2].to_vec();
    plan.extend(rest.into_iter().take(n - 2));
    plan
}

/// Mutate one gene of `g`: flip a feature bit or step a knob axis to a
/// different value, then canonicalize. Every pick genuinely moves the
/// genome: knob axes that canonicalization would pin back for this
/// parent (FP-ALU count without the engine, gating policy without the
/// clock-gating feature) are not offered. May still return a genome
/// equal to a previously *seen* one — the caller dedups.
fn mutate(space: &DesignSpace, rng: &mut Rng, g: Genome) -> Genome {
    // Gene slots: 5 feature bits + the knob axes that have >1 value
    // AND are expressible under the parent's feature mask.
    let mut out = g;
    let f = crate::sim::config::Features::from_mask(g.mask);
    let knob_axes = [
        space.tiles.len() > 1,
        space.spm_kbs.len() > 1,
        space.alus.len() > 1 && f.uses_engine(),
        space.gates.len() > 1 && f.clock_gating,
        // the GEMM datapath backend reprices every candidate (GEMM
        // ops exist at any mask), so it is always expressible
        space.backends.len() > 1,
    ];
    let n_knobs = knob_axes.iter().filter(|&&b| b).count();
    let pick = rng.below(5 + n_knobs);
    if pick < 5 {
        out.mask ^= 1 << pick;
    } else {
        // index among the variable axes
        let mut which = pick - 5;
        let mut axis = 0;
        for (i, &variable) in knob_axes.iter().enumerate() {
            if variable {
                if which == 0 {
                    axis = i;
                    break;
                }
                which -= 1;
            }
        }
        let step = |cur: u8, len: usize, rng: &mut Rng| -> u8 {
            let next = rng.below(len.saturating_sub(1));
            // skip the current index so the gene always changes
            if next as u8 >= cur { next as u8 + 1 } else { next as u8 }
        };
        match axis {
            0 => out.tile = step(out.tile, space.tiles.len(), rng),
            1 => out.spm = step(out.spm, space.spm_kbs.len(), rng),
            2 => out.alu = step(out.alu, space.alus.len(), rng),
            3 => out.gate = step(out.gate, space.gates.len(), rng),
            _ => out.backend = step(out.backend, space.backends.len(), rng),
        }
    }
    space.canonical(out)
}

/// Run the evolutionary search. `eval` receives each generation's
/// batch of genomes and must return one [`Objectives`] per genome in
/// order (the caller records whatever else it needs). Returns the
/// full evaluated genome sequence (anchors first), which together
/// with `eval`'s bookkeeping is the sweep.
pub fn run_evolve<F>(
    space: &DesignSpace,
    budget: usize,
    seed: u64,
    mut eval: F,
) -> Vec<Genome>
where
    F: FnMut(&[Genome]) -> Vec<Objectives>,
{
    let budget = clamp_budget(space, budget);
    let mut rng = Rng::new(seed ^ 0xE_0E_0E);
    let mut seen: BTreeSet<Genome> = BTreeSet::new();
    let mut evaluated: Vec<Genome> = Vec::new();
    let mut scores: Vec<Objectives> = Vec::new();

    // Fresh unseen genome drawn uniformly from the space (fallback
    // when mutation keeps landing on seen genomes).
    let fresh = |rng: &mut Rng, seen: &BTreeSet<Genome>| -> Option<Genome> {
        let unseen: Vec<Genome> =
            space.genomes().iter().copied().filter(|g| !seen.contains(g)).collect();
        if unseen.is_empty() {
            None
        } else {
            Some(unseen[rng.below(unseen.len())])
        }
    };

    // Generation 0: anchors + random fill.
    let mut batch: Vec<Genome> = space.genomes()[..2].to_vec();
    for g in &batch {
        seen.insert(*g);
    }
    while batch.len() < EVOLVE_POP.min(budget) {
        match fresh(&mut rng, &seen) {
            Some(g) => {
                seen.insert(g);
                batch.push(g);
            }
            None => break,
        }
    }

    while !batch.is_empty() {
        let objs = eval(&batch);
        assert_eq!(objs.len(), batch.len(), "eval must score every genome");
        evaluated.extend(batch.iter().copied());
        scores.extend(objs);
        let remaining = budget - evaluated.len();
        if remaining == 0 {
            break;
        }
        // Parents: the current frontier prefix (already sorted by the
        // deterministic (cycles, energy, area, id) order).
        let front = pareto_front(&scores);
        let parents: Vec<Genome> =
            front.iter().take(EVOLVE_PARENTS).map(|&i| evaluated[i]).collect();
        // Children: mutated parents, deduped against everything seen.
        batch = Vec::new();
        let want = EVOLVE_POP.min(remaining);
        'fill: while batch.len() < want {
            let parent = parents[rng.below(parents.len())];
            let mut child = None;
            for _ in 0..MUTATE_TRIES {
                let c = mutate(space, &mut rng, parent);
                if space.contains(c) && !seen.contains(&c) {
                    child = Some(c);
                    break;
                }
            }
            let c = match child.or_else(|| fresh(&mut rng, &seen)) {
                Some(c) => c,
                None => break 'fill, // space exhausted
            };
            seen.insert(c);
            batch.push(c);
        }
    }
    evaluated
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::space::SpaceKind;

    #[test]
    fn grid_is_the_enumeration_prefix() {
        let s = DesignSpace::new(SpaceKind::Features);
        let plan = plan_grid(&s, 8);
        assert_eq!(plan.len(), 8);
        assert_eq!(plan, s.genomes()[..8].to_vec());
        // over-budget clamps to the space
        assert_eq!(plan_grid(&s, 10_000).len(), 32);
        // under-budget still evaluates both anchors
        assert_eq!(plan_grid(&s, 0).len(), 2);
    }

    #[test]
    fn random_is_seed_deterministic_and_duplicate_free() {
        let s = DesignSpace::new(SpaceKind::Full);
        let a = plan_random(&s, 20, 7);
        let b = plan_random(&s, 20, 7);
        assert_eq!(a, b);
        let c = plan_random(&s, 20, 8);
        assert_ne!(a, c);
        let mut set: Vec<Genome> = a.clone();
        set.sort();
        set.dedup();
        assert_eq!(set.len(), a.len(), "duplicates in random plan");
        assert_eq!(&a[..2], &s.genomes()[..2]);
    }

    #[test]
    fn mutation_always_moves_and_stays_canonical() {
        let s = DesignSpace::new(SpaceKind::Full);
        let mut rng = Rng::new(11);
        // parents exercising every knob-applicability combination:
        // full engine + gating, engine-less + ungated, gating-only
        let parents = [
            s.canonical(Genome { mask: 0b10011, tile: 1, spm: 2, alu: 1, gate: 1, backend: 1 }),
            Genome::of_mask(0b00100),
            s.canonical(Genome { mask: 0b10000, tile: 2, spm: 0, alu: 0, gate: 1, backend: 0 }),
        ];
        for g in parents {
            for _ in 0..200 {
                let m = mutate(&s, &mut rng, g);
                assert_eq!(m, s.canonical(m), "mutants are canonical");
                assert_ne!(m, g, "mutation must move (parent {g:?})");
            }
        }
    }

    #[test]
    fn evolve_respects_budget_and_dedups() {
        let s = DesignSpace::new(SpaceKind::Features);
        // Synthetic objective: fewer enabled features = more cycles,
        // more area with mask (monotone fake landscape).
        let evaluated = run_evolve(&s, 17, 3, |batch| {
            batch
                .iter()
                .map(|g| Objectives {
                    cycles: 1_000 - 10 * g.mask.count_ones() as u64,
                    energy_mj: f64::from(g.mask) * 0.5 + 1.0,
                    area_luts: 100 + u64::from(g.mask),
                })
                .collect()
        });
        assert!(evaluated.len() <= 17);
        assert!(evaluated.len() >= 8, "{}", evaluated.len());
        let mut uniq = evaluated.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), evaluated.len(), "evolve revisited a genome");
        assert_eq!(&evaluated[..2], &s.genomes()[..2]);
        // deterministic in the seed
        let again = run_evolve(&s, 17, 3, |batch| {
            batch
                .iter()
                .map(|g| Objectives {
                    cycles: 1_000 - 10 * g.mask.count_ones() as u64,
                    energy_mj: f64::from(g.mask) * 0.5 + 1.0,
                    area_luts: 100 + u64::from(g.mask),
                })
                .collect()
        });
        assert_eq!(evaluated, again);
    }

    #[test]
    fn evolve_exhausts_tiny_spaces_gracefully() {
        let s = DesignSpace::new(SpaceKind::Paper);
        let evaluated = run_evolve(&s, 10, 1, |batch| {
            batch
                .iter()
                .map(|_| Objectives { cycles: 1, energy_mj: 1.0, area_luts: 1 })
                .collect()
        });
        assert_eq!(evaluated.len(), 2);
    }
}
