//! The design-space descriptor: which SoC candidates exist, how they
//! are enumerated, and what each costs in area.
//!
//! A candidate is a [`Genome`]: the 5-bit [`Features`] mask plus one
//! index per knob axis (GEMM tile edge, SPM capacity, FP-ALU count,
//! clock-gating policy, GEMM datapath backend). [`DesignSpace`] owns
//! the axis value lists and
//! enumerates genomes in a fixed, documented order, so every strategy
//! and every `--parallel` width sees the identical candidate universe.
//!
//! Genomes are *canonical*: knobs that cannot influence a candidate's
//! cost (FP-ALU count without the engine instantiated, gating policy
//! without the clock-gating feature) are pinned to their first axis
//! value, so the space never contains two genomes that decode to
//! cost-identical SoCs.

use crate::sim::config::{Backend, Features, GatingPolicy, SocConfig, Variant};

/// One candidate design point: feature mask + knob axis indices.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Genome {
    /// 5-bit [`Features`] mask (bit order per [`Features::SHORT_NAMES`]).
    pub mask: u8,
    /// Index into [`DesignSpace::tiles`].
    pub tile: u8,
    /// Index into [`DesignSpace::spm_kbs`].
    pub spm: u8,
    /// Index into [`DesignSpace::alus`].
    pub alu: u8,
    /// Index into [`DesignSpace::gates`].
    pub gate: u8,
    /// Index into [`DesignSpace::backends`] (the GEMM datapath cost
    /// model — ISSUE 9).
    pub backend: u8,
}

impl Genome {
    /// The all-defaults genome for a feature mask.
    pub fn of_mask(mask: u8) -> Genome {
        Genome { mask, tile: 0, spm: 0, alu: 0, gate: 0, backend: 0 }
    }
}

/// Which slice of the space to enumerate (`--space`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpaceKind {
    /// The two paper SoCs only (baseline + TT-Edge).
    Paper,
    /// All 2^5 feature combinations at the paper's knob values.
    Features,
    /// Feature combinations x every knob axis (canonical genomes).
    Full,
}

impl SpaceKind {
    pub fn parse(s: &str) -> Option<SpaceKind> {
        match s {
            "paper" => Some(SpaceKind::Paper),
            "features" => Some(SpaceKind::Features),
            "full" => Some(SpaceKind::Full),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SpaceKind::Paper => "paper",
            SpaceKind::Features => "features",
            SpaceKind::Full => "full",
        }
    }
}

/// The candidate universe: knob axes + the enumeration over them.
/// Axis position 0 is always the paper's default value, so
/// `Genome::of_mask` decodes to a paper-knobbed SoC.
#[derive(Clone, Debug)]
pub struct DesignSpace {
    pub kind: SpaceKind,
    /// GEMM tile edges (position 0 = 16, the paper's).
    pub tiles: Vec<u64>,
    /// SPM capacities in KB (position 0 = 320).
    pub spm_kbs: Vec<u64>,
    /// FP-ALU unit counts (position 0 = 1).
    pub alus: Vec<u64>,
    /// Clock-gating policies (position 0 = engine-owned).
    pub gates: Vec<GatingPolicy>,
    /// GEMM datapath backends (position 0 = the paper's tiled
    /// accelerator; the `full` space adds the group-vector systolic
    /// model).
    pub backends: Vec<Backend>,
    /// Canonical genomes in enumeration order (anchors first).
    genomes: Vec<Genome>,
}

impl DesignSpace {
    /// Build the space for `kind`. Enumeration order: the two paper
    /// anchors (baseline mask 0, TT-Edge mask 31, default knobs),
    /// then knob combinations in axis-lexicographic order (defaults
    /// first) with the feature mask varying fastest — so any budget
    /// prefix is feature-diverse before it is knob-diverse.
    pub fn new(kind: SpaceKind) -> DesignSpace {
        let (tiles, spm_kbs, alus, gates, backends) = match kind {
            SpaceKind::Full => (
                vec![16u64, 8, 32],
                vec![320u64, 64, 160],
                vec![1u64, 2, 4],
                vec![GatingPolicy::EngineOwned, GatingPolicy::HbdOnly],
                Backend::ALL.to_vec(),
            ),
            _ => (
                vec![16u64],
                vec![320u64],
                vec![1u64],
                vec![GatingPolicy::EngineOwned],
                vec![Backend::TtEdgeGemm],
            ),
        };
        let mut space =
            DesignSpace { kind, tiles, spm_kbs, alus, gates, backends, genomes: Vec::new() };
        space.genomes = space.enumerate();
        space
    }

    /// Anchor candidates: the paper's baseline and TT-Edge, always the
    /// first two ids so speedup/energy comparisons and small budgets
    /// are well-defined.
    fn anchors() -> [Genome; 2] {
        [Genome::of_mask(0), Genome::of_mask(0x1F)]
    }

    fn enumerate(&self) -> Vec<Genome> {
        let mut v: Vec<Genome> = Self::anchors().to_vec();
        if self.kind == SpaceKind::Paper {
            return v;
        }
        // backend varies second-fastest (inside every knob, outside
        // the mask): a small budget prefix covers all 32 masks on the
        // paper datapath and then the same 32 on the systolic one,
        // before any other knob moves.
        for gate in 0..self.gates.len() as u8 {
            for alu in 0..self.alus.len() as u8 {
                for spm in 0..self.spm_kbs.len() as u8 {
                    for tile in 0..self.tiles.len() as u8 {
                        for backend in 0..self.backends.len() as u8 {
                            for mask in 0u8..32 {
                                let g = Genome { mask, tile, spm, alu, gate, backend };
                                if self.canonical(g) == g && !v.contains(&g) {
                                    v.push(g);
                                }
                            }
                        }
                    }
                }
            }
        }
        v
    }

    /// Pin cost-inert knob indices to 0 (see module docs).
    pub fn canonical(&self, mut g: Genome) -> Genome {
        let f = Features::from_mask(g.mask);
        if !f.uses_engine() {
            g.alu = 0;
        }
        if !f.clock_gating {
            g.gate = 0;
        }
        g
    }

    /// All canonical genomes, anchors first.
    pub fn genomes(&self) -> &[Genome] {
        &self.genomes
    }

    /// Is `g` one of this space's candidates? (Mutation operators must
    /// not wander outside the declared universe — e.g. the `paper`
    /// space contains nothing but the two anchors.)
    pub fn contains(&self, g: Genome) -> bool {
        self.genomes.contains(&g)
    }

    pub fn len(&self) -> usize {
        self.genomes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.genomes.is_empty()
    }

    /// Decode a genome into a simulatable SoC. A featureless genome is
    /// the true [`Variant::Baseline`] (no engine area/power); anything
    /// with a feature enabled is a TT-Edge variant.
    pub fn to_soc(&self, g: Genome) -> SocConfig {
        let features = Features::from_mask(g.mask);
        let mut soc = if g.mask == 0 { SocConfig::baseline() } else { SocConfig::tt_edge() };
        soc.features = features;
        soc.gating = self.gates[g.gate as usize];
        soc.backend = self.backends[g.backend as usize];
        soc.cost.gemm_tile = self.tiles[g.tile as usize];
        soc.cost.spm_kb = self.spm_kbs[g.spm as usize];
        soc.cost.fpalu_units = self.alus[g.alu as usize];
        soc
    }

    /// Human label for a genome: feature label + non-default knob
    /// suffixes. The two anchors get their canonical names.
    pub fn name(&self, g: Genome) -> String {
        let anchors = Self::anchors();
        if g == anchors[0] {
            return "baseline".to_string();
        }
        if g == anchors[1] {
            return "tt-edge".to_string();
        }
        let mut s = Features::from_mask(g.mask).label();
        if g.tile != 0 {
            s.push_str(&format!(" t{}", self.tiles[g.tile as usize]));
        }
        if g.spm != 0 {
            s.push_str(&format!(" spm{}", self.spm_kbs[g.spm as usize]));
        }
        if g.alu != 0 {
            s.push_str(&format!(" alu{}", self.alus[g.alu as usize]));
        }
        if g.gate != 0 {
            s.push_str(&format!(" {}", self.gates[g.gate as usize].label()));
        }
        if g.backend != 0 {
            s.push_str(&format!(" {}", self.backends[g.backend as usize].label()));
        }
        s
    }

    /// Area proxy for a genome, in LUT-equivalents (see
    /// [`area_proxy_luts`]).
    pub fn area(&self, g: Genome) -> u64 {
        let soc = self.to_soc(g);
        area_proxy_luts(&soc)
    }
}

/// LUT-equivalents charged per KB of SPM away from the paper's 320 KB
/// (BRAM macros don't consume LUTs on the FPGA; the proxy charges an
/// area-equivalent so capacity is not free in the trade space).
pub const SPM_LUT_EQ_PER_KB: u64 = 96;

/// LUT cost of the clock-gating controller (ICG cells + FSM; tiny).
pub const GATING_LUTS: u64 = 48;

/// Area/overhead proxy for one SoC configuration, in LUT-equivalents.
///
/// Derived from the Table-II inventory ([`crate::hw_model`]): the
/// non-specialized blocks are always present (the GEMM accelerator
/// scaled linearly by PE count around the paper's 64), and each
/// enabled TT-Edge mechanism adds its measured block — HBD-ACC +
/// engine glue (kept by `hbd_acc` OR `direct_gemm_link`, since the
/// hardware descriptor generator lives on the HBD-ACC address
/// calculator), the direct-link interface, SORTING + TRUNCATION, the
/// shared FP-ALU (once a compute-streaming module exists, times
/// `fpalu_units`), and the gating controller. `sim::power` prices
/// partial-feature candidates with the same absent-block rules, so
/// the two objectives never disagree about which hardware exists.
/// SPM capacity departures from 320 KB are
/// charged at [`SPM_LUT_EQ_PER_KB`]. At the paper's two SoCs the
/// proxy reproduces Table II's totals exactly (modulo the SPM term,
/// which is zero there).
pub fn area_proxy_luts(soc: &SocConfig) -> u64 {
    // panics on unknown block names — a renamed Table-II block must
    // fail loudly, not silently price a mechanism at zero area
    let lut = |name: &str| -> u64 { crate::hw_model::block(name).luts };
    // Always-present SoC fabric.
    let mut area: u64 = 0;
    for n in ["Rocket RISC-V Core", "SRAM", "DDR Controller",
        "Peripherals incl. DMA", "System Interconnect"]
    {
        area += lut(n);
    }
    // GEMM accelerator scales with the PE array.
    area += lut("GEMM Accelerator") * soc.cost.gemm_pes.max(1) / 64;
    // SPM capacity proxy (signed around the 320 KB baseline).
    let spm_delta = soc.cost.spm_kb as i64 - 320;
    area = (area as i64 + spm_delta * SPM_LUT_EQ_PER_KB as i64).max(0) as u64;
    // Feature-conditional engine blocks.
    let f = &soc.features;
    if soc.variant == Variant::Baseline {
        return area;
    }
    if f.hbd_acc || f.direct_gemm_link {
        // the HBD-ACC block hosts both the Householder pipeline and
        // the hardware descriptor generator the direct link relies on
        area += lut("HBD-ACC") + lut("TTD-Engine glue (unitemized)");
    }
    if f.direct_gemm_link {
        area += lut("DMA/SPM/GEMM IF + interconnect");
    }
    if f.hw_sort_trunc {
        area += lut("SORTING") + lut("TRUNCATION");
    }
    if f.uses_engine() {
        area += lut("FP-ALU") * soc.cost.fpalu_units.max(1);
    }
    if f.clock_gating {
        area += GATING_LUTS;
    }
    area
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw_model;

    #[test]
    fn paper_space_is_the_two_anchors() {
        let s = DesignSpace::new(SpaceKind::Paper);
        assert_eq!(s.len(), 2);
        assert_eq!(s.name(s.genomes()[0]), "baseline");
        assert_eq!(s.name(s.genomes()[1]), "tt-edge");
        assert_eq!(s.to_soc(s.genomes()[0]).variant, Variant::Baseline);
        assert_eq!(s.to_soc(s.genomes()[1]).variant, Variant::TtEdge);
    }

    #[test]
    fn features_space_enumerates_all_masks_once() {
        let s = DesignSpace::new(SpaceKind::Features);
        assert_eq!(s.len(), 32);
        let mut masks: Vec<u8> = s.genomes().iter().map(|g| g.mask).collect();
        masks.sort_unstable();
        masks.dedup();
        assert_eq!(masks.len(), 32);
        // anchors lead
        assert_eq!(s.genomes()[0].mask, 0);
        assert_eq!(s.genomes()[1].mask, 0x1F);
    }

    #[test]
    fn full_space_is_canonical_and_duplicate_free() {
        let s = DesignSpace::new(SpaceKind::Full);
        let mut seen = std::collections::BTreeSet::new();
        for &g in s.genomes() {
            assert_eq!(s.canonical(g), g, "{g:?} not canonical");
            assert!(seen.insert(g), "duplicate {g:?}");
        }
        // engine-less masks never vary the ALU axis; ungated masks
        // never vary the policy axis
        for &g in s.genomes() {
            let f = Features::from_mask(g.mask);
            if !f.uses_engine() {
                assert_eq!(g.alu, 0);
            }
            if !f.clock_gating {
                assert_eq!(g.gate, 0);
            }
        }
        assert!(s.len() > 200, "{}", s.len());
    }

    #[test]
    fn budget_prefix_is_feature_diverse() {
        // the first 32+ genomes at default knobs cover every mask
        let s = DesignSpace::new(SpaceKind::Full);
        let prefix: Vec<u8> = s.genomes()[..32].iter().map(|g| g.mask).collect();
        let mut sorted = prefix.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 32);
        assert!(s.genomes()[..32]
            .iter()
            .all(|g| (g.tile, g.spm, g.alu, g.gate, g.backend) == (0, 0, 0, 0, 0)));
        // ...and the next 32 are the same masks on the systolic
        // backend, still at default knobs — a budget of 64 spans both
        // datapaths over every feature combination
        let next: Vec<u8> = s.genomes()[32..64].iter().map(|g| g.mask).collect();
        let mut next_sorted = next.clone();
        next_sorted.sort_unstable();
        next_sorted.dedup();
        assert_eq!(next_sorted.len(), 32);
        assert!(s.genomes()[32..64]
            .iter()
            .all(|g| (g.tile, g.spm, g.alu, g.gate, g.backend) == (0, 0, 0, 0, 1)));
    }

    #[test]
    fn backend_axis_exists_only_in_the_full_space() {
        assert_eq!(DesignSpace::new(SpaceKind::Paper).backends, vec![Backend::TtEdgeGemm]);
        assert_eq!(DesignSpace::new(SpaceKind::Features).backends, vec![Backend::TtEdgeGemm]);
        let s = DesignSpace::new(SpaceKind::Full);
        assert_eq!(s.backends, Backend::ALL.to_vec());
        let systolic_twin = Genome { backend: 1, ..Genome::of_mask(0x1F) };
        assert!(s.contains(systolic_twin));
        assert_eq!(s.to_soc(systolic_twin).backend, Backend::Systolic);
        assert_eq!(s.name(systolic_twin), "all systolic");
        // the backend repriced GEMM only: area (no new Table-II rows)
        // is identical to the tiled twin
        assert_eq!(s.area(systolic_twin), s.area(Genome::of_mask(0x1F)));
    }

    #[test]
    fn anchor_areas_reproduce_table_ii() {
        let s = DesignSpace::new(SpaceKind::Paper);
        let hw = hw_model::summarize();
        let tte = s.area(s.genomes()[1]);
        assert_eq!(tte, hw.total_luts + GATING_LUTS);
        let base = s.area(s.genomes()[0]);
        assert_eq!(base, hw.total_luts - hw.ttd_engine_luts);
        assert!(base < tte);
    }

    #[test]
    fn knobs_move_the_area_proxy_monotonically() {
        let s = DesignSpace::new(SpaceKind::Full);
        let mut tte = s.to_soc(Genome::of_mask(0x1F));
        let a1 = area_proxy_luts(&tte);
        tte.cost.fpalu_units = 4;
        let a4 = area_proxy_luts(&tte);
        assert_eq!(a4 - a1, 3 * 3_314);
        tte.cost.spm_kb = 64;
        assert!(area_proxy_luts(&tte) < a4);
        tte.cost.gemm_pes = 128;
        assert!(area_proxy_luts(&tte) > a4 - (320 - 64) * SPM_LUT_EQ_PER_KB);
    }

    #[test]
    fn direct_link_keeps_the_hbd_acc_block() {
        // the link's descriptor generator lives on HBD-ACC: a
        // link-only candidate pays for both blocks
        let s = DesignSpace::new(SpaceKind::Features);
        let base = s.area(Genome::of_mask(0));
        let link_only = s.area(s.canonical(Genome::of_mask(0b00010)));
        assert_eq!(link_only - base, 1_346 + 29 + 1_412);
    }

    #[test]
    fn names_mention_non_default_knobs_only() {
        let s = DesignSpace::new(SpaceKind::Full);
        let g = Genome { mask: 0b01001, tile: 2, spm: 1, alu: 1, gate: 0, backend: 0 };
        assert_eq!(s.name(s.canonical(g)), "hbd+sort t32 spm64 alu2");
        let plain = Genome::of_mask(0b00110);
        assert_eq!(s.name(plain), "link+spm");
    }
}
