//! Request supervision: panic containment and per-request deadlines.
//!
//! [`supervise`] is the serve drain's isolation boundary — it converts
//! any panic escaping one request (injected chaos or a genuine bug)
//! into a structured [`JobError`] so one bad request can never kill
//! the process. [`with_deadline`] arms the existing cooperative
//! [`CancelToken`] from a watchdog thread; a zero deadline expires
//! before the run starts, which is the fully deterministic spelling
//! the chaos suites and CI use (positive deadlines are best-effort
//! wall-clock and excluded from byte-determinism claims).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::time::Duration;

use super::JobError;
use crate::pipeline::CancelToken;

/// Run `f` with panic containment. A panic carrying a [`JobError`]
/// payload (the `ttd::decompose` hard-stall path uses
/// `std::panic::panic_any`) surfaces as that error; string panics
/// become [`JobError::WorkerPanic`] with the message preserved.
pub fn supervise<T>(f: impl FnOnce() -> Result<T, JobError>) -> Result<T, JobError> {
    // AssertUnwindSafe: the closure only borrows the shared cache,
    // whose single-flight MissGuard releases its Pending slot on
    // unwind — no half-updated state survives the catch.
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(result) => result,
        Err(payload) => Err(downcast_panic(payload.as_ref())),
    }
}

fn downcast_panic(payload: &(dyn std::any::Any + Send)) -> JobError {
    if let Some(err) = payload.downcast_ref::<JobError>() {
        err.clone()
    } else if let Some(msg) = payload.downcast_ref::<&str>() {
        JobError::WorkerPanic((*msg).to_string())
    } else if let Some(msg) = payload.downcast_ref::<String>() {
        JobError::WorkerPanic(msg.clone())
    } else {
        JobError::WorkerPanic("opaque panic payload".to_string())
    }
}

/// Run `f` under a per-request deadline, arming `token` when it
/// expires. `None` runs unwatched; `Some(0)` cancels the token before
/// `f` starts (deterministic); `Some(ms)` parks a watchdog thread on
/// an `mpsc::recv_timeout` — no `Instant::now` polling — that cancels
/// the token on timeout and exits silently when `f` finishes first.
pub fn with_deadline<T>(deadline_ms: Option<u64>, token: &CancelToken, f: impl FnOnce() -> T) -> T {
    match deadline_ms {
        None => f(),
        Some(0) => {
            token.cancel();
            f()
        }
        Some(ms) => {
            let (done_tx, done_rx) = mpsc::channel::<()>();
            std::thread::scope(|scope| {
                scope.spawn(move || {
                    if matches!(
                        done_rx.recv_timeout(Duration::from_millis(ms)),
                        Err(mpsc::RecvTimeoutError::Timeout)
                    ) {
                        token.cancel();
                    }
                });
                let out = f();
                // Disconnect wakes the watchdog without a timeout.
                drop(done_tx);
                out
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn supervise_passes_results_through() {
        assert_eq!(supervise(|| Ok::<_, JobError>(7)), Ok(7));
        assert_eq!(
            supervise(|| Err::<u32, _>(JobError::Cancelled)),
            Err(JobError::Cancelled)
        );
    }

    #[test]
    fn supervise_downcasts_string_panics() {
        let got = supervise(|| -> Result<(), JobError> { panic!("injected worker panic") });
        assert_eq!(got, Err(JobError::WorkerPanic("injected worker panic".into())));
        let got = supervise(|| -> Result<(), JobError> {
            std::panic::panic_any("static str".to_string())
        });
        assert_eq!(got, Err(JobError::WorkerPanic("static str".into())));
    }

    #[test]
    fn supervise_preserves_joberror_panic_payloads() {
        let got = supervise(|| -> Result<(), JobError> {
            std::panic::panic_any(JobError::SvdNonConvergence { iterations: 41 })
        });
        assert_eq!(got, Err(JobError::SvdNonConvergence { iterations: 41 }));
    }

    #[test]
    fn zero_deadline_expires_before_the_run_starts() {
        let token = CancelToken::default();
        let cancelled_at_entry = with_deadline(Some(0), &token, || token.is_cancelled());
        assert!(cancelled_at_entry);
    }

    #[test]
    fn absent_deadline_never_arms_the_token() {
        let token = CancelToken::default();
        let cancelled_at_entry = with_deadline(None, &token, || token.is_cancelled());
        assert!(!cancelled_at_entry);
        assert!(!token.is_cancelled());
    }

    #[test]
    fn generous_deadline_leaves_a_fast_run_uncancelled() {
        let token = CancelToken::default();
        let out = with_deadline(Some(60_000), &token, || 3 + 4);
        assert_eq!(out, 7);
        assert!(!token.is_cancelled());
    }

    #[test]
    fn expired_deadline_arms_the_token() {
        let token = CancelToken::default();
        with_deadline(Some(1), &token, || {
            // Park until the watchdog fires; the cooperative check is
            // how real jobs observe the deadline.
            while !token.is_cancelled() {
                std::thread::yield_now();
            }
        });
        assert!(token.is_cancelled());
    }
}
