//! Crate-wide seeded fault injection and the structured failure
//! taxonomy (ISSUE 10).
//!
//! PR 2 proved the seeded-chaos pattern for the federated coordinator
//! (`coordinator/faults.rs`): a plan is a pure function from
//! `(seed, index, attempt)` to fault decisions, so an entire chaos run
//! replays byte-for-byte from its seed and a *benign* plan leaves
//! every numeric result bit-identical to the fault-free path. This
//! module generalizes that idiom into injection points the whole
//! numerics -> cache -> serve stack consults:
//!
//! * **poison** — NaN-poison one weight of the request's input before
//!   submission (caught by the [`crate::job`] input screen as
//!   [`JobError::NonFiniteInput`], never propagated into ranks);
//! * **stall** — force SVD non-convergence ([`SvdStall`]): a *soft*
//!   stall is rescued by the Jacobi fallback in `ttd::decompose`, a
//!   *hard* stall models the fallback failing too and surfaces as
//!   [`JobError::SvdNonConvergence`];
//! * **panic** — a seeded worker panic mid-request, converted by the
//!   serve supervisor's `catch_unwind` into a structured error
//!   response instead of process death;
//! * **cancel** — forced cache-miss cancellation through the existing
//!   `CancelToken`, exercising the single-flight `MissGuard` release
//!   path.
//!
//! Decisions are keyed per `(request, attempt)` — never per worker —
//! so a chaos drain is byte-identical at any worker count. Forced
//! indices fire on *every* attempt (a deterministic, greppable error
//! count for CI); probabilistic faults redraw per attempt, so a
//! bounded retry may genuinely rescue a request.

use std::fmt;

use crate::util::Rng;

pub mod supervisor;

pub use supervisor::{supervise, with_deadline};

/// Stream-separation constant: chaos decisions must never alias the
/// coordinator's fault/transport streams (`0x...0001`/`0x...0002`) or
/// any workload weight stream.
const CHAOS_STREAM: u64 = 0xFA_0175_0000_0003;

/// The round/index mixer every fault stream uses (the PR-2 idiom,
/// now shared crate-wide).
pub(crate) const STREAM_MIX: u64 = 0x9E3779B97F4A7C15;

/// The canonical fault-stream constructor: `seed ^ stream ^
/// major * golden-ratio`, forked per minor index by the caller.
pub fn stream_rng(seed: u64, stream: u64, major: u64) -> Rng {
    Rng::new(seed ^ stream ^ major.wrapping_mul(STREAM_MIX))
}

/// Structured failure taxonomy for one compression request. Every
/// variant has a stable wire `code()` — the serve JSONL error field —
/// and a retryability class the supervisor consults.
#[derive(Clone, Debug, PartialEq)]
pub enum JobError {
    /// A NaN/Inf weight reached the job input boundary (`layer` is the
    /// first offending layer index).
    NonFiniteInput { layer: usize },
    /// The QR diagonalization hit its iteration cap and the Jacobi
    /// fallback could not rescue it.
    SvdNonConvergence { iterations: usize },
    /// The request's `CancelToken` fired mid-run.
    Cancelled,
    /// The per-request deadline expired before the run finished.
    DeadlineExceeded,
    /// The request line failed to parse (only reachable under
    /// `serve --lenient`; strict mode aborts the queue).
    MalformedRequest(String),
    /// A worker panicked mid-request (injected or real); the payload
    /// is the panic message.
    WorkerPanic(String),
}

impl JobError {
    /// Stable wire identifier (the `error.code` response field).
    pub fn code(&self) -> &'static str {
        match self {
            JobError::NonFiniteInput { .. } => "non-finite-input",
            JobError::SvdNonConvergence { .. } => "svd-non-convergence",
            JobError::Cancelled => "cancelled",
            JobError::DeadlineExceeded => "deadline-exceeded",
            JobError::MalformedRequest(_) => "malformed-request",
            JobError::WorkerPanic(_) => "worker-panic",
        }
    }

    /// Whether a bounded retry can plausibly clear the fault. Bad
    /// input, cancellation, and expired deadlines are final; panics
    /// and non-convergence may be transient (an injected probabilistic
    /// fault redraws per attempt).
    pub fn retryable(&self) -> bool {
        matches!(self, JobError::WorkerPanic(_) | JobError::SvdNonConvergence { .. })
    }
}

impl fmt::Display for JobError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobError::NonFiniteInput { layer } => {
                write!(f, "non-finite weight in input layer {layer}")
            }
            JobError::SvdNonConvergence { iterations } => {
                write!(f, "SVD failed to converge after {iterations} iterations")
            }
            JobError::Cancelled => write!(f, "request cancelled"),
            JobError::DeadlineExceeded => write!(f, "request deadline exceeded"),
            JobError::MalformedRequest(e) => write!(f, "malformed request: {e}"),
            JobError::WorkerPanic(msg) => write!(f, "worker panicked: {msg}"),
        }
    }
}

impl std::error::Error for JobError {}

/// Forced SVD non-convergence mode, carried on `TtSpec` so it reaches
/// `ttd::decompose` on any worker thread without globals — and, being
/// numeric identity, participates in the cache key.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum SvdStall {
    /// No injection: the QR path's own `converged` flag decides.
    #[default]
    None,
    /// Pretend the QR sweep stalled — the Jacobi fallback rescues the
    /// factorization and the job still succeeds.
    Soft,
    /// The fallback fails too: `decompose` raises
    /// [`JobError::SvdNonConvergence`] mid-recording (exercising the
    /// single-flight `MissGuard` panic path).
    Hard,
}

impl SvdStall {
    /// Stable cache-key discriminant.
    pub fn discriminant(&self) -> u8 {
        match self {
            SvdStall::None => 0,
            SvdStall::Soft => 1,
            SvdStall::Hard => 2,
        }
    }
}

/// The fault decisions one `(request, attempt)` drew.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RequestFaults {
    pub poison: bool,
    pub stall: SvdStall,
    pub panic: bool,
    pub cancel: bool,
}

impl RequestFaults {
    pub fn nominal() -> Self {
        RequestFaults { poison: false, stall: SvdStall::None, panic: false, cancel: false }
    }
}

/// Seeded chaos schedule for a serve drain (the crate-wide
/// generalization of the coordinator's `FaultPlan`).
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosPlan {
    pub seed: u64,
    /// Per-attempt probability of NaN-poisoning the request input.
    pub poison: f64,
    /// Per-attempt probability of a *soft* SVD stall (Jacobi-rescued).
    pub stall: f64,
    /// Per-attempt probability of a worker panic.
    pub panic: f64,
    /// Per-attempt probability of a forced mid-run cancellation.
    pub cancel: f64,
    /// Request indices whose input is poisoned on every attempt.
    pub forced_poison: Vec<usize>,
    /// Request indices that *hard*-stall on every attempt (the
    /// deterministic `svd-non-convergence` error count CI greps).
    pub forced_stalls: Vec<usize>,
    /// Request indices that panic on every attempt.
    pub forced_panics: Vec<usize>,
    /// Request indices cancelled on every attempt.
    pub forced_cancels: Vec<usize>,
}

impl Default for ChaosPlan {
    fn default() -> Self {
        ChaosPlan {
            seed: 0xC4A05,
            poison: 0.0,
            stall: 0.0,
            panic: 0.0,
            cancel: 0.0,
            forced_poison: Vec::new(),
            forced_stalls: Vec::new(),
            forced_panics: Vec::new(),
            forced_cancels: Vec::new(),
        }
    }
}

impl ChaosPlan {
    /// True when the plan cannot perturb a drain — serve's fault-free
    /// path must then be bit-identical to the pre-chaos behaviour.
    pub fn is_benign(&self) -> bool {
        self.poison <= 0.0
            && self.stall <= 0.0
            && self.panic <= 0.0
            && self.cancel <= 0.0
            && self.forced_poison.is_empty()
            && self.forced_stalls.is_empty()
            && self.forced_panics.is_empty()
            && self.forced_cancels.is_empty()
    }

    fn rng(&self, index: usize, attempt: usize) -> Rng {
        stream_rng(self.seed, CHAOS_STREAM, index as u64).fork(attempt as u64 + 1)
    }

    /// Decide one `(request, attempt)`'s faults. All four uniforms are
    /// drawn unconditionally so each fault kind owns a fixed draw
    /// slot: toggling one probability at the same seed never
    /// reshuffles another kind's decisions (the PR-2 invariant).
    pub fn for_request(&self, index: usize, attempt: usize) -> RequestFaults {
        let mut rng = self.rng(index, attempt);
        let poison_draw = rng.uniform();
        let stall_draw = rng.uniform();
        let panic_draw = rng.uniform();
        let cancel_draw = rng.uniform();
        let poison = self.forced_poison.contains(&index)
            || (self.poison > 0.0 && poison_draw < self.poison);
        let stall = if self.forced_stalls.contains(&index) {
            SvdStall::Hard
        } else if self.stall > 0.0 && stall_draw < self.stall {
            SvdStall::Soft
        } else {
            SvdStall::None
        };
        let panic = self.forced_panics.contains(&index)
            || (self.panic > 0.0 && panic_draw < self.panic);
        let cancel = self.forced_cancels.contains(&index)
            || (self.cancel > 0.0 && cancel_draw < self.cancel);
        RequestFaults { poison, stall, panic, cancel }
    }

    /// Which weight slot of a `len`-element input the poison hits
    /// (a pure function of the plan seed and request index, so a
    /// poisoned drain replays byte-for-byte).
    pub fn poison_slot(&self, index: usize, len: usize) -> usize {
        debug_assert!(len > 0, "cannot poison an empty input");
        stream_rng(self.seed, CHAOS_STREAM ^ 0x1, index as u64).below(len.max(1))
    }

    /// Seeded retry backoff in milliseconds — a pure function of
    /// `(seed, request, attempt)`, bounded to [0, 4) so chaos suites
    /// stay fast. Deterministic in *value*; the actual sleep is
    /// wall-clock and never reaches a byte-pinned artifact.
    pub fn backoff_ms(&self, index: usize, attempt: usize) -> u64 {
        self.rng(index, attempt).fork(0x42).next_u64() % 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_benign_and_nominal() {
        let plan = ChaosPlan::default();
        assert!(plan.is_benign());
        for index in 0..16 {
            for attempt in 0..3 {
                assert_eq!(plan.for_request(index, attempt), RequestFaults::nominal());
            }
        }
    }

    #[test]
    fn decisions_replay_from_the_seed() {
        let plan =
            ChaosPlan { poison: 0.2, stall: 0.3, panic: 0.3, cancel: 0.1, ..ChaosPlan::default() };
        assert!(!plan.is_benign());
        for index in 0..32 {
            for attempt in 0..3 {
                assert_eq!(plan.for_request(index, attempt), plan.for_request(index, attempt));
                assert_eq!(plan.backoff_ms(index, attempt), plan.backoff_ms(index, attempt));
                assert!(plan.backoff_ms(index, attempt) < 4);
            }
        }
    }

    #[test]
    fn fault_kinds_use_independent_draw_slots() {
        // Toggling panic injection must not reshuffle which requests
        // get poisoned or stalled at the same seed.
        let base = ChaosPlan { poison: 0.3, stall: 0.3, ..ChaosPlan::default() };
        let with_panics = ChaosPlan { panic: 0.5, ..base.clone() };
        for index in 0..64 {
            let a = base.for_request(index, 0);
            let b = with_panics.for_request(index, 0);
            assert_eq!(a.poison, b.poison, "request {index}");
            assert_eq!(a.stall, b.stall, "request {index}");
        }
    }

    #[test]
    fn forced_faults_fire_on_every_attempt() {
        let plan = ChaosPlan {
            forced_poison: vec![1],
            forced_stalls: vec![2],
            forced_panics: vec![3],
            forced_cancels: vec![4],
            ..ChaosPlan::default()
        };
        assert!(!plan.is_benign());
        for attempt in 0..4 {
            assert!(plan.for_request(1, attempt).poison);
            assert_eq!(plan.for_request(2, attempt).stall, SvdStall::Hard);
            assert!(plan.for_request(3, attempt).panic);
            assert!(plan.for_request(4, attempt).cancel);
            // neighbours stay nominal
            assert_eq!(plan.for_request(0, attempt), RequestFaults::nominal());
            assert_eq!(plan.for_request(5, attempt), RequestFaults::nominal());
        }
    }

    #[test]
    fn probabilistic_faults_redraw_per_attempt() {
        // With p = 0.5 some request must panic on attempt 0 and
        // recover on attempt 1 — that redraw is what makes a retry
        // worth paying for.
        let plan = ChaosPlan { panic: 0.5, ..ChaosPlan::default() };
        let recovered = (0..64).any(|i| {
            plan.for_request(i, 0).panic && !plan.for_request(i, 1).panic
        });
        assert!(recovered, "no request recovered on retry across 64 draws");
    }

    #[test]
    fn fault_rate_roughly_matches_probability() {
        let plan = ChaosPlan { panic: 0.25, ..ChaosPlan::default() };
        let hits = (0..1024).filter(|&i| plan.for_request(i, 0).panic).count();
        let rate = hits as f64 / 1024.0;
        assert!((0.18..0.32).contains(&rate), "rate {rate}");
    }

    #[test]
    fn poison_slot_is_stable_and_in_range() {
        let plan = ChaosPlan::default();
        for index in 0..8 {
            let slot = plan.poison_slot(index, 100);
            assert!(slot < 100);
            assert_eq!(slot, plan.poison_slot(index, 100));
        }
    }

    #[test]
    fn error_codes_are_stable_and_retryability_is_classed() {
        let cases: [(JobError, &str, bool); 6] = [
            (JobError::NonFiniteInput { layer: 3 }, "non-finite-input", false),
            (JobError::SvdNonConvergence { iterations: 40 }, "svd-non-convergence", true),
            (JobError::Cancelled, "cancelled", false),
            (JobError::DeadlineExceeded, "deadline-exceeded", false),
            (JobError::MalformedRequest("bad".into()), "malformed-request", false),
            (JobError::WorkerPanic("boom".into()), "worker-panic", true),
        ];
        for (err, code, retryable) in cases {
            assert_eq!(err.code(), code);
            assert_eq!(err.retryable(), retryable, "{code}");
            assert!(!err.to_string().is_empty());
        }
    }

    #[test]
    fn stall_discriminants_are_distinct() {
        assert_eq!(SvdStall::default(), SvdStall::None);
        let d: Vec<u8> =
            [SvdStall::None, SvdStall::Soft, SvdStall::Hard].iter().map(|s| s.discriminant()).collect();
        assert_eq!(d, vec![0, 1, 2]);
    }
}
