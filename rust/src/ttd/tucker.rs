//! Tucker decomposition (truncated HOSVD) — the Table-I baseline [12].
//!
//! `W ~= C x1 U_1 x2 U_2 ... xN U_N` with per-mode factor matrices and
//! a dense core. Mode ranks are selected by the same prescribed-accuracy
//! rule as TTD (per-mode budget `eps/sqrt(N) * ||W||_F`), so the
//! Table-I comparison varies only the decomposition, not the policy.

use crate::trace::{NullSink, TraceSink};
use crate::ttd::svd::svd;
use crate::ttd::tensor::{Matrix, Tensor};

#[derive(Clone, Debug)]
pub struct TuckerDecomp {
    pub dims: Vec<usize>,
    pub ranks: Vec<usize>,
    /// Core tensor, shape `ranks`.
    pub core: Tensor,
    /// Factor matrices `U_k` of shape `(n_k, r_k)`.
    pub factors: Vec<Matrix>,
    pub eps: f32,
}

impl TuckerDecomp {
    pub fn param_count(&self) -> usize {
        self.core.numel()
            + self
                .factors
                .iter()
                .map(|u| u.rows * u.cols)
                .sum::<usize>()
    }

    pub fn dense_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn compression_ratio(&self) -> f64 {
        self.dense_count() as f64 / self.param_count() as f64
    }
}

/// Truncated HOSVD with prescribed accuracy `eps`.
pub fn decompose(w: &Tensor, eps: f32) -> TuckerDecomp {
    decompose_traced(w, eps, &mut NullSink)
}

pub fn decompose_traced<S: TraceSink>(w: &Tensor, eps: f32, sink: &mut S) -> TuckerDecomp {
    let nd = w.shape.len();
    let budget = eps / (nd as f32).sqrt() * w.frobenius();

    let mut factors = Vec::with_capacity(nd);
    let mut ranks = Vec::with_capacity(nd);
    for mode in 0..nd {
        let unf = w.unfold(mode);
        let s = svd(&unf, sink);
        // sort descending (svd() output is unsorted by contract)
        let mut order: Vec<usize> = (0..s.sigma.len()).collect();
        order.sort_by(|&a, &b| s.sigma[b].partial_cmp(&s.sigma[a]).unwrap());
        let sorted: Vec<f32> = order.iter().map(|&i| s.sigma[i]).collect();
        // keep smallest r with tail norm < budget
        let mut tail = 0.0f64;
        let mut r = sorted.len();
        while r > 1 {
            let cand = tail + (sorted[r - 1] as f64).powi(2);
            if (cand.sqrt() as f32) < budget {
                tail = cand;
                r -= 1;
            } else {
                break;
            }
        }
        let mut u = Matrix::zeros(unf.rows, r);
        for (new_c, &old_c) in order[..r].iter().enumerate() {
            for row in 0..unf.rows {
                u.set(row, new_c, s.u.get(row, old_c));
            }
        }
        ranks.push(r);
        factors.push(u);
    }

    // Core: C = W x1 U_1^T x2 U_2^T ... (project every mode).
    let mut core = w.clone();
    for (mode, u) in factors.iter().enumerate() {
        core = core.mode_product(mode, &u.transpose());
    }

    TuckerDecomp { dims: w.shape.clone(), ranks, core, factors, eps }
}

/// `C x1 U_1 ... xN U_N` — Tucker reconstruction.
pub fn reconstruct(d: &TuckerDecomp) -> Tensor {
    let mut t = d.core.clone();
    for (mode, u) in d.factors.iter().enumerate() {
        t = t.mode_product(mode, u);
    }
    t
}

pub fn relative_error(original: &Tensor, d: &TuckerDecomp) -> f32 {
    crate::ttd::reconstruct::rel_error_to(original, &reconstruct(d))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::util::Rng;

    #[test]
    fn exact_at_full_rank() {
        check(8, 800, |rng| {
            let shape = [2 + rng.below(4), 2 + rng.below(4), 2 + rng.below(4)];
            let w = Tensor::from_vec(&shape, rng.normal_vec(shape.iter().product()));
            let d = decompose(&w, 0.0);
            assert_eq!(d.ranks, shape.to_vec());
            assert!(relative_error(&w, &d) < 1e-3);
        });
    }

    #[test]
    fn error_bound_holds() {
        // HOSVD: ||W - W_R|| <= sqrt(sum of discarded sv^2) <= eps||W||.
        check(8, 801, |rng| {
            let shape = [4, 6, 6];
            let w = Tensor::from_vec(&shape, rng.normal_vec(shape.iter().product()));
            let eps = 0.35;
            let d = decompose(&w, eps);
            assert!(relative_error(&w, &d) <= eps + 1e-3);
        });
    }

    #[test]
    fn low_mode_rank_recovered() {
        let mut rng = Rng::new(95);
        // mode-0 rank 2 tensor: W = U G with U (6,2)
        let u = Matrix::from_vec(6, 2, rng.normal_vec(12));
        let g = Matrix::from_vec(2, 30, rng.normal_vec(60));
        let w_mat = u.matmul(&g);
        let w = Tensor::from_vec(&[6, 5, 6], w_mat.data);
        let d = decompose(&w, 0.01);
        assert_eq!(d.ranks[0], 2);
        assert!(relative_error(&w, &d) < 0.02);
    }

    #[test]
    fn param_accounting() {
        let mut rng = Rng::new(96);
        let w = Tensor::from_vec(&[4, 5, 6], rng.normal_vec(120));
        let d = decompose(&w, 0.4);
        let manual = d.ranks.iter().product::<usize>()
            + d.dims.iter().zip(&d.ranks).map(|(n, r)| n * r).sum::<usize>();
        assert_eq!(d.param_count(), manual);
    }
}
