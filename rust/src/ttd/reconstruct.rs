//! TTD decoding — Eq. (1)/(2): chained `reshape . matmul . reshape`
//! contractions, exactly the receiving node's reconstruction in Fig. 1.

use crate::ttd::tensor::{Matrix, Tensor};
use crate::ttd::ttd::TtDecomp;

/// `W_R = G_1 x1 G_2 x1 ... x1 G_N` (Eq. 1).
pub fn reconstruct(d: &TtDecomp) -> Tensor {
    assert!(!d.cores.is_empty());
    assert_eq!(d.cores[0].r_in, 1, "r_0 must be 1");
    assert_eq!(d.cores.last().unwrap().r_out, 1, "r_N must be 1");

    // acc: ([n_1 .. n_k], r_k) kept flat, row-major (Eq. 2).
    let first = &d.cores[0];
    let mut acc = Matrix::from_vec(first.n, first.r_out, first.data.clone());
    for core in &d.cores[1..] {
        // (r_{k-1}, n_k * r_k) — borrowed view, no clone of the core
        let right = core.as_matrix_right();
        let prod = acc.matmul_view(&right); // ([n_1..n_{k-1}], n_k * r_k)
        acc = Matrix::from_vec(prod.rows * core.n, core.r_out, prod.data);
    }
    Tensor::from_vec(&d.dims, acc.data)
}

/// `||W - W_R||_F / ||W||_F` for any reconstruction — shared by the
/// TT/TR/Tucker error metrics so Table I compares one formula.
pub fn rel_error_to(original: &Tensor, reconstructed: &Tensor) -> f32 {
    let num: f64 = original
        .data
        .iter()
        .zip(&reconstructed.data)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum();
    let den: f64 = original.data.iter().map(|a| (*a as f64).powi(2)).sum();
    (num / den.max(1e-30)).sqrt() as f32
}

/// Reconstruction error `||W - W_R||_F / ||W||_F`.
pub fn relative_error(original: &Tensor, d: &TtDecomp) -> f32 {
    let wr = reconstruct(d);
    assert_eq!(wr.shape, original.shape);
    rel_error_to(original, &wr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;
    use crate::ttd::ttd::{decompose, TtCore, TtSpec};
    use crate::util::Rng;

    #[test]
    fn reconstruct_matches_explicit_einsum() {
        let mut rng = Rng::new(90);
        let (n1, r1, n2, r2, n3) = (3usize, 2usize, 4usize, 3usize, 5usize);
        let g1 = TtCore { r_in: 1, n: n1, r_out: r1, data: rng.normal_vec(n1 * r1) };
        let g2 = TtCore { r_in: r1, n: n2, r_out: r2, data: rng.normal_vec(r1 * n2 * r2) };
        let g3 = TtCore { r_in: r2, n: n3, r_out: 1, data: rng.normal_vec(r2 * n3) };
        let d = TtDecomp {
            dims: vec![n1, n2, n3],
            ranks: vec![1, r1, r2, 1],
            cores: vec![g1.clone(), g2.clone(), g3.clone()],
            eps: 0.0,
        };
        let got = reconstruct(&d);
        // manual einsum aib,bjc,ck -> ijk
        for i in 0..n1 {
            for j in 0..n2 {
                for k in 0..n3 {
                    let mut want = 0.0f32;
                    for b in 0..r1 {
                        for c in 0..r2 {
                            want += g1.data[i * r1 + b]
                                * g2.data[b * n2 * r2 + j * r2 + c]
                                * g3.data[c * n3 + k];
                        }
                    }
                    let got_v = got.data[(i * n2 + j) * n3 + k];
                    assert!((got_v - want).abs() < 1e-4, "({i},{j},{k})");
                }
            }
        }
    }

    #[test]
    fn roundtrip_error_metric() {
        let mut rng = Rng::new(91);
        let w = Tensor::from_vec(&[4, 5, 6], rng.normal_vec(120));
        let d = decompose(&w, &TtSpec::eps(0.0), &mut NullSink);
        assert!(relative_error(&w, &d) < 1e-4);
    }

    #[test]
    fn two_core_decomposition_is_matrix_factorization() {
        let mut rng = Rng::new(92);
        let w = Tensor::from_vec(&[6, 9], rng.normal_vec(54));
        let d = decompose(&w, &TtSpec::eps(0.0), &mut NullSink);
        assert_eq!(d.cores.len(), 2);
        assert!(relative_error(&w, &d) < 1e-4);
    }

    #[test]
    fn four_core_roundtrip() {
        let mut rng = Rng::new(93);
        let w = Tensor::from_vec(&[3, 4, 4, 5], rng.normal_vec(240));
        let d = decompose(&w, &TtSpec::eps(0.0), &mut NullSink);
        assert_eq!(d.cores.len(), 4);
        assert!(relative_error(&w, &d) < 2e-4);
    }
}
