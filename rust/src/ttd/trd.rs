//! Tensor-Ring Decomposition (TR-SVD, Zhao et al.) — the Table-I
//! baseline [13].
//!
//! TR generalizes TT by closing the chain: `r_0 = r_N > 1`, and
//! `W(i_1..i_N) = Tr(G_1[i_1] G_2[i_2] ... G_N[i_N])`. TR-SVD performs
//! a first SVD whose rank is *split* between the two boundary bonds,
//! then proceeds TT-style with the first boundary rank folded into the
//! trailing dimension so the last core closes the ring.

use crate::trace::{NullSink, TraceSink};
use crate::ttd::svd::svd;
use crate::ttd::tensor::{Matrix, Tensor};
use crate::ttd::ttd::{delta_truncation, sorting_basis, TtCore};

#[derive(Clone, Debug)]
pub struct TrDecomp {
    pub dims: Vec<usize>,
    /// Bond ranks `r_0..r_N` with `r_0 == r_N` (the ring closure).
    pub ranks: Vec<usize>,
    /// Cores `G_k` of shape `(r_{k-1}, n_k, r_k)`.
    pub cores: Vec<TtCore>,
    pub eps: f32,
}

impl TrDecomp {
    pub fn param_count(&self) -> usize {
        self.cores.iter().map(|c| c.numel()).sum()
    }

    pub fn dense_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn compression_ratio(&self) -> f64 {
        self.dense_count() as f64 / self.param_count() as f64
    }
}

/// Split `r` into a balanced factor pair `(a, b)`, `a*b == r`, `a <= b`,
/// `a` as close to `sqrt(r)` as possible (Zhao's boundary-rank split).
pub fn balanced_split(r: usize) -> (usize, usize) {
    let mut best = (1, r);
    let mut a = 1;
    while a * a <= r {
        if r % a == 0 {
            best = (a, r / a);
        }
        a += 1;
    }
    best
}

/// TR boundary split with a *genuine* ring: `r0 >= 2` whenever
/// `r >= 2`, rounding the total rank up to `r0 * r1` (the extra
/// columns are zero-padded). Degenerating to `r0 = 1` would just be
/// TT, which defeats the ring structure TR-SVD is defined by — this
/// rounding is also why TR trails TT in compression ratio at equal
/// accuracy (paper Table I: 2.7x vs 3.4x).
pub fn ring_split(r: usize) -> (usize, usize) {
    if r < 2 {
        return (1, 1);
    }
    let r0 = ((r as f64).sqrt().floor() as usize).max(2);
    let r1 = r.div_ceil(r0);
    (r0, r1)
}

/// TR-SVD with prescribed accuracy `eps`.
pub fn decompose(w: &Tensor, eps: f32) -> TrDecomp {
    decompose_traced(w, eps, &mut NullSink)
}

pub fn decompose_traced<S: TraceSink>(w: &Tensor, eps: f32, sink: &mut S) -> TrDecomp {
    let dims = w.shape.clone();
    let nd = dims.len();
    assert!(nd >= 2);
    let delta = eps / ((nd - 1) as f32).sqrt() * w.frobenius();

    // ---- First step: SVD of the mode-1 unfolding, split the rank.
    let n1 = dims[0];
    let rest: usize = w.numel() / n1;
    let mat = Matrix::from_vec(n1, rest, w.data.clone());
    let mut s = svd(&mat, sink);
    sorting_basis(&mut s, sink);
    let mut r1_total = delta_truncation(&s.sigma, delta, usize::MAX, sink);
    if r1_total < 2 {
        r1_total = 2.min(s.sigma.len()).max(1);
    }
    // Boundary split with a genuine ring (r0 >= 2); the padded total
    // rank is r0*r1 >= r1_total, extra columns exactly zero.
    let (r0, r1) = ring_split(r1_total);
    let k_pad = r0 * r1;

    // G_1: U (n1, k_pad) -> cores (r0, n1, r1): G_1[a, i, b] = U[i, a*r1+b]
    let u_col = |i: usize, c: usize| -> f32 {
        if c < r1_total {
            s.u.get(i, c)
        } else {
            0.0
        }
    };
    let mut g1 = vec![0.0f32; r0 * n1 * r1];
    for i in 0..n1 {
        for a in 0..r0 {
            for b in 0..r1 {
                g1[(a * n1 + i) * r1 + b] = u_col(i, a * r1 + b);
            }
        }
    }

    // Remainder M = Sigma_t V_t^T with rows indexed by (a, b): shape
    // (k_pad, n2..nN), rows >= r1_total zero. Fold r0 into the
    // trailing dim -> working tensor (r1, n2, .., nN, r0).
    let mut m = Matrix::zeros(k_pad, rest);
    for row in 0..r1_total.min(k_pad) {
        let sv = s.sigma[row];
        for c in 0..rest {
            m.set(row, c, sv * s.vt.get(row, c));
        }
    }
    // working buffer indexed (b, j, a) where j in [0, rest)
    let mut work = vec![0.0f32; r1 * rest * r0];
    for a in 0..r0 {
        for b in 0..r1 {
            let src = a * r1 + b;
            for j in 0..rest {
                work[(b * rest + j) * r0 + a] = m.get(src, j);
            }
        }
    }

    // ---- TT sweep over modes 2..N with r0 glued to the last dim.
    let mut ranks = vec![0usize; nd + 1];
    ranks[0] = r0;
    ranks[1] = r1;
    ranks[nd] = r0;
    let mut cores = vec![TtCore { r_in: r0, n: n1, r_out: r1, data: g1 }];
    let mut cur_rows = r1; // r_{k-1}
    let mut cur_rest = rest * r0; // includes trailing r0
    let mut buf = work;

    for kk in 1..nd - 1 {
        let nk = dims[kk];
        let rows = cur_rows * nk;
        let cols = cur_rest / nk;
        let mat = Matrix::from_vec(rows, cols, buf.clone());
        let mut s = svd(&mat, sink);
        sorting_basis(&mut s, sink);
        let r = delta_truncation(&s.sigma, delta, usize::MAX, sink);
        let mut core = vec![0.0f32; cur_rows * nk * r];
        for row in 0..rows {
            for c in 0..r {
                core[row * r + c] = s.u.get(row, c);
            }
        }
        cores.push(TtCore { r_in: cur_rows, n: nk, r_out: r, data: core });
        ranks[kk + 1] = r;
        let mut next = vec![0.0f32; r * cols];
        for row in 0..r {
            let sv = s.sigma[row];
            for c in 0..cols {
                next[row * cols + c] = sv * s.vt.get(row, c);
            }
        }
        buf = next;
        cur_rows = r;
        cur_rest = cols;
    }

    // ---- Last core: (r_{N-1}, n_N, r0) — fold the glued r0 back.
    let n_last = dims[nd - 1];
    assert_eq!(cur_rest, n_last * r0);
    cores.push(TtCore { r_in: cur_rows, n: n_last, r_out: r0, data: buf });

    TrDecomp { dims, ranks, cores, eps }
}

/// Ring contraction: `W(i..) = Tr(G_1[i_1] .. G_N[i_N])`.
pub fn reconstruct(d: &TrDecomp) -> Tensor {
    let r0 = d.cores[0].r_in;
    // acc: ([n_1..n_k], r0 * r_k) — keep the open boundary index a.
    let first = &d.cores[0];
    // acc[a, i, b] -> row (i), col (a, b)
    let mut acc = Matrix::zeros(first.n, r0 * first.r_out);
    for a in 0..r0 {
        for i in 0..first.n {
            for b in 0..first.r_out {
                acc.set(i, a * first.r_out + b, first.data[(a * first.n + i) * first.r_out + b]);
            }
        }
    }
    let mut prod_dims = vec![first.n];
    for core in &d.cores[1..] {
        let (rk, nk, rk1) = (core.r_in, core.n, core.r_out);
        // acc ([I], r0*rk) x core (rk, nk*rk1) -> ([I], r0, nk, rk1)
        let rows = acc.rows;
        let mut next = Matrix::zeros(rows * nk, r0 * rk1);
        let right = core.as_matrix_right(); // (rk, nk*rk1)
        for i in 0..rows {
            for a in 0..r0 {
                for j in 0..nk {
                    for b in 0..rk1 {
                        let mut s = 0.0f32;
                        for c in 0..rk {
                            s += acc.get(i, a * rk + c) * right.get(c, j * rk1 + b);
                        }
                        next.set(i * nk + j, a * rk1 + b, s);
                    }
                }
            }
        }
        acc = next;
        prod_dims.push(nk);
    }
    // close the ring: trace over (a, a)
    let total: usize = prod_dims.iter().product();
    let r_last = d.cores.last().unwrap().r_out;
    assert_eq!(r_last, r0);
    let mut out = vec![0.0f32; total];
    for (i, o) in out.iter_mut().enumerate() {
        let mut s = 0.0f32;
        for a in 0..r0 {
            s += acc.get(i, a * r0 + a);
        }
        *o = s;
    }
    Tensor::from_vec(&d.dims, out)
}

pub fn relative_error(original: &Tensor, d: &TrDecomp) -> f32 {
    crate::ttd::reconstruct::rel_error_to(original, &reconstruct(d))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::util::Rng;

    #[test]
    fn balanced_split_properties() {
        assert_eq!(balanced_split(1), (1, 1));
        assert_eq!(balanced_split(6), (2, 3));
        assert_eq!(balanced_split(9), (3, 3));
        assert_eq!(balanced_split(7), (1, 7)); // prime
        for r in 1..50usize {
            let (a, b) = balanced_split(r);
            assert_eq!(a * b, r);
            assert!(a <= b);
        }
    }

    #[test]
    fn near_exact_at_tiny_eps() {
        check(6, 900, |rng| {
            let shape = [3 + rng.below(3), 3 + rng.below(4), 3 + rng.below(4)];
            let w = Tensor::from_vec(&shape, rng.normal_vec(shape.iter().product()));
            let d = decompose(&w, 1e-4);
            let err = relative_error(&w, &d);
            assert!(err < 1e-2, "err {err}");
        });
    }

    #[test]
    fn error_tracks_eps_budget() {
        let mut rng = Rng::new(97);
        let w = Tensor::from_vec(&[4, 6, 6], rng.normal_vec(144));
        let e1 = relative_error(&w, &decompose(&w, 0.05));
        let e2 = relative_error(&w, &decompose(&w, 0.5));
        assert!(e1 <= e2 + 1e-4, "{e1} vs {e2}");
        // loose budget must stay within a usable bound for Table-I use
        assert!(e2 < 0.9);
    }

    #[test]
    fn ring_closure_ranks() {
        let mut rng = Rng::new(98);
        let w = Tensor::from_vec(&[4, 5, 6], rng.normal_vec(120));
        let d = decompose(&w, 0.1);
        assert_eq!(d.cores.first().unwrap().r_in, d.cores.last().unwrap().r_out);
        for (k, c) in d.cores.iter().enumerate() {
            assert_eq!(c.n, d.dims[k]);
        }
        // chain consistency
        for w2 in d.cores.windows(2) {
            assert_eq!(w2[0].r_out, w2[1].r_in);
        }
    }

    #[test]
    fn param_count_sums_cores() {
        let mut rng = Rng::new(99);
        let w = Tensor::from_vec(&[4, 4, 4], rng.normal_vec(64));
        let d = decompose(&w, 0.2);
        let manual: usize = d.cores.iter().map(|c| c.r_in * c.n * c.r_out).sum();
        assert_eq!(d.param_count(), manual);
    }
}
