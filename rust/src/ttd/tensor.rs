//! Dense row-major tensors and matrices — the numeric substrate under
//! Algorithm 1/2. No BLAS in this environment: `matmul` is a
//! cache-blocked ikj kernel (see `benches/hotpath.rs` for its tuning).

use std::fmt;

/// Row-major 2-D matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Self { rows, cols, data }
    }

    /// Rectangular identity (ones on the main diagonal).
    pub fn eye(rows: usize, cols: usize) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows.min(cols) {
            m.data[i * cols + i] = 1.0;
        }
        m
    }

    #[inline(always)]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline(always)]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// `self @ other`, cache-blocked ikj with f32 accumulation.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        const BK: usize = 128;
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out.data[i * n..(i + 1) * n];
                // k-unrolled by 2: the compiler keeps two FMA chains in
                // flight, hiding the accumulator dependency (measured
                // +25% over the single-chain loop; see EXPERIMENTS §Perf).
                let mut kk = k0;
                while kk + 1 < k1 {
                    let a0 = arow[kk];
                    let a1 = arow[kk + 1];
                    let b0 = &other.data[kk * n..kk * n + n];
                    let b1 = &other.data[(kk + 1) * n..(kk + 1) * n + n];
                    for ((o, x), y) in orow.iter_mut().zip(b0).zip(b1) {
                        *o += a0 * x + a1 * y;
                    }
                    kk += 2;
                }
                if kk < k1 {
                    let a = arow[kk];
                    let brow = &other.data[kk * n..kk * n + n];
                    for (o, b) in orow.iter_mut().zip(brow) {
                        *o += a * b;
                    }
                }
            }
        }
        out
    }

    /// `self @ other^T` (row-times-row dot products, cache-friendly).
    pub fn matmul_transb(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_transb dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &other.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (a, b) in arow.iter().zip(brow) {
                    acc += a * b;
                }
                out.data[i * n + j] = acc;
            }
        }
        out
    }

    /// Submatrix copy `[r0..r1) x [c0..c1)`.
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for r in r0..r1 {
            out.data[(r - r0) * (c1 - c0)..(r - r0 + 1) * (c1 - c0)]
                .copy_from_slice(&self.data[r * self.cols + c0..r * self.cols + c1]);
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Dense N-dimensional tensor, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Row-major reshape (element order preserved — Alg. 1 Reshape).
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.numel(),
            "reshape numel mismatch: {:?} -> {:?}",
            self.shape,
            shape
        );
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    pub fn to_matrix(&self, rows: usize, cols: usize) -> Matrix {
        assert_eq!(rows * cols, self.numel());
        Matrix::from_vec(rows, cols, self.data.clone())
    }

    pub fn from_matrix(m: &Matrix, shape: &[usize]) -> Tensor {
        Tensor::from_vec(shape, m.data.clone())
    }

    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Mode-k unfolding: rows indexed by dim k, columns by the
    /// remaining dims in row-major order (Tucker/HOSVD convention).
    pub fn unfold(&self, mode: usize) -> Matrix {
        let nk = self.shape[mode];
        let rest: usize = self.numel() / nk;
        let mut out = Matrix::zeros(nk, rest);
        let strides = row_major_strides(&self.shape);
        let mut idx = vec![0usize; self.shape.len()];
        for (flat, &v) in self.data.iter().enumerate() {
            // decode flat -> multi-index
            let mut rem = flat;
            for (d, s) in strides.iter().enumerate() {
                idx[d] = rem / s;
                rem %= s;
            }
            let r = idx[mode];
            // column index: remaining dims, row-major
            let mut c = 0usize;
            for d in 0..self.shape.len() {
                if d != mode {
                    c = c * self.shape[d] + idx[d];
                }
            }
            out.set(r, c, v);
        }
        out
    }

    /// Inverse of [`Tensor::unfold`].
    pub fn fold(m: &Matrix, mode: usize, shape: &[usize]) -> Tensor {
        let mut out = Tensor::zeros(shape);
        let strides = row_major_strides(shape);
        let mut idx = vec![0usize; shape.len()];
        for flat in 0..out.data.len() {
            let mut rem = flat;
            for (d, s) in strides.iter().enumerate() {
                idx[d] = rem / s;
                rem %= s;
            }
            let r = idx[mode];
            let mut c = 0usize;
            for d in 0..shape.len() {
                if d != mode {
                    c = c * shape[d] + idx[d];
                }
            }
            out.data[flat] = m.get(r, c);
        }
        out
    }

    /// Mode-k product: replace dim k by `u.rows`, contracting with
    /// `u` (rows_new x n_k).
    pub fn mode_product(&self, mode: usize, u: &Matrix) -> Tensor {
        assert_eq!(u.cols, self.shape[mode]);
        let unf = self.unfold(mode);
        let prod = u.matmul(&unf);
        let mut new_shape = self.shape.clone();
        new_shape[mode] = u.rows;
        Tensor::fold(&prod, mode, &new_shape)
    }

    /// Dimension permutation (generalized transpose).
    pub fn permute(&self, perm: &[usize]) -> Tensor {
        assert_eq!(perm.len(), self.shape.len());
        let new_shape: Vec<usize> = perm.iter().map(|&p| self.shape[p]).collect();
        let mut out = Tensor::zeros(&new_shape);
        let old_strides = row_major_strides(&self.shape);
        let new_strides = row_major_strides(&new_shape);
        let mut idx = vec![0usize; self.shape.len()];
        for (flat, &v) in self.data.iter().enumerate() {
            let mut rem = flat;
            for (d, s) in old_strides.iter().enumerate() {
                idx[d] = rem / s;
                rem %= s;
            }
            let mut nf = 0usize;
            for (nd, &od) in perm.iter().enumerate() {
                nf += idx[od] * new_strides[nd];
            }
            out.data[nf] = v;
        }
        out
    }
}

fn row_major_strides(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; shape.len()];
    for d in (0..shape.len().saturating_sub(1)).rev() {
        s[d] = s[d + 1] * shape[d + 1];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::util::Rng;

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_vec(r, c, rng.normal_vec(r * c))
    }

    #[test]
    fn matmul_matches_naive() {
        check(20, 100, |rng| {
            let (m, k, n) = (1 + rng.below(40), 1 + rng.below(40), 1 + rng.below(40));
            let a = rand_mat(rng, m, k);
            let b = rand_mat(rng, k, n);
            let got = a.matmul(&b);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..k).map(|kk| a.get(i, kk) * b.get(kk, j)).sum();
                    assert!((got.get(i, j) - want).abs() < 1e-3, "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn matmul_transb_matches_matmul() {
        check(10, 101, |rng| {
            let (m, k, n) = (1 + rng.below(30), 1 + rng.below(30), 1 + rng.below(30));
            let a = rand_mat(rng, m, k);
            let b = rand_mat(rng, n, k);
            let got = a.matmul_transb(&b);
            let want = a.matmul(&b.transpose());
            assert!(got.max_abs_diff(&want) < 1e-4);
        });
    }

    #[test]
    fn transpose_involution() {
        check(10, 102, |rng| {
            let (r, c) = (1 + rng.below(20), 1 + rng.below(20));
            let a = rand_mat(rng, r, c);
            assert_eq!(a.transpose().transpose(), a);
        });
    }

    #[test]
    fn eye_is_identity_under_matmul() {
        let mut rng = Rng::new(5);
        let a = rand_mat(&mut rng, 7, 7);
        assert!(a.matmul(&Matrix::eye(7, 7)).max_abs_diff(&a) < 1e-6);
        assert!(Matrix::eye(7, 7).matmul(&a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn reshape_preserves_order() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        let r = t.reshape(&[3, 2]);
        assert_eq!(r.data, t.data);
        assert_eq!(r.shape, vec![3, 2]);
    }

    #[test]
    #[should_panic(expected = "numel mismatch")]
    fn reshape_rejects_bad_numel() {
        Tensor::zeros(&[2, 3]).reshape(&[4, 2]);
    }

    #[test]
    fn unfold_fold_roundtrip() {
        check(10, 103, |rng| {
            let shape = [1 + rng.below(5), 1 + rng.below(5), 1 + rng.below(5)];
            let t = Tensor::from_vec(&shape, rng.normal_vec(shape.iter().product()));
            for mode in 0..3 {
                let unf = t.unfold(mode);
                assert_eq!(unf.rows, shape[mode]);
                let back = Tensor::fold(&unf, mode, &shape);
                assert_eq!(back, t);
            }
        });
    }

    #[test]
    fn unfold_mode0_is_plain_reshape() {
        let t = Tensor::from_vec(&[2, 3, 4], (0..24).map(|x| x as f32).collect());
        let unf = t.unfold(0);
        assert_eq!(unf.data, t.data);
    }

    #[test]
    fn mode_product_shrinks_dim() {
        let mut rng = Rng::new(9);
        let t = Tensor::from_vec(&[4, 5, 6], rng.normal_vec(120));
        let u = rand_mat(&mut rng, 2, 5);
        let p = t.mode_product(1, &u);
        assert_eq!(p.shape, vec![4, 2, 6]);
    }

    #[test]
    fn permute_roundtrip_and_shape() {
        let mut rng = Rng::new(10);
        let t = Tensor::from_vec(&[2, 3, 4], rng.normal_vec(24));
        let p = t.permute(&[2, 0, 1]);
        assert_eq!(p.shape, vec![4, 2, 3]);
        let back = p.permute(&[1, 2, 0]);
        assert_eq!(back, t);
    }

    #[test]
    fn permute_matches_manual_transpose() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        let p = t.permute(&[1, 0]);
        let m = t.to_matrix(2, 3).transpose();
        assert_eq!(p.data, m.data);
    }

    #[test]
    fn frobenius_matches_manual() {
        let t = Tensor::from_vec(&[2, 2], vec![3.0, 4.0, 0.0, 0.0]);
        assert!((t.frobenius() - 5.0).abs() < 1e-6);
    }
}
