//! Dense row-major tensors and matrices — the numeric substrate under
//! Algorithm 1/2. No BLAS in this environment: every GEMM funnels
//! through one process-selectable microkernel pair (see
//! [`GemmKernel`]) — the cache-blocked scalar [`matmul_reference`]
//! and the lanes-of-f32 register-tiled [`matmul_vectorized`]. The two
//! are **bit-identical by construction** (same k-pairing, same
//! `a0 * x + a1 * y` association per output element; the vectorized
//! kernel only reorders *independent* output columns into register
//! tiles), which is what keeps the op stream and every downstream
//! Table-III pin byte-identical no matter which kernel runs. See
//! `benches/hotpath.rs` for the tuning numbers and `matmul_naive` for
//! the unblocked baseline both are measured against. The Householder
//! rank-1 updates (`apply_house_left` / `apply_house_right`) live here
//! as in-place `Matrix` methods — the HBD hot loop never materializes
//! a reflector matrix or clones the working buffer.

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};

/// Row-major 2-D matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Self { rows, cols, data }
    }

    /// Rectangular identity (ones on the main diagonal).
    pub fn eye(rows: usize, cols: usize) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows.min(cols) {
            m.data[i * cols + i] = 1.0;
        }
        m
    }

    #[inline(always)]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        // lint: allow(hard-assert-dispatch-guards): per-element accessor inside O(mkn) loops, not a dispatch guard — the slice index below hard-panics on OOB either way
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        // lint: allow(hard-assert-dispatch-guards): per-element accessor inside O(mkn) loops, not a dispatch guard — the slice index below hard-panics on OOB either way
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline(always)]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    /// Cache-blocked transpose: both the row-major read and the
    /// column-strided write stay inside one `TB x TB` tile, so the
    /// write stream touches at most `TB` distinct cache lines at a
    /// time instead of `rows` (the naive strided loop thrashed on the
    /// wide-SVD hot path, where every wide input round-trips through
    /// `transpose`).
    pub fn transpose(&self) -> Matrix {
        const TB: usize = 32;
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r0 in (0..self.rows).step_by(TB) {
            let r1 = (r0 + TB).min(self.rows);
            for c0 in (0..self.cols).step_by(TB) {
                let c1 = (c0 + TB).min(self.cols);
                for r in r0..r1 {
                    for c in c0..c1 {
                        t.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        t
    }

    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// `self @ other`, cache-blocked ikj with f32 accumulation.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        matmul_kernel(m, k, n, &self.data, &other.data, &mut out.data);
        out
    }

    /// `self @ view` for a borrowed right-hand side (e.g. a TT core
    /// viewed as a matrix) — same blocked kernel, no operand clone.
    pub fn matmul_view(&self, other: &MatrixView<'_>) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul_view dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        matmul_kernel(m, k, n, &self.data, other.data, &mut out.data);
        out
    }

    /// Textbook ijk triple loop — the unblocked reference the blocked
    /// kernel is benchmarked against (`benches/hotpath.rs`). Kept out
    /// of every hot path.
    pub fn matmul_naive(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += self.data[i * k + kk] * other.data[kk * n + j];
                }
                out.data[i * n + j] = acc;
            }
        }
        out
    }

    /// In-place left Householder rank-1 update on the subblock
    /// `self[r0.., c0..]`: `A <- A + (v/beta)(v^T A)` with
    /// `v.len() == rows - r0`. `scratch` must hold `cols - c0` slots;
    /// callers in the HBD loop reuse one buffer across all columns so
    /// the hot path performs zero allocations.
    pub fn apply_house_left(&mut self, r0: usize, c0: usize, v: &[f32], beta: f32, scratch: &mut [f32]) {
        // lint: hotpath
        if v.is_empty() {
            return;
        }
        // Hard assert: this is a kernel entry-path size guard (the
        // PR-7 bug class) — a wrong v length in release would read
        // the wrong logical rows, O(1) cost next to the O(mn) body.
        assert_eq!(v.len(), self.rows - r0);
        let cols = self.cols;
        let width = cols - c0;
        let w = &mut scratch[..width];
        w.fill(0.0);
        // w = v^T A  (first chained GEMM)
        for (i, &vi) in v.iter().enumerate() {
            if vi == 0.0 {
                continue;
            }
            let row = &self.data[(r0 + i) * cols + c0..(r0 + i) * cols + cols];
            for (wj, &ar) in w.iter_mut().zip(row) {
                *wj += vi * ar;
            }
        }
        // A += (v/beta) w  (second chained GEMM, rank-1)
        let inv_beta = 1.0 / beta;
        for (i, &vi) in v.iter().enumerate() {
            let scale = vi * inv_beta;
            if scale == 0.0 {
                continue;
            }
            let row = &mut self.data[(r0 + i) * cols + c0..(r0 + i) * cols + cols];
            for (ar, &wj) in row.iter_mut().zip(w.iter()) {
                *ar += scale * wj;
            }
        }
    }

    /// In-place right Householder rank-1 update on the subblock
    /// `self[r0.., c0..]`: `A <- A + (A v)(v/beta)` with
    /// `v.len() == cols - c0`. Row-at-a-time, no scratch needed.
    pub fn apply_house_right(&mut self, r0: usize, c0: usize, v: &[f32], beta: f32) {
        // lint: hotpath
        if v.is_empty() {
            return;
        }
        // Hard assert: kernel entry-path size guard (the PR-7 bug
        // class), O(1) next to the O(mn) body below.
        assert_eq!(v.len(), self.cols - c0);
        let cols = self.cols;
        let inv_beta = 1.0 / beta;
        for r in r0..self.rows {
            let row = &mut self.data[r * cols + c0..(r + 1) * cols];
            // u_r = A[r, c0..] . v   (first chained GEMM)
            let mut u = 0.0f32;
            for (ar, &vj) in row.iter().zip(v) {
                u += *ar * vj;
            }
            // A[r, c0..] += u * (v/beta)  (second chained GEMM)
            let scale = u * inv_beta;
            if scale != 0.0 {
                for (ar, &vj) in row.iter_mut().zip(v) {
                    *ar += scale * vj;
                }
            }
        }
    }

    /// `self @ other^T` through the shared microkernel: `other` is
    /// packed once via the cache-blocked [`Matrix::transpose`] (an
    /// O(kn) permutation next to the O(mkn) product) so the multiply
    /// itself runs on whichever blocked/vectorized kernel is selected
    /// instead of the old unblocked row-dot loop (kept as
    /// [`Matrix::matmul_transb_reference`] and pinned in tests).
    pub fn matmul_transb(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_transb dim mismatch");
        self.matmul(&other.transpose())
    }

    /// The pre-PR-7 hand-rolled `self @ other^T` (row-times-row dot
    /// products, unblocked) — kept purely as the agreement reference
    /// for [`Matrix::matmul_transb`]; not called from any hot path.
    pub fn matmul_transb_reference(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_transb dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &other.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (a, b) in arow.iter().zip(brow) {
                    acc += a * b;
                }
                out.data[i * n + j] = acc;
            }
        }
        out
    }

    /// Submatrix copy `[r0..r1) x [c0..c1)`.
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for r in r0..r1 {
            out.data[(r - r0) * (c1 - c0)..(r - r0 + 1) * (c1 - c0)]
                .copy_from_slice(&self.data[r * self.cols + c0..r * self.cols + c1]);
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Borrowed row-major matrix view over someone else's storage (e.g. a
/// TT core reinterpreted as its left/right unfolding) — reshapes are
/// free and carry no clone.
#[derive(Clone, Copy)]
pub struct MatrixView<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a [f32],
}

impl fmt::Debug for MatrixView<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "MatrixView({}x{})", self.rows, self.cols)
    }
}

impl<'a> MatrixView<'a> {
    pub fn new(rows: usize, cols: usize, data: &'a [f32]) -> Self {
        assert_eq!(data.len(), rows * cols, "view length mismatch");
        Self { rows, cols, data }
    }

    #[inline(always)]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        // lint: allow(hard-assert-dispatch-guards): per-element accessor, not a dispatch guard — the slice index below hard-panics on OOB either way
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn row(&self, r: usize) -> &'a [f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Materialize an owned copy (only when ownership is truly needed).
    pub fn to_matrix(&self) -> Matrix {
        Matrix::from_vec(self.rows, self.cols, self.data.to_vec())
    }
}

/// Which GEMM microkernel every `matmul`/`matmul_acc` call dispatches
/// to. Both kernels compute every output element with the *same*
/// f32 operation sequence (same k-pairing, same association), so the
/// selection is purely a host-speed knob: results — and therefore the
/// op stream, golden traces, and Table-III pins — are bit-identical
/// either way. Pinned by `tests/kernel_equivalence.rs`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmKernel {
    /// The cache-blocked scalar ikj kernel ([`matmul_reference`]).
    Reference,
    /// The lanes-of-f32 register-tiled kernel ([`matmul_vectorized`]).
    Vectorized,
}

// Process-global kernel selection: 0 = unresolved (read the
// TTEDGE_KERNEL env var on first use), then the encoded GemmKernel.
// Relaxed ordering is enough — both kernels are bit-identical, so a
// racing reader picking the stale kernel cannot change any result.
static GEMM_KERNEL: AtomicU8 = AtomicU8::new(0);
const KERNEL_REFERENCE: u8 = 1;
const KERNEL_VECTORIZED: u8 = 2;

/// The currently selected microkernel. Defaults to
/// [`GemmKernel::Vectorized`] unless the `TTEDGE_KERNEL` env var says
/// `reference`/`scalar` (how CI's kernel-matrix job forces the scalar
/// path through an unmodified test suite).
pub fn gemm_kernel() -> GemmKernel {
    match GEMM_KERNEL.load(Ordering::Relaxed) {
        KERNEL_REFERENCE => GemmKernel::Reference,
        KERNEL_VECTORIZED => GemmKernel::Vectorized,
        _ => {
            let kernel = match std::env::var("TTEDGE_KERNEL").as_deref() {
                Ok("reference") | Ok("scalar") => GemmKernel::Reference,
                _ => GemmKernel::Vectorized,
            };
            set_gemm_kernel(kernel);
            kernel
        }
    }
}

/// Select the process-wide microkernel (see [`GemmKernel`]; jobs set
/// this through `CompressionJob::kernel`).
pub fn set_gemm_kernel(kernel: GemmKernel) {
    let enc = match kernel {
        GemmKernel::Reference => KERNEL_REFERENCE,
        GemmKernel::Vectorized => KERNEL_VECTORIZED,
    };
    GEMM_KERNEL.store(enc, Ordering::Relaxed);
}

/// `out += a @ b` over raw row-major slices through the selected
/// kernel — the accumulate form the blocked compact-WY Householder
/// panels in [`crate::ttd::svd::bidiag`] build on (`out` may be a
/// row-contiguous sub-slice of a larger matrix). `out` must hold at
/// least `m * n` leading slots; real `assert!`s, because a release
/// caller with a miscomputed `(m, k, n)` would otherwise read the
/// wrong logical region and produce silently wrong panels (the cost
/// is negligible next to the O(mkn) body).
pub fn matmul_acc(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    assert!(
        a.len() >= m * k && b.len() >= k * n && out.len() >= m * n,
        "matmul_acc size mismatch: a {} < {}x{} or b {} < {}x{} or out {} < {}x{}",
        a.len(),
        m,
        k,
        b.len(),
        k,
        n,
        out.len(),
        m,
        n
    );
    matmul_kernel(m, k, n, a, b, out);
}

/// Dispatch to the selected microkernel (see [`GemmKernel`]).
fn matmul_kernel(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    match gemm_kernel() {
        GemmKernel::Reference => matmul_reference(m, k, n, a, b, out),
        GemmKernel::Vectorized => matmul_vectorized(m, k, n, a, b, out),
    }
}

/// Cache-blocked scalar ikj kernel over raw row-major slices:
/// `out += a @ b` with `a` (m x k), `b` (k x n), `out` (m x n).
///
/// This is the arithmetic contract both kernels implement: k advances
/// in pairs `(0,1), (2,3), ...` (the k-block size `BK` is even, so the
/// pairing is global across blocks, with one unpaired remainder iff k
/// is odd), and each output element accumulates
/// `o += a0 * x + a1 * y` per pair. [`matmul_vectorized`] keeps this
/// exact per-element sequence and only tiles *independent* outputs.
pub fn matmul_reference(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    // lint: hotpath
    const BK: usize = 128;
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            // k-unrolled by 2: the compiler keeps two FMA chains in
            // flight, hiding the accumulator dependency (measured
            // +25% over the single-chain loop; see EXPERIMENTS §Perf).
            let mut kk = k0;
            while kk + 1 < k1 {
                let a0 = arow[kk];
                let a1 = arow[kk + 1];
                let b0 = &b[kk * n..kk * n + n];
                let b1 = &b[(kk + 1) * n..(kk + 1) * n + n];
                for ((o, x), y) in orow.iter_mut().zip(b0).zip(b1) {
                    *o += a0 * x + a1 * y;
                }
                kk += 2;
            }
            if kk < k1 {
                let a0 = arow[kk];
                let brow = &b[kk * n..kk * n + n];
                for (o, bv) in orow.iter_mut().zip(brow) {
                    *o += a0 * bv;
                }
            }
        }
    }
}

/// f32 lanes per accumulator vector in [`matmul_vectorized`] (one
/// 256-bit register's worth; fixed-length `[f32; GEMM_LANES]` loops
/// are what the compiler turns into packed SIMD).
pub const GEMM_LANES: usize = 8;
/// Rows per register tile.
const GEMM_MR: usize = 4;
/// Columns per register tile (two lane-vectors wide).
const GEMM_NR: usize = 2 * GEMM_LANES;

/// Explicitly vectorized microkernel: `out += a @ b`, bit-identical
/// to [`matmul_reference`].
///
/// The output is walked in `GEMM_MR x GEMM_NR` register tiles (4 rows
/// x 2 lane-vectors of [`GEMM_LANES`] f32). Each tile's accumulators
/// live in registers for the whole k loop — the scalar kernel instead
/// re-streams the full output row through memory once per k-pair,
/// which is where the speedup comes from. Bit-identity holds because
/// every output element still sees the reference's exact operation
/// sequence (`acc += a0 * x + a1 * y` over the same global k-pairs;
/// Rust f32 math is strict IEEE — never reassociated, no implicit FMA
/// contraction) — lanes only batch *independent* columns.
pub fn matmul_vectorized(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    // lint: hotpath
    let nv = n - n % GEMM_NR;
    let mut i = 0;
    while i + GEMM_MR <= m {
        vec_row_tile::<GEMM_MR>(i, k, n, nv, a, b, out);
        i += GEMM_MR;
    }
    while i < m {
        vec_row_tile::<1>(i, k, n, nv, a, b, out);
        i += 1;
    }
}

/// One `R`-row band of the vectorized kernel: register tiles across
/// the `nv` lane-aligned columns, then the scalar column tail
/// (`nv..n`) with the same k-pairing.
#[inline(always)]
fn vec_row_tile<const R: usize>(
    i: usize,
    k: usize,
    n: usize,
    nv: usize,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
) {
    // lint: hotpath
    const L: usize = GEMM_LANES;
    let mut j = 0;
    while j < nv {
        // R x (2 lane-vectors) accumulator tile, loaded from out once.
        let mut acc = [[[0.0f32; L]; 2]; R];
        for (r, tile) in acc.iter_mut().enumerate() {
            let orow = &out[(i + r) * n + j..];
            for (h, lane) in tile.iter_mut().enumerate() {
                lane.copy_from_slice(&orow[h * L..h * L + L]);
            }
        }
        let mut kk = 0;
        while kk + 1 < k {
            let b0 = &b[kk * n + j..kk * n + j + GEMM_NR];
            let b1 = &b[(kk + 1) * n + j..(kk + 1) * n + j + GEMM_NR];
            for (r, tile) in acc.iter_mut().enumerate() {
                let a0 = a[(i + r) * k + kk];
                let a1 = a[(i + r) * k + kk + 1];
                for (h, lane) in tile.iter_mut().enumerate() {
                    for (l, slot) in lane.iter_mut().enumerate() {
                        *slot += a0 * b0[h * L + l] + a1 * b1[h * L + l];
                    }
                }
            }
            kk += 2;
        }
        if kk < k {
            let b0 = &b[kk * n + j..kk * n + j + GEMM_NR];
            for (r, tile) in acc.iter_mut().enumerate() {
                let a0 = a[(i + r) * k + kk];
                for (h, lane) in tile.iter_mut().enumerate() {
                    for (l, slot) in lane.iter_mut().enumerate() {
                        *slot += a0 * b0[h * L + l];
                    }
                }
            }
        }
        for (r, tile) in acc.iter().enumerate() {
            let orow = &mut out[(i + r) * n + j..];
            for (h, lane) in tile.iter().enumerate() {
                orow[h * L..h * L + L].copy_from_slice(lane);
            }
        }
        j += GEMM_NR;
    }
    // Scalar tail columns: identical pairing and association, one
    // register accumulator per element.
    for r in 0..R {
        let arow = &a[(i + r) * k..(i + r) * k + k];
        for col in nv..n {
            let mut acc = out[(i + r) * n + col];
            let mut kk = 0;
            while kk + 1 < k {
                acc += arow[kk] * b[kk * n + col] + arow[kk + 1] * b[(kk + 1) * n + col];
                kk += 2;
            }
            if kk < k {
                acc += arow[kk] * b[kk * n + col];
            }
            out[(i + r) * n + col] = acc;
        }
    }
}

/// Dense N-dimensional tensor, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Row-major reshape (element order preserved — Alg. 1 Reshape).
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.numel(),
            "reshape numel mismatch: {:?} -> {:?}",
            self.shape,
            shape
        );
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    pub fn to_matrix(&self, rows: usize, cols: usize) -> Matrix {
        assert_eq!(rows * cols, self.numel());
        Matrix::from_vec(rows, cols, self.data.clone())
    }

    pub fn from_matrix(m: &Matrix, shape: &[usize]) -> Tensor {
        Tensor::from_vec(shape, m.data.clone())
    }

    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Mode-k unfolding: rows indexed by dim k, columns by the
    /// remaining dims in row-major order (Tucker/HOSVD convention).
    pub fn unfold(&self, mode: usize) -> Matrix {
        let nk = self.shape[mode];
        let rest: usize = self.numel() / nk;
        let mut out = Matrix::zeros(nk, rest);
        let strides = row_major_strides(&self.shape);
        let mut idx = vec![0usize; self.shape.len()];
        for (flat, &v) in self.data.iter().enumerate() {
            // decode flat -> multi-index
            let mut rem = flat;
            for (d, s) in strides.iter().enumerate() {
                idx[d] = rem / s;
                rem %= s;
            }
            let r = idx[mode];
            // column index: remaining dims, row-major
            let mut c = 0usize;
            for d in 0..self.shape.len() {
                if d != mode {
                    c = c * self.shape[d] + idx[d];
                }
            }
            out.set(r, c, v);
        }
        out
    }

    /// Inverse of [`Tensor::unfold`].
    pub fn fold(m: &Matrix, mode: usize, shape: &[usize]) -> Tensor {
        let mut out = Tensor::zeros(shape);
        let strides = row_major_strides(shape);
        let mut idx = vec![0usize; shape.len()];
        for flat in 0..out.data.len() {
            let mut rem = flat;
            for (d, s) in strides.iter().enumerate() {
                idx[d] = rem / s;
                rem %= s;
            }
            let r = idx[mode];
            let mut c = 0usize;
            for d in 0..shape.len() {
                if d != mode {
                    c = c * shape[d] + idx[d];
                }
            }
            out.data[flat] = m.get(r, c);
        }
        out
    }

    /// Mode-k product: replace dim k by `u.rows`, contracting with
    /// `u` (rows_new x n_k).
    pub fn mode_product(&self, mode: usize, u: &Matrix) -> Tensor {
        assert_eq!(u.cols, self.shape[mode]);
        let unf = self.unfold(mode);
        let prod = u.matmul(&unf);
        let mut new_shape = self.shape.clone();
        new_shape[mode] = u.rows;
        Tensor::fold(&prod, mode, &new_shape)
    }

    /// Dimension permutation (generalized transpose).
    pub fn permute(&self, perm: &[usize]) -> Tensor {
        assert_eq!(perm.len(), self.shape.len());
        let new_shape: Vec<usize> = perm.iter().map(|&p| self.shape[p]).collect();
        let mut out = Tensor::zeros(&new_shape);
        let old_strides = row_major_strides(&self.shape);
        let new_strides = row_major_strides(&new_shape);
        let mut idx = vec![0usize; self.shape.len()];
        for (flat, &v) in self.data.iter().enumerate() {
            let mut rem = flat;
            for (d, s) in old_strides.iter().enumerate() {
                idx[d] = rem / s;
                rem %= s;
            }
            let mut nf = 0usize;
            for (nd, &od) in perm.iter().enumerate() {
                nf += idx[od] * new_strides[nd];
            }
            out.data[nf] = v;
        }
        out
    }
}

fn row_major_strides(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; shape.len()];
    for d in (0..shape.len().saturating_sub(1)).rev() {
        s[d] = s[d + 1] * shape[d + 1];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::util::Rng;

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_vec(r, c, rng.normal_vec(r * c))
    }

    #[test]
    fn matmul_matches_naive() {
        check(20, 100, |rng| {
            let (m, k, n) = (1 + rng.below(40), 1 + rng.below(40), 1 + rng.below(40));
            let a = rand_mat(rng, m, k);
            let b = rand_mat(rng, k, n);
            let got = a.matmul(&b);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..k).map(|kk| a.get(i, kk) * b.get(kk, j)).sum();
                    assert!((got.get(i, j) - want).abs() < 1e-3, "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn matmul_naive_and_view_match_blocked() {
        check(10, 104, |rng| {
            let (m, k, n) = (1 + rng.below(30), 1 + rng.below(300), 1 + rng.below(30));
            let a = rand_mat(rng, m, k);
            let b = rand_mat(rng, k, n);
            let blocked = a.matmul(&b);
            let naive = a.matmul_naive(&b);
            // summation orders differ; bound scales with sqrt(k)
            let tol = 1e-4 * (k as f32).sqrt().max(1.0) * 10.0;
            assert!(blocked.max_abs_diff(&naive) < tol);
            let view = MatrixView::new(k, n, &b.data);
            let viewed = a.matmul_view(&view);
            assert_eq!(viewed, blocked);
        });
    }

    #[test]
    fn house_updates_match_svd_house_wrappers() {
        use crate::ttd::svd::house::{apply_left, apply_right, house};
        check(10, 105, |rng| {
            let (m, n) = (2 + rng.below(16), 2 + rng.below(16));
            let a0 = rand_mat(rng, m, n);
            let x: Vec<f32> = (0..m).map(|r| a0.get(r, 0)).collect();
            let h = house(&x);
            let mut a = a0.clone();
            let mut b = a0.clone();
            let mut scratch = vec![0.0f32; n];
            a.apply_house_left(0, 0, &h.v, h.beta, &mut scratch);
            apply_left(&mut b, 0, 0, &h.v, h.beta);
            assert_eq!(a, b);

            let y: Vec<f32> = a0.row(0).to_vec();
            let h = house(&y);
            let mut a = a0.clone();
            let mut b = a0;
            a.apply_house_right(0, 0, &h.v, h.beta);
            apply_right(&mut b, 0, 0, &h.v, h.beta);
            assert_eq!(a, b);
        });
    }

    #[test]
    fn matmul_acc_accumulates_into_subslices() {
        check(10, 106, |rng| {
            let (m, k, n) = (1 + rng.below(12), 1 + rng.below(12), 1 + rng.below(12));
            let a = rand_mat(rng, m, k);
            let b = rand_mat(rng, k, n);
            // accumulate into the tail rows of a larger buffer
            let r0 = rng.below(4);
            let mut big = rand_mat(rng, r0 + m, n);
            let before = big.clone();
            let prod = a.matmul(&b);
            matmul_acc(m, k, n, &a.data, &b.data, &mut big.data[r0 * n..]);
            for r in 0..r0 {
                assert_eq!(big.row(r), before.row(r), "head rows untouched");
            }
            for r in 0..m {
                for c in 0..n {
                    let want = before.get(r0 + r, c) + prod.get(r, c);
                    assert!((big.get(r0 + r, c) - want).abs() < 1e-4);
                }
            }
        });
    }

    #[test]
    fn matrix_view_accessors() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let v = MatrixView::new(2, 3, &m.data);
        assert_eq!(v.get(1, 2), 6.0);
        assert_eq!(v.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(v.to_matrix(), m);
        // reinterpret the same storage with another shape — free reshape
        let v2 = MatrixView::new(3, 2, &m.data);
        assert_eq!(v2.get(2, 1), 6.0);
    }

    #[test]
    fn matmul_transb_matches_matmul() {
        check(10, 101, |rng| {
            let (m, k, n) = (1 + rng.below(30), 1 + rng.below(30), 1 + rng.below(30));
            let a = rand_mat(rng, m, k);
            let b = rand_mat(rng, n, k);
            let got = a.matmul_transb(&b);
            let want = a.matmul(&b.transpose());
            assert!(got.max_abs_diff(&want) < 1e-4);
        });
    }

    #[test]
    fn matmul_transb_agrees_with_the_old_rowdot_loop() {
        // The kernel-routed matmul_transb vs the pre-PR-7 unblocked
        // loop it replaced: summation orders differ (pairwise ikj vs
        // sequential dot), so pin with a k-scaled tolerance.
        check(10, 107, |rng| {
            let (m, k, n) = (1 + rng.below(24), 1 + rng.below(200), 1 + rng.below(24));
            let a = rand_mat(rng, m, k);
            let b = rand_mat(rng, n, k);
            let got = a.matmul_transb(&b);
            let want = a.matmul_transb_reference(&b);
            let tol = 1e-4 * (k as f32).sqrt().max(1.0);
            assert!(got.max_abs_diff(&want) < tol, "m={m} k={k} n={n}");
        });
    }

    #[test]
    fn vectorized_kernel_is_bit_identical_to_reference() {
        // Exact equality — not tolerance — across shapes that cross
        // every tile boundary: n below one lane vector, n straddling
        // the 16-column tile, odd k (unpaired remainder), row counts
        // around the 4-row tile, and accumulation into non-zero out.
        let shapes = [
            (1, 1, 1),
            (3, 5, 7),
            (4, 2, 16),
            (5, 9, 17),
            (8, 33, 24),
            (4, 128, 16),
            (7, 129, 31),
            (12, 257, 40),
        ];
        let mut rng = Rng::new(108);
        for &(m, k, n) in &shapes {
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            let seed_out = rng.normal_vec(m * n);
            let mut out_ref = seed_out.clone();
            let mut out_vec = seed_out;
            matmul_reference(m, k, n, &a, &b, &mut out_ref);
            matmul_vectorized(m, k, n, &a, &b, &mut out_vec);
            assert_eq!(out_vec, out_ref, "kernel divergence at m={m} k={k} n={n}");
        }
        check(20, 109, |rng| {
            let (m, k, n) = (1 + rng.below(40), 1 + rng.below(300), 1 + rng.below(60));
            let a = rng.normal_vec(m * k);
            let b = rng.normal_vec(k * n);
            let mut out_ref = vec![0.0f32; m * n];
            let mut out_vec = vec![0.0f32; m * n];
            matmul_reference(m, k, n, &a, &b, &mut out_ref);
            matmul_vectorized(m, k, n, &a, &b, &mut out_vec);
            assert_eq!(out_vec, out_ref, "kernel divergence at m={m} k={k} n={n}");
        });
    }

    #[test]
    fn kernel_selection_roundtrips() {
        // Flipping the global is benign mid-suite: both kernels are
        // bit-identical, so concurrent tests cannot observe the flip.
        let before = gemm_kernel();
        set_gemm_kernel(GemmKernel::Reference);
        assert_eq!(gemm_kernel(), GemmKernel::Reference);
        set_gemm_kernel(GemmKernel::Vectorized);
        assert_eq!(gemm_kernel(), GemmKernel::Vectorized);
        set_gemm_kernel(before);
    }

    #[test]
    #[should_panic(expected = "matmul_acc size mismatch")]
    fn matmul_acc_rejects_short_buffers() {
        let a = vec![0.0f32; 4]; // claims 2x3 below: 2 slots short
        let b = vec![0.0f32; 6];
        let mut out = vec![0.0f32; 4];
        matmul_acc(2, 3, 2, &a, &b, &mut out);
    }

    #[test]
    fn transpose_involution() {
        check(10, 102, |rng| {
            let (r, c) = (1 + rng.below(20), 1 + rng.below(20));
            let a = rand_mat(rng, r, c);
            assert_eq!(a.transpose().transpose(), a);
        });
    }

    #[test]
    fn eye_is_identity_under_matmul() {
        let mut rng = Rng::new(5);
        let a = rand_mat(&mut rng, 7, 7);
        assert!(a.matmul(&Matrix::eye(7, 7)).max_abs_diff(&a) < 1e-6);
        assert!(Matrix::eye(7, 7).matmul(&a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn reshape_preserves_order() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        let r = t.reshape(&[3, 2]);
        assert_eq!(r.data, t.data);
        assert_eq!(r.shape, vec![3, 2]);
    }

    #[test]
    #[should_panic(expected = "numel mismatch")]
    fn reshape_rejects_bad_numel() {
        Tensor::zeros(&[2, 3]).reshape(&[4, 2]);
    }

    #[test]
    fn unfold_fold_roundtrip() {
        check(10, 103, |rng| {
            let shape = [1 + rng.below(5), 1 + rng.below(5), 1 + rng.below(5)];
            let t = Tensor::from_vec(&shape, rng.normal_vec(shape.iter().product()));
            for mode in 0..3 {
                let unf = t.unfold(mode);
                assert_eq!(unf.rows, shape[mode]);
                let back = Tensor::fold(&unf, mode, &shape);
                assert_eq!(back, t);
            }
        });
    }

    #[test]
    fn unfold_mode0_is_plain_reshape() {
        let t = Tensor::from_vec(&[2, 3, 4], (0..24).map(|x| x as f32).collect());
        let unf = t.unfold(0);
        assert_eq!(unf.data, t.data);
    }

    #[test]
    fn mode_product_shrinks_dim() {
        let mut rng = Rng::new(9);
        let t = Tensor::from_vec(&[4, 5, 6], rng.normal_vec(120));
        let u = rand_mat(&mut rng, 2, 5);
        let p = t.mode_product(1, &u);
        assert_eq!(p.shape, vec![4, 2, 6]);
    }

    #[test]
    fn permute_roundtrip_and_shape() {
        let mut rng = Rng::new(10);
        let t = Tensor::from_vec(&[2, 3, 4], rng.normal_vec(24));
        let p = t.permute(&[2, 0, 1]);
        assert_eq!(p.shape, vec![4, 2, 3]);
        let back = p.permute(&[1, 2, 0]);
        assert_eq!(back, t);
    }

    #[test]
    fn permute_matches_manual_transpose() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        let p = t.permute(&[1, 0]);
        let m = t.to_matrix(2, 3).transpose();
        assert_eq!(p.data, m.data);
    }

    #[test]
    fn frobenius_matches_manual() {
        let t = Tensor::from_vec(&[2, 2], vec![3.0, 4.0, 0.0, 0.0]);
        assert!((t.frobenius() - 5.0).abs() < 1e-6);
    }
}
