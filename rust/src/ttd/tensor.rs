//! Dense row-major tensors and matrices — the numeric substrate under
//! Algorithm 1/2. No BLAS in this environment: `matmul` is a
//! cache-blocked ikj kernel (see `benches/hotpath.rs` for its tuning
//! and `matmul_naive` for the unblocked reference it is measured
//! against). The Householder rank-1 updates (`apply_house_left` /
//! `apply_house_right`) live here as in-place `Matrix` methods — the
//! HBD hot loop never materializes a reflector matrix or clones the
//! working buffer.

use std::fmt;

/// Row-major 2-D matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Self { rows, cols, data }
    }

    /// Rectangular identity (ones on the main diagonal).
    pub fn eye(rows: usize, cols: usize) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows.min(cols) {
            m.data[i * cols + i] = 1.0;
        }
        m
    }

    #[inline(always)]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline(always)]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// `self @ other`, cache-blocked ikj with f32 accumulation.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        matmul_kernel(m, k, n, &self.data, &other.data, &mut out.data);
        out
    }

    /// `self @ view` for a borrowed right-hand side (e.g. a TT core
    /// viewed as a matrix) — same blocked kernel, no operand clone.
    pub fn matmul_view(&self, other: &MatrixView<'_>) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul_view dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        matmul_kernel(m, k, n, &self.data, other.data, &mut out.data);
        out
    }

    /// Textbook ijk triple loop — the unblocked reference the blocked
    /// kernel is benchmarked against (`benches/hotpath.rs`). Kept out
    /// of every hot path.
    pub fn matmul_naive(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += self.data[i * k + kk] * other.data[kk * n + j];
                }
                out.data[i * n + j] = acc;
            }
        }
        out
    }

    /// In-place left Householder rank-1 update on the subblock
    /// `self[r0.., c0..]`: `A <- A + (v/beta)(v^T A)` with
    /// `v.len() == rows - r0`. `scratch` must hold `cols - c0` slots;
    /// callers in the HBD loop reuse one buffer across all columns so
    /// the hot path performs zero allocations.
    pub fn apply_house_left(&mut self, r0: usize, c0: usize, v: &[f32], beta: f32, scratch: &mut [f32]) {
        if v.is_empty() {
            return;
        }
        debug_assert_eq!(v.len(), self.rows - r0);
        let cols = self.cols;
        let width = cols - c0;
        let w = &mut scratch[..width];
        w.fill(0.0);
        // w = v^T A  (first chained GEMM)
        for (i, &vi) in v.iter().enumerate() {
            if vi == 0.0 {
                continue;
            }
            let row = &self.data[(r0 + i) * cols + c0..(r0 + i) * cols + cols];
            for (wj, &ar) in w.iter_mut().zip(row) {
                *wj += vi * ar;
            }
        }
        // A += (v/beta) w  (second chained GEMM, rank-1)
        let inv_beta = 1.0 / beta;
        for (i, &vi) in v.iter().enumerate() {
            let scale = vi * inv_beta;
            if scale == 0.0 {
                continue;
            }
            let row = &mut self.data[(r0 + i) * cols + c0..(r0 + i) * cols + cols];
            for (ar, &wj) in row.iter_mut().zip(w.iter()) {
                *ar += scale * wj;
            }
        }
    }

    /// In-place right Householder rank-1 update on the subblock
    /// `self[r0.., c0..]`: `A <- A + (A v)(v/beta)` with
    /// `v.len() == cols - c0`. Row-at-a-time, no scratch needed.
    pub fn apply_house_right(&mut self, r0: usize, c0: usize, v: &[f32], beta: f32) {
        if v.is_empty() {
            return;
        }
        debug_assert_eq!(v.len(), self.cols - c0);
        let cols = self.cols;
        let inv_beta = 1.0 / beta;
        for r in r0..self.rows {
            let row = &mut self.data[r * cols + c0..(r + 1) * cols];
            // u_r = A[r, c0..] . v   (first chained GEMM)
            let mut u = 0.0f32;
            for (ar, &vj) in row.iter().zip(v) {
                u += *ar * vj;
            }
            // A[r, c0..] += u * (v/beta)  (second chained GEMM)
            let scale = u * inv_beta;
            if scale != 0.0 {
                for (ar, &vj) in row.iter_mut().zip(v) {
                    *ar += scale * vj;
                }
            }
        }
    }

    /// `self @ other^T` (row-times-row dot products, cache-friendly).
    pub fn matmul_transb(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_transb dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &other.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (a, b) in arow.iter().zip(brow) {
                    acc += a * b;
                }
                out.data[i * n + j] = acc;
            }
        }
        out
    }

    /// Submatrix copy `[r0..r1) x [c0..c1)`.
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for r in r0..r1 {
            out.data[(r - r0) * (c1 - c0)..(r - r0 + 1) * (c1 - c0)]
                .copy_from_slice(&self.data[r * self.cols + c0..r * self.cols + c1]);
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Borrowed row-major matrix view over someone else's storage (e.g. a
/// TT core reinterpreted as its left/right unfolding) — reshapes are
/// free and carry no clone.
#[derive(Clone, Copy)]
pub struct MatrixView<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a [f32],
}

impl fmt::Debug for MatrixView<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "MatrixView({}x{})", self.rows, self.cols)
    }
}

impl<'a> MatrixView<'a> {
    pub fn new(rows: usize, cols: usize, data: &'a [f32]) -> Self {
        assert_eq!(data.len(), rows * cols, "view length mismatch");
        Self { rows, cols, data }
    }

    #[inline(always)]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn row(&self, r: usize) -> &'a [f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Materialize an owned copy (only when ownership is truly needed).
    pub fn to_matrix(&self) -> Matrix {
        Matrix::from_vec(self.rows, self.cols, self.data.to_vec())
    }
}

/// `out += a @ b` over raw row-major slices through the blocked
/// kernel — the accumulate form the blocked compact-WY Householder
/// panels in [`crate::ttd::svd::bidiag`] build on (`out` may be a
/// row-contiguous sub-slice of a larger matrix). `out` must hold at
/// least `m * n` leading slots.
pub fn matmul_acc(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && out.len() >= m * n);
    matmul_kernel(m, k, n, a, b, out);
}

/// Shared cache-blocked ikj kernel over raw row-major slices:
/// `out += a @ b` with `a` (m x k), `b` (k x n), `out` (m x n).
fn matmul_kernel(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    const BK: usize = 128;
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            // k-unrolled by 2: the compiler keeps two FMA chains in
            // flight, hiding the accumulator dependency (measured
            // +25% over the single-chain loop; see EXPERIMENTS §Perf).
            let mut kk = k0;
            while kk + 1 < k1 {
                let a0 = arow[kk];
                let a1 = arow[kk + 1];
                let b0 = &b[kk * n..kk * n + n];
                let b1 = &b[(kk + 1) * n..(kk + 1) * n + n];
                for ((o, x), y) in orow.iter_mut().zip(b0).zip(b1) {
                    *o += a0 * x + a1 * y;
                }
                kk += 2;
            }
            if kk < k1 {
                let a0 = arow[kk];
                let brow = &b[kk * n..kk * n + n];
                for (o, bv) in orow.iter_mut().zip(brow) {
                    *o += a0 * bv;
                }
            }
        }
    }
}

/// Dense N-dimensional tensor, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Row-major reshape (element order preserved — Alg. 1 Reshape).
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.numel(),
            "reshape numel mismatch: {:?} -> {:?}",
            self.shape,
            shape
        );
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    pub fn to_matrix(&self, rows: usize, cols: usize) -> Matrix {
        assert_eq!(rows * cols, self.numel());
        Matrix::from_vec(rows, cols, self.data.clone())
    }

    pub fn from_matrix(m: &Matrix, shape: &[usize]) -> Tensor {
        Tensor::from_vec(shape, m.data.clone())
    }

    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Mode-k unfolding: rows indexed by dim k, columns by the
    /// remaining dims in row-major order (Tucker/HOSVD convention).
    pub fn unfold(&self, mode: usize) -> Matrix {
        let nk = self.shape[mode];
        let rest: usize = self.numel() / nk;
        let mut out = Matrix::zeros(nk, rest);
        let strides = row_major_strides(&self.shape);
        let mut idx = vec![0usize; self.shape.len()];
        for (flat, &v) in self.data.iter().enumerate() {
            // decode flat -> multi-index
            let mut rem = flat;
            for (d, s) in strides.iter().enumerate() {
                idx[d] = rem / s;
                rem %= s;
            }
            let r = idx[mode];
            // column index: remaining dims, row-major
            let mut c = 0usize;
            for d in 0..self.shape.len() {
                if d != mode {
                    c = c * self.shape[d] + idx[d];
                }
            }
            out.set(r, c, v);
        }
        out
    }

    /// Inverse of [`Tensor::unfold`].
    pub fn fold(m: &Matrix, mode: usize, shape: &[usize]) -> Tensor {
        let mut out = Tensor::zeros(shape);
        let strides = row_major_strides(shape);
        let mut idx = vec![0usize; shape.len()];
        for flat in 0..out.data.len() {
            let mut rem = flat;
            for (d, s) in strides.iter().enumerate() {
                idx[d] = rem / s;
                rem %= s;
            }
            let r = idx[mode];
            let mut c = 0usize;
            for d in 0..shape.len() {
                if d != mode {
                    c = c * shape[d] + idx[d];
                }
            }
            out.data[flat] = m.get(r, c);
        }
        out
    }

    /// Mode-k product: replace dim k by `u.rows`, contracting with
    /// `u` (rows_new x n_k).
    pub fn mode_product(&self, mode: usize, u: &Matrix) -> Tensor {
        assert_eq!(u.cols, self.shape[mode]);
        let unf = self.unfold(mode);
        let prod = u.matmul(&unf);
        let mut new_shape = self.shape.clone();
        new_shape[mode] = u.rows;
        Tensor::fold(&prod, mode, &new_shape)
    }

    /// Dimension permutation (generalized transpose).
    pub fn permute(&self, perm: &[usize]) -> Tensor {
        assert_eq!(perm.len(), self.shape.len());
        let new_shape: Vec<usize> = perm.iter().map(|&p| self.shape[p]).collect();
        let mut out = Tensor::zeros(&new_shape);
        let old_strides = row_major_strides(&self.shape);
        let new_strides = row_major_strides(&new_shape);
        let mut idx = vec![0usize; self.shape.len()];
        for (flat, &v) in self.data.iter().enumerate() {
            let mut rem = flat;
            for (d, s) in old_strides.iter().enumerate() {
                idx[d] = rem / s;
                rem %= s;
            }
            let mut nf = 0usize;
            for (nd, &od) in perm.iter().enumerate() {
                nf += idx[od] * new_strides[nd];
            }
            out.data[nf] = v;
        }
        out
    }
}

fn row_major_strides(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; shape.len()];
    for d in (0..shape.len().saturating_sub(1)).rev() {
        s[d] = s[d + 1] * shape[d + 1];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::util::Rng;

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_vec(r, c, rng.normal_vec(r * c))
    }

    #[test]
    fn matmul_matches_naive() {
        check(20, 100, |rng| {
            let (m, k, n) = (1 + rng.below(40), 1 + rng.below(40), 1 + rng.below(40));
            let a = rand_mat(rng, m, k);
            let b = rand_mat(rng, k, n);
            let got = a.matmul(&b);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..k).map(|kk| a.get(i, kk) * b.get(kk, j)).sum();
                    assert!((got.get(i, j) - want).abs() < 1e-3, "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn matmul_naive_and_view_match_blocked() {
        check(10, 104, |rng| {
            let (m, k, n) = (1 + rng.below(30), 1 + rng.below(300), 1 + rng.below(30));
            let a = rand_mat(rng, m, k);
            let b = rand_mat(rng, k, n);
            let blocked = a.matmul(&b);
            let naive = a.matmul_naive(&b);
            // summation orders differ; bound scales with sqrt(k)
            let tol = 1e-4 * (k as f32).sqrt().max(1.0) * 10.0;
            assert!(blocked.max_abs_diff(&naive) < tol);
            let view = MatrixView::new(k, n, &b.data);
            let viewed = a.matmul_view(&view);
            assert_eq!(viewed, blocked);
        });
    }

    #[test]
    fn house_updates_match_svd_house_wrappers() {
        use crate::ttd::svd::house::{apply_left, apply_right, house};
        check(10, 105, |rng| {
            let (m, n) = (2 + rng.below(16), 2 + rng.below(16));
            let a0 = rand_mat(rng, m, n);
            let x: Vec<f32> = (0..m).map(|r| a0.get(r, 0)).collect();
            let h = house(&x);
            let mut a = a0.clone();
            let mut b = a0.clone();
            let mut scratch = vec![0.0f32; n];
            a.apply_house_left(0, 0, &h.v, h.beta, &mut scratch);
            apply_left(&mut b, 0, 0, &h.v, h.beta);
            assert_eq!(a, b);

            let y: Vec<f32> = a0.row(0).to_vec();
            let h = house(&y);
            let mut a = a0.clone();
            let mut b = a0;
            a.apply_house_right(0, 0, &h.v, h.beta);
            apply_right(&mut b, 0, 0, &h.v, h.beta);
            assert_eq!(a, b);
        });
    }

    #[test]
    fn matmul_acc_accumulates_into_subslices() {
        check(10, 106, |rng| {
            let (m, k, n) = (1 + rng.below(12), 1 + rng.below(12), 1 + rng.below(12));
            let a = rand_mat(rng, m, k);
            let b = rand_mat(rng, k, n);
            // accumulate into the tail rows of a larger buffer
            let r0 = rng.below(4);
            let mut big = rand_mat(rng, r0 + m, n);
            let before = big.clone();
            let prod = a.matmul(&b);
            matmul_acc(m, k, n, &a.data, &b.data, &mut big.data[r0 * n..]);
            for r in 0..r0 {
                assert_eq!(big.row(r), before.row(r), "head rows untouched");
            }
            for r in 0..m {
                for c in 0..n {
                    let want = before.get(r0 + r, c) + prod.get(r, c);
                    assert!((big.get(r0 + r, c) - want).abs() < 1e-4);
                }
            }
        });
    }

    #[test]
    fn matrix_view_accessors() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let v = MatrixView::new(2, 3, &m.data);
        assert_eq!(v.get(1, 2), 6.0);
        assert_eq!(v.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(v.to_matrix(), m);
        // reinterpret the same storage with another shape — free reshape
        let v2 = MatrixView::new(3, 2, &m.data);
        assert_eq!(v2.get(2, 1), 6.0);
    }

    #[test]
    fn matmul_transb_matches_matmul() {
        check(10, 101, |rng| {
            let (m, k, n) = (1 + rng.below(30), 1 + rng.below(30), 1 + rng.below(30));
            let a = rand_mat(rng, m, k);
            let b = rand_mat(rng, n, k);
            let got = a.matmul_transb(&b);
            let want = a.matmul(&b.transpose());
            assert!(got.max_abs_diff(&want) < 1e-4);
        });
    }

    #[test]
    fn transpose_involution() {
        check(10, 102, |rng| {
            let (r, c) = (1 + rng.below(20), 1 + rng.below(20));
            let a = rand_mat(rng, r, c);
            assert_eq!(a.transpose().transpose(), a);
        });
    }

    #[test]
    fn eye_is_identity_under_matmul() {
        let mut rng = Rng::new(5);
        let a = rand_mat(&mut rng, 7, 7);
        assert!(a.matmul(&Matrix::eye(7, 7)).max_abs_diff(&a) < 1e-6);
        assert!(Matrix::eye(7, 7).matmul(&a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn reshape_preserves_order() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        let r = t.reshape(&[3, 2]);
        assert_eq!(r.data, t.data);
        assert_eq!(r.shape, vec![3, 2]);
    }

    #[test]
    #[should_panic(expected = "numel mismatch")]
    fn reshape_rejects_bad_numel() {
        Tensor::zeros(&[2, 3]).reshape(&[4, 2]);
    }

    #[test]
    fn unfold_fold_roundtrip() {
        check(10, 103, |rng| {
            let shape = [1 + rng.below(5), 1 + rng.below(5), 1 + rng.below(5)];
            let t = Tensor::from_vec(&shape, rng.normal_vec(shape.iter().product()));
            for mode in 0..3 {
                let unf = t.unfold(mode);
                assert_eq!(unf.rows, shape[mode]);
                let back = Tensor::fold(&unf, mode, &shape);
                assert_eq!(back, t);
            }
        });
    }

    #[test]
    fn unfold_mode0_is_plain_reshape() {
        let t = Tensor::from_vec(&[2, 3, 4], (0..24).map(|x| x as f32).collect());
        let unf = t.unfold(0);
        assert_eq!(unf.data, t.data);
    }

    #[test]
    fn mode_product_shrinks_dim() {
        let mut rng = Rng::new(9);
        let t = Tensor::from_vec(&[4, 5, 6], rng.normal_vec(120));
        let u = rand_mat(&mut rng, 2, 5);
        let p = t.mode_product(1, &u);
        assert_eq!(p.shape, vec![4, 2, 6]);
    }

    #[test]
    fn permute_roundtrip_and_shape() {
        let mut rng = Rng::new(10);
        let t = Tensor::from_vec(&[2, 3, 4], rng.normal_vec(24));
        let p = t.permute(&[2, 0, 1]);
        assert_eq!(p.shape, vec![4, 2, 3]);
        let back = p.permute(&[1, 2, 0]);
        assert_eq!(back, t);
    }

    #[test]
    fn permute_matches_manual_transpose() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|x| x as f32).collect());
        let p = t.permute(&[1, 0]);
        let m = t.to_matrix(2, 3).transpose();
        assert_eq!(p.data, m.data);
    }

    #[test]
    fn frobenius_matches_manual() {
        let t = Tensor::from_vec(&[2, 2], vec![3.0, 4.0, 0.0, 0.0]);
        assert!((t.frobenius() - 5.0).abs() < 1e-6);
    }
}
