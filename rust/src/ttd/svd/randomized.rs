//! Seeded randomized range-finder SVD (Halko, Martinsson & Tropp),
//! Algorithm-1 line 8's `--method rsvd` (ISSUE 9).
//!
//! For a tall `A` (m x n) and sketch width `l`:
//!
//! 1. `Y = A Omega` with a seeded Gaussian `Omega` (n x l) — one big
//!    GEMM, the step that replaces O(mn^2) dense HBD work with O(mnl).
//! 2. Householder QR of `Y` -> orthonormal `Q` (m x l).
//! 3. `B = Q^T A` (l x n), then the **existing** dense HBD/GK SVD of
//!    `B` — so the small-problem numerics, phase bracketing, and op
//!    vocabulary are exactly the ones the simulator already prices.
//! 4. `U = Q U_B`.
//!
//! Everything is emitted through the same closed [`HwOp`] stream as
//! the exact path (sketch/projection GEMMs + per-reflector
//! `HouseGen`/rank-1 `Gemm` ops in the HBD phase), so programs,
//! replay, caching, and every SoC backend compose unchanged. The
//! factorization is a pure function of `(A, sketch, seed)` — no
//! thread-count or kernel dependence anywhere on the path — which is
//! what the byte-determinism suites pin.

use crate::trace::{HwOp, Phase, TraceSink};
use crate::ttd::svd::house;
use crate::ttd::svd::{svd, Svd};
use crate::ttd::tensor::Matrix;
use crate::util::Rng;

/// Economy randomized SVD with `min(sketch, min(m, n))` retained
/// components. Like [`svd`], the result is **not** sorted —
/// Sorting_Basis runs afterwards. Wide inputs go through the transpose
/// (costed as a Reshape), mirroring the exact path.
pub fn rsvd<S: TraceSink>(a: &Matrix, sketch: usize, seed: u64, sink: &mut S) -> Svd {
    if a.rows >= a.cols {
        rsvd_tall(a, sketch, seed, sink)
    } else {
        sink.op(HwOp::SetPhase(Phase::ReshapeEtc));
        sink.op(HwOp::Reshape { elems: a.rows * a.cols });
        let at = a.transpose();
        let s = rsvd_tall(&at, sketch, seed, sink);
        sink.op(HwOp::SetPhase(Phase::ReshapeEtc));
        sink.op(HwOp::Reshape { elems: 2 * a.rows * a.cols });
        Svd {
            u: s.vt.transpose(),
            sigma: s.sigma,
            vt: s.u.transpose(),
            qr_iterations: s.qr_iterations,
            converged: s.converged,
        }
    }
}

fn rsvd_tall<S: TraceSink>(a: &Matrix, sketch: usize, seed: u64, sink: &mut S) -> Svd {
    let (m, n) = (a.rows, a.cols);
    let l = sketch.clamp(1, n);

    // 1. Sketch: Y = A Omega, Omega seeded Gaussian. The range-finder
    // runs on the GEMM accelerator; Omega generation is core-side
    // bookkeeping already covered by the GEMM's operand streaming.
    sink.op(HwOp::SetPhase(Phase::Hbd));
    let mut rng = Rng::new(seed);
    let omega = Matrix::from_vec(n, l, rng.normal_vec(n * l));
    sink.op(HwOp::Gemm { m, n: l, k: n });
    let mut y = a.matmul(&omega);

    // 2. Householder QR of Y: l reflectors, each generated
    // (`HouseGen`) and applied to the trailing panel as a rank-1
    // update through the GEMM unit — the same op shapes the HBD path
    // emits, so both backends price the sketch QR natively.
    let mut hs = Vec::with_capacity(l);
    let mut col = vec![0.0f32; m];
    {
        // lint: hotpath
        for j in 0..l {
            let len = m - j;
            for (i, c) in col[..len].iter_mut().enumerate() {
                *c = y.get(j + i, j);
            }
            sink.op(HwOp::HouseGen { len });
            let h = house::house(&col[..len]);
            if j + 1 < l {
                sink.op(HwOp::Gemm { m: len, n: l - j - 1, k: 1 });
                house::apply_left(&mut y, j, j + 1, &h.v, h.beta);
            }
            hs.push(h);
        }
    }

    // Explicit Q (m x l) by backward accumulation: H_j fixes e_c for
    // c < j, so each reflector only touches the trailing block.
    let mut q = Matrix::eye(m, l);
    for j in (0..l).rev() {
        let h = &hs[j];
        sink.op(HwOp::Gemm { m: m - j, n: l - j, k: 1 });
        house::apply_left(&mut q, j, j, &h.v, h.beta);
    }

    // 3. Project: B = Q^T A (l x n), then the existing dense SVD
    // (emits its own Hbd/QrDiag phase brackets).
    sink.op(HwOp::Gemm { m: l, n, k: m });
    let b = q.transpose().matmul(a);
    let s = svd(&b, sink);

    // 4. Lift the left basis back: U = Q U_B (m x l @ l x k).
    sink.op(HwOp::SetPhase(Phase::Hbd));
    sink.op(HwOp::Gemm { m, n: s.u.cols, k: l });
    Svd {
        u: q.matmul(&s.u),
        sigma: s.sigma,
        vt: s.vt,
        qr_iterations: s.qr_iterations,
        converged: s.converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::trace::{NullSink, VecSink};
    use crate::util::Rng;

    fn reconstruct(s: &Svd) -> Matrix {
        let mut us = s.u.clone();
        for r in 0..us.rows {
            for c in 0..us.cols {
                let v = us.get(r, c) * s.sigma[c];
                us.set(r, c, v);
            }
        }
        us.matmul(&s.vt)
    }

    #[test]
    fn full_sketch_reconstructs_any_aspect_ratio() {
        check(15, 900, |rng| {
            let m = 2 + rng.below(24);
            let n = 2 + rng.below(24);
            let a = Matrix::from_vec(m, n, rng.normal_vec(m * n));
            let s = rsvd(&a, m.max(n), 7, &mut NullSink);
            let k = m.min(n);
            assert_eq!((s.u.rows, s.u.cols), (m, k));
            assert_eq!(s.sigma.len(), k);
            assert_eq!((s.vt.rows, s.vt.cols), (k, n));
            let scale = a.frobenius().max(1.0);
            assert!(
                reconstruct(&s).max_abs_diff(&a) / scale < 1e-3,
                "m={m} n={n}"
            );
        });
    }

    #[test]
    fn q_is_orthonormal() {
        let mut rng = Rng::new(31);
        let a = Matrix::from_vec(40, 12, rng.normal_vec(480));
        let s = rsvd(&a, 6, 3, &mut NullSink);
        // U = Q U_B with both factors orthonormal: U^T U = I_6.
        let gram = s.u.transpose().matmul(&s.u);
        assert!(gram.max_abs_diff(&Matrix::eye(6, 6)) < 1e-4);
    }

    #[test]
    fn truncated_sketch_captures_a_planted_range() {
        // A = L R with inner dimension 4: a rank-4 matrix must be
        // recovered (to rounding) by any sketch >= 4.
        let mut rng = Rng::new(32);
        let l = Matrix::from_vec(50, 4, rng.normal_vec(200));
        let r = Matrix::from_vec(4, 20, rng.normal_vec(80));
        let a = l.matmul(&r);
        let s = rsvd(&a, 8, 11, &mut NullSink);
        let scale = a.frobenius();
        assert!(reconstruct(&s).max_abs_diff(&a) / scale < 1e-3);
        // trailing sketch directions beyond the true rank are noise
        assert!(s.sigma.iter().filter(|v| **v > 1e-3 * scale).count() == 4);
    }

    #[test]
    fn seed_determinism_is_bitwise() {
        let mut rng = Rng::new(33);
        let a = Matrix::from_vec(30, 10, rng.normal_vec(300));
        let mut t1 = VecSink::default();
        let mut t2 = VecSink::default();
        let s1 = rsvd(&a, 5, 42, &mut t1);
        let s2 = rsvd(&a, 5, 42, &mut t2);
        assert_eq!(s1.u.data, s2.u.data);
        assert_eq!(s1.sigma, s2.sigma);
        assert_eq!(s1.vt.data, s2.vt.data);
        assert_eq!(t1.ops, t2.ops);
        // a different seed draws a different sketch
        let s3 = rsvd(&a, 5, 43, &mut NullSink);
        assert_ne!(s1.u.data, s3.u.data);
    }

    #[test]
    fn trace_stays_in_the_closed_vocabulary_and_phases() {
        let mut rng = Rng::new(34);
        let a = Matrix::from_vec(18, 6, rng.normal_vec(108));
        let mut sink = VecSink::default();
        let _ = rsvd(&a, 4, 9, &mut sink);
        assert!(matches!(sink.ops[0], HwOp::SetPhase(Phase::Hbd)));
        assert!(sink.ops.iter().any(|o| matches!(o, HwOp::Gemm { .. })));
        assert!(sink.ops.iter().any(|o| matches!(o, HwOp::HouseGen { .. })));
        assert!(sink
            .ops
            .iter()
            .any(|o| matches!(o, HwOp::SetPhase(Phase::QrDiag))));
    }
}
