//! SVD via the paper's two-phase split: Householder bidiagonalization
//! ([`bidiag`], offloadable to the HBD-ACC) + QR diagonalization
//! ([`golub_kahan`], core-resident). [`jacobi`] is the independent
//! numerical cross-check.

pub mod bidiag;
pub mod golub_kahan;
pub mod house;
pub mod jacobi;
pub mod randomized;

use crate::trace::{HwOp, Phase, TraceSink};
use crate::ttd::tensor::Matrix;

/// Economy SVD: `a = u diag(sigma) vt` with `u` (m, k), `vt` (k, n),
/// `k = min(m, n)`. **Not sorted** — Algorithm 1 runs its explicit
/// Sorting_Basis phase afterwards (see [`crate::ttd::decompose`]).
pub struct Svd {
    pub u: Matrix,
    pub sigma: Vec<f32>,
    pub vt: Matrix,
    pub qr_iterations: usize,
    /// False when the QR diagonalization hit its iteration cap
    /// (surfaced from [`golub_kahan::BidiagSvd::converged`];
    /// `ttd::decompose` reacts with the [`jacobi_fallback`]).
    pub converged: bool,
}

/// Full SVD of an arbitrary matrix through HBD + implicit-shift QR,
/// emitting the phase-bracketed hardware trace.
///
/// Wide inputs go through the transpose (costed as a Reshape — the
/// hardware reads the same buffer with swapped strides).
pub fn svd<S: TraceSink>(a: &Matrix, sink: &mut S) -> Svd {
    if a.rows >= a.cols {
        svd_tall(a, sink)
    } else {
        sink.op(HwOp::SetPhase(Phase::ReshapeEtc));
        sink.op(HwOp::Reshape { elems: a.rows * a.cols });
        let at = a.transpose();
        let s = svd_tall(&at, sink);
        sink.op(HwOp::SetPhase(Phase::ReshapeEtc));
        sink.op(HwOp::Reshape { elems: 2 * a.rows * a.cols });
        Svd {
            u: s.vt.transpose(),
            sigma: s.sigma,
            vt: s.u.transpose(),
            qr_iterations: s.qr_iterations,
            converged: s.converged,
        }
    }
}

fn svd_tall<S: TraceSink>(a: &Matrix, sink: &mut S) -> Svd {
    sink.op(HwOp::SetPhase(Phase::Hbd));
    let f = bidiag::bidiagonalize(a, sink);
    sink.op(HwOp::SetPhase(Phase::QrDiag));
    // diagonalize takes the HBD factors by value and returns them by
    // move — no dense matrix is cloned on the SVD hot path.
    let d = golub_kahan::diagonalize(&f.b, f.u, f.vt, sink);
    Svd { u: d.u, sigma: d.sigma, vt: d.vt, qr_iterations: d.iterations, converged: d.converged }
}

/// Sweep cap for the Jacobi rescue path — generous for the <= 64-dim
/// bidiagonal cores this workload produces (the cross-check suite
/// converges well under 40).
const JACOBI_RESCUE_SWEEPS: usize = 60;

/// The ISSUE-10 rescue path for a non-converged (or chaos-stalled) QR
/// diagonalization: bidiagonalize, run the independent one-sided
/// [`jacobi`] cross-check on the square bidiagonal core, and compose
/// the factors back (`A = U_hbd B V_hbd^T`, `B = U_j S V_j^T`). The
/// HBD half emits its usual trace ops; Jacobi rotations are
/// core-resident and uncosted — the fallback trades modeled cost
/// fidelity for a converged factorization.
pub fn jacobi_fallback<S: TraceSink>(a: &Matrix, sink: &mut S) -> Svd {
    if a.rows >= a.cols {
        jacobi_fallback_tall(a, sink)
    } else {
        sink.op(HwOp::SetPhase(Phase::ReshapeEtc));
        sink.op(HwOp::Reshape { elems: a.rows * a.cols });
        let at = a.transpose();
        let s = jacobi_fallback_tall(&at, sink);
        sink.op(HwOp::SetPhase(Phase::ReshapeEtc));
        sink.op(HwOp::Reshape { elems: 2 * a.rows * a.cols });
        Svd {
            u: s.vt.transpose(),
            sigma: s.sigma,
            vt: s.u.transpose(),
            qr_iterations: s.qr_iterations,
            converged: s.converged,
        }
    }
}

fn jacobi_fallback_tall<S: TraceSink>(a: &Matrix, sink: &mut S) -> Svd {
    sink.op(HwOp::SetPhase(Phase::Hbd));
    let f = bidiag::bidiagonalize(a, sink);
    sink.op(HwOp::SetPhase(Phase::QrDiag));
    let jc = jacobi::jacobi_svd(&f.b, JACOBI_RESCUE_SWEEPS);
    Svd {
        u: f.u.matmul(&jc.u),
        sigma: jc.sigma,
        vt: jc.vt.matmul(&f.vt),
        qr_iterations: jc.sweeps_used,
        // `sweeps_used == cap` means the off-diagonal tolerance was
        // never met — conservative, like the QR flag.
        converged: jc.sweeps_used < JACOBI_RESCUE_SWEEPS,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::trace::{NullSink, VecSink};
    use crate::util::Rng;

    fn reconstruct(s: &Svd) -> Matrix {
        let mut us = s.u.clone();
        for r in 0..us.rows {
            for c in 0..us.cols {
                let v = us.get(r, c) * s.sigma[c];
                us.set(r, c, v);
            }
        }
        us.matmul(&s.vt)
    }

    #[test]
    fn economy_svd_any_aspect_ratio() {
        check(20, 600, |rng| {
            let m = 2 + rng.below(30);
            let n = 2 + rng.below(30);
            let a = Matrix::from_vec(m, n, rng.normal_vec(m * n));
            let s = svd(&a, &mut NullSink);
            let k = m.min(n);
            assert_eq!((s.u.rows, s.u.cols), (m, k));
            assert_eq!(s.sigma.len(), k);
            assert_eq!((s.vt.rows, s.vt.cols), (k, n));
            let recon = reconstruct(&s);
            let scale = a.frobenius().max(1.0);
            assert!(
                recon.max_abs_diff(&a) / scale < 3e-4,
                "m={m} n={n} err {}",
                recon.max_abs_diff(&a) / scale
            );
            assert!(s.converged, "m={m} n={n}: QR must converge on random input");
        });
    }

    #[test]
    fn jacobi_fallback_factors_any_aspect_ratio() {
        check(10, 601, |rng| {
            let m = 2 + rng.below(20);
            let n = 2 + rng.below(20);
            let a = Matrix::from_vec(m, n, rng.normal_vec(m * n));
            let s = jacobi_fallback(&a, &mut NullSink);
            assert!(s.converged, "m={m} n={n}");
            let k = m.min(n);
            assert_eq!((s.u.rows, s.u.cols), (m, k));
            assert_eq!(s.sigma.len(), k);
            assert_eq!((s.vt.rows, s.vt.cols), (k, n));
            let recon = reconstruct(&s);
            let scale = a.frobenius().max(1.0);
            assert!(
                recon.max_abs_diff(&a) / scale < 3e-4,
                "m={m} n={n} fallback err {}",
                recon.max_abs_diff(&a) / scale
            );
            // and its singular values agree with the QR path's
            let mut qr = svd(&a, &mut NullSink).sigma;
            qr.sort_by(|a, b| b.partial_cmp(a).unwrap());
            for (a, b) in qr.iter().zip(&s.sigma) {
                assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "qr {a} vs fallback {b}");
            }
        });
    }

    #[test]
    fn singular_values_match_between_orientations() {
        let mut rng = Rng::new(70);
        let a = Matrix::from_vec(9, 21, rng.normal_vec(9 * 21));
        let s1 = svd(&a, &mut NullSink);
        let s2 = svd(&a.transpose(), &mut NullSink);
        let mut v1 = s1.sigma.clone();
        let mut v2 = s2.sigma.clone();
        v1.sort_by(|a, b| b.partial_cmp(a).unwrap());
        v2.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for (a, b) in v1.iter().zip(&v2) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn trace_is_phase_bracketed() {
        use crate::trace::HwOp::*;
        let mut rng = Rng::new(71);
        let a = Matrix::from_vec(16, 8, rng.normal_vec(128));
        let mut sink = VecSink::default();
        let _ = svd(&a, &mut sink);
        assert!(matches!(sink.ops[0], SetPhase(Phase::Hbd)));
        assert!(sink.ops.iter().any(|o| matches!(o, SetPhase(Phase::QrDiag))));
        // HBD ops come before QR ops
        let hbd_end = sink
            .ops
            .iter()
            .position(|o| matches!(o, SetPhase(Phase::QrDiag)))
            .unwrap();
        assert!(sink.ops[..hbd_end].iter().any(|o| matches!(o, HouseGen { .. })));
        assert!(sink.ops[hbd_end..].iter().any(|o| matches!(o, GivensRot { .. })));
    }
}
