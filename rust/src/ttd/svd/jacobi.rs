//! One-sided Jacobi SVD — the independent cross-check for the
//! Golub–Kahan QR path (two self-implemented algorithms agreeing is
//! the offline substitute for a LAPACK oracle), and the rust mirror of
//! the exportable L2 `jacobi_svd` in `python/compile/svd.py`.

use crate::ttd::tensor::Matrix;

pub struct JacobiSvd {
    pub u: Matrix,
    pub sigma: Vec<f32>,
    pub vt: Matrix,
    pub sweeps_used: usize,
}

/// One-sided Jacobi on a square matrix: orthogonalize the columns of
/// `G = B` with Givens rotations until convergence, then
/// `sigma_k = ||G[:,k]||`, `U = G Sigma^{-1}`, `B = U Sigma V^T`.
pub fn jacobi_svd(b: &Matrix, max_sweeps: usize) -> JacobiSvd {
    let n = b.rows;
    assert_eq!(b.cols, n);
    let mut g = b.clone();
    let mut v = Matrix::eye(n, n);
    let tol = 1e-12f64;
    let mut sweeps_used = max_sweeps;

    for sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n.saturating_sub(1) {
            for q in p + 1..n {
                let mut app = 0.0f64;
                let mut aqq = 0.0f64;
                let mut apq = 0.0f64;
                for r in 0..n {
                    let gp = g.get(r, p) as f64;
                    let gq = g.get(r, q) as f64;
                    app += gp * gp;
                    aqq += gq * gq;
                    apq += gp * gq;
                }
                if apq.abs() <= tol * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                off += apq.abs();
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                for r in 0..n {
                    let gp = g.get(r, p);
                    let gq = g.get(r, q);
                    g.set(r, p, cf * gp - sf * gq);
                    g.set(r, q, sf * gp + cf * gq);
                    let vp = v.get(r, p);
                    let vq = v.get(r, q);
                    v.set(r, p, cf * vp - sf * vq);
                    v.set(r, q, sf * vp + cf * vq);
                }
            }
        }
        if off < 1e-10 {
            sweeps_used = sweep + 1;
            break;
        }
    }

    // Column norms -> singular values, sorted descending.
    let mut sig: Vec<(f32, usize)> = (0..n)
        .map(|c| {
            let s: f64 = (0..n).map(|r| (g.get(r, c) as f64).powi(2)).sum();
            (s.sqrt() as f32, c)
        })
        .collect();
    sig.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut u = Matrix::zeros(n, n);
    let mut vt = Matrix::zeros(n, n);
    let mut sigma = Vec::with_capacity(n);
    for (k, (s, c)) in sig.iter().enumerate() {
        sigma.push(*s);
        let inv = if *s > 1e-30 { 1.0 / *s } else { 0.0 };
        for r in 0..n {
            u.set(r, k, g.get(r, *c) * inv);
            vt.set(k, r, v.get(r, *c));
        }
    }
    JacobiSvd { u, sigma, vt, sweeps_used }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::util::Rng;

    #[test]
    fn factorization_and_ordering() {
        check(15, 500, |rng| {
            let n = 2 + rng.below(20);
            let b = Matrix::from_vec(n, n, rng.normal_vec(n * n));
            let svd = jacobi_svd(&b, 30);
            // descending
            for w in svd.sigma.windows(2) {
                assert!(w[0] >= w[1] - 1e-5);
            }
            // reconstruction
            let mut us = svd.u.clone();
            for r in 0..n {
                for c in 0..n {
                    let v = us.get(r, c) * svd.sigma[c];
                    us.set(r, c, v);
                }
            }
            let recon = us.matmul(&svd.vt);
            let scale = b.frobenius().max(1.0);
            assert!(recon.max_abs_diff(&b) / scale < 1e-4);
        });
    }

    #[test]
    fn orthogonal_factors() {
        let mut rng = Rng::new(60);
        let n = 12;
        let b = Matrix::from_vec(n, n, rng.normal_vec(n * n));
        let svd = jacobi_svd(&b, 30);
        assert!(svd.u.transpose().matmul(&svd.u).max_abs_diff(&Matrix::eye(n, n)) < 1e-4);
        assert!(svd.vt.matmul(&svd.vt.transpose()).max_abs_diff(&Matrix::eye(n, n)) < 1e-4);
    }

    #[test]
    fn agrees_with_golub_kahan() {
        use crate::trace::NullSink;
        use crate::ttd::svd::{bidiag::bidiagonalize, golub_kahan::diagonalize};
        check(10, 501, |rng| {
            let n = 2 + rng.below(12);
            let m = n + rng.below(12);
            let a = Matrix::from_vec(m, n, rng.normal_vec(m * n));
            let f = bidiagonalize(&a, &mut NullSink);
            let gk = diagonalize(&f.b, f.u, f.vt, &mut NullSink);
            let jc = jacobi_svd(&f.b, 40);
            let mut gk_sorted = gk.sigma.clone();
            gk_sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            for (a, b) in gk_sorted.iter().zip(&jc.sigma) {
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + a.abs()),
                    "gk {a} vs jacobi {b}"
                );
            }
        });
    }

    #[test]
    fn rank_deficient_matrix_has_zero_tail() {
        let mut rng = Rng::new(61);
        let left = Matrix::from_vec(8, 2, rng.normal_vec(16));
        let right = Matrix::from_vec(2, 8, rng.normal_vec(16));
        let b = left.matmul(&right);
        let svd = jacobi_svd(&b, 30);
        for s in &svd.sigma[2..] {
            assert!(*s < 1e-3, "tail sv {s}");
        }
    }
}
