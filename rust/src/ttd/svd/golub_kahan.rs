//! Diagonalization of the upper-bidiagonal matrix: implicit-shift
//! Golub–Kahan QR iteration ("a standard QR-based procedure", paper
//! section II-A-2c). Runs on the core in both SoC configurations —
//! Table III's "QR Decomp." row.
//!
//! The bidiagonal matrices in this workload are small (n = min-dim of
//! the working matrix, <= 64 for ResNet-32), so the bulge chase is
//! applied to an explicit dense `B` via plane rotations; every rotation
//! is reported to the trace sink with the number of elements it touches
//! (the simulator's Givens cost unit).

use crate::trace::{HwOp, TraceSink};
use crate::ttd::tensor::Matrix;

/// SVD of a bidiagonal matrix: `B = U_q diag(sigma) V_q^T`.
pub struct BidiagSvd {
    pub u: Matrix,
    pub sigma: Vec<f32>,
    pub vt: Matrix,
    /// Total implicit-shift QR steps taken (convergence metric).
    pub iterations: usize,
    /// False when the sweep hit its iteration cap with superdiagonal
    /// mass remaining — the caller must not trust `sigma` and should
    /// fall back (ISSUE 10: `ttd::decompose` reruns through the
    /// one-sided Jacobi cross-check before erroring).
    pub converged: bool,
}

/// Plane rotation `(c, s)` with `c*a + s*b = r`, `-s*a + c*b = 0`.
#[inline]
fn rot(a: f32, b: f32) -> (f32, f32, f32) {
    if b == 0.0 {
        (1.0, 0.0, a)
    } else {
        let r = (a * a + b * b).sqrt();
        (a / r, b / r, r)
    }
}

/// Columns p,q: `col_p' = c col_p + s col_q; col_q' = -s col_p + c col_q`.
fn rot_cols(m: &mut Matrix, p: usize, q: usize, c: f32, s: f32) {
    let cols = m.cols;
    for r in 0..m.rows {
        let xp = m.data[r * cols + p];
        let xq = m.data[r * cols + q];
        m.data[r * cols + p] = c * xp + s * xq;
        m.data[r * cols + q] = -s * xp + c * xq;
    }
}

/// Rows p,q: `row_p' = c row_p + s row_q; row_q' = -s row_p + c row_q`.
fn rot_rows(m: &mut Matrix, p: usize, q: usize, c: f32, s: f32) {
    let cols = m.cols;
    debug_assert!(p < q);
    let (head, tail) = m.data.split_at_mut(q * cols);
    let rp = &mut head[p * cols..(p + 1) * cols];
    let rq = &mut tail[..cols];
    for (xp, xq) in rp.iter_mut().zip(rq.iter_mut()) {
        let (a, b) = (*xp, *xq);
        *xp = c * a + s * b;
        *xq = -s * a + c * b;
    }
}

/// Wilkinson shift from the trailing 2x2 of `T = B^T B` on block
/// `[lo, hi]`.
fn wilkinson_shift(b: &Matrix, lo: usize, hi: usize) -> f32 {
    let d_hm1 = b.get(hi - 1, hi - 1);
    let d_h = b.get(hi, hi);
    let e_hm1 = b.get(hi - 1, hi);
    let e_hm2 = if hi >= 2 && hi - 1 > lo { b.get(hi - 2, hi - 1) } else { 0.0 };
    let t11 = d_hm1 * d_hm1 + e_hm2 * e_hm2;
    let t12 = d_hm1 * e_hm1;
    let t22 = d_h * d_h + e_hm1 * e_hm1;
    let d = (t11 - t22) * 0.5;
    if d == 0.0 && t12 == 0.0 {
        return t22;
    }
    let denom = d + d.signum() * (d * d + t12 * t12).sqrt();
    if denom == 0.0 {
        t22
    } else {
        t22 - t12 * t12 / denom
    }
}

/// Implicit-shift QR SVD of an upper-bidiagonal `b` (n x n).
///
/// `u_acc` (m x n) and `vt_acc` (n x n) are taken by value, updated
/// with the accumulated rotations, and **returned by move** as
/// [`BidiagSvd::u`]/[`BidiagSvd::vt`] — no dense matrix is cloned
/// (pass `U_B` / `V_B^T` from the HBD phase to get the full SVD of
/// the original matrix).
pub fn diagonalize<S: TraceSink>(
    b: &Matrix,
    mut u_acc: Matrix,
    mut vt_acc: Matrix,
    sink: &mut S,
) -> BidiagSvd {
    let n = b.rows;
    assert_eq!(b.cols, n);
    let mut b = b.clone();
    let eps = f32::EPSILON;
    let anorm = b.frobenius().max(1e-30);
    let max_iter = 40 * n.max(1) * n.max(1) + 100;
    let mut iterations = 0usize;
    let mut converged = true;

    if n > 0 {
        let mut hi = n - 1;
        'outer: loop {
            // Zero ALL negligible superdiagonals (splitting interior
            // blocks too — only checking e[hi-1] lets interior
            // rounding-level e's trap the shift strategy). The absolute
            // `eps * anorm` floor matters in f32: after the cubic
            // Wilkinson phase, e plateaus at rounding level relative to
            // ||B||, not relative to its (possibly tiny) neighbours.
            for i in 0..hi {
                let e = b.get(i, i + 1);
                if e != 0.0
                    && e.abs()
                        <= eps * (b.get(i, i).abs() + b.get(i + 1, i + 1).abs())
                            + eps * anorm
                {
                    b.set(i, i + 1, 0.0);
                }
            }
            // Deflate converged trailing values.
            while hi > 0 && b.get(hi - 1, hi) == 0.0 {
                hi -= 1;
            }
            if hi == 0 {
                break 'outer;
            }
            // Active block [lo, hi]: all superdiagonals nonzero.
            let mut lo = hi;
            while lo > 0 && b.get(lo - 1, lo) != 0.0 {
                lo -= 1;
            }

            // Zero diagonal inside the block: chase the offending
            // superdiagonal e[i] along row i with left rotations
            // (Demmel-Kahan splitting), guaranteeing progress.
            let mut handled_zero = false;
            for i in lo..hi {
                if b.get(i, i).abs() <= eps * anorm {
                    b.set(i, i, 0.0);
                    for j in i + 1..=hi {
                        let eij = b.get(i, j);
                        if eij == 0.0 {
                            break;
                        }
                        let djj = b.get(j, j);
                        let r = (eij * eij + djj * djj).sqrt();
                        if r <= eps * anorm {
                            b.set(i, j, 0.0);
                            break;
                        }
                        // rows (i, j): zero B[i,j] against pivot B[j,j]
                        let (c, s) = (djj / r, -eij / r);
                        rot_rows(&mut b, i, j, c, s);
                        rot_cols(&mut u_acc, i, j, c, s);
                        sink.op(HwOp::GivensRot { len: 4 + u_acc.rows });
                        b.set(i, j, 0.0); // exact by construction
                    }
                    handled_zero = true;
                    break;
                }
            }
            if handled_zero {
                iterations += 1;
                if iterations > max_iter {
                    converged = false;
                    break 'outer;
                }
                continue 'outer;
            }

            // One implicit-shift QR step on [lo, hi].
            iterations += 1;
            if iterations > max_iter {
                converged = false;
                break 'outer;
            }
            let mu = wilkinson_shift(&b, lo, hi);
            let mut y = b.get(lo, lo) * b.get(lo, lo) - mu;
            let mut z = b.get(lo, lo) * b.get(lo, lo + 1);
            for k in lo..hi {
                // Right rotation in plane (k, k+1) annihilating z.
                let (c, s, _) = rot(y, z);
                rot_cols(&mut b, k, k + 1, c, s);
                rot_rows(&mut vt_acc, k, k + 1, c, s);
                sink.op(HwOp::GivensRot { len: 4 + vt_acc.cols });
                // Left rotation zeroing the bulge at (k+1, k).
                let (c2, s2, _) = rot(b.get(k, k), b.get(k + 1, k));
                rot_rows(&mut b, k, k + 1, c2, s2);
                rot_cols(&mut u_acc, k, k + 1, c2, s2);
                sink.op(HwOp::GivensRot { len: 4 + u_acc.rows });
                b.set(k + 1, k, 0.0); // exact by construction
                if k + 1 < hi {
                    y = b.get(k, k + 1);
                    z = b.get(k, k + 2);
                }
            }
        }
    }

    // Extract singular values; make them non-negative.
    let mut sigma: Vec<f32> = (0..n).map(|i| b.get(i, i)).collect();
    for (i, s) in sigma.iter_mut().enumerate() {
        if *s < 0.0 {
            *s = -*s;
            for c in 0..vt_acc.cols {
                let v = vt_acc.get(i, c);
                vt_acc.set(i, c, -v);
            }
        }
    }

    BidiagSvd { u: u_acc, sigma, vt: vt_acc, iterations, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::trace::NullSink;
    use crate::ttd::svd::bidiag::bidiagonalize;
    use crate::util::Rng;

    fn rand_bidiag(rng: &mut Rng, n: usize) -> Matrix {
        let mut b = Matrix::zeros(n, n);
        for i in 0..n {
            b.set(i, i, rng.normal() as f32);
            if i + 1 < n {
                b.set(i, i + 1, rng.normal() as f32);
            }
        }
        b
    }

    fn reconstruct(u: &Matrix, s: &[f32], vt: &Matrix) -> Matrix {
        let mut us = u.clone();
        for r in 0..us.rows {
            for c in 0..us.cols {
                let v = us.get(r, c) * s[c];
                us.set(r, c, v);
            }
        }
        us.matmul(vt)
    }

    #[test]
    fn diagonalizes_random_bidiagonal() {
        check(20, 400, |rng| {
            let n = 2 + rng.below(24);
            let b = rand_bidiag(rng, n);
            let svd = diagonalize(&b, Matrix::eye(n, n), Matrix::eye(n, n), &mut NullSink);
            let recon = reconstruct(&svd.u, &svd.sigma, &svd.vt);
            let scale = b.frobenius().max(1.0);
            assert!(
                recon.max_abs_diff(&b) / scale < 2e-4,
                "n={n} err={}",
                recon.max_abs_diff(&b) / scale
            );
            assert!(svd.sigma.iter().all(|s| *s >= 0.0));
        });
    }

    #[test]
    fn orthogonality_of_accumulated_factors() {
        check(10, 401, |rng| {
            let n = 2 + rng.below(16);
            let b = rand_bidiag(rng, n);
            let svd = diagonalize(&b, Matrix::eye(n, n), Matrix::eye(n, n), &mut NullSink);
            assert!(svd.u.transpose().matmul(&svd.u).max_abs_diff(&Matrix::eye(n, n)) < 3e-4);
            assert!(svd.vt.matmul(&svd.vt.transpose()).max_abs_diff(&Matrix::eye(n, n)) < 3e-4);
        });
    }

    #[test]
    fn convergence_is_qr_fast() {
        // Implicit shift should need only a few iterations per value.
        let mut rng = Rng::new(50);
        let n = 32;
        let b = rand_bidiag(&mut rng, n);
        let svd = diagonalize(&b, Matrix::eye(n, n), Matrix::eye(n, n), &mut NullSink);
        assert!(svd.iterations < 8 * n, "iterations {}", svd.iterations);
        assert!(svd.converged, "well-conditioned input must converge within the cap");
    }

    #[test]
    fn full_svd_through_hbd_matches_frobenius() {
        // ||sigma||_2 == ||A||_F
        check(10, 402, |rng| {
            let n = 2 + rng.below(10);
            let m = n + rng.below(16);
            let a = Matrix::from_vec(m, n, rng.normal_vec(m * n));
            let f = bidiagonalize(&a, &mut NullSink);
            let svd = diagonalize(&f.b, f.u, f.vt, &mut NullSink);
            let s_norm: f32 =
                svd.sigma.iter().map(|s| (*s as f64) * (*s as f64)).sum::<f64>().sqrt() as f32;
            let fa = a.frobenius();
            assert!((s_norm - fa).abs() / fa.max(1.0) < 1e-4, "{s_norm} vs {fa}");
        });
    }

    #[test]
    fn handles_exact_zero_diagonal() {
        let mut b = Matrix::zeros(4, 4);
        b.set(0, 0, 1.0);
        b.set(0, 1, 2.0);
        b.set(1, 1, 0.0); // exact zero diagonal inside the block
        b.set(1, 2, 1.5);
        b.set(2, 2, 3.0);
        b.set(2, 3, 0.5);
        b.set(3, 3, 2.0);
        let svd = diagonalize(&b, Matrix::eye(4, 4), Matrix::eye(4, 4), &mut NullSink);
        let recon = reconstruct(&svd.u, &svd.sigma, &svd.vt);
        assert!(recon.max_abs_diff(&b) < 1e-4, "err {}", recon.max_abs_diff(&b));
    }

    #[test]
    fn identity_input_yields_unit_singular_values() {
        let b = Matrix::eye(5, 5);
        let svd = diagonalize(&b, Matrix::eye(5, 5), Matrix::eye(5, 5), &mut NullSink);
        for s in &svd.sigma {
            assert!((s - 1.0).abs() < 1e-6);
        }
    }
}
