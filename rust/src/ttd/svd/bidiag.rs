//! Householder bidiagonalization — Algorithm 2 verbatim, with the
//! HW-op trace the simulator replays.
//!
//! Phase 1 (*Householder Reduction*) stores each Householder vector in
//! place of the entries it annihilated (Alg. 2 keeps `v` in `A` / the
//! SPM — the on-chip-retention idea); phase 2 (*Householder
//! Accumulation*) replays them backwards to form `U_B` and `V_B^T`.
//!
//! Phase 2 is the O(mn^2) hot half of HBD. The default path
//! accumulates reflectors in blocked compact-WY panels — each panel of
//! up to [`WY_PANEL`] reflectors applies as `I - V T V^T` through two
//! GEMM passes over the existing blocked [`matmul_acc`] kernel instead
//! of one rank-1 sweep per reflector. The **emitted op stream is the
//! per-reflector Algorithm-2 stream in both modes**: op sizes are
//! shape-only functions of `(m, n, i)`, so golden traces and the
//! calibrated Table-III anchors are untouched by construction
//! ([`bidiagonalize_reference`] keeps the rank-1 reference loop
//! available; the trace-equality + numeric-agreement pins live in
//! this module's tests).
//!
//! PR-7 adds two host-speed refinements to the blocked path, both
//! invisible to the trace and to the numerics bit-for-bit:
//!
//! * the five per-panel work buffers live in one [`WyScratch`] sized
//!   once per factorization (the PR-5 pivot-scratch pattern — zero
//!   allocations per panel, asserted in tests);
//! * the panel GEMM passes optionally split their **output row
//!   bands** across `std::thread::scope` workers ([`panel_threads`]).
//!   A row band leaves every element's k-accumulation chain
//!   untouched, so any worker count produces bit-identical panels —
//!   and row-major row bands are disjoint `&mut` chunks, so the split
//!   needs no unsafe striding.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::trace::{HwOp, TraceSink};
use crate::ttd::svd::house::house;
use crate::ttd::tensor::{matmul_acc, Matrix};

// Process-global in-layer parallelism width: 0 = unresolved (read the
// TTEDGE_HBD_THREADS env var on first use). Relaxed is enough — every
// width is bit-identical, so racing readers cannot change results.
static PANEL_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Workers the compact-WY panel GEMMs fan their row bands across.
/// Defaults to 1 (serial) unless the `TTEDGE_HBD_THREADS` env var
/// says otherwise; jobs set it through `CompressionJob::hbd_threads`.
/// Composes with pipeline-level layer fan-out: layers x in-layer
/// bands.
pub fn panel_threads() -> usize {
    match PANEL_THREADS.load(Ordering::Relaxed) {
        0 => {
            let threads = std::env::var("TTEDGE_HBD_THREADS")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(1)
                .max(1);
            PANEL_THREADS.store(threads, Ordering::Relaxed);
            threads
        }
        threads => threads,
    }
}

/// Select the process-wide panel-parallelism width (clamped to >= 1).
pub fn set_panel_threads(threads: usize) {
    PANEL_THREADS.store(threads.max(1), Ordering::Relaxed);
}

/// Reflectors per compact-WY accumulation panel. 32 keeps `T` and the
/// panel buffers L1-resident for the workload's n <= 64 while the two
/// panel GEMMs amortize the per-reflector pass over `U`/`V^T`.
const WY_PANEL: usize = 32;

/// `A = U_B B V_B^T` for tall `A` (m >= n): `u` (m, n) orthonormal
/// columns, `b` (n, n) upper bidiagonal, `vt` (n, n) orthogonal.
pub struct Bidiag {
    pub u: Matrix,
    pub b: Matrix,
    pub vt: Matrix,
}

/// Householder bidiagonalization of a tall matrix (Algorithm 2),
/// blocked compact-WY accumulation (the default hot path).
///
/// Every hardware-visible primitive is reported to `sink`: HOUSE
/// generations (norm streams), VEC-DIVISIONs, and the two chained
/// GEMMs per HOUSE_MM_UPDATE with their true block sizes.
pub fn bidiagonalize<S: TraceSink>(a: &Matrix, sink: &mut S) -> Bidiag {
    bidiagonalize_with(a, sink, false)
}

/// [`bidiagonalize`] with the per-reflector rank-1 accumulation loop
/// (Algorithm 2 lines 14-18 verbatim). Same factorization up to
/// floating-point rounding and the **identical** op stream; kept as
/// the naive reference the blocked path is pinned against in tests
/// and measured against in `benches/hotpath.rs`.
pub fn bidiagonalize_reference<S: TraceSink>(a: &Matrix, sink: &mut S) -> Bidiag {
    bidiagonalize_with(a, sink, true)
}

fn bidiagonalize_with<S: TraceSink>(a: &Matrix, sink: &mut S, naive: bool) -> Bidiag {
    let (m, n) = (a.rows, a.cols);
    let red = reduce(a, sink);

    // ---- Householder Accumulation (Alg. 2, lines 14-18) ----
    // U_B = H^L_1 .. H^L_n I  (apply backwards, left-multiplying);
    // V_B^T = I H^R_n .. H^R_1 (apply backwards, right-multiplying).
    //
    // The op stream is emitted per reflector in the backward Alg.-2
    // order in BOTH modes — sizes depend only on (m, n, i) and on
    // which reflectors are degenerate, never on how the numerics
    // batch the arithmetic (or across how many panel workers).
    for i in (0..n).rev() {
        let (v, _) = &red.vl[i];
        if !v.is_empty() {
            sink.op(HwOp::VecDiv { len: v.len() });
            sink.op(HwOp::Gemm { m: 1, n: n - i, k: m - i });
            sink.op(HwOp::Gemm { m: m - i, n: n - i, k: 1 });
        }
        let (v, _) = &red.vr[i];
        if !v.is_empty() {
            sink.op(HwOp::VecDiv { len: v.len() });
            sink.op(HwOp::Gemm { m: n - i, n: 1, k: n - i - 1 });
            sink.op(HwOp::Gemm { m: n - i, n: n - i - 1, k: 1 });
        }
    }

    let (u, vt) = if naive {
        accumulate_reference(m, n, &red.vl, &red.vr, &mut vec![0.0f32; n])
    } else {
        let threads = panel_threads();
        let mut wy = WyScratch::for_shape(m, n);
        let u = accumulate_u_blocked(m, n, &red.vl, &mut wy, threads);
        let vt = accumulate_vt_blocked(n, &red.vr, &mut wy, threads);
        // Hard assert (the PR-7 rule): a release-mode realloc means a
        // panel was mis-sized — the zero-alloc contract the benches
        // self-assert against would rot silently under debug_assert.
        // O(1), checked once per factorization.
        assert_eq!(wy.reallocs, 0, "WY scratch must be sized once per factorization");
        (u, vt)
    };

    Bidiag { u, b: red.b, vt }
}

/// The reduction phase's outputs: the bidiagonal `b` plus the
/// SPM-retained left/right reflector stores the accumulation phase
/// replays.
struct Reduction {
    b: Matrix,
    vl: Vec<(Vec<f32>, f32)>,
    vr: Vec<(Vec<f32>, f32)>,
}

/// Householder Reduction (Alg. 2, lines 4-13), shared by both
/// accumulation modes.
fn reduce<S: TraceSink>(a: &Matrix, sink: &mut S) -> Reduction {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "bidiagonalize expects tall input, got {m}x{n}");
    let mut a = a.clone();
    let mut b = Matrix::zeros(n, n);

    // Householder vector store — the SPM-retained vectors.
    let mut vl: Vec<(Vec<f32>, f32)> = Vec::with_capacity(n);
    let mut vr: Vec<(Vec<f32>, f32)> = Vec::with_capacity(n);
    // Scratch reused across all reflectors: one buffer for the left
    // rank-1 updates (widths <= n) and one gather buffer for the
    // pivot column/row HOUSE inputs (lengths <= m) — the hot loop
    // allocates nothing per reflector beyond the retained `v`.
    let mut scratch = vec![0.0f32; n];
    let mut gather = vec![0.0f32; m];

    for i in 0..n {
        // Left transform: annihilate sub-diagonal of column i.
        let x = &mut gather[..m - i];
        for (slot, r) in x.iter_mut().zip(i..m) {
            *slot = a.get(r, i);
        }
        sink.op(HwOp::HouseGen { len: m - i });
        let h = house(x);
        b.set(i, i, if h.q != 0.0 { h.q } else { x[0] });
        if !h.v.is_empty() {
            sink.op(HwOp::VecDiv { len: h.v.len() });
            // Two chained GEMMs over A[i.., i+1..]: (1 x w) = v^T A,
            // then the (h x w) rank-1 update.
            let (hh, ww) = (m - i, n - i - 1);
            if ww > 0 {
                sink.op(HwOp::Gemm { m: 1, n: ww, k: hh });
                sink.op(HwOp::Gemm { m: hh, n: ww, k: 1 });
                a.apply_house_left(i, i + 1, &h.v, h.beta, &mut scratch);
            }
            // exact cleanup of the pivot column
            for r in i + 1..m {
                a.set(r, i, 0.0);
            }
            a.set(i, i, b.get(i, i));
        }
        vl.push((h.v, h.beta));

        // Right transform: annihilate row i beyond the superdiagonal.
        if i + 2 < n {
            let y = &mut gather[..n - i - 1];
            for (slot, c) in y.iter_mut().zip(i + 1..n) {
                *slot = a.get(i, c);
            }
            sink.op(HwOp::HouseGen { len: n - i - 1 });
            let h = house(y);
            b.set(i, i + 1, if h.q != 0.0 { h.q } else { y[0] });
            if !h.v.is_empty() {
                sink.op(HwOp::VecDiv { len: h.v.len() });
                let (hh, ww) = (m - i - 1, n - i - 1);
                sink.op(HwOp::Gemm { m: hh, n: 1, k: ww });
                sink.op(HwOp::Gemm { m: hh, n: ww, k: 1 });
                a.apply_house_right(i + 1, i + 1, &h.v, h.beta);
                for c in i + 2..n {
                    a.set(i, c, 0.0);
                }
                a.set(i, i + 1, b.get(i, i + 1));
            }
            vr.push((h.v, h.beta));
        } else {
            if i + 1 < n {
                b.set(i, i + 1, a.get(i, i + 1));
            }
            vr.push((Vec::new(), 1.0));
        }
    }

    Reduction { b, vl, vr }
}

/// Per-reflector backward accumulation — the Algorithm-2 reference.
fn accumulate_reference(
    m: usize,
    n: usize,
    vl: &[(Vec<f32>, f32)],
    vr: &[(Vec<f32>, f32)],
    scratch: &mut [f32],
) -> (Matrix, Matrix) {
    let mut u = Matrix::eye(m, n);
    let mut vt = Matrix::eye(n, n);
    for i in (0..n).rev() {
        let (v, beta) = &vl[i];
        if !v.is_empty() {
            u.apply_house_left(i, i, v, *beta, scratch);
        }
        let (v, beta) = &vr[i];
        if !v.is_empty() {
            vt.apply_house_right(i, i + 1, v, *beta);
        }
    }
    (u, vt)
}

/// The five compact-WY panel work buffers, sized once per
/// factorization and reused by every panel of both accumulation
/// passes — the hot half of HBD performs **zero** allocations per
/// panel (pinned in tests via the `reallocs` growth counter).
struct WyScratch {
    v_mat: Vec<f32>,
    vt_mat: Vec<f32>,
    t_mat: Vec<f32>,
    s_buf: Vec<f32>,
    w: Vec<f32>,
    w2: Vec<f32>,
    /// Times a panel had to grow a buffer — 0 by construction when
    /// the scratch was sized with [`WyScratch::for_shape`].
    reallocs: usize,
}

impl WyScratch {
    /// Size every buffer for the worst panel of an `m x n` (tall)
    /// factorization: panels hold `p <= WY_PANEL` reflectors, the U
    /// pass spans up to `m` rows, the VT pass up to `n <= m`.
    fn for_shape(m: usize, n: usize) -> Self {
        let p = WY_PANEL.min(n).max(1);
        WyScratch {
            v_mat: vec![0.0; m * p],
            vt_mat: vec![0.0; p * m],
            t_mat: vec![0.0; p * p],
            s_buf: vec![0.0; p],
            w: vec![0.0; p * n],
            w2: vec![0.0; p * n],
            reallocs: 0,
        }
    }
}

/// Borrow `len` zeroed slots from a scratch buffer, growing (and
/// counting the growth) only when undersized.
fn borrow_zeroed<'a>(buf: &'a mut Vec<f32>, len: usize, reallocs: &mut usize) -> &'a mut [f32] {
    if buf.len() < len {
        *reallocs += 1;
        buf.resize(len, 0.0);
    }
    let s = &mut buf[..len];
    s.fill(0.0);
    s
}

/// Split the `m` rows of `out` (row-major, `n` columns each) into one
/// contiguous band per worker and run `f(first_row, band)` on scoped
/// threads. Row bands partition the output and every element keeps
/// its full serial k-accumulation chain, so any worker count is
/// bit-identical to `f(0, out)`; width <= 1 runs inline with no
/// thread traffic.
fn par_row_bands<F>(threads: usize, m: usize, n: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let body = &mut out[..m * n];
    let workers = if n == 0 { 1 } else { threads.max(1).min(m.max(1)) };
    if workers <= 1 {
        f(0, body);
        return;
    }
    let chunk = m.div_ceil(workers);
    std::thread::scope(|scope| {
        for (bi, band) in body.chunks_mut(chunk * n).enumerate() {
            let f = &f;
            scope.spawn(move || f(bi * chunk, band));
        }
    });
}

/// `U_B = H^L_{p0} .. H^L_{n-1} E` accumulated panel by panel from the
/// top index down, each panel applied as `U <- (I - V T V^T) U` — two
/// blocked-GEMM passes over `U` instead of one rank-1 pass per
/// reflector. Exact restriction: reflector i only sees rows i.. of
/// `U`, and the rows/columns a panel nominally over-covers are still
/// unit-basis (only later reflectors touch them), so their panel
/// contributions are exactly zero.
fn accumulate_u_blocked(
    m: usize,
    n: usize,
    vl: &[(Vec<f32>, f32)],
    scratch: &mut WyScratch,
    threads: usize,
) -> Matrix {
    let mut u = Matrix::eye(m, n);
    let mut p1 = n;
    while p1 > 0 {
        let p0 = p1.saturating_sub(WY_PANEL);
        // H_i = I - tau_i v_i v_i^T (tau = -1/beta); the backward loop
        // applies H_{p0} leftmost, so the panel product appends each
        // higher-index reflector on the RIGHT: increasing seat order.
        let seats: Vec<usize> =
            (p0..p1).filter(|&i| !vl[i].0.is_empty()).collect();
        let nb = seats.len();
        if nb > 0 {
            let r0 = seats[0];
            let rows = m - r0;
            let WyScratch { v_mat, vt_mat, t_mat, s_buf, w, w2, reallocs } = scratch;
            let v_mat = borrow_zeroed(v_mat, rows * nb, reallocs);
            let vt_mat = borrow_zeroed(vt_mat, nb * rows, reallocs);
            embed_panel(&seats, vl, r0, rows, 0, v_mat, vt_mat);
            let t_mat = borrow_zeroed(t_mat, nb * nb, reallocs);
            wy_t(&seats, vl, 0, t_mat, borrow_zeroed(s_buf, nb, reallocs));
            let w = borrow_zeroed(w, nb * n, reallocs);
            let w2 = borrow_zeroed(w2, nb * n, reallocs);
            let (v_mat, vt_mat, t_mat) = (&*v_mat, &*vt_mat, &*t_mat);
            // W = V^T U[r0..]  (first big GEMM, banded over the nb
            // output rows when in-layer parallelism is on)
            let u_top = &u.data[r0 * n..];
            par_row_bands(threads, nb, n, w, |b0, band| {
                matmul_acc(band.len() / n, rows, n, &vt_mat[b0 * rows..], u_top, band);
            });
            // W2 = -(T W)  (small triangular apply, serial)
            matmul_acc(nb, nb, n, t_mat, w, w2);
            for x in w2.iter_mut() {
                *x = -*x;
            }
            // U[r0..] += V W2  (second big GEMM, banded over `rows`)
            let w2 = &*w2;
            par_row_bands(threads, rows, n, &mut u.data[r0 * n..], |b0, band| {
                matmul_acc(band.len() / n, nb, n, &v_mat[b0 * nb..], w2, band);
            });
        }
        p1 = p0;
    }
    u
}

/// `V_B^T = E G_{n-1} .. G_0` accumulated panel by panel, each panel
/// applied as `VT <- VT (I - V T V^T)` (right reflector `G_i` acts on
/// columns i+1..; the backward loop right-multiplies the highest index
/// first, so the panel product appends DECREASING seats on the right).
fn accumulate_vt_blocked(
    n: usize,
    vr: &[(Vec<f32>, f32)],
    scratch: &mut WyScratch,
    threads: usize,
) -> Matrix {
    let mut vt = Matrix::eye(n, n);
    let mut p1 = n;
    while p1 > 0 {
        let p0 = p1.saturating_sub(WY_PANEL);
        let seats: Vec<usize> =
            (p0..p1).rev().filter(|&i| !vr[i].0.is_empty()).collect();
        let nb = seats.len();
        if nb > 0 {
            let r0 = *seats.last().expect("nb > 0");
            let rows = n - r0;
            let WyScratch { v_mat, vt_mat, t_mat, s_buf, w, w2, reallocs } = scratch;
            // reflector i spans columns i+1..n of the n-wide basis
            let v_mat = borrow_zeroed(v_mat, n * nb, reallocs);
            let vt_mat = borrow_zeroed(vt_mat, nb * n, reallocs);
            embed_panel(&seats, vr, 0, n, 1, v_mat, vt_mat);
            let t_mat = borrow_zeroed(t_mat, nb * nb, reallocs);
            wy_t(&seats, vr, 1, t_mat, borrow_zeroed(s_buf, nb, reallocs));
            let w = borrow_zeroed(w, rows * nb, reallocs);
            let w2 = borrow_zeroed(w2, rows * nb, reallocs);
            let (v_mat, vt_mat, t_mat) = (&*v_mat, &*vt_mat, &*t_mat);
            let sub = &mut vt.data[r0 * n..];
            // All three panel GEMMs touch only their own row band of
            // VT[r0..] (W and W2 band along with it), so the whole
            // chain fans out in one scope per panel:
            //   W = VT[r0..] V ; W2 = -(W T) ; VT[r0..] += W2 V^T.
            let run_band = |sub_b: &mut [f32], w_b: &mut [f32], w2_b: &mut [f32]| {
                let br = sub_b.len() / n;
                matmul_acc(br, n, nb, sub_b, v_mat, w_b);
                matmul_acc(br, nb, nb, w_b, t_mat, w2_b);
                for x in w2_b.iter_mut() {
                    *x = -*x;
                }
                matmul_acc(br, nb, n, w2_b, vt_mat, sub_b);
            };
            let workers = threads.max(1).min(rows);
            if workers <= 1 {
                run_band(sub, w, w2);
            } else {
                let chunk = rows.div_ceil(workers);
                std::thread::scope(|scope| {
                    let bands = sub
                        .chunks_mut(chunk * n)
                        .zip(w.chunks_mut(chunk * nb))
                        .zip(w2.chunks_mut(chunk * nb));
                    for ((sub_b, w_b), w2_b) in bands {
                        let run_band = &run_band;
                        scope.spawn(move || run_band(sub_b, w_b, w2_b));
                    }
                });
            }
        }
        p1 = p0;
    }
    vt
}

/// Materialize a panel's reflector block into scratch: `v_mat` is `V`
/// (`rows` x nb, row-major) and `vt_mat` is `V^T` (nb x `rows`), with
/// reflector `seats[j]` embedded at offset `seats[j] + shift - r0`
/// (left panels: shift 0, seated on the diagonal row; right panels:
/// shift 1, seated one past the diagonal column). Both outputs must
/// arrive zeroed.
fn embed_panel(
    seats: &[usize],
    vs: &[(Vec<f32>, f32)],
    r0: usize,
    rows: usize,
    shift: usize,
    v_mat: &mut [f32],
    vt_mat: &mut [f32],
) {
    // lint: hotpath
    let nb = seats.len();
    for (j, &s) in seats.iter().enumerate() {
        let (v, _) = &vs[s];
        let off = s + shift - r0;
        for (t, &x) in v.iter().enumerate() {
            v_mat[(off + t) * nb + j] = x;
            vt_mat[j * rows + off + t] = x;
        }
    }
}

/// Upper-triangular compact-WY factor for the panel product
/// `Q = H_{seats[0]} H_{seats[1]} ..` with `H = I - tau v v^T`:
/// appending `H_j` on the right extends `T` by the column
/// `[-tau_j T (V^T v_j); tau_j]` (Schreiber–Van Loan). `t_mat`
/// (nb x nb) must arrive zeroed; `s_buf` (nb) is pure scratch.
fn wy_t(
    seats: &[usize],
    vs: &[(Vec<f32>, f32)],
    shift: usize,
    t_mat: &mut [f32],
    s_buf: &mut [f32],
) {
    // lint: hotpath
    let nb = seats.len();
    for (j, &sj) in seats.iter().enumerate() {
        let (vj, beta) = &vs[sj];
        let tau = -1.0 / *beta;
        let start_j = sj + shift;
        for (a, &sa) in seats[..j].iter().enumerate() {
            let (va, _) = &vs[sa];
            let start_a = sa + shift;
            // overlap dot: both vectors run to the same end row/col
            let (lead, tail, skip) = if start_a <= start_j {
                (va, vj, start_j - start_a)
            } else {
                (vj, va, start_a - start_j)
            };
            let mut dot = 0.0f32;
            for (x, y) in lead[skip..].iter().zip(tail.iter()) {
                dot += x * y;
            }
            s_buf[a] = dot;
        }
        for a in 0..j {
            let mut acc = 0.0f32;
            for b in a..j {
                acc += t_mat[a * nb + b] * s_buf[b];
            }
            t_mat[a * nb + j] = -tau * acc;
        }
        t_mat[j * nb + j] = tau;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::trace::{NullSink, VecSink};
    use crate::util::Rng;

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_vec(r, c, rng.normal_vec(r * c))
    }

    fn is_upper_bidiagonal(b: &Matrix) -> bool {
        for r in 0..b.rows {
            for c in 0..b.cols {
                if c != r && c != r + 1 && b.get(r, c) != 0.0 {
                    return false;
                }
            }
        }
        true
    }

    #[test]
    fn factorization_reconstructs_input() {
        check(15, 300, |rng| {
            let n = 2 + rng.below(16);
            let m = n + rng.below(24);
            let a = rand_mat(rng, m, n);
            let f = bidiagonalize(&a, &mut NullSink);
            let recon = f.u.matmul(&f.b).matmul(&f.vt);
            let scale = a.frobenius().max(1.0);
            assert!(
                recon.max_abs_diff(&a) / scale < 1e-4,
                "err {}",
                recon.max_abs_diff(&a) / scale
            );
            assert!(is_upper_bidiagonal(&f.b));
        });
    }

    #[test]
    fn factors_are_orthogonal() {
        check(10, 301, |rng| {
            let n = 2 + rng.below(12);
            let m = n + rng.below(12);
            let a = rand_mat(rng, m, n);
            let f = bidiagonalize(&a, &mut NullSink);
            let utu = f.u.transpose().matmul(&f.u);
            assert!(utu.max_abs_diff(&Matrix::eye(n, n)) < 1e-4);
            let vvt = f.vt.matmul(&f.vt.transpose());
            assert!(vvt.max_abs_diff(&Matrix::eye(n, n)) < 1e-4);
        });
    }

    #[test]
    fn blocked_accumulation_matches_reference_numerics_and_trace() {
        // The PR-5 acceptance pin: identical op stream by construction,
        // same factorization up to rounding — across panel-boundary
        // shapes (n < panel, n == panel, n > panel) and a rank-deficient
        // input that degenerates some reflectors.
        check(12, 305, |rng| {
            let n = 2 + rng.below(40); // crosses WY_PANEL = 32
            let m = n + rng.below(24);
            let a = rand_mat(rng, m, n);
            let mut blocked_trace = VecSink::default();
            let mut reference_trace = VecSink::default();
            let blocked = bidiagonalize(&a, &mut blocked_trace);
            let reference = bidiagonalize_reference(&a, &mut reference_trace);
            assert_eq!(blocked_trace.ops, reference_trace.ops, "op streams diverged");
            assert_eq!(blocked.b.data, reference.b.data, "reduction phase is shared");
            let tol = 1e-4 * (n as f32).sqrt();
            assert!(
                blocked.u.max_abs_diff(&reference.u) < tol,
                "U diverged by {}",
                blocked.u.max_abs_diff(&reference.u)
            );
            assert!(
                blocked.vt.max_abs_diff(&reference.vt) < tol,
                "V^T diverged by {}",
                blocked.vt.max_abs_diff(&reference.vt)
            );
        });
    }

    #[test]
    fn blocked_accumulation_matches_reference_on_rank_deficient_input() {
        let mut rng = Rng::new(47);
        let left = rand_mat(&mut rng, 40, 3);
        let right = rand_mat(&mut rng, 3, 36);
        let a = left.matmul(&right);
        let mut t1 = VecSink::default();
        let mut t2 = VecSink::default();
        let blocked = bidiagonalize(&a, &mut t1);
        let reference = bidiagonalize_reference(&a, &mut t2);
        assert_eq!(t1.ops, t2.ops);
        assert!(blocked.u.max_abs_diff(&reference.u) < 1e-3);
        assert!(blocked.vt.max_abs_diff(&reference.vt) < 1e-3);
    }

    #[test]
    fn handles_rank_deficient_input() {
        let mut rng = Rng::new(44);
        let left = rand_mat(&mut rng, 12, 2);
        let right = rand_mat(&mut rng, 2, 6);
        let a = left.matmul(&right);
        let f = bidiagonalize(&a, &mut NullSink);
        let recon = f.u.matmul(&f.b).matmul(&f.vt);
        assert!(recon.max_abs_diff(&a) < 1e-3);
        assert!(f.b.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn square_input_works() {
        let mut rng = Rng::new(45);
        let a = rand_mat(&mut rng, 8, 8);
        let f = bidiagonalize(&a, &mut NullSink);
        assert!(f.u.matmul(&f.b).matmul(&f.vt).max_abs_diff(&a) < 1e-4);
    }

    #[test]
    fn trace_contains_expected_op_mix() {
        let mut rng = Rng::new(46);
        let a = rand_mat(&mut rng, 20, 8);
        let mut sink = VecSink::default();
        let _ = bidiagonalize(&a, &mut sink);
        use crate::trace::HwOp::*;
        // n left + (n-2) right HOUSE generations
        let gens = sink.count(|o| matches!(o, HouseGen { .. }));
        assert_eq!(gens, 8 + 6);
        // every non-degenerate transform issues exactly two GEMMs
        let gemms = sink.count(|o| matches!(o, Gemm { .. }));
        assert!(gemms > 0 && gemms % 2 == 0);
        // first HOUSE spans the full column
        assert!(sink.ops.iter().any(|o| matches!(o, HouseGen { len: 20 })));
    }

    #[test]
    fn wy_scratch_is_sized_once_with_zero_panel_growth() {
        // The PR-7 allocation bugfix pin: a for_shape scratch carries
        // every panel of both accumulation passes — and repeated
        // factorizations — without a single buffer growth.
        let mut rng = Rng::new(48);
        let a = rand_mat(&mut rng, 80, 48); // two WY panels per pass
        let red = reduce(&a, &mut NullSink);
        let mut wy = WyScratch::for_shape(80, 48);
        let u = accumulate_u_blocked(80, 48, &red.vl, &mut wy, 1);
        let vt = accumulate_vt_blocked(48, &red.vr, &mut wy, 1);
        let u_again = accumulate_u_blocked(80, 48, &red.vl, &mut wy, 1);
        assert_eq!(wy.reallocs, 0, "panels must reuse the once-sized scratch");
        assert_eq!(u_again.data, u.data, "scratch reuse must not leak state");
        // the counter is live: an undersized scratch grows and says so,
        // while the grown buffers still produce identical panels
        let mut tiny = WyScratch::for_shape(2, 2);
        let u_grown = accumulate_u_blocked(80, 48, &red.vl, &mut tiny, 1);
        assert!(tiny.reallocs > 0, "undersized scratch must count its growth");
        assert_eq!(u_grown.data, u.data);
        let _ = vt;
    }

    #[test]
    fn panel_parallel_accumulation_is_bit_identical_to_serial() {
        check(8, 306, |rng| {
            let n = 2 + rng.below(40); // crosses WY_PANEL = 32
            let m = n + rng.below(24);
            let a = rand_mat(rng, m, n);
            let red = reduce(&a, &mut NullSink);
            let mut wy = WyScratch::for_shape(m, n);
            let u1 = accumulate_u_blocked(m, n, &red.vl, &mut wy, 1);
            let vt1 = accumulate_vt_blocked(n, &red.vr, &mut wy, 1);
            for threads in [2, 4, 8] {
                let up = accumulate_u_blocked(m, n, &red.vl, &mut wy, threads);
                let vtp = accumulate_vt_blocked(n, &red.vr, &mut wy, threads);
                assert_eq!(up.data, u1.data, "U diverged at width {threads} ({m}x{n})");
                assert_eq!(vtp.data, vt1.data, "V^T diverged at width {threads} ({m}x{n})");
            }
            assert_eq!(wy.reallocs, 0);
        });
    }

    #[test]
    fn bidiagonalize_is_panel_thread_invariant() {
        // End-to-end: the thread knob changes neither the op stream
        // nor a single output bit. Restores the process-global width
        // afterwards; a concurrent test observing width 3 is benign
        // because every width is bit-identical.
        let mut rng = Rng::new(49);
        let a = rand_mat(&mut rng, 40, 36);
        let before = panel_threads();
        set_panel_threads(1);
        let mut serial_trace = VecSink::default();
        let serial = bidiagonalize(&a, &mut serial_trace);
        set_panel_threads(3);
        let mut par_trace = VecSink::default();
        let par = bidiagonalize(&a, &mut par_trace);
        set_panel_threads(before);
        assert_eq!(serial_trace.ops, par_trace.ops, "op stream saw the thread knob");
        assert_eq!(par.u.data, serial.u.data);
        assert_eq!(par.b.data, serial.b.data);
        assert_eq!(par.vt.data, serial.vt.data);
    }

    #[test]
    fn bidiagonal_preserves_singular_values_vs_gram_trace() {
        // ||A||_F^2 == ||B||_F^2 (orthogonal invariance).
        check(10, 302, |rng| {
            let n = 2 + rng.below(10);
            let m = n + rng.below(10);
            let a = rand_mat(rng, m, n);
            let f = bidiagonalize(&a, &mut NullSink);
            let fa = a.frobenius();
            let fb = f.b.frobenius();
            assert!((fa - fb).abs() / fa.max(1.0) < 1e-4, "{fa} vs {fb}");
        });
    }
}
