//! Householder bidiagonalization — Algorithm 2 verbatim, with the
//! HW-op trace the simulator replays.
//!
//! Phase 1 (*Householder Reduction*) stores each Householder vector in
//! place of the entries it annihilated (Alg. 2 keeps `v` in `A` / the
//! SPM — the on-chip-retention idea); phase 2 (*Householder
//! Accumulation*) replays them backwards to form `U_B` and `V_B^T`.

use crate::trace::{HwOp, TraceSink};
use crate::ttd::svd::house::house;
use crate::ttd::tensor::Matrix;

/// `A = U_B B V_B^T` for tall `A` (m >= n): `u` (m, n) orthonormal
/// columns, `b` (n, n) upper bidiagonal, `vt` (n, n) orthogonal.
pub struct Bidiag {
    pub u: Matrix,
    pub b: Matrix,
    pub vt: Matrix,
}

/// Householder bidiagonalization of a tall matrix (Algorithm 2).
///
/// Every hardware-visible primitive is reported to `sink`: HOUSE
/// generations (norm streams), VEC-DIVISIONs, and the two chained
/// GEMMs per HOUSE_MM_UPDATE with their true block sizes.
pub fn bidiagonalize<S: TraceSink>(a: &Matrix, sink: &mut S) -> Bidiag {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "bidiagonalize expects tall input, got {m}x{n}");
    let mut a = a.clone();
    let mut b = Matrix::zeros(n, n);

    // Householder vector store — the SPM-retained vectors.
    let mut vl: Vec<(Vec<f32>, f32)> = Vec::with_capacity(n);
    let mut vr: Vec<(Vec<f32>, f32)> = Vec::with_capacity(n);
    // One scratch buffer reused by every left rank-1 update (all
    // widths are <= n): the hot loop allocates nothing per reflector.
    let mut scratch = vec![0.0f32; n];

    // ---- Householder Reduction (Alg. 2, lines 4-13) ----
    for i in 0..n {
        // Left transform: annihilate sub-diagonal of column i.
        let x: Vec<f32> = (i..m).map(|r| a.get(r, i)).collect();
        sink.op(HwOp::HouseGen { len: x.len() });
        let h = house(&x);
        b.set(i, i, if h.q != 0.0 { h.q } else { x[0] });
        if !h.v.is_empty() {
            sink.op(HwOp::VecDiv { len: h.v.len() });
            // Two chained GEMMs over A[i.., i+1..]: (1 x w) = v^T A,
            // then the (h x w) rank-1 update.
            let (hh, ww) = (m - i, n - i - 1);
            if ww > 0 {
                sink.op(HwOp::Gemm { m: 1, n: ww, k: hh });
                sink.op(HwOp::Gemm { m: hh, n: ww, k: 1 });
                a.apply_house_left(i, i + 1, &h.v, h.beta, &mut scratch);
            }
            // exact cleanup of the pivot column
            for r in i + 1..m {
                a.set(r, i, 0.0);
            }
            a.set(i, i, b.get(i, i));
        }
        vl.push((h.v, h.beta));

        // Right transform: annihilate row i beyond the superdiagonal.
        if i + 2 < n {
            let y: Vec<f32> = (i + 1..n).map(|c| a.get(i, c)).collect();
            sink.op(HwOp::HouseGen { len: y.len() });
            let h = house(&y);
            b.set(i, i + 1, if h.q != 0.0 { h.q } else { y[0] });
            if !h.v.is_empty() {
                sink.op(HwOp::VecDiv { len: h.v.len() });
                let (hh, ww) = (m - i - 1, n - i - 1);
                sink.op(HwOp::Gemm { m: hh, n: 1, k: ww });
                sink.op(HwOp::Gemm { m: hh, n: ww, k: 1 });
                a.apply_house_right(i + 1, i + 1, &h.v, h.beta);
                for c in i + 2..n {
                    a.set(i, c, 0.0);
                }
                a.set(i, i + 1, b.get(i, i + 1));
            }
            vr.push((h.v, h.beta));
        } else {
            if i + 1 < n {
                b.set(i, i + 1, a.get(i, i + 1));
            }
            vr.push((Vec::new(), 1.0));
        }
    }

    // ---- Householder Accumulation (Alg. 2, lines 14-18) ----
    // U_B = H^L_1 .. H^L_n I  (apply backwards, left-multiplying);
    // V_B^T = I H^R_n .. H^R_1 (apply backwards, right-multiplying).
    let mut u = Matrix::eye(m, n);
    let mut vt = Matrix::eye(n, n);
    for i in (0..n).rev() {
        let (v, beta) = &vl[i];
        if !v.is_empty() {
            sink.op(HwOp::VecDiv { len: v.len() });
            sink.op(HwOp::Gemm { m: 1, n: n - i, k: m - i });
            sink.op(HwOp::Gemm { m: m - i, n: n - i, k: 1 });
            u.apply_house_left(i, i, v, *beta, &mut scratch);
        }
        let (v, beta) = &vr[i];
        if !v.is_empty() {
            sink.op(HwOp::VecDiv { len: v.len() });
            sink.op(HwOp::Gemm { m: n - i, n: 1, k: n - i - 1 });
            sink.op(HwOp::Gemm { m: n - i, n: n - i - 1, k: 1 });
            vt.apply_house_right(i, i + 1, v, *beta);
        }
    }

    Bidiag { u, b, vt }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::trace::{NullSink, VecSink};
    use crate::util::Rng;

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        Matrix::from_vec(r, c, rng.normal_vec(r * c))
    }

    fn is_upper_bidiagonal(b: &Matrix) -> bool {
        for r in 0..b.rows {
            for c in 0..b.cols {
                if c != r && c != r + 1 && b.get(r, c) != 0.0 {
                    return false;
                }
            }
        }
        true
    }

    #[test]
    fn factorization_reconstructs_input() {
        check(15, 300, |rng| {
            let n = 2 + rng.below(16);
            let m = n + rng.below(24);
            let a = rand_mat(rng, m, n);
            let f = bidiagonalize(&a, &mut NullSink);
            let recon = f.u.matmul(&f.b).matmul(&f.vt);
            let scale = a.frobenius().max(1.0);
            assert!(
                recon.max_abs_diff(&a) / scale < 1e-4,
                "err {}",
                recon.max_abs_diff(&a) / scale
            );
            assert!(is_upper_bidiagonal(&f.b));
        });
    }

    #[test]
    fn factors_are_orthogonal() {
        check(10, 301, |rng| {
            let n = 2 + rng.below(12);
            let m = n + rng.below(12);
            let a = rand_mat(rng, m, n);
            let f = bidiagonalize(&a, &mut NullSink);
            let utu = f.u.transpose().matmul(&f.u);
            assert!(utu.max_abs_diff(&Matrix::eye(n, n)) < 1e-4);
            let vvt = f.vt.matmul(&f.vt.transpose());
            assert!(vvt.max_abs_diff(&Matrix::eye(n, n)) < 1e-4);
        });
    }

    #[test]
    fn handles_rank_deficient_input() {
        let mut rng = Rng::new(44);
        let left = rand_mat(&mut rng, 12, 2);
        let right = rand_mat(&mut rng, 2, 6);
        let a = left.matmul(&right);
        let f = bidiagonalize(&a, &mut NullSink);
        let recon = f.u.matmul(&f.b).matmul(&f.vt);
        assert!(recon.max_abs_diff(&a) < 1e-3);
        assert!(f.b.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn square_input_works() {
        let mut rng = Rng::new(45);
        let a = rand_mat(&mut rng, 8, 8);
        let f = bidiagonalize(&a, &mut NullSink);
        assert!(f.u.matmul(&f.b).matmul(&f.vt).max_abs_diff(&a) < 1e-4);
    }

    #[test]
    fn trace_contains_expected_op_mix() {
        let mut rng = Rng::new(46);
        let a = rand_mat(&mut rng, 20, 8);
        let mut sink = VecSink::default();
        let _ = bidiagonalize(&a, &mut sink);
        use crate::trace::HwOp::*;
        // n left + (n-2) right HOUSE generations
        let gens = sink.count(|o| matches!(o, HouseGen { .. }));
        assert_eq!(gens, 8 + 6);
        // every non-degenerate transform issues exactly two GEMMs
        let gemms = sink.count(|o| matches!(o, Gemm { .. }));
        assert!(gemms > 0 && gemms % 2 == 0);
        // first HOUSE spans the full column
        assert!(sink.ops.iter().any(|o| matches!(o, HouseGen { len: 20 })));
    }

    #[test]
    fn bidiagonal_preserves_singular_values_vs_gram_trace() {
        // ||A||_F^2 == ||B||_F^2 (orthogonal invariance).
        check(10, 302, |rng| {
            let n = 2 + rng.below(10);
            let m = n + rng.below(10);
            let a = rand_mat(rng, m, n);
            let f = bidiagonalize(&a, &mut NullSink);
            let fa = a.frobenius();
            let fb = f.b.frobenius();
            assert!((fa - fb).abs() / fa.max(1.0) < 1e-4, "{fa} vs {fb}");
        });
    }
}
