//! HOUSE + HOUSE_MM_UPDATE (Algorithm 2, lines 22-32) — the L3 mirror
//! of the L1 Pallas kernel `python/compile/kernels/house_update.py`.
//!
//! This is the HBD hot path: `apply_left`/`apply_right` are the fused
//! rank-1 updates (`A += (v/beta)(v^T A)` / `A += (A v)(v/beta)`) that
//! the HBD-ACC issues as two chained GEMMs on the reused accelerator.

use crate::ttd::tensor::Matrix;

/// Result of HOUSE(x): `q = -sign(x1)||x||`, `v = x + sign(x1)||x|| e1`,
/// `beta = v1 * q`. `v` is empty when `x` is numerically zero (the
/// degenerate transform is the identity).
#[derive(Clone, Debug)]
pub struct House {
    pub q: f32,
    pub v: Vec<f32>,
    pub beta: f32,
}

const TINY: f32 = 1e-30;

/// Algorithm 2, HOUSE. `sign(0) = +1` (IEEE sign bit, as the FP-ALU).
pub fn house(x: &[f32]) -> House {
    let nrm = norm(x);
    if nrm <= TINY {
        return House { q: 0.0, v: Vec::new(), beta: 1.0 };
    }
    let s = if x[0].is_sign_negative() { -1.0 } else { 1.0 };
    let q = -s * nrm;
    let mut v = x.to_vec();
    v[0] += s * nrm;
    let beta = v[0] * q;
    House { q, v, beta }
}

/// Streaming norm (the Shared FP-ALU opcode): MAC accumulate + SQRT.
/// f64 accumulator — the FPU's wide internal accumulate path.
pub fn norm(x: &[f32]) -> f32 {
    x.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32
}

/// Left transform on the subblock `A[r0.., c0..]`:
/// `A <- A + (v/beta)(v^T A)`; `v.len() == rows - r0`.
///
/// Thin wrapper over [`Matrix::apply_house_left`] that allocates its
/// own scratch; the HBD loop calls the method directly with a reused
/// buffer (zero allocations per reflector).
pub fn apply_left(a: &mut Matrix, r0: usize, c0: usize, v: &[f32], beta: f32) {
    if v.is_empty() {
        return;
    }
    let mut scratch = vec![0.0f32; a.cols - c0];
    a.apply_house_left(r0, c0, v, beta, &mut scratch);
}

/// Right transform on the subblock `A[r0.., c0..]`:
/// `A <- A + (A v)(v/beta)`; `v.len() == cols - c0`.
pub fn apply_right(a: &mut Matrix, r0: usize, c0: usize, v: &[f32], beta: f32) {
    a.apply_house_right(r0, c0, v, beta);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::util::Rng;

    fn dense_reflector(v: &[f32]) -> Matrix {
        // H = I - 2 v v^T / (v^T v)
        let n = v.len();
        let vtv: f32 = v.iter().map(|x| x * x).sum();
        let mut h = Matrix::eye(n, n);
        for i in 0..n {
            for j in 0..n {
                let cur = h.get(i, j);
                h.set(i, j, cur - 2.0 * v[i] * v[j] / vtv);
            }
        }
        h
    }

    #[test]
    fn house_annihilates_tail() {
        check(30, 200, |rng| {
            let n = 2 + rng.below(40);
            let x = rng.normal_vec(n);
            let h = house(&x);
            // H x = q e1
            let hm = dense_reflector(&h.v);
            let mut hx = vec![0.0f32; n];
            for i in 0..n {
                hx[i] = (0..n).map(|j| hm.get(i, j) * x[j]).sum();
            }
            assert!((hx[0] - h.q).abs() < 1e-3 * (1.0 + h.q.abs()), "{} vs {}", hx[0], h.q);
            for v in &hx[1..] {
                assert!(v.abs() < 1e-3, "tail {v}");
            }
        });
    }

    #[test]
    fn house_beta_identity() {
        // v^T v == -2 beta for HOUSE-generated vectors.
        check(30, 201, |rng| {
            let n = 2 + rng.below(30);
            let x = rng.normal_vec(n);
            let h = house(&x);
            let vtv: f32 = h.v.iter().map(|v| v * v).sum();
            assert!(
                (vtv + 2.0 * h.beta).abs() < 1e-2 * vtv.max(1.0),
                "vtv={vtv} beta={}",
                h.beta
            );
        });
    }

    #[test]
    fn house_zero_vector_is_identity() {
        let h = house(&[0.0, 0.0, 0.0]);
        assert_eq!(h.q, 0.0);
        assert!(h.v.is_empty());
        let mut a = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let before = a.clone();
        apply_left(&mut a, 0, 0, &h.v, h.beta);
        assert_eq!(a, before);
    }

    #[test]
    fn apply_left_equals_dense_reflection() {
        check(20, 202, |rng| {
            let (m, n) = (2 + rng.below(20), 1 + rng.below(20));
            let mut a = Matrix::from_vec(m, n, rng.normal_vec(m * n));
            let x: Vec<f32> = (0..m).map(|r| a.get(r, 0)).collect();
            let h = house(&x);
            let want = dense_reflector(&h.v).matmul(&a);
            apply_left(&mut a, 0, 0, &h.v, h.beta);
            assert!(a.max_abs_diff(&want) < 1e-3, "diff {}", a.max_abs_diff(&want));
        });
    }

    #[test]
    fn apply_right_equals_dense_reflection() {
        check(20, 203, |rng| {
            let (m, n) = (1 + rng.below(20), 2 + rng.below(20));
            let mut a = Matrix::from_vec(m, n, rng.normal_vec(m * n));
            let y: Vec<f32> = a.row(0).to_vec();
            let h = house(&y);
            let want = a.matmul(&dense_reflector(&h.v));
            apply_right(&mut a, 0, 0, &h.v, h.beta);
            assert!(a.max_abs_diff(&want) < 1e-3);
        });
    }

    #[test]
    fn subblock_application_leaves_rest_untouched() {
        let mut rng = Rng::new(7);
        let mut a = Matrix::from_vec(6, 5, rng.normal_vec(30));
        let before = a.clone();
        let x: Vec<f32> = (2..6).map(|r| a.get(r, 1)).collect();
        let h = house(&x);
        apply_left(&mut a, 2, 1, &h.v, h.beta);
        // rows 0..2 and column 0 untouched
        for c in 0..5 {
            assert_eq!(a.get(0, c), before.get(0, c));
            assert_eq!(a.get(1, c), before.get(1, c));
        }
        for r in 0..6 {
            assert_eq!(a.get(r, 0), before.get(r, 0));
        }
        // pivot column annihilated below the pivot
        for r in 3..6 {
            assert!(a.get(r, 1).abs() < 1e-4);
        }
    }
}
