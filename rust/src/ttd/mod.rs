//! The TTD numeric substrate: tensors, the paper's two-phase SVD,
//! Algorithm 1 (TTD), reconstruction (Eq. 1/2), and the Table-I
//! baselines (Tucker, TRD).

pub mod reconstruct;
pub mod svd;
pub mod tensor;
pub mod trd;
pub mod tucker;
#[allow(clippy::module_inception)]
pub mod ttd;

pub use reconstruct::{reconstruct, relative_error};
pub use tensor::{Matrix, Tensor};
pub use ttd::{decompose, SvdMethod, TtCore, TtDecomp, TtSpec};
