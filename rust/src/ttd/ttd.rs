//! Algorithm 1: Tensor-Train Decomposition with Sorting_Basis and
//! delta-Truncation, emitting the hardware trace the simulator costs.

use crate::fault::{JobError, SvdStall};
use crate::trace::{HwOp, Phase, TraceSink};
use crate::ttd::svd::{jacobi_fallback, randomized, svd, Svd};
use crate::ttd::tensor::{Matrix, MatrixView, Tensor};

/// One TT core `G_k` of shape `(r_{k-1}, n_k, r_k)`, row-major.
#[derive(Clone, Debug)]
pub struct TtCore {
    pub r_in: usize,
    pub n: usize,
    pub r_out: usize,
    pub data: Vec<f32>,
}

impl TtCore {
    pub fn numel(&self) -> usize {
        self.r_in * self.n * self.r_out
    }

    /// Left unfolding `(r_in * n, r_out)` — a borrowed view: the
    /// reshape is free, no clone of the core data.
    pub fn as_matrix_left(&self) -> MatrixView<'_> {
        MatrixView::new(self.r_in * self.n, self.r_out, &self.data)
    }

    /// Right unfolding `(r_in, n * r_out)` — borrowed, clone-free.
    pub fn as_matrix_right(&self) -> MatrixView<'_> {
        MatrixView::new(self.r_in, self.n * self.r_out, &self.data)
    }
}

/// A complete TT decomposition of a tensor with dims `dims` and
/// boundary ranks `ranks[0] = ranks[N] = 1`.
#[derive(Clone, Debug)]
pub struct TtDecomp {
    pub dims: Vec<usize>,
    pub ranks: Vec<usize>,
    pub cores: Vec<TtCore>,
    pub eps: f32,
}

impl TtDecomp {
    /// Total TT parameters: sum of core sizes.
    pub fn param_count(&self) -> usize {
        self.cores.iter().map(|c| c.numel()).sum()
    }

    pub fn dense_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn compression_ratio(&self) -> f64 {
        self.dense_count() as f64 / self.param_count() as f64
    }

    /// Bytes on the wire for the Fig.-1 transmission: f32 cores plus a
    /// small header (dims + ranks as u32).
    pub fn wire_bytes(&self) -> usize {
        4 * self.param_count() + 4 * (self.dims.len() + self.ranks.len()) + 8
    }
}

/// Sorting_Basis (Alg. 1, lines 18-25): sort the singular values
/// descending and reorder the columns of U and rows of V^T to match.
///
/// The hardware SORTING module is a bubble sorter, and the trace
/// reports its exact swap count; in software we compute the same
/// number as the strict inversion count of the sequence (bubble-sort
/// swaps == inversions) in O(k log k), then apply the permutation
/// in place by cycle-following — no O(k^2) compare loop and no clone
/// of the basis matrices.
pub fn sorting_basis<S: TraceSink>(s: &mut Svd, sink: &mut S) {
    let k = s.sigma.len();
    // Swap count the SORTING module would report (strict inversions).
    let swaps = count_inversions_ascending_pairs(&s.sigma);
    // Stable descending argsort: ind[new] = old. Ties keep their
    // original order, matching the strict-compare bubble sorter.
    let mut ind: Vec<usize> = (0..k).collect();
    ind.sort_by(|&a, &b| {
        s.sigma[b].partial_cmp(&s.sigma[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    sink.op(HwOp::Sort { n: k, swaps });
    if swaps > 0 {
        // sigma: O(k) gather.
        let sorted: Vec<f32> = ind.iter().map(|&o| s.sigma[o]).collect();
        s.sigma = sorted;
        // U columns / V^T rows: cycle-following permutation, one
        // column/row temp buffer instead of full-matrix clones.
        permute_columns(&mut s.u, &ind);
        permute_rows(&mut s.vt, &ind);
    }
    sink.op(HwOp::ReorderBasis { rows: s.u.rows + s.vt.cols, cols: k });
}

/// Number of pairs `i < j` with `v[i] < v[j]` (strict) — exactly the
/// swap count of a strict-compare descending bubble sort. Merge-count,
/// O(k log k).
fn count_inversions_ascending_pairs(v: &[f32]) -> usize {
    fn go(v: &mut [f32], buf: &mut [f32]) -> usize {
        let n = v.len();
        if n < 2 {
            return 0;
        }
        let mid = n / 2;
        let mut count = go(&mut v[..mid], buf) + go(&mut v[mid..], buf);
        // Merge descending; when the right element strictly beats the
        // left one it jumps ahead of every remaining left element.
        let (mut i, mut j, mut o) = (0, mid, 0);
        while i < mid && j < n {
            if v[j] > v[i] {
                count += mid - i;
                buf[o] = v[j];
                j += 1;
            } else {
                buf[o] = v[i];
                i += 1;
            }
            o += 1;
        }
        while i < mid {
            buf[o] = v[i];
            i += 1;
            o += 1;
        }
        while j < n {
            buf[o] = v[j];
            j += 1;
            o += 1;
        }
        v.copy_from_slice(&buf[..n]);
        count
    }
    let mut work = v.to_vec();
    let mut buf = vec![0.0f32; v.len()];
    go(&mut work, &mut buf)
}

/// In-place `new_col[j] = old_col[perm[j]]` by cycle decomposition.
fn permute_columns(m: &mut Matrix, perm: &[usize]) {
    let rows = m.rows;
    let mut visited = vec![false; perm.len()];
    let mut tmp = vec![0.0f32; rows];
    for start in 0..perm.len() {
        if visited[start] || perm[start] == start {
            visited[start] = true;
            continue;
        }
        for (r, t) in tmp.iter_mut().enumerate() {
            *t = m.get(r, start);
        }
        let mut j = start;
        while perm[j] != start {
            let src = perm[j];
            for r in 0..rows {
                let v = m.get(r, src);
                m.set(r, j, v);
            }
            visited[j] = true;
            j = src;
        }
        for (r, t) in tmp.iter().enumerate() {
            m.set(r, j, *t);
        }
        visited[j] = true;
    }
}

/// In-place `new_row[j] = old_row[perm[j]]` by cycle decomposition.
fn permute_rows(m: &mut Matrix, perm: &[usize]) {
    let cols = m.cols;
    let mut visited = vec![false; perm.len()];
    let mut tmp = vec![0.0f32; cols];
    for start in 0..perm.len() {
        if visited[start] || perm[start] == start {
            visited[start] = true;
            continue;
        }
        tmp.copy_from_slice(m.row(start));
        let mut j = start;
        while perm[j] != start {
            let src = perm[j];
            m.data.copy_within(src * cols..(src + 1) * cols, j * cols);
            visited[j] = true;
            j = src;
        }
        m.row_mut(j).copy_from_slice(&tmp);
        visited[j] = true;
    }
}

/// delta-Truncation (Alg. 1, lines 27-31) as the paper's FSM: walk the
/// tail of the sorted singular values, accumulating the error vector
/// norm, and decrement the retained rank while `||e||_2 < delta`.
/// Returns the retained rank; probe count goes to the trace.
pub fn delta_truncation<S: TraceSink>(
    sigma: &[f32],
    delta: f32,
    max_rank: usize,
    sink: &mut S,
) -> usize {
    let k = sigma.len();
    let mut tail = 0.0f64;
    let mut r = k;
    let mut probes = 0usize;
    while r > 1 {
        let cand = tail + (sigma[r - 1] as f64) * (sigma[r - 1] as f64);
        probes += 1;
        if (cand.sqrt() as f32) < delta {
            tail = cand;
            r -= 1;
        } else {
            break;
        }
    }
    sink.op(HwOp::Trunc { probes: probes.max(1), veclen: k });
    r.min(max_rank).max(1)
}

/// Per-bond rank caps for [`TtSpec`].
#[derive(Clone, Debug, PartialEq, Eq)]
enum RankCaps {
    Unbounded,
    /// Same cap on every bond.
    Uniform(usize),
    /// `caps[k]` bounds bond `k`; missing trailing bonds are unbounded.
    PerBond(Vec<usize>),
}

/// Which SVD algorithm runs Algorithm-1 line 8 (ISSUE 9).
///
/// A *numerics* knob: it changes the factorization (and therefore the
/// op stream, the program cache key, and potentially the ranks), so it
/// lives on [`TtSpec`] — never on a cost-only axis like the simulator
/// backend.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SvdMethod {
    /// Dense HBD + Golub-Kahan SVD of every unfolding (the default).
    #[default]
    Exact,
    /// Seeded randomized range-finder (Halko et al.): sketch
    /// `Y = A Omega`, Householder QR of `Y`, dense SVD of `Q^T A`.
    /// The sketch width is the bond's rank cap plus `oversample`
    /// (clamped to the full rank, so uncapped specs keep the eps
    /// contract exactly).
    Randomized { seed: u64, oversample: u32 },
}

/// Tuning for one Algorithm-1 run. Replaces the positional
/// `(eps, max_ranks)` pair that used to thread through every
/// signature: construct with [`TtSpec::eps`], then chain
/// [`TtSpec::rank_cap`] / [`TtSpec::rank_caps`].
///
/// ```
/// use tt_edge::trace::NullSink;
/// use tt_edge::ttd::{decompose, Tensor, TtSpec};
/// use tt_edge::util::Rng;
/// let mut rng = Rng::new(7);
/// let w = Tensor::from_vec(&[4, 4, 4], rng.normal_vec(64));
/// let d = decompose(&w, &TtSpec::eps(0.1).rank_cap(3), &mut NullSink);
/// assert!(d.ranks.iter().all(|&r| r <= 3));
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct TtSpec {
    /// Prescribed relative accuracy (the Oseledets bound; the
    /// per-split truncation threshold `delta` derives from it).
    pub eps: f32,
    caps: RankCaps,
    method: SvdMethod,
    /// Chaos injection: force line 8's SVD to stall (ISSUE 10). Like
    /// `method`, this changes the factorization path, so it is part
    /// of spec equality and of every cache key derived from the spec.
    stall: SvdStall,
}

impl TtSpec {
    /// Spec with prescribed accuracy `eps`, unbounded ranks, and the
    /// exact SVD.
    pub fn eps(eps: f32) -> Self {
        TtSpec {
            eps,
            caps: RankCaps::Unbounded,
            method: SvdMethod::Exact,
            stall: SvdStall::None,
        }
    }

    /// Cap every bond rank at `cap`.
    pub fn rank_cap(mut self, cap: usize) -> Self {
        self.caps = RankCaps::Uniform(cap);
        self
    }

    /// Per-bond caps: `caps[k]` bounds bond `k` (between cores `k` and
    /// `k+1`); bonds past the end of the slice stay unbounded.
    pub fn rank_caps(mut self, caps: &[usize]) -> Self {
        self.caps = RankCaps::PerBond(caps.to_vec());
        self
    }

    /// Run line 8 with the randomized range-finder (`--method rsvd`).
    pub fn rsvd(mut self, seed: u64, oversample: u32) -> Self {
        self.method = SvdMethod::Randomized { seed, oversample };
        self
    }

    /// Set the SVD method wholesale (the serve wire path, where the
    /// method arrives already parsed).
    pub fn with_method(mut self, method: SvdMethod) -> Self {
        self.method = method;
        self
    }

    /// Which SVD algorithm line 8 runs.
    pub fn method(&self) -> SvdMethod {
        self.method
    }

    /// Inject a forced SVD stall (the chaos path; [`SvdStall::None`]
    /// leaves the numerics bit-identical to a spec without it).
    pub fn with_stall(mut self, stall: SvdStall) -> Self {
        self.stall = stall;
        self
    }

    /// The injected stall mode.
    pub fn svd_stall(&self) -> SvdStall {
        self.stall
    }

    /// Effective cap for bond `bond` (`usize::MAX` when unbounded).
    pub fn cap_for(&self, bond: usize) -> usize {
        match &self.caps {
            RankCaps::Unbounded => usize::MAX,
            RankCaps::Uniform(c) => *c,
            RankCaps::PerBond(v) => v.get(bond).copied().unwrap_or(usize::MAX),
        }
    }
}

impl Default for TtSpec {
    /// The repo-wide default accuracy budget (`eps = 0.12`, the
    /// Table-I operating point).
    fn default() -> Self {
        TtSpec::eps(0.12)
    }
}

/// Algorithm 1: decompose `w` into TT cores under `spec` (prescribed
/// accuracy + optional rank caps), emitting the hardware-op stream
/// into `sink` as it runs.
pub fn decompose<S: TraceSink>(w: &Tensor, spec: &TtSpec, sink: &mut S) -> TtDecomp {
    let dims = w.shape.clone();
    let nd = dims.len();
    assert!(nd >= 2, "TTD needs at least 2 dims");
    let eps = spec.eps;

    // delta = eps / sqrt(d-1) * ||W||_F  (TRUNCATION module: SQRT,MUL,DIV)
    sink.op(HwOp::SetPhase(Phase::SortTrunc));
    sink.op(HwOp::CoreScalar { ops: 3 });
    let delta = eps / ((nd - 1) as f32).sqrt() * w.frobenius();

    let mut ranks = vec![1usize; nd + 1];
    let mut cores: Vec<TtCore> = Vec::with_capacity(nd);
    let mut w_temp = w.data.clone(); // current working buffer
    let mut w_rows; // r_{k-1} * n_k
    let mut w_cols;

    for k in 0..nd - 1 {
        // Reshape (Alg. 1, line 7)
        sink.op(HwOp::SetPhase(Phase::ReshapeEtc));
        w_rows = ranks[k] * dims[k];
        w_cols = w_temp.len() / w_rows;
        sink.op(HwOp::Reshape { elems: w_temp.len() });
        let mat = Matrix::from_vec(w_rows, w_cols, w_temp.clone());

        // SVD (line 8) — phases traced inside
        let mut s = match spec.method {
            SvdMethod::Exact => svd(&mat, sink),
            SvdMethod::Randomized { seed, oversample } => {
                // Sketch width: the bond's cap + oversampling, clamped
                // to the full rank (uncapped bonds degrade to a full
                // sketch, preserving the eps contract exactly). The
                // per-split seed is a deterministic function of the
                // sketch seed and the split index.
                let full = w_rows.min(w_cols);
                let sketch =
                    spec.cap_for(k).saturating_add(oversample as usize).min(full);
                let split_seed =
                    seed.wrapping_add((k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                randomized::rsvd(&mat, sketch, split_seed, sink)
            }
        };

        // Non-convergence handling (ISSUE 10). A hard stall models the
        // Jacobi fallback failing too: raise the structured error as a
        // panic payload so the serve supervisor (and the single-flight
        // MissGuard drop path) convert it — mid-recording — into a
        // `svd-non-convergence` response instead of process death.
        if spec.stall == SvdStall::Hard {
            std::panic::panic_any(JobError::SvdNonConvergence {
                iterations: s.qr_iterations,
            });
        }
        // A genuinely stuck QR sweep (or an injected soft stall) is
        // rescued by the independent one-sided Jacobi path before
        // giving up.
        if spec.stall == SvdStall::Soft || !s.converged {
            s = jacobi_fallback(&mat, sink);
            if !s.converged {
                std::panic::panic_any(JobError::SvdNonConvergence {
                    iterations: s.qr_iterations,
                });
            }
        }

        // Sorting (line 9) + Truncation (line 10)
        sink.op(HwOp::SetPhase(Phase::SortTrunc));
        sorting_basis(&mut s, sink);
        let r = delta_truncation(&s.sigma, delta, spec.cap_for(k), sink);
        ranks[k + 1] = r;

        // New core G_k = reshape(U_t) (line 13)
        sink.op(HwOp::SetPhase(Phase::ReshapeEtc));
        let mut core = vec![0.0f32; ranks[k] * dims[k] * r];
        for row in 0..w_rows {
            for c in 0..r {
                core[row * r + c] = s.u.get(row, c);
            }
        }
        sink.op(HwOp::Reshape { elems: core.len() });
        cores.push(TtCore { r_in: ranks[k], n: dims[k], r_out: r, data: core });

        // W_temp <- Sigma_t V_t^T (lines 11-12)
        sink.op(HwOp::SetPhase(Phase::UpdateSvdInput));
        sink.op(HwOp::Gemm { m: r, n: w_cols, k: 1 });
        let mut next = vec![0.0f32; r * w_cols];
        for row in 0..r {
            let sv = s.sigma[row];
            let src = s.vt.row(row);
            let dst = &mut next[row * w_cols..(row + 1) * w_cols];
            for (d, v) in dst.iter_mut().zip(src) {
                *d = sv * v;
            }
        }
        w_temp = next;
    }

    // Last core (line 14): G_N = reshape(W_temp, [r_{N-1}, n_N, 1])
    sink.op(HwOp::SetPhase(Phase::ReshapeEtc));
    sink.op(HwOp::Reshape { elems: w_temp.len() });
    cores.push(TtCore {
        r_in: ranks[nd - 1],
        n: dims[nd - 1],
        r_out: 1,
        data: w_temp,
    });
    ranks[nd] = 1;

    TtDecomp { dims, ranks, cores, eps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::check;
    use crate::trace::{NullSink, VecSink};
    use crate::ttd::reconstruct::reconstruct;
    use crate::util::Rng;

    fn rel_err(a: &Tensor, b: &Tensor) -> f32 {
        let num: f64 = a
            .data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum();
        let den: f64 = b.data.iter().map(|y| (*y as f64).powi(2)).sum();
        (num / den.max(1e-30)).sqrt() as f32
    }

    #[test]
    fn oseledets_error_bound_holds() {
        // ||W - W_R||_F <= eps ||W||_F for the prescribed-accuracy TTD.
        check(10, 700, |rng| {
            let shape = [2 + rng.below(6), 2 + rng.below(8), 2 + rng.below(8)];
            let w = Tensor::from_vec(&shape, rng.normal_vec(shape.iter().product()));
            let eps = 0.3;
            let d = decompose(&w, &TtSpec::eps(eps), &mut NullSink);
            let wr = reconstruct(&d);
            assert!(rel_err(&wr, &w) <= eps + 1e-3, "err {}", rel_err(&wr, &w));
        });
    }

    #[test]
    fn exact_recovery_of_low_rank_tensor() {
        let mut rng = Rng::new(80);
        // build a TT-rank-(3,2) tensor explicitly
        let g1 = Matrix::from_vec(5, 3, rng.normal_vec(15));
        let g2 = Matrix::from_vec(3, 6 * 2, rng.normal_vec(36));
        let g3 = Matrix::from_vec(2, 7, rng.normal_vec(14));
        let w12 = g1.matmul(&Matrix::from_vec(3, 12, g2.data.clone())); // (5, 6*2)
        let w12 = Matrix::from_vec(30, 2, w12.data);
        let w = w12.matmul(&g3); // (5*6, 7)
        let w = Tensor::from_vec(&[5, 6, 7], w.data);
        let d = decompose(&w, &TtSpec::eps(1e-3), &mut NullSink);
        assert_eq!(d.ranks, vec![1, 3, 2, 1]);
        let wr = reconstruct(&d);
        assert!(rel_err(&wr, &w) < 1e-3);
    }

    #[test]
    fn boundary_ranks_are_one() {
        let mut rng = Rng::new(81);
        let w = Tensor::from_vec(&[4, 5, 6], rng.normal_vec(120));
        let d = decompose(&w, &TtSpec::eps(0.1), &mut NullSink);
        assert_eq!(d.ranks[0], 1);
        assert_eq!(*d.ranks.last().unwrap(), 1);
        assert_eq!(d.cores.len(), 3);
        for (k, c) in d.cores.iter().enumerate() {
            assert_eq!(c.r_in, d.ranks[k]);
            assert_eq!(c.r_out, d.ranks[k + 1]);
            assert_eq!(c.n, d.dims[k]);
        }
    }

    #[test]
    fn rank_caps_are_respected() {
        let mut rng = Rng::new(82);
        let w = Tensor::from_vec(&[6, 6, 6], rng.normal_vec(216));
        let d = decompose(&w, &TtSpec::eps(0.0).rank_caps(&[2, 3]), &mut NullSink);
        assert!(d.ranks[1] <= 2);
        assert!(d.ranks[2] <= 3);
    }

    #[test]
    fn eps_zero_keeps_full_rank() {
        let mut rng = Rng::new(83);
        let w = Tensor::from_vec(&[4, 4, 4], rng.normal_vec(64));
        let d = decompose(&w, &TtSpec::eps(0.0), &mut NullSink);
        assert_eq!(d.ranks, vec![1, 4, 4, 1]);
        let wr = reconstruct(&d);
        assert!(rel_err(&wr, &w) < 1e-4);
    }

    #[test]
    fn larger_eps_never_increases_params() {
        let mut rng = Rng::new(84);
        let w = Tensor::from_vec(&[6, 8, 8], rng.normal_vec(384));
        let mut last = usize::MAX;
        for eps in [0.01f32, 0.1, 0.3, 0.6] {
            let d = decompose(&w, &TtSpec::eps(eps), &mut NullSink);
            assert!(d.param_count() <= last, "eps={eps}");
            last = d.param_count();
        }
    }

    #[test]
    fn compression_accounting() {
        let mut rng = Rng::new(85);
        let w = Tensor::from_vec(&[4, 8, 8], rng.normal_vec(256));
        let d = decompose(&w, &TtSpec::eps(0.5), &mut NullSink);
        let manual: usize = d
            .ranks
            .windows(2)
            .zip(&d.dims)
            .map(|(r, n)| r[0] * n * r[1])
            .sum();
        assert_eq!(d.param_count(), manual);
        assert!(d.compression_ratio() >= 1.0 || d.param_count() > d.dense_count());
        assert_eq!(d.wire_bytes(), 4 * manual + 4 * (3 + 4) + 8);
    }

    #[test]
    fn sorting_basis_sorts_and_reorders_consistently() {
        let mut rng = Rng::new(86);
        let a = Matrix::from_vec(12, 6, rng.normal_vec(72));
        let mut s = svd(&a, &mut NullSink);
        // scramble
        s.sigma.reverse();
        let k = s.sigma.len();
        let u_rev: Vec<f32> = (0..s.u.rows)
            .flat_map(|r| (0..k).rev().map(move |c| (r, c)))
            .map(|(r, c)| s.u.get(r, c))
            .collect();
        s.u = Matrix::from_vec(s.u.rows, k, u_rev);
        let vt_rev: Vec<f32> = (0..k).rev().flat_map(|r| s.vt.row(r).to_vec()).collect();
        s.vt = Matrix::from_vec(k, s.vt.cols, vt_rev);

        let mut sink = VecSink::default();
        sorting_basis(&mut s, &mut sink);
        for w in s.sigma.windows(2) {
            assert!(w[0] >= w[1]);
        }
        // reconstruction still valid after the reorder
        let mut us = s.u.clone();
        for r in 0..us.rows {
            for c in 0..k {
                let v = us.get(r, c) * s.sigma[c];
                us.set(r, c, v);
            }
        }
        let recon = us.matmul(&s.vt);
        assert!(recon.max_abs_diff(&a) < 1e-3);
        assert!(sink.count(|o| matches!(o, HwOp::Sort { .. })) == 1);
    }

    #[test]
    fn sorting_swap_count_matches_bubble_sort() {
        // The trace's swap count must keep bubble-sort semantics even
        // though the implementation argsorts + counts inversions.
        fn bubble_swaps(v: &[f32]) -> usize {
            let mut v = v.to_vec();
            let mut swaps = 0;
            for i in 0..v.len().saturating_sub(1) {
                for j in 0..v.len() - 1 - i {
                    if v[j] < v[j + 1] {
                        v.swap(j, j + 1);
                        swaps += 1;
                    }
                }
            }
            swaps
        }
        check(30, 88, |rng| {
            let k = 1 + rng.below(20);
            // duplicates included: quantize to force ties
            let sig: Vec<f32> =
                (0..k).map(|_| (rng.uniform() * 4.0).floor() as f32).collect();
            let mut s = Svd {
                u: Matrix::eye(k, k),
                sigma: sig.clone(),
                vt: Matrix::eye(k, k),
                qr_iterations: 0,
                converged: true,
            };
            let mut sink = VecSink::default();
            sorting_basis(&mut s, &mut sink);
            let want = bubble_swaps(&sig);
            assert!(
                sink.ops.iter().any(
                    |o| matches!(o, HwOp::Sort { n, swaps } if *n == k && *swaps == want)
                ),
                "swap count mismatch for {sig:?}: want {want}, ops {:?}",
                sink.ops
            );
            for w in s.sigma.windows(2) {
                assert!(w[0] >= w[1]);
            }
            // U columns carry the permutation: U (started as I) must
            // now satisfy U[:, new] = e_{old}, i.e. recon still valid.
            for (new_c, sv) in s.sigma.iter().enumerate() {
                let old_c = (0..k)
                    .find(|&r| s.u.get(r, new_c) == 1.0)
                    .expect("permutation column");
                assert_eq!(sig[old_c], *sv);
            }
        });
    }

    #[test]
    fn core_views_borrow_without_cloning() {
        let core = TtCore { r_in: 2, n: 3, r_out: 4, data: (0..24).map(|x| x as f32).collect() };
        let left = core.as_matrix_left();
        assert_eq!((left.rows, left.cols), (6, 4));
        let right = core.as_matrix_right();
        assert_eq!((right.rows, right.cols), (2, 12));
        // same storage, both unfoldings
        assert!(std::ptr::eq(left.data.as_ptr(), right.data.as_ptr()));
        assert_eq!(left.get(1, 3), 7.0);
        assert_eq!(right.get(1, 0), 12.0);
    }

    #[test]
    fn delta_truncation_fsm_semantics() {
        let mut sink = NullSink;
        // sigma = [5, 3, 1, 0.1]; delta = 1.2 -> drop 0.1 and 1? tail
        // norms: ||{0.1}||=0.1<1.2 drop; ||{1,0.1}||=1.005<1.2 drop;
        // ||{3,1,0.1}||=3.17>1.2 keep => r=2
        let r = delta_truncation(&[5.0, 3.0, 1.0, 0.1], 1.2, usize::MAX, &mut sink);
        assert_eq!(r, 2);
        // delta = 0 keeps everything
        assert_eq!(delta_truncation(&[5.0, 3.0], 0.0, usize::MAX, &mut sink), 2);
        // cap applies after the accuracy rule
        assert_eq!(delta_truncation(&[5.0, 3.0, 1.0], 0.0, 2, &mut sink), 2);
        // never below 1
        assert_eq!(delta_truncation(&[1e-9], 1.0, usize::MAX, &mut sink), 1);
    }

    #[test]
    fn rsvd_method_keeps_the_eps_contract_when_uncapped() {
        // Uncapped bonds degrade rsvd to a full sketch, so the
        // Oseledets bound must hold exactly as for the exact method.
        check(6, 701, |rng| {
            let shape = [2 + rng.below(5), 2 + rng.below(6), 2 + rng.below(6)];
            let w = Tensor::from_vec(&shape, rng.normal_vec(shape.iter().product()));
            let eps = 0.3;
            let d = decompose(&w, &TtSpec::eps(eps).rsvd(9, 4), &mut NullSink);
            let wr = reconstruct(&d);
            assert!(rel_err(&wr, &w) <= eps + 1e-3, "err {}", rel_err(&wr, &w));
        });
    }

    #[test]
    fn rsvd_spec_is_explicit_and_default_is_exact() {
        assert_eq!(TtSpec::eps(0.1).method(), SvdMethod::Exact);
        assert_eq!(TtSpec::default().method(), SvdMethod::Exact);
        assert_eq!(
            TtSpec::eps(0.1).rsvd(7, 8).method(),
            SvdMethod::Randomized { seed: 7, oversample: 8 }
        );
        // the method participates in spec equality (and so in cache
        // keys derived from the spec)
        assert_ne!(TtSpec::eps(0.1), TtSpec::eps(0.1).rsvd(7, 8));
        assert_ne!(TtSpec::eps(0.1).rsvd(7, 8), TtSpec::eps(0.1).rsvd(8, 8));
    }

    #[test]
    fn soft_stall_is_rescued_by_the_jacobi_fallback() {
        // An injected soft stall reroutes every split through the
        // Jacobi fallback — the decomposition must still satisfy the
        // Oseledets bound, deterministically.
        let mut rng = Rng::new(89);
        let w = Tensor::from_vec(&[4, 6, 6], rng.normal_vec(144));
        let eps = 0.3;
        let spec = TtSpec::eps(eps).with_stall(SvdStall::Soft);
        let d = decompose(&w, &spec, &mut NullSink);
        let wr = reconstruct(&d);
        assert!(rel_err(&wr, &w) <= eps + 1e-3, "err {}", rel_err(&wr, &w));
        let again = decompose(&w, &spec, &mut NullSink);
        assert_eq!(d.cores[0].data, again.cores[0].data, "fallback must be deterministic");
    }

    #[test]
    fn hard_stall_raises_the_structured_error_as_a_panic_payload() {
        let mut rng = Rng::new(90);
        let w = Tensor::from_vec(&[4, 4, 4], rng.normal_vec(64));
        let spec = TtSpec::eps(0.1).with_stall(SvdStall::Hard);
        let payload = std::panic::catch_unwind(|| decompose(&w, &spec, &mut NullSink))
            .expect_err("hard stall must unwind");
        let err = payload.downcast_ref::<JobError>().expect("JobError payload");
        assert!(matches!(err, JobError::SvdNonConvergence { .. }), "{err:?}");
    }

    #[test]
    fn stall_participates_in_spec_equality() {
        assert_eq!(TtSpec::eps(0.1).svd_stall(), SvdStall::None);
        assert_eq!(TtSpec::eps(0.1), TtSpec::eps(0.1).with_stall(SvdStall::None));
        assert_ne!(TtSpec::eps(0.1), TtSpec::eps(0.1).with_stall(SvdStall::Soft));
        assert_ne!(
            TtSpec::eps(0.1).with_stall(SvdStall::Soft),
            TtSpec::eps(0.1).with_stall(SvdStall::Hard)
        );
    }

    #[test]
    fn trace_covers_all_phases() {
        use crate::trace::Phase;
        let mut rng = Rng::new(87);
        let w = Tensor::from_vec(&[4, 6, 6], rng.normal_vec(144));
        let mut sink = VecSink::default();
        let _ = decompose(&w, &TtSpec::eps(0.2), &mut sink);
        for ph in Phase::ALL {
            assert!(
                sink.ops.iter().any(|o| matches!(o, HwOp::SetPhase(p) if *p == ph)),
                "missing phase {ph:?}"
            );
        }
    }
}
