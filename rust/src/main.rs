//! `ttedge` — the TT-Edge launcher.
//!
//! Subcommands (hand-rolled CLI; clap is unavailable offline):
//!
//! * `simulate`  — Table III: TTD ResNet-32 compression on Baseline vs
//!   TT-Edge SoCs (`--eps`, `--seed`, `--parallel N` host workers; the
//!   simulated cycles are identical at any width; `--json` emits one
//!   `SimReport` JSON object per SoC).
//! * `compress`  — Table I: compare TTD / Tucker / TRD on the model
//!   (`--method all|ttd|tucker|trd`, `--parallel N`).
//! * `federate`  — Fig. 1: fault-tolerant federated rounds over
//!   simulated edge nodes (`--nodes`, `--rounds`,
//!   `--soc baseline|tt-edge`, chaos: `--dropout p --straggler-mult x
//!   --quorum q --loss p`, `--json` for machine-readable reports).
//! * `resources` — Table II: FPGA/45 nm resource + power breakdown.
//! * `related`   — Table IV: comparison with Qu et al. [21].
//! * `artifacts` — list AOT artifacts; `--smoke` runs a PJRT check.

use anyhow::Result;

use tt_edge::coordinator::{Coordinator, FederatedConfig};
use tt_edge::hw_model::{self, related};
use tt_edge::metrics::{f1, f2, Table};
use tt_edge::sim::{compress_resnet32, format_table3, SocConfig};
use tt_edge::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "simulate" => cmd_simulate(&args),
        "compress" => cmd_compress(&args),
        "federate" => cmd_federate(&args),
        "resources" => cmd_resources(),
        "related" => cmd_related(),
        "artifacts" => cmd_artifacts(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "ttedge — TT-Edge (DATE 2026) reproduction\n\n\
         USAGE: ttedge <simulate|compress|federate|resources|related|artifacts> [--opts]\n\n\
         simulate   Table III (exec time + energy, baseline vs TT-Edge; --parallel N, --json)\n\
         compress   Table I  (TTD vs Tucker vs TRD on ResNet-32; --parallel N)\n\
         federate   Fig. 1   (fault-tolerant federated rounds; --threads N per node,\n\
                    --dropout p --straggler-mult x --straggler-frac f --quorum q\n\
                    --loss p --retries n --deadline-slack s --fault-seed s\n\
                    --no-oracle --json)\n\
         resources  Table II (resource + power breakdown)\n\
         related    Table IV (vs Qu et al. [21])\n\
         artifacts  list / smoke-run the AOT artifacts"
    );
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let eps: f32 = args.parse_opt("eps").unwrap_or(0.12);
    let seed: u64 = args.parse_opt("seed").unwrap_or(42);
    let parallel: usize = args.parse_opt("parallel").unwrap_or(1);
    let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
    let t0 = std::time::Instant::now();
    let (out, reports) = if parallel > 1 {
        tt_edge::pipeline::compress_resnet32_parallel(seed, eps, parallel, &configs)
    } else {
        compress_resnet32(seed, eps, &configs)
    };
    if args.flag("json") {
        for r in &reports {
            println!("{}", r.to_json().render());
        }
        return Ok(());
    }
    println!(
        "workload: ResNet-32, eps={eps}, compression {:.2}x, final params {} \
         ({} host thread{}, {:.0} ms wall)\n",
        out.compression_ratio,
        out.final_params,
        parallel.max(1),
        if parallel > 1 { "s" } else { "" },
        t0.elapsed().as_secs_f64() * 1e3
    );
    println!("{}", format_table3(&reports[0], &reports[1]));
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    use tt_edge::sim::workload::{compress_model, synthetic_model};
    use tt_edge::trace::NullSink;

    let method = args.opt_or("method", "all");
    let eps: f32 = args.parse_opt("eps").unwrap_or(0.12);
    let seed: u64 = args.parse_opt("seed").unwrap_or(42);
    let parallel: usize = args.parse_opt("parallel").unwrap_or(1);
    let layers = synthetic_model(seed, 3.55, 0.035);
    let dense = tt_edge::model::param_count();
    let conv_dense: usize = layers.iter().map(|(l, _)| l.numel()).sum();

    let mut t = Table::new(
        "TABLE I: TD method comparison, ResNet-32 (synthetic-trained weights)",
        &["Method", "Recon err", "Comp. ratio", "Final #params"],
    );
    t.row(&["Uncompressed".into(), "-".into(), "1.0x".into(), dense.to_string()]);

    if method == "all" || method == "tucker" {
        let (params, err) = run_tucker(&layers, eps);
        let fin = dense - conv_dense + params;
        t.row(&[
            "Tucker [12]".into(),
            format!("{err:.3}"),
            format!("{:.1}x", dense as f64 / fin as f64),
            fin.to_string(),
        ]);
    }
    if method == "all" || method == "trd" {
        let (params, err) = run_trd(&layers, eps);
        let fin = dense - conv_dense + params;
        t.row(&[
            "TRD [13]".into(),
            format!("{err:.3}"),
            format!("{:.1}x", dense as f64 / fin as f64),
            fin.to_string(),
        ]);
    }
    if method == "all" || method == "ttd" {
        let t0 = std::time::Instant::now();
        let out = if parallel > 1 {
            tt_edge::pipeline::compress_model_parallel(&layers, eps, parallel, &mut NullSink)
        } else {
            compress_model(&layers, eps, &mut NullSink)
        };
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        t.row(&[
            "TTD (this work)".into(),
            format!("{:.3}", out.max_rel_err),
            format!("{:.1}x", out.compression_ratio),
            out.final_params.to_string(),
        ]);
        println!(
            "TTD: {} layers on {} host thread{} in {wall_ms:.0} ms",
            layers.len(),
            parallel.max(1),
            if parallel > 1 { "s" } else { "" },
        );
    }
    println!("{}", t.render());
    Ok(())
}

fn run_tucker(
    layers: &[(tt_edge::model::ConvLayer, tt_edge::ttd::Tensor)],
    eps: f32,
) -> (usize, f32) {
    use tt_edge::ttd::tucker;
    let mut params = 0usize;
    let mut worst = 0.0f32;
    for (l, w) in layers {
        let t = w.reshape(&l.tt_dims());
        let d = tucker::decompose(&t, eps);
        params += d.param_count();
        worst = worst.max(tucker::relative_error(&t, &d));
    }
    (params, worst)
}

fn run_trd(
    layers: &[(tt_edge::model::ConvLayer, tt_edge::ttd::Tensor)],
    eps: f32,
) -> (usize, f32) {
    use tt_edge::ttd::trd;
    let mut params = 0usize;
    let mut worst = 0.0f32;
    for (l, w) in layers {
        let t = w.reshape(&l.tt_dims());
        let d = trd::decompose(&t, eps);
        params += d.param_count();
        worst = worst.max(trd::relative_error(&t, &d));
    }
    (params, worst)
}

fn cmd_federate(args: &Args) -> Result<()> {
    use tt_edge::coordinator::{FaultPlan, Link};

    let soc = match args.opt_or("soc", "tt-edge").as_str() {
        "baseline" => SocConfig::baseline(),
        _ => SocConfig::tt_edge(),
    };
    let faults = FaultPlan {
        dropout: args.parse_opt("dropout").unwrap_or(0.0),
        straggler_mult: args.parse_opt("straggler-mult").unwrap_or(1.0),
        straggler_frac: args.parse_opt("straggler-frac").unwrap_or(0.25),
        seed: args.parse_opt("fault-seed").unwrap_or(0xFA17),
        ..Default::default()
    };
    let link = Link {
        loss: args.parse_opt("loss").unwrap_or(0.0),
        max_retries: args.parse_opt("retries").unwrap_or(3),
        ..Link::default()
    };
    let cfg = FederatedConfig {
        nodes: args.parse_opt("nodes").unwrap_or(4),
        rounds: args.parse_opt("rounds").unwrap_or(3),
        eps: args.parse_opt("eps").unwrap_or(0.12),
        threads_per_node: args.parse_opt("threads").unwrap_or(1),
        min_quorum: args.parse_opt("quorum").unwrap_or(0),
        deadline_slack: args.parse_opt("deadline-slack").unwrap_or(1.0),
        exact_oracle: !args.flag("no-oracle"),
        soc,
        link,
        faults,
        ..Default::default()
    };
    let as_json = args.flag("json");
    if !as_json {
        println!(
            "federated run: {} nodes x {} rounds on {} SoCs \
             (dropout {:.2}, straggler x{:.1}, link loss {:.2}, quorum {})\n",
            cfg.nodes,
            cfg.rounds,
            cfg.soc.name(),
            cfg.faults.dropout,
            cfg.faults.straggler_mult,
            cfg.link.loss,
            if cfg.min_quorum == 0 { "all".to_string() } else { cfg.min_quorum.to_string() },
        );
    }
    let mut c = Coordinator::new(cfg);
    let reports = c.run();
    if as_json {
        // One JSON object per round — the machine-readable surface of
        // the same table, with every participation/fault field.
        for r in &reports {
            println!("{}", r.to_json().render());
        }
        return Ok(());
    }
    let mut t = Table::new(
        "Fig. 1 workflow: compressed parameter transmission",
        &[
            "round", "part", "drop", "late", "retry", "wire KB", "comm red.",
            "compress ms", "energy mJ", "xfer ms", "deadline ms", "agg err",
        ],
    );
    for r in &reports {
        t.row(&[
            r.round.to_string(),
            format!("{}/{}", r.participants, r.scheduled),
            r.dropped.to_string(),
            r.late.to_string(),
            r.retries.to_string(),
            f1(r.wire_bytes as f64 / 1024.0),
            format!("{:.2}x", r.communication_reduction),
            f1(r.mean_compress_ms),
            f1(r.mean_compress_mj),
            f1(r.round_transfer_ms),
            f1(r.deadline_ms),
            if r.aggregate_rel_err.is_nan() {
                "-".to_string()
            } else {
                format!("{:.4}", r.aggregate_rel_err)
            },
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_resources() -> Result<()> {
    let mut t = Table::new(
        "TABLE II: resource usage and 45 nm power breakdown",
        &["IP", "LUTs", "FFs", "Power (mW)"],
    );
    for b in hw_model::tt_edge_blocks() {
        let name = if b.ttd_engine_specialized {
            format!("TTD-Engine: {}", b.name)
        } else {
            b.name.to_string()
        };
        let p = match b.gated_power_mw {
            Some(g) => format!("{:.2} / {:.2} (gated)", b.power_mw, g),
            None => f2(b.power_mw),
        };
        t.row(&[name, b.luts.to_string(), b.ffs.to_string(), p]);
    }
    let s = hw_model::summarize();
    t.row(&[
        "TOTAL (TT-Edge)".into(),
        s.total_luts.to_string(),
        s.total_ffs.to_string(),
        f2(s.total_power_mw),
    ]);
    println!("{}", t.render());
    println!(
        "baseline {:.2} mW | TT-Edge {:.2} mW (+{:.1}%) | gated {:.2} mW\n\
         TTD-Engine specialized logic: {:.1}% LUTs, {:.1}% FFs",
        s.baseline_power_mw,
        s.total_power_mw,
        (s.total_power_mw / s.baseline_power_mw - 1.0) * 100.0,
        s.gated_power_mw,
        s.ttd_engine_luts as f64 / s.total_luts as f64 * 100.0,
        s.ttd_engine_ffs as f64 / s.total_ffs as f64 * 100.0,
    );
    Ok(())
}

fn cmd_related() -> Result<()> {
    let specs = [related::qu_tcad21(), related::tt_edge()];
    let mut t = Table::new(
        "TABLE IV: comparison with prior hardware TTD",
        &["Metric", specs[0].name, specs[1].name],
    );
    let mut row = |m: &str, f: &dyn Fn(&related::AcceleratorSpec) -> String| {
        t.row(&[m.to_string(), f(&specs[0]), f(&specs[1])]);
    };
    row("Process technology", &|s| format!("{} nm", s.process_nm));
    row("Number of PEs", &|s| format!("{} + {}", s.pes.0, s.pes.1));
    row("On-chip memory", &|s| format!("{} KB", s.on_chip_memory_kb));
    row("Arithmetic precision", &|s| s.precision.to_string());
    row("Clock frequency", &|s| format!("{} MHz", s.clock_mhz));
    row("Power consumption", &|s| match s.total_power_mw {
        Some(tp) => format!("{:.0} mW ({:.0} mW total)", s.power_mw, tp),
        None => format!("{:.2} W", s.power_mw / 1000.0),
    });
    println!("{}", t.render());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    use tt_edge::runtime::Engine;
    let mut eng = Engine::load_default()?;
    println!("PJRT platform: {}", eng.platform());
    let mut t = Table::new("AOT artifacts", &["entry", "inputs", "outputs", "note"]);
    for name in eng.entry_names() {
        let e = eng.manifest.entry(&name)?.clone();
        t.row(&[
            e.name.clone(),
            e.inputs.len().to_string(),
            e.outputs.len().to_string(),
            e.note.clone(),
        ]);
    }
    println!("{}", t.render());
    if args.flag("smoke") {
        use tt_edge::runtime::Value;
        let out = eng.run(
            "norm_4096",
            &[Value::F32 { shape: vec![4096], data: vec![1.0; 4096] }],
        )?;
        let got = out[0].as_f32()?[0];
        println!("smoke: norm(ones(4096)) = {got} (want 64)");
        anyhow::ensure!((got - 64.0).abs() < 1e-3, "smoke failed");
    }
    Ok(())
}
