//! `ttedge` — the TT-Edge launcher.
//!
//! Subcommands (hand-rolled CLI; clap is unavailable offline). Every
//! subcommand declares its option/flag surface in [`COMMANDS`];
//! unknown subcommands, options or flags are a usage error (exit 2)
//! rather than being silently ignored. All compression paths go
//! through the [`CompressionJob`] builder's streaming cost sink.
//!
//! * `simulate`  — Table III: TTD ResNet-32 compression on Baseline vs
//!   TT-Edge SoCs (`--eps`, `--seed`, `--parallel N` host workers,
//!   `--hbd-threads N` in-layer row-band workers; the
//!   simulated cycles are identical at any width; `--json` emits one
//!   `SimReport` JSON object per SoC).
//! * `compress`  — Table I: compare TTD / Tucker / TRD on a workload
//!   (`--workload resnet32|tiny|tiny-gpt|bert-base|activations`,
//!   `--method all|ttd|rsvd|tucker|trd`, `--parallel N`, `--json`;
//!   `rsvd` runs TTD with the seeded randomized range-finder).
//! * `explore`   — design-space exploration: sweep feature toggles +
//!   hardware knobs under a search strategy and budget, report the
//!   (cycles, energy, area) Pareto frontier, and write the sweep
//!   artifact into `EXPERIMENTS/` (`--workload`, `--space`,
//!   `--strategy grid|random|evolve`, `--method exact|rsvd`,
//!   `--budget`, `--seed`, `--parallel`, `--out`, `--json`).
//! * `serve`     — compression-as-a-service: drain a JSONL request
//!   queue through a keyed `JobProgram` cache (`--requests FILE`,
//!   `--workers N`, `--cache CAPACITY`, `--out FILE`, `--json`); a
//!   repeated (workload, TtSpec) key is served at replay speed with
//!   zero numerics. The greppable cache metrics line goes to stderr;
//!   the serve-metrics-v1 artifact lands in `EXPERIMENTS/`. The drain
//!   is supervised (ISSUE 10): `--lenient` answers malformed lines in
//!   place, and the seeded chaos knobs (`--fault-seed`, `--poison p`,
//!   `--stall p`, `--panic p`, `--cancel p`, `--forced-*` index
//!   lists, `--retries n`) inject faults that surface as structured
//!   `"status": "error"` responses — never process death — plus a
//!   fault-report-v1 artifact when the plan is non-benign.
//! * `federate`  — Fig. 1: fault-tolerant federated rounds over
//!   simulated edge nodes (`--nodes`, `--rounds`,
//!   `--soc baseline|tt-edge|systolic`, chaos: `--dropout p --straggler-mult x
//!   --quorum q --loss p`, `--json` for machine-readable reports).
//! * `resources` — Table II: FPGA/45 nm resource + power breakdown.
//! * `related`   — Table IV: comparison with Qu et al. [21].
//! * `artifacts` — list AOT artifacts; `--smoke` runs a PJRT check.

use anyhow::Result;

use tt_edge::coordinator::{Coordinator, FederatedConfig};
use tt_edge::hw_model::{self, related};
use tt_edge::metrics::{f1, f2, Table};
use tt_edge::sim::{format_table3, SocConfig};
use tt_edge::util::cli::Args;
use tt_edge::CompressionJob;

/// Declared CLI surface of one subcommand — the validation source of
/// truth. Anything not listed here is a usage error (exit 2), never
/// silently ignored.
struct CmdSpec {
    name: &'static str,
    opts: &'static [&'static str],
    flags: &'static [&'static str],
}

const COMMANDS: &[CmdSpec] = &[
    CmdSpec { name: "simulate", opts: &["eps", "seed", "parallel", "hbd-threads"], flags: &["json"] },
    CmdSpec {
        name: "compress",
        opts: &["workload", "method", "eps", "seed", "parallel"],
        flags: &["json"],
    },
    CmdSpec {
        name: "explore",
        opts: &["workload", "space", "strategy", "method", "budget", "seed", "eps", "parallel", "out"],
        flags: &["json"],
    },
    CmdSpec {
        name: "federate",
        opts: &[
            "nodes",
            "rounds",
            "eps",
            "threads",
            "soc",
            "quorum",
            "deadline-slack",
            "dropout",
            "straggler-mult",
            "straggler-frac",
            "fault-seed",
            "loss",
            "retries",
        ],
        flags: &["json", "no-oracle"],
    },
    CmdSpec {
        name: "serve",
        opts: &[
            "requests",
            "workers",
            "cache",
            "out",
            "retries",
            "fault-seed",
            "poison",
            "stall",
            "panic",
            "cancel",
            "forced-poison",
            "forced-stalls",
            "forced-panics",
            "forced-cancels",
        ],
        flags: &["json", "lenient"],
    },
    CmdSpec { name: "resources", opts: &[], flags: &[] },
    CmdSpec { name: "related", opts: &[], flags: &[] },
    CmdSpec { name: "artifacts", opts: &[], flags: &["smoke"] },
];

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    if cmd == "help" || args.flag("help") {
        print_help();
        return;
    }
    let Some(spec) = COMMANDS.iter().find(|c| c.name == cmd) else {
        eprintln!("error: unknown command `{cmd}`");
        eprintln!("run `ttedge help` for usage");
        std::process::exit(2);
    };
    if let Err(msg) = args.validate(spec.opts, spec.flags) {
        eprintln!("error: {msg}");
        eprintln!("run `ttedge help` for usage");
        std::process::exit(2);
    }
    let result = match cmd {
        "simulate" => cmd_simulate(&args),
        "compress" => cmd_compress(&args),
        "explore" => cmd_explore(&args),
        "serve" => cmd_serve(&args),
        "federate" => cmd_federate(&args),
        "resources" => cmd_resources(),
        "related" => cmd_related(),
        "artifacts" => cmd_artifacts(&args),
        _ => unreachable!("command table covers every spec"),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Usage error for an enum-ish option value: print the expected
/// values and exit 2 (same contract as unknown options/flags).
fn invalid(key: &str, val: &str, expected: &str) -> ! {
    eprintln!("error: invalid value for --{key}: `{val}` (expected {expected})");
    eprintln!("run `ttedge help` for usage");
    std::process::exit(2);
}

/// `--key` value with a default — but a *present, unparseable* value
/// is a usage error (exit 2), never a silent fall-back to the default.
fn opt_or<T: std::str::FromStr>(args: &Args, key: &str, default: T) -> T {
    match args.parse_opt_strict(key) {
        Ok(Some(v)) => v,
        Ok(None) => default,
        Err(msg) => {
            eprintln!("error: {msg}");
            eprintln!("run `ttedge help` for usage");
            std::process::exit(2);
        }
    }
}

fn print_help() {
    println!(
        "ttedge — TT-Edge (DATE 2026) reproduction\n\n\
         USAGE: ttedge <simulate|compress|explore|serve|federate|resources|related|artifacts> [--opts]\n\n\
         simulate   Table III (exec time + energy, baseline vs TT-Edge; --parallel N, --hbd-threads N, --json)\n\
         compress   Table I  (TTD vs Tucker vs TRD; --workload resnet32|tiny|tiny-gpt|bert-base|activations\n\
                    --method all|ttd|rsvd|tucker|trd --parallel N --json;\n\
                    rsvd = TTD with the seeded randomized range-finder)\n\
         explore    design-space exploration: Pareto frontier over (cycles, energy, area)\n\
                    (--workload resnet32|tiny|tiny-gpt|bert-base|activations\n\
                    --space paper|features|full --strategy grid|random|evolve\n\
                    --method exact|rsvd --budget N --seed S --parallel N\n\
                    --out FILE --json; sweep artifact lands in EXPERIMENTS/)\n\
         serve      compression-as-a-service: drain a JSONL request queue through a\n\
                    keyed JobProgram cache (--requests FILE --workers N --cache CAP\n\
                    --out FILE --json; cache metrics on stderr, serve-metrics-v1\n\
                    artifact in EXPERIMENTS/). Supervised drain: --lenient answers\n\
                    malformed lines in place; chaos: --fault-seed S --poison p\n\
                    --stall p --panic p --cancel p --forced-poison I,J\n\
                    --forced-stalls I,J --forced-panics I,J --forced-cancels I,J\n\
                    --retries n (faults become structured error responses and a\n\
                    fault-report-v1 artifact, never process death)\n\
         federate   Fig. 1   (fault-tolerant federated rounds; --threads N per node,\n\
                    --dropout p --straggler-mult x --straggler-frac f --quorum q\n\
                    --loss p --retries n --deadline-slack s --fault-seed s\n\
                    --no-oracle --json)\n\
         resources  Table II (resource + power breakdown)\n\
         related    Table IV (vs Qu et al. [21])\n\
         artifacts  list / smoke-run the AOT artifacts"
    );
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let eps: f32 = opt_or(args, "eps", 0.12);
    let seed: u64 = opt_or(args, "seed", 42);
    let parallel: usize = opt_or(args, "parallel", 1);
    // In-layer row-band workers for each bidiagonalization; 0 keeps
    // the TTEDGE_HBD_THREADS/env default. Bit-identical at any width,
    // so the simulated cycles (and --json bytes) never move.
    let hbd_threads: usize = opt_or(args, "hbd-threads", 0);
    let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
    // lint: allow(no-wallclock-or-unseeded-rng): operator-facing wall timing on stderr only; simulated cycles and --json bytes never depend on it
    let t0 = std::time::Instant::now();
    // Streaming job: ops fold into both SoC cost models online — no
    // trace is materialized at any --parallel width.
    let mut job = CompressionJob::synthetic(seed)
        .eps(eps)
        .parallel(parallel)
        .socs(&configs);
    if hbd_threads > 0 {
        job = job.hbd_threads(hbd_threads);
    }
    let job_out = job.run().expect("no cancel token on the CLI path");
    let (out, reports) = (job_out.outcome, job_out.reports);
    if args.flag("json") {
        for r in &reports {
            println!("{}", r.to_json().render());
        }
        return Ok(());
    }
    println!(
        "workload: ResNet-32, eps={eps}, compression {:.2}x, final params {} \
         ({} host thread{}, {:.0} ms wall)\n",
        out.compression_ratio,
        out.final_params,
        parallel.max(1),
        if parallel > 1 { "s" } else { "" },
        t0.elapsed().as_secs_f64() * 1e3
    );
    println!("{}", format_table3(&reports[0], &reports[1]));
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    use std::collections::BTreeMap;
    use tt_edge::dse::Workload;
    use tt_edge::model::TransformerSpec;
    use tt_edge::ttd::TtSpec;
    use tt_edge::util::json::Json;

    let method = args.opt_or("method", "all");
    if !matches!(method.as_str(), "all" | "ttd" | "rsvd" | "tucker" | "trd") {
        invalid("method", &method, "all|ttd|rsvd|tucker|trd");
    }
    let workload = args.opt_or("workload", "resnet32");
    let workload = Workload::parse(&workload).unwrap_or_else(|| {
        invalid("workload", &workload, "resnet32|tiny|tiny-gpt|bert-base|activations")
    });
    let eps: f32 = opt_or(args, "eps", 0.12);
    let seed: u64 = opt_or(args, "seed", 42);
    let parallel: usize = opt_or(args, "parallel", 1);
    let as_json = args.flag("json");

    // Whole-model dense inventory: the ResNet workloads keep the
    // paper's full param count (Table I denominators); transformer
    // workloads account their own block inventory (ISSUE 9).
    let dense = match workload {
        Workload::Resnet32 | Workload::Tiny => tt_edge::model::param_count(),
        Workload::TinyGpt => TransformerSpec::tiny_gpt().param_count(),
        Workload::BertBase => TransformerSpec::bert_base().param_count(),
        Workload::Activations => TransformerSpec::tiny_gpt().activation_count(),
    };

    // (table label, json key, worst rel err or NaN, final params)
    let mut rows: Vec<(&str, &str, f64, usize)> =
        vec![("Uncompressed", "uncompressed", f64::NAN, dense)];
    if matches!(method.as_str(), "all" | "tucker" | "trd") {
        // The baseline decompositions consume the materialized layer
        // list directly, so only these branches pay to generate it.
        let layers = workload.layers(seed);
        let conv_dense: usize = layers.iter().map(|(l, _)| l.numel()).sum();
        if method == "all" || method == "tucker" {
            let (params, err) = run_tucker(&layers, eps);
            rows.push(("Tucker [12]", "tucker", f64::from(err), dense - conv_dense + params));
        }
        if method == "all" || method == "trd" {
            let (params, err) = run_trd(&layers, eps);
            rows.push(("TRD [13]", "trd", f64::from(err), dense - conv_dense + params));
        }
    }
    if matches!(method.as_str(), "all" | "ttd" | "rsvd") {
        // `rsvd` swaps the exact bidiagonal SVD for the seeded
        // randomized range-finder inside the same TTD pipeline; the
        // sketch seed is the run seed so reruns are bit-identical.
        let spec =
            if method == "rsvd" { TtSpec::eps(eps).rsvd(seed, 8) } else { TtSpec::eps(eps) };
        // lint: allow(no-wallclock-or-unseeded-rng): operator-facing wall timing on stderr only; table artifacts are derived from deterministic job outputs
        let t0 = std::time::Instant::now();
        let mut backing = None;
        let out = workload
            .job(seed, &mut backing)
            .spec(spec)
            .parallel(parallel)
            .run()
            .expect("no cancel token on the CLI path")
            .outcome;
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let (label, key) =
            if method == "rsvd" { ("TTD (rsvd)", "rsvd") } else { ("TTD (this work)", "ttd") };
        rows.push((label, key, f64::from(out.max_rel_err), out.final_params));
        if !as_json {
            println!(
                "TTD: {} decomposition{} on {} host thread{} in {wall_ms:.0} ms",
                out.decomps.len(),
                if out.decomps.len() == 1 { "" } else { "s" },
                parallel.max(1),
                if parallel > 1 { "s" } else { "" },
            );
        }
    }

    if as_json {
        // Machine-readable Table I: one object per method, NaN rel
        // errors (the uncompressed row) render as null.
        let methods: Vec<Json> = rows
            .iter()
            .map(|(_, key, err, fin)| {
                let mut m = BTreeMap::new();
                m.insert("method".into(), Json::from(*key));
                m.insert("recon_err".into(), Json::from(*err));
                m.insert("compression_ratio".into(), Json::from(dense as f64 / *fin as f64));
                m.insert("final_params".into(), Json::from(*fin));
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("workload".into(), Json::from(workload.label()));
        m.insert("method".into(), Json::from(method.as_str()));
        m.insert("eps".into(), Json::from(f64::from(eps)));
        // string: u64 seeds don't fit JSON's f64-exact integer range
        m.insert("seed".into(), Json::Str(seed.to_string()));
        m.insert("dense_params".into(), Json::from(dense));
        m.insert("methods".into(), Json::Arr(methods));
        println!("{}", Json::Obj(m).render());
        return Ok(());
    }

    let title = format!(
        "TABLE I: TD method comparison, {} (synthetic-trained weights)",
        workload.label()
    );
    let mut t = Table::new(&title, &["Method", "Recon err", "Comp. ratio", "Final #params"]);
    for (label, _, err, fin) in &rows {
        t.row(&[
            (*label).to_string(),
            if err.is_nan() { "-".into() } else { format!("{err:.3}") },
            format!("{:.1}x", dense as f64 / *fin as f64),
            fin.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_explore(args: &Args) -> Result<()> {
    use std::path::PathBuf;
    use tt_edge::dse::{self, ExploreConfig, SpaceKind, Strategy, Workload};
    use tt_edge::ttd::SvdMethod;

    let workload = args.opt_or("workload", "resnet32");
    let workload = Workload::parse(&workload).unwrap_or_else(|| {
        invalid("workload", &workload, "resnet32|tiny|tiny-gpt|bert-base|activations")
    });
    let space = args.opt_or("space", "full");
    let space = SpaceKind::parse(&space)
        .unwrap_or_else(|| invalid("space", &space, "paper|features|full"));
    let strategy = args.opt_or("strategy", "grid");
    let strategy = Strategy::parse(&strategy)
        .unwrap_or_else(|| invalid("strategy", &strategy, "grid|random|evolve"));
    let seed: u64 = opt_or(args, "seed", 42);
    let method = args.opt_or("method", "exact");
    // `--method` is a numerics knob, not a genome axis: it shapes the
    // recorded op stream, so it lives on the ExploreConfig and the
    // whole sweep shares one method (record-once / replay-many holds).
    let method = match method.as_str() {
        "exact" => SvdMethod::Exact,
        "rsvd" => SvdMethod::Randomized { seed, oversample: 8 },
        _ => invalid("method", &method, "exact|rsvd"),
    };
    let cfg = ExploreConfig {
        workload,
        space,
        strategy,
        budget: opt_or(args, "budget", 32),
        seed,
        eps: opt_or(args, "eps", 0.12),
        method,
        parallel: opt_or(args, "parallel", 1),
    };

    // lint: allow(no-wallclock-or-unseeded-rng): operator-facing wall timing on stderr only; DSE rankings are cycle-model ordered, never wall-clock ordered
    let t0 = std::time::Instant::now();
    let out = dse::explore(&cfg);
    // Record-once / replay-many instrumentation: one numerics pass
    // regardless of strategy or generation count. Printed to stderr
    // (CI asserts it) so the stdout artifacts stay byte-identical to
    // the live-costed reference path.
    eprintln!("numerics passes: {}", out.numerics_passes);

    // Sweep artifact: every evaluated point (schema in
    // EXPERIMENTS/README.md). Byte-identical at any --parallel width.
    // Default target is the checkout's EXPERIMENTS/ when this binary
    // still runs next to it, else ./EXPERIMENTS relative to the cwd
    // (the compile-time manifest path is meaningless for a shipped
    // binary). A failed artifact write warns but never aborts the run
    // — the frontier report is the primary output.
    let path: PathBuf = match args.opt("out") {
        Some(p) => PathBuf::from(p),
        None => {
            let checkout: PathBuf =
                [env!("CARGO_MANIFEST_DIR"), "..", "EXPERIMENTS"].iter().collect();
            let dir = if checkout.is_dir() {
                checkout
            } else {
                PathBuf::from("EXPERIMENTS")
            };
            dir.join("DSE_sweep.json")
        }
    };
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(&path, out.sweep_json().render() + "\n") {
        Ok(()) => eprintln!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write sweep artifact {}: {e}", path.display()),
    }

    if args.flag("json") {
        println!("{}", out.report_json().render());
        return Ok(());
    }
    println!(
        "explored {} of {} candidates (workload {}, eps {}, {} host thread{}, \
         {} numerics pass{}, {:.0} ms wall)\n",
        out.evaluated.len(),
        out.space_size,
        cfg.workload.label(),
        cfg.eps,
        cfg.parallel.max(1),
        if cfg.parallel > 1 { "s" } else { "" },
        out.numerics_passes,
        if out.numerics_passes == 1 { "" } else { "es" },
        t0.elapsed().as_secs_f64() * 1e3,
    );
    println!("{}", out.frontier_table());
    let tte = &out.evaluated[1];
    println!(
        "paper anchor `tt-edge`: {:.2}x speedup, {:.1}% energy reduction, +{} LUTs vs baseline{}",
        out.speedup(tte),
        out.energy_reduction_pct(tte),
        tte.objectives.area_luts.saturating_sub(out.baseline().objectives.area_luts),
        if out.frontier.contains(&1) { " (on the frontier)" } else { "" },
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use std::path::PathBuf;
    use tt_edge::fault::ChaosPlan;
    use tt_edge::serve::{self, QueueEntry, ServeConfig};

    let Some(path) = args.opt("requests") else {
        eprintln!("error: serve requires --requests FILE (JSONL, one request object per line)");
        eprintln!("run `ttedge help` for usage");
        std::process::exit(2);
    };
    let workers: usize = opt_or(args, "workers", 1);
    let capacity: usize = opt_or(args, "cache", 64);
    // Seeded chaos plan (ISSUE 10). The defaults are the benign plan
    // — zero probabilities, empty forced lists — under which the drain
    // is bit-identical to the unsupervised PR-6 path.
    let chaos = ChaosPlan {
        seed: opt_or(args, "fault-seed", ChaosPlan::default().seed),
        poison: opt_or(args, "poison", 0.0),
        stall: opt_or(args, "stall", 0.0),
        panic: opt_or(args, "panic", 0.0),
        cancel: opt_or(args, "cancel", 0.0),
        forced_poison: index_list(args, "forced-poison"),
        forced_stalls: index_list(args, "forced-stalls"),
        forced_panics: index_list(args, "forced-panics"),
        forced_cancels: index_list(args, "forced-cancels"),
    };
    let cfg = ServeConfig {
        workers,
        cache_capacity: capacity,
        chaos: chaos.clone(),
        retries: opt_or(args, "retries", ServeConfig::default().retries),
    };
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("could not read {path}: {e}"))?;
    // Strict mode fails the whole file on the first malformed line;
    // --lenient turns each bad line into an in-place error response.
    let entries: Vec<QueueEntry> = if args.flag("lenient") {
        serve::parse_requests_lenient(&text)
    } else {
        serve::parse_requests(&text)
            .map_err(|e| anyhow::anyhow!("{path}: {e} (--lenient answers bad lines in place)"))?
            .into_iter()
            .map(QueueEntry::Request)
            .collect()
    };
    anyhow::ensure!(!entries.is_empty(), "{path}: no requests in the queue");

    // lint: allow(no-wallclock-or-unseeded-rng): wall_ms feeds the serve-metrics artifact by design (PR-6); byte-pinned outputs exclude it
    let t0 = std::time::Instant::now();
    let out = serve::serve_queue(&entries, &cfg);
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    // The greppable cache/numerics accounting goes to stderr (CI
    // asserts hit counts and exactly-K numerics passes against it) so
    // stdout stays byte-identical at any --workers width.
    eprintln!("{}", out.metrics_line());

    // serve-metrics-v1 artifact (same default-dir logic as `explore`:
    // the checkout's EXPERIMENTS/ when the binary still runs next to
    // it, else ./EXPERIMENTS; a failed write warns, never aborts).
    let apath: PathBuf = match args.opt("out") {
        Some(p) => PathBuf::from(p),
        None => {
            let checkout: PathBuf =
                [env!("CARGO_MANIFEST_DIR"), "..", "EXPERIMENTS"].iter().collect();
            let dir = if checkout.is_dir() {
                checkout
            } else {
                PathBuf::from("EXPERIMENTS")
            };
            dir.join("SERVE_metrics.json")
        }
    };
    if let Some(dir) = apath.parent().filter(|d| !d.as_os_str().is_empty()) {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(&apath, out.metrics_json(wall_ms).render() + "\n") {
        Ok(()) => eprintln!("wrote {}", apath.display()),
        Err(e) => {
            eprintln!("warning: could not write serve artifact {}: {e}", apath.display())
        }
    }

    // fault-report-v1 artifact, only under a non-benign plan (benign
    // runs keep the PR-6 artifact surface byte-for-byte). Lands next
    // to the serve-metrics artifact; a failed write warns, never
    // aborts — the responses are the primary output.
    if !chaos.is_benign() {
        let fpath = apath.with_file_name("FAULT_report.json");
        match std::fs::write(&fpath, serve::fault_report(&out, &chaos).render() + "\n") {
            Ok(()) => eprintln!("wrote {}", fpath.display()),
            Err(e) => {
                eprintln!("warning: could not write fault report {}: {e}", fpath.display())
            }
        }
    }

    if args.flag("json") {
        for r in &out.responses {
            println!("{}", r.to_json().render());
        }
        return Ok(());
    }
    println!(
        "served {} request{} with {} worker{} (cache capacity {}, hit rate {:.0}%, \
         {} numerics pass{}, {wall_ms:.0} ms wall)\n",
        out.responses.len(),
        if out.responses.len() == 1 { "" } else { "s" },
        out.workers,
        if out.workers == 1 { "" } else { "s" },
        out.cache_capacity,
        out.stats.hit_rate() * 100.0,
        out.numerics_passes,
        if out.numerics_passes == 1 { "" } else { "es" },
    );
    let mut t = Table::new(
        "serve: per-request compression + SoC costing",
        &["req", "workload", "seed", "eps", "caps", "ratio", "SoC", "T (ms)", "E (mJ)"],
    );
    for r in &out.responses {
        // Error responses (injected faults, deadlines, lenient-mode
        // malformed lines) keep their queue slot in the table: one row
        // with the structured error code where the SoC costing would
        // have gone. Malformed lines never parsed, so the request echo
        // columns are dashes.
        let Some(req) = &r.request else {
            let code =
                r.error.as_ref().map(|e| e.code().to_string()).unwrap_or_else(|| "?".into());
            t.row(&[
                r.index.to_string(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                format!("error: {code}"),
                "-".into(),
                "-".into(),
            ]);
            continue;
        };
        let caps = if !req.rank_caps.is_empty() {
            req.rank_caps.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",")
        } else if let Some(cap) = req.rank_cap {
            format!("u{cap}")
        } else {
            "-".into()
        };
        if let Some(e) = &r.error {
            t.row(&[
                r.index.to_string(),
                req.workload.label().to_string(),
                req.seed.to_string(),
                format!("{}", req.eps),
                caps.clone(),
                "-".into(),
                format!("error: {}", e.code()),
                "-".into(),
                "-".into(),
            ]);
            continue;
        }
        for rep in &r.reports {
            t.row(&[
                r.index.to_string(),
                req.workload.label().to_string(),
                req.seed.to_string(),
                format!("{}", req.eps),
                caps.clone(),
                format!("{:.2}x", r.compression_ratio),
                rep.config_name.clone(),
                f1(rep.total_ms),
                f1(rep.total_mj),
            ]);
        }
    }
    println!("{}", t.render());
    Ok(())
}

/// `--forced-*` chaos lists: the CLI option surface is single-valued,
/// so request-index lists ride in one comma-separated argument.
fn index_list(args: &Args, key: &str) -> Vec<usize> {
    let Some(raw) = args.opt(key) else {
        return Vec::new();
    };
    raw.split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| {
            s.parse().unwrap_or_else(|_| {
                invalid(key, raw, "comma-separated request indices, e.g. 0,3,7")
            })
        })
        .collect()
}

fn run_tucker(
    layers: &[(tt_edge::model::ConvLayer, tt_edge::ttd::Tensor)],
    eps: f32,
) -> (usize, f32) {
    use tt_edge::ttd::tucker;
    let mut params = 0usize;
    let mut worst = 0.0f32;
    for (l, w) in layers {
        let t = w.reshape(&l.tt_dims());
        let d = tucker::decompose(&t, eps);
        params += d.param_count();
        worst = worst.max(tucker::relative_error(&t, &d));
    }
    (params, worst)
}

fn run_trd(
    layers: &[(tt_edge::model::ConvLayer, tt_edge::ttd::Tensor)],
    eps: f32,
) -> (usize, f32) {
    use tt_edge::ttd::trd;
    let mut params = 0usize;
    let mut worst = 0.0f32;
    for (l, w) in layers {
        let t = w.reshape(&l.tt_dims());
        let d = trd::decompose(&t, eps);
        params += d.param_count();
        worst = worst.max(trd::relative_error(&t, &d));
    }
    (params, worst)
}

fn cmd_federate(args: &Args) -> Result<()> {
    use tt_edge::coordinator::{FaultPlan, Link};

    let soc = match args.opt_or("soc", "tt-edge").as_str() {
        "baseline" => SocConfig::baseline(),
        "tt-edge" => SocConfig::tt_edge(),
        "systolic" => SocConfig::systolic(),
        other => invalid("soc", other, "baseline|tt-edge|systolic"),
    };
    let faults = FaultPlan {
        dropout: opt_or(args, "dropout", 0.0),
        straggler_mult: opt_or(args, "straggler-mult", 1.0),
        straggler_frac: opt_or(args, "straggler-frac", 0.25),
        seed: opt_or(args, "fault-seed", 0xFA17),
        ..Default::default()
    };
    let link = Link {
        loss: opt_or(args, "loss", 0.0),
        max_retries: opt_or(args, "retries", 3),
        ..Link::default()
    };
    let cfg = FederatedConfig {
        nodes: opt_or(args, "nodes", 4),
        rounds: opt_or(args, "rounds", 3),
        eps: opt_or(args, "eps", 0.12),
        threads_per_node: opt_or(args, "threads", 1),
        min_quorum: opt_or(args, "quorum", 0),
        deadline_slack: opt_or(args, "deadline-slack", 1.0),
        exact_oracle: !args.flag("no-oracle"),
        soc,
        link,
        faults,
        ..Default::default()
    };
    let as_json = args.flag("json");
    if !as_json {
        println!(
            "federated run: {} nodes x {} rounds on {} SoCs \
             (dropout {:.2}, straggler x{:.1}, link loss {:.2}, quorum {})\n",
            cfg.nodes,
            cfg.rounds,
            cfg.soc.name(),
            cfg.faults.dropout,
            cfg.faults.straggler_mult,
            cfg.link.loss,
            if cfg.min_quorum == 0 { "all".to_string() } else { cfg.min_quorum.to_string() },
        );
    }
    let mut c = Coordinator::new(cfg);
    let reports = c.run();
    if as_json {
        // One JSON object per round — the machine-readable surface of
        // the same table, with every participation/fault field.
        for r in &reports {
            println!("{}", r.to_json().render());
        }
        return Ok(());
    }
    let mut t = Table::new(
        "Fig. 1 workflow: compressed parameter transmission",
        &[
            "round", "part", "drop", "late", "retry", "wire KB", "comm red.",
            "compress ms", "energy mJ", "xfer ms", "deadline ms", "agg err",
        ],
    );
    for r in &reports {
        t.row(&[
            r.round.to_string(),
            format!("{}/{}", r.participants, r.scheduled),
            r.dropped.to_string(),
            r.late.to_string(),
            r.retries.to_string(),
            f1(r.wire_bytes as f64 / 1024.0),
            format!("{:.2}x", r.communication_reduction),
            f1(r.mean_compress_ms),
            f1(r.mean_compress_mj),
            f1(r.round_transfer_ms),
            f1(r.deadline_ms),
            if r.aggregate_rel_err.is_nan() {
                "-".to_string()
            } else {
                format!("{:.4}", r.aggregate_rel_err)
            },
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_resources() -> Result<()> {
    let mut t = Table::new(
        "TABLE II: resource usage and 45 nm power breakdown",
        &["IP", "LUTs", "FFs", "Power (mW)"],
    );
    for b in hw_model::tt_edge_blocks() {
        let name = if b.ttd_engine_specialized {
            format!("TTD-Engine: {}", b.name)
        } else {
            b.name.to_string()
        };
        let p = match b.gated_power_mw {
            Some(g) => format!("{:.2} / {:.2} (gated)", b.power_mw, g),
            None => f2(b.power_mw),
        };
        t.row(&[name, b.luts.to_string(), b.ffs.to_string(), p]);
    }
    let s = hw_model::summarize();
    t.row(&[
        "TOTAL (TT-Edge)".into(),
        s.total_luts.to_string(),
        s.total_ffs.to_string(),
        f2(s.total_power_mw),
    ]);
    println!("{}", t.render());
    println!(
        "baseline {:.2} mW | TT-Edge {:.2} mW (+{:.1}%) | gated {:.2} mW\n\
         TTD-Engine specialized logic: {:.1}% LUTs, {:.1}% FFs",
        s.baseline_power_mw,
        s.total_power_mw,
        (s.total_power_mw / s.baseline_power_mw - 1.0) * 100.0,
        s.gated_power_mw,
        s.ttd_engine_luts as f64 / s.total_luts as f64 * 100.0,
        s.ttd_engine_ffs as f64 / s.total_ffs as f64 * 100.0,
    );
    Ok(())
}

fn cmd_related() -> Result<()> {
    let specs = [related::qu_tcad21(), related::tt_edge()];
    let mut t = Table::new(
        "TABLE IV: comparison with prior hardware TTD",
        &["Metric", specs[0].name, specs[1].name],
    );
    let mut row = |m: &str, f: &dyn Fn(&related::AcceleratorSpec) -> String| {
        t.row(&[m.to_string(), f(&specs[0]), f(&specs[1])]);
    };
    row("Process technology", &|s| format!("{} nm", s.process_nm));
    row("Number of PEs", &|s| format!("{} + {}", s.pes.0, s.pes.1));
    row("On-chip memory", &|s| format!("{} KB", s.on_chip_memory_kb));
    row("Arithmetic precision", &|s| s.precision.to_string());
    row("Clock frequency", &|s| format!("{} MHz", s.clock_mhz));
    row("Power consumption", &|s| match s.total_power_mw {
        Some(tp) => format!("{:.0} mW ({:.0} mW total)", s.power_mw, tp),
        None => format!("{:.2} W", s.power_mw / 1000.0),
    });
    println!("{}", t.render());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    use tt_edge::runtime::Engine;
    let mut eng = Engine::load_default()?;
    println!("PJRT platform: {}", eng.platform());
    let mut t = Table::new("AOT artifacts", &["entry", "inputs", "outputs", "note"]);
    for name in eng.entry_names() {
        let e = eng.manifest.entry(&name)?.clone();
        t.row(&[
            e.name.clone(),
            e.inputs.len().to_string(),
            e.outputs.len().to_string(),
            e.note.clone(),
        ]);
    }
    println!("{}", t.render());
    if args.flag("smoke") {
        use tt_edge::runtime::Value;
        let out = eng.run(
            "norm_4096",
            &[Value::F32 { shape: vec![4096], data: vec![1.0; 4096] }],
        )?;
        let got = out[0].as_f32()?[0];
        println!("smoke: norm(ones(4096)) = {got} (want 64)");
        anyhow::ensure!((got - 64.0).abs() < 1e-3, "smoke failed");
    }
    Ok(())
}
