//! Batched, parallel multi-layer TTD compression.
//!
//! Per-layer TT compression is embarrassingly parallel (each conv
//! kernel is an independent Algorithm-1 run), so the pipeline fans the
//! layer queue out to `std::thread::scope` workers that *steal* work
//! from a shared atomic cursor: a worker that finishes a small
//! stage-0 layer immediately grabs the next job instead of waiting on
//! the big stage-2 kernels.
//!
//! Every worker emits its layer's hardware ops into a **private sink**
//! built by a caller-supplied factory ([`compress_layers_sinked`]),
//! and the per-layer sinks merge back **deterministically in layer
//! order**. Two concrete shapes matter:
//!
//! * **Streaming (default)** — [`compress_layers_costed`]: each layer
//!   folds its ops into a [`CostSink`] (per-phase u64 cycle counters,
//!   O(1) memory in trace length) and the summaries are absorbed in
//!   layer order. Because all accumulators are u64, the merged totals
//!   are bit-identical to the serial single-sink stream at any worker
//!   count. Nothing proportional to the trace is ever allocated.
//! * **Recording (observers)** — [`compress_layers`] and friends keep
//!   a [`VecSink`] per layer; [`replay_traces`] replays them in layer
//!   order, op-for-op identical to the serial trace (golden-pinned by
//!   `tests/golden_trace.rs`). This is the opt-in path for tests,
//!   benches and [`crate::job::CompressionJob::sink`] observers.
//!
//! This is the scaling substrate for everything downstream: the
//! [`crate::job::CompressionJob`] builder (the single user-facing
//! entry point), the CLI (`ttedge compress/simulate --parallel N`),
//! the federated coordinator (nodes compress their layer batch through
//! this module and ship one [`TtBatch`]), and `benches/hotpath.rs`
//! (serial vs parallel wall-clock).
//!
//! The layer fan-out here composes with **in-layer** parallelism: the
//! compact-WY bidiagonalization inside each Algorithm-1 run can split
//! its row-band GEMM passes across
//! `crate::ttd::svd::bidiag::panel_threads()` workers
//! (`CompressionJob::hbd_threads` / `TTEDGE_HBD_THREADS`). Row bands
//! keep every k-accumulation chain intact, so the composed
//! parallelism — layers times bands — is still bit-identical to the
//! fully serial run; with few large layers in flight the in-layer
//! split is where the remaining cores go.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;

use crate::model::resnet32::ConvLayer;
use crate::sim::config::SocConfig;
use crate::sim::cost::CostSink;
use crate::sim::report::SimReport;
use crate::sim::workload::{aggregate_outcome, synthetic_model, CompressionOutcome};
use crate::trace::{OpProgram, RecordingSink, TraceSink, VecSink};
use crate::ttd::ttd::{TtDecomp, TtSpec};
use crate::ttd::{decompose, relative_error, Tensor};

/// One compressed layer: the decomposition plus the sink its
/// Algorithm-1 ops were emitted into — a full [`VecSink`] trace on the
/// recording path, a folded [`CostSink`] summary on the streaming
/// path.
#[derive(Clone, Debug)]
pub struct LayerResult<S = VecSink> {
    /// Position in the input layer list (merge key).
    pub index: usize,
    pub decomp: TtDecomp,
    pub sink: S,
    pub rel_err: f32,
}

/// A batch of TT decompositions shipped as one unit (the Fig.-1 wire
/// payload of a federated node: every layer's cores + a batch header).
#[derive(Clone, Debug, Default)]
pub struct TtBatch {
    pub decomps: Vec<TtDecomp>,
}

impl TtBatch {
    pub fn from_results<S>(results: &[LayerResult<S>]) -> Self {
        TtBatch { decomps: results.iter().map(|r| r.decomp.clone()).collect() }
    }

    /// Take ownership of already-extracted decompositions (no clone).
    pub fn from_decomps(decomps: Vec<TtDecomp>) -> Self {
        TtBatch { decomps }
    }

    pub fn len(&self) -> usize {
        self.decomps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.decomps.is_empty()
    }

    /// Total TT parameters across the batch.
    pub fn param_count(&self) -> usize {
        self.decomps.iter().map(|d| d.param_count()).sum()
    }

    /// Bytes on the wire: every decomposition's payload plus an
    /// 8-byte batch header (count + flags).
    pub fn wire_bytes(&self) -> usize {
        8 + self.decomps.iter().map(|d| d.wire_bytes()).sum::<usize>()
    }
}

/// Clamp a requested worker count to something sensible for `jobs`.
fn worker_count(requested: usize, jobs: usize) -> usize {
    requested.max(1).min(jobs.max(1))
}

/// Cooperative cancellation for a layer batch. The fault-tolerant
/// coordinator hands every node's compression a token; a node the
/// fault plan crashes gets a pre-cancelled one, and a batch whose
/// token trips mid-flight is discarded whole — no partially-compressed
/// batch can ever escape into aggregation.
#[derive(Debug, Default)]
pub struct CancelToken {
    flag: AtomicBool,
}

impl CancelToken {
    pub fn cancelled() -> Self {
        let t = CancelToken::default();
        t.cancel();
        t
    }

    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }
}

/// The generic work-stealing engine. Compress every `(layer, tensor)`
/// pair with `threads` workers stealing from a shared queue, each
/// layer emitting into a private sink from `make_sink`. Results come
/// back sorted by layer index. `threads == 1` runs inline (no thread
/// spawn) and is byte-identical to the serial path. Workers check
/// `cancel` before claiming each layer; a cancelled batch returns
/// `None` — never a partial result.
pub fn compress_layers_sinked<S, F>(
    jobs: &[(&ConvLayer, &Tensor)],
    spec: &TtSpec,
    threads: usize,
    cancel: &CancelToken,
    make_sink: F,
) -> Option<Vec<LayerResult<S>>>
where
    S: TraceSink + Send,
    F: Fn() -> S + Sync,
{
    if cancel.is_cancelled() {
        return None;
    }
    let threads = worker_count(threads, jobs.len());
    let compress_one = |index: usize| -> LayerResult<S> {
        let (layer, w) = jobs[index];
        let dims = layer.tt_dims();
        // reshape only when the caller's tensor is not already in the
        // TT layout (reshape clones the data; decompose only reads it)
        let reshaped;
        let t: &Tensor = if w.shape == dims {
            w
        } else {
            reshaped = w.reshape(&dims);
            &reshaped
        };
        let mut sink = make_sink();
        let decomp = decompose(t, spec, &mut sink);
        let rel_err = relative_error(t, &decomp);
        LayerResult { index, decomp, sink, rel_err }
    };

    if threads <= 1 {
        let mut results = Vec::with_capacity(jobs.len());
        for i in 0..jobs.len() {
            if cancel.is_cancelled() {
                return None;
            }
            results.push(compress_one(i));
        }
        return Some(results);
    }

    let cursor = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<LayerResult<S>>();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let cursor = &cursor;
            let compress_one = &compress_one;
            scope.spawn(move || loop {
                // Work stealing: the shared cursor is the queue head;
                // whichever worker is free claims the next layer.
                if cancel.is_cancelled() {
                    break;
                }
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                if tx.send(compress_one(i)).is_err() {
                    break;
                }
            });
        }
    });
    drop(tx);
    if cancel.is_cancelled() {
        return None;
    }
    let mut results: Vec<LayerResult<S>> = rx.into_iter().collect();
    results.sort_by_key(|r| r.index);
    Some(results)
}

/// Recording path: compress every `(layer, tensor)` pair, each layer
/// carrying its own full [`VecSink`] trace.
pub fn compress_layers(
    layers: &[(ConvLayer, Tensor)],
    eps: f32,
    threads: usize,
) -> Vec<LayerResult> {
    let jobs: Vec<(&ConvLayer, &Tensor)> = layers.iter().map(|(l, w)| (l, w)).collect();
    compress_layers_ref(&jobs, eps, threads)
}

/// Borrowed-pair variant of [`compress_layers`] — callers that hold
/// layers and tensors in separate collections (the coordinator's
/// per-node locals) fan out without cloning any weight data.
pub fn compress_layers_ref(
    jobs: &[(&ConvLayer, &Tensor)],
    eps: f32,
    threads: usize,
) -> Vec<LayerResult> {
    compress_layers_cancellable(jobs, eps, threads, &CancelToken::default())
        .expect("uncancellable batch cannot be cancelled")
}

/// [`compress_layers_ref`] with cooperative cancellation (see
/// [`compress_layers_sinked`] for the cancellation contract). A
/// never-tripped token is byte-identical to the plain path (the check
/// is one atomic load per layer).
pub fn compress_layers_cancellable(
    jobs: &[(&ConvLayer, &Tensor)],
    eps: f32,
    threads: usize,
    cancel: &CancelToken,
) -> Option<Vec<LayerResult>> {
    compress_layers_sinked(jobs, &TtSpec::eps(eps), threads, cancel, VecSink::default)
}

/// A streaming-compressed layer batch: decompositions plus the merged
/// per-config cost summaries — no per-op storage anywhere.
#[derive(Debug)]
pub struct CostedBatch {
    pub decomps: Vec<TtDecomp>,
    /// Per-layer relative reconstruction errors, in layer order.
    pub rel_errs: Vec<f32>,
    pub max_rel_err: f32,
    /// The layer-order merge of every layer's streaming cost summary.
    pub cost: CostSink,
}

impl CostedBatch {
    pub fn reports(&self) -> Vec<SimReport> {
        self.cost.reports()
    }
}

/// Streaming default path: compress the batch with each layer folding
/// its ops into a private [`CostSink`] over `configs`, then merge the
/// summaries in layer order. Memory is O(layers x configs), constant
/// in trace length; the merged cycle/energy totals are bit-identical
/// to a `VecSink`-then-replay run at any thread count (pinned by
/// `tests/sink_composition.rs` and the golden-trace harness).
pub fn compress_layers_costed(
    jobs: &[(&ConvLayer, &Tensor)],
    spec: &TtSpec,
    threads: usize,
    cancel: &CancelToken,
    configs: &[SocConfig],
) -> Option<CostedBatch> {
    let results =
        compress_layers_sinked(jobs, spec, threads, cancel, || CostSink::new(configs))?;
    let mut cost = CostSink::new(configs);
    let mut decomps = Vec::with_capacity(results.len());
    let mut rel_errs = Vec::with_capacity(results.len());
    let mut max_rel = 0.0f32;
    for r in results {
        cost.absorb(&r.sink);
        if r.rel_err > max_rel {
            max_rel = r.rel_err;
        }
        rel_errs.push(r.rel_err);
        decomps.push(r.decomp);
    }
    Some(CostedBatch { decomps, rel_errs, max_rel_err: max_rel, cost })
}

/// A recorded layer batch: decompositions plus the RLE-compacted
/// [`OpProgram`] (one segment per layer, layer order) — the
/// record-once half of the record-once / replay-many costing seam
/// ([`crate::job::CompressionJob::program`] builds on this).
#[derive(Debug)]
pub struct RecordedBatch {
    pub decomps: Vec<TtDecomp>,
    /// Per-layer relative reconstruction errors, in layer order.
    pub rel_errs: Vec<f32>,
    pub max_rel_err: f32,
    /// The compacted op stream; replaying it is op-for-op identical
    /// to the serial single-sink trace.
    pub program: OpProgram,
}

/// Recording path for replay-many costing: compress the batch with
/// each layer run-length-encoding its ops into a private
/// [`RecordingSink`], then splice the segments in layer order into one
/// [`OpProgram`]. Memory is O(#runs) — far below a `VecSink` trace —
/// and the program replays bit-identically at any thread count (same
/// determinism argument as [`compress_layers_costed`]).
pub fn compress_layers_recorded(
    jobs: &[(&ConvLayer, &Tensor)],
    spec: &TtSpec,
    threads: usize,
    cancel: &CancelToken,
) -> Option<RecordedBatch> {
    let results =
        compress_layers_sinked(jobs, spec, threads, cancel, RecordingSink::default)?;
    let mut program = OpProgram::default();
    let mut decomps = Vec::with_capacity(results.len());
    let mut rel_errs = Vec::with_capacity(results.len());
    let mut max_rel = 0.0f32;
    for r in results {
        program.push_layer(r.sink);
        if r.rel_err > max_rel {
            max_rel = r.rel_err;
        }
        rel_errs.push(r.rel_err);
        decomps.push(r.decomp);
    }
    Some(RecordedBatch { decomps, rel_errs, max_rel_err: max_rel, program })
}

/// Replay the per-layer traces into `sink` in layer order — the
/// deterministic merge of the recording path. Because Algorithm 1 is
/// deterministic per layer, the merged stream equals the serial
/// single-sink trace op for op (asserted by `tests/golden_trace.rs`).
pub fn replay_traces<S: TraceSink>(results: &[LayerResult], sink: &mut S) {
    for r in results {
        r.sink.replay(sink);
    }
}

/// Parallel drop-in for `sim::workload::compress_model`: same
/// [`CompressionOutcome`], same merged trace into `sink`, computed on
/// `threads` workers. Records per-layer traces (O(trace) memory) —
/// use [`compress_layers_costed`] / [`crate::job::CompressionJob`]
/// when only costs are needed.
pub fn compress_model_parallel<S: TraceSink>(
    layers: &[(ConvLayer, Tensor)],
    eps: f32,
    threads: usize,
    sink: &mut S,
) -> CompressionOutcome {
    let results = compress_layers(layers, eps, threads);
    replay_traces(&results, sink);
    let max_rel = results.iter().map(|r| r.rel_err).fold(0.0f32, f32::max);
    let decomps = results.into_iter().map(|r| r.decomp).collect();
    aggregate_outcome(layers, decomps, max_rel)
}

/// Parallel drop-in for `sim::workload::compress_resnet32`: compress
/// the synthetic-trained model on `threads` workers, costing the op
/// stream **online** under each SoC configuration — no trace is
/// materialized at any thread count.
pub fn compress_resnet32_parallel(
    seed: u64,
    eps: f32,
    threads: usize,
    configs: &[SocConfig],
) -> (CompressionOutcome, Vec<SimReport>) {
    let layers = synthetic_model(seed, 3.55, 0.035);
    let jobs: Vec<(&ConvLayer, &Tensor)> = layers.iter().map(|(l, w)| (l, w)).collect();
    let batch = compress_layers_costed(
        &jobs,
        &TtSpec::eps(eps),
        threads,
        &CancelToken::default(),
        configs,
    )
    .expect("uncancellable batch cannot be cancelled");
    let reports = batch.reports();
    let outcome = aggregate_outcome(&layers, batch.decomps, batch.max_rel_err);
    (outcome, reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::workload::compress_model;
    use crate::trace::HwOp;

    fn small_model() -> Vec<(ConvLayer, Tensor)> {
        let mut layers = synthetic_model(11, 3.55, 0.035);
        layers.truncate(6);
        layers
    }

    #[test]
    fn parallel_outcome_matches_serial_exactly() {
        let layers = small_model();
        let mut serial_trace = VecSink::default();
        let serial = compress_model(&layers, 0.12, &mut serial_trace);
        for threads in [1, 2, 4] {
            let mut par_trace = VecSink::default();
            let par = compress_model_parallel(&layers, 0.12, threads, &mut par_trace);
            assert_eq!(par.final_params, serial.final_params, "threads={threads}");
            assert_eq!(par.conv_tt_params, serial.conv_tt_params);
            assert_eq!(par.max_rel_err, serial.max_rel_err);
            // merged trace is op-for-op the serial trace
            assert_eq!(par_trace.ops.len(), serial_trace.ops.len());
            assert_eq!(par_trace.ops, serial_trace.ops);
            // and the decompositions are bit-identical
            for (a, b) in par.decomps.iter().zip(&serial.decomps) {
                assert_eq!(a.ranks, b.ranks);
                for (ca, cb) in a.cores.iter().zip(&b.cores) {
                    assert_eq!(ca.data, cb.data);
                }
            }
        }
    }

    #[test]
    fn results_come_back_in_layer_order() {
        let layers = small_model();
        let results = compress_layers(&layers, 0.2, 3);
        assert_eq!(results.len(), layers.len());
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.index, i);
            assert_eq!(r.decomp.dims, layers[i].0.tt_dims().to_vec());
        }
    }

    #[test]
    fn batch_wire_accounting() {
        let layers = small_model();
        let results = compress_layers(&layers, 0.12, 2);
        let batch = TtBatch::from_results(&results);
        assert_eq!(batch.len(), layers.len());
        assert!(!batch.is_empty());
        let per_layer: usize = results.iter().map(|r| r.decomp.wire_bytes()).sum();
        assert_eq!(batch.wire_bytes(), 8 + per_layer);
        assert_eq!(
            batch.param_count(),
            results.iter().map(|r| r.decomp.param_count()).sum::<usize>()
        );
    }

    #[test]
    fn simulated_cost_is_thread_count_invariant() {
        let (out1, rep1) =
            compress_resnet32_parallel(42, 0.12, 1, &[SocConfig::tt_edge()]);
        let (out4, rep4) =
            compress_resnet32_parallel(42, 0.12, 4, &[SocConfig::tt_edge()]);
        assert_eq!(out1.final_params, out4.final_params);
        assert_eq!(rep1[0].total_ms, rep4[0].total_ms);
        assert_eq!(rep1[0].total_mj, rep4[0].total_mj);
    }

    #[test]
    fn streaming_costed_batch_matches_recorded_replay() {
        // The acceptance invariant at the pipeline level: the O(1)-
        // memory streaming merge costs bit-identically to recording
        // every op and replaying.
        let layers = small_model();
        let jobs: Vec<(&ConvLayer, &Tensor)> = layers.iter().map(|(l, w)| (l, w)).collect();
        let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
        for threads in [1, 3] {
            let batch = compress_layers_costed(
                &jobs,
                &TtSpec::eps(0.12),
                threads,
                &CancelToken::default(),
                &configs,
            )
            .unwrap();
            let recorded = compress_layers_ref(&jobs, 0.12, threads);
            let mut replayed = CostSink::new(&configs);
            replay_traces(&recorded, &mut replayed);
            for (a, b) in batch.cost.timelines().iter().zip(replayed.timelines()) {
                assert_eq!(a.cycles.total(), b.cycles.total(), "threads={threads}");
                for p in crate::trace::Phase::ALL {
                    assert_eq!(a.cycles.get(p), b.cycles.get(p), "{p:?}");
                }
            }
            let ra = batch.reports();
            let rb = replayed.reports();
            for (a, b) in ra.iter().zip(&rb) {
                assert_eq!(a.total_ms, b.total_ms);
                assert_eq!(a.total_mj, b.total_mj);
            }
            // identical numerics on both paths
            for (a, b) in batch.decomps.iter().zip(&recorded) {
                for (ca, cb) in a.cores.iter().zip(&b.decomp.cores) {
                    assert_eq!(ca.data, cb.data);
                }
            }
            assert_eq!(batch.rel_errs.len(), layers.len());
        }
    }

    #[test]
    fn precancelled_batch_compresses_nothing() {
        let layers = small_model();
        let jobs: Vec<(&ConvLayer, &Tensor)> = layers.iter().map(|(l, w)| (l, w)).collect();
        for threads in [1, 3] {
            let got = compress_layers_cancellable(&jobs, 0.12, threads, &CancelToken::cancelled());
            assert!(got.is_none(), "threads={threads}");
        }
    }

    #[test]
    fn untripped_token_is_identical_to_plain_path() {
        let layers = small_model();
        let jobs: Vec<(&ConvLayer, &Tensor)> = layers.iter().map(|(l, w)| (l, w)).collect();
        let plain = compress_layers_ref(&jobs, 0.12, 2);
        let tok = CancelToken::default();
        let cancellable = compress_layers_cancellable(&jobs, 0.12, 2, &tok).unwrap();
        assert!(!tok.is_cancelled());
        assert_eq!(plain.len(), cancellable.len());
        for (a, b) in plain.iter().zip(&cancellable) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.sink.ops, b.sink.ops);
            for (ca, cb) in a.decomp.cores.iter().zip(&b.decomp.cores) {
                assert_eq!(ca.data, cb.data);
            }
        }
    }

    #[test]
    fn mid_flight_cancellation_discards_the_batch() {
        // Serial path: cancel after the first layer's check — the
        // batch must come back None, not partially filled.
        let layers = small_model();
        let jobs: Vec<(&ConvLayer, &Tensor)> = layers.iter().map(|(l, w)| (l, w)).collect();
        let tok = CancelToken::default();
        tok.cancel();
        assert!(compress_layers_cancellable(&jobs, 0.12, 1, &tok).is_none());
    }

    #[test]
    fn worker_count_clamps() {
        assert_eq!(worker_count(0, 5), 1);
        assert_eq!(worker_count(8, 3), 3);
        assert_eq!(worker_count(2, 0), 1);
    }

    #[test]
    fn recorded_program_replays_the_serial_trace_at_any_width() {
        let layers = small_model();
        let jobs: Vec<(&ConvLayer, &Tensor)> = layers.iter().map(|(l, w)| (l, w)).collect();
        let mut serial = VecSink::default();
        let _ = compress_model(&layers, 0.12, &mut serial);
        for threads in [1, 3] {
            let batch = compress_layers_recorded(
                &jobs,
                &TtSpec::eps(0.12),
                threads,
                &CancelToken::default(),
            )
            .unwrap();
            assert_eq!(batch.program.layer_count(), layers.len());
            assert_eq!(batch.program.op_count() as usize, serial.ops.len());
            let mut replayed = VecSink::default();
            batch.program.replay(&mut replayed);
            assert_eq!(replayed.ops, serial.ops, "threads={threads}");
            assert_eq!(batch.rel_errs.len(), layers.len());
        }
    }

    #[test]
    fn trace_replay_preserves_op_multiset() {
        let layers = small_model();
        let results = compress_layers(&layers, 0.12, 2);
        let mut merged = VecSink::default();
        replay_traces(&results, &mut merged);
        let per_layer_total: usize = results.iter().map(|r| r.sink.ops.len()).sum();
        assert_eq!(merged.ops.len(), per_layer_total);
        let gemms = merged.count(|o| matches!(o, HwOp::Gemm { .. }));
        assert!(gemms > 0);
    }
}
