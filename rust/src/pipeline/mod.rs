//! Batched, parallel multi-layer TTD compression.
//!
//! Per-layer TT compression is embarrassingly parallel (each conv
//! kernel is an independent Algorithm-1 run), so the pipeline fans the
//! layer queue out to `std::thread::scope` workers that *steal* work
//! from a shared atomic cursor: a worker that finishes a small
//! stage-0 layer immediately grabs the next job instead of waiting on
//! the big stage-2 kernels. Traces are captured per layer in private
//! [`VecSink`]s and merged back **deterministically in layer order**,
//! so the merged stream is op-for-op identical to the serial
//! `compress_model` trace — the SoC simulator costs the same cycles
//! and energy no matter how many host threads ran the numerics.
//!
//! This is the scaling substrate for everything downstream: the CLI
//! (`ttedge compress/simulate --parallel N`), the federated
//! coordinator (nodes compress their layer batch through this module
//! and ship one [`TtBatch`]), and `benches/hotpath.rs` (serial vs
//! parallel wall-clock).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;

use crate::model::resnet32::ConvLayer;
use crate::sim::config::SocConfig;
use crate::sim::report::SimReport;
use crate::sim::timeline::HwTimeline;
use crate::sim::workload::{aggregate_outcome, synthetic_model, CompressionOutcome};
use crate::trace::{TraceSink, VecSink};
use crate::ttd::ttd::TtDecomp;
use crate::ttd::{decompose, relative_error, Tensor};

/// One compressed layer: the decomposition plus the hardware-op trace
/// its Algorithm-1 run emitted (replayed later in deterministic order).
#[derive(Clone, Debug)]
pub struct LayerResult {
    /// Position in the input layer list (merge key).
    pub index: usize,
    pub decomp: TtDecomp,
    pub trace: VecSink,
    pub rel_err: f32,
}

/// A batch of TT decompositions shipped as one unit (the Fig.-1 wire
/// payload of a federated node: every layer's cores + a batch header).
#[derive(Clone, Debug, Default)]
pub struct TtBatch {
    pub decomps: Vec<TtDecomp>,
}

impl TtBatch {
    pub fn from_results(results: &[LayerResult]) -> Self {
        TtBatch { decomps: results.iter().map(|r| r.decomp.clone()).collect() }
    }

    /// Take ownership of already-extracted decompositions (no clone).
    pub fn from_decomps(decomps: Vec<TtDecomp>) -> Self {
        TtBatch { decomps }
    }

    pub fn len(&self) -> usize {
        self.decomps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.decomps.is_empty()
    }

    /// Total TT parameters across the batch.
    pub fn param_count(&self) -> usize {
        self.decomps.iter().map(|d| d.param_count()).sum()
    }

    /// Bytes on the wire: every decomposition's payload plus an
    /// 8-byte batch header (count + flags).
    pub fn wire_bytes(&self) -> usize {
        8 + self.decomps.iter().map(|d| d.wire_bytes()).sum::<usize>()
    }
}

/// Clamp a requested worker count to something sensible for `jobs`.
fn worker_count(requested: usize, jobs: usize) -> usize {
    requested.max(1).min(jobs.max(1))
}

/// Cooperative cancellation for a layer batch. The fault-tolerant
/// coordinator hands every node's compression a token; a node the
/// fault plan crashes gets a pre-cancelled one, and a batch whose
/// token trips mid-flight is discarded whole — no partially-compressed
/// batch can ever escape into aggregation.
#[derive(Debug, Default)]
pub struct CancelToken {
    flag: AtomicBool,
}

impl CancelToken {
    pub fn cancelled() -> Self {
        let t = CancelToken::default();
        t.cancel();
        t
    }

    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }
}

/// Compress every `(layer, tensor)` pair with `threads` workers
/// stealing from a shared queue. Results come back sorted by layer
/// index; each carries its own trace. `threads == 1` runs inline
/// (no thread spawn) and is byte-identical to the serial path.
pub fn compress_layers(
    layers: &[(ConvLayer, Tensor)],
    eps: f32,
    threads: usize,
) -> Vec<LayerResult> {
    let jobs: Vec<(&ConvLayer, &Tensor)> = layers.iter().map(|(l, w)| (l, w)).collect();
    compress_layers_ref(&jobs, eps, threads)
}

/// Borrowed-pair variant of [`compress_layers`] — callers that hold
/// layers and tensors in separate collections (the coordinator's
/// per-node locals) fan out without cloning any weight data.
pub fn compress_layers_ref(
    jobs: &[(&ConvLayer, &Tensor)],
    eps: f32,
    threads: usize,
) -> Vec<LayerResult> {
    compress_layers_cancellable(jobs, eps, threads, &CancelToken::default())
        .expect("uncancellable batch cannot be cancelled")
}

/// [`compress_layers_ref`] with cooperative cancellation: workers
/// check `cancel` before claiming each layer, and a cancelled batch
/// returns `None` — never a partial result. A never-tripped token is
/// byte-identical to the plain path (the check is one atomic load per
/// layer).
pub fn compress_layers_cancellable(
    jobs: &[(&ConvLayer, &Tensor)],
    eps: f32,
    threads: usize,
    cancel: &CancelToken,
) -> Option<Vec<LayerResult>> {
    if cancel.is_cancelled() {
        return None;
    }
    let threads = worker_count(threads, jobs.len());
    let compress_one = |index: usize| -> LayerResult {
        let (layer, w) = jobs[index];
        let dims = layer.tt_dims();
        // reshape only when the caller's tensor is not already in the
        // TT layout (reshape clones the data; decompose only reads it)
        let reshaped;
        let t: &Tensor = if w.shape == dims {
            w
        } else {
            reshaped = w.reshape(&dims);
            &reshaped
        };
        let mut trace = VecSink::default();
        let decomp = decompose(t, eps, None, &mut trace);
        let rel_err = relative_error(t, &decomp);
        LayerResult { index, decomp, trace, rel_err }
    };

    if threads <= 1 {
        let mut results = Vec::with_capacity(jobs.len());
        for i in 0..jobs.len() {
            if cancel.is_cancelled() {
                return None;
            }
            results.push(compress_one(i));
        }
        return Some(results);
    }

    let cursor = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<LayerResult>();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let cursor = &cursor;
            let compress_one = &compress_one;
            scope.spawn(move || loop {
                // Work stealing: the shared cursor is the queue head;
                // whichever worker is free claims the next layer.
                if cancel.is_cancelled() {
                    break;
                }
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                if tx.send(compress_one(i)).is_err() {
                    break;
                }
            });
        }
    });
    drop(tx);
    if cancel.is_cancelled() {
        return None;
    }
    let mut results: Vec<LayerResult> = rx.into_iter().collect();
    results.sort_by_key(|r| r.index);
    Some(results)
}

/// Replay the per-layer traces into `sink` in layer order — the
/// deterministic merge. Because Algorithm 1 is deterministic per
/// layer, the merged stream equals the serial single-sink trace
/// op for op (asserted by `tests/golden_trace.rs`).
pub fn replay_traces<S: TraceSink>(results: &[LayerResult], sink: &mut S) {
    for r in results {
        for op in &r.trace.ops {
            sink.op(*op);
        }
    }
}

/// Parallel drop-in for `sim::workload::compress_model`: same
/// [`CompressionOutcome`], same merged trace into `sink`, computed on
/// `threads` workers.
pub fn compress_model_parallel<S: TraceSink>(
    layers: &[(ConvLayer, Tensor)],
    eps: f32,
    threads: usize,
    sink: &mut S,
) -> CompressionOutcome {
    let results = compress_layers(layers, eps, threads);
    replay_traces(&results, sink);
    let max_rel = results.iter().map(|r| r.rel_err).fold(0.0f32, f32::max);
    let decomps = results.into_iter().map(|r| r.decomp).collect();
    aggregate_outcome(layers, decomps, max_rel)
}

/// Parallel drop-in for `sim::workload::compress_resnet32`: compress
/// the synthetic-trained model on `threads` workers, then replay the
/// merged trace under each SoC configuration.
pub fn compress_resnet32_parallel(
    seed: u64,
    eps: f32,
    threads: usize,
    configs: &[SocConfig],
) -> (CompressionOutcome, Vec<SimReport>) {
    let layers = synthetic_model(seed, 3.55, 0.035);
    let mut trace = VecSink::default();
    let outcome = compress_model_parallel(&layers, eps, threads, &mut trace);
    let reports = configs
        .iter()
        .map(|cfg| {
            let mut tl = HwTimeline::new(cfg.clone());
            for op in &trace.ops {
                tl.op(*op);
            }
            SimReport::from_timeline(&tl)
        })
        .collect();
    (outcome, reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::workload::compress_model;
    use crate::trace::HwOp;

    fn small_model() -> Vec<(ConvLayer, Tensor)> {
        let mut layers = synthetic_model(11, 3.55, 0.035);
        layers.truncate(6);
        layers
    }

    #[test]
    fn parallel_outcome_matches_serial_exactly() {
        let layers = small_model();
        let mut serial_trace = VecSink::default();
        let serial = compress_model(&layers, 0.12, &mut serial_trace);
        for threads in [1, 2, 4] {
            let mut par_trace = VecSink::default();
            let par = compress_model_parallel(&layers, 0.12, threads, &mut par_trace);
            assert_eq!(par.final_params, serial.final_params, "threads={threads}");
            assert_eq!(par.conv_tt_params, serial.conv_tt_params);
            assert_eq!(par.max_rel_err, serial.max_rel_err);
            // merged trace is op-for-op the serial trace
            assert_eq!(par_trace.ops.len(), serial_trace.ops.len());
            assert_eq!(par_trace.ops, serial_trace.ops);
            // and the decompositions are bit-identical
            for (a, b) in par.decomps.iter().zip(&serial.decomps) {
                assert_eq!(a.ranks, b.ranks);
                for (ca, cb) in a.cores.iter().zip(&b.cores) {
                    assert_eq!(ca.data, cb.data);
                }
            }
        }
    }

    #[test]
    fn results_come_back_in_layer_order() {
        let layers = small_model();
        let results = compress_layers(&layers, 0.2, 3);
        assert_eq!(results.len(), layers.len());
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.index, i);
            assert_eq!(r.decomp.dims, layers[i].0.tt_dims().to_vec());
        }
    }

    #[test]
    fn batch_wire_accounting() {
        let layers = small_model();
        let results = compress_layers(&layers, 0.12, 2);
        let batch = TtBatch::from_results(&results);
        assert_eq!(batch.len(), layers.len());
        assert!(!batch.is_empty());
        let per_layer: usize = results.iter().map(|r| r.decomp.wire_bytes()).sum();
        assert_eq!(batch.wire_bytes(), 8 + per_layer);
        assert_eq!(
            batch.param_count(),
            results.iter().map(|r| r.decomp.param_count()).sum::<usize>()
        );
    }

    #[test]
    fn simulated_cost_is_thread_count_invariant() {
        let (out1, rep1) =
            compress_resnet32_parallel(42, 0.12, 1, &[SocConfig::tt_edge()]);
        let (out4, rep4) =
            compress_resnet32_parallel(42, 0.12, 4, &[SocConfig::tt_edge()]);
        assert_eq!(out1.final_params, out4.final_params);
        assert_eq!(rep1[0].total_ms, rep4[0].total_ms);
        assert_eq!(rep1[0].total_mj, rep4[0].total_mj);
    }

    #[test]
    fn precancelled_batch_compresses_nothing() {
        let layers = small_model();
        let jobs: Vec<(&ConvLayer, &Tensor)> = layers.iter().map(|(l, w)| (l, w)).collect();
        for threads in [1, 3] {
            let got = compress_layers_cancellable(&jobs, 0.12, threads, &CancelToken::cancelled());
            assert!(got.is_none(), "threads={threads}");
        }
    }

    #[test]
    fn untripped_token_is_identical_to_plain_path() {
        let layers = small_model();
        let jobs: Vec<(&ConvLayer, &Tensor)> = layers.iter().map(|(l, w)| (l, w)).collect();
        let plain = compress_layers_ref(&jobs, 0.12, 2);
        let tok = CancelToken::default();
        let cancellable = compress_layers_cancellable(&jobs, 0.12, 2, &tok).unwrap();
        assert!(!tok.is_cancelled());
        assert_eq!(plain.len(), cancellable.len());
        for (a, b) in plain.iter().zip(&cancellable) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.trace.ops, b.trace.ops);
            for (ca, cb) in a.decomp.cores.iter().zip(&b.decomp.cores) {
                assert_eq!(ca.data, cb.data);
            }
        }
    }

    #[test]
    fn mid_flight_cancellation_discards_the_batch() {
        // Serial path: cancel after the first layer's check — the
        // batch must come back None, not partially filled.
        let layers = small_model();
        let jobs: Vec<(&ConvLayer, &Tensor)> = layers.iter().map(|(l, w)| (l, w)).collect();
        let tok = CancelToken::default();
        tok.cancel();
        assert!(compress_layers_cancellable(&jobs, 0.12, 1, &tok).is_none());
    }

    #[test]
    fn worker_count_clamps() {
        assert_eq!(worker_count(0, 5), 1);
        assert_eq!(worker_count(8, 3), 3);
        assert_eq!(worker_count(2, 0), 1);
    }

    #[test]
    fn trace_replay_preserves_op_multiset() {
        let layers = small_model();
        let results = compress_layers(&layers, 0.12, 2);
        let mut merged = VecSink::default();
        replay_traces(&results, &mut merged);
        let per_layer_total: usize = results.iter().map(|r| r.trace.ops.len()).sum();
        assert_eq!(merged.ops.len(), per_layer_total);
        let gemms = merged.count(|o| matches!(o, HwOp::Gemm { .. }));
        assert!(gemms > 0);
    }
}
