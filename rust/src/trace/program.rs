//! Record-once / replay-many op-stream programs.
//!
//! TT-Edge's central premise is that the TTD hardware-op stream is a
//! function of the *workload* alone — [`crate::sim`] configs only
//! change how each op is costed. An [`OpProgram`] exploits that: a
//! [`RecordingSink`] captures the stream as the numerics run (stacked
//! like any other sink — `Tee::new(&mut cost, &mut rec)` works), and
//! the resulting program replays against any number of `SocConfig`s
//! without touching the numerics again.
//!
//! The encoding is a run-length compaction per layer: consecutive
//! identical [`HwOp`]s collapse into one [`OpRun`] with a count
//! (Givens sweeps over square stages and repeated phase markers
//! collapse well; heterogeneous HBD runs stay near 1:1). Replay emits
//! the ops **in the original order** — [`OpProgram::replay`] is
//! op-for-op identical to the recorded stream, so phase attribution
//! and the order-sensitive consumers downstream see exactly the live
//! sequence. `crate::sim::CostSink::fold_program` additionally costs a
//! run in O(1) (cycles x count is bit-identical to count u64 adds).

use crate::trace::{HwOp, Phase, TraceSink};

/// One maximal run of identical ops in the recorded stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpRun {
    pub op: HwOp,
    pub count: u64,
}

/// A [`TraceSink`] that run-length-encodes the op stream as it is
/// emitted. O(#runs) memory; stack it via `Tee` or hand it to the
/// pipeline as a per-layer sink factory.
#[derive(Clone, Debug, Default)]
pub struct RecordingSink {
    runs: Vec<OpRun>,
}

impl TraceSink for RecordingSink {
    #[inline]
    fn op(&mut self, op: HwOp) {
        if let Some(last) = self.runs.last_mut() {
            if last.op == op {
                last.count += 1;
                return;
            }
        }
        self.runs.push(OpRun { op, count: 1 });
    }
}

impl RecordingSink {
    /// Number of RLE runs recorded so far.
    pub fn run_count(&self) -> usize {
        self.runs.len()
    }

    /// Number of ops recorded so far (sum of run counts).
    pub fn op_count(&self) -> u64 {
        self.runs.iter().map(|r| r.count).sum()
    }

    /// RLE footprint of the recorded stream so far (what a resident
    /// program cache pays to keep this recording).
    pub fn encoded_bytes(&self) -> usize {
        self.runs.len() * std::mem::size_of::<OpRun>()
    }

    /// Replay the recorded stream into another sink, in order.
    pub fn replay<S: TraceSink>(&self, sink: &mut S) {
        for run in &self.runs {
            for _ in 0..run.count {
                sink.op(run.op);
            }
        }
    }
}

/// A canonical, replayable compaction of a whole job's op stream: one
/// RLE segment per layer, in serial layer order. Recorded once by
/// [`crate::job::CompressionJob::program`], replayed arbitrarily many
/// times by `CompressionJob::replay` / `CostSink::fold_program`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OpProgram {
    layers: Vec<LayerProgram>,
}

/// One layer's RLE segment of an [`OpProgram`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerProgram {
    runs: Vec<OpRun>,
}

impl LayerProgram {
    pub fn runs(&self) -> &[OpRun] {
        &self.runs
    }

    /// Ops encoded in this segment (sum of run counts).
    pub fn op_count(&self) -> u64 {
        self.runs.iter().map(|r| r.count).sum()
    }

    /// True when the segment establishes its own phase before any
    /// costed op — its first run is a `SetPhase` marker (or the
    /// segment is empty). Every Algorithm-1 layer stream is:
    /// `decompose` opens with `SetPhase(SortTrunc)`. Self-phased
    /// segments cost identically whether folded mid-stream or into a
    /// fresh timeline, which is the precondition
    /// `crate::sim::CostSink::fold_program_parallel` checks before
    /// farming segments out to workers.
    pub fn is_self_phased(&self) -> bool {
        match self.runs.first() {
            None => true,
            Some(run) => matches!(run.op, HwOp::SetPhase(_)),
        }
    }
}

impl OpProgram {
    /// Append one layer's recorded stream as the next segment.
    pub fn push_layer(&mut self, sink: RecordingSink) {
        self.layers.push(LayerProgram { runs: sink.runs });
    }

    /// Per-layer segments, in serial layer order.
    pub fn layers(&self) -> &[LayerProgram] {
        &self.layers
    }

    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }

    /// Total RLE runs across all layers.
    pub fn run_count(&self) -> usize {
        self.layers.iter().map(|l| l.runs.len()).sum()
    }

    /// Total ops encoded (including `SetPhase` markers) — equals the
    /// recorded stream's length.
    pub fn op_count(&self) -> u64 {
        self.layers.iter().flat_map(|l| &l.runs).map(|r| r.count).sum()
    }

    /// All runs in stream order (layer by layer).
    pub fn runs(&self) -> impl Iterator<Item = &OpRun> + '_ {
        self.layers.iter().flat_map(|l| l.runs.iter())
    }

    /// RLE footprint of the whole program — the residency cost a
    /// keyed program cache accounts for this entry.
    pub fn encoded_bytes(&self) -> usize {
        self.run_count() * std::mem::size_of::<OpRun>()
    }

    /// Ops attributed to one Table-III phase (tracking `SetPhase`
    /// markers from the simulator's `ReshapeEtc` reset state; the
    /// markers themselves are not counted).
    pub fn ops_in_phase(&self, phase: Phase) -> u64 {
        let mut current = Phase::ReshapeEtc;
        let mut n = 0u64;
        for run in self.runs() {
            if let HwOp::SetPhase(p) = run.op {
                current = p;
            } else if current == phase {
                n += run.count;
            }
        }
        n
    }

    /// Replay the whole program into a sink, op for op, in the exact
    /// recorded order (layer segments in layer order).
    pub fn replay<S: TraceSink>(&self, sink: &mut S) {
        for layer in &self.layers {
            for run in &layer.runs {
                for _ in 0..run.count {
                    sink.op(run.op);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::VecSink;

    fn sample_stream() -> Vec<HwOp> {
        vec![
            HwOp::SetPhase(Phase::Hbd),
            HwOp::HouseGen { len: 8 },
            HwOp::Gemm { m: 4, n: 4, k: 4 },
            HwOp::SetPhase(Phase::QrDiag),
            HwOp::GivensRot { len: 12 },
            HwOp::GivensRot { len: 12 },
            HwOp::GivensRot { len: 12 },
            HwOp::GivensRot { len: 9 },
            HwOp::SetPhase(Phase::Hbd),
            HwOp::VecDiv { len: 8 },
        ]
    }

    #[test]
    fn recording_collapses_identical_neighbours_only() {
        let mut rec = RecordingSink::default();
        for op in sample_stream() {
            rec.op(op);
        }
        assert_eq!(rec.op_count() as usize, sample_stream().len());
        // the three identical GivensRot ops collapse into one run
        assert_eq!(rec.run_count(), sample_stream().len() - 2);
        let mut out = VecSink::default();
        rec.replay(&mut out);
        assert_eq!(out.ops, sample_stream());
    }

    #[test]
    fn program_replays_layers_in_order() {
        let mut program = OpProgram::default();
        for _ in 0..2 {
            let mut rec = RecordingSink::default();
            for op in sample_stream() {
                rec.op(op);
            }
            program.push_layer(rec);
        }
        assert_eq!(program.layer_count(), 2);
        assert_eq!(program.op_count() as usize, 2 * sample_stream().len());
        assert_eq!(program.run_count(), 2 * (sample_stream().len() - 2));
        let mut out = VecSink::default();
        program.replay(&mut out);
        let mut want = sample_stream();
        want.extend(sample_stream());
        assert_eq!(out.ops, want);
    }

    #[test]
    fn phase_attribution_matches_the_marker_stream() {
        let mut program = OpProgram::default();
        let mut rec = RecordingSink::default();
        for op in sample_stream() {
            rec.op(op);
        }
        program.push_layer(rec);
        assert_eq!(program.ops_in_phase(Phase::Hbd), 3);
        assert_eq!(program.ops_in_phase(Phase::QrDiag), 4);
        assert_eq!(program.ops_in_phase(Phase::SortTrunc), 0);
    }

    #[test]
    fn encoded_bytes_tracks_run_count() {
        let mut rec = RecordingSink::default();
        for op in sample_stream() {
            rec.op(op);
        }
        assert_eq!(rec.encoded_bytes(), rec.run_count() * std::mem::size_of::<OpRun>());
        let mut program = OpProgram::default();
        program.push_layer(rec);
        assert_eq!(
            program.encoded_bytes(),
            program.run_count() * std::mem::size_of::<OpRun>()
        );
        assert_eq!(program.layers()[0].op_count(), sample_stream().len() as u64);
        assert_eq!(OpProgram::default().encoded_bytes(), 0);
    }

    #[test]
    fn self_phased_detection_reads_the_first_run() {
        let mut rec = RecordingSink::default();
        for op in sample_stream() {
            rec.op(op); // opens with SetPhase(Hbd)
        }
        let mut program = OpProgram::default();
        program.push_layer(rec);
        assert!(program.layers()[0].is_self_phased());

        let mut bare = RecordingSink::default();
        bare.op(HwOp::HouseGen { len: 8 }); // inherits ambient phase
        let mut program = OpProgram::default();
        program.push_layer(bare);
        assert!(!program.layers()[0].is_self_phased());

        // empty segments cost nothing anywhere — trivially self-phased
        let mut program = OpProgram::default();
        program.push_layer(RecordingSink::default());
        assert!(program.layers()[0].is_self_phased());
    }

    #[test]
    fn empty_program_replays_nothing() {
        let program = OpProgram::default();
        let mut out = VecSink::default();
        program.replay(&mut out);
        assert!(out.ops.is_empty());
        assert_eq!(program.op_count(), 0);
        assert_eq!(program.ops_in_phase(Phase::Hbd), 0);
    }
}
