//! `artifacts/manifest.json` — the AOT contract between `python/compile/aot.py`
//! and the PJRT runtime: one entry per exported HLO module with the
//! flattened argument order, shapes and dtypes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{parse, Json};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub file: String,
    pub note: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, Entry>,
}

fn parse_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("specs not an array"))?
        .iter()
        .map(|s| {
            let shape = s
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            let dtype = Dtype::from_str(
                s.get("dtype").and_then(Json::as_str).unwrap_or("float32"),
            )?;
            Ok(TensorSpec { shape, dtype })
        })
        .collect()
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`?)", path.display()))?;
        let j = parse(&text).map_err(|e| anyhow!("{e}"))?;
        let mut entries = BTreeMap::new();
        for e in j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest has no entries"))?
        {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("entry without name"))?
                .to_string();
            let entry = Entry {
                name: name.clone(),
                file: e
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry without file"))?
                    .to_string(),
                note: e.get("note").and_then(Json::as_str).unwrap_or("").to_string(),
                inputs: parse_specs(e.get("inputs").ok_or_else(|| anyhow!("no inputs"))?)?,
                outputs: parse_specs(e.get("outputs").ok_or_else(|| anyhow!("no outputs"))?)?,
            };
            entries.insert(name, entry);
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("no artifact entry '{name}'"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.entry(name)?.file))
    }
}

/// Default artifacts directory: `$TT_EDGE_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("TT_EDGE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_inline_manifest() {
        let dir = std::env::temp_dir().join("tt_edge_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"entries": [{"name": "gemm", "file": "gemm.hlo.txt", "note": "x",
                "inputs": [{"shape": [2,3], "dtype": "float32"}],
                "outputs": [{"shape": [], "dtype": "int32"}]}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let e = m.entry("gemm").unwrap();
        assert_eq!(e.inputs[0].shape, vec![2, 3]);
        assert_eq!(e.inputs[0].numel(), 6);
        assert_eq!(e.outputs[0].dtype, Dtype::I32);
        assert!(m.entry("nope").is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rejects_unknown_dtype() {
        assert!(Dtype::from_str("float64").is_err());
        assert_eq!(Dtype::from_str("int32").unwrap(), Dtype::I32);
    }
}
