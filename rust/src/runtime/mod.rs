//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`), compile
//! once per entry, execute from the L3 hot path. Python never runs
//! here — the interchange is HLO *text* (see `python/compile/aot.py`
//! and /opt/xla-example/README.md for why text, not serialized proto).
//!
//! The XLA/PJRT client lives behind the `pjrt` cargo feature: the
//! offline CI image has no `xla` crate, so the default build ships a
//! manifest-only stub [`Engine`] with the same API that fails with a
//! clear message on `compile`/`run`. Everything manifest-shaped
//! (shapes, dtypes, entry inventory — the cross-language contract
//! tests) works in both builds.

pub mod manifest;

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;

#[cfg(feature = "pjrt")]
use anyhow::{anyhow, Context};
use anyhow::{bail, Result};

pub use manifest::{default_dir, Dtype, Entry, Manifest, TensorSpec};

/// A host-side tensor value crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum Value {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Value {
    pub fn scalar_f32(v: f32) -> Self {
        Value::F32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_i32(v: i32) -> Self {
        Value::I32 { shape: vec![], data: vec![v] }
    }

    pub fn from_tensor(t: &crate::ttd::Tensor) -> Self {
        Value::F32 { shape: t.shape.clone(), data: t.data.clone() }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32 { shape, .. } | Value::I32 { shape, .. } => shape,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Value::F32 { data, .. } => Ok(data),
            _ => bail!("value is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32 { data, .. } => Ok(data),
            _ => bail!("value is not i32"),
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Value::F32 { .. } => Dtype::F32,
            Value::I32 { .. } => Dtype::I32,
        }
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32 { data, .. } => xla::Literal::vec1(data),
            Value::I32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    #[cfg(feature = "pjrt")]
    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Value> {
        match spec.dtype {
            Dtype::F32 => Ok(Value::F32 { shape: spec.shape.clone(), data: lit.to_vec::<f32>()? }),
            Dtype::I32 => Ok(Value::I32 { shape: spec.shape.clone(), data: lit.to_vec::<i32>()? }),
        }
    }
}

/// The artifact engine: one PJRT CPU client + lazily compiled
/// executables keyed by manifest entry name.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// Manifest-only stub engine for offline builds (no `pjrt` feature):
/// entry inventory and shape/dtype validation work, execution bails.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    pub manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Load the manifest (no PJRT client in this build).
    pub fn load(dir: &Path) -> Result<Self> {
        Ok(Engine { manifest: Manifest::load(dir)? })
    }

    /// Load from `$TT_EDGE_ARTIFACTS` / `./artifacts`.
    pub fn load_default() -> Result<Self> {
        Self::load(&default_dir())
    }

    pub fn platform(&self) -> String {
        "stub (rebuild with --features pjrt for PJRT execution)".to_string()
    }

    /// Validates the entry exists, then bails: no compiler in this build.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        let _ = self.manifest.entry(name)?;
        bail!("cannot compile '{name}': PJRT runtime disabled (enable the `pjrt` feature)")
    }

    /// Validates inputs against the manifest, then bails.
    pub fn run(&mut self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let entry = self.manifest.entry(name)?;
        if inputs.len() != entry.inputs.len() {
            bail!(
                "entry '{name}' expects {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            );
        }
        bail!("cannot run '{name}': PJRT runtime disabled (enable the `pjrt` feature)")
    }

    /// Names of all available entries.
    pub fn entry_names(&self) -> Vec<String> {
        self.manifest.entries.keys().cloned().collect()
    }
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Load the manifest and create the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, manifest, exes: HashMap::new() })
    }

    /// Load from `$TT_EDGE_ARTIFACTS` / `./artifacts`.
    pub fn load_default() -> Result<Self> {
        Self::load(&default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and cache the executable for `name`.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute entry `name` with `inputs` (validated against the
    /// manifest), returning the outputs in manifest order.
    pub fn run(&mut self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let entry = self.manifest.entry(name)?.clone();
        if inputs.len() != entry.inputs.len() {
            bail!(
                "entry '{name}' expects {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            );
        }
        for (i, (v, spec)) in inputs.iter().zip(&entry.inputs).enumerate() {
            if v.shape() != spec.shape.as_slice() || v.dtype() != spec.dtype {
                bail!(
                    "entry '{name}' input {i}: got {:?}/{:?}, want {:?}/{:?}",
                    v.shape(),
                    v.dtype(),
                    spec.shape,
                    spec.dtype
                );
            }
        }
        self.compile(name)?;
        let exe = self.exes.get(name).ok_or_else(|| anyhow!("compile failed"))?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != entry.outputs.len() {
            bail!(
                "entry '{name}' returned {} outputs, manifest says {}",
                parts.len(),
                entry.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&entry.outputs)
            .map(|(lit, spec)| Value::from_literal(lit, spec))
            .collect()
    }

    /// Names of all available entries.
    pub fn entry_names(&self) -> Vec<String> {
        self.manifest.entries.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "pjrt")]
    #[test]
    fn value_roundtrip_literal() {
        let v = Value::F32 { shape: vec![2, 2], data: vec![1.0, 2.0, 3.0, 4.0] };
        let lit = v.to_literal().unwrap();
        let spec = TensorSpec { shape: vec![2, 2], dtype: Dtype::F32 };
        let back = Value::from_literal(&lit, &spec).unwrap();
        assert_eq!(back.as_f32().unwrap(), v.as_f32().unwrap());
    }

    #[test]
    fn scalar_values() {
        let v = Value::scalar_i32(7);
        assert_eq!(v.numel(), 1);
        assert!(v.as_f32().is_err());
        assert_eq!(v.as_i32().unwrap(), &[7]);
    }
}
