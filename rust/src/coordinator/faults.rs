//! Seeded fault injection for federated rounds.
//!
//! A [`FaultPlan`] is a pure function from `(seed, round, node)` to
//! fault decisions — node dropout, straggler latency multipliers, and
//! the RNG stream the lossy transport draws from — so an entire chaos
//! run replays byte-for-byte from its seed. The plan's RNG streams are
//! completely separate from the coordinator's drift RNG: a benign plan
//! (no dropout, unit multiplier, lossless link) leaves every numeric
//! result of the round bit-identical to the fault-free path.

use crate::fault::stream_rng;
use crate::util::Rng;

/// Stream-separation constants: fault decisions and transport loss
/// draws must never alias the coordinator's `seed ^ round * 0x9E37`
/// drift streams — or the crate-wide chaos stream (`0x...0003`,
/// `crate::fault`), which generalizes this module's idiom.
const FAULT_STREAM: u64 = 0xFA_0175_0000_0001;
const TRANSPORT_STREAM: u64 = 0xFA_0175_0000_0002;

/// Per-round fault decisions for one node.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NodeFaults {
    /// The node crashes this round: its compression is cancelled and
    /// it never uploads.
    pub dropped: bool,
    /// Multiplier on the node's *wall-clock* compression completion
    /// time (`1.0` = nominal; `> 1.0` marks the node a straggler).
    /// Models co-resident work preempting the device: the upload
    /// starts `mult x` later, but the SoC cost of the compression
    /// itself (`SimReport` ms/mJ, the `mean_compress_*` report
    /// columns) is unchanged — a straggler is delayed, not burning
    /// extra compression energy.
    pub latency_mult: f64,
}

impl NodeFaults {
    pub fn nominal() -> Self {
        NodeFaults { dropped: false, latency_mult: 1.0 }
    }

    pub fn is_straggler(&self) -> bool {
        !self.dropped && self.latency_mult > 1.0
    }
}

/// Seeded chaos schedule threaded through `FederatedConfig`.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub seed: u64,
    /// Per-round probability that a node drops out entirely.
    pub dropout: f64,
    /// Latency multiplier applied to straggler nodes.
    pub straggler_mult: f64,
    /// Probability a node straggles in a given round (only consulted
    /// when `straggler_mult != 1.0`).
    pub straggler_frac: f64,
    /// Deterministic `(round, node)` dropouts, independent of the
    /// probabilistic draws — the golden-trace harness pins exactly one
    /// failure with these.
    pub forced_dropouts: Vec<(usize, usize)>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0xFA17,
            dropout: 0.0,
            straggler_mult: 1.0,
            straggler_frac: 0.25,
            forced_dropouts: Vec::new(),
        }
    }
}

impl FaultPlan {
    /// True when the plan cannot perturb a round — the scheduler's
    /// fault-free path must then reproduce the legacy reports exactly.
    pub fn is_benign(&self) -> bool {
        self.dropout <= 0.0
            && (self.straggler_mult == 1.0 || self.straggler_frac <= 0.0)
            && self.forced_dropouts.is_empty()
    }

    /// Decide every node's faults for `round`. Decisions are drawn
    /// from per-node forked streams, so they are stable under changes
    /// to the node count of *other* rounds and under reordering.
    pub fn for_round(&self, round: usize, nodes: usize) -> Vec<NodeFaults> {
        let base = stream_rng(self.seed, FAULT_STREAM, round as u64);
        (0..nodes)
            .map(|node| {
                let mut rng = base.fork(node as u64 + 1);
                // Both uniforms are drawn unconditionally so each
                // fault kind owns a fixed draw slot: toggling dropout
                // on/off at the same seed cannot reshuffle which nodes
                // straggle (and vice versa).
                let drop_draw = rng.uniform();
                let straggle_draw = rng.uniform();
                let forced = self.forced_dropouts.contains(&(round, node));
                let dropped = forced || (self.dropout > 0.0 && drop_draw < self.dropout);
                let latency_mult = if self.straggler_mult != 1.0
                    && self.straggler_frac > 0.0
                    && straggle_draw < self.straggler_frac
                {
                    self.straggler_mult
                } else {
                    1.0
                };
                NodeFaults { dropped, latency_mult }
            })
            .collect()
    }

    /// The RNG stream one node's transport attempts draw loss from in
    /// `round` (lossless links never consume it).
    pub fn transport_rng(&self, round: usize, node: usize) -> Rng {
        stream_rng(self.seed, TRANSPORT_STREAM, round as u64).fork(node as u64 + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_benign_and_nominal() {
        let plan = FaultPlan::default();
        assert!(plan.is_benign());
        for f in plan.for_round(0, 8) {
            assert_eq!(f, NodeFaults::nominal());
            assert!(!f.is_straggler());
        }
    }

    #[test]
    fn decisions_replay_from_the_seed() {
        let plan = FaultPlan {
            dropout: 0.3,
            straggler_mult: 4.0,
            straggler_frac: 0.5,
            ..FaultPlan::default()
        };
        assert!(!plan.is_benign());
        for round in 0..4 {
            assert_eq!(plan.for_round(round, 16), plan.for_round(round, 16));
        }
    }

    #[test]
    fn node_decisions_are_stable_under_fleet_growth() {
        let plan = FaultPlan { dropout: 0.5, ..FaultPlan::default() };
        let small = plan.for_round(2, 4);
        let big = plan.for_round(2, 12);
        assert_eq!(&big[..4], &small[..]);
    }

    #[test]
    fn forced_dropouts_hit_exactly_their_round_and_node() {
        let plan =
            FaultPlan { forced_dropouts: vec![(1, 2)], ..FaultPlan::default() };
        assert!(!plan.is_benign());
        let r0 = plan.for_round(0, 4);
        let r1 = plan.for_round(1, 4);
        assert!(r0.iter().all(|f| !f.dropped));
        assert!(r1[2].dropped);
        assert_eq!(r1.iter().filter(|f| f.dropped).count(), 1);
    }

    #[test]
    fn dropout_rate_roughly_matches_probability() {
        let plan = FaultPlan { dropout: 0.25, ..FaultPlan::default() };
        let mut dropped = 0usize;
        let mut total = 0usize;
        for round in 0..64 {
            for f in plan.for_round(round, 32) {
                total += 1;
                if f.dropped {
                    dropped += 1;
                }
            }
        }
        let rate = dropped as f64 / total as f64;
        assert!((0.15..0.35).contains(&rate), "rate {rate}");
    }

    #[test]
    fn fault_kinds_use_independent_draw_slots() {
        // Toggling dropout must not reshuffle straggler assignment at
        // the same seed (each fault kind owns a fixed draw slot).
        let base = FaultPlan { straggler_mult: 3.0, straggler_frac: 0.5, ..FaultPlan::default() };
        let with_dropout = FaultPlan { dropout: 0.4, ..base.clone() };
        for round in 0..4 {
            let a = base.for_round(round, 16);
            let b = with_dropout.for_round(round, 16);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.latency_mult, y.latency_mult);
            }
        }
    }

    #[test]
    fn stragglers_only_appear_when_mult_is_not_unity() {
        let none = FaultPlan { straggler_mult: 1.0, straggler_frac: 1.0, ..FaultPlan::default() };
        assert!(none.for_round(0, 8).iter().all(|f| f.latency_mult == 1.0));
        let all = FaultPlan { straggler_mult: 3.0, straggler_frac: 1.0, ..FaultPlan::default() };
        assert!(all.for_round(0, 8).iter().all(|f| f.latency_mult == 3.0));
    }
}
