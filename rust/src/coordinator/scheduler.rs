//! Event-driven round admission: the substrate the fault-tolerant
//! coordinator (and every later sharding/caching layer) schedules on.
//!
//! A [`RoundScheduler`] consumes *arrival events* — one per node
//! update, stamped with the simulated time the leader would receive it
//! (compression latency x straggler multiplier + transport time
//! including retries) — and closes the round deterministically:
//!
//! * every arrival at or before the deadline is admitted;
//! * past the deadline, arrivals are admitted **only** while the
//!   admitted count is below `min_quorum` (the leader keeps waiting
//!   for stragglers it cannot close without);
//! * everything later is marked late and excluded.
//!
//! Events are processed in `(arrival_ms, node)` order, so the outcome
//! is a pure function of the offered events — no wall-clock, no
//! threads, byte-for-byte replayable.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One node update's arrival at the leader, in simulated time.
#[derive(Clone, Copy, Debug)]
pub struct Arrival {
    pub node: usize,
    /// Leader-side receive time: compress x mult + transfer.
    pub arrival_ms: f64,
    /// Transfer component alone (including retry timeouts).
    pub transfer_ms: f64,
    /// Transport attempts consumed (1 = clean first try).
    pub attempts: u32,
}

/// Heap entry: min-order on `(arrival_ms, node)`. Node id breaks
/// exact-time ties (every node arriving "at the deadline" in the
/// fault-free case), keeping admission order total and deterministic.
struct Pending<T> {
    arrival: Arrival,
    payload: T,
}

impl<T> PartialEq for Pending<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp_key(other) == Ordering::Equal
    }
}
impl<T> Eq for Pending<T> {}
impl<T> PartialOrd for Pending<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Pending<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: reverse for earliest-first pops.
        self.cmp_key(other).reverse()
    }
}

impl<T> Pending<T> {
    fn cmp_key(&self, other: &Self) -> Ordering {
        self.arrival
            .arrival_ms
            .total_cmp(&other.arrival.arrival_ms)
            .then(self.arrival.node.cmp(&other.arrival.node))
    }
}

/// The closed round: who made it, who was late, and when the leader
/// stopped listening.
#[derive(Debug)]
pub struct ClosedRound<T> {
    /// Admitted updates in arrival order.
    pub admitted: Vec<(Arrival, T)>,
    /// Delivered but excluded (arrived past the deadline with quorum
    /// already satisfied).
    pub late: Vec<Arrival>,
    /// Simulated time the round closed: the last admitted arrival, or
    /// the deadline itself when the leader closed on an empty/partial
    /// fleet.
    pub close_ms: f64,
    pub deadline_ms: f64,
}

/// Deadline + quorum admission over a simulated-time event queue.
pub struct RoundScheduler<T> {
    deadline_ms: f64,
    min_quorum: usize,
    events: BinaryHeap<Pending<T>>,
}

impl<T> RoundScheduler<T> {
    /// `min_quorum` is the number of updates the leader keeps waiting
    /// for past the deadline; pass the scheduled node count for "all".
    /// When deliveries run out below the quorum (too many dropouts),
    /// the round still closes with what arrived — the caller reads the
    /// admitted count (`RoundReport::quorum_met` downstream) to tell a
    /// satisfied round from a degraded one.
    pub fn new(deadline_ms: f64, min_quorum: usize) -> Self {
        RoundScheduler { deadline_ms, min_quorum, events: BinaryHeap::new() }
    }

    /// Offer one delivered update to the round.
    pub fn offer(&mut self, arrival: Arrival, payload: T) {
        self.events.push(Pending { arrival, payload });
    }

    /// Drain the event queue in simulated-time order and close the
    /// round under the deadline/quorum policy.
    pub fn close(mut self) -> ClosedRound<T> {
        let mut admitted: Vec<(Arrival, T)> = Vec::new();
        let mut late: Vec<Arrival> = Vec::new();
        while let Some(Pending { arrival, payload }) = self.events.pop() {
            if arrival.arrival_ms <= self.deadline_ms || admitted.len() < self.min_quorum {
                admitted.push((arrival, payload));
            } else {
                late.push(arrival);
            }
        }
        let last_admitted =
            admitted.last().map(|(a, _)| a.arrival_ms).unwrap_or(self.deadline_ms);
        // The leader closes early only when nothing was excluded (it
        // heard from the whole scheduled fleet); with late arrivals it
        // listened until the deadline (or past it, for quorum).
        let close_ms = if late.is_empty() {
            last_admitted
        } else {
            last_admitted.max(self.deadline_ms)
        };
        ClosedRound { admitted, late, close_ms, deadline_ms: self.deadline_ms }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arr(node: usize, arrival_ms: f64) -> Arrival {
        Arrival { node, arrival_ms, transfer_ms: arrival_ms / 2.0, attempts: 1 }
    }

    fn close_with(deadline: f64, quorum: usize, times: &[f64]) -> ClosedRound<usize> {
        let mut s = RoundScheduler::new(deadline, quorum);
        for (node, &t) in times.iter().enumerate() {
            s.offer(arr(node, t), node);
        }
        s.close()
    }

    #[test]
    fn everything_before_deadline_is_admitted_in_time_order() {
        let c = close_with(100.0, 3, &[90.0, 10.0, 50.0]);
        let order: Vec<usize> = c.admitted.iter().map(|(a, _)| a.node).collect();
        assert_eq!(order, vec![1, 2, 0]);
        assert!(c.late.is_empty());
        assert_eq!(c.close_ms, 90.0);
    }

    #[test]
    fn arrival_exactly_at_deadline_is_admitted() {
        let c = close_with(100.0, 0, &[100.0]);
        assert_eq!(c.admitted.len(), 1);
        assert!(c.late.is_empty());
    }

    #[test]
    fn late_arrivals_are_excluded_once_quorum_is_met() {
        let c = close_with(100.0, 2, &[10.0, 20.0, 150.0, 160.0]);
        assert_eq!(c.admitted.len(), 2);
        assert_eq!(c.late.len(), 2);
        // the leader listened until the deadline before giving up
        assert_eq!(c.close_ms, 100.0);
    }

    #[test]
    fn scheduler_waits_past_deadline_for_quorum() {
        let c = close_with(100.0, 3, &[10.0, 150.0, 250.0, 300.0]);
        let order: Vec<usize> = c.admitted.iter().map(|(a, _)| a.node).collect();
        assert_eq!(order, vec![0, 1, 2]);
        assert_eq!(c.late.len(), 1);
        assert_eq!(c.close_ms, 250.0);
    }

    #[test]
    fn empty_round_closes_at_deadline() {
        let c = close_with(42.0, 4, &[]);
        assert!(c.admitted.is_empty() && c.late.is_empty());
        assert_eq!(c.close_ms, 42.0);
        assert_eq!(c.deadline_ms, 42.0);
    }

    #[test]
    fn simultaneous_arrivals_break_ties_by_node_id() {
        let c = close_with(50.0, 0, &[50.0, 50.0, 50.0]);
        let order: Vec<usize> = c.admitted.iter().map(|(a, _)| a.node).collect();
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn quorum_larger_than_fleet_admits_everyone() {
        let c = close_with(10.0, 8, &[500.0, 600.0]);
        assert_eq!(c.admitted.len(), 2);
        assert!(c.late.is_empty());
        assert_eq!(c.close_ms, 600.0);
    }
}
