//! L3 coordinator: the Fig.-1 distributed-learning workflow, made
//! fault-tolerant.
//!
//! A leader orchestrates `N` edge nodes over simulated constrained
//! uplinks. Each round, every node
//!
//! 1. produces a local model update (synthetic drift, or real SGD via
//!    the PJRT `resnet32_sgd_b8` artifact in the e2e example),
//! 2. compresses its conv parameters with Algorithm-1 TTD — *timing
//!    and energy come from the SoC simulator* folding the node's
//!    actual op stream online under its configuration (Baseline or
//!    TT-Edge; streaming cost sink, no trace materialized),
//! 3. ships the TT cores (wire format: cores + rank header) through
//!    the transport model.
//!
//! Unlike the original all-or-nothing round, the leader now runs an
//! event-driven [`scheduler::RoundScheduler`]: updates are admitted as
//! they arrive in simulated time, a deadline derived from the slowest
//! surviving node's nominal profile (compression latency + one clean
//! transfer) bounds the round, and the round closes with whatever
//! quorum arrived.
//! Partial FedAvg renormalizes by the participating node count, so
//! dropouts and stragglers degrade participation — never corrupt the
//! aggregate. The whole failure surface ([`faults::FaultPlan`]:
//! dropout, straggler multipliers, lossy links with retries) is a pure
//! function of its seed and replays byte-for-byte; with a benign plan
//! the scheduler reproduces the legacy reports exactly (pinned by
//! `tests/golden_trace.rs` and `tests/federated_faults.rs`).
//!
//! Host-side, nodes still run on `std::thread::scope` workers (no
//! tokio in the offline build) collecting over mpsc channels; a node
//! the plan crashes spawns no worker and materializes no local model,
//! and every surviving batch carries a [`CancelToken`] so an
//! admission policy can abort it mid-round without a partial result
//! escaping.

pub mod faults;
pub mod scheduler;
pub mod transport;

use std::collections::BTreeMap;
use std::sync::mpsc;

use crate::job::CompressionJob;
use crate::model::resnet32::ConvLayer;
use crate::pipeline::{CancelToken, TtBatch};
use crate::sim::report::SimReport;
use crate::sim::SocConfig;
use crate::ttd::{reconstruct, Tensor};
use crate::util::json::Json;
use crate::util::Rng;

pub use faults::{FaultPlan, NodeFaults};
pub use scheduler::{Arrival, ClosedRound, RoundScheduler};
pub use transport::{Link, SendOutcome, TransportStats};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct FederatedConfig {
    pub nodes: usize,
    pub rounds: usize,
    /// TTD prescribed accuracy per layer.
    pub eps: f32,
    pub link: Link,
    /// SoC each edge node runs (Baseline vs TT-Edge).
    pub soc: SocConfig,
    /// Host worker threads each node uses for its layer batch (the
    /// pipeline work-stealing width; simulated SoC cost is invariant).
    pub threads_per_node: usize,
    /// Magnitude of the synthetic local drift per round.
    pub drift: f32,
    pub seed: u64,
    /// Updates the leader keeps waiting for past the deadline; `0`
    /// means the full scheduled fleet. When too many nodes drop for
    /// the quorum to ever arrive, the round still closes with what
    /// delivered — degraded, flagged by `RoundReport::quorum_met =
    /// false` — rather than stalling the fleet forever.
    pub min_quorum: usize,
    /// Round deadline as a multiple of the slowest *surviving* node's
    /// nominal profile (compress + one clean transfer) — the leader
    /// plans from the nodes that respond, so a crashed node's profile
    /// does not stretch the deadline. `1.0` admits exactly the
    /// fault-free fleet; stragglers running slower miss it.
    pub deadline_slack: f64,
    /// Materialize the exact-FedAvg oracle and report
    /// `aggregate_rel_err` against it. Costs O(model) extra memory per
    /// round — disable for big-model rounds (`federate --no-oracle`),
    /// which reports NaN instead.
    pub exact_oracle: bool,
    /// Seeded chaos schedule (dropout / stragglers / forced drops).
    /// Link loss lives on [`Link`]; its RNG stream comes from here.
    pub faults: FaultPlan,
}

impl Default for FederatedConfig {
    fn default() -> Self {
        FederatedConfig {
            nodes: 4,
            rounds: 3,
            eps: 0.12,
            link: Link::default(),
            soc: SocConfig::tt_edge(),
            threads_per_node: 1,
            drift: 0.02,
            seed: 7,
            min_quorum: 0,
            deadline_slack: 1.0,
            exact_oracle: true,
            faults: FaultPlan::default(),
        }
    }
}

/// One node's contribution to a round: the batched TT decompositions
/// plus the SoC-simulated cost of producing them.
#[derive(Debug)]
pub struct NodeUpdate {
    pub node: usize,
    /// All layers' decompositions, shipped as one wire unit.
    pub batch: TtBatch,
    pub wire_bytes: usize,
    pub dense_bytes: usize,
    /// SoC simulation of this node's compression work.
    pub sim: SimReport,
}

/// Aggregated metrics for one federated round.
#[derive(Debug, Clone)]
pub struct RoundReport {
    pub round: usize,
    /// Payload bytes of the updates that made it into the aggregate.
    pub wire_bytes: usize,
    pub dense_bytes: usize,
    pub communication_reduction: f64,
    /// Mean on-device compression latency of participants (simulated
    /// ms). Deliberately *nominal*: a straggler's latency multiplier
    /// models preemption delaying its upload start, so it shifts
    /// `deadline_ms`/`round_close_ms` accounting but not the SoC cost
    /// of the compression itself (see `NodeFaults::latency_mult`).
    pub mean_compress_ms: f64,
    /// Mean on-device compression energy of participants (simulated
    /// mJ); nominal under stragglers, like `mean_compress_ms`.
    pub mean_compress_mj: f64,
    /// Transfer time of the slowest admitted upload, including retry
    /// timeouts (ms).
    pub round_transfer_ms: f64,
    /// Relative error of the aggregated global model vs exact FedAvg
    /// over the same participants (NaN when the oracle is disabled).
    pub aggregate_rel_err: f32,
    /// Fleet size scheduled for this round.
    pub scheduled: usize,
    /// Updates admitted into the aggregate.
    pub participants: usize,
    /// Whether the requested quorum (`min_quorum`, or the full fleet
    /// at 0) was actually reached; `false` marks a degraded round that
    /// closed on whatever delivered.
    pub quorum_met: bool,
    /// Nodes lost this round: fault-plan crashes + transport-exhausted
    /// uploads.
    pub dropped: usize,
    /// Scheduled nodes running at a latency multiplier > 1.
    pub stragglers: usize,
    /// Updates delivered but excluded (past deadline, quorum already met).
    pub late: usize,
    /// Lost transport attempts that were retransmitted this round.
    pub retries: usize,
    /// Payload bytes burned by those lost attempts.
    pub retrans_bytes: usize,
    /// The scheduler's admission deadline (simulated ms).
    pub deadline_ms: f64,
    /// Simulated time the leader closed the round.
    pub round_close_ms: f64,
}

impl RoundReport {
    /// Machine-readable round report (`federate --json`), including
    /// every participation/straggler/retry field.
    pub fn to_json(&self) -> Json {
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        m.insert("round".into(), Json::from(self.round));
        m.insert("wire_bytes".into(), Json::from(self.wire_bytes));
        m.insert("dense_bytes".into(), Json::from(self.dense_bytes));
        m.insert("communication_reduction".into(), Json::from(self.communication_reduction));
        m.insert("mean_compress_ms".into(), Json::from(self.mean_compress_ms));
        m.insert("mean_compress_mj".into(), Json::from(self.mean_compress_mj));
        m.insert("round_transfer_ms".into(), Json::from(self.round_transfer_ms));
        m.insert("aggregate_rel_err".into(), Json::from(self.aggregate_rel_err as f64));
        m.insert("scheduled".into(), Json::from(self.scheduled));
        m.insert("participants".into(), Json::from(self.participants));
        m.insert("quorum_met".into(), Json::Bool(self.quorum_met));
        m.insert("dropped".into(), Json::from(self.dropped));
        m.insert("stragglers".into(), Json::from(self.stragglers));
        m.insert("late".into(), Json::from(self.late));
        m.insert("retries".into(), Json::from(self.retries));
        m.insert("retrans_bytes".into(), Json::from(self.retrans_bytes));
        m.insert("deadline_ms".into(), Json::from(self.deadline_ms));
        m.insert("round_close_ms".into(), Json::from(self.round_close_ms));
        Json::Obj(m)
    }
}

/// The federated leader + its edge fleet.
pub struct Coordinator {
    pub cfg: FederatedConfig,
    /// Global conv parameters (layer inventory + tensors, TT-dims).
    pub global: Vec<(ConvLayer, Tensor)>,
    pub transport: TransportStats,
}

fn drifted(global: &[(ConvLayer, Tensor)], rng: &mut Rng, drift: f32) -> Vec<Tensor> {
    // Local "training": small parameter drift around the global model
    // (scaled to each layer's RMS so compressibility is preserved).
    global
        .iter()
        .map(|(_, w)| {
            let rms = w.frobenius() / (w.numel() as f32).sqrt();
            let mut t = w.clone();
            for v in t.data.iter_mut() {
                *v += drift * rms * rng.normal() as f32;
            }
            t
        })
        .collect()
}

/// Compress one node's layer batch through the [`CompressionJob`]
/// streaming path: every layer folds its hardware ops into a
/// per-layer cost summary **online**, and the summaries merge
/// deterministically in layer order — no `Vec<HwOp>` proportional to
/// the trace is ever allocated, and the simulated cycles/energy are
/// bit-identical to the old record-then-replay loop. Returns `None`
/// when the node's cancel token trips mid-batch: no partial batch
/// ever reaches the leader.
fn compress_node(
    node: usize,
    layers: &[(ConvLayer, Tensor)],
    locals: &[Tensor],
    eps: f32,
    soc: SocConfig,
    threads: usize,
    cancel: &CancelToken,
) -> Option<NodeUpdate> {
    let jobs: Vec<(&ConvLayer, &Tensor)> =
        layers.iter().map(|(l, _)| l).zip(locals).collect();
    let out = CompressionJob::layer_refs(jobs)
        .eps(eps)
        .parallel(threads)
        .soc(soc)
        .cancel(cancel)
        .run()?;
    let sim = out.reports.into_iter().next().expect("one .soc() config was set");
    let batch = TtBatch::from_decomps(out.outcome.decomps);
    let dense_bytes: usize = layers.iter().map(|(l, _)| 4 * l.numel()).sum();
    let wire_bytes = batch.wire_bytes();
    Some(NodeUpdate { node, batch, wire_bytes, dense_bytes, sim })
}

impl Coordinator {
    /// New coordinator over synthetic trained-like global weights.
    pub fn new(cfg: FederatedConfig) -> Self {
        let global = crate::sim::workload::synthetic_model(cfg.seed, 3.55, 0.03);
        Coordinator { cfg, global, transport: TransportStats::default() }
    }

    /// New coordinator over externally supplied global conv tensors
    /// (the e2e example passes genuinely trained weights here).
    pub fn with_global(cfg: FederatedConfig, global: Vec<(ConvLayer, Tensor)>) -> Self {
        Coordinator { cfg, global, transport: TransportStats::default() }
    }

    /// Run one round: fan out to worker threads, push every surviving
    /// upload through the lossy transport, admit arrivals through the
    /// event-driven scheduler, then partial-FedAvg whatever quorum
    /// made it and advance the global model.
    pub fn round(&mut self, round: usize) -> RoundReport {
        let n = self.cfg.nodes;
        let faults = self.cfg.faults.for_round(round, n);
        let stragglers = faults.iter().filter(|f| f.is_straggler()).count();
        let plan_drops = faults.iter().filter(|f| f.dropped).count();

        // Per-node local models (deterministic fork per node+round —
        // this stream is untouched by the fault plan, so a benign plan
        // reproduces the fault-free numerics bit-for-bit). A crashed
        // node skips the O(model) drift materialization entirely; the
        // forks are independent per node, so everyone else's local
        // model is byte-identical either way.
        let base_rng = Rng::new(self.cfg.seed ^ (round as u64).wrapping_mul(0x9E37));
        let mut locals: Vec<Option<Vec<Tensor>>> = (0..n)
            .map(|i| {
                if faults[i].dropped {
                    return None;
                }
                let mut rng = base_rng.fork(i as u64 + 1);
                Some(drifted(&self.global, &mut rng, self.cfg.drift))
            })
            .collect();

        // Fan out compression to worker threads (leader/worker shape).
        // Crashed nodes spawn nothing; surviving nodes carry a cancel
        // token so a future admission policy can abort their batch
        // mid-round without a partial result escaping.
        let tokens: Vec<CancelToken> =
            (0..n).map(|_| CancelToken::default()).collect();
        let (tx, rx) = mpsc::channel::<NodeUpdate>();
        let cfg = self.cfg.clone();
        let global = &self.global;
        std::thread::scope(|scope| {
            for (i, local) in locals.iter().enumerate() {
                let Some(local) = local else { continue };
                let tx = tx.clone();
                let soc = cfg.soc.clone();
                let eps = cfg.eps;
                let threads = cfg.threads_per_node;
                let token = &tokens[i];
                scope.spawn(move || {
                    if let Some(upd) =
                        compress_node(i, global, local, eps, soc, threads, token)
                    {
                        let _ = tx.send(upd);
                    }
                });
            }
        });
        drop(tx);
        let mut updates: Vec<NodeUpdate> = rx.into_iter().collect();
        updates.sort_by_key(|u| u.node);

        // Deliver on the --no-oracle promise: nothing reads the
        // drifted local models past this point unless the oracle runs,
        // so release the O(nodes x model) buffer up front.
        if !self.cfg.exact_oracle {
            locals.clear();
            locals.shrink_to_fit();
        }

        // Round deadline: the leader's nominal expectation of its
        // slowest *surviving* node — SimReport latency plus one clean
        // transfer — scaled by the slack (crashed nodes have no
        // profile to plan from). At slack 1.0 the fault-free fleet
        // arrives exactly at (<=) the deadline.
        let deadline_ms = self.cfg.deadline_slack
            * updates
                .iter()
                .map(|u| u.sim.total_ms + self.cfg.link.transfer_ms(u.wire_bytes))
                .fold(0.0, f64::max);
        let min_quorum =
            if self.cfg.min_quorum == 0 { updates.len() } else { self.cfg.min_quorum };

        // Transport in node order: loss draws come from per-(round,
        // node) forked streams and stats accumulate in a fixed order,
        // so the tally is independent of worker-thread timing.
        let retries_before = self.transport.retries;
        let retrans_before = self.transport.retrans_bytes;
        let mut sched: RoundScheduler<NodeUpdate> =
            RoundScheduler::new(deadline_ms, min_quorum);
        let mut transport_drops = 0usize;
        for u in updates {
            let mut rng = self.cfg.faults.transport_rng(round, u.node);
            let out = self.transport.send_faulty(&self.cfg.link, u.wire_bytes, &mut rng);
            if !out.delivered {
                transport_drops += 1;
                continue;
            }
            // The node starts uploading when its (possibly straggling)
            // compression finishes; the leader receives it a transfer
            // (incl. retry timeouts) later.
            let arrival_ms = u.sim.total_ms * faults[u.node].latency_mult + out.ms;
            sched.offer(
                Arrival { node: u.node, arrival_ms, transfer_ms: out.ms, attempts: out.attempts },
                u,
            );
        }
        let closed = sched.close();
        let late = closed.late.len();
        let round_close_ms = closed.close_ms;

        // Participants in node order: the partial-FedAvg summation
        // order matches the legacy full-participation loop exactly.
        let mut admitted = closed.admitted;
        admitted.sort_by_key(|(a, _)| a.node);
        let k = admitted.len();
        let round_transfer_ms =
            admitted.iter().map(|(a, _)| a.transfer_ms).fold(0.0, f64::max);
        let retries = self.transport.retries - retries_before;
        let retrans_bytes = self.transport.retrans_bytes - retrans_before;

        let mut wire = 0usize;
        let mut dense = 0usize;
        for (_, u) in &admitted {
            wire += u.wire_bytes;
            dense += u.dense_bytes;
        }

        // Leader: reconstruct every participant's layers, FedAvg into
        // the new global model renormalized by the participant count
        // (Eq. 1/2 decode — the receiving side of Fig. 1). An empty
        // round leaves the global model untouched.
        let mut agg_err = if self.cfg.exact_oracle { 0.0f32 } else { f32::NAN };
        if k > 0 {
            let mut new_global: Vec<Tensor> = self
                .global
                .iter()
                .map(|(l, _)| Tensor::zeros(&l.tt_dims()))
                .collect();
            for (_, u) in &admitted {
                for (l, d) in u.batch.decomps.iter().enumerate() {
                    let w = reconstruct(d);
                    for (a, b) in new_global[l].data.iter_mut().zip(&w.data) {
                        *a += b / k as f32;
                    }
                }
            }

            if self.cfg.exact_oracle {
                // Exact FedAvg over the same participants (oracle for
                // the aggregation-error metric). Gated: materializing
                // it costs O(model) extra memory per round.
                let exact_avg: Vec<Tensor> = (0..self.global.len())
                    .map(|l| {
                        let mut acc = Tensor::zeros(&self.global[l].1.shape);
                        for (_, u) in &admitted {
                            let node_locals = locals[u.node]
                                .as_ref()
                                .expect("admitted node has a local model");
                            for (a, b) in acc.data.iter_mut().zip(&node_locals[l].data) {
                                *a += b / k as f32;
                            }
                        }
                        acc
                    })
                    .collect();
                let mut num = 0.0f64;
                let mut den = 0.0f64;
                for (got, want) in new_global.iter().zip(&exact_avg) {
                    let want_r = want.reshape(&got.shape);
                    for (a, b) in got.data.iter().zip(&want_r.data) {
                        num += ((a - b) as f64).powi(2);
                        den += (*b as f64).powi(2);
                    }
                }
                agg_err = (num / den.max(1e-30)).sqrt() as f32;
            }

            // Advance the global model (no shape clone: the borrow of
            // the old tensor's shape ends before the slot is written).
            for (slot, w) in self.global.iter_mut().zip(new_global) {
                let advanced = w.reshape(&slot.1.shape);
                slot.1 = advanced;
            }
        }

        let (mean_ms, mean_mj) = if k > 0 {
            (
                admitted.iter().map(|(_, u)| u.sim.total_ms).sum::<f64>() / k as f64,
                admitted.iter().map(|(_, u)| u.sim.total_mj).sum::<f64>() / k as f64,
            )
        } else {
            (0.0, 0.0)
        };

        RoundReport {
            round,
            wire_bytes: wire,
            dense_bytes: dense,
            communication_reduction: if wire > 0 { dense as f64 / wire as f64 } else { 0.0 },
            mean_compress_ms: mean_ms,
            mean_compress_mj: mean_mj,
            round_transfer_ms,
            aggregate_rel_err: agg_err,
            scheduled: n,
            participants: k,
            quorum_met: k
                >= if self.cfg.min_quorum == 0 { n } else { self.cfg.min_quorum },
            dropped: plan_drops + transport_drops,
            stragglers,
            late,
            retries,
            retrans_bytes,
            deadline_ms,
            round_close_ms,
        }
    }

    /// Run all configured rounds.
    pub fn run(&mut self) -> Vec<RoundReport> {
        (0..self.cfg.rounds).map(|r| self.round(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(soc: SocConfig) -> FederatedConfig {
        FederatedConfig { nodes: 3, rounds: 2, eps: 0.12, soc, ..Default::default() }
    }

    fn small_coordinator(soc: SocConfig) -> Coordinator {
        let mut c = Coordinator::new(small_cfg(soc));
        // keep the test fast: only the first 4 conv layers
        c.global.truncate(4);
        c
    }

    #[test]
    fn rounds_compress_and_aggregate() {
        let mut c = small_coordinator(SocConfig::tt_edge());
        let reports = c.run();
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert!(r.communication_reduction > 1.5, "{}", r.communication_reduction);
            assert!(r.aggregate_rel_err < 0.12, "{}", r.aggregate_rel_err);
            assert!(r.mean_compress_ms > 0.0);
            assert!(r.round_transfer_ms > 0.0);
            // fault-free: everyone scheduled participates, on time
            assert_eq!(r.participants, 3);
            assert!(r.quorum_met);
            assert_eq!((r.dropped, r.late, r.retries, r.stragglers), (0, 0, 0, 0));
            assert!(r.deadline_ms >= r.round_transfer_ms);
            assert!(r.round_close_ms <= r.deadline_ms);
        }
        // global model stays finite after aggregation
        for (_, w) in &c.global {
            assert!(w.data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn tt_edge_nodes_are_faster_and_cheaper_than_baseline() {
        let mut a = small_coordinator(SocConfig::baseline());
        let mut b = small_coordinator(SocConfig::tt_edge());
        let ra = &a.run()[0];
        let rb = &b.run()[0];
        let speedup = ra.mean_compress_ms / rb.mean_compress_ms;
        assert!(speedup > 1.4, "speedup {speedup}");
        let saving = 1.0 - rb.mean_compress_mj / ra.mean_compress_mj;
        assert!(saving > 0.3, "energy saving {saving}");
        // identical numerics => identical bytes on the wire
        assert_eq!(ra.wire_bytes, rb.wire_bytes);
    }

    #[test]
    fn deterministic_by_seed() {
        let r1 = small_coordinator(SocConfig::tt_edge()).run();
        let r2 = small_coordinator(SocConfig::tt_edge()).run();
        assert_eq!(r1[0].wire_bytes, r2[0].wire_bytes);
        assert_eq!(r1[1].aggregate_rel_err, r2[1].aggregate_rel_err);
        // byte-identical reports, all fields
        assert_eq!(format!("{r1:?}"), format!("{r2:?}"));
    }

    #[test]
    fn transport_tally_covers_all_nodes() {
        let mut c = small_coordinator(SocConfig::tt_edge());
        let _ = c.round(0);
        assert_eq!(c.transport.messages, 3);
        assert!(c.transport.bytes > 0);
        assert_eq!(c.transport.retries, 0);
        assert_eq!(c.transport.dropped, 0);
    }

    #[test]
    fn forced_dropout_renormalizes_partial_fedavg() {
        let mut cfg = small_cfg(SocConfig::tt_edge());
        cfg.faults.forced_dropouts = vec![(0, 1)];
        let mut c = Coordinator::new(cfg);
        c.global.truncate(4);
        let r = c.round(0);
        assert_eq!(r.scheduled, 3);
        assert_eq!(r.participants, 2);
        assert_eq!(r.dropped, 1);
        // quorum "all" (0) was not reached — degraded round, flagged
        assert!(!r.quorum_met);
        // renormalized aggregate still tracks the participants' exact
        // average within the per-layer budget
        assert!(r.aggregate_rel_err < 0.12, "{}", r.aggregate_rel_err);
        for (_, w) in &c.global {
            assert!(w.data.iter().all(|v| v.is_finite()));
        }
        // the crashed node never hit the wire
        assert_eq!(c.transport.messages, 2);
    }

    #[test]
    fn oracle_gating_skips_the_error_metric_only() {
        let mut with = small_coordinator(SocConfig::tt_edge());
        let mut without = small_coordinator(SocConfig::tt_edge());
        without.cfg.exact_oracle = false;
        let rw = with.round(0);
        let ro = without.round(0);
        assert!(rw.aggregate_rel_err.is_finite());
        assert!(ro.aggregate_rel_err.is_nan());
        // everything else — including the advanced global model — is
        // bit-identical
        assert_eq!(rw.wire_bytes, ro.wire_bytes);
        assert_eq!(rw.mean_compress_ms, ro.mean_compress_ms);
        for ((_, a), (_, b)) in with.global.iter().zip(&without.global) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn round_report_json_has_participation_fields() {
        let mut c = small_coordinator(SocConfig::tt_edge());
        let r = c.round(0);
        let text = r.to_json().render();
        let j = crate::util::json::parse(&text).unwrap();
        assert_eq!(j.get("participants").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("dropped").unwrap().as_usize().unwrap(), 0);
        assert_eq!(j.get("wire_bytes").unwrap().as_usize().unwrap(), r.wire_bytes);
        assert!(j.get("deadline_ms").unwrap().as_f64().unwrap() > 0.0);
    }
}
