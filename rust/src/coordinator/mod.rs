//! L3 coordinator: the Fig.-1 distributed-learning workflow.
//!
//! A leader orchestrates `N` edge nodes over simulated constrained
//! uplinks. Each round, every node
//!
//! 1. produces a local model update (synthetic drift, or real SGD via
//!    the PJRT `resnet32_sgd_b8` artifact in the e2e example),
//! 2. compresses its conv parameters with Algorithm-1 TTD — *timing
//!    and energy come from the SoC simulator* replaying the node's
//!    actual op trace under its configuration (Baseline or TT-Edge),
//! 3. ships the TT cores (wire format: cores + rank header) through
//!    the transport model.
//!
//! The leader reconstructs (Eq. 1/2), FedAvg-aggregates, and the next
//! round starts from the new global model. Nodes run on worker threads
//! (std::thread — no tokio in the offline build); the leader collects
//! updates over mpsc channels exactly like a request/response router.

pub mod transport;

use std::sync::mpsc;

use crate::model::resnet32::ConvLayer;
use crate::pipeline::{self, TtBatch};
use crate::sim::report::SimReport;
use crate::sim::timeline::HwTimeline;
use crate::sim::SocConfig;
use crate::ttd::{reconstruct, Tensor};
use crate::util::Rng;

pub use transport::{Link, TransportStats};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct FederatedConfig {
    pub nodes: usize,
    pub rounds: usize,
    /// TTD prescribed accuracy per layer.
    pub eps: f32,
    pub link: Link,
    /// SoC each edge node runs (Baseline vs TT-Edge).
    pub soc: SocConfig,
    /// Host worker threads each node uses for its layer batch (the
    /// pipeline work-stealing width; simulated SoC cost is invariant).
    pub threads_per_node: usize,
    /// Magnitude of the synthetic local drift per round.
    pub drift: f32,
    pub seed: u64,
}

impl Default for FederatedConfig {
    fn default() -> Self {
        FederatedConfig {
            nodes: 4,
            rounds: 3,
            eps: 0.12,
            link: Link::default(),
            soc: SocConfig::tt_edge(),
            threads_per_node: 1,
            drift: 0.02,
            seed: 7,
        }
    }
}

/// One node's contribution to a round: the batched TT decompositions
/// plus the SoC-simulated cost of producing them.
#[derive(Debug)]
pub struct NodeUpdate {
    pub node: usize,
    /// All layers' decompositions, shipped as one wire unit.
    pub batch: TtBatch,
    pub wire_bytes: usize,
    pub dense_bytes: usize,
    /// SoC simulation of this node's compression work.
    pub sim: SimReport,
}

/// Aggregated metrics for one federated round.
#[derive(Debug, Clone)]
pub struct RoundReport {
    pub round: usize,
    pub wire_bytes: usize,
    pub dense_bytes: usize,
    pub communication_reduction: f64,
    /// Mean on-device compression latency (simulated ms).
    pub mean_compress_ms: f64,
    /// Mean on-device compression energy (simulated mJ).
    pub mean_compress_mj: f64,
    /// Wall-clock transfer time of the slowest node (ms).
    pub round_transfer_ms: f64,
    /// Relative error of the aggregated global model vs exact FedAvg.
    pub aggregate_rel_err: f32,
}

/// The federated leader + its edge fleet.
pub struct Coordinator {
    pub cfg: FederatedConfig,
    /// Global conv parameters (layer inventory + tensors, TT-dims).
    pub global: Vec<(ConvLayer, Tensor)>,
    pub transport: TransportStats,
}

fn drifted(global: &[(ConvLayer, Tensor)], rng: &mut Rng, drift: f32) -> Vec<Tensor> {
    // Local "training": small parameter drift around the global model
    // (scaled to each layer's RMS so compressibility is preserved).
    global
        .iter()
        .map(|(_, w)| {
            let rms = w.frobenius() / (w.numel() as f32).sqrt();
            let mut t = w.clone();
            for v in t.data.iter_mut() {
                *v += drift * rms * rng.normal() as f32;
            }
            t
        })
        .collect()
}

/// Compress one node's layer batch through the pipeline, replaying
/// the merged per-layer traces into a fresh SoC timeline. The
/// simulated cycles/energy are identical to the old serial loop —
/// the merge is deterministic in layer order.
fn compress_node(
    node: usize,
    layers: &[(ConvLayer, Tensor)],
    locals: &[Tensor],
    eps: f32,
    soc: SocConfig,
    threads: usize,
) -> NodeUpdate {
    let jobs: Vec<(&ConvLayer, &Tensor)> =
        layers.iter().map(|(l, _)| l).zip(locals).collect();
    let results = pipeline::compress_layers_ref(&jobs, eps, threads);
    let mut tl = HwTimeline::new(soc);
    pipeline::replay_traces(&results, &mut tl);
    let sim = SimReport::from_timeline(&tl);
    let batch =
        TtBatch::from_decomps(results.into_iter().map(|r| r.decomp).collect());
    let dense_bytes: usize = layers.iter().map(|(l, _)| 4 * l.numel()).sum();
    let wire_bytes = batch.wire_bytes();
    NodeUpdate { node, batch, wire_bytes, dense_bytes, sim }
}

impl Coordinator {
    /// New coordinator over synthetic trained-like global weights.
    pub fn new(cfg: FederatedConfig) -> Self {
        let global = crate::sim::workload::synthetic_model(cfg.seed, 3.55, 0.03);
        Coordinator { cfg, global, transport: TransportStats::default() }
    }

    /// New coordinator over externally supplied global conv tensors
    /// (the e2e example passes genuinely trained weights here).
    pub fn with_global(cfg: FederatedConfig, global: Vec<(ConvLayer, Tensor)>) -> Self {
        Coordinator { cfg, global, transport: TransportStats::default() }
    }

    /// Run one round: fan out to worker threads, collect updates,
    /// reconstruct + FedAvg, advance the global model.
    pub fn round(&mut self, round: usize) -> RoundReport {
        let n = self.cfg.nodes;
        // Per-node local models (deterministic fork per node+round).
        let base_rng = Rng::new(self.cfg.seed ^ (round as u64).wrapping_mul(0x9E37));
        let locals: Vec<Vec<Tensor>> = (0..n)
            .map(|i| {
                let mut rng = base_rng.fork(i as u64 + 1);
                drifted(&self.global, &mut rng, self.cfg.drift)
            })
            .collect();

        // Exact FedAvg (oracle for the aggregation-error metric).
        let exact_avg: Vec<Tensor> = (0..self.global.len())
            .map(|l| {
                let mut acc = Tensor::zeros(&self.global[l].1.shape);
                for node_layers in &locals {
                    for (a, b) in acc.data.iter_mut().zip(&node_layers[l].data) {
                        *a += b / n as f32;
                    }
                }
                acc
            })
            .collect();

        // Fan out compression to worker threads (leader/worker shape).
        let (tx, rx) = mpsc::channel::<NodeUpdate>();
        let cfg = self.cfg.clone();
        let global = &self.global;
        std::thread::scope(|scope| {
            for (i, local) in locals.iter().enumerate() {
                let tx = tx.clone();
                let soc = cfg.soc.clone();
                let eps = cfg.eps;
                let threads = cfg.threads_per_node;
                scope.spawn(move || {
                    let upd = compress_node(i, global, local, eps, soc, threads);
                    let _ = tx.send(upd);
                });
            }
        });
        drop(tx);
        let mut updates: Vec<NodeUpdate> = rx.into_iter().collect();
        updates.sort_by_key(|u| u.node);

        // Transport: every node ships its cores; round latency is the
        // slowest node (they upload in parallel).
        let mut round_transfer_ms = 0.0f64;
        let mut wire = 0usize;
        let mut dense = 0usize;
        for u in &updates {
            let ms = self.transport.send(&self.cfg.link, u.wire_bytes);
            round_transfer_ms = round_transfer_ms.max(ms);
            wire += u.wire_bytes;
            dense += u.dense_bytes;
        }

        // Leader: reconstruct every node's layers, FedAvg into the new
        // global model (Eq. 1/2 decode — the receiving side of Fig. 1).
        let mut new_global: Vec<Tensor> = self
            .global
            .iter()
            .map(|(l, _)| Tensor::zeros(&l.tt_dims()))
            .collect();
        for u in &updates {
            for (l, d) in u.batch.decomps.iter().enumerate() {
                let w = reconstruct(d);
                for (a, b) in new_global[l].data.iter_mut().zip(&w.data) {
                    *a += b / n as f32;
                }
            }
        }

        // Aggregation error vs the exact average.
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (got, want) in new_global.iter().zip(&exact_avg) {
            let want_r = want.reshape(&got.shape);
            for (a, b) in got.data.iter().zip(&want_r.data) {
                num += ((a - b) as f64).powi(2);
                den += (*b as f64).powi(2);
            }
        }
        let agg_err = (num / den.max(1e-30)).sqrt() as f32;

        // Advance the global model.
        for (slot, w) in self.global.iter_mut().zip(new_global) {
            slot.1 = w.reshape(&slot.1.shape.clone());
        }

        let mean_ms =
            updates.iter().map(|u| u.sim.total_ms).sum::<f64>() / updates.len() as f64;
        let mean_mj =
            updates.iter().map(|u| u.sim.total_mj).sum::<f64>() / updates.len() as f64;

        RoundReport {
            round,
            wire_bytes: wire,
            dense_bytes: dense,
            communication_reduction: dense as f64 / wire as f64,
            mean_compress_ms: mean_ms,
            mean_compress_mj: mean_mj,
            round_transfer_ms,
            aggregate_rel_err: agg_err,
        }
    }

    /// Run all configured rounds.
    pub fn run(&mut self) -> Vec<RoundReport> {
        (0..self.cfg.rounds).map(|r| self.round(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(soc: SocConfig) -> FederatedConfig {
        FederatedConfig { nodes: 3, rounds: 2, eps: 0.12, soc, ..Default::default() }
    }

    fn small_coordinator(soc: SocConfig) -> Coordinator {
        let mut c = Coordinator::new(small_cfg(soc));
        // keep the test fast: only the first 4 conv layers
        c.global.truncate(4);
        c
    }

    #[test]
    fn rounds_compress_and_aggregate() {
        let mut c = small_coordinator(SocConfig::tt_edge());
        let reports = c.run();
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert!(r.communication_reduction > 1.5, "{}", r.communication_reduction);
            assert!(r.aggregate_rel_err < 0.12, "{}", r.aggregate_rel_err);
            assert!(r.mean_compress_ms > 0.0);
            assert!(r.round_transfer_ms > 0.0);
        }
        // global model stays finite after aggregation
        for (_, w) in &c.global {
            assert!(w.data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn tt_edge_nodes_are_faster_and_cheaper_than_baseline() {
        let mut a = small_coordinator(SocConfig::baseline());
        let mut b = small_coordinator(SocConfig::tt_edge());
        let ra = &a.run()[0];
        let rb = &b.run()[0];
        let speedup = ra.mean_compress_ms / rb.mean_compress_ms;
        assert!(speedup > 1.4, "speedup {speedup}");
        let saving = 1.0 - rb.mean_compress_mj / ra.mean_compress_mj;
        assert!(saving > 0.3, "energy saving {saving}");
        // identical numerics => identical bytes on the wire
        assert_eq!(ra.wire_bytes, rb.wire_bytes);
    }

    #[test]
    fn deterministic_by_seed() {
        let r1 = small_coordinator(SocConfig::tt_edge()).run();
        let r2 = small_coordinator(SocConfig::tt_edge()).run();
        assert_eq!(r1[0].wire_bytes, r2[0].wire_bytes);
        assert_eq!(r1[1].aggregate_rel_err, r2[1].aggregate_rel_err);
    }

    #[test]
    fn transport_tally_covers_all_nodes() {
        let mut c = small_coordinator(SocConfig::tt_edge());
        let _ = c.round(0);
        assert_eq!(c.transport.messages, 3);
        assert!(c.transport.bytes > 0);
    }
}
