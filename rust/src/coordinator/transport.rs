//! Edge-to-leader transport model: the communication channel whose
//! overhead motivates on-device compression (paper section I).
//!
//! A latency + bandwidth model with an optional lossy-link mode: each
//! attempt is dropped with probability [`Link::loss`] and retried up
//! to [`Link::max_retries`] times (stop-and-wait — a lost attempt
//! costs a full transfer timeout before the retransmit). What matters
//! for the Fig.-1 experiment is the *ratio* between shipping dense
//! parameters and shipping TT cores, which is bandwidth-independent;
//! what matters for the fault-tolerant scheduler is that loss and
//! retries are deterministic functions of the caller-supplied RNG, so
//! a chaos run replays byte-for-byte from its seed.

use crate::util::Rng;

/// Uplink characteristics of an edge node.
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// Sustained uplink bandwidth, kilobytes per second.
    pub bandwidth_kbps: f64,
    /// Per-message latency, milliseconds.
    pub latency_ms: f64,
    /// Per-attempt loss probability in `[0, 1)`. `0.0` is the exact
    /// lossless model: one attempt, no RNG consumed.
    pub loss: f64,
    /// Retransmissions allowed after the first attempt before the
    /// message is declared dropped.
    pub max_retries: u32,
}

impl Default for Link {
    fn default() -> Self {
        // A constrained IoT uplink (LTE Cat-M1-class), lossless by
        // default so existing experiments reproduce exactly.
        Link { bandwidth_kbps: 128.0, latency_ms: 50.0, loss: 0.0, max_retries: 3 }
    }
}

impl Link {
    /// Transfer time for one attempt carrying `bytes`, in milliseconds.
    pub fn transfer_ms(&self, bytes: usize) -> f64 {
        self.latency_ms + bytes as f64 / self.bandwidth_kbps
    }
}

/// Result of pushing one message through a (possibly lossy) link.
#[derive(Clone, Copy, Debug)]
pub struct SendOutcome {
    /// False when every attempt (1 + `max_retries`) was lost.
    pub delivered: bool,
    /// Attempts consumed, including the successful one.
    pub attempts: u32,
    /// Total channel time from first attempt to outcome: every lost
    /// attempt burns a full transfer timeout before the retransmit.
    pub ms: f64,
}

/// Tally of bytes moved through the channel.
#[derive(Clone, Copy, Debug, Default)]
pub struct TransportStats {
    /// Messages delivered to the leader.
    pub messages: usize,
    /// Payload bytes of delivered messages (counted once per message,
    /// on the attempt that got through).
    pub bytes: usize,
    /// Lost attempts (retransmitted or abandoned).
    pub retries: usize,
    /// Payload bytes burned by lost attempts. Conservation law (see
    /// `tests/transport_properties.rs`): `bytes + retrans_bytes`
    /// equals payload x total attempts.
    pub retrans_bytes: usize,
    /// Messages abandoned after exhausting `max_retries`.
    pub dropped: usize,
    pub total_ms: f64,
}

impl TransportStats {
    /// Lossless send — the original transport model, kept as the exact
    /// baseline the property tests compare the lossy path against.
    pub fn send(&mut self, link: &Link, bytes: usize) -> f64 {
        let ms = link.transfer_ms(bytes);
        self.messages += 1;
        self.bytes += bytes;
        self.total_ms += ms;
        ms
    }

    /// Send through a lossy link. With `link.loss == 0.0` this is
    /// bit-identical to [`TransportStats::send`] (one attempt, the
    /// exact same `transfer_ms`, and `rng` untouched).
    pub fn send_faulty(&mut self, link: &Link, bytes: usize, rng: &mut Rng) -> SendOutcome {
        let per_attempt = link.transfer_ms(bytes);
        // saturating: --retries u32::MAX means "retry forever", not an
        // overflow panic (debug) or a zero-attempt wrap (release)
        let max_attempts = link.max_retries.saturating_add(1);
        let mut attempts = 0u32;
        let mut ms = 0.0f64;
        while attempts < max_attempts {
            attempts += 1;
            ms += per_attempt;
            let lost = link.loss > 0.0 && rng.uniform() < link.loss;
            if !lost {
                self.messages += 1;
                self.bytes += bytes;
                self.total_ms += ms;
                return SendOutcome { delivered: true, attempts, ms };
            }
            self.retries += 1;
            self.retrans_bytes += bytes;
        }
        self.dropped += 1;
        self.total_ms += ms;
        SendOutcome { delivered: false, attempts, ms }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link(bandwidth_kbps: f64, latency_ms: f64) -> Link {
        Link { bandwidth_kbps, latency_ms, ..Link::default() }
    }

    #[test]
    fn transfer_time_is_latency_plus_payload() {
        let l = link(100.0, 10.0);
        assert!((l.transfer_ms(1000) - 20.0).abs() < 1e-9);
        assert!((l.transfer_ms(0) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn stats_accumulate() {
        let l = link(100.0, 0.0);
        let mut s = TransportStats::default();
        s.send(&l, 500);
        s.send(&l, 1500);
        assert_eq!(s.messages, 2);
        assert_eq!(s.bytes, 2000);
        assert!((s.total_ms - 20.0).abs() < 1e-9);
    }

    #[test]
    fn lossless_faulty_send_equals_plain_send() {
        let l = link(128.0, 50.0);
        let mut plain = TransportStats::default();
        let mut faulty = TransportStats::default();
        let mut rng = Rng::new(1);
        let before = rng.clone().next_u64();
        let ms_plain = plain.send(&l, 4096);
        let out = faulty.send_faulty(&l, 4096, &mut rng);
        assert!(out.delivered);
        assert_eq!(out.attempts, 1);
        assert_eq!(out.ms, ms_plain);
        assert_eq!(faulty.messages, plain.messages);
        assert_eq!(faulty.bytes, plain.bytes);
        assert_eq!(faulty.total_ms, plain.total_ms);
        assert_eq!(faulty.retries, 0);
        // zero-loss consumes no randomness
        assert_eq!(rng.next_u64(), before);
    }

    #[test]
    fn total_loss_exhausts_retries_and_reports_drop() {
        let l = Link { loss: 1.0, max_retries: 2, ..link(100.0, 0.0) };
        let mut s = TransportStats::default();
        let mut rng = Rng::new(7);
        let out = s.send_faulty(&l, 1000, &mut rng);
        assert!(!out.delivered);
        assert_eq!(out.attempts, 3);
        assert!((out.ms - 30.0).abs() < 1e-9);
        assert_eq!(s.messages, 0);
        assert_eq!(s.bytes, 0);
        assert_eq!(s.retries, 3);
        assert_eq!(s.retrans_bytes, 3000);
        assert_eq!(s.dropped, 1);
    }

    #[test]
    fn lossy_send_is_deterministic_in_the_seed() {
        let l = Link { loss: 0.4, max_retries: 5, ..link(64.0, 10.0) };
        let run = || {
            let mut s = TransportStats::default();
            let mut rng = Rng::new(0xC0FFEE);
            let outs: Vec<SendOutcome> =
                (0..16).map(|_| s.send_faulty(&l, 777, &mut rng)).collect();
            (format!("{outs:?}"), format!("{s:?}"))
        };
        assert_eq!(run(), run());
    }
}
