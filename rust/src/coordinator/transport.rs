//! Edge-to-leader transport model: the communication channel whose
//! overhead motivates on-device compression (paper section I).
//!
//! A simple latency + bandwidth model; what matters for the Fig.-1
//! experiment is the *ratio* between shipping dense parameters and
//! shipping TT cores, which is bandwidth-independent.

/// Uplink characteristics of an edge node.
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// Sustained uplink bandwidth, kilobytes per second.
    pub bandwidth_kbps: f64,
    /// Per-message latency, milliseconds.
    pub latency_ms: f64,
}

impl Default for Link {
    fn default() -> Self {
        // A constrained IoT uplink (LTE Cat-M1-class).
        Link { bandwidth_kbps: 128.0, latency_ms: 50.0 }
    }
}

impl Link {
    /// Transfer time for `bytes`, in milliseconds.
    pub fn transfer_ms(&self, bytes: usize) -> f64 {
        self.latency_ms + bytes as f64 / self.bandwidth_kbps
    }
}

/// Tally of bytes moved through the channel.
#[derive(Clone, Copy, Debug, Default)]
pub struct TransportStats {
    pub messages: usize,
    pub bytes: usize,
    pub total_ms: f64,
}

impl TransportStats {
    pub fn send(&mut self, link: &Link, bytes: usize) -> f64 {
        let ms = link.transfer_ms(bytes);
        self.messages += 1;
        self.bytes += bytes;
        self.total_ms += ms;
        ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_is_latency_plus_payload() {
        let l = Link { bandwidth_kbps: 100.0, latency_ms: 10.0 };
        assert!((l.transfer_ms(1000) - 20.0).abs() < 1e-9);
        assert!((l.transfer_ms(0) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn stats_accumulate() {
        let l = Link { bandwidth_kbps: 100.0, latency_ms: 0.0 };
        let mut s = TransportStats::default();
        s.send(&l, 500);
        s.send(&l, 1500);
        assert_eq!(s.messages, 2);
        assert_eq!(s.bytes, 2000);
        assert!((s.total_ms - 20.0).abs() < 1e-9);
    }
}
