//! `ttedge-lint` — run the repo-invariant static-analysis pass over
//! `src/`, `tests/`, and `benches/` (see `tt_edge::analysis` for the
//! rule set and pragma grammar).
//!
//! ```text
//! ttedge-lint [--root DIR] [--warn] [--json] [--report PATH]
//! ```
//!
//! * `--root DIR`   crate root to scan (default: auto-detect — the
//!   cwd if it has a `src/`, else `./rust`, else the compiled-in
//!   manifest dir, so the binary works from the repo root, from
//!   `rust/`, and from CI).
//! * `--warn`       report violations but exit 0 (deny is the default:
//!   any violation exits 1).
//! * `--json`       print the `lint-report-v1` document to stdout
//!   after the `file:line rule message` lines.
//! * `--report PATH` also write the JSON document to `PATH`.
//!
//! Exit codes: 0 clean (or `--warn`), 1 violations in deny mode,
//! 2 usage error.

use std::path::PathBuf;
use std::process::ExitCode;

use tt_edge::analysis;
use tt_edge::util::cli::Args;

const USAGE: &str = "usage: ttedge-lint [--root DIR] [--warn] [--json] [--report PATH]";

fn resolve_root(explicit: Option<&str>) -> PathBuf {
    if let Some(dir) = explicit {
        return PathBuf::from(dir);
    }
    if PathBuf::from("src").is_dir() {
        return PathBuf::from(".");
    }
    if PathBuf::from("rust/src").is_dir() {
        return PathBuf::from("rust");
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn main() -> ExitCode {
    let args = Args::from_env();
    if args.flag("help") {
        println!("{USAGE}");
        return ExitCode::SUCCESS;
    }
    if let Err(msg) = args.validate(&["root", "report"], &["warn", "json", "help"]) {
        eprintln!("ttedge-lint: {msg}\n{USAGE}");
        return ExitCode::from(2);
    }
    if !args.positional.is_empty() {
        eprintln!(
            "ttedge-lint: unexpected argument `{}`\n{USAGE}",
            args.positional[0]
        );
        return ExitCode::from(2);
    }

    let root = resolve_root(args.opt("root"));
    let report = match analysis::analyze_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("ttedge-lint: failed to scan {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    let mode = if args.flag("warn") { "warn" } else { "deny" };
    for v in &report.violations {
        println!("{}", v.render());
    }
    let json = report.to_json(mode).render();
    if args.flag("json") {
        println!("{json}");
    }
    if let Some(path) = args.opt("report") {
        if let Err(e) = std::fs::write(path, format!("{json}\n")) {
            eprintln!("ttedge-lint: failed to write --report {path}: {e}");
            return ExitCode::from(2);
        }
    }
    eprintln!(
        "ttedge-lint: {} file(s) scanned, {} violation(s), {} allow pragma(s) [{mode} mode]",
        report.files_scanned,
        report.violations.len(),
        report.allows.len()
    );
    if mode == "deny" && !report.clean() {
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}
