//! Compression-as-a-service: drain a queue of compression requests
//! through a shared, keyed [`ProgramCache`].
//!
//! The wire format is JSONL — one request object per line, blank lines
//! and `#` comment lines skipped:
//!
//! ```text
//! {"workload": "tiny", "seed": "7", "eps": 0.12, "socs": ["baseline", "tt-edge"]}
//! {"workload": "resnet32", "eps": 0.2, "rank_cap": 8}
//! {"workload": "tiny", "seed": "7", "eps": 0.12, "rank_caps": [4, 6]}
//! {"workload": "tiny-gpt", "method": "rsvd", "socs": ["systolic"]}
//! ```
//!
//! Every field is optional (`workload` resnet32, `seed` 42, `eps`
//! 0.12, `method` exact, unbounded ranks, both SoCs; `method: "rsvd"`
//! keys the randomized range-finder off the request seed with the
//! default oversampling of 8); a *present but malformed* field
//! — or an unknown key — is a hard parse error naming the line, never
//! a silent default (the CmdSpec philosophy, applied to the wire).
//!
//! [`serve`] drains the queue with N workers stealing requests off a
//! shared cursor (the `pipeline` idiom). Two properties are pinned by
//! `tests/program_cache.rs`:
//!
//! * **Determinism** — each response is a pure function of its request
//!   (cache hits replay a program that is bit-identical to what a
//!   fresh run would record), so per-request outputs are byte-
//!   identical at any worker count. Scheduling-dependent facts (which
//!   occurrence of a key missed) are deliberately kept *out* of the
//!   responses and live only in the aggregate [`ServeOutcome`].
//! * **Exactly-K numerics** — R requests over K unique cache keys cost
//!   exactly K numerics passes at any worker count (single-flight
//!   misses; see [`crate::cache`]).
//!
//! **Lock discipline / poison policy.** This module owns no mutex of
//! its own: workers share `&ProgramCache` and the response channel,
//! and every cache-lock acquisition happens inside
//! [`ProgramCache::lock_cache`] — the cache's single named helper,
//! whose documented policy is to *propagate* a poison panic rather
//! than recover. That propagation is safe for serve's single-flight
//! protocol because a panicking recorder runs its numerics outside
//! the lock and its `MissGuard` releases the Pending key on drop, so
//! the remaining workers either take over the recording or crash the
//! drain loudly — they never deadlock on a wedged key and never serve
//! a response derived from half-updated cache state.
//!
//! **Supervision & chaos (ISSUE 10).** Every request attempt runs
//! inside [`crate::fault::supervise`]'s `catch_unwind`: a worker panic
//! (injected by a [`ChaosPlan`] or real) becomes a structured
//! `"status": "error"` response instead of process death. Retryable
//! faults (panics, SVD non-convergence) get up to
//! [`ServeConfig::retries`] extra attempts with seeded bounded
//! backoff; an optional per-request `"deadline_ms"` arms the existing
//! `CancelToken` through [`crate::fault::with_deadline`]. Fault
//! decisions are keyed per `(request, attempt)` — never per worker —
//! so a chaos drain, like a benign one, is byte-identical at any
//! worker count and across reruns of the same plan.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Duration;

use crate::cache::ProgramCache;
use crate::dse::Workload;
use crate::fault::{supervise, with_deadline, ChaosPlan, JobError, RequestFaults};
use crate::job::{numerics_pass_count, CompressionJob, JobOutput};
use crate::metrics::CacheStats;
use crate::pipeline::CancelToken;
use crate::sim::report::SimReport;
use crate::sim::SocConfig;
use crate::ttd::ttd::{SvdMethod, TtSpec};
use crate::util::json::{self, Json};

/// Keys a request object may carry; anything else is a parse error.
const REQUEST_KEYS: &[&str] =
    &["workload", "seed", "eps", "method", "rank_cap", "rank_caps", "socs", "deadline_ms"];

/// One parsed queue entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeRequest {
    pub workload: Workload,
    /// Seeds the synthetic-trained weights (the workload identity).
    pub seed: u64,
    pub eps: f32,
    /// SVD method (`"method": "exact"|"rsvd"`). `rsvd` resolves to the
    /// randomized range-finder seeded by the request seed.
    pub method: SvdMethod,
    /// Uniform bond cap (`"rank_cap"`); `None` leaves bonds unbounded
    /// unless `rank_caps` is given.
    pub rank_cap: Option<usize>,
    /// Per-bond caps (`"rank_caps"`); mutually exclusive with
    /// `rank_cap` on the wire.
    pub rank_caps: Vec<usize>,
    /// SoC wire names to cost under, in request order.
    pub socs: Vec<String>,
    /// Optional per-request deadline (`"deadline_ms"`): the serve
    /// supervisor arms the job's `CancelToken` when it expires, and
    /// the response reports `deadline-exceeded`. `0` expires before
    /// the run starts (the deterministic form tests and CI use).
    pub deadline_ms: Option<u64>,
}

impl Default for ServeRequest {
    fn default() -> Self {
        ServeRequest {
            workload: Workload::Resnet32,
            seed: 42,
            eps: 0.12,
            method: SvdMethod::Exact,
            rank_cap: None,
            rank_caps: Vec::new(),
            socs: vec!["baseline".into(), "tt-edge".into()],
            deadline_ms: None,
        }
    }
}

impl ServeRequest {
    /// The full numeric spec this request asks for.
    pub fn spec(&self) -> TtSpec {
        let spec = TtSpec::eps(self.eps).with_method(self.method);
        if !self.rank_caps.is_empty() {
            spec.rank_caps(&self.rank_caps)
        } else if let Some(cap) = self.rank_cap {
            spec.rank_cap(cap)
        } else {
            spec
        }
    }

    /// Resolve the SoC wire names (validated at parse time).
    pub fn soc_configs(&self) -> Vec<SocConfig> {
        self.socs
            .iter()
            .map(|name| match name.as_str() {
                "baseline" => SocConfig::baseline(),
                "tt-edge" => SocConfig::tt_edge(),
                "systolic" => SocConfig::systolic(),
                other => unreachable!("parse_request validated soc names, got `{other}`"),
            })
            .collect()
    }

    /// Echo of the request (stable field order; part of the response).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("workload".into(), Json::from(self.workload.label()));
        // string: u64 seeds don't fit JSON's f64-exact integer range
        m.insert("seed".into(), Json::Str(self.seed.to_string()));
        m.insert("eps".into(), Json::from(f64::from(self.eps)));
        if matches!(self.method, SvdMethod::Randomized { .. }) {
            m.insert("method".into(), Json::from("rsvd"));
        }
        if let Some(cap) = self.rank_cap {
            m.insert("rank_cap".into(), Json::from(cap));
        }
        if !self.rank_caps.is_empty() {
            m.insert(
                "rank_caps".into(),
                Json::Arr(self.rank_caps.iter().map(|&c| Json::from(c)).collect()),
            );
        }
        m.insert(
            "socs".into(),
            Json::Arr(self.socs.iter().map(|s| Json::from(s.as_str())).collect()),
        );
        if let Some(ms) = self.deadline_ms {
            m.insert("deadline_ms".into(), Json::from(ms as usize));
        }
        Json::Obj(m)
    }
}

fn parse_seed(j: &Json) -> Result<u64, String> {
    match j {
        // string form is canonical (u64 exactness); a small integer
        // number is accepted for hand-written request files
        Json::Str(s) => s.parse().map_err(|_| format!("bad seed `{s}`")),
        Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n < 9.0e15 => Ok(*n as u64),
        other => Err(format!("bad seed {other:?}")),
    }
}

fn parse_cap(j: &Json, field: &str) -> Result<usize, String> {
    match j {
        Json::Num(n) if n.fract() == 0.0 && *n >= 1.0 && *n < 9.0e15 => Ok(*n as usize),
        _ => Err(format!("{field} entries must be integers >= 1")),
    }
}

/// Parse one request line (a JSON object; see the module docs).
pub fn parse_request(text: &str) -> Result<ServeRequest, String> {
    let j = json::parse(text).map_err(|e| e.to_string())?;
    let Json::Obj(map) = &j else {
        return Err("request must be a JSON object".into());
    };
    for key in map.keys() {
        if !REQUEST_KEYS.contains(&key.as_str()) {
            return Err(format!("unknown request key `{key}`"));
        }
    }
    let mut req = ServeRequest::default();
    if let Some(w) = j.get("workload") {
        let name = w.as_str().ok_or("workload must be a string")?;
        req.workload = Workload::parse(name).ok_or_else(|| {
            format!("bad workload `{name}` (resnet32|tiny|tiny-gpt|bert-base|activations)")
        })?;
    }
    if let Some(s) = j.get("seed") {
        req.seed = parse_seed(s)?;
    }
    if let Some(m) = j.get("method") {
        let name = m.as_str().ok_or("method must be a string")?;
        req.method = match name {
            "exact" => SvdMethod::Exact,
            // keyed off the (possibly defaulted) request seed: the
            // sketch is part of the workload identity, so two seeds
            // are two cache keys
            "rsvd" => SvdMethod::Randomized { seed: req.seed, oversample: 8 },
            _ => return Err(format!("bad method `{name}` (exact|rsvd)")),
        };
    }
    if let Some(e) = j.get("eps") {
        let eps = e.as_f64().ok_or("eps must be a number")?;
        if !(eps.is_finite() && eps >= 0.0) {
            return Err(format!("eps must be finite and >= 0, got {eps}"));
        }
        req.eps = eps as f32;
    }
    if map.contains_key("rank_cap") && map.contains_key("rank_caps") {
        return Err("rank_cap and rank_caps are mutually exclusive".into());
    }
    if let Some(c) = j.get("rank_cap") {
        req.rank_cap = Some(parse_cap(c, "rank_cap")?);
    }
    if let Some(caps) = j.get("rank_caps") {
        let arr = caps.as_arr().ok_or("rank_caps must be an array")?;
        if arr.is_empty() {
            return Err("rank_caps must not be empty (omit it for unbounded)".into());
        }
        req.rank_caps =
            arr.iter().map(|c| parse_cap(c, "rank_caps")).collect::<Result<_, _>>()?;
    }
    if let Some(socs) = j.get("socs") {
        let arr = socs.as_arr().ok_or("socs must be an array of strings")?;
        if arr.is_empty() {
            return Err("socs must not be empty (omit it for both SoCs)".into());
        }
        req.socs = arr
            .iter()
            .map(|s| {
                let name = s.as_str().ok_or("socs must be an array of strings")?;
                if matches!(name, "baseline" | "tt-edge" | "systolic") {
                    Ok(name.to_string())
                } else {
                    Err(format!("bad soc `{name}` (baseline|tt-edge|systolic)"))
                }
            })
            .collect::<Result<_, String>>()?;
    }
    if let Some(d) = j.get("deadline_ms") {
        req.deadline_ms = Some(match d {
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n < 9.0e15 => *n as u64,
            _ => return Err("deadline_ms must be a non-negative integer".into()),
        });
    }
    Ok(req)
}

/// Parse a whole JSONL request file. Blank lines and `#` comments are
/// skipped; any malformed line fails the whole file with its line
/// number (a queue with a corrupt entry should not half-run).
pub fn parse_requests(text: &str) -> Result<Vec<ServeRequest>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        out.push(parse_request(line).map_err(|e| format!("request line {}: {e}", i + 1))?);
    }
    Ok(out)
}

/// One queue slot: a well-formed request, or — in lenient mode — a
/// line that failed to parse and is answered in place with a
/// structured `malformed-request` error response.
#[derive(Clone, Debug)]
pub enum QueueEntry {
    Request(ServeRequest),
    Malformed {
        /// 1-based line number in the request file.
        line: usize,
        /// The parse error text.
        error: String,
    },
}

/// Lenient JSONL parsing (`serve --lenient`): a malformed line becomes
/// a [`QueueEntry::Malformed`] — answered with a per-line error
/// response — instead of failing the whole file. Blank and `#` comment
/// lines are still skipped, and well-formed lines parse identically to
/// [`parse_requests`].
pub fn parse_requests_lenient(text: &str) -> Vec<QueueEntry> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        out.push(match parse_request(line) {
            Ok(req) => QueueEntry::Request(req),
            Err(error) => QueueEntry::Malformed { line: i + 1, error },
        });
    }
    out
}

/// One served request: the request echo, and either the compression
/// summary (one report per requested SoC) or a structured
/// [`JobError`]. A pure function of `(request, index, chaos plan)` —
/// byte-identical whether it was served by a hit, a miss, or any
/// worker interleaving.
#[derive(Clone, Debug)]
pub struct ServeResponse {
    /// Position in the request file (responses are returned sorted).
    pub index: usize,
    /// Echo of the parsed request; `None` only for lenient-mode
    /// malformed lines, which never parsed.
    pub request: Option<ServeRequest>,
    /// `Some` makes this an error response (`"status": "error"` on the
    /// wire); the compression fields below are then zero/empty.
    pub error: Option<JobError>,
    pub compression_ratio: f64,
    pub max_rel_err: f32,
    pub final_params: usize,
    pub reports: Vec<SimReport>,
}

impl ServeResponse {
    fn ok(index: usize, request: ServeRequest, out: JobOutput) -> Self {
        ServeResponse {
            index,
            request: Some(request),
            error: None,
            compression_ratio: out.outcome.compression_ratio,
            max_rel_err: out.outcome.max_rel_err,
            final_params: out.outcome.final_params,
            reports: out.reports,
        }
    }

    fn fail(index: usize, request: Option<ServeRequest>, error: JobError) -> Self {
        ServeResponse {
            index,
            request,
            error: Some(error),
            compression_ratio: 0.0,
            max_rel_err: 0.0,
            final_params: 0,
            reports: Vec::new(),
        }
    }

    /// The wire object. Every response — ok or error — carries
    /// `"index"` and `"status"`; ok responses add the request echo,
    /// compression summary and reports, error responses an
    /// `"error": {"code", "message"}` object (plus the echo when the
    /// line parsed).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("index".into(), Json::from(self.index));
        if let Some(req) = &self.request {
            m.insert("request".into(), req.to_json());
        }
        match &self.error {
            Some(e) => {
                m.insert("status".into(), Json::from("error"));
                let mut err = BTreeMap::new();
                err.insert("code".into(), Json::from(e.code()));
                err.insert("message".into(), Json::Str(e.to_string()));
                m.insert("error".into(), Json::Obj(err));
            }
            None => {
                m.insert("status".into(), Json::from("ok"));
                let mut c = BTreeMap::new();
                c.insert("compression_ratio".into(), Json::from(self.compression_ratio));
                c.insert("max_rel_err".into(), Json::from(f64::from(self.max_rel_err)));
                c.insert("final_params".into(), Json::from(self.final_params));
                m.insert("compression".into(), Json::Obj(c));
                m.insert(
                    "reports".into(),
                    Json::Arr(self.reports.iter().map(|r| r.to_json()).collect()),
                );
            }
        }
        Json::Obj(m)
    }
}

/// Service knobs (`serve --workers N --cache C`, plus the chaos
/// flags).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub workers: usize,
    /// Program-cache capacity; 0 disables residency (the uncached
    /// baseline benchmarks compare against).
    pub cache_capacity: usize,
    /// Seeded fault-injection schedule. The default plan is benign:
    /// it draws no faults, and the drain is bit-identical to the
    /// pre-chaos serve path.
    pub chaos: ChaosPlan,
    /// Extra attempts granted to retryable faults (worker panics, SVD
    /// non-convergence) before the request answers with an error.
    pub retries: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 1, cache_capacity: 64, chaos: ChaosPlan::default(), retries: 2 }
    }
}

/// Everything one drain produced: per-request responses (sorted by
/// request index) plus the aggregate cache/numerics accounting.
#[derive(Debug)]
pub struct ServeOutcome {
    pub responses: Vec<ServeResponse>,
    pub stats: CacheStats,
    /// Numerics passes the whole drain cost (summed across workers).
    /// With enough cache capacity this equals the number of unique
    /// cache keys in the stream, at any worker count.
    pub numerics_passes: u64,
    pub workers: usize,
    pub cache_capacity: usize,
    /// Requests answered with a structured error.
    pub errors: usize,
    /// Retry attempts spent across the drain (beyond first attempts).
    pub retries: u64,
}

impl ServeOutcome {
    /// The greppable stderr metrics line. `numerics_passes` is last on
    /// purpose — CI anchors `numerics_passes=K$` on it.
    pub fn metrics_line(&self) -> String {
        format!(
            "serve metrics: requests={} workers={} cache_capacity={} errors={} retries={} {} numerics_passes={}",
            self.responses.len(),
            self.workers,
            self.cache_capacity,
            self.errors,
            self.retries,
            self.stats.render(),
            self.numerics_passes,
        )
    }

    /// The serve-metrics-v1 artifact object (schema in
    /// `EXPERIMENTS/README.md`). `wall_ms` is host-measured; the
    /// derived `rps` is the sustained requests/sec of this drain.
    pub fn metrics_json(&self, wall_ms: f64) -> Json {
        let mut m = self.stats.json_fields();
        m.insert("schema".into(), Json::from("serve-metrics-v1"));
        m.insert("requests".into(), Json::from(self.responses.len()));
        m.insert("workers".into(), Json::from(self.workers));
        m.insert("cache_capacity".into(), Json::from(self.cache_capacity));
        m.insert("numerics_passes".into(), Json::from(self.numerics_passes as usize));
        m.insert("errors".into(), Json::from(self.errors));
        m.insert("retries".into(), Json::from(self.retries as usize));
        m.insert("wall_ms".into(), Json::from(wall_ms));
        let rps = if wall_ms > 0.0 {
            self.responses.len() as f64 / (wall_ms / 1e3)
        } else {
            f64::NAN // renders as null
        };
        m.insert("rps".into(), Json::from(rps));
        Json::Obj(m)
    }
}

/// One attempt at a request: apply this attempt's fault decisions,
/// then run the job through the shared cache. Always called inside
/// [`supervise`]'s `catch_unwind`, so an injected (or real) panic —
/// including the hard-stall `SvdNonConvergence` raised mid-recording —
/// never escapes the worker.
fn execute_request(
    index: usize,
    req: &ServeRequest,
    cache: &ProgramCache,
    faults: &RequestFaults,
    token: &CancelToken,
    plan: &ChaosPlan,
) -> Result<JobOutput, JobError> {
    if faults.panic {
        panic!("chaos: injected worker panic on request {index}");
    }
    let spec = req.spec().with_stall(faults.stall);
    let socs = req.soc_configs();
    if faults.poison {
        // Poison one seeded weight slot of the materialized input and
        // submit through ::model — the job's NaN screen rejects it
        // before any numerics run. The poisoned key can never collide
        // with the clean one (the NaN bit pattern is in the
        // fingerprint), so the cache stays uncontaminated.
        let mut layers = req.workload.layers(req.seed);
        let li = plan.poison_slot(index, layers.len());
        let wi = plan.poison_slot(index, layers[li].1.data.len());
        layers[li].1.data[wi] = f32::NAN;
        return CompressionJob::model(&layers)
            .spec(spec)
            .socs(&socs)
            .cached(cache)
            .cancel(token)
            .try_run();
    }
    match req.workload {
        // The synthetic builder keys the cache by generator params —
        // a hit never even materializes the weights.
        Workload::Resnet32 => CompressionJob::synthetic(req.seed)
            .spec(spec)
            .socs(&socs)
            .cached(cache)
            .cancel(token)
            .try_run(),
        Workload::Tiny => {
            let layers = req.workload.layers(req.seed);
            CompressionJob::model(&layers)
                .spec(spec)
                .socs(&socs)
                .cached(cache)
                .cancel(token)
                .try_run()
        }
        // Transformer inputs key the cache by spec (name, dims, seed)
        // and materialize lazily on a miss, like `synthetic`.
        Workload::TinyGpt | Workload::BertBase | Workload::Activations => {
            let mut backing = None;
            req.workload
                .job(req.seed, &mut backing)
                .spec(spec)
                .socs(&socs)
                .cached(cache)
                .cancel(token)
                .try_run()
        }
    }
}

/// Serve one queue entry through the supervised retry loop. Returns
/// the response plus the retries spent — both pure functions of
/// `(entry, index, plan)`, never of worker identity or scheduling, so
/// drains stay byte-identical at any worker count.
fn serve_entry(
    index: usize,
    entry: &QueueEntry,
    cache: &ProgramCache,
    cfg: &ServeConfig,
) -> (ServeResponse, u64) {
    let req = match entry {
        QueueEntry::Malformed { line, error } => {
            let e = JobError::MalformedRequest(format!("request line {line}: {error}"));
            return (ServeResponse::fail(index, None, e), 0);
        }
        QueueEntry::Request(req) => req,
    };
    let mut retries = 0u64;
    loop {
        let attempt = retries as usize;
        if attempt > 0 {
            // Seeded bounded backoff: deterministic in value, pure
            // wall delay — it never reaches a byte-pinned artifact.
            std::thread::sleep(Duration::from_millis(cfg.chaos.backoff_ms(index, attempt)));
        }
        let faults = cfg.chaos.for_request(index, attempt);
        let token = if faults.cancel { CancelToken::cancelled() } else { CancelToken::default() };
        let result = with_deadline(req.deadline_ms, &token, || {
            supervise(|| execute_request(index, req, cache, &faults, &token, &cfg.chaos))
        });
        match result {
            Ok(out) => return (ServeResponse::ok(index, req.clone(), out), retries),
            Err(e) => {
                // A cancellation with a deadline armed (and no
                // injected cancel) is the deadline firing.
                let e = if e == JobError::Cancelled && req.deadline_ms.is_some() && !faults.cancel
                {
                    JobError::DeadlineExceeded
                } else {
                    e
                };
                if e.retryable() && attempt < cfg.retries {
                    retries += 1;
                    continue;
                }
                return (ServeResponse::fail(index, Some(req.clone()), e), retries);
            }
        }
    }
}

/// Drain `requests` with a fresh cache of `cfg.cache_capacity`
/// (honouring `cfg.chaos`/`cfg.retries`; the default config is the
/// benign, no-retry-needed path).
pub fn serve(requests: &[ServeRequest], cfg: &ServeConfig) -> ServeOutcome {
    let cache = ProgramCache::new(cfg.cache_capacity);
    let entries: Vec<QueueEntry> = requests.iter().cloned().map(QueueEntry::Request).collect();
    drain(&entries, cfg, &cache)
}

/// Drain a lenient-parsed queue (well-formed requests interleaved with
/// malformed lines answered in place) with a fresh cache.
pub fn serve_queue(entries: &[QueueEntry], cfg: &ServeConfig) -> ServeOutcome {
    let cache = ProgramCache::new(cfg.cache_capacity);
    drain(entries, cfg, &cache)
}

/// Drain `requests` against a caller-owned (possibly pre-warmed)
/// cache, under the benign default plan.
pub fn serve_with_cache(
    requests: &[ServeRequest],
    workers: usize,
    cache: &ProgramCache,
) -> ServeOutcome {
    let entries: Vec<QueueEntry> = requests.iter().cloned().map(QueueEntry::Request).collect();
    let cfg = ServeConfig { workers, cache_capacity: cache.capacity(), ..ServeConfig::default() };
    drain(&entries, &cfg, cache)
}

/// The shared drain loop. `workers <= 1` drains inline on the calling
/// thread; more workers steal entries off a shared cursor (the
/// `pipeline` idiom) and responses are re-sorted into request order.
fn drain(entries: &[QueueEntry], cfg: &ServeConfig, cache: &ProgramCache) -> ServeOutcome {
    let capacity = cache.capacity();
    let workers = cfg.workers.max(1).min(entries.len().max(1));
    let (responses, numerics_passes, retries) = if workers <= 1 {
        let before = numerics_pass_count();
        let mut retries = 0u64;
        let responses: Vec<ServeResponse> = entries
            .iter()
            .enumerate()
            .map(|(i, entry)| {
                let (resp, spent) = serve_entry(i, entry, cache, cfg);
                retries += spent;
                resp
            })
            .collect();
        (responses, numerics_pass_count() - before, retries)
    } else {
        let cursor = AtomicUsize::new(0);
        let passes = AtomicU64::new(0);
        let retry_total = AtomicU64::new(0);
        let (tx, rx) = mpsc::channel::<ServeResponse>();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let tx = tx.clone();
                let cursor = &cursor;
                let passes = &passes;
                let retry_total = &retry_total;
                scope.spawn(move || {
                    // Fresh scope threads start at 0 passes, but take a
                    // baseline anyway in case a runtime reuses threads.
                    let before = numerics_pass_count();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= entries.len() {
                            break;
                        }
                        let (resp, spent) = serve_entry(i, &entries[i], cache, cfg);
                        retry_total.fetch_add(spent, Ordering::Relaxed);
                        if tx.send(resp).is_err() {
                            break;
                        }
                    }
                    passes.fetch_add(numerics_pass_count() - before, Ordering::Relaxed);
                });
            }
        });
        drop(tx);
        let mut responses: Vec<ServeResponse> = rx.into_iter().collect();
        responses.sort_by_key(|r| r.index);
        (responses, passes.load(Ordering::Relaxed), retry_total.load(Ordering::Relaxed))
    };
    let errors = responses.iter().filter(|r| r.error.is_some()).count();
    ServeOutcome {
        responses,
        stats: cache.stats(),
        numerics_passes,
        workers,
        cache_capacity: capacity,
        errors,
        retries,
    }
}

/// The fault-report-v1 artifact (schema in `EXPERIMENTS/README.md`):
/// the chaos plan's identity plus the drain's structured-error
/// accounting. `ttedge serve` writes it whenever the plan is not
/// benign.
pub fn fault_report(outcome: &ServeOutcome, plan: &ChaosPlan) -> Json {
    let mut counts: BTreeMap<&'static str, usize> = BTreeMap::new();
    for r in &outcome.responses {
        if let Some(e) = &r.error {
            *counts.entry(e.code()).or_insert(0) += 1;
        }
    }
    let by_code: BTreeMap<String, Json> =
        counts.into_iter().map(|(code, n)| (code.to_string(), Json::from(n))).collect();
    let mut m = BTreeMap::new();
    m.insert("schema".into(), Json::from("fault-report-v1"));
    m.insert("fault_seed".into(), Json::Str(plan.seed.to_string()));
    m.insert("requests".into(), Json::from(outcome.responses.len()));
    m.insert("ok".into(), Json::from(outcome.responses.len() - outcome.errors));
    m.insert("errors".into(), Json::from(outcome.errors));
    m.insert("retries".into(), Json::from(outcome.retries as usize));
    m.insert("errors_by_code".into(), Json::Obj(by_code));
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_defaults_and_explicit_fields() {
        let req = parse_request(r#"{}"#).unwrap();
        assert_eq!(req, ServeRequest::default());
        let req = parse_request(
            r#"{"workload": "tiny", "seed": "7", "eps": 0.2, "rank_cap": 8, "socs": ["tt-edge"]}"#,
        )
        .unwrap();
        assert_eq!(req.workload, Workload::Tiny);
        assert_eq!(req.seed, 7);
        assert_eq!(req.eps, 0.2);
        assert_eq!(req.rank_cap, Some(8));
        assert_eq!(req.socs, vec!["tt-edge".to_string()]);
        assert_eq!(req.spec().cap_for(0), 8);
        // numeric seeds are accepted for hand-written files
        assert_eq!(parse_request(r#"{"seed": 9}"#).unwrap().seed, 9);
    }

    #[test]
    fn rejects_malformed_requests() {
        for (line, needle) in [
            (r#"[1]"#, "object"),
            (r#"{"epz": 0.1}"#, "unknown request key"),
            (r#"{"workload": "vgg"}"#, "bad workload"),
            (r#"{"eps": "big"}"#, "eps must be a number"),
            (r#"{"eps": -0.1}"#, ">= 0"),
            (r#"{"seed": -3}"#, "bad seed"),
            (r#"{"rank_cap": 0}"#, ">= 1"),
            (r#"{"rank_caps": []}"#, "must not be empty"),
            (r#"{"rank_cap": 2, "rank_caps": [2]}"#, "mutually exclusive"),
            (r#"{"method": "qr"}"#, "bad method"),
            (r#"{"method": 3}"#, "method must be a string"),
            (r#"{"socs": ["gpu"]}"#, "bad soc"),
            (r#"{"socs": []}"#, "must not be empty"),
            (r#"{"deadline_ms": -5}"#, "deadline_ms"),
            (r#"{"deadline_ms": "soon"}"#, "deadline_ms"),
            (r#"not json"#, "json error"),
        ] {
            let err = parse_request(line).unwrap_err();
            assert!(err.contains(needle), "line {line}: {err}");
        }
    }

    #[test]
    fn request_file_skips_blanks_and_names_bad_lines() {
        let text = "\n# warm-up batch\n{\"workload\": \"tiny\"}\n\n{\"eps\": 0.3}\n";
        let reqs = parse_requests(text).unwrap();
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].workload, Workload::Tiny);
        assert_eq!(reqs[1].eps, 0.3);
        let err = parse_requests("{\"workload\": \"tiny\"}\n{\"epz\": 1}\n").unwrap_err();
        assert!(err.contains("request line 2"), "{err}");
    }

    #[test]
    fn parses_transformer_rsvd_requests() {
        let req = parse_request(
            r#"{"workload": "tiny-gpt", "seed": "7", "method": "rsvd", "socs": ["systolic"]}"#,
        )
        .unwrap();
        assert_eq!(req.workload, Workload::TinyGpt);
        // the sketch is keyed by the request seed, not a fixed default
        assert_eq!(req.method, SvdMethod::Randomized { seed: 7, oversample: 8 });
        assert_eq!(req.spec().method(), req.method);
        assert_eq!(req.soc_configs()[0].name(), SocConfig::systolic().name());
        let echoed = parse_request(&req.to_json().render()).unwrap();
        assert_eq!(echoed, req);
    }

    #[test]
    fn request_echo_round_trips_through_the_parser() {
        let req = parse_request(
            r#"{"workload": "tiny", "seed": "7", "eps": 0.2, "rank_caps": [4, 6]}"#,
        )
        .unwrap();
        let echoed = parse_request(&req.to_json().render()).unwrap();
        assert_eq!(echoed, req);
    }

    #[test]
    fn empty_queue_drains_to_empty_outcome() {
        let out = serve(&[], &ServeConfig::default());
        assert!(out.responses.is_empty());
        assert_eq!(out.numerics_passes, 0);
        assert!(out.stats.conserved());
        assert!(out.metrics_line().contains("requests=0"));
        let j = out.metrics_json(0.0).render();
        assert!(j.contains("\"schema\":\"serve-metrics-v1\""), "{j}");
        assert!(j.contains("\"rps\":null"), "{j}");
        assert!(j.contains("\"errors\":0"), "{j}");
    }

    fn tiny_line() -> &'static str {
        r#"{"workload": "tiny", "eps": 0.2, "socs": ["tt-edge"]}"#
    }

    #[test]
    fn deadline_field_parses_and_round_trips() {
        assert_eq!(parse_request(r#"{}"#).unwrap().deadline_ms, None);
        let req = parse_request(r#"{"workload": "tiny", "deadline_ms": 5000}"#).unwrap();
        assert_eq!(req.deadline_ms, Some(5000));
        let echoed = parse_request(&req.to_json().render()).unwrap();
        assert_eq!(echoed, req);
    }

    #[test]
    fn ok_responses_carry_status_ok_on_the_wire() {
        let req = parse_request(tiny_line()).unwrap();
        let out = serve(&[req], &ServeConfig::default());
        let line = out.responses[0].to_json().render();
        assert!(line.contains("\"status\":\"ok\""), "{line}");
        assert!(line.contains("\"request\":"), "{line}");
        assert!(!line.contains("\"error\""), "{line}");
        assert_eq!((out.errors, out.retries), (0, 0));
        assert!(out.metrics_line().contains("errors=0 retries=0"), "{}", out.metrics_line());
    }

    #[test]
    fn lenient_queue_answers_bad_lines_in_place() {
        let text = format!("{}\nnot json\n{{\"epz\": 1}}\n", tiny_line());
        let entries = parse_requests_lenient(&text);
        assert_eq!(entries.len(), 3);
        assert!(matches!(entries[0], QueueEntry::Request(_)));
        // strict mode still aborts the whole file
        assert!(parse_requests(&text).is_err());
        let out = serve_queue(&entries, &ServeConfig::default());
        assert_eq!(out.responses.len(), 3, "every line is answered");
        assert_eq!(out.errors, 2);
        assert!(out.responses[0].error.is_none());
        let bad = out.responses[1].to_json().render();
        assert!(bad.contains("\"status\":\"error\""), "{bad}");
        assert!(bad.contains("malformed-request"), "{bad}");
        assert!(bad.contains("line 2"), "{bad}");
        assert!(out.responses[1].request.is_none(), "a malformed line has no echo");
        assert!(bad.contains("\"index\":1"), "{bad}");
    }

    #[test]
    fn zero_deadline_is_a_structured_deadline_error() {
        let mut req = parse_request(tiny_line()).unwrap();
        req.deadline_ms = Some(0);
        let out = serve(&[req], &ServeConfig::default());
        assert_eq!(out.responses[0].error, Some(JobError::DeadlineExceeded));
        assert_eq!(out.errors, 1);
        let line = out.responses[0].to_json().render();
        assert!(line.contains("deadline-exceeded"), "{line}");
    }

    #[test]
    fn injected_faults_become_structured_errors_not_process_death() {
        let reqs: Vec<ServeRequest> =
            (0..5).map(|_| parse_request(tiny_line()).unwrap()).collect();
        let cfg = ServeConfig {
            chaos: ChaosPlan {
                forced_panics: vec![1],
                forced_stalls: vec![2],
                forced_cancels: vec![3],
                forced_poison: vec![4],
                ..ChaosPlan::default()
            },
            ..ServeConfig::default()
        };
        let out = serve(&reqs, &cfg);
        assert_eq!(out.responses.len(), 5, "every request is answered");
        assert!(out.responses[0].error.is_none());
        let code = |i: usize| out.responses[i].error.as_ref().unwrap().code();
        assert_eq!(code(1), "worker-panic");
        assert_eq!(code(2), "svd-non-convergence");
        assert_eq!(code(3), "cancelled");
        assert_eq!(code(4), "non-finite-input");
        assert_eq!(out.errors, 4);
        // panic and non-convergence are retryable; forced faults burn
        // every attempt, the rest fail fast
        assert_eq!(out.retries, 2 * cfg.retries as u64);
        assert!(out.stats.conserved(), "{:?}", out.stats);
        let report = fault_report(&out, &cfg.chaos).render();
        assert!(report.contains("\"schema\":\"fault-report-v1\""), "{report}");
        assert!(report.contains("\"errors\":4"), "{report}");
        assert!(report.contains("\"worker-panic\":1"), "{report}");
        assert!(report.contains("\"ok\":1"), "{report}");
    }

    #[test]
    fn soft_stalls_are_rescued_and_still_serve_ok() {
        let req = parse_request(tiny_line()).unwrap();
        let cfg = ServeConfig {
            chaos: ChaosPlan { stall: 1.0, ..ChaosPlan::default() },
            ..ServeConfig::default()
        };
        let out = serve(&[req], &cfg);
        assert!(out.responses[0].error.is_none(), "{:?}", out.responses[0].error);
        assert!(out.responses[0].compression_ratio > 1.0);
        assert_eq!(out.errors, 0);
    }

    #[test]
    fn chaos_drains_are_byte_identical_across_workers_and_reruns() {
        let reqs: Vec<ServeRequest> = (0..6)
            .map(|i| {
                let mut r = parse_request(tiny_line()).unwrap();
                r.seed = 40 + (i % 2) as u64;
                r
            })
            .collect();
        let chaos =
            ChaosPlan { seed: 7, panic: 0.4, stall: 0.4, cancel: 0.2, ..ChaosPlan::default() };
        let render = |out: &ServeOutcome| {
            out.responses.iter().map(|r| r.to_json().render()).collect::<Vec<_>>().join("\n")
        };
        let cfg = |workers| ServeConfig {
            workers,
            chaos: chaos.clone(),
            ..ServeConfig::default()
        };
        let serial = serve(&reqs, &cfg(1));
        let rerun = serve(&reqs, &cfg(1));
        let wide = serve(&reqs, &cfg(4));
        assert_eq!(render(&serial), render(&rerun), "same plan must replay byte-for-byte");
        assert_eq!(render(&serial), render(&wide), "worker count must not leak into responses");
        assert_eq!(serial.errors, wide.errors);
        assert_eq!(serial.retries, wide.retries);
    }
}
