//! Compression-as-a-service: drain a queue of compression requests
//! through a shared, keyed [`ProgramCache`].
//!
//! The wire format is JSONL — one request object per line, blank lines
//! and `#` comment lines skipped:
//!
//! ```text
//! {"workload": "tiny", "seed": "7", "eps": 0.12, "socs": ["baseline", "tt-edge"]}
//! {"workload": "resnet32", "eps": 0.2, "rank_cap": 8}
//! {"workload": "tiny", "seed": "7", "eps": 0.12, "rank_caps": [4, 6]}
//! {"workload": "tiny-gpt", "method": "rsvd", "socs": ["systolic"]}
//! ```
//!
//! Every field is optional (`workload` resnet32, `seed` 42, `eps`
//! 0.12, `method` exact, unbounded ranks, both SoCs; `method: "rsvd"`
//! keys the randomized range-finder off the request seed with the
//! default oversampling of 8); a *present but malformed* field
//! — or an unknown key — is a hard parse error naming the line, never
//! a silent default (the CmdSpec philosophy, applied to the wire).
//!
//! [`serve`] drains the queue with N workers stealing requests off a
//! shared cursor (the `pipeline` idiom). Two properties are pinned by
//! `tests/program_cache.rs`:
//!
//! * **Determinism** — each response is a pure function of its request
//!   (cache hits replay a program that is bit-identical to what a
//!   fresh run would record), so per-request outputs are byte-
//!   identical at any worker count. Scheduling-dependent facts (which
//!   occurrence of a key missed) are deliberately kept *out* of the
//!   responses and live only in the aggregate [`ServeOutcome`].
//! * **Exactly-K numerics** — R requests over K unique cache keys cost
//!   exactly K numerics passes at any worker count (single-flight
//!   misses; see [`crate::cache`]).
//!
//! **Lock discipline / poison policy.** This module owns no mutex of
//! its own: workers share `&ProgramCache` and the response channel,
//! and every cache-lock acquisition happens inside
//! [`ProgramCache::lock_cache`] — the cache's single named helper,
//! whose documented policy is to *propagate* a poison panic rather
//! than recover. That propagation is safe for serve's single-flight
//! protocol because a panicking recorder runs its numerics outside
//! the lock and its `MissGuard` releases the Pending key on drop, so
//! the remaining workers either take over the recording or crash the
//! drain loudly — they never deadlock on a wedged key and never serve
//! a response derived from half-updated cache state.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;

use crate::cache::ProgramCache;
use crate::dse::Workload;
use crate::job::{numerics_pass_count, CompressionJob};
use crate::metrics::CacheStats;
use crate::sim::report::SimReport;
use crate::sim::SocConfig;
use crate::ttd::ttd::{SvdMethod, TtSpec};
use crate::util::json::{self, Json};

/// Keys a request object may carry; anything else is a parse error.
const REQUEST_KEYS: &[&str] =
    &["workload", "seed", "eps", "method", "rank_cap", "rank_caps", "socs"];

/// One parsed queue entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeRequest {
    pub workload: Workload,
    /// Seeds the synthetic-trained weights (the workload identity).
    pub seed: u64,
    pub eps: f32,
    /// SVD method (`"method": "exact"|"rsvd"`). `rsvd` resolves to the
    /// randomized range-finder seeded by the request seed.
    pub method: SvdMethod,
    /// Uniform bond cap (`"rank_cap"`); `None` leaves bonds unbounded
    /// unless `rank_caps` is given.
    pub rank_cap: Option<usize>,
    /// Per-bond caps (`"rank_caps"`); mutually exclusive with
    /// `rank_cap` on the wire.
    pub rank_caps: Vec<usize>,
    /// SoC wire names to cost under, in request order.
    pub socs: Vec<String>,
}

impl Default for ServeRequest {
    fn default() -> Self {
        ServeRequest {
            workload: Workload::Resnet32,
            seed: 42,
            eps: 0.12,
            method: SvdMethod::Exact,
            rank_cap: None,
            rank_caps: Vec::new(),
            socs: vec!["baseline".into(), "tt-edge".into()],
        }
    }
}

impl ServeRequest {
    /// The full numeric spec this request asks for.
    pub fn spec(&self) -> TtSpec {
        let spec = TtSpec::eps(self.eps).with_method(self.method);
        if !self.rank_caps.is_empty() {
            spec.rank_caps(&self.rank_caps)
        } else if let Some(cap) = self.rank_cap {
            spec.rank_cap(cap)
        } else {
            spec
        }
    }

    /// Resolve the SoC wire names (validated at parse time).
    pub fn soc_configs(&self) -> Vec<SocConfig> {
        self.socs
            .iter()
            .map(|name| match name.as_str() {
                "baseline" => SocConfig::baseline(),
                "tt-edge" => SocConfig::tt_edge(),
                "systolic" => SocConfig::systolic(),
                other => unreachable!("parse_request validated soc names, got `{other}`"),
            })
            .collect()
    }

    /// Echo of the request (stable field order; part of the response).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("workload".into(), Json::from(self.workload.label()));
        // string: u64 seeds don't fit JSON's f64-exact integer range
        m.insert("seed".into(), Json::Str(self.seed.to_string()));
        m.insert("eps".into(), Json::from(f64::from(self.eps)));
        if matches!(self.method, SvdMethod::Randomized { .. }) {
            m.insert("method".into(), Json::from("rsvd"));
        }
        if let Some(cap) = self.rank_cap {
            m.insert("rank_cap".into(), Json::from(cap));
        }
        if !self.rank_caps.is_empty() {
            m.insert(
                "rank_caps".into(),
                Json::Arr(self.rank_caps.iter().map(|&c| Json::from(c)).collect()),
            );
        }
        m.insert(
            "socs".into(),
            Json::Arr(self.socs.iter().map(|s| Json::from(s.as_str())).collect()),
        );
        Json::Obj(m)
    }
}

fn parse_seed(j: &Json) -> Result<u64, String> {
    match j {
        // string form is canonical (u64 exactness); a small integer
        // number is accepted for hand-written request files
        Json::Str(s) => s.parse().map_err(|_| format!("bad seed `{s}`")),
        Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n < 9.0e15 => Ok(*n as u64),
        other => Err(format!("bad seed {other:?}")),
    }
}

fn parse_cap(j: &Json, field: &str) -> Result<usize, String> {
    match j {
        Json::Num(n) if n.fract() == 0.0 && *n >= 1.0 && *n < 9.0e15 => Ok(*n as usize),
        _ => Err(format!("{field} entries must be integers >= 1")),
    }
}

/// Parse one request line (a JSON object; see the module docs).
pub fn parse_request(text: &str) -> Result<ServeRequest, String> {
    let j = json::parse(text).map_err(|e| e.to_string())?;
    let Json::Obj(map) = &j else {
        return Err("request must be a JSON object".into());
    };
    for key in map.keys() {
        if !REQUEST_KEYS.contains(&key.as_str()) {
            return Err(format!("unknown request key `{key}`"));
        }
    }
    let mut req = ServeRequest::default();
    if let Some(w) = j.get("workload") {
        let name = w.as_str().ok_or("workload must be a string")?;
        req.workload = Workload::parse(name).ok_or_else(|| {
            format!("bad workload `{name}` (resnet32|tiny|tiny-gpt|bert-base|activations)")
        })?;
    }
    if let Some(s) = j.get("seed") {
        req.seed = parse_seed(s)?;
    }
    if let Some(m) = j.get("method") {
        let name = m.as_str().ok_or("method must be a string")?;
        req.method = match name {
            "exact" => SvdMethod::Exact,
            // keyed off the (possibly defaulted) request seed: the
            // sketch is part of the workload identity, so two seeds
            // are two cache keys
            "rsvd" => SvdMethod::Randomized { seed: req.seed, oversample: 8 },
            _ => return Err(format!("bad method `{name}` (exact|rsvd)")),
        };
    }
    if let Some(e) = j.get("eps") {
        let eps = e.as_f64().ok_or("eps must be a number")?;
        if !(eps.is_finite() && eps >= 0.0) {
            return Err(format!("eps must be finite and >= 0, got {eps}"));
        }
        req.eps = eps as f32;
    }
    if map.contains_key("rank_cap") && map.contains_key("rank_caps") {
        return Err("rank_cap and rank_caps are mutually exclusive".into());
    }
    if let Some(c) = j.get("rank_cap") {
        req.rank_cap = Some(parse_cap(c, "rank_cap")?);
    }
    if let Some(caps) = j.get("rank_caps") {
        let arr = caps.as_arr().ok_or("rank_caps must be an array")?;
        if arr.is_empty() {
            return Err("rank_caps must not be empty (omit it for unbounded)".into());
        }
        req.rank_caps =
            arr.iter().map(|c| parse_cap(c, "rank_caps")).collect::<Result<_, _>>()?;
    }
    if let Some(socs) = j.get("socs") {
        let arr = socs.as_arr().ok_or("socs must be an array of strings")?;
        if arr.is_empty() {
            return Err("socs must not be empty (omit it for both SoCs)".into());
        }
        req.socs = arr
            .iter()
            .map(|s| {
                let name = s.as_str().ok_or("socs must be an array of strings")?;
                if matches!(name, "baseline" | "tt-edge" | "systolic") {
                    Ok(name.to_string())
                } else {
                    Err(format!("bad soc `{name}` (baseline|tt-edge|systolic)"))
                }
            })
            .collect::<Result<_, String>>()?;
    }
    Ok(req)
}

/// Parse a whole JSONL request file. Blank lines and `#` comments are
/// skipped; any malformed line fails the whole file with its line
/// number (a queue with a corrupt entry should not half-run).
pub fn parse_requests(text: &str) -> Result<Vec<ServeRequest>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        out.push(parse_request(line).map_err(|e| format!("request line {}: {e}", i + 1))?);
    }
    Ok(out)
}

/// One served request: the request echo, the compression summary, and
/// one report per requested SoC. A pure function of the request —
/// byte-identical whether it was served by a hit, a miss, or any
/// worker interleaving.
#[derive(Clone, Debug)]
pub struct ServeResponse {
    /// Position in the request file (responses are returned sorted).
    pub index: usize,
    pub request: ServeRequest,
    pub compression_ratio: f64,
    pub max_rel_err: f32,
    pub final_params: usize,
    pub reports: Vec<SimReport>,
}

impl ServeResponse {
    pub fn to_json(&self) -> Json {
        let mut c = BTreeMap::new();
        c.insert("compression_ratio".into(), Json::from(self.compression_ratio));
        c.insert("max_rel_err".into(), Json::from(f64::from(self.max_rel_err)));
        c.insert("final_params".into(), Json::from(self.final_params));
        let mut m = BTreeMap::new();
        m.insert("index".into(), Json::from(self.index));
        m.insert("request".into(), self.request.to_json());
        m.insert("compression".into(), Json::Obj(c));
        m.insert(
            "reports".into(),
            Json::Arr(self.reports.iter().map(|r| r.to_json()).collect()),
        );
        Json::Obj(m)
    }
}

/// Service knobs (`serve --workers N --cache C`).
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    pub workers: usize,
    /// Program-cache capacity; 0 disables residency (the uncached
    /// baseline benchmarks compare against).
    pub cache_capacity: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 1, cache_capacity: 64 }
    }
}

/// Everything one drain produced: per-request responses (sorted by
/// request index) plus the aggregate cache/numerics accounting.
#[derive(Debug)]
pub struct ServeOutcome {
    pub responses: Vec<ServeResponse>,
    pub stats: CacheStats,
    /// Numerics passes the whole drain cost (summed across workers).
    /// With enough cache capacity this equals the number of unique
    /// cache keys in the stream, at any worker count.
    pub numerics_passes: u64,
    pub workers: usize,
    pub cache_capacity: usize,
}

impl ServeOutcome {
    /// The greppable stderr metrics line. `numerics_passes` is last on
    /// purpose — CI anchors `numerics_passes=K$` on it.
    pub fn metrics_line(&self) -> String {
        format!(
            "serve metrics: requests={} workers={} cache_capacity={} {} numerics_passes={}",
            self.responses.len(),
            self.workers,
            self.cache_capacity,
            self.stats.render(),
            self.numerics_passes,
        )
    }

    /// The serve-metrics-v1 artifact object (schema in
    /// `EXPERIMENTS/README.md`). `wall_ms` is host-measured; the
    /// derived `rps` is the sustained requests/sec of this drain.
    pub fn metrics_json(&self, wall_ms: f64) -> Json {
        let mut m = self.stats.json_fields();
        m.insert("schema".into(), Json::from("serve-metrics-v1"));
        m.insert("requests".into(), Json::from(self.responses.len()));
        m.insert("workers".into(), Json::from(self.workers));
        m.insert("cache_capacity".into(), Json::from(self.cache_capacity));
        m.insert("numerics_passes".into(), Json::from(self.numerics_passes as usize));
        m.insert("wall_ms".into(), Json::from(wall_ms));
        let rps = if wall_ms > 0.0 {
            self.responses.len() as f64 / (wall_ms / 1e3)
        } else {
            f64::NAN // renders as null
        };
        m.insert("rps".into(), Json::from(rps));
        Json::Obj(m)
    }
}

/// Serve one request through the shared cache.
fn serve_one(index: usize, req: &ServeRequest, cache: &ProgramCache) -> ServeResponse {
    let spec = req.spec();
    let socs = req.soc_configs();
    let out = match req.workload {
        // The synthetic builder keys the cache by generator params —
        // a hit never even materializes the weights.
        Workload::Resnet32 => CompressionJob::synthetic(req.seed)
            .spec(spec)
            .socs(&socs)
            .cached(cache)
            .run(),
        Workload::Tiny => {
            let layers = req.workload.layers(req.seed);
            CompressionJob::model(&layers).spec(spec).socs(&socs).cached(cache).run()
        }
        // Transformer inputs key the cache by spec (name, dims, seed)
        // and materialize lazily on a miss, like `synthetic`.
        Workload::TinyGpt | Workload::BertBase | Workload::Activations => {
            let mut backing = None;
            req.workload.job(req.seed, &mut backing).spec(spec).socs(&socs).cached(cache).run()
        }
    }
    .expect("serve requests carry no cancel token");
    ServeResponse {
        index,
        request: req.clone(),
        compression_ratio: out.outcome.compression_ratio,
        max_rel_err: out.outcome.max_rel_err,
        final_params: out.outcome.final_params,
        reports: out.reports,
    }
}

/// Drain `requests` with a fresh cache of `cfg.cache_capacity`.
pub fn serve(requests: &[ServeRequest], cfg: &ServeConfig) -> ServeOutcome {
    let cache = ProgramCache::new(cfg.cache_capacity);
    serve_with_cache(requests, cfg.workers, &cache)
}

/// Drain `requests` against a caller-owned (possibly pre-warmed)
/// cache. `workers <= 1` drains inline on the calling thread; more
/// workers steal requests off a shared cursor (the `pipeline` idiom)
/// and responses are re-sorted into request order.
pub fn serve_with_cache(
    requests: &[ServeRequest],
    workers: usize,
    cache: &ProgramCache,
) -> ServeOutcome {
    let capacity = cache.capacity();
    let workers = workers.max(1).min(requests.len().max(1));
    let (responses, numerics_passes) = if workers <= 1 {
        let before = numerics_pass_count();
        let responses: Vec<ServeResponse> = requests
            .iter()
            .enumerate()
            .map(|(i, req)| serve_one(i, req, cache))
            .collect();
        (responses, numerics_pass_count() - before)
    } else {
        let cursor = AtomicUsize::new(0);
        let passes = AtomicU64::new(0);
        let (tx, rx) = mpsc::channel::<ServeResponse>();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let tx = tx.clone();
                let cursor = &cursor;
                let passes = &passes;
                scope.spawn(move || {
                    // Fresh scope threads start at 0 passes, but take a
                    // baseline anyway in case a runtime reuses threads.
                    let before = numerics_pass_count();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= requests.len() {
                            break;
                        }
                        if tx.send(serve_one(i, &requests[i], cache)).is_err() {
                            break;
                        }
                    }
                    passes.fetch_add(numerics_pass_count() - before, Ordering::Relaxed);
                });
            }
        });
        drop(tx);
        let mut responses: Vec<ServeResponse> = rx.into_iter().collect();
        responses.sort_by_key(|r| r.index);
        (responses, passes.load(Ordering::Relaxed))
    };
    ServeOutcome {
        responses,
        stats: cache.stats(),
        numerics_passes,
        workers,
        cache_capacity: capacity,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_defaults_and_explicit_fields() {
        let req = parse_request(r#"{}"#).unwrap();
        assert_eq!(req, ServeRequest::default());
        let req = parse_request(
            r#"{"workload": "tiny", "seed": "7", "eps": 0.2, "rank_cap": 8, "socs": ["tt-edge"]}"#,
        )
        .unwrap();
        assert_eq!(req.workload, Workload::Tiny);
        assert_eq!(req.seed, 7);
        assert_eq!(req.eps, 0.2);
        assert_eq!(req.rank_cap, Some(8));
        assert_eq!(req.socs, vec!["tt-edge".to_string()]);
        assert_eq!(req.spec().cap_for(0), 8);
        // numeric seeds are accepted for hand-written files
        assert_eq!(parse_request(r#"{"seed": 9}"#).unwrap().seed, 9);
    }

    #[test]
    fn rejects_malformed_requests() {
        for (line, needle) in [
            (r#"[1]"#, "object"),
            (r#"{"epz": 0.1}"#, "unknown request key"),
            (r#"{"workload": "vgg"}"#, "bad workload"),
            (r#"{"eps": "big"}"#, "eps must be a number"),
            (r#"{"eps": -0.1}"#, ">= 0"),
            (r#"{"seed": -3}"#, "bad seed"),
            (r#"{"rank_cap": 0}"#, ">= 1"),
            (r#"{"rank_caps": []}"#, "must not be empty"),
            (r#"{"rank_cap": 2, "rank_caps": [2]}"#, "mutually exclusive"),
            (r#"{"method": "qr"}"#, "bad method"),
            (r#"{"method": 3}"#, "method must be a string"),
            (r#"{"socs": ["gpu"]}"#, "bad soc"),
            (r#"{"socs": []}"#, "must not be empty"),
            (r#"not json"#, "json error"),
        ] {
            let err = parse_request(line).unwrap_err();
            assert!(err.contains(needle), "line {line}: {err}");
        }
    }

    #[test]
    fn request_file_skips_blanks_and_names_bad_lines() {
        let text = "\n# warm-up batch\n{\"workload\": \"tiny\"}\n\n{\"eps\": 0.3}\n";
        let reqs = parse_requests(text).unwrap();
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].workload, Workload::Tiny);
        assert_eq!(reqs[1].eps, 0.3);
        let err = parse_requests("{\"workload\": \"tiny\"}\n{\"epz\": 1}\n").unwrap_err();
        assert!(err.contains("request line 2"), "{err}");
    }

    #[test]
    fn parses_transformer_rsvd_requests() {
        let req = parse_request(
            r#"{"workload": "tiny-gpt", "seed": "7", "method": "rsvd", "socs": ["systolic"]}"#,
        )
        .unwrap();
        assert_eq!(req.workload, Workload::TinyGpt);
        // the sketch is keyed by the request seed, not a fixed default
        assert_eq!(req.method, SvdMethod::Randomized { seed: 7, oversample: 8 });
        assert_eq!(req.spec().method(), req.method);
        assert_eq!(req.soc_configs()[0].name(), SocConfig::systolic().name());
        let echoed = parse_request(&req.to_json().render()).unwrap();
        assert_eq!(echoed, req);
    }

    #[test]
    fn request_echo_round_trips_through_the_parser() {
        let req = parse_request(
            r#"{"workload": "tiny", "seed": "7", "eps": 0.2, "rank_caps": [4, 6]}"#,
        )
        .unwrap();
        let echoed = parse_request(&req.to_json().render()).unwrap();
        assert_eq!(echoed, req);
    }

    #[test]
    fn empty_queue_drains_to_empty_outcome() {
        let out = serve(&[], &ServeConfig::default());
        assert!(out.responses.is_empty());
        assert_eq!(out.numerics_passes, 0);
        assert!(out.stats.conserved());
        assert!(out.metrics_line().contains("requests=0"));
        let j = out.metrics_json(0.0).render();
        assert!(j.contains("\"schema\":\"serve-metrics-v1\""), "{j}");
        assert!(j.contains("\"rps\":null"), "{j}");
    }
}
