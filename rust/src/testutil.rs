//! In-repo property-test harness (no `proptest` in the offline build).
//!
//! `check(n, seed, f)` runs `f` over `n` deterministic pseudo-random
//! cases and reports the failing case index + seed on panic, which is
//! what we actually use proptest for: randomized invariants with a
//! reproducible counterexample.

use crate::util::Rng;

/// Run `cases` randomized checks. On failure the panic message names
/// the case seed so the exact input can be replayed.
pub fn check<F: FnMut(&mut Rng)>(cases: usize, seed: u64, mut f: F) {
    for i in 0..cases {
        let case_seed = seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = r {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {i} (case_seed={case_seed:#x}): {msg}");
        }
    }
}

/// Max |a-b| over two slices (test helper).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Relative Frobenius error ||a-b|| / ||b||.
pub fn rel_err(a: &[f32], b: &[f32]) -> f32 {
    let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f32 = b.iter().map(|y| y * y).sum();
    (num / den.max(1e-30)).sqrt()
}

/// Assert near-equality with a labelled tolerance.
#[macro_export]
macro_rules! assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b, tol) = ($a as f64, $b as f64, $tol as f64);
        assert!(
            (a - b).abs() <= tol,
            "assert_close failed: {} vs {} (tol {})",
            a,
            b,
            tol
        );
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check(17, 1, |_| n += 1);
        assert_eq!(n, 17);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn check_reports_case() {
        check(5, 2, |rng| {
            let x = rng.uniform();
            assert!(x < 2.0); // always true...
            if rng.below(2) == 0 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn helpers() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert!(rel_err(&[1.0, 0.0], &[1.0, 0.0]) == 0.0);
        assert_close!(1.0, 1.0000001, 1e-5);
    }
}
