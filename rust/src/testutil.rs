//! In-repo property-test harness (no `proptest` in the offline build).
//!
//! `check(n, seed, f)` runs `f` over `n` deterministic pseudo-random
//! cases and reports the failing case index + seed on panic, which is
//! what we actually use proptest for: randomized invariants with a
//! reproducible counterexample.

use crate::ttd::tensor::{Matrix, Tensor};
use crate::util::Rng;

/// Random standard-normal matrix (the workhorse of every numeric
/// property test).
pub fn rand_matrix(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
    Matrix::from_vec(rows, cols, rng.normal_vec(rows * cols))
}

/// Random standard-normal tensor of the given shape.
pub fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
    Tensor::from_vec(shape, rng.normal_vec(shape.iter().product()))
}

/// Random tensor shape: `nd` dims, each uniform in `[lo, hi]`.
pub fn rand_shape(rng: &mut Rng, nd: usize, lo: usize, hi: usize) -> Vec<usize> {
    (0..nd).map(|_| lo + rng.below(hi - lo + 1)).collect()
}

/// A random tensor with *planted* TT ranks: the product of `nd` cores
/// with bond ranks drawn in `[1, rmax]`. Exact-recovery property tests
/// decompose these and must find ranks `<=` the planted ones.
pub fn rand_tt_tensor(rng: &mut Rng, shape: &[usize], rmax: usize) -> Tensor {
    use crate::ttd::ttd::{TtCore, TtDecomp};
    let nd = shape.len();
    let mut ranks = vec![1usize];
    for _ in 1..nd {
        ranks.push(1 + rng.below(rmax));
    }
    ranks.push(1);
    let cores: Vec<TtCore> = (0..nd)
        .map(|k| {
            let (r_in, n, r_out) = (ranks[k], shape[k], ranks[k + 1]);
            let scale = 1.0 / (r_in as f32).sqrt();
            let data = rng
                .normal_vec(r_in * n * r_out)
                .into_iter()
                .map(|v| v * scale)
                .collect();
            TtCore { r_in, n, r_out, data }
        })
        .collect();
    let d = TtDecomp { dims: shape.to_vec(), ranks, cores, eps: 0.0 };
    crate::ttd::reconstruct(&d)
}

/// Relative Frobenius distance `||a - b||_F / ||b||_F` over tensors.
pub fn rel_frobenius(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.shape, b.shape, "shape mismatch");
    rel_err(&a.data, &b.data)
}

/// Run `cases` randomized checks. On failure the panic message names
/// the case seed so the exact input can be replayed.
pub fn check<F: FnMut(&mut Rng)>(cases: usize, seed: u64, mut f: F) {
    for i in 0..cases {
        let case_seed = seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = r {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {i} (case_seed={case_seed:#x}): {msg}");
        }
    }
}

/// Max |a-b| over two slices (test helper).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Relative Frobenius error ||a-b|| / ||b||.
pub fn rel_err(a: &[f32], b: &[f32]) -> f32 {
    let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f32 = b.iter().map(|y| y * y).sum();
    (num / den.max(1e-30)).sqrt()
}

/// Assert near-equality with a labelled tolerance.
#[macro_export]
macro_rules! assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b, tol) = ($a as f64, $b as f64, $tol as f64);
        assert!(
            (a - b).abs() <= tol,
            "assert_close failed: {} vs {} (tol {})",
            a,
            b,
            tol
        );
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check(17, 1, |_| n += 1);
        assert_eq!(n, 17);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn check_reports_case() {
        check(5, 2, |rng| {
            let x = rng.uniform();
            assert!(x < 2.0); // always true...
            if rng.below(2) == 0 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn helpers() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert!(rel_err(&[1.0, 0.0], &[1.0, 0.0]) == 0.0);
        assert_close!(1.0, 1.0000001, 1e-5);
    }

    #[test]
    fn random_generators_have_declared_shapes() {
        let mut rng = Rng::new(3);
        let m = rand_matrix(&mut rng, 4, 6);
        assert_eq!((m.rows, m.cols), (4, 6));
        let shape = rand_shape(&mut rng, 3, 2, 5);
        assert_eq!(shape.len(), 3);
        assert!(shape.iter().all(|&d| (2..=5).contains(&d)));
        let t = rand_tensor(&mut rng, &shape);
        assert_eq!(t.shape, shape);
        assert_eq!(rel_frobenius(&t, &t), 0.0);
    }

    #[test]
    fn planted_tt_tensor_is_low_rank() {
        use crate::trace::NullSink;
        let mut rng = Rng::new(4);
        let t = rand_tt_tensor(&mut rng, &[5, 6, 7], 2);
        // near-exact TTD recovery at tiny eps with ranks <= planted
        let d = crate::ttd::decompose(&t, &crate::ttd::TtSpec::eps(1e-3), &mut NullSink);
        assert!(d.ranks[1] <= 5 && d.ranks[2] <= 7);
        assert!(rel_frobenius(&crate::ttd::reconstruct(&d), &t) < 1e-3);
    }
}
