//! Minimal benchmarking harness for the `harness = false` bench
//! binaries (criterion is unavailable offline). Warmup + repeated
//! timed runs with mean / stddev / min reporting.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub stddev_ms: f64,
    pub min_ms: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.3} ms/iter (+/- {:>8.3}, min {:>10.3}, n={})",
            self.name, self.mean_ms, self.stddev_ms, self.min_ms, self.iters
        )
    }
}

/// Time `f` over `iters` iterations after `warmup` runs.
pub fn time_it<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        stddev_ms: var.sqrt(),
        min_ms: min,
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_produces_sane_stats() {
        let r = time_it("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_ms >= 0.0);
        assert!(r.min_ms <= r.mean_ms + 1e-9);
        assert!(r.report().contains("spin"));
    }
}
