//! Cache observability counters (the `serve` metrics surface).
//!
//! [`CacheStats`] is a plain snapshot — the [`crate::cache`] module
//! owns the live counters under its lock and hands out copies, so a
//! reader can never observe a torn update. Two conservation laws hold
//! at every quiescent point and are pinned by `tests/program_cache.rs`:
//!
//! * `hits + misses == lookups`
//! * `inserts - evictions == resident`
//!
//! (Replacing an existing entry counts as an insert *plus* an eviction
//! of the entry it displaced, which is what keeps the second law exact.)

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Counter snapshot for one [`crate::cache::ProgramCache`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Keyed probes: every `lookup`/`claim` call counts exactly once
    /// (a claim that waits out another worker's in-flight miss still
    /// counts as a single lookup, resolved as a hit).
    pub lookups: u64,
    /// Lookups served from a resident program.
    pub hits: u64,
    /// Lookups that found nothing resident (the caller runs numerics).
    pub misses: u64,
    /// Programs stored (fulfilled misses + direct inserts; replacing
    /// an existing entry counts here too).
    pub inserts: u64,
    /// Programs removed — LRU pressure and replacement displacements.
    pub evictions: u64,
    /// Programs resident right now.
    pub resident: u64,
    /// Total RLE-encoded bytes of the resident programs.
    pub resident_bytes: u64,
}

impl CacheStats {
    /// Fraction of lookups served from cache (0 when nothing was
    /// looked up yet).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    /// Both conservation laws (see the module docs). Test hook — the
    /// cache upholds these by construction.
    pub fn conserved(&self) -> bool {
        self.hits + self.misses == self.lookups
            && self.inserts >= self.evictions
            && self.inserts - self.evictions == self.resident
    }

    /// The greppable `key=value` fragment used by the `serve` metrics
    /// line (stable field order; CI anchors regexes on it).
    pub fn render(&self) -> String {
        format!(
            "lookups={} hits={} misses={} inserts={} evictions={} resident={} resident_bytes={}",
            self.lookups,
            self.hits,
            self.misses,
            self.inserts,
            self.evictions,
            self.resident,
            self.resident_bytes,
        )
    }

    /// The same fields as flat JSON entries, ready to merge into a
    /// metrics artifact object (serve-metrics-v1).
    pub fn json_fields(&self) -> BTreeMap<String, Json> {
        let mut m = BTreeMap::new();
        m.insert("lookups".into(), Json::from(self.lookups as usize));
        m.insert("hits".into(), Json::from(self.hits as usize));
        m.insert("misses".into(), Json::from(self.misses as usize));
        m.insert("inserts".into(), Json::from(self.inserts as usize));
        m.insert("evictions".into(), Json::from(self.evictions as usize));
        m.insert("resident".into(), Json::from(self.resident as usize));
        m.insert("resident_bytes".into(), Json::from(self.resident_bytes as usize));
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_stats_are_conserved_and_rate_is_zero() {
        let s = CacheStats::default();
        assert!(s.conserved());
        assert_eq!(s.hit_rate(), 0.0);
    }

    #[test]
    fn render_and_json_agree_on_every_field() {
        let s = CacheStats {
            lookups: 10,
            hits: 7,
            misses: 3,
            inserts: 3,
            evictions: 1,
            resident: 2,
            resident_bytes: 4096,
        };
        assert!(s.conserved());
        assert!((s.hit_rate() - 0.7).abs() < 1e-12);
        let line = s.render();
        for frag in
            ["lookups=10", "hits=7", "misses=3", "inserts=3", "evictions=1", "resident=2"]
        {
            assert!(line.contains(frag), "{line}");
        }
        let j = s.json_fields();
        assert_eq!(j["hits"], Json::from(7usize));
        assert_eq!(j["resident_bytes"], Json::from(4096usize));
    }

    #[test]
    fn violated_laws_are_detected() {
        let s = CacheStats { lookups: 2, hits: 1, misses: 0, ..Default::default() };
        assert!(!s.conserved());
        let s = CacheStats { inserts: 1, evictions: 2, ..Default::default() };
        assert!(!s.conserved());
    }
}
