//! Table formatting, a minimal bench harness (no criterion offline),
//! and the cache observability counters the `serve` mode reports.

pub mod bench;
pub mod counters;

pub use counters::CacheStats;

/// Fixed-width text table builder (paper-style tables on stdout).
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&self.title);
            out.push('\n');
        }
        let line = |out: &mut String, cells: &[String]| {
            for (i, (c, w)) in cells.iter().zip(&widths).enumerate() {
                if i > 0 {
                    out.push_str(" | ");
                }
                out.push_str(&format!("{c:>w$}", w = w));
            }
            out.push('\n');
        };
        line(&mut out, &self.header);
        let total: usize = widths.iter().sum::<usize>() + 3 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }
}

/// `format!("{:.2}", v)` convenience for table cells.
pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("T", &["name", "value"]);
        t.row(&["a".into(), "1.00".into()]);
        t.row(&["longer".into(), "2.50".into()]);
        let s = t.render();
        assert!(s.contains("T\n"));
        assert!(s.contains("longer |  2.50"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["only one".into()]);
    }
}
