//! The hardware timeline: a [`TraceSink`] that costs every [`HwOp`]
//! under a [`SocConfig`], accumulating cycles per Table-III phase.
//!
//! The same operation stream (produced by the real Algorithm-1 run in
//! [`crate::ttd`]) is replayed under both configurations; the cycle
//! difference *is* the paper's speedup. Dispatch per op:
//!
//! | op            | Baseline                  | TT-Edge                      |
//! |---------------|---------------------------|------------------------------|
//! | HouseGen      | core scalar loops         | HBD-ACC PREPARE+HOUSE stages |
//! | VecDiv        | core FDIV loop            | HBD-ACC VEC-DIVISION         |
//! | Gemm          | accel, core descriptors   | accel, HW descriptors + SPM  |
//! | Sort/Reorder  | core loops                | SORTING module               |
//! | Trunc         | core loop                 | TRUNCATION FSM               |
//! | GivensRot     | core (both)               | core (both)                  |
//! | Reshape/Scalar| core (both)               | core (both)                  |

use crate::sim::config::{Backend, SocConfig};
use crate::sim::{core_model, gemm, systolic, ttd_engine};
use crate::trace::{HwOp, Phase, TraceSink};

/// Per-phase cycle accumulator.
#[derive(Clone, Debug, Default)]
pub struct PhaseCycles {
    pub hbd: u64,
    pub qr: u64,
    pub sort_trunc: u64,
    pub update_svd: u64,
    pub reshape: u64,
}

impl PhaseCycles {
    pub fn get(&self, p: Phase) -> u64 {
        match p {
            Phase::Hbd => self.hbd,
            Phase::QrDiag => self.qr,
            Phase::SortTrunc => self.sort_trunc,
            Phase::UpdateSvdInput => self.update_svd,
            Phase::ReshapeEtc => self.reshape,
        }
    }

    fn add(&mut self, p: Phase, cycles: u64) {
        match p {
            Phase::Hbd => self.hbd += cycles,
            Phase::QrDiag => self.qr += cycles,
            Phase::SortTrunc => self.sort_trunc += cycles,
            Phase::UpdateSvdInput => self.update_svd += cycles,
            Phase::ReshapeEtc => self.reshape += cycles,
        }
    }

    pub fn total(&self) -> u64 {
        self.hbd + self.qr + self.sort_trunc + self.update_svd + self.reshape
    }

    /// Fold another accumulator in (u64 adds: merging per-layer
    /// summaries in any grouping is bit-identical to one long stream).
    pub fn absorb(&mut self, other: &PhaseCycles) {
        self.hbd += other.hbd;
        self.qr += other.qr;
        self.sort_trunc += other.sort_trunc;
        self.update_svd += other.update_svd;
        self.reshape += other.reshape;
    }
}

/// Simple op statistics (introspection for benches / DESIGN.md).
#[derive(Clone, Debug, Default)]
pub struct OpStats {
    pub house_gens: u64,
    pub gemms: u64,
    pub gemm_tiles: u64,
    pub givens_rots: u64,
    pub sort_compares: u64,
    pub trunc_probes: u64,
    pub reshape_elems: u64,
}

impl OpStats {
    /// Fold another stat block in (all counters are additive).
    pub fn absorb(&mut self, other: &OpStats) {
        self.house_gens += other.house_gens;
        self.gemms += other.gemms;
        self.gemm_tiles += other.gemm_tiles;
        self.givens_rots += other.givens_rots;
        self.sort_compares += other.sort_compares;
        self.trunc_probes += other.trunc_probes;
        self.reshape_elems += other.reshape_elems;
    }
}

/// The timeline sink.
#[derive(Clone, Debug)]
pub struct HwTimeline {
    pub config: SocConfig,
    pub cycles: PhaseCycles,
    pub stats: OpStats,
    phase: Phase,
}

impl HwTimeline {
    pub fn new(config: SocConfig) -> Self {
        Self {
            config,
            cycles: PhaseCycles::default(),
            stats: OpStats::default(),
            phase: Phase::ReshapeEtc,
        }
    }

    pub fn current_phase(&self) -> Phase {
        self.phase
    }

    /// Fold another timeline's accumulated cycles and stats into this
    /// one. This is the deterministic per-layer merge: because every
    /// layer's op stream re-asserts its phase (`SetPhase`) before its
    /// first costed op, summing independently-folded layer timelines
    /// in layer order is bit-identical to streaming the concatenated
    /// trace through one timeline (all accumulators are u64). The
    /// phase register is left untouched.
    pub fn absorb(&mut self, other: &HwTimeline) {
        self.cycles.absorb(&other.cycles);
        self.stats.absorb(&other.stats);
    }

    /// Cycles for one op under this config in the current phase — pure
    /// (stat bookkeeping lives in [`HwTimeline::note`]), so a run of
    /// `count` identical ops costs `count * cost(op)`, bit-identical
    /// to `count` repeated u64 adds.
    fn cost(&self, op: &HwOp) -> u64 {
        let c = &self.config.cost;
        let f = &self.config.features;
        match *op {
            HwOp::SetPhase(_) => 0,
            HwOp::HouseGen { len } => {
                if f.hbd_acc {
                    ttd_engine::hbd_acc::house_gen(c, len as u64)
                } else {
                    core_model::house_gen(c, len as u64)
                }
            }
            HwOp::VecDiv { len } => {
                if f.hbd_acc {
                    ttd_engine::hbd_acc::vec_division(c, len as u64)
                } else {
                    core_model::vec_div(c, len as u64)
                }
            }
            HwOp::Gemm { m, n, k } => {
                if self.phase == Phase::UpdateSvdInput {
                    // Sigma_t V_t^T is a core-managed scale loop in both
                    // designs (Table III's Update-SVD rows are equal).
                    (m * n) as u64 * c.core_update_elem
                } else {
                    match self.config.backend {
                        Backend::TtEdgeGemm => {
                            gemm::gemm_cycles(c, f, m as u64, n as u64, k as u64)
                        }
                        Backend::Systolic => {
                            systolic::gemm_cycles(c, f, m as u64, n as u64, k as u64)
                        }
                    }
                }
            }
            HwOp::DataMove { bytes } => bytes as u64 / c.dram_bytes_per_cycle + c.dma_setup,
            HwOp::Sort { n, swaps: _ } => {
                if f.hw_sort_trunc {
                    ttd_engine::sorting::sort(c, n as u64)
                } else {
                    core_model::sort(c, n as u64)
                }
            }
            HwOp::ReorderBasis { rows, cols } => {
                let elems = (rows * cols) as u64;
                if f.hw_sort_trunc {
                    ttd_engine::sorting::reorder(c, elems)
                } else {
                    core_model::reorder(c, elems)
                }
            }
            HwOp::Trunc { probes, veclen: _ } => {
                if f.hw_sort_trunc {
                    ttd_engine::truncation::trunc(c, probes as u64)
                } else {
                    core_model::trunc(c, probes as u64)
                }
            }
            HwOp::GivensRot { len } => core_model::givens(c, len as u64),
            HwOp::CoreScalar { ops } => core_model::scalar(c, ops as u64),
            HwOp::Reshape { elems } => core_model::reshape(c, elems as u64),
        }
    }

    /// Record `times` occurrences of `op` in the op statistics. All
    /// counters are additive, so scaling by `times` equals `times`
    /// individual bumps exactly.
    fn note(&mut self, op: &HwOp, times: u64) {
        match *op {
            HwOp::HouseGen { .. } => self.stats.house_gens += times,
            HwOp::Gemm { m, n, k } => {
                self.stats.gemms += times;
                self.stats.gemm_tiles += times
                    * gemm::tiles(self.config.cost.gemm_tile, m as u64, n as u64, k as u64);
            }
            HwOp::Sort { n, swaps: _ } => {
                let n = n as u64;
                self.stats.sort_compares += times * (n * n.saturating_sub(1) / 2);
            }
            HwOp::Trunc { probes, .. } => self.stats.trunc_probes += times * probes as u64,
            HwOp::GivensRot { .. } => self.stats.givens_rots += times,
            HwOp::Reshape { elems } => self.stats.reshape_elems += times * elems as u64,
            _ => {}
        }
    }

    /// Fold a run of `count` identical ops in O(1): cost once,
    /// accumulate `count * cycles`. Since u64 multiplication is exact
    /// repeated addition, this is bit-identical (cycles and stats) to
    /// streaming the ops one by one — the fast half of the
    /// [`crate::trace::OpProgram`] replay seam.
    pub fn fold_run(&mut self, op: HwOp, count: u64) {
        if let HwOp::SetPhase(p) = op {
            self.phase = p;
            return;
        }
        if count == 0 {
            return;
        }
        self.note(&op, count);
        let cycles = self.cost(&op);
        self.cycles.add(self.phase, cycles * count);
    }
}

impl TraceSink for HwTimeline {
    fn op(&mut self, op: HwOp) {
        if let HwOp::SetPhase(p) = op {
            self.phase = p;
            return;
        }
        self.note(&op, 1);
        let cycles = self.cost(&op);
        self.cycles.add(self.phase, cycles);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::SocConfig;
    use crate::trace::TraceSink;

    #[test]
    fn phase_attribution() {
        let mut t = HwTimeline::new(SocConfig::baseline());
        t.op(HwOp::SetPhase(Phase::Hbd));
        t.op(HwOp::HouseGen { len: 100 });
        t.op(HwOp::SetPhase(Phase::QrDiag));
        t.op(HwOp::GivensRot { len: 10 });
        assert!(t.cycles.hbd > 0);
        assert!(t.cycles.qr > 0);
        assert_eq!(t.cycles.sort_trunc, 0);
        assert_eq!(t.cycles.total(), t.cycles.hbd + t.cycles.qr);
    }

    #[test]
    fn tt_edge_is_never_slower_on_offloaded_ops() {
        for op in [
            HwOp::HouseGen { len: 500 },
            HwOp::VecDiv { len: 500 },
            HwOp::Gemm { m: 64, n: 64, k: 64 },
            HwOp::Sort { n: 64, swaps: 100 },
            HwOp::Trunc { probes: 20, veclen: 64 },
            HwOp::ReorderBasis { rows: 64, cols: 64 },
        ] {
            let mut b = HwTimeline::new(SocConfig::baseline());
            let mut t = HwTimeline::new(SocConfig::tt_edge());
            b.op(HwOp::SetPhase(Phase::Hbd));
            t.op(HwOp::SetPhase(Phase::Hbd));
            b.op(op);
            t.op(op);
            assert!(
                t.cycles.total() <= b.cycles.total(),
                "{op:?}: tte {} vs base {}",
                t.cycles.total(),
                b.cycles.total()
            );
        }
    }

    #[test]
    fn shared_ops_cost_identically() {
        // QR, reshape, update-SVD scalar work are core-resident in both.
        for op in [
            HwOp::GivensRot { len: 64 },
            HwOp::Reshape { elems: 1000 },
            HwOp::CoreScalar { ops: 12 },
        ] {
            let mut b = HwTimeline::new(SocConfig::baseline());
            let mut t = HwTimeline::new(SocConfig::tt_edge());
            b.op(op);
            t.op(op);
            assert_eq!(b.cycles.total(), t.cycles.total(), "{op:?}");
        }
    }

    #[test]
    fn stats_accumulate() {
        let mut t = HwTimeline::new(SocConfig::tt_edge());
        t.op(HwOp::Gemm { m: 32, n: 32, k: 32 });
        t.op(HwOp::Gemm { m: 16, n: 16, k: 16 });
        assert_eq!(t.stats.gemms, 2);
        assert_eq!(t.stats.gemm_tiles, 8 + 1);
    }

    #[test]
    fn systolic_backend_reprices_only_gemm_ops() {
        let tile = SocConfig::tt_edge();
        let sys = crate::sim::config::SocConfig::systolic();
        // Non-GEMM ops and the core-managed Update-SVD scale loop are
        // backend-invariant...
        for (phase, op) in [
            (Phase::Hbd, HwOp::HouseGen { len: 500 }),
            (Phase::SortTrunc, HwOp::Sort { n: 64, swaps: 100 }),
            (Phase::QrDiag, HwOp::GivensRot { len: 64 }),
            (Phase::UpdateSvdInput, HwOp::Gemm { m: 64, n: 64, k: 1 }),
        ] {
            let mut a = HwTimeline::new(tile.clone());
            let mut b = HwTimeline::new(sys.clone());
            a.op(HwOp::SetPhase(phase));
            b.op(HwOp::SetPhase(phase));
            a.op(op);
            b.op(op);
            assert_eq!(a.cycles.total(), b.cycles.total(), "{op:?}");
        }
        // ...while an HBD GEMM is priced by the selected backend.
        let mut a = HwTimeline::new(tile);
        let mut b = HwTimeline::new(sys);
        a.op(HwOp::SetPhase(Phase::Hbd));
        b.op(HwOp::SetPhase(Phase::Hbd));
        a.op(HwOp::Gemm { m: 64, n: 64, k: 576 });
        b.op(HwOp::Gemm { m: 64, n: 64, k: 576 });
        assert_ne!(a.cycles.total(), b.cycles.total());
    }

    #[test]
    fn fold_run_is_bit_identical_to_repeated_ops() {
        for config in [SocConfig::baseline(), SocConfig::tt_edge()] {
            let runs = [
                (HwOp::SetPhase(Phase::Hbd), 1u64),
                (HwOp::HouseGen { len: 100 }, 7),
                (HwOp::Gemm { m: 33, n: 17, k: 65 }, 5),
                (HwOp::SetPhase(Phase::SortTrunc), 1),
                (HwOp::Sort { n: 16, swaps: 5 }, 3),
                (HwOp::Trunc { probes: 4, veclen: 16 }, 2),
                (HwOp::SetPhase(Phase::QrDiag), 1),
                (HwOp::GivensRot { len: 68 }, 11),
                (HwOp::Reshape { elems: 123 }, 4),
            ];
            let mut folded = HwTimeline::new(config.clone());
            let mut streamed = HwTimeline::new(config);
            for (op, count) in runs {
                folded.fold_run(op, count);
                for _ in 0..count {
                    streamed.op(op);
                }
            }
            for p in Phase::ALL {
                assert_eq!(folded.cycles.get(p), streamed.cycles.get(p), "{p:?}");
            }
            assert_eq!(folded.stats.gemms, streamed.stats.gemms);
            assert_eq!(folded.stats.gemm_tiles, streamed.stats.gemm_tiles);
            assert_eq!(folded.stats.sort_compares, streamed.stats.sort_compares);
            assert_eq!(folded.stats.trunc_probes, streamed.stats.trunc_probes);
            assert_eq!(folded.stats.givens_rots, streamed.stats.givens_rots);
            assert_eq!(folded.stats.reshape_elems, streamed.stats.reshape_elems);
            assert_eq!(folded.current_phase(), streamed.current_phase());
        }
    }
}
