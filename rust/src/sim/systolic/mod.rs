//! Group-vector systolic GEMM backend (ISSUE 9, after arXiv
//! 2501.19135's Group Vector Systolic Accelerator).
//!
//! The PE array is organized as `lanes` vector lanes x `groups` PE
//! groups instead of the blockwise square tile of
//! [`crate::sim::gemm`]. A matmul (m x k)@(k x n) is executed as
//! *waves*: each wave maps a `lanes`-row band of A against a
//! `groups`-column band of B, streaming the shared dimension `k`
//! through the skewed array. A wave therefore costs `k` steady-state
//! beats plus the systolic fill/drain skew of `lanes + groups - 1`
//! beats — the signature difference from the tile model, which has no
//! skew but pays a descriptor per k-tile.
//!
//! Everything is priced from the *same* [`CostModel`] constants as the
//! tile backend (no new `hw_model` rows — the resource totals are
//! test-pinned): the array reuses the `gemm_pes` PE budget, the
//! control path reuses the descriptor/link constants per wave, and
//! data traffic reuses the DRAM/AXI/DMA constants. Power likewise
//! reuses the GEMM-accelerator block — the backend is a cycle-shape
//! knob, not a new die. The backend is selected per
//! [`crate::sim::config::SocConfig::backend`]; both paper anchors keep
//! the default tile backend, so this module is cost-neutral for every
//! calibrated pin by construction.

use crate::sim::config::{CostModel, Features};

/// Vector-lane count: one lane per row of the paper's PE tile edge, so
/// the array consumes the same PE budget as the tile backend.
pub fn lanes(c: &CostModel) -> u64 {
    c.gemm_tile.max(1)
}

/// PE groups: the remaining PE budget split across column groups.
pub fn groups(c: &CostModel) -> u64 {
    (c.gemm_pes / lanes(c)).max(1)
}

/// Wave count for an (m x k)@(k x n) matmul: one wave per
/// `lanes`-row x `groups`-column output band. `k` streams within a
/// wave, so unlike the tile model there is no k-loop of descriptors.
pub fn waves(c: &CostModel, m: u64, n: u64) -> u64 {
    m.div_ceil(lanes(c)) * n.div_ceil(groups(c))
}

/// Cycles for one GEMM on the group-vector systolic array.
pub fn gemm_cycles(c: &CostModel, f: &Features, m: u64, n: u64, k: u64) -> u64 {
    let w = waves(c, m, n);
    let skew = lanes(c) + groups(c) - 1;
    // Compute: per wave, k steady-state beats + fill/drain skew.
    let compute = w * (k.max(1) + skew);
    // Control: one descriptor per wave (vs per tile op in the
    // blockwise model — the systolic array's main control win).
    let ctrl = if f.direct_gemm_link {
        w * (c.desc_hw + c.link_per_tile)
    } else {
        w * (c.desc_core + c.apb_per_tile)
    };
    // Data: each wave streams a lanes x k A-band and writes a
    // lanes x groups output band; the k x groups B-band is SPM-cached
    // across the row-band sweep when it fits, re-streamed otherwise.
    let a_bytes = lanes(c) * k * 4;
    let out_bytes = lanes(c) * groups(c) * 4;
    let mut dram_bytes = w * (a_bytes + out_bytes);
    let b_band_bytes = k * groups(c) * 4;
    if b_band_bytes > c.spm_bytes() {
        dram_bytes += w * b_band_bytes;
    }
    let data = dram_bytes / c.dram_bytes_per_cycle + w * c.axi_per_tile + c.dma_setup;
    ctrl + data + compute
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::gemm;

    #[test]
    fn default_geometry_reuses_the_pe_budget() {
        let c = CostModel::default();
        assert_eq!(lanes(&c), 16);
        assert_eq!(groups(&c), 4);
        assert_eq!(lanes(&c) * groups(&c), c.gemm_pes);
        assert_eq!(waves(&c, 64, 64), 4 * 16);
    }

    #[test]
    fn wave_cost_scales_with_k_not_k_tiles() {
        // Doubling k adds exactly w*k beats of compute + the extra
        // A-band traffic: no new descriptors (the tile model would
        // double its descriptor count).
        let c = CostModel::default();
        let f = Features::ALL_ON;
        let short = gemm_cycles(&c, &f, 16, 4, 64);
        let long = gemm_cycles(&c, &f, 16, 4, 128);
        assert_eq!(long - short, 64 + (16 * 64 * 4) / c.dram_bytes_per_cycle);
    }

    #[test]
    fn systolic_beats_tiles_on_deep_k_baselines() {
        // On the baseline control path (core descriptors), a deep-k
        // GEMM has ceil(k/16) descriptors per output tile in the
        // blockwise model but one per output band here.
        let c = CostModel::default();
        let f = Features::ALL_OFF;
        assert!(
            gemm_cycles(&c, &f, 64, 64, 4096) < gemm::gemm_cycles(&c, &f, 64, 64, 4096)
        );
    }

    #[test]
    fn skew_makes_tiny_gemms_relatively_expensive() {
        // Fill/drain cannot be amortized on a 1-beat GEMM: the wave
        // still pays the full lanes+groups-1 skew.
        let c = CostModel::default();
        let f = Features::ALL_ON;
        let one = gemm_cycles(&c, &f, 1, 1, 1);
        assert!(one >= lanes(&c) + groups(&c), "skew floor: {one}");
    }

    #[test]
    fn deterministic_and_feature_sensitive() {
        let c = CostModel::default();
        for (m, n, k) in [(9, 4096, 4096), (576, 64, 1), (64, 64, 64)] {
            assert_eq!(
                gemm_cycles(&c, &Features::ALL_ON, m, n, k),
                gemm_cycles(&c, &Features::ALL_ON, m, n, k)
            );
            assert!(
                gemm_cycles(&c, &Features::ALL_ON, m, n, k)
                    < gemm_cycles(&c, &Features::ALL_OFF, m, n, k),
                "direct link must help {m}x{n}x{k}"
            );
        }
    }
}
