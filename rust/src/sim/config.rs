//! SoC configurations and the cycle-cost model.
//!
//! Two microarchitectures (paper section II-B / III):
//!
//! * **Baseline** — Rocket core + 64-PE GEMM accelerator (16x16 tiles,
//!   320 KB SPM, APB control / AXI data) + DMA + DDR3. The core runs
//!   every non-GEMM TTD step and computes/issues every blockwise-GEMM
//!   tile descriptor over APB.
//! * **TT-Edge** — adds the TTD-Engine: HBD-ACC (4-stage pipeline),
//!   SORTING and TRUNCATION modules, one Shared FP-ALU, directly wired
//!   to the GEMM unit and its SPM.
//!
//! [`Features`] exposes each TT-Edge mechanism independently for the
//! ablation bench (`rust/benches/ablation_features.rs`).
//!
//! Cost constants are microarchitecturally motivated (comments give
//! the derivation) and calibrated against Table III; see
//! EXPERIMENTS.md for calibrated-vs-paper numbers.

/// Which processor is being simulated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Baseline,
    TtEdge,
}

/// Individually toggleable TT-Edge mechanisms (all true = the paper's
/// TT-Edge; all false = the baseline datapath with the engine present
/// but unused).
#[derive(Clone, Copy, Debug)]
pub struct Features {
    /// HBD-ACC executes HOUSE / VEC-DIVISION (else: core scalar FPU).
    pub hbd_acc: bool,
    /// Tile descriptors generated in hardware and sent over the direct
    /// TTD-Engine <-> GEMM link (else: core computes them, APB writes).
    pub direct_gemm_link: bool,
    /// Householder vectors stay in the SPM between the two chained
    /// GEMMs (else: DRAM round-trip per use).
    pub spm_retention: bool,
    /// SORTING / TRUNCATION modules (else: core loops).
    pub hw_sort_trunc: bool,
    /// Core clock-gated during HBD + Sort/Trunc (power only).
    pub clock_gating: bool,
}

impl Features {
    pub const ALL_ON: Features = Features {
        hbd_acc: true,
        direct_gemm_link: true,
        spm_retention: true,
        hw_sort_trunc: true,
        clock_gating: true,
    };
    pub const ALL_OFF: Features = Features {
        hbd_acc: false,
        direct_gemm_link: false,
        spm_retention: false,
        hw_sort_trunc: false,
        clock_gating: false,
    };
}

/// Cycle costs @ 100 MHz. Comments: derivation / calibration role.
#[derive(Clone, Debug)]
pub struct CostModel {
    // ---- Rocket core (in-order, scalar FPU) ----
    /// One load+FMA+loop-overhead step of a scalar dot/norm loop.
    pub core_fp_mac: u64,
    /// Scalar FP divide (Rocket FDIV latency + issue).
    pub core_fp_div: u64,
    /// Scalar FP sqrt.
    pub core_fp_sqrt: u64,
    /// Vector element update (load, op, store).
    pub core_vec_elem: u64,
    /// One bubble-sort compare (+ conditional swap) through the cache.
    pub core_sort_compare: u64,
    /// Move one basis element during reorder (load + store + index).
    pub core_reorder_elem: u64,
    /// One delta-truncation probe (MAC + SQRT + compare on the core).
    pub core_trunc_probe: u64,
    /// One element of a Givens rotation (4 mul + 2 add, scalar).
    pub core_givens_elem: u64,
    /// One element of a reshape/copy (address arith + load + store).
    pub core_reshape_elem: u64,
    /// Generic scalar bookkeeping op.
    pub core_scalar_op: u64,

    // ---- GEMM accelerator (16x16 PE-tile, 64 PEs) ----
    /// Compute cycles per 16x16x16 tile (4096 MACs / 64 PEs).
    pub tile_compute: u64,
    /// Core-side work per tile: descriptor computation (addresses,
    /// dims, layout — paper bottleneck #2) PLUS per-tile DMA
    /// programming and completion polling. ~100 scalar instructions +
    /// MMIO writes + poll loop on the in-order core.
    pub desc_core: u64,
    /// APB writes per tile descriptor (regs x bus cycles).
    pub apb_per_tile: u64,
    /// Descriptor generation on the HBD-ACC address calculator.
    pub desc_hw: u64,
    /// Direct-link transfer per descriptor.
    pub link_per_tile: u64,
    /// DRAM bandwidth, bytes/cycle (DDR3 x16, small-burst efficiency
    /// at the 100 MHz core clock).
    pub dram_bytes_per_cycle: u64,
    /// AXI burst setup/arbitration per tile transfer.
    pub axi_per_tile: u64,
    /// SPM bandwidth, bytes/cycle.
    pub spm_bytes_per_cycle: u64,
    /// DMA setup overhead per burst.
    pub dma_setup: u64,
    /// `Sigma_t V_t^T` scale loop, cycles per element (core-managed in
    /// BOTH designs — Table III shows identical Update-SVD rows).
    pub core_update_elem: u64,

    // ---- TTD-Engine (shared FP-ALU, SORTING, TRUNCATION) ----
    /// FP-ALU streamer: elements per cycle = 1 (norm MAC stream).
    pub fpalu_stream_per_elem: u64,
    /// FP-ALU DIV cycles per element (not fully pipelined).
    pub fpalu_div_per_elem: u64,
    /// FP-ALU SQRT latency.
    pub fpalu_sqrt: u64,
    /// Pipeline fill / opcode issue per FP-ALU vector op.
    pub fpalu_setup: u64,
    /// SORTING module: cycles per compare-and-store.
    pub sort_compare_hw: u64,
    /// SORTING module: cycles per reordered element (SPM to SPM).
    pub reorder_elem_hw: u64,
    /// TRUNCATION FSM: cycles per tail probe.
    pub trunc_probe_hw: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            // Rocket scalar loops: ld + fmadd + addi + bne ~ 4 insts,
            // no dual issue, FPU latency partially hidden -> ~8 cyc.
            core_fp_mac: 8,
            core_fp_div: 33,
            core_fp_sqrt: 40,
            core_vec_elem: 6,
            // ld, ld, fle, branch, (fsw, fsw), index update, loop.
            core_sort_compare: 28,
            // strided gather/scatter through the cache per basis elem.
            core_reorder_elem: 36,
            core_trunc_probe: 60,
            // 4 mul + 2 add + ld/st pairs, scalar FPU, some overlap.
            core_givens_elem: 12,
            // address arithmetic + ld + st per element.
            core_reshape_elem: 8,
            core_scalar_op: 10,
            core_update_elem: 13,

            // 16^3 MACs / 64 PEs = 64 compute cycles per tile.
            tile_compute: 64,
            // descriptor math + DMA MMIO programming + completion poll
            // (the paper's bottleneck #2; calibrated vs Table III HBD).
            desc_core: 466,
            // 6 control regs x 8-cycle APB write.
            apb_per_tile: 48,
            desc_hw: 2,
            link_per_tile: 4,
            // DDR3 x16, 16x16-tile bursts: ~400 MB/s effective.
            dram_bytes_per_cycle: 4,
            axi_per_tile: 48,
            spm_bytes_per_cycle: 16,
            dma_setup: 24,

            fpalu_stream_per_elem: 1,
            fpalu_div_per_elem: 4,
            fpalu_sqrt: 15,
            fpalu_setup: 8,
            // the SORTING module round-trips the *shared* FP-ALU per
            // compare (paper section III-B), so a pair costs issue +
            // compare + SPM writeback — not a parallel sort network.
            sort_compare_hw: 20,
            // SPM-to-SPM move (read + write + index) per element.
            reorder_elem_hw: 3,
            trunc_probe_hw: 20,
        }
    }
}

/// A simulated SoC: variant + feature set + costs + clock.
#[derive(Clone, Debug)]
pub struct SocConfig {
    pub variant: Variant,
    pub features: Features,
    pub cost: CostModel,
    pub freq_mhz: f64,
}

impl SocConfig {
    /// The paper's baseline processor.
    pub fn baseline() -> Self {
        SocConfig {
            variant: Variant::Baseline,
            features: Features::ALL_OFF,
            cost: CostModel::default(),
            freq_mhz: 100.0,
        }
    }

    /// The paper's TT-Edge processor (all mechanisms on).
    pub fn tt_edge() -> Self {
        SocConfig {
            variant: Variant::TtEdge,
            features: Features::ALL_ON,
            cost: CostModel::default(),
            freq_mhz: 100.0,
        }
    }

    /// TT-Edge with a modified feature set (ablations).
    pub fn tt_edge_with(features: Features) -> Self {
        SocConfig { features, ..Self::tt_edge() }
    }

    pub fn name(&self) -> &'static str {
        match self.variant {
            Variant::Baseline => "Baseline",
            Variant::TtEdge => "TT-Edge",
        }
    }

    /// Cycles -> milliseconds at the configured clock.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.freq_mhz * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_configs() {
        let b = SocConfig::baseline();
        assert_eq!(b.variant, Variant::Baseline);
        assert!(!b.features.hbd_acc);
        let t = SocConfig::tt_edge();
        assert!(t.features.hbd_acc && t.features.clock_gating);
        assert_eq!(t.freq_mhz, 100.0);
    }

    #[test]
    fn cycles_to_ms_at_100mhz() {
        let c = SocConfig::baseline();
        assert!((c.cycles_to_ms(100_000) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tile_compute_is_macs_over_pes() {
        let c = CostModel::default();
        assert_eq!(c.tile_compute, 16 * 16 * 16 / 64);
    }
}
