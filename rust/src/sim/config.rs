//! SoC configurations and the cycle-cost model.
//!
//! Two microarchitectures (paper section II-B / III):
//!
//! * **Baseline** — Rocket core + 64-PE GEMM accelerator (16x16 tiles,
//!   320 KB SPM, APB control / AXI data) + DMA + DDR3. The core runs
//!   every non-GEMM TTD step and computes/issues every blockwise-GEMM
//!   tile descriptor over APB.
//! * **TT-Edge** — adds the TTD-Engine: HBD-ACC (4-stage pipeline),
//!   SORTING and TRUNCATION modules, one Shared FP-ALU, directly wired
//!   to the GEMM unit and its SPM.
//!
//! [`Features`] exposes each TT-Edge mechanism independently for the
//! ablation bench (`rust/benches/ablation_features.rs`).
//!
//! Cost constants are microarchitecturally motivated (comments give
//! the derivation) and calibrated against Table III; see
//! EXPERIMENTS.md for calibrated-vs-paper numbers.

/// Which processor is being simulated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Baseline,
    TtEdge,
}

/// Individually toggleable TT-Edge mechanisms (all true = the paper's
/// TT-Edge; all false = the baseline datapath with the engine present
/// but unused).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Features {
    /// HBD-ACC executes HOUSE / VEC-DIVISION (else: core scalar FPU).
    pub hbd_acc: bool,
    /// Tile descriptors generated in hardware and sent over the direct
    /// TTD-Engine <-> GEMM link (else: core computes them, APB writes).
    pub direct_gemm_link: bool,
    /// Householder vectors stay in the SPM between the two chained
    /// GEMMs (else: DRAM round-trip per use).
    pub spm_retention: bool,
    /// SORTING / TRUNCATION modules (else: core loops).
    pub hw_sort_trunc: bool,
    /// Core clock-gated during HBD + Sort/Trunc (power only).
    pub clock_gating: bool,
}

impl Features {
    pub const ALL_ON: Features = Features {
        hbd_acc: true,
        direct_gemm_link: true,
        spm_retention: true,
        hw_sort_trunc: true,
        clock_gating: true,
    };
    pub const ALL_OFF: Features = Features {
        hbd_acc: false,
        direct_gemm_link: false,
        spm_retention: false,
        hw_sort_trunc: false,
        clock_gating: false,
    };

    /// Number of independent toggles (the DSE bitmask width).
    pub const COUNT: usize = 5;

    /// Short names in bit order (bit 0 = `hbd_acc`, ... bit 4 =
    /// `clock_gating`) — the design-space enumeration and candidate
    /// labels in [`crate::dse`] index these.
    pub const SHORT_NAMES: [&'static str; Features::COUNT] =
        ["hbd", "link", "spm", "sort", "gate"];

    /// Decode a 5-bit mask (bit order per [`Features::SHORT_NAMES`]).
    /// Bits above [`Features::COUNT`] are ignored, so
    /// `from_mask(m)` for `m in 0..32` enumerates the whole space.
    pub fn from_mask(mask: u8) -> Features {
        Features {
            hbd_acc: mask & 1 != 0,
            direct_gemm_link: mask & 2 != 0,
            spm_retention: mask & 4 != 0,
            hw_sort_trunc: mask & 8 != 0,
            clock_gating: mask & 16 != 0,
        }
    }

    /// Inverse of [`Features::from_mask`].
    pub fn mask(&self) -> u8 {
        (self.hbd_acc as u8)
            | (self.direct_gemm_link as u8) << 1
            | (self.spm_retention as u8) << 2
            | (self.hw_sort_trunc as u8) << 3
            | (self.clock_gating as u8) << 4
    }

    /// Does this feature set instantiate the TTD-Engine datapath (and
    /// therefore the shared FP-ALU)?
    pub fn uses_engine(&self) -> bool {
        self.hbd_acc || self.hw_sort_trunc
    }

    /// Compact label: `"base"`, `"all"`, or enabled short names joined
    /// with `+` (e.g. `"hbd+sort"`).
    pub fn label(&self) -> String {
        match self.mask() {
            0 => "base".to_string(),
            0x1F => "all".to_string(),
            m => {
                let names: Vec<&str> = Features::SHORT_NAMES
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| m & (1 << i) != 0)
                    .map(|(_, n)| *n)
                    .collect();
                names.join("+")
            }
        }
    }
}

/// Which GEMM accelerator model prices `HwOp::Gemm` work (ISSUE 9).
///
/// The backend is a *costing* knob, never a numerics knob: the op
/// stream is identical under every backend, only the cycle model that
/// folds it differs. The two paper anchors keep the default backend,
/// so Table-III pins and golden traces are untouched by construction.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Backend {
    /// The paper's blockwise 16x16-tile GEMM accelerator
    /// ([`crate::sim::gemm`]).
    #[default]
    TtEdgeGemm,
    /// Group-vector systolic array (arXiv 2501.19135): vector lanes x
    /// PE groups with skewed fill/drain ([`crate::sim::systolic`]).
    Systolic,
}

impl Backend {
    pub const ALL: [Backend; 2] = [Backend::TtEdgeGemm, Backend::Systolic];

    pub fn label(&self) -> &'static str {
        match self {
            Backend::TtEdgeGemm => "tt-edge-gemm",
            Backend::Systolic => "systolic",
        }
    }

    /// Parse a wire/CLI name (`"tt-edge-gemm"` | `"systolic"`).
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "tt-edge-gemm" => Some(Backend::TtEdgeGemm),
            "systolic" => Some(Backend::Systolic),
            _ => None,
        }
    }
}

/// When the Rocket core's clock gate closes while the TTD-Engine owns
/// the work — a power-only policy knob ([`crate::dse`] sweeps it).
/// Gating only ever takes effect when [`Features::clock_gating`] is
/// enabled; the policy narrows *which* engine-owned phases gate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum GatingPolicy {
    /// Gate during both phases the engine fully owns (HBD and
    /// Sort & Trunc) — the paper's policy.
    #[default]
    EngineOwned,
    /// Gate only during HBD (conservative: avoids the wake latency on
    /// the short Sort & Trunc bursts).
    HbdOnly,
    /// Gate only during Sort & Trunc.
    SortTruncOnly,
}

impl GatingPolicy {
    pub const ALL: [GatingPolicy; 3] =
        [GatingPolicy::EngineOwned, GatingPolicy::HbdOnly, GatingPolicy::SortTruncOnly];

    /// Is `phase` gated under this policy (assuming the clock-gating
    /// feature itself is enabled)?
    pub fn covers(&self, phase: crate::trace::Phase) -> bool {
        use crate::trace::Phase;
        match self {
            GatingPolicy::EngineOwned => matches!(phase, Phase::Hbd | Phase::SortTrunc),
            GatingPolicy::HbdOnly => phase == Phase::Hbd,
            GatingPolicy::SortTruncOnly => phase == Phase::SortTrunc,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            GatingPolicy::EngineOwned => "engine-owned",
            GatingPolicy::HbdOnly => "hbd-only",
            GatingPolicy::SortTruncOnly => "sort-trunc-only",
        }
    }
}

/// Cycle costs @ 100 MHz. Comments: derivation / calibration role.
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    // ---- Rocket core (in-order, scalar FPU) ----
    /// One load+FMA+loop-overhead step of a scalar dot/norm loop.
    pub core_fp_mac: u64,
    /// Scalar FP divide (Rocket FDIV latency + issue).
    pub core_fp_div: u64,
    /// Scalar FP sqrt.
    pub core_fp_sqrt: u64,
    /// Vector element update (load, op, store).
    pub core_vec_elem: u64,
    /// One bubble-sort compare (+ conditional swap) through the cache.
    pub core_sort_compare: u64,
    /// Move one basis element during reorder (load + store + index).
    pub core_reorder_elem: u64,
    /// One delta-truncation probe (MAC + SQRT + compare on the core).
    pub core_trunc_probe: u64,
    /// One element of a Givens rotation (4 mul + 2 add, scalar).
    pub core_givens_elem: u64,
    /// One element of a reshape/copy (address arith + load + store).
    pub core_reshape_elem: u64,
    /// Generic scalar bookkeeping op.
    pub core_scalar_op: u64,

    // ---- GEMM accelerator (16x16 PE-tile, 64 PEs) ----
    /// Blockwise tile edge (the paper's accelerator uses 16x16 tiles).
    /// A DSE knob: changing it moves the control-overhead vs DRAM-
    /// traffic balance of every GEMM.
    pub gemm_tile: u64,
    /// Processing elements in the GEMM array (64 in the paper).
    /// Compute cycles per tile = tile^3 / PEs (see
    /// [`CostModel::tile_compute_cycles`]).
    pub gemm_pes: u64,
    /// Scratchpad capacity in KB (320 in the paper). Bounds what the
    /// SPM can retain: Householder vectors (SPM-retention feature) and
    /// the B-operand panel cached across a GEMM's k-loop.
    pub spm_kb: u64,
    /// Shared FP-ALU instances in the TTD-Engine (1 in the paper).
    /// Extra units raise streaming throughput of norm/divide/compare
    /// work — and cost area + power (see [`crate::dse`]'s proxy and
    /// `sim::power`).
    pub fpalu_units: u64,
    /// Core-side work per tile: descriptor computation (addresses,
    /// dims, layout — paper bottleneck #2) PLUS per-tile DMA
    /// programming and completion polling. ~100 scalar instructions +
    /// MMIO writes + poll loop on the in-order core.
    pub desc_core: u64,
    /// APB writes per tile descriptor (regs x bus cycles).
    pub apb_per_tile: u64,
    /// Descriptor generation on the HBD-ACC address calculator.
    pub desc_hw: u64,
    /// Direct-link transfer per descriptor.
    pub link_per_tile: u64,
    /// DRAM bandwidth, bytes/cycle (DDR3 x16, small-burst efficiency
    /// at the 100 MHz core clock).
    pub dram_bytes_per_cycle: u64,
    /// AXI burst setup/arbitration per tile transfer.
    pub axi_per_tile: u64,
    /// SPM bandwidth, bytes/cycle.
    pub spm_bytes_per_cycle: u64,
    /// DMA setup overhead per burst.
    pub dma_setup: u64,
    /// `Sigma_t V_t^T` scale loop, cycles per element (core-managed in
    /// BOTH designs — Table III shows identical Update-SVD rows).
    pub core_update_elem: u64,

    // ---- TTD-Engine (shared FP-ALU, SORTING, TRUNCATION) ----
    /// FP-ALU streamer: elements per cycle = 1 (norm MAC stream).
    pub fpalu_stream_per_elem: u64,
    /// FP-ALU DIV cycles per element (not fully pipelined).
    pub fpalu_div_per_elem: u64,
    /// FP-ALU SQRT latency.
    pub fpalu_sqrt: u64,
    /// Pipeline fill / opcode issue per FP-ALU vector op.
    pub fpalu_setup: u64,
    /// SORTING module: cycles per compare-and-store.
    pub sort_compare_hw: u64,
    /// SORTING module: cycles per reordered element (SPM to SPM).
    pub reorder_elem_hw: u64,
    /// TRUNCATION FSM: cycles per tail probe.
    pub trunc_probe_hw: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            // Rocket scalar loops: ld + fmadd + addi + bne ~ 4 insts,
            // no dual issue, FPU latency partially hidden -> ~8 cyc.
            core_fp_mac: 8,
            core_fp_div: 33,
            core_fp_sqrt: 40,
            core_vec_elem: 6,
            // ld, ld, fle, branch, (fsw, fsw), index update, loop.
            core_sort_compare: 28,
            // strided gather/scatter through the cache per basis elem.
            core_reorder_elem: 36,
            core_trunc_probe: 60,
            // 4 mul + 2 add + ld/st pairs, scalar FPU, some overlap.
            core_givens_elem: 12,
            // address arithmetic + ld + st per element.
            core_reshape_elem: 8,
            core_scalar_op: 10,
            core_update_elem: 13,

            // 16x16 tiles on 64 PEs: 16^3/64 = 64 compute cycles per
            // tile; 320 KB SPM; one shared FP-ALU (the paper's SoC).
            gemm_tile: 16,
            gemm_pes: 64,
            spm_kb: 320,
            fpalu_units: 1,
            // descriptor math + DMA MMIO programming + completion poll
            // (the paper's bottleneck #2; calibrated vs Table III HBD).
            desc_core: 466,
            // 6 control regs x 8-cycle APB write.
            apb_per_tile: 48,
            desc_hw: 2,
            link_per_tile: 4,
            // DDR3 x16, 16x16-tile bursts: ~400 MB/s effective.
            dram_bytes_per_cycle: 4,
            axi_per_tile: 48,
            spm_bytes_per_cycle: 16,
            dma_setup: 24,

            fpalu_stream_per_elem: 1,
            fpalu_div_per_elem: 4,
            fpalu_sqrt: 15,
            fpalu_setup: 8,
            // the SORTING module round-trips the *shared* FP-ALU per
            // compare (paper section III-B), so a pair costs issue +
            // compare + SPM writeback — not a parallel sort network.
            sort_compare_hw: 20,
            // SPM-to-SPM move (read + write + index) per element.
            reorder_elem_hw: 3,
            trunc_probe_hw: 20,
        }
    }
}

impl CostModel {
    /// Compute cycles for one `gemm_tile`^3 tile op through the PE
    /// array (tile^3 MACs spread over `gemm_pes` PEs).
    pub fn tile_compute_cycles(&self) -> u64 {
        (self.gemm_tile * self.gemm_tile * self.gemm_tile).div_ceil(self.gemm_pes.max(1))
    }

    /// SPM capacity in bytes.
    pub fn spm_bytes(&self) -> u64 {
        self.spm_kb * 1024
    }
}

/// A simulated SoC: variant + feature set + costs + clock + gating
/// policy.
#[derive(Clone, Debug, PartialEq)]
pub struct SocConfig {
    pub variant: Variant,
    pub features: Features,
    pub cost: CostModel,
    pub freq_mhz: f64,
    /// Which engine-owned phases the core clock-gate covers (only
    /// effective when `features.clock_gating` is set).
    pub gating: GatingPolicy,
    /// Which accelerator model prices GEMM work (cost-only knob; the
    /// default keeps both paper anchors bit-identical).
    pub backend: Backend,
}

impl SocConfig {
    /// The paper's baseline processor.
    pub fn baseline() -> Self {
        SocConfig {
            variant: Variant::Baseline,
            features: Features::ALL_OFF,
            cost: CostModel::default(),
            freq_mhz: 100.0,
            gating: GatingPolicy::EngineOwned,
            backend: Backend::TtEdgeGemm,
        }
    }

    /// The paper's TT-Edge processor (all mechanisms on).
    pub fn tt_edge() -> Self {
        SocConfig {
            variant: Variant::TtEdge,
            features: Features::ALL_ON,
            cost: CostModel::default(),
            freq_mhz: 100.0,
            gating: GatingPolicy::EngineOwned,
            backend: Backend::TtEdgeGemm,
        }
    }

    /// TT-Edge with a modified feature set (ablations).
    pub fn tt_edge_with(features: Features) -> Self {
        SocConfig { features, ..Self::tt_edge() }
    }

    /// TT-Edge with the group-vector systolic GEMM backend swapped in
    /// (`--soc systolic`).
    pub fn systolic() -> Self {
        SocConfig { backend: Backend::Systolic, ..Self::tt_edge() }
    }

    pub fn name(&self) -> &'static str {
        match (self.variant, self.backend) {
            (Variant::Baseline, Backend::TtEdgeGemm) => "Baseline",
            (Variant::TtEdge, Backend::TtEdgeGemm) => "TT-Edge",
            (Variant::Baseline, Backend::Systolic) => "Baseline/systolic",
            (Variant::TtEdge, Backend::Systolic) => "TT-Edge/systolic",
        }
    }

    /// Cycles -> milliseconds at the configured clock.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.freq_mhz * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_configs() {
        let b = SocConfig::baseline();
        assert_eq!(b.variant, Variant::Baseline);
        assert!(!b.features.hbd_acc);
        let t = SocConfig::tt_edge();
        assert!(t.features.hbd_acc && t.features.clock_gating);
        assert_eq!(t.freq_mhz, 100.0);
    }

    #[test]
    fn cycles_to_ms_at_100mhz() {
        let c = SocConfig::baseline();
        assert!((c.cycles_to_ms(100_000) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tile_compute_is_macs_over_pes() {
        let c = CostModel::default();
        assert_eq!(c.tile_compute_cycles(), 16 * 16 * 16 / 64);
        let mut wide = c.clone();
        wide.gemm_tile = 32;
        assert_eq!(wide.tile_compute_cycles(), 32 * 32 * 32 / 64);
        wide.gemm_pes = 256;
        assert_eq!(wide.tile_compute_cycles(), 32 * 32 * 32 / 256);
    }

    #[test]
    fn feature_mask_round_trips_all_32_combos() {
        for m in 0u8..32 {
            let f = Features::from_mask(m);
            assert_eq!(f.mask(), m);
        }
        assert_eq!(Features::ALL_ON.mask(), 0x1F);
        assert_eq!(Features::ALL_OFF.mask(), 0);
        assert_eq!(Features::from_mask(0x1F), Features::ALL_ON);
        assert_eq!(Features::ALL_OFF.label(), "base");
        assert_eq!(Features::ALL_ON.label(), "all");
        assert_eq!(Features::from_mask(0b01001).label(), "hbd+sort");
        assert!(Features::from_mask(0b01000).uses_engine());
        assert!(!Features::from_mask(0b10110).uses_engine());
    }

    #[test]
    fn gating_policy_covers_engine_phases() {
        use crate::trace::Phase;
        let eo = GatingPolicy::EngineOwned;
        assert!(eo.covers(Phase::Hbd) && eo.covers(Phase::SortTrunc));
        assert!(!eo.covers(Phase::QrDiag));
        assert!(GatingPolicy::HbdOnly.covers(Phase::Hbd));
        assert!(!GatingPolicy::HbdOnly.covers(Phase::SortTrunc));
        assert!(GatingPolicy::SortTruncOnly.covers(Phase::SortTrunc));
        assert!(!GatingPolicy::SortTruncOnly.covers(Phase::Hbd));
        assert_eq!(GatingPolicy::default(), GatingPolicy::EngineOwned);
        assert_eq!(SocConfig::tt_edge().gating, GatingPolicy::EngineOwned);
    }

    #[test]
    fn backend_defaults_keep_the_paper_anchors() {
        // Both anchors price GEMMs on the paper's accelerator; the
        // systolic preset differs ONLY in the backend knob.
        assert_eq!(Backend::default(), Backend::TtEdgeGemm);
        assert_eq!(SocConfig::baseline().backend, Backend::TtEdgeGemm);
        assert_eq!(SocConfig::tt_edge().backend, Backend::TtEdgeGemm);
        let s = SocConfig::systolic();
        assert_eq!(s.backend, Backend::Systolic);
        assert_eq!(SocConfig { backend: Backend::TtEdgeGemm, ..s }, SocConfig::tt_edge());
        assert_eq!(SocConfig::systolic().name(), "TT-Edge/systolic");
        assert_eq!(SocConfig::tt_edge().name(), "TT-Edge");
        for b in Backend::ALL {
            assert_eq!(Backend::parse(b.label()), Some(b));
        }
        assert_eq!(Backend::parse("warp"), None);
    }

    #[test]
    fn default_knobs_match_the_paper_soc() {
        let c = CostModel::default();
        assert_eq!((c.gemm_tile, c.gemm_pes, c.spm_kb, c.fpalu_units), (16, 64, 320, 1));
        assert_eq!(c.spm_bytes(), 320 * 1024);
    }
}
