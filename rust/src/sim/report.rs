//! Per-phase simulation reports — the Table III generator.

use std::collections::BTreeMap;

use crate::sim::config::SocConfig;
use crate::sim::power::PowerModel;
use crate::sim::timeline::HwTimeline;
use crate::trace::Phase;
use crate::util::json::Json;

/// One row of Table III (a TTD phase on one configuration).
#[derive(Clone, Debug)]
pub struct PhaseReport {
    pub phase: Phase,
    pub cycles: u64,
    pub time_ms: f64,
    pub energy_mj: f64,
    pub core_gated: bool,
}

/// A full Table-III column: all five phases + totals.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub config_name: String,
    pub phases: Vec<PhaseReport>,
    pub total_ms: f64,
    pub total_mj: f64,
}

impl SimReport {
    pub fn from_timeline(t: &HwTimeline) -> Self {
        let power = PowerModel::for_config(&t.config);
        let phases: Vec<PhaseReport> = Phase::ALL
            .iter()
            .map(|&p| {
                let cycles = t.cycles.get(p);
                let ms = t.config.cycles_to_ms(cycles);
                PhaseReport {
                    phase: p,
                    cycles,
                    time_ms: ms,
                    energy_mj: power.energy_mj(p, ms),
                    core_gated: power.gated(p),
                }
            })
            .collect();
        let total_ms = phases.iter().map(|p| p.time_ms).sum();
        let total_mj = phases.iter().map(|p| p.energy_mj).sum();
        SimReport { config_name: t.config.name().to_string(), phases, total_ms, total_mj }
    }

    pub fn phase(&self, p: Phase) -> &PhaseReport {
        self.phases.iter().find(|r| r.phase == p).unwrap()
    }

    /// Machine-readable report (the `--json` CLI surface): per-phase
    /// cycles/ms/mJ plus totals, mirroring the Table-III columns.
    pub fn to_json(&self) -> Json {
        let phases: Vec<Json> = self
            .phases
            .iter()
            .map(|p| {
                let mut m = BTreeMap::new();
                m.insert("phase".into(), Json::from(p.phase.label()));
                m.insert("cycles".into(), Json::from(p.cycles as f64));
                m.insert("time_ms".into(), Json::from(p.time_ms));
                m.insert("energy_mj".into(), Json::from(p.energy_mj));
                m.insert("core_gated".into(), Json::Bool(p.core_gated));
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("config".into(), Json::from(self.config_name.as_str()));
        m.insert("phases".into(), Json::Arr(phases));
        m.insert("total_ms".into(), Json::from(self.total_ms));
        m.insert("total_mj".into(), Json::from(self.total_mj));
        Json::Obj(m)
    }
}

/// Table III: the baseline/TT-Edge side-by-side, same layout as the
/// paper (T_exec ms and E mJ per phase; `*` = core clock-gated).
pub fn format_table3(base: &SimReport, tte: &SimReport) -> String {
    let mut s = String::new();
    s.push_str("TABLE III: Execution time and energy breakdown, TTD-based ResNet-32 compression\n");
    s.push_str(&format!(
        "{:<16} | {:>12} {:>10} | {:>12} {:>10}\n",
        "TTD procedure", "Base T(ms)", "E(mJ)", "TTE T(ms)", "E(mJ)"
    ));
    s.push_str(&"-".repeat(70));
    s.push('\n');
    for p in Phase::ALL {
        let b = base.phase(p);
        let t = tte.phase(p);
        s.push_str(&format!(
            "{:<16} | {:>12.2} {:>10.2} | {:>12.2} {:>9.2}{}\n",
            p.label(),
            b.time_ms,
            b.energy_mj,
            t.time_ms,
            t.energy_mj,
            if t.core_gated { "*" } else { " " }
        ));
    }
    s.push_str(&"-".repeat(70));
    s.push('\n');
    s.push_str(&format!(
        "{:<16} | {:>12.2} {:>10.2} | {:>12.2} {:>10.2}\n",
        "Total", base.total_ms, base.total_mj, tte.total_ms, tte.total_mj
    ));
    s.push_str(&format!(
        "Speedup: {:.2}x   Energy reduction: {:.1}%   (*core clock-gated)\n",
        base.total_ms / tte.total_ms,
        (1.0 - tte.total_mj / base.total_mj) * 100.0
    ));
    s
}

/// Paper targets for Table III (ms, mJ) used by calibration tests and
/// EXPERIMENTS.md comparisons.
pub mod paper {
    use crate::trace::Phase;

    pub const BASE: [(Phase, f64, f64); 5] = [
        (Phase::Hbd, 5626.42, 962.17),
        (Phase::QrDiag, 1554.66, 265.91),
        (Phase::SortTrunc, 312.56, 53.46),
        (Phase::UpdateSvdInput, 46.65, 8.15),
        (Phase::ReshapeEtc, 189.24, 32.37),
    ];
    pub const TTE: [(Phase, f64, f64); 5] = [
        (Phase::Hbd, 2743.80, 466.34),
        (Phase::QrDiag, 1554.66, 277.09),
        (Phase::SortTrunc, 31.37, 5.33),
        (Phase::UpdateSvdInput, 46.65, 8.49),
        (Phase::ReshapeEtc, 189.24, 33.73),
    ];
    pub const BASE_TOTAL: (f64, f64) = (7729.52, 1322.06);
    pub const TTE_TOTAL: (f64, f64) = (4566.71, 790.97);
    pub const SPEEDUP: f64 = 1.69;
    pub const ENERGY_REDUCTION_PCT: f64 = 40.2;
}

/// Create a [`SocConfig`]-driven timeline, used by benches/examples.
pub fn new_timeline(cfg: SocConfig) -> HwTimeline {
    HwTimeline::new(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::SocConfig;
    use crate::trace::{HwOp, TraceSink};

    fn tiny_report(cfg: SocConfig) -> SimReport {
        let mut t = HwTimeline::new(cfg);
        t.op(HwOp::SetPhase(Phase::Hbd));
        t.op(HwOp::HouseGen { len: 64 });
        t.op(HwOp::Gemm { m: 64, n: 64, k: 1 });
        t.op(HwOp::SetPhase(Phase::QrDiag));
        t.op(HwOp::GivensRot { len: 64 });
        t.op(HwOp::SetPhase(Phase::SortTrunc));
        t.op(HwOp::Sort { n: 16, swaps: 4 });
        SimReport::from_timeline(&t)
    }

    #[test]
    fn report_totals_are_sums() {
        let r = tiny_report(SocConfig::baseline());
        let ms: f64 = r.phases.iter().map(|p| p.time_ms).sum();
        assert!((r.total_ms - ms).abs() < 1e-12);
        assert!(r.total_mj > 0.0);
    }

    #[test]
    fn gating_flags_in_report() {
        let r = tiny_report(SocConfig::tt_edge());
        assert!(r.phase(Phase::Hbd).core_gated);
        assert!(!r.phase(Phase::QrDiag).core_gated);
    }

    #[test]
    fn table3_formatting_contains_rows() {
        let b = tiny_report(SocConfig::baseline());
        let t = tiny_report(SocConfig::tt_edge());
        let s = format_table3(&b, &t);
        assert!(s.contains("HBD"));
        assert!(s.contains("Sort. & Trunc."));
        assert!(s.contains("Speedup"));
    }

    #[test]
    fn json_report_round_trips_and_names_all_phases() {
        let r = tiny_report(SocConfig::tt_edge());
        let text = r.to_json().render();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.get("config").unwrap().as_str().unwrap(), r.config_name);
        let phases = parsed.get("phases").unwrap().as_arr().unwrap();
        assert_eq!(phases.len(), Phase::ALL.len());
        let total = parsed.get("total_ms").unwrap().as_f64().unwrap();
        assert!((total - r.total_ms).abs() < 1e-12);
    }

    #[test]
    fn paper_targets_self_consistent() {
        let sum: f64 = paper::BASE.iter().map(|(_, t, _)| t).sum();
        assert!((sum - paper::BASE_TOTAL.0).abs() < 0.1);
        let sum_e: f64 = paper::TTE.iter().map(|(_, _, e)| e).sum();
        assert!((sum_e - paper::TTE_TOTAL.1).abs() < 0.1);
        assert!((paper::BASE_TOTAL.0 / paper::TTE_TOTAL.0 - paper::SPEEDUP).abs() < 0.01);
    }
}
