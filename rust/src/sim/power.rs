//! Power & energy integration over the simulated timeline.
//!
//! The per-mode processor power comes from the Table-II model
//! ([`crate::hw_model`]):
//!
//! * Baseline: 171.04 mW in every phase (core always active).
//! * TT-Edge, core active: 178.23 mW (QR, Update-SVD, Reshape).
//! * TT-Edge, core clock-gated: 169.96 mW (HBD, Sort & Trunc — the
//!   phases the TTD-Engine fully owns).
//!
//! Energy per phase = time x mode power; the paper's own Table III is
//! consistent with exactly this model to <0.5% in every cell.
//!
//! Partial-feature TT-Edge configurations (ablations, DSE candidates)
//! are priced **feature-aware**, mirroring `dse::area_proxy_luts`'s
//! semantics: a disabled mechanism's Table-II block is absent, so it
//! burns no power — HBD-ACC + engine glue are present only when
//! `hbd_acc` or `direct_gemm_link` needs the block (hardware tile
//! descriptors are generated on the HBD-ACC address calculator, so
//! the link cannot exist without it), `hw_sort_trunc` off sheds
//! SORTING + TRUNCATION, `direct_gemm_link` off sheds the link
//! interface, and the shared FP-ALU exists only while a
//! compute-streaming module (`hbd_acc`/`hw_sort_trunc`) does.
//! Likewise the core gate only closes
//! over a phase the *engine actually executes* (HBD needs `hbd_acc`,
//! Sort & Trunc needs `hw_sort_trunc`): a core doing the work itself
//! cannot be gated. Both rules are no-ops for the paper's two anchor
//! SoCs, so every calibrated number is bit-identical.
//!
//! Two DSE knobs perturb the mode powers away from the paper's
//! defaults (and contribute *zero* delta at the defaults):
//!
//! * `CostModel::fpalu_units` — each FP-ALU beyond the paper's single
//!   shared unit adds one more Table-II FP-ALU block (2.23 mW) in
//!   every mode (engine-bearing TT-Edge only).
//! * `CostModel::spm_kb` — scratchpad capacity scales the on-chip
//!   SRAM power linearly around the 320 KB baseline (both variants
//!   carry the SPM).
//!
//! Which phases the clock gate covers is the [`GatingPolicy`] knob;
//! the paper's policy gates HBD and Sort & Trunc.

use crate::hw_model;
use crate::sim::config::{GatingPolicy, SocConfig, Variant};
use crate::trace::Phase;

/// Per-phase power modes for a configuration.
#[derive(Clone, Debug)]
pub struct PowerModel {
    pub active_mw: f64,
    pub gated_mw: f64,
    pub gating_enabled: bool,
    pub policy: GatingPolicy,
    pub variant: Variant,
    /// The engine executes HBD (else the core does, ungateable).
    pub engine_hbd: bool,
    /// The engine executes Sort & Trunc.
    pub engine_sort_trunc: bool,
}

/// Active power of one named Table-II block, mW (panics on unknown
/// names — see [`hw_model::block`]).
fn block_power_mw(name: &str) -> f64 {
    hw_model::block(name).power_mw
}

impl PowerModel {
    pub fn for_config(cfg: &SocConfig) -> Self {
        let s = hw_model::summarize();
        // Knob deltas (zero at the paper's default knobs).
        let spm_delta =
            (cfg.cost.spm_kb as f64 - 320.0) / 320.0 * block_power_mw("SRAM");
        let f = &cfg.features;
        match cfg.variant {
            Variant::Baseline => PowerModel {
                active_mw: s.baseline_power_mw + spm_delta,
                gated_mw: s.baseline_power_mw + spm_delta,
                gating_enabled: false,
                policy: cfg.gating,
                variant: cfg.variant,
                engine_hbd: false,
                engine_sort_trunc: false,
            },
            Variant::TtEdge => {
                // Disabled mechanisms shed their Table-II blocks
                // (zero for the ALL_ON anchor), matching the area
                // proxy's absent-block semantics.
                let mut absent = 0.0;
                // The HBD-ACC block hosts both the Householder
                // pipeline AND the hardware descriptor generator, so
                // the direct link keeps it instantiated.
                if !f.hbd_acc && !f.direct_gemm_link {
                    absent += block_power_mw("HBD-ACC")
                        + block_power_mw("TTD-Engine glue (unitemized)");
                }
                if !f.hw_sort_trunc {
                    absent += block_power_mw("SORTING") + block_power_mw("TRUNCATION");
                }
                if !f.direct_gemm_link {
                    absent += block_power_mw("DMA/SPM/GEMM IF + interconnect");
                }
                let alu_delta = if f.uses_engine() {
                    cfg.cost.fpalu_units.saturating_sub(1) as f64
                        * block_power_mw("FP-ALU")
                } else {
                    absent += block_power_mw("FP-ALU");
                    0.0
                };
                PowerModel {
                    active_mw: s.total_power_mw + spm_delta + alu_delta - absent,
                    gated_mw: s.gated_power_mw + spm_delta + alu_delta - absent,
                    gating_enabled: f.clock_gating,
                    policy: cfg.gating,
                    variant: cfg.variant,
                    engine_hbd: f.hbd_acc,
                    engine_sort_trunc: f.hw_sort_trunc,
                }
            }
        }
    }

    /// Is the core clock-gated during this phase? Requires the gating
    /// feature, a policy that covers the phase, and an engine module
    /// that actually owns the phase's work.
    pub fn gated(&self, phase: Phase) -> bool {
        let offloaded = match phase {
            Phase::Hbd => self.engine_hbd,
            Phase::SortTrunc => self.engine_sort_trunc,
            _ => false,
        };
        self.gating_enabled && offloaded && self.policy.covers(phase)
    }

    /// Processor power during `phase`, mW.
    pub fn power_mw(&self, phase: Phase) -> f64 {
        if self.gated(phase) {
            self.gated_mw
        } else {
            self.active_mw
        }
    }

    /// Energy for `ms` milliseconds spent in `phase`, in mJ.
    pub fn energy_mj(&self, phase: Phase, ms: f64) -> f64 {
        self.power_mw(phase) * ms / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::{Features, SocConfig};

    #[test]
    fn baseline_power_is_constant() {
        let p = PowerModel::for_config(&SocConfig::baseline());
        for ph in Phase::ALL {
            assert!((p.power_mw(ph) - 171.04).abs() < 0.4);
        }
    }

    #[test]
    fn tt_edge_gates_hbd_and_sort_trunc() {
        let p = PowerModel::for_config(&SocConfig::tt_edge());
        assert!((p.power_mw(Phase::Hbd) - 169.96).abs() < 0.2);
        assert!((p.power_mw(Phase::SortTrunc) - 169.96).abs() < 0.2);
        assert!((p.power_mw(Phase::QrDiag) - 178.23).abs() < 0.2);
        assert!((p.power_mw(Phase::ReshapeEtc) - 178.23).abs() < 0.2);
    }

    #[test]
    fn gating_can_be_ablated() {
        let mut f = Features::ALL_ON;
        f.clock_gating = false;
        let p = PowerModel::for_config(&SocConfig::tt_edge_with(f));
        assert!((p.power_mw(Phase::Hbd) - 178.23).abs() < 0.2);
    }

    #[test]
    fn energy_is_power_times_time() {
        let p = PowerModel::for_config(&SocConfig::baseline());
        let e = p.energy_mj(Phase::Hbd, 1000.0); // 1 s
        assert!((e - 171.04).abs() < 0.4);
    }

    #[test]
    fn gating_policy_narrows_the_gated_phases() {
        let mut cfg = SocConfig::tt_edge();
        cfg.gating = GatingPolicy::HbdOnly;
        let p = PowerModel::for_config(&cfg);
        assert!(p.gated(Phase::Hbd));
        assert!(!p.gated(Phase::SortTrunc));
        cfg.gating = GatingPolicy::SortTruncOnly;
        let p = PowerModel::for_config(&cfg);
        assert!(!p.gated(Phase::Hbd));
        assert!(p.gated(Phase::SortTrunc));
    }

    #[test]
    fn absent_feature_blocks_shed_their_power() {
        let full = PowerModel::for_config(&SocConfig::tt_edge());
        // one mechanism off: its block's power disappears
        let mut f = Features::ALL_ON;
        f.hw_sort_trunc = false;
        let p = PowerModel::for_config(&SocConfig::tt_edge_with(f));
        assert!((full.active_mw - p.active_mw - (0.49 + 0.78)).abs() < 1e-9);
        // engine-less TT-Edge variant converges to the baseline power
        let mut gate_only = Features::ALL_OFF;
        gate_only.clock_gating = true;
        let p = PowerModel::for_config(&SocConfig::tt_edge_with(gate_only));
        let base = PowerModel::for_config(&SocConfig::baseline());
        assert!((p.active_mw - base.active_mw).abs() < 1e-9);
        // the direct link keeps the HBD-ACC (descriptor generator)
        // powered even with hbd_acc off: link-only pays HBD-ACC +
        // glue + link IF over the engine-less floor
        let mut link_only = Features::ALL_OFF;
        link_only.direct_gemm_link = true;
        let p = PowerModel::for_config(&SocConfig::tt_edge_with(link_only));
        assert!((p.active_mw - base.active_mw - (1.42 + 0.84 + 1.43)).abs() < 1e-9);
    }

    #[test]
    fn gating_requires_the_engine_to_own_the_phase() {
        // clock gating on, but the core itself executes HBD and
        // Sort & Trunc: nothing may gate.
        let mut gate_only = Features::ALL_OFF;
        gate_only.clock_gating = true;
        let p = PowerModel::for_config(&SocConfig::tt_edge_with(gate_only));
        for ph in Phase::ALL {
            assert!(!p.gated(ph), "{ph:?}");
        }
        // hbd_acc alone + gating: only HBD gates
        let mut f = Features::ALL_OFF;
        f.hbd_acc = true;
        f.clock_gating = true;
        let p = PowerModel::for_config(&SocConfig::tt_edge_with(f));
        assert!(p.gated(Phase::Hbd));
        assert!(!p.gated(Phase::SortTrunc));
    }

    #[test]
    fn knob_deltas_are_zero_at_the_defaults_and_monotone() {
        let tte = PowerModel::for_config(&SocConfig::tt_edge());
        let mut more_alus = SocConfig::tt_edge();
        more_alus.cost.fpalu_units = 3;
        let p = PowerModel::for_config(&more_alus);
        assert!((p.active_mw - tte.active_mw - 2.0 * 2.23).abs() < 1e-9);
        assert!((p.gated_mw - tte.gated_mw - 2.0 * 2.23).abs() < 1e-9);
        let mut small_spm = SocConfig::tt_edge();
        small_spm.cost.spm_kb = 160;
        let p = PowerModel::for_config(&small_spm);
        assert!(p.active_mw < tte.active_mw);
        // baseline carries the SPM too
        let mut base_spm = SocConfig::baseline();
        base_spm.cost.spm_kb = 640;
        let base = PowerModel::for_config(&SocConfig::baseline());
        assert!(PowerModel::for_config(&base_spm).active_mw > base.active_mw);
        // ...but not the FP-ALU
        let mut base_alu = SocConfig::baseline();
        base_alu.cost.fpalu_units = 4;
        assert_eq!(PowerModel::for_config(&base_alu).active_mw, base.active_mw);
    }
}
