//! Power & energy integration over the simulated timeline.
//!
//! The per-mode processor power comes from the Table-II model
//! ([`crate::hw_model`]):
//!
//! * Baseline: 171.04 mW in every phase (core always active).
//! * TT-Edge, core active: 178.23 mW (QR, Update-SVD, Reshape).
//! * TT-Edge, core clock-gated: 169.96 mW (HBD, Sort & Trunc — the
//!   phases the TTD-Engine fully owns).
//!
//! Energy per phase = time x mode power; the paper's own Table III is
//! consistent with exactly this model to <0.5% in every cell.

use crate::hw_model;
use crate::sim::config::{SocConfig, Variant};
use crate::trace::Phase;

/// Per-phase power modes for a configuration.
#[derive(Clone, Debug)]
pub struct PowerModel {
    pub active_mw: f64,
    pub gated_mw: f64,
    pub gating_enabled: bool,
    pub variant: Variant,
}

impl PowerModel {
    pub fn for_config(cfg: &SocConfig) -> Self {
        let s = hw_model::summarize();
        match cfg.variant {
            Variant::Baseline => PowerModel {
                active_mw: s.baseline_power_mw,
                gated_mw: s.baseline_power_mw,
                gating_enabled: false,
                variant: cfg.variant,
            },
            Variant::TtEdge => PowerModel {
                active_mw: s.total_power_mw,
                gated_mw: s.gated_power_mw,
                gating_enabled: cfg.features.clock_gating,
                variant: cfg.variant,
            },
        }
    }

    /// Is the core clock-gated during this phase?
    pub fn gated(&self, phase: Phase) -> bool {
        self.gating_enabled
            && matches!(phase, Phase::Hbd | Phase::SortTrunc)
    }

    /// Processor power during `phase`, mW.
    pub fn power_mw(&self, phase: Phase) -> f64 {
        if self.gated(phase) {
            self.gated_mw
        } else {
            self.active_mw
        }
    }

    /// Energy for `ms` milliseconds spent in `phase`, in mJ.
    pub fn energy_mj(&self, phase: Phase, ms: f64) -> f64 {
        self.power_mw(phase) * ms / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::{Features, SocConfig};

    #[test]
    fn baseline_power_is_constant() {
        let p = PowerModel::for_config(&SocConfig::baseline());
        for ph in Phase::ALL {
            assert!((p.power_mw(ph) - 171.04).abs() < 0.4);
        }
    }

    #[test]
    fn tt_edge_gates_hbd_and_sort_trunc() {
        let p = PowerModel::for_config(&SocConfig::tt_edge());
        assert!((p.power_mw(Phase::Hbd) - 169.96).abs() < 0.2);
        assert!((p.power_mw(Phase::SortTrunc) - 169.96).abs() < 0.2);
        assert!((p.power_mw(Phase::QrDiag) - 178.23).abs() < 0.2);
        assert!((p.power_mw(Phase::ReshapeEtc) - 178.23).abs() < 0.2);
    }

    #[test]
    fn gating_can_be_ablated() {
        let mut f = Features::ALL_ON;
        f.clock_gating = false;
        let p = PowerModel::for_config(&SocConfig::tt_edge_with(f));
        assert!((p.power_mw(Phase::Hbd) - 178.23).abs() < 0.2);
    }

    #[test]
    fn energy_is_power_times_time() {
        let p = PowerModel::for_config(&SocConfig::baseline());
        let e = p.energy_mj(Phase::Hbd, 1000.0); // 1 s
        assert!((e - 171.04).abs() < 0.4);
    }
}
