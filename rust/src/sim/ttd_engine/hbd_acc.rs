//! HBD-ACC (Fig. 3): the four-stage Householder pipeline —
//! PREPARE (address calc + DMA request), HOUSE (norm + q on the shared
//! FP-ALU), VEC DIVISION (v/beta), REQUEST GEMM (two chained GEMMs on
//! the reused accelerator; costed in `sim::gemm`).

use crate::sim::config::CostModel;
use crate::sim::ttd_engine::fp_alu;

/// PREPARE: `a.addr = A.addr + i*(A.width+1) + order` — one MAC-class
/// address computation plus the DMA request for the vector (vector
/// lands in SPM; bandwidth-limited by DRAM).
pub fn prepare(c: &CostModel, len: u64) -> u64 {
    c.desc_hw + c.dma_setup + (len * 4) / c.dram_bytes_per_cycle
}

/// HOUSE stage: norm of v on the FP-ALU + q/v1 update (2 scalar ops).
pub fn house_stage(c: &CostModel, len: u64) -> u64 {
    fp_alu::norm(c, len) + fp_alu::scalar(c, 2)
}

/// Full HOUSE generation as the engine executes it.
pub fn house_gen(c: &CostModel, len: u64) -> u64 {
    prepare(c, len) + house_stage(c, len)
}

/// VEC DIVISION stage: beta = v1*q (1 scalar) + streamed divide.
pub fn vec_division(c: &CostModel, len: u64) -> u64 {
    fp_alu::scalar(c, 1) + fp_alu::vec_div(c, len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::core_model;

    #[test]
    fn engine_house_beats_core_house() {
        let c = CostModel::default();
        for len in [16u64, 64, 576, 4096] {
            assert!(
                house_gen(&c, len) < core_model::house_gen(&c, len),
                "len {len}"
            );
        }
    }

    #[test]
    fn engine_vecdiv_beats_core_vecdiv() {
        let c = CostModel::default();
        assert!(vec_division(&c, 512) < core_model::vec_div(&c, 512));
    }

    #[test]
    fn prepare_is_dma_bound_for_long_vectors() {
        let c = CostModel::default();
        let p = prepare(&c, 4096);
        assert!(p >= 4096 * 4 / c.dram_bytes_per_cycle);
    }
}
