//! SORTING module (Fig. 4a): bubble sort over the singular values in
//! the SPM (pairwise compares on the shared FP-ALU, results + index
//! vector written back), then basis reordering by the index vector
//! with SPM-to-SPM moves.

use crate::sim::config::CostModel;

/// Bubble sort of `n` values: n(n-1)/2 compare-and-store operations in
/// the hardware comparator pipeline. Compares round-trip the shared
/// FP-ALU (paper III-B), so extra `fpalu_units` interleave them.
pub fn sort(c: &CostModel, n: u64) -> u64 {
    (n * n.saturating_sub(1) / 2 * c.sort_compare_hw).div_ceil(c.fpalu_units.max(1))
}

/// Reorder U columns / V^T rows (`elems` total) via SPM moves.
pub fn reorder(c: &CostModel, elems: u64) -> u64 {
    elems * c.reorder_elem_hw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::core_model;

    #[test]
    fn hw_sort_not_slower_than_core() {
        // The SORTING module serializes compares through the *shared*
        // FP-ALU (paper III-B), so the sort itself is only modestly
        // faster; the Sort&Trunc speedup comes from basis reordering.
        let c = CostModel::default();
        let n = 64;
        assert!(sort(&c, n) <= core_model::sort(&c, n));
    }

    #[test]
    fn composite_sort_trunc_speedup_is_order_of_magnitude() {
        // Workload mix (from the ResNet-32 trace): reorder dominates.
        let c = CostModel::default();
        // ~31 reordered elements per compare, as in the real trace.
        let (n, elems) = (64u64, 62_000u64);
        let hw = sort(&c, n) + reorder(&c, elems);
        let core = core_model::sort(&c, n) + core_model::reorder(&c, elems);
        let ratio = core as f64 / hw as f64;
        assert!(ratio > 8.0, "ratio {ratio}");
    }

    #[test]
    fn reorder_streams_spm() {
        let c = CostModel::default();
        assert!(reorder(&c, 1000) < core_model::reorder(&c, 1000));
    }
}
