//! The TTD-Engine (Fig. 2): HBD-ACC, SORTING, TRUNCATION, and the
//! Shared FP-ALU they all serialize on. Each module exposes cycle
//! functions used by the timeline when the corresponding feature is
//! enabled; the module structure mirrors Figs. 3-5.

pub mod fp_alu;
pub mod hbd_acc;
pub mod sorting;
pub mod truncation;
