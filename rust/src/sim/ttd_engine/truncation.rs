//! TRUNCATION module (Fig. 4b): a lightweight FSM that forms the
//! error vector from the tail of the sorted singular values and checks
//! `||e||_2` against delta (SQRT/MUL/DIV on the shared FP-ALU),
//! decrementing the retained rank until the accuracy target holds.

use crate::sim::config::CostModel;

/// `probes` tail-norm tests of the FSM.
pub fn trunc(c: &CostModel, probes: u64) -> u64 {
    probes * c.trunc_probe_hw
}

/// One-time delta computation at TTD start: SQRT + MUL + DIV.
pub fn delta_setup(c: &CostModel) -> u64 {
    c.fpalu_sqrt + 2 * c.fpalu_setup
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::core_model;

    #[test]
    fn fsm_probe_beats_core_probe() {
        let c = CostModel::default();
        assert!(trunc(&c, 50) < core_model::trunc(&c, 50));
    }

    #[test]
    fn delta_setup_is_constant() {
        let c = CostModel::default();
        assert_eq!(delta_setup(&c), c.fpalu_sqrt + 2 * c.fpalu_setup);
    }
}
