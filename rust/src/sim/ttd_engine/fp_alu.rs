//! Shared FP-ALU (Fig. 5): a Vector Streamer feeding one FP core
//! (MAC / DIV / SQRT) from the SPM. All three TTD-Engine modules issue
//! their floating-point work here, so its busy time is a serializing
//! resource — the timeline adds these cycles sequentially, which is
//! exactly the paper's single-FPU sharing discipline.
//!
//! `CostModel::fpalu_units` is the DSE sharing knob: extra units split
//! the *streamed* element work (the Vector Streamer interleaves
//! lanes), while per-op issue overhead and the final SQRT stay
//! serialized. One unit (the paper's design) reproduces the original
//! costs exactly.

use crate::sim::config::CostModel;

#[inline]
fn units(c: &CostModel) -> u64 {
    c.fpalu_units.max(1)
}

/// Dedicated `norm` opcode: stream `len` elements (1/cycle MAC
/// accumulate per unit) + final SQRT + issue overhead.
pub fn norm(c: &CostModel, len: u64) -> u64 {
    c.fpalu_setup + (len * c.fpalu_stream_per_elem).div_ceil(units(c)) + c.fpalu_sqrt
}

/// Elementwise vector divide v/beta, streamed through the DIV units.
pub fn vec_div(c: &CostModel, len: u64) -> u64 {
    c.fpalu_setup + (len * c.fpalu_div_per_elem).div_ceil(units(c))
}

/// Single scalar ops (ADD/MUL/MAC/DIV/SQRT issued directly) — issue
/// is serialized regardless of unit count.
pub fn scalar(c: &CostModel, ops: u64) -> u64 {
    ops * c.fpalu_setup
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_streams_one_elem_per_cycle() {
        let c = CostModel::default();
        assert_eq!(norm(&c, 100) - norm(&c, 0), 100 * c.fpalu_stream_per_elem);
    }

    #[test]
    fn hw_norm_beats_core_norm() {
        let c = CostModel::default();
        let hw = norm(&c, 1000);
        let core = crate::sim::core_model::house_gen(&c, 1000);
        assert!(hw * 4 < core, "hw {hw} vs core {core}");
    }

    #[test]
    fn div_not_fully_pipelined() {
        let c = CostModel::default();
        assert!(vec_div(&c, 10) > norm(&c, 10) - c.fpalu_sqrt);
    }

    #[test]
    fn extra_units_split_only_the_streamed_work() {
        let one = CostModel::default();
        let two = CostModel { fpalu_units: 2, ..CostModel::default() };
        // streamed halves (up to the ceil), overheads unchanged
        assert_eq!(
            norm(&two, 1000),
            one.fpalu_setup + 500 * one.fpalu_stream_per_elem + one.fpalu_sqrt
        );
        assert_eq!(vec_div(&two, 1000), one.fpalu_setup + 2000);
        assert_eq!(scalar(&two, 5), scalar(&one, 5));
        // one unit reproduces the paper's costs exactly
        assert_eq!(norm(&one, 777), one.fpalu_setup + 777 + one.fpalu_sqrt);
    }
}
