//! Shared FP-ALU (Fig. 5): a Vector Streamer feeding one FP core
//! (MAC / DIV / SQRT) from the SPM. All three TTD-Engine modules issue
//! their floating-point work here, so its busy time is a serializing
//! resource — the timeline adds these cycles sequentially, which is
//! exactly the paper's single-FPU sharing discipline.

use crate::sim::config::CostModel;

/// Dedicated `norm` opcode: stream `len` elements (1/cycle MAC
/// accumulate) + final SQRT + issue overhead.
pub fn norm(c: &CostModel, len: u64) -> u64 {
    c.fpalu_setup + len * c.fpalu_stream_per_elem + c.fpalu_sqrt
}

/// Elementwise vector divide v/beta, streamed through the DIV unit.
pub fn vec_div(c: &CostModel, len: u64) -> u64 {
    c.fpalu_setup + len * c.fpalu_div_per_elem
}

/// Single scalar ops (ADD/MUL/MAC/DIV/SQRT issued directly).
pub fn scalar(c: &CostModel, ops: u64) -> u64 {
    ops * c.fpalu_setup
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_streams_one_elem_per_cycle() {
        let c = CostModel::default();
        assert_eq!(norm(&c, 100) - norm(&c, 0), 100 * c.fpalu_stream_per_elem);
    }

    #[test]
    fn hw_norm_beats_core_norm() {
        let c = CostModel::default();
        let hw = norm(&c, 1000);
        let core = crate::sim::core_model::house_gen(&c, 1000);
        assert!(hw * 4 < core, "hw {hw} vs core {core}");
    }

    #[test]
    fn div_not_fully_pipelined() {
        let c = CostModel::default();
        assert!(vec_div(&c, 10) > norm(&c, 10) - c.fpalu_sqrt);
    }
}
