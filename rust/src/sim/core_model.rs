//! Rocket-core cost functions — the baseline datapath for every
//! operation the GEMM accelerator cannot execute (paper bottleneck #1).

use crate::sim::config::CostModel;

/// HOUSE on the core: streamed norm (MAC loop) + SQRT + sign/pivot
/// update + writing v back (the vector lives in DRAM/cache).
pub fn house_gen(c: &CostModel, len: u64) -> u64 {
    len * c.core_fp_mac        // sum of squares
        + c.core_fp_sqrt       // ||x||
        + 4 * c.core_scalar_op // sign, q, v1 update
        + len * c.core_vec_elem // materialize v
}

/// v / beta on the core: one FP divide per element plus loop.
pub fn vec_div(c: &CostModel, len: u64) -> u64 {
    len * (c.core_fp_div + c.core_vec_elem)
}

/// One bubble-sort pass set over n values (n(n-1)/2 compares).
pub fn sort(c: &CostModel, n: u64) -> u64 {
    n * n.saturating_sub(1) / 2 * c.core_sort_compare
}

pub fn reorder(c: &CostModel, elems: u64) -> u64 {
    elems * c.core_reorder_elem
}

pub fn trunc(c: &CostModel, probes: u64) -> u64 {
    probes * c.core_trunc_probe
}

pub fn givens(c: &CostModel, len: u64) -> u64 {
    len * c.core_givens_elem
}

pub fn reshape(c: &CostModel, elems: u64) -> u64 {
    elems * c.core_reshape_elem
}

pub fn scalar(c: &CostModel, ops: u64) -> u64 {
    ops * c.core_scalar_op
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn house_gen_scales_linearly() {
        let c = CostModel::default();
        let a = house_gen(&c, 100);
        let b = house_gen(&c, 200);
        let fixed = c.core_fp_sqrt + 4 * c.core_scalar_op;
        assert_eq!(b - a, 100 * (c.core_fp_mac + c.core_vec_elem));
        assert_eq!(a, 100 * (c.core_fp_mac + c.core_vec_elem) + fixed);
    }

    #[test]
    fn sort_is_quadratic() {
        let c = CostModel::default();
        assert_eq!(sort(&c, 2), c.core_sort_compare);
        assert_eq!(sort(&c, 10), 45 * c.core_sort_compare);
        assert_eq!(sort(&c, 0), 0);
        assert_eq!(sort(&c, 1), 0);
    }

    #[test]
    fn unit_costs() {
        let c = CostModel::default();
        assert_eq!(trunc(&c, 3), 3 * c.core_trunc_probe);
        assert_eq!(reshape(&c, 7), 7 * c.core_reshape_elem);
        assert_eq!(givens(&c, 5), 5 * c.core_givens_elem);
    }
}
