//! Streaming cost sink: fold the hardware-op stream into per-phase
//! cycles/energy **online**, under any number of [`SocConfig`]s at
//! once, without ever materializing a `Vec<HwOp>`.
//!
//! This is the default consumer of the numerics' trace. Memory is
//! O(#configs x #phases) — constant in trace length — so simulate /
//! federate scale to arbitrarily large models. Per-layer sinks merge
//! deterministically in layer order via [`CostSink::absorb`]: all
//! accumulators are u64 cycle counts, so the merged totals are
//! bit-identical to streaming one concatenated trace (and therefore to
//! the legacy `VecSink`-then-replay path, pinned by the golden-trace
//! harness and `tests/sink_composition.rs`).

use crate::sim::config::SocConfig;
use crate::sim::report::SimReport;
use crate::sim::timeline::HwTimeline;
use crate::trace::{HwOp, TraceSink};

/// A bank of [`HwTimeline`]s, one per SoC configuration, fed by a
/// single op stream.
#[derive(Clone, Debug)]
pub struct CostSink {
    timelines: Vec<HwTimeline>,
}

impl CostSink {
    /// Cost the stream under every configuration in `configs`
    /// simultaneously (one pass over the numerics instead of one
    /// replay per config).
    pub fn new(configs: &[SocConfig]) -> Self {
        CostSink { timelines: configs.iter().map(|c| HwTimeline::new(c.clone())).collect() }
    }

    /// Single-configuration convenience.
    pub fn single(config: SocConfig) -> Self {
        CostSink { timelines: vec![HwTimeline::new(config)] }
    }

    /// Fold another sink (same config bank, e.g. one layer's private
    /// sink) into this one. Call in layer order for the deterministic
    /// merge; see [`HwTimeline::absorb`] for why the result is
    /// bit-identical to one long stream.
    pub fn absorb(&mut self, other: &CostSink) {
        assert_eq!(
            self.timelines.len(),
            other.timelines.len(),
            "CostSink::absorb: config banks differ"
        );
        for (mine, theirs) in self.timelines.iter_mut().zip(&other.timelines) {
            // hard assert: silently merging cycles costed under a
            // different SoC would corrupt every report downstream.
            // Compare the FULL config — variant labels alone cannot
            // distinguish the many TT-Edge candidates a DSE sweep
            // builds (same name, different features/knobs).
            assert!(
                mine.config == theirs.config,
                "CostSink::absorb: config banks differ"
            );
            mine.absorb(theirs);
        }
    }

    /// Replay a recorded [`OpProgram`] into every timeline, one run at
    /// a time ([`HwTimeline::fold_run`]): O(#runs) per config instead
    /// of O(#ops), and bit-identical — cycles, energy, per-phase banks
    /// and op stats — to streaming the live op sequence, because a run
    /// preserves order and `count * cost` equals `count` u64 adds.
    /// This is the replay-many half of the record-once costing seam.
    pub fn fold_program(&mut self, program: &crate::trace::OpProgram) {
        for tl in &mut self.timelines {
            for run in program.runs() {
                tl.fold_run(run.op, run.count);
            }
        }
    }

    /// [`CostSink::fold_program`] with the per-layer segments farmed
    /// out across `threads` scoped workers (work-stealing over the
    /// layer index, the same shape as the pipeline engine). Each
    /// worker folds whole segments into a private config bank; the
    /// banks are then [`CostSink::absorb`]ed in layer order —
    /// bit-identical to the serial fold because all accumulators are
    /// u64 and every segment re-asserts its phase before its first
    /// costed op ([`crate::trace::LayerProgram::is_self_phased`] —
    /// true for every Algorithm-1 stream). Falls back to the serial
    /// fold at width <= 1, for single-segment programs, and for
    /// foreign programs with any non-self-phased segment (where a
    /// fresh worker timeline could mis-attribute the segment head).
    ///
    /// One observable difference from the serial fold: the workers'
    /// phase registers die with their banks, so `self`'s phase
    /// register keeps its pre-call value instead of the program's
    /// final phase. Reports never read it; a caller streaming more
    /// ops into the same sink afterwards must re-assert phase (every
    /// real stream opens with `SetPhase` anyway).
    pub fn fold_program_parallel(&mut self, program: &crate::trace::OpProgram, threads: usize) {
        let layers = program.layers();
        let workers = threads.max(1).min(layers.len());
        if workers <= 1 || !layers.iter().all(|l| l.is_self_phased()) {
            self.fold_program(program);
            return;
        }
        let configs: Vec<SocConfig> =
            self.timelines.iter().map(|tl| tl.config.clone()).collect();
        let cursor = std::sync::atomic::AtomicUsize::new(0);
        let (tx, rx) = std::sync::mpsc::channel::<(usize, CostSink)>();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let tx = tx.clone();
                let cursor = &cursor;
                let configs = &configs;
                scope.spawn(move || loop {
                    let i = cursor.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    let Some(layer) = layers.get(i) else { break };
                    let mut bank = CostSink::new(configs);
                    for tl in &mut bank.timelines {
                        for run in layer.runs() {
                            tl.fold_run(run.op, run.count);
                        }
                    }
                    if tx.send((i, bank)).is_err() {
                        break;
                    }
                });
            }
        });
        drop(tx);
        let mut banks: Vec<(usize, CostSink)> = rx.into_iter().collect();
        banks.sort_by_key(|(i, _)| *i);
        for (_, bank) in &banks {
            self.absorb(bank);
        }
    }

    /// One [`SimReport`] per configuration, in constructor order.
    pub fn reports(&self) -> Vec<SimReport> {
        self.timelines.iter().map(SimReport::from_timeline).collect()
    }

    /// The underlying timelines (cycle/stat introspection).
    pub fn timelines(&self) -> &[HwTimeline] {
        &self.timelines
    }
}

impl TraceSink for CostSink {
    #[inline]
    fn op(&mut self, op: HwOp) {
        for tl in &mut self.timelines {
            tl.op(op);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Phase, VecSink};

    fn stream() -> Vec<HwOp> {
        vec![
            HwOp::SetPhase(Phase::Hbd),
            HwOp::HouseGen { len: 64 },
            HwOp::Gemm { m: 16, n: 16, k: 16 },
            HwOp::SetPhase(Phase::SortTrunc),
            HwOp::Sort { n: 16, swaps: 5 },
            HwOp::Trunc { probes: 4, veclen: 16 },
        ]
    }

    #[test]
    fn streaming_equals_replay_per_phase() {
        let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
        let mut cost = CostSink::new(&configs);
        let mut vec = VecSink::default();
        for op in stream() {
            cost.op(op);
            vec.op(op);
        }
        for (i, cfg) in configs.iter().enumerate() {
            let mut tl = HwTimeline::new(cfg.clone());
            vec.replay(&mut tl);
            for p in Phase::ALL {
                assert_eq!(cost.timelines()[i].cycles.get(p), tl.cycles.get(p), "{p:?}");
            }
        }
    }

    #[test]
    fn absorb_in_order_equals_one_stream() {
        let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
        // one long stream...
        let mut whole = CostSink::new(&configs);
        for op in stream() {
            whole.op(op);
        }
        for op in stream() {
            whole.op(op);
        }
        // ...vs two per-"layer" sinks merged in order
        let mut merged = CostSink::new(&configs);
        for _ in 0..2 {
            let mut part = CostSink::new(&configs);
            for op in stream() {
                part.op(op);
            }
            merged.absorb(&part);
        }
        for (a, b) in whole.timelines().iter().zip(merged.timelines()) {
            assert_eq!(a.cycles.total(), b.cycles.total());
            for p in Phase::ALL {
                assert_eq!(a.cycles.get(p), b.cycles.get(p));
            }
            assert_eq!(a.stats.gemms, b.stats.gemms);
            assert_eq!(a.stats.sort_compares, b.stats.sort_compares);
        }
        // and the f64 report layer is computed from identical u64s
        let ra = whole.reports();
        let rb = merged.reports();
        for (a, b) in ra.iter().zip(&rb) {
            assert_eq!(a.total_ms, b.total_ms);
            assert_eq!(a.total_mj, b.total_mj);
        }
    }

    #[test]
    fn reports_follow_constructor_order() {
        let mut cost = CostSink::new(&[SocConfig::baseline(), SocConfig::tt_edge()]);
        for op in stream() {
            cost.op(op);
        }
        let r = cost.reports();
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].config_name, SocConfig::baseline().name());
        assert_eq!(r[1].config_name, SocConfig::tt_edge().name());
        // offloaded phases cost less on TT-Edge
        assert!(r[1].total_ms < r[0].total_ms);
    }

    #[test]
    #[should_panic(expected = "config banks differ")]
    fn absorb_rejects_same_variant_different_knobs() {
        // Both banks are single TT-Edge configs (identical name()),
        // but with different knob values — merging them would sum
        // cycles costed under different models.
        let mut a = CostSink::single(SocConfig::tt_edge());
        let mut tweaked = SocConfig::tt_edge();
        tweaked.cost.gemm_tile = 32;
        let b = CostSink::single(tweaked);
        a.absorb(&b);
    }

    #[test]
    fn fold_program_equals_per_op_replay() {
        use crate::trace::{RecordingSink, TraceSink};
        let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
        let mut rec = RecordingSink::default();
        // repeated ops so RLE genuinely compacts
        for _ in 0..3 {
            for op in stream() {
                rec.op(op);
            }
            for _ in 0..5 {
                rec.op(HwOp::GivensRot { len: 20 });
            }
        }
        let mut program = crate::trace::OpProgram::default();
        program.push_layer(rec);
        assert!(program.run_count() < program.op_count() as usize);

        let mut live = CostSink::new(&configs);
        program.replay(&mut live);
        let mut folded = CostSink::new(&configs);
        folded.fold_program(&program);
        for (a, b) in live.timelines().iter().zip(folded.timelines()) {
            for p in Phase::ALL {
                assert_eq!(a.cycles.get(p), b.cycles.get(p), "{p:?}");
            }
            assert_eq!(a.stats.gemms, b.stats.gemms);
            assert_eq!(a.stats.sort_compares, b.stats.sort_compares);
            assert_eq!(a.stats.trunc_probes, b.stats.trunc_probes);
        }
        let ra = live.reports();
        let rb = folded.reports();
        for (a, b) in ra.iter().zip(&rb) {
            assert_eq!(a.total_ms, b.total_ms);
            assert_eq!(a.total_mj, b.total_mj);
        }
    }

    fn multi_layer_program(layers: usize) -> crate::trace::OpProgram {
        use crate::trace::RecordingSink;
        let mut program = crate::trace::OpProgram::default();
        for l in 0..layers {
            let mut rec = RecordingSink::default();
            for op in stream() {
                rec.op(op); // opens with SetPhase -> self-phased
            }
            for _ in 0..l {
                rec.op(HwOp::GivensRot { len: 20 + l });
            }
            program.push_layer(rec);
        }
        program
    }

    #[test]
    fn parallel_fold_is_bit_identical_to_serial_at_any_width() {
        let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
        let program = multi_layer_program(5);
        assert!(program.layers().iter().all(|l| l.is_self_phased()));
        let mut serial = CostSink::new(&configs);
        serial.fold_program(&program);
        for threads in [1, 2, 4, 8] {
            let mut par = CostSink::new(&configs);
            par.fold_program_parallel(&program, threads);
            for (a, b) in serial.timelines().iter().zip(par.timelines()) {
                for p in Phase::ALL {
                    assert_eq!(a.cycles.get(p), b.cycles.get(p), "{p:?} at width {threads}");
                }
                assert_eq!(a.stats.gemms, b.stats.gemms);
                assert_eq!(a.stats.sort_compares, b.stats.sort_compares);
                assert_eq!(a.stats.trunc_probes, b.stats.trunc_probes);
            }
            let ra = serial.reports();
            let rb = par.reports();
            for (a, b) in ra.iter().zip(&rb) {
                assert_eq!(a.total_ms, b.total_ms, "width {threads}");
                assert_eq!(a.total_mj, b.total_mj, "width {threads}");
            }
        }
    }

    #[test]
    fn parallel_fold_falls_back_on_unphased_segments() {
        use crate::trace::RecordingSink;
        // Layer 1 carries no SetPhase marker: its ops must inherit
        // layer 0's final phase, which only the serial fold can
        // attribute — fold_program_parallel must detect this and take
        // the fallback, staying bit-identical.
        let configs = [SocConfig::baseline(), SocConfig::tt_edge()];
        let mut program = crate::trace::OpProgram::default();
        let mut rec = RecordingSink::default();
        for op in stream() {
            rec.op(op);
        }
        program.push_layer(rec);
        let mut bare = RecordingSink::default();
        bare.op(HwOp::HouseGen { len: 32 });
        bare.op(HwOp::Gemm { m: 8, n: 8, k: 8 });
        program.push_layer(bare);
        assert!(!program.layers()[1].is_self_phased());

        let mut serial = CostSink::new(&configs);
        serial.fold_program(&program);
        let mut par = CostSink::new(&configs);
        par.fold_program_parallel(&program, 4);
        for (a, b) in serial.timelines().iter().zip(par.timelines()) {
            for p in Phase::ALL {
                assert_eq!(a.cycles.get(p), b.cycles.get(p), "{p:?}");
            }
        }
    }

    #[test]
    fn empty_bank_is_a_null_sink() {
        let mut cost = CostSink::new(&[]);
        for op in stream() {
            cost.op(op);
        }
        assert!(cost.reports().is_empty());
    }
}
