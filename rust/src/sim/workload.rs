//! The Table-III workload: TTD-based compression of ResNet-32 under a
//! simulated SoC.
//!
//! Trained CNN weights are TT-compressible (that is the phenomenon the
//! paper exploits: 3.4x at 92.09% accuracy); He-initialized random
//! weights are not. Since CIFAR-10 training is out of scope for the
//! simulator substrate (DESIGN.md section 2), [`synthetic_trained_conv`]
//! generates *trained-like* weights: a planted low-TT-rank component
//! plus noise, with ranks chosen per layer so that prescribed-accuracy
//! TTD lands at the paper's compression ratio. The e2e federated
//! example uses genuinely trained weights through the PJRT runtime
//! instead.

use crate::model::resnet32::{conv_layers, param_count, ConvLayer};
use crate::sim::config::SocConfig;
use crate::sim::cost::CostSink;
use crate::sim::report::SimReport;
use crate::trace::TraceSink;
use crate::ttd::ttd::{TtDecomp, TtSpec};
use crate::ttd::{decompose, reconstruct, Tensor};
use crate::util::Rng;

/// Result of compressing the full model.
#[derive(Clone, Debug)]
pub struct CompressionOutcome {
    pub decomps: Vec<TtDecomp>,
    /// Dense parameters of the whole model (conv + bn + fc).
    pub model_dense_params: usize,
    /// Conv parameters replaced by TT cores.
    pub conv_dense_params: usize,
    pub conv_tt_params: usize,
    /// Whole-model parameter count after compression (Table I col 4).
    pub final_params: usize,
    /// Whole-model compression ratio (Table I col 3).
    pub compression_ratio: f64,
    /// Worst per-layer relative reconstruction error.
    pub max_rel_err: f32,
}

/// Planted TT ranks for a conv layer targeting the paper's ratio:
/// solve `n1 r1 + r1 n2 r2 + r2 n3 ~= dense / ratio` with
/// `r1 ~= 0.75 n1`.
pub fn planted_ranks(dims: [usize; 3], target_ratio: f64) -> (usize, usize) {
    let [n1, n2, n3] = dims;
    let dense = (n1 * n2 * n3) as f64;
    let budget = dense / target_ratio;
    let r1 = ((n1 as f64) * 0.75).round().max(1.0) as usize;
    let r1 = r1.min(n1);
    // budget - n1 r1 = r2 (r1 n2 + n3)
    let rem = (budget - (n1 * r1) as f64).max(1.0);
    let r2 = (rem / (r1 * n2 + n3) as f64).round().max(1.0) as usize;
    let r2 = r2.min(n3).min(r1 * n2);
    (r1, r2)
}

/// A trained-like conv kernel: planted TT structure + relative noise.
pub fn synthetic_trained_conv(rng: &mut Rng, layer: &ConvLayer, target_ratio: f64, noise: f32) -> Tensor {
    let dims = layer.tt_dims();
    let (r1, r2) = planted_ranks(dims, target_ratio);
    let [n1, n2, n3] = dims;
    // cores ~ N(0, 1/sqrt(r)) keep the product variance bounded
    let g1: Vec<f32> = rng.normal_vec(n1 * r1);
    let g2: Vec<f32> = rng.normal_vec(r1 * n2 * r2).iter().map(|v| v / (r1 as f32).sqrt()).collect();
    let g3: Vec<f32> = rng.normal_vec(r2 * n3).iter().map(|v| v / (r2 as f32).sqrt()).collect();
    let d = TtDecomp {
        dims: dims.to_vec(),
        ranks: vec![1, r1, r2, 1],
        cores: vec![
            crate::ttd::TtCore { r_in: 1, n: n1, r_out: r1, data: g1 },
            crate::ttd::TtCore { r_in: r1, n: n2, r_out: r2, data: g2 },
            crate::ttd::TtCore { r_in: r2, n: n3, r_out: 1, data: g3 },
        ],
        eps: 0.0,
    };
    let mut w = reconstruct(&d);
    let scale = w.frobenius() / (w.numel() as f32).sqrt();
    for v in w.data.iter_mut() {
        *v += noise * scale * rng.normal() as f32;
    }
    w
}

/// Generate all 31 trained-like conv tensors.
pub fn synthetic_model(seed: u64, target_ratio: f64, noise: f32) -> Vec<(ConvLayer, Tensor)> {
    let rng = Rng::new(seed);
    conv_layers()
        .into_iter()
        .map(|l| {
            let mut child = rng.fork(l.param_index as u64);
            let w = synthetic_trained_conv(&mut child, &l, target_ratio, noise);
            (l, w)
        })
        .collect()
}

/// Fold per-layer decompositions into the whole-model accounting
/// (shared by the serial path here, `crate::pipeline`'s parallel
/// path, and `crate::job`, so all report byte-identical outcomes).
pub fn aggregate_outcome(
    layers: &[(ConvLayer, Tensor)],
    decomps: Vec<TtDecomp>,
    max_rel_err: f32,
) -> CompressionOutcome {
    let conv_dense: usize = layers.iter().map(|(l, _)| l.numel()).sum();
    aggregate_outcome_conv(conv_dense, decomps, max_rel_err)
}

/// [`aggregate_outcome`] from a precomputed dense conv parameter count
/// — for callers holding `(&ConvLayer, &Tensor)` refs instead of owned
/// pairs (the coordinator's per-node locals, [`crate::job`]).
///
/// Accounting is **whole-ResNet-32**: the non-conv remainder comes
/// from [`param_count`], matching what every legacy path reported
/// (truncated layer subsets still count the full model's bn/fc
/// params). Conv layers beyond the ResNet-32 budget saturate the
/// remainder to zero rather than underflowing.
pub fn aggregate_outcome_conv(
    conv_dense: usize,
    decomps: Vec<TtDecomp>,
    max_rel_err: f32,
) -> CompressionOutcome {
    aggregate_outcome_model(param_count(), conv_dense, decomps, max_rel_err)
}

/// [`aggregate_outcome_conv`] for a non-ResNet model inventory
/// (transformer decoder stacks, activation maps — ISSUE 9):
/// `model_dense` is the workload's own whole-model parameter count and
/// supplies the uncompressed remainder. Saturates to `conv_dense` the
/// same way the ResNet path does.
pub fn aggregate_outcome_model(
    model_dense: usize,
    conv_dense: usize,
    decomps: Vec<TtDecomp>,
    max_rel_err: f32,
) -> CompressionOutcome {
    let conv_tt: usize = decomps.iter().map(|d| d.param_count()).sum();
    let model_dense = model_dense.max(conv_dense);
    let non_conv = model_dense - conv_dense;
    let final_params = non_conv + conv_tt;
    CompressionOutcome {
        decomps,
        model_dense_params: model_dense,
        conv_dense_params: conv_dense,
        conv_tt_params: conv_tt,
        final_params,
        compression_ratio: model_dense as f64 / final_params as f64,
        max_rel_err,
    }
}

/// Run Algorithm 1 over every conv layer, emitting one combined trace.
pub fn compress_model<S: TraceSink>(
    layers: &[(ConvLayer, Tensor)],
    eps: f32,
    sink: &mut S,
) -> CompressionOutcome {
    let spec = TtSpec::eps(eps);
    let mut decomps = Vec::with_capacity(layers.len());
    let mut max_rel = 0.0f32;
    for (layer, w) in layers {
        let t = w.reshape(&layer.tt_dims());
        // lint: allow(single-entry-point): pre-Job serial reference path kept as the oracle the JobProgram pipeline is tested against (PR-3)
        let d = decompose(&t, &spec, sink);
        let err = crate::ttd::relative_error(&t, &d);
        if err > max_rel {
            max_rel = err;
        }
        decomps.push(d);
    }
    aggregate_outcome(layers, decomps, max_rel)
}

/// Full Table-III experiment: compress synthetic-trained ResNet-32
/// once, costing the identical op stream under every SoC **online**
/// (one [`CostSink`] pass, O(1) memory in trace length — no
/// `Vec<HwOp>` is ever materialized on this path).
pub fn compress_resnet32(
    seed: u64,
    eps: f32,
    configs: &[SocConfig],
) -> (CompressionOutcome, Vec<SimReport>) {
    // Ratio/noise chosen so prescribed-accuracy TTD at `eps` lands at
    // Table I's 3.4x whole-model ratio (see bench table1).
    let layers = synthetic_model(seed, 3.55, 0.035);
    let mut cost = CostSink::new(configs);
    let outcome = compress_model(&layers, eps, &mut cost);
    (outcome, cost.reports())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::SocConfig;
    use crate::trace::NullSink;

    #[test]
    fn planted_ranks_hit_budget() {
        let (r1, r2) = planted_ranks([9, 64, 64], 3.55);
        let dense = 9 * 64 * 64;
        let tt = 9 * r1 + r1 * 64 * r2 + r2 * 64;
        let ratio = dense as f64 / tt as f64;
        assert!((ratio - 3.55).abs() < 0.7, "ratio {ratio}");
    }

    #[test]
    fn synthetic_conv_is_compressible() {
        let mut rng = Rng::new(5);
        let layer = conv_layers().pop().unwrap();
        let w = synthetic_trained_conv(&mut rng, &layer, 3.55, 0.035);
        let d = decompose(&w.reshape(&layer.tt_dims()), &TtSpec::eps(0.12), &mut NullSink);
        assert!(
            d.compression_ratio() > 2.5,
            "ratio {}",
            d.compression_ratio()
        );
    }

    #[test]
    fn whole_model_ratio_in_table1_band() {
        let layers = synthetic_model(42, 3.55, 0.035);
        let mut sink = NullSink;
        let out = compress_model(&layers, 0.12, &mut sink);
        assert!(
            (2.9..4.0).contains(&out.compression_ratio),
            "ratio {}",
            out.compression_ratio
        );
        // error stays within the prescribed budget
        assert!(out.max_rel_err <= 0.12 + 0.01, "{}", out.max_rel_err);
        assert!(out.final_params < out.model_dense_params);
    }

    #[test]
    fn both_configs_replay_identical_numerics() {
        let (out, reports) =
            compress_resnet32(1, 0.12, &[SocConfig::baseline(), SocConfig::tt_edge()]);
        assert_eq!(reports.len(), 2);
        // trace replay: baseline strictly slower
        assert!(reports[0].total_ms > reports[1].total_ms);
        assert!(out.compression_ratio > 2.5);
    }
}
