//! GEMM accelerator model: 16x16 PE tile, 320 KB SPM, APB control +
//! AXI/DMA data movement (paper section II-B).
//!
//! A matmul (m x k)@(k x n) is executed as `ceil(m/16) ceil(n/16)
//! ceil(k/16)` tile operations. Per tile the *baseline* pays:
//! descriptor computation on the core + APB programming + DMA of the
//! operand tiles; *TT-Edge* generates descriptors on the HBD-ACC
//! address calculator and ships them over the direct link (paper idea
//! #2), and keeps Householder vectors SPM-resident (idea #3).

use crate::sim::config::{CostModel, Features};

pub const PE_TILE: u64 = 16;

/// Tile-op count for an (m x k)@(k x n) blockwise multiplication.
pub fn tiles(m: u64, n: u64, k: u64) -> u64 {
    let c = |a: u64| a.div_ceil(PE_TILE);
    c(m) * c(n) * c(k)
}

/// True when one operand is a (Householder) vector — the operand the
/// SPM-retention feature keeps on-chip.
pub fn is_vector_op(m: u64, n: u64, k: u64) -> bool {
    m == 1 || n == 1 || k == 1
}

/// Cycles for one blockwise GEMM under the given feature set.
pub fn gemm_cycles(c: &CostModel, f: &Features, m: u64, n: u64, k: u64) -> u64 {
    let t = tiles(m, n, k);
    // Control path: descriptor per tile.
    let ctrl = if f.direct_gemm_link {
        t * (c.desc_hw + c.link_per_tile)
    } else {
        t * (c.desc_core + c.apb_per_tile)
    };
    // Data path: operand + result traffic.
    //  - matrix operand: streamed from DRAM tile by tile (A and the
    //    result; B-tiles assumed SPM-cached across the k-loop).
    //  - vector operand: DRAM round trip unless SPM-retained.
    let tile_bytes = PE_TILE * PE_TILE * 4;
    let matrix_bytes = 2 * t * tile_bytes; // in + out per tile op
    let mut dram_bytes = matrix_bytes;
    if is_vector_op(m, n, k) && !f.spm_retention {
        // vector fetched + intermediate written back per GEMM
        let vlen = m.max(n).max(k) * 4;
        dram_bytes += 2 * vlen;
    }
    let data = dram_bytes / c.dram_bytes_per_cycle + t * c.axi_per_tile + c.dma_setup;
    // Compute: tiles through the 64-PE array.
    let compute = t * c.tile_compute;
    ctrl + data + compute
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::CostModel;

    #[test]
    fn tile_counts() {
        assert_eq!(tiles(16, 16, 16), 1);
        assert_eq!(tiles(17, 16, 16), 2);
        assert_eq!(tiles(64, 64, 64), 64);
        assert_eq!(tiles(1, 64, 576), 4 * 36);
    }

    #[test]
    fn direct_link_removes_core_descriptor_cost() {
        let c = CostModel::default();
        let base = gemm_cycles(&c, &Features::ALL_OFF, 64, 64, 64);
        let tte = gemm_cycles(&c, &Features::ALL_ON, 64, 64, 64);
        assert!(tte < base);
        let t = tiles(64, 64, 64);
        assert_eq!(
            base - tte,
            t * (c.desc_core + c.apb_per_tile) - t * (c.desc_hw + c.link_per_tile)
        );
    }

    #[test]
    fn spm_retention_only_affects_vector_ops() {
        let c = CostModel::default();
        let mut f_no_spm = Features::ALL_ON;
        f_no_spm.spm_retention = false;
        // square op: no difference
        assert_eq!(
            gemm_cycles(&c, &Features::ALL_ON, 64, 64, 64),
            gemm_cycles(&c, &f_no_spm, 64, 64, 64)
        );
        // rank-1 op: retention saves DRAM traffic
        assert!(
            gemm_cycles(&c, &Features::ALL_ON, 576, 64, 1)
                < gemm_cycles(&c, &f_no_spm, 576, 64, 1)
        );
    }

    #[test]
    fn compute_floor_is_tiles_times_64() {
        let c = CostModel::default();
        let cycles = gemm_cycles(&c, &Features::ALL_ON, 16, 16, 16);
        assert!(cycles >= c.tile_compute);
    }
}
