//! GEMM accelerator model: parameterized PE tile (16x16 with 64 PEs
//! and a 320 KB SPM in the paper, section II-B), APB control +
//! AXI/DMA data movement.
//!
//! A matmul (m x k)@(k x n) is executed as `ceil(m/T) ceil(n/T)
//! ceil(k/T)` tile operations for tile edge `T = CostModel::gemm_tile`.
//! Per tile the *baseline* pays: descriptor computation on the core +
//! APB programming + DMA of the operand tiles; *TT-Edge* generates
//! descriptors on the HBD-ACC address calculator and ships them over
//! the direct link (paper idea #2), and keeps Householder vectors
//! SPM-resident (idea #3).
//!
//! The SPM capacity knob bounds both retention mechanisms: a
//! Householder vector only stays resident when it fits the vector
//! partition (a quarter of the SPM), and the B-operand panel is only
//! cached across the k-loop when the whole panel fits the SPM. At the
//! paper's 320 KB neither bound binds for the ResNet-32 workload
//! (largest vector 16 KB, largest panel 256 KB), so the default cost
//! is identical to the pre-knob model; the DSE sweeps where smaller
//! scratchpads start paying DRAM round-trips.

use crate::sim::config::{CostModel, Features};

/// The paper's tile edge (the default `CostModel::gemm_tile`).
pub const PE_TILE: u64 = 16;

/// Tile-op count for an (m x k)@(k x n) blockwise multiplication at
/// tile edge `tile`.
pub fn tiles(tile: u64, m: u64, n: u64, k: u64) -> u64 {
    let t = tile.max(1);
    let c = |a: u64| a.div_ceil(t);
    c(m) * c(n) * c(k)
}

/// True when one operand is a (Householder) vector — the operand the
/// SPM-retention feature keeps on-chip.
pub fn is_vector_op(m: u64, n: u64, k: u64) -> bool {
    m == 1 || n == 1 || k == 1
}

/// Cycles for one blockwise GEMM under the given feature set.
pub fn gemm_cycles(c: &CostModel, f: &Features, m: u64, n: u64, k: u64) -> u64 {
    let tile = c.gemm_tile.max(1);
    let t = tiles(tile, m, n, k);
    // Control path: descriptor per tile.
    let ctrl = if f.direct_gemm_link {
        t * (c.desc_hw + c.link_per_tile)
    } else {
        t * (c.desc_core + c.apb_per_tile)
    };
    // Data path: operand + result traffic.
    //  - matrix operand: streamed from DRAM tile by tile (A and the
    //    result; B-tiles SPM-cached across the k-loop when the panel
    //    fits the scratchpad, re-fetched per tile op otherwise).
    //  - vector operand: DRAM round trip unless SPM-retained (and the
    //    vector fits the SPM's vector partition).
    let tile_bytes = tile * tile * 4;
    let mut dram_bytes = 2 * t * tile_bytes; // in + out per tile op
    let b_panel_bytes = k.div_ceil(tile) * tile_bytes;
    if b_panel_bytes > c.spm_bytes() {
        dram_bytes += t * tile_bytes; // B tile re-fetched per tile op
    }
    if is_vector_op(m, n, k) {
        let vbytes = m.max(n).max(k) * 4;
        let retained = f.spm_retention && vbytes <= c.spm_bytes() / 4;
        if !retained {
            // vector fetched + intermediate written back per GEMM
            dram_bytes += 2 * vbytes;
        }
    }
    let data = dram_bytes / c.dram_bytes_per_cycle + t * c.axi_per_tile + c.dma_setup;
    // Compute: tiles through the PE array.
    let compute = t * c.tile_compute_cycles();
    ctrl + data + compute
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::CostModel;

    #[test]
    fn tile_counts() {
        assert_eq!(tiles(PE_TILE, 16, 16, 16), 1);
        assert_eq!(tiles(PE_TILE, 17, 16, 16), 2);
        assert_eq!(tiles(PE_TILE, 64, 64, 64), 64);
        assert_eq!(tiles(PE_TILE, 1, 64, 576), 4 * 36);
        assert_eq!(tiles(32, 64, 64, 64), 8);
    }

    #[test]
    fn direct_link_removes_core_descriptor_cost() {
        let c = CostModel::default();
        let base = gemm_cycles(&c, &Features::ALL_OFF, 64, 64, 64);
        let tte = gemm_cycles(&c, &Features::ALL_ON, 64, 64, 64);
        assert!(tte < base);
        let t = tiles(c.gemm_tile, 64, 64, 64);
        assert_eq!(
            base - tte,
            t * (c.desc_core + c.apb_per_tile) - t * (c.desc_hw + c.link_per_tile)
        );
    }

    #[test]
    fn spm_retention_only_affects_vector_ops() {
        let c = CostModel::default();
        let mut f_no_spm = Features::ALL_ON;
        f_no_spm.spm_retention = false;
        // square op: no difference
        assert_eq!(
            gemm_cycles(&c, &Features::ALL_ON, 64, 64, 64),
            gemm_cycles(&c, &f_no_spm, 64, 64, 64)
        );
        // rank-1 op: retention saves DRAM traffic
        assert!(
            gemm_cycles(&c, &Features::ALL_ON, 576, 64, 1)
                < gemm_cycles(&c, &f_no_spm, 576, 64, 1)
        );
    }

    #[test]
    fn compute_floor_is_tiles_times_tile_cycles() {
        let c = CostModel::default();
        let cycles = gemm_cycles(&c, &Features::ALL_ON, 16, 16, 16);
        assert!(cycles >= c.tile_compute_cycles());
    }

    #[test]
    fn paper_spm_never_binds_on_the_workload_shapes() {
        // The capacity model must be cost-neutral at the paper's
        // 320 KB for every shape the ResNet-32 numerics emit (largest
        // mode product 4096): the numeric pins depend on it.
        let c = CostModel::default();
        let mut huge = c.clone();
        huge.spm_kb = 1 << 20; // effectively unbounded scratchpad
        for (m, n, k) in [(9, 4096, 4096), (1, 4096, 4096), (576, 64, 1), (4096, 9, 9)] {
            assert_eq!(
                gemm_cycles(&c, &Features::ALL_ON, m, n, k),
                gemm_cycles(&huge, &Features::ALL_ON, m, n, k),
                "{m}x{n}x{k}"
            );
        }
    }

    #[test]
    fn small_spm_pays_dram_round_trips() {
        let big = CostModel::default();
        let small = CostModel { spm_kb: 8, ..CostModel::default() };
        // 8 KB SPM: a 4096-element (16 KB) Householder vector no
        // longer fits the 2 KB vector partition -> retention is moot.
        assert!(
            gemm_cycles(&small, &Features::ALL_ON, 1, 64, 4096)
                > gemm_cycles(&big, &Features::ALL_ON, 1, 64, 4096)
        );
        // ...and the 256 KB B panel of a k=4096 GEMM spills too.
        assert!(
            gemm_cycles(&small, &Features::ALL_ON, 64, 64, 4096)
                > gemm_cycles(&big, &Features::ALL_ON, 64, 64, 4096)
        );
    }

    #[test]
    fn wider_tile_trades_control_for_traffic() {
        // Bigger tiles mean fewer descriptors (cheaper on the
        // baseline's core-descriptor path) but coarser DRAM bursts.
        let c16 = CostModel::default();
        let c32 = CostModel { gemm_tile: 32, ..CostModel::default() };
        let b16 = gemm_cycles(&c16, &Features::ALL_OFF, 64, 64, 64);
        let b32 = gemm_cycles(&c32, &Features::ALL_OFF, 64, 64, 64);
        // On the baseline the 466-cycle core descriptor dominates:
        // 8 tiles beat 64.
        assert!(b32 < b16, "b32 {b32} vs b16 {b16}");
    }
}
