//! The edge-SoC simulator: the paper's hardware contribution as an
//! executable model.
//!
//! The real TTD numerics ([`crate::ttd`]) emit a hardware-op stream;
//! [`cost::CostSink`] folds it **online** into [`timeline::HwTimeline`]
//! accumulators under any number of [`config::SocConfig`]s (Baseline
//! and TT-Edge in one pass, O(1) memory in trace length), and
//! [`power`] integrates the Table-II power states over the phase
//! timeline. [`report`] renders Table III. Recorded `VecSink` traces
//! replay to the same accumulators bit-for-bit.
//!
//! See DESIGN.md section 6 for the modelling approach and section 2 for
//! why a cycle-approximate simulator is the faithful substitute for
//! the paper's FPGA prototype in this environment.

pub mod config;
pub mod core_model;
pub mod cost;
pub mod gemm;
pub mod power;
pub mod report;
pub mod systolic;
pub mod timeline;
pub mod ttd_engine;
pub mod workload;

pub use config::{Backend, CostModel, Features, GatingPolicy, SocConfig, Variant};
pub use cost::CostSink;
pub use report::{format_table3, SimReport};
pub use timeline::HwTimeline;
pub use workload::{compress_resnet32, CompressionOutcome};
