//! Minimal JSON parser — just enough for `artifacts/manifest.json`.
//!
//! The offline environment has no `serde_json`; this hand-rolled
//! recursive-descent parser covers the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, booleans, null), which is
//! more than the manifest needs but keeps the module reusable.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Serialize to compact JSON text. Numbers use Rust's shortest
    /// round-trip `f64` formatting (deterministic across runs);
    /// non-finite numbers — e.g. a disabled metric — render as `null`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    // Integral values print without a trailing ".0"
                    // (usize counters round-trip as integers).
                    if n.fract() == 0.0 && n.abs() < 9.0e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).render_into(out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for report emitters.
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"entries": [{"name": "svd", "file": "svd.hlo.txt",
            "inputs": [{"shape": [144, 64], "dtype": "float32"}],
            "hlo_chars": 67469}]}"#;
        let j = parse(doc).unwrap();
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str().unwrap(), "svd");
        let shape = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect::<Vec<_>>();
        assert_eq!(shape, vec![144, 64]);
        assert_eq!(e.get("hlo_chars").unwrap().as_usize().unwrap(), 67469);
    }

    #[test]
    fn parses_scalars_and_escapes() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            parse(r#""a\n\"bA""#).unwrap(),
            Json::Str("a\n\"bA".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("'single'").is_err());
        assert!(parse("{}extra").is_err());
    }

    #[test]
    fn nested_structures() {
        let j = parse(r#"[[1,2],[3,[4]],{"k":[]}]"#).unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 3);
    }

    #[test]
    fn render_round_trips_through_parse() {
        let doc = r#"{"a": [1, 2.5, -3], "b": {"s": "x\n\"y"}, "c": true, "d": null}"#;
        let j = parse(doc).unwrap();
        let rendered = j.render();
        assert_eq!(parse(&rendered).unwrap(), j);
        // compact + deterministic key order (BTreeMap)
        assert_eq!(rendered, r#"{"a":[1,2.5,-3],"b":{"s":"x\n\"y"},"c":true,"d":null}"#);
    }

    #[test]
    fn render_integral_floats_without_fraction() {
        assert_eq!(Json::Num(3.0).render(), "3");
        assert_eq!(Json::Num(-0.125).render(), "-0.125");
        assert_eq!(Json::from(42usize).render(), "42");
    }

    #[test]
    fn render_nan_as_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }
}
