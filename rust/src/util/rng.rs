//! Deterministic PRNG (SplitMix64 + xoshiro256**), replacing the
//! unavailable `rand` crate. Used for synthetic weights, workloads and
//! the property-test harness; determinism keeps every experiment and
//! test reproducible from a seed.

/// xoshiro256** seeded via SplitMix64 (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard-normal f32 values.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Fork a child RNG (stable under reordering of sibling forks).
    pub fn fork(&self, stream: u64) -> Rng {
        Rng::new(self.s[0] ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
