//! Small self-contained utilities (offline build: no external crates).

pub mod cli;
pub mod json;
pub mod rng;

pub use rng::Rng;
