//! Tiny CLI argument parser (no `clap` offline).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positional
//! arguments — all the launcher needs.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn parse_opt<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.opt(key).and_then(|s| s.parse().ok())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_mixed_forms() {
        // Policy: `--key token` binds token as the value unless token
        // itself starts with `--`; bare flags therefore go last or are
        // followed by another option.
        let a = args("simulate extra --config tt-edge --eps=0.12 --verbose");
        assert_eq!(a.positional, vec!["simulate", "extra"]);
        assert_eq!(a.opt("config"), Some("tt-edge"));
        assert_eq!(a.parse_opt::<f64>("eps"), Some(0.12));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn flag_followed_by_option_stays_flag() {
        let a = args("cmd --gate --fast");
        assert!(a.flag("gate") && a.flag("fast"));
        assert_eq!(a.positional, vec!["cmd"]);
    }

    #[test]
    fn negative_number_as_value() {
        // a value starting with '-' (not '--') still binds to the key
        let a = args("--delta -0.5");
        assert_eq!(a.parse_opt::<f64>("delta"), Some(-0.5));
    }
}
