//! Tiny CLI argument parser (no `clap` offline).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positional
//! arguments — all the launcher needs.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn parse_opt<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.opt(key).and_then(|s| s.parse().ok())
    }

    /// Strict parse: `Ok(None)` when `--key` is absent, `Err` when it
    /// is present but unparseable — so a typo'd value is a usage
    /// error, never a silent fall-back to the default.
    pub fn parse_opt_strict<T: std::str::FromStr>(
        &self,
        key: &str,
    ) -> Result<Option<T>, String> {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|_| format!("invalid value for --{key}: `{s}`")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Validate the parsed arguments against a subcommand's declared
    /// interface: every `--key value` must be a declared option, every
    /// bare `--flag` a declared flag, and nothing positional may
    /// follow the subcommand itself. Returns a usage-error message on
    /// the first violation — unknown or misused arguments are a hard
    /// error, never silently ignored.
    pub fn validate(&self, opts: &[&str], flags: &[&str]) -> Result<(), String> {
        for k in self.options.keys() {
            if opts.iter().any(|o| o == k) {
                continue;
            }
            if flags.iter().any(|f| f == k) {
                return Err(format!(
                    "--{k} is a flag and takes no value (got `--{k} {}`)",
                    self.options[k]
                ));
            }
            return Err(format!("unknown option --{k}"));
        }
        for f in &self.flags {
            if flags.iter().any(|x| x == f) {
                continue;
            }
            if opts.iter().any(|x| x == f) {
                return Err(format!("--{f} requires a value"));
            }
            return Err(format!("unknown flag --{f}"));
        }
        if self.positional.len() > 1 {
            return Err(format!("unexpected argument `{}`", self.positional[1]));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_mixed_forms() {
        // Policy: `--key token` binds token as the value unless token
        // itself starts with `--`; bare flags therefore go last or are
        // followed by another option.
        let a = args("simulate extra --config tt-edge --eps=0.12 --verbose");
        assert_eq!(a.positional, vec!["simulate", "extra"]);
        assert_eq!(a.opt("config"), Some("tt-edge"));
        assert_eq!(a.parse_opt::<f64>("eps"), Some(0.12));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn flag_followed_by_option_stays_flag() {
        let a = args("cmd --gate --fast");
        assert!(a.flag("gate") && a.flag("fast"));
        assert_eq!(a.positional, vec!["cmd"]);
    }

    #[test]
    fn negative_number_as_value() {
        // a value starting with '-' (not '--') still binds to the key
        let a = args("--delta -0.5");
        assert_eq!(a.parse_opt::<f64>("delta"), Some(-0.5));
    }

    #[test]
    fn validate_accepts_declared_interface() {
        let a = args("simulate --eps 0.12 --parallel 4 --json");
        assert!(a.validate(&["eps", "seed", "parallel"], &["json"]).is_ok());
    }

    #[test]
    fn validate_rejects_unknown_option_and_flag() {
        let a = args("simulate --epz 0.12");
        let err = a.validate(&["eps"], &["json"]).unwrap_err();
        assert!(err.contains("unknown option --epz"), "{err}");
        let b = args("simulate --jsn");
        let err = b.validate(&["eps"], &["json"]).unwrap_err();
        assert!(err.contains("unknown flag --jsn"), "{err}");
    }

    #[test]
    fn validate_rejects_flag_given_a_value_and_option_missing_one() {
        // `--json 1`: the parser binds 1 as a value; validation names
        // the misuse instead of silently treating it as an option.
        let a = args("simulate --json 1");
        let err = a.validate(&["eps"], &["json"]).unwrap_err();
        assert!(err.contains("takes no value"), "{err}");
        // `--eps` at end of line parses as a flag; validation catches
        // the missing value.
        let b = args("simulate --eps");
        let err = b.validate(&["eps"], &["json"]).unwrap_err();
        assert!(err.contains("requires a value"), "{err}");
    }

    #[test]
    fn validate_rejects_stray_positionals() {
        let a = args("simulate extra");
        let err = a.validate(&["eps"], &[]).unwrap_err();
        assert!(err.contains("unexpected argument `extra`"), "{err}");
    }

    #[test]
    fn strict_parse_distinguishes_absent_from_garbage() {
        let a = args("simulate --eps 0.15x");
        assert_eq!(a.parse_opt_strict::<f64>("seed"), Ok(None));
        let err = a.parse_opt_strict::<f64>("eps").unwrap_err();
        assert!(err.contains("invalid value for --eps"), "{err}");
        let b = args("simulate --eps 0.15");
        assert_eq!(b.parse_opt_strict("eps"), Ok(Some(0.15f64)));
    }
}
