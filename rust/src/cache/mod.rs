//! Keyed [`JobProgram`] cache — the memory of the compression service.
//!
//! TT-Edge's record-once / replay-many seam (PR 5) made a recorded
//! [`JobProgram`] bit-identical to live costing; this module makes
//! that artifact *resident*: a request whose (workload, full
//! [`TtSpec`]) key was seen before is served by replaying the cached
//! program — zero numerics — while a first-of-its-kind request runs
//! the numerics exactly once and populates the cache for everyone
//! behind it.
//!
//! Design points:
//!
//! * **Key soundness.** A program is a pure function of the workload
//!   weights and the *entire* numeric spec. [`CacheKey`] therefore
//!   combines a [`Fingerprint`] of the workload identity with
//!   `eps.to_bits()`, the effective per-bond rank caps read through
//!   [`TtSpec::cap_for`], **and** the SVD method discriminant
//!   (exact vs randomized, with the sketch seed and oversampling) —
//!   so `rank_cap(8)` and `rank_caps(&[8, 8])` share an entry (same
//!   numerics), while two requests differing only in caps or only in
//!   method never collide.
//! * **Single-flight misses.** Under a concurrent drain, the first
//!   claimant of an absent key installs a *pending* slot and runs the
//!   numerics; every later claimant blocks on a condvar and resolves
//!   as a hit when the program lands. A request stream with R requests
//!   over K unique keys costs exactly K numerics passes at any worker
//!   count. If the recording claimant panics or is cancelled, its
//!   [`MissGuard`] clears the pending slot on drop and wakes the
//!   waiters so one of them becomes the new recorder — a failure never
//!   wedges the key.
//! * **LRU eviction.** Ready entries above `capacity` are evicted
//!   least-recently-used first (pending slots are never evicted — they
//!   hold no program yet and a waiter is counting on them). Recency is
//!   tracked in a `BTreeMap<tick, key>` side index: ticks are unique
//!   and monotonic under the lock, so BTreeMap order *is* recency
//!   order and the victim is `pop_first()` — O(log n), deterministic
//!   by construction rather than by a full-map scan whose tie-breaking
//!   depends on hasher order. Capacity 0 is the degenerate "uncached"
//!   mode benchmarks use as a baseline: every insert is immediately
//!   displaced, residency stays 0, and correctness is unchanged.
//! * **Observability.** All counters live in
//!   [`crate::metrics::CacheStats`] and obey its conservation laws;
//!   [`ProgramCache::stats`] snapshots them under the lock.
//! * **Lock discipline.** Every acquisition of the state mutex goes
//!   through [`ProgramCache::lock_cache`], the module's one named
//!   lock helper — it documents why *propagating* a poison panic is
//!   the correct policy here, so no call site carries its own ad-hoc
//!   `.unwrap()` judgment (enforced by `ttedge-lint`'s
//!   lock-discipline rule).

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::job::JobProgram;
use crate::metrics::CacheStats;
use crate::ttd::ttd::{SvdMethod, TtSpec};

/// Streaming FNV-1a (64-bit) over the workload identity. Not
/// cryptographic — it keys a cache, it does not authenticate one —
/// but deterministic across runs and platforms (explicit little-endian
/// byte order, no pointer or layout dependence).
#[derive(Clone, Copy, Debug)]
pub struct Fingerprint(u64);

impl Default for Fingerprint {
    fn default() -> Self {
        Self::new()
    }
}

impl Fingerprint {
    pub fn new() -> Self {
        Fingerprint(0xcbf2_9ce4_8422_2325)
    }

    #[inline]
    fn byte(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    pub fn push_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.byte(b);
        }
    }

    pub fn push_u64(&mut self, v: u64) {
        self.push_bytes(&v.to_le_bytes());
    }

    pub fn push_usize(&mut self, v: usize) {
        self.push_u64(v as u64);
    }

    /// Length-prefixed, so `("ab", "c")` and `("a", "bc")` digest
    /// differently.
    pub fn push_str(&mut self, s: &str) {
        self.push_usize(s.len());
        self.push_bytes(s.as_bytes());
    }

    /// Exact bit patterns (length-prefixed): distinct weights always
    /// fingerprint differently, -0.0 vs 0.0 included.
    pub fn push_f32s(&mut self, vs: &[f32]) {
        self.push_usize(vs.len());
        for v in vs {
            self.push_bytes(&v.to_bits().to_le_bytes());
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// What a cached program is indexed by: the workload fingerprint plus
/// the **full** numeric spec — `eps` bits and the effective cap of
/// every bond the workload has. Caps are canonicalized through
/// [`TtSpec::cap_for`], so equivalent specs expressed differently
/// (uniform vs per-bond, trailing unbounded caps) map to one key.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CacheKey {
    workload: u64,
    eps_bits: u32,
    caps: Vec<u64>,
    /// SVD method discriminant `(tag, seed, oversample)`: exact is
    /// `(0, 0, 0)`; randomized carries its sketch seed and
    /// oversampling, both of which change the op stream (ISSUE 9).
    method: (u8, u64, u32),
    /// Injected-stall discriminant (ISSUE 10): a chaos-stalled run
    /// takes the Jacobi fallback and must never share a program with
    /// the fault-free run of the same workload.
    stall: u8,
}

impl CacheKey {
    /// `bonds` is the number of TT bonds the workload's tensors have
    /// (`dims.len() - 1`); caps past it cannot affect the numerics and
    /// are deliberately excluded.
    pub fn new(workload_fingerprint: u64, spec: &TtSpec, bonds: usize) -> Self {
        CacheKey {
            workload: workload_fingerprint,
            eps_bits: spec.eps.to_bits(),
            caps: (0..bonds).map(|b| spec.cap_for(b) as u64).collect(),
            method: match spec.method() {
                SvdMethod::Exact => (0, 0, 0),
                SvdMethod::Randomized { seed, oversample } => (1, seed, oversample),
            },
            stall: spec.svd_stall().discriminant(),
        }
    }
}

enum Slot {
    /// A miss claimant is recording this key right now; waiters block
    /// until it lands (or the claimant's guard drops).
    Pending,
    /// A resident program and its last-use tick (LRU order).
    Ready(Arc<JobProgram>, u64),
}

struct Inner {
    capacity: usize,
    slots: HashMap<CacheKey, Slot>,
    /// Recency side index: last-use tick → key, mirroring exactly the
    /// `Slot::Ready` entries of `slots` (pending slots are never
    /// indexed). Ticks are unique and monotonic under the lock, so
    /// the map's first entry is always the LRU victim.
    lru: BTreeMap<u64, CacheKey>,
    /// Monotonic logical clock; bumped on every cache operation so
    /// last-use ticks are unique and LRU order is total.
    tick: u64,
    stats: CacheStats,
}

impl Inner {
    /// Re-seat a just-used ready entry at its new tick: the slot's
    /// `last_used` and the `lru` index must move together or eviction
    /// order silently drifts from true recency.
    fn touch(&mut self, key: &CacheKey, old_tick: u64, new_tick: u64) {
        self.lru.remove(&old_tick);
        self.lru.insert(new_tick, key.clone());
    }

    fn evict_over_capacity(&mut self) {
        while self.stats.resident > self.capacity as u64 {
            let Some((_, key)) = self.lru.pop_first() else { break };
            match self.slots.remove(&key) {
                Some(Slot::Ready(p, _)) => {
                    self.stats.evictions += 1;
                    self.stats.resident -= 1;
                    self.stats.resident_bytes -= p.ops.encoded_bytes() as u64;
                }
                // The index mirrors Ready slots exactly; a dangling
                // tick means the mirror (and `resident`) is corrupt —
                // fail loudly instead of evicting garbage.
                _ => unreachable!("lru tick index points at a missing or pending slot"),
            }
        }
    }

    fn store(&mut self, key: CacheKey, program: Arc<JobProgram>) {
        self.tick += 1;
        let tick = self.tick;
        let bytes = program.ops.encoded_bytes() as u64;
        let prev = self.slots.insert(key.clone(), Slot::Ready(program, tick));
        self.lru.insert(tick, key);
        self.stats.inserts += 1;
        match prev {
            // Replacement: the displaced program counts as evicted —
            // this is what keeps `inserts - evictions == resident` —
            // and its stale tick leaves the index with it.
            Some(Slot::Ready(old, old_tick)) => {
                self.lru.remove(&old_tick);
                self.stats.evictions += 1;
                self.stats.resident_bytes -= old.ops.encoded_bytes() as u64;
            }
            // Fulfilling a pending claim, or a brand-new key.
            Some(Slot::Pending) | None => self.stats.resident += 1,
        }
        self.stats.resident_bytes += bytes;
        self.evict_over_capacity();
    }
}

/// What [`ProgramCache::claim`] resolved to.
pub enum Claim<'a> {
    /// Served from cache (possibly after waiting out another worker's
    /// in-flight recording): replay this, run no numerics.
    Hit(Arc<JobProgram>),
    /// This caller is the key's designated recorder: run the numerics
    /// once and [`MissGuard::fulfill`] the guard.
    Miss(MissGuard<'a>),
}

/// The exclusive right (and obligation) to record one missing key.
/// Dropping it unfulfilled — panic, cancellation — releases the key so
/// a waiter can take over.
pub struct MissGuard<'a> {
    cache: &'a ProgramCache,
    key: CacheKey,
    fulfilled: bool,
}

impl MissGuard<'_> {
    /// Install the freshly recorded program, wake every waiter, and
    /// return the shared handle (callers keep costing from it).
    pub fn fulfill(mut self, program: JobProgram) -> Arc<JobProgram> {
        let arc = Arc::new(program);
        {
            let mut inner = self.cache.lock_cache();
            inner.store(self.key.clone(), arc.clone());
        }
        self.fulfilled = true;
        self.cache.ready_cv.notify_all();
        arc
    }
}

impl Drop for MissGuard<'_> {
    fn drop(&mut self) {
        if self.fulfilled {
            return;
        }
        {
            let mut inner = self.cache.lock_cache();
            if matches!(inner.slots.get(&self.key), Some(Slot::Pending)) {
                inner.slots.remove(&self.key);
            }
        }
        self.cache.ready_cv.notify_all();
    }
}

/// The keyed, single-flight, LRU program cache. Shared by reference
/// across worker threads (`&ProgramCache` is `Sync`); see the module
/// docs for the semantics.
#[derive(Debug)]
pub struct ProgramCache {
    state: Mutex<Inner>,
    ready_cv: Condvar,
}

impl std::fmt::Debug for Inner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ProgramCache")
            .field("capacity", &self.capacity)
            .field("stats", &self.stats)
            .finish()
    }
}

impl ProgramCache {
    /// A cache holding at most `capacity` ready programs. Capacity 0
    /// disables residency (every lookup misses) without changing any
    /// caller-visible output — the benchmark's uncached baseline.
    pub fn new(capacity: usize) -> Self {
        ProgramCache {
            state: Mutex::new(Inner {
                capacity,
                slots: HashMap::new(),
                lru: BTreeMap::new(),
                tick: 0,
                stats: CacheStats::default(),
            }),
            ready_cv: Condvar::new(),
        }
    }

    /// The one blessed way to take the cache mutex — every call site
    /// in this module goes through here.
    ///
    /// Poison policy: **propagate the panic**. The lock is only
    /// poisoned if a thread panicked *inside* one of this module's
    /// short critical sections, which would leave a half-applied
    /// counter update and silently break the [`CacheStats`]
    /// conservation laws if we limped on via `into_inner`. Crashing
    /// loudly is the deterministic option, and single-flight safety
    /// does not depend on recovery: a recording claimant runs its
    /// numerics *outside* the lock, and its [`MissGuard`] releases the
    /// Pending key on drop, so a claimant panic never wedges waiters.
    fn lock_cache(&self) -> MutexGuard<'_, Inner> {
        self.state.lock().expect("program cache poisoned") // lint: allow(lock-discipline): this IS the named lock helper stating the poison policy; every other site calls lock_cache()
    }

    pub fn capacity(&self) -> usize {
        self.lock_cache().capacity
    }

    /// Ready programs resident right now.
    pub fn len(&self) -> usize {
        self.lock_cache().stats.resident as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counter snapshot (consistent: taken under the lock).
    pub fn stats(&self) -> CacheStats {
        self.lock_cache().stats
    }

    /// Whether `key` is resident and ready. No counter movement, no
    /// LRU touch — an observation hook for tests, not a lookup.
    pub fn contains(&self, key: &CacheKey) -> bool {
        let inner = self.lock_cache();
        matches!(inner.slots.get(key), Some(Slot::Ready(..)))
    }

    /// Single-flight keyed probe. A hit (including one resolved by
    /// waiting out another claimant's recording) touches the entry's
    /// LRU tick; an outright miss installs a pending slot and returns
    /// the [`MissGuard`] obligating this caller to record.
    pub fn claim(&self, key: &CacheKey) -> Claim<'_> {
        enum Probe {
            Ready(Arc<JobProgram>, u64),
            Pending,
            Absent,
        }
        let mut inner = self.lock_cache();
        inner.stats.lookups += 1;
        loop {
            inner.tick += 1;
            let tick = inner.tick;
            // Resolve the slot state without holding a borrow across
            // the wait / insert below.
            let probe = match inner.slots.get_mut(key) {
                Some(Slot::Ready(program, last_used)) => {
                    let prev = std::mem::replace(last_used, tick);
                    Probe::Ready(program.clone(), prev)
                }
                Some(Slot::Pending) => Probe::Pending,
                None => Probe::Absent,
            };
            match probe {
                Probe::Ready(program, prev_tick) => {
                    inner.touch(key, prev_tick, tick);
                    inner.stats.hits += 1;
                    return Claim::Hit(program);
                }
                Probe::Pending => {
                    inner = self
                        .ready_cv
                        .wait(inner)
                        .expect("program cache poisoned");
                }
                Probe::Absent => {
                    inner.slots.insert(key.clone(), Slot::Pending);
                    inner.stats.misses += 1;
                    return Claim::Miss(MissGuard {
                        cache: self,
                        key: key.clone(),
                        fulfilled: false,
                    });
                }
            }
        }
    }

    /// Plain probe: hit (touches LRU) or miss, never waits and never
    /// installs a pending slot. An in-flight pending key counts as a
    /// miss here — use [`ProgramCache::claim`] for single-flight.
    pub fn lookup(&self, key: &CacheKey) -> Option<Arc<JobProgram>> {
        let mut inner = self.lock_cache();
        inner.stats.lookups += 1;
        inner.tick += 1;
        let tick = inner.tick;
        let found = match inner.slots.get_mut(key) {
            Some(Slot::Ready(program, last_used)) => {
                let prev = std::mem::replace(last_used, tick);
                Some((program.clone(), prev))
            }
            _ => None,
        };
        match found {
            Some((program, prev_tick)) => {
                inner.touch(key, prev_tick, tick);
                inner.stats.hits += 1;
                Some(program)
            }
            None => {
                inner.stats.misses += 1;
                None
            }
        }
    }

    /// Direct insert (no claim protocol). Replacing a resident entry
    /// counts as insert + eviction of the displaced program. Intended
    /// for tests and warm-start loaders; concurrent `claim`s on the
    /// same key should go through [`MissGuard::fulfill`] instead.
    pub fn insert(&self, key: CacheKey, program: JobProgram) -> Arc<JobProgram> {
        let arc = Arc::new(program);
        {
            let mut inner = self.lock_cache();
            inner.store(key, arc.clone());
        }
        self.ready_cv.notify_all();
        arc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ttd::Tensor;
    use crate::util::Rng;
    use crate::CompressionJob;

    fn sample_program() -> JobProgram {
        let mut rng = Rng::new(901);
        let w = Tensor::from_vec(&[4, 4, 4], rng.normal_vec(64));
        let (_, program) = CompressionJob::new(&w).eps(0.2).program().unwrap();
        program
    }

    fn key(eps: f32) -> CacheKey {
        CacheKey::new(0xABCD, &TtSpec::eps(eps), 2)
    }

    #[test]
    fn fingerprint_is_order_and_boundary_sensitive() {
        let mut a = Fingerprint::new();
        a.push_str("ab");
        a.push_str("c");
        let mut b = Fingerprint::new();
        b.push_str("a");
        b.push_str("bc");
        assert_ne!(a.finish(), b.finish());
        let mut c = Fingerprint::new();
        c.push_f32s(&[0.0]);
        let mut d = Fingerprint::new();
        d.push_f32s(&[-0.0]);
        assert_ne!(c.finish(), d.finish(), "distinct bit patterns must differ");
        assert_eq!(Fingerprint::new().finish(), Fingerprint::new().finish());
    }

    #[test]
    fn cache_key_canonicalizes_equivalent_caps() {
        let spec_uniform = TtSpec::eps(0.12).rank_cap(8);
        let spec_per_bond = TtSpec::eps(0.12).rank_caps(&[8, 8]);
        assert_eq!(
            CacheKey::new(1, &spec_uniform, 2),
            CacheKey::new(1, &spec_per_bond, 2)
        );
        // trailing unbounded caps canonicalize too
        let explicit_max = TtSpec::eps(0.12).rank_caps(&[8]);
        let with_tail = TtSpec::eps(0.12).rank_caps(&[8, usize::MAX]);
        assert_eq!(CacheKey::new(1, &explicit_max, 2), CacheKey::new(1, &with_tail, 2));
        // ...but a real cap difference is a different key
        assert_ne!(
            CacheKey::new(1, &TtSpec::eps(0.12), 2),
            CacheKey::new(1, &TtSpec::eps(0.12).rank_cap(8), 2)
        );
    }

    #[test]
    fn cache_key_covers_the_svd_method() {
        let exact = TtSpec::eps(0.12);
        let rand = TtSpec::eps(0.12).rsvd(7, 8);
        assert_ne!(
            CacheKey::new(1, &exact, 2),
            CacheKey::new(1, &rand, 2),
            "exact and randomized runs emit different op streams"
        );
        assert_ne!(CacheKey::new(1, &rand, 2), CacheKey::new(1, &TtSpec::eps(0.12).rsvd(8, 8), 2));
        assert_ne!(CacheKey::new(1, &rand, 2), CacheKey::new(1, &TtSpec::eps(0.12).rsvd(7, 16), 2));
        assert_eq!(CacheKey::new(1, &rand, 2), CacheKey::new(1, &TtSpec::eps(0.12).rsvd(7, 8), 2));
    }

    #[test]
    fn cache_key_covers_the_injected_stall() {
        use crate::fault::SvdStall;
        let clean = TtSpec::eps(0.12);
        let soft = TtSpec::eps(0.12).with_stall(SvdStall::Soft);
        assert_ne!(
            CacheKey::new(1, &clean, 2),
            CacheKey::new(1, &soft, 2),
            "the Jacobi fallback records a different program"
        );
        assert_ne!(
            CacheKey::new(1, &soft, 2),
            CacheKey::new(1, &TtSpec::eps(0.12).with_stall(SvdStall::Hard), 2)
        );
        assert_eq!(
            CacheKey::new(1, &clean, 2),
            CacheKey::new(1, &TtSpec::eps(0.12).with_stall(SvdStall::None), 2),
            "a benign plan must not split any existing key"
        );
    }

    #[test]
    fn claim_miss_fulfill_then_hit() {
        let cache = ProgramCache::new(4);
        let k = key(0.1);
        let Claim::Miss(guard) = cache.claim(&k) else {
            panic!("first claim must miss")
        };
        let stored = guard.fulfill(sample_program());
        let Claim::Hit(hit) = cache.claim(&k) else { panic!("second claim must hit") };
        assert!(Arc::ptr_eq(&stored, &hit));
        let s = cache.stats();
        assert!(s.conserved(), "{s:?}");
        assert_eq!((s.lookups, s.hits, s.misses), (2, 1, 1));
        assert_eq!(s.resident, 1);
        assert_eq!(s.resident_bytes, stored.ops.encoded_bytes() as u64);
    }

    #[test]
    fn dropped_guard_releases_the_key() {
        let cache = ProgramCache::new(4);
        let k = key(0.1);
        match cache.claim(&k) {
            Claim::Miss(guard) => drop(guard), // recorder failed
            Claim::Hit(_) => panic!("empty cache cannot hit"),
        }
        // the key is claimable again, not wedged
        let Claim::Miss(guard) = cache.claim(&k) else {
            panic!("released key must miss again")
        };
        guard.fulfill(sample_program());
        assert!(cache.contains(&k));
        assert!(cache.stats().conserved());
    }

    #[test]
    fn concurrent_claims_coalesce_to_one_recorder() {
        let cache = ProgramCache::new(8);
        let k = key(0.3);
        let recorders = std::sync::atomic::AtomicU64::new(0);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| match cache.claim(&k) {
                    Claim::Miss(guard) => {
                        recorders.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        guard.fulfill(sample_program());
                    }
                    Claim::Hit(p) => {
                        assert!(p.ops.op_count() > 0);
                    }
                });
            }
        });
        assert_eq!(recorders.load(std::sync::atomic::Ordering::Relaxed), 1);
        let s = cache.stats();
        assert!(s.conserved(), "{s:?}");
        assert_eq!(s.lookups, 8);
        assert_eq!(s.misses, 1, "single-flight: one miss for 8 racing claims");
        assert_eq!(s.hits, 7);
    }

    #[test]
    fn panicking_recorder_releases_the_key_and_wakes_claimants() {
        // ISSUE 10: the hard-stall chaos path panics *inside* the
        // MissGuard holder, mid-recording. That panic must release the
        // Pending slot, wake every blocked claimant so one takes over
        // the recording, and leave the CacheStats conservation laws
        // intact — under an 8-thread race.
        use std::panic::{catch_unwind, AssertUnwindSafe};
        use std::sync::atomic::{AtomicU64, Ordering};
        let cache = ProgramCache::new(8);
        let k = key(0.5);
        let miss_claims = AtomicU64::new(0);
        let fulfilled = AtomicU64::new(0);
        let hit_claims = AtomicU64::new(0);
        let caught = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    let outcome = catch_unwind(AssertUnwindSafe(|| match cache.claim(&k) {
                        Claim::Miss(guard) => {
                            // The first recorder dies mid-recording
                            // (guard unfulfilled — its Drop must run
                            // during the unwind); a waiter takes over.
                            if miss_claims.fetch_add(1, Ordering::Relaxed) == 0 {
                                panic!("injected recorder panic");
                            }
                            guard.fulfill(sample_program());
                            fulfilled.fetch_add(1, Ordering::Relaxed);
                        }
                        Claim::Hit(p) => {
                            assert!(p.ops.op_count() > 0);
                            hit_claims.fetch_add(1, Ordering::Relaxed);
                        }
                    }));
                    if outcome.is_err() {
                        caught.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(caught.load(Ordering::Relaxed), 1, "exactly one injected panic");
        assert_eq!(miss_claims.load(Ordering::Relaxed), 2, "panicked recorder + takeover");
        assert_eq!(fulfilled.load(Ordering::Relaxed), 1, "exactly one recording lands");
        assert_eq!(hit_claims.load(Ordering::Relaxed), 6);
        assert!(cache.contains(&k), "the takeover recording must be resident");
        let s = cache.stats();
        assert!(s.conserved(), "{s:?}");
        assert_eq!(s.lookups, 8);
        assert_eq!(s.misses, 2);
        assert_eq!(s.hits, 6);
        assert_eq!((s.inserts, s.evictions, s.resident), (1, 0, 1));
    }

    #[test]
    fn capacity_zero_keeps_nothing_resident() {
        let cache = ProgramCache::new(0);
        let k = key(0.1);
        cache.insert(k.clone(), sample_program());
        assert!(cache.is_empty());
        assert!(!cache.contains(&k));
        assert!(cache.lookup(&k).is_none());
        let s = cache.stats();
        assert!(s.conserved(), "{s:?}");
        assert_eq!((s.inserts, s.evictions, s.resident), (1, 1, 0));
        assert_eq!(s.resident_bytes, 0);
    }

    #[test]
    fn lru_index_mirrors_ready_slots_exactly() {
        // Churn a capacity-2 cache through inserts, replacements,
        // touches, and a pending claim, then audit the invariant the
        // eviction path relies on: `lru` holds exactly one entry per
        // Ready slot, keyed by that slot's current last-use tick.
        let cache = ProgramCache::new(2);
        let program = sample_program();
        cache.insert(key(0.1), program.clone());
        cache.insert(key(0.2), program.clone());
        cache.lookup(&key(0.1)); // touch
        cache.insert(key(0.3), program.clone()); // evicts 0.2
        cache.insert(key(0.3), program.clone()); // replacement
        let pending = key(0.4);
        let Claim::Miss(guard) = cache.claim(&pending) else {
            panic!("fresh key must miss")
        };
        let inner = cache.lock_cache();
        assert_eq!(inner.lru.len() as u64, inner.stats.resident);
        for (tick, k) in &inner.lru {
            match inner.slots.get(k) {
                Some(Slot::Ready(_, last_used)) => assert_eq!(last_used, tick),
                _ => panic!("lru entry for tick {tick} has no ready slot"),
            }
        }
        assert!(
            !inner.lru.values().any(|k| *k == pending),
            "pending slots must never be indexed"
        );
        drop(inner);
        drop(guard);
        assert!(cache.stats().conserved());
    }

    #[test]
    fn replacement_counts_as_insert_plus_eviction() {
        let cache = ProgramCache::new(4);
        let k = key(0.1);
        cache.insert(k.clone(), sample_program());
        cache.insert(k.clone(), sample_program());
        let s = cache.stats();
        assert!(s.conserved(), "{s:?}");
        assert_eq!((s.inserts, s.evictions, s.resident), (2, 1, 1));
        assert_eq!(cache.len(), 1);
    }
}
